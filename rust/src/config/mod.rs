//! Runtime configuration for the PerCache engine and all baselines.
//!
//! Mirrors the paper's knobs: τ_query (QA-bank similarity threshold),
//! τ_scheduler (population-strategy cutoff), prediction stride, top-k
//! retrieval, per-layer storage limits.  Loadable from a JSON file so the
//! launcher (`percache serve --config …`) and the experiment harness share
//! one format.

use std::path::Path;

use anyhow::{Context, Result};

use crate::llm::ReuseVariant;
use crate::util::json::Json;

/// When the caches are populated.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PopulationMode {
    /// Update caches only from served user queries (RAGCache/MeanCache).
    Reactive,
    /// Also run query prediction during idle time (PerCache, Sleep-time
    /// Compute).
    Predictive,
}

/// Warm/cold shard tiering knobs (the `tiering` subsystem, DESIGN.md
/// §11).  Disabled by default: every shard stays resident, exactly the
/// pre-tiering behaviour.
#[derive(Debug, Clone)]
pub struct TieringConfig {
    pub enabled: bool,
    /// Ticks (scheduling rounds) without a request before a shard is
    /// demotion-eligible.
    pub idle_ticks_to_demote: u64,
    /// EWMA smoothing for the per-tenant request-rate tracker.
    pub activity_alpha: f64,
    /// Proactive demotion pressure point: when resident QKV bytes exceed
    /// this fraction of the global budget, the least-recently-active
    /// shard demotes even before its idle threshold.
    pub demote_watermark_frac: f64,
    /// Never demote below this many resident shards.
    pub min_resident: usize,
    /// Scheduled prefetches start hydrating this many ticks before the
    /// forecasted active period.
    pub prefetch_lead_ticks: u64,
    /// Cold-tier disk budget in bytes (0 = unlimited): demoted shard
    /// snapshots beyond the cap evict oldest-demotion-first.
    pub cold_bytes_cap: usize,
    /// Ask each shard's own `QueryPredictor` for a periodicity forecast
    /// at demotion time and schedule the prefetch it implies (on by
    /// default; a predictor that has never seen arrival ticks simply
    /// forecasts nothing).
    pub predictor_prefetch: bool,
    /// SLO veto: a tenant whose windowed SLO-miss rate is at or above
    /// this is never a demotion/pressure victim, and prefetch hydration
    /// is deferred while the system-wide miss rate sits above it.
    pub slo_veto_miss_rate: f64,
}

impl Default for TieringConfig {
    fn default() -> Self {
        TieringConfig {
            enabled: false,
            idle_ticks_to_demote: 48,
            activity_alpha: 0.2,
            demote_watermark_frac: 0.85,
            min_resident: 1,
            prefetch_lead_ticks: 2,
            cold_bytes_cap: 0,
            predictor_prefetch: true,
            slo_veto_miss_rate: 0.5,
        }
    }
}

impl TieringConfig {
    pub fn from_json(j: &Json) -> Result<Self> {
        let mut t = TieringConfig::default();
        if let Some(b) = j.get("enabled").as_bool() {
            t.enabled = b;
        }
        if let Some(v) = j.get("idle_ticks_to_demote").as_usize() {
            t.idle_ticks_to_demote = v as u64;
        }
        if let Some(v) = j.get("activity_alpha").as_f64() {
            t.activity_alpha = v;
        }
        if let Some(v) = j.get("demote_watermark_frac").as_f64() {
            t.demote_watermark_frac = v;
        }
        if let Some(v) = j.get("min_resident").as_usize() {
            t.min_resident = v;
        }
        if let Some(v) = j.get("prefetch_lead_ticks").as_usize() {
            t.prefetch_lead_ticks = v as u64;
        }
        if let Some(v) = j.get("cold_bytes_cap").as_usize() {
            t.cold_bytes_cap = v;
        }
        if let Some(b) = j.get("predictor_prefetch").as_bool() {
            t.predictor_prefetch = b;
        }
        if let Some(v) = j.get("slo_veto_miss_rate").as_f64() {
            t.slo_veto_miss_rate = v;
        }
        t.validate()?;
        Ok(t)
    }

    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.idle_ticks_to_demote >= 1, "idle_ticks_to_demote >= 1");
        anyhow::ensure!(
            self.activity_alpha > 0.0 && self.activity_alpha <= 1.0,
            "activity_alpha must be in (0,1]"
        );
        anyhow::ensure!(
            self.demote_watermark_frac > 0.0 && self.demote_watermark_frac <= 1.0,
            "demote_watermark_frac must be in (0,1]"
        );
        anyhow::ensure!(self.min_resident >= 1, "min_resident >= 1");
        anyhow::ensure!(
            self.slo_veto_miss_rate > 0.0 && self.slo_veto_miss_rate <= 1.0,
            "slo_veto_miss_rate must be in (0,1]"
        );
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.insert("enabled", self.enabled);
        o.insert("idle_ticks_to_demote", self.idle_ticks_to_demote);
        o.insert("activity_alpha", self.activity_alpha);
        o.insert("demote_watermark_frac", self.demote_watermark_frac);
        o.insert("min_resident", self.min_resident);
        o.insert("prefetch_lead_ticks", self.prefetch_lead_ticks);
        o.insert("cold_bytes_cap", self.cold_bytes_cap);
        o.insert("predictor_prefetch", self.predictor_prefetch);
        o.insert("slo_veto_miss_rate", self.slo_veto_miss_rate);
        Json::Obj(o)
    }
}

/// Runtime telemetry knobs (the `obs` subsystem, DESIGN.md §12).
/// Enabled by default: a recording call site costs one relaxed atomic
/// load plus one relaxed read-modify-write, and `percache exp obs`
/// holds the end-to-end overhead under 3%.
#[derive(Debug, Clone)]
pub struct ObsConfig {
    pub enabled: bool,
    /// Total event-journal capacity (records), split across stripes.
    pub journal_capacity: usize,
    /// Request-scoped causal tracing (DESIGN.md §16).  Off by default:
    /// a disabled tracer costs one relaxed atomic load per call site and
    /// does no heap work.
    pub trace_enabled: bool,
    /// Trace 1-in-N requests (1 = every request).
    pub trace_sample_every: u64,
    /// Tail-exemplar reservoir: K slowest traces kept per tenant per
    /// window.
    pub trace_tail_k: usize,
    /// Tail-exemplar reservoir: uniform-sample slots per tenant per
    /// window.
    pub trace_uniform_k: usize,
}

impl Default for ObsConfig {
    fn default() -> Self {
        ObsConfig {
            enabled: true,
            journal_capacity: 1024,
            trace_enabled: false,
            trace_sample_every: 8,
            trace_tail_k: 4,
            trace_uniform_k: 4,
        }
    }
}

impl ObsConfig {
    pub fn from_json(j: &Json) -> Result<Self> {
        let mut o = ObsConfig::default();
        if let Some(b) = j.get("enabled").as_bool() {
            o.enabled = b;
        }
        if let Some(v) = j.get("journal_capacity").as_usize() {
            o.journal_capacity = v;
        }
        if let Some(b) = j.get("trace_enabled").as_bool() {
            o.trace_enabled = b;
        }
        if let Some(v) = j.get("trace_sample_every").as_usize() {
            o.trace_sample_every = v as u64;
        }
        if let Some(v) = j.get("trace_tail_k").as_usize() {
            o.trace_tail_k = v;
        }
        if let Some(v) = j.get("trace_uniform_k").as_usize() {
            o.trace_uniform_k = v;
        }
        o.validate()?;
        Ok(o)
    }

    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.journal_capacity >= 1, "journal_capacity >= 1");
        anyhow::ensure!(self.trace_sample_every >= 1, "trace_sample_every >= 1");
        anyhow::ensure!(
            !self.trace_enabled || self.trace_tail_k + self.trace_uniform_k >= 1,
            "tracing needs at least one exemplar slot (trace_tail_k + trace_uniform_k >= 1)"
        );
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.insert("enabled", self.enabled);
        o.insert("journal_capacity", self.journal_capacity);
        o.insert("trace_enabled", self.trace_enabled);
        o.insert("trace_sample_every", self.trace_sample_every);
        o.insert("trace_tail_k", self.trace_tail_k);
        o.insert("trace_uniform_k", self.trace_uniform_k);
        Json::Obj(o)
    }

    /// Push these knobs into the global obs registry (the CLI entry
    /// points call this once after loading their config).
    pub fn apply(&self) {
        crate::obs::set_enabled(self.enabled);
        crate::obs::registry().journal().set_capacity(self.journal_capacity);
        let tracer = crate::obs::tracer();
        tracer.set_sample_every(self.trace_sample_every);
        tracer.set_exemplar_config(crate::obs::ExemplarConfig {
            tail_k: self.trace_tail_k,
            uniform_k: self.trace_uniform_k,
            ..crate::obs::ExemplarConfig::default()
        });
        tracer.set_enabled(self.trace_enabled);
    }
}

/// SLO-aware control knobs (DESIGN.md §14): how per-tenant SLO-miss and
/// queue-delay signals, read back from the obs metrics registry, feed
/// the governor's utility and the router's load shedding.  With no
/// signals published (`TenantRegistry::set_slo_signals` never called)
/// every knob is inert and behaviour matches the pre-SLO control plane.
#[derive(Debug, Clone)]
pub struct SloConfig {
    /// Utility boost per unit of windowed SLO-miss rate.
    pub miss_weight: f64,
    /// Utility boost per unit of queue-delay/target ratio (the ratio is
    /// clamped to 1 so delay alone cannot dominate).
    pub delay_weight: f64,
    /// Cap on the combined SLO utility boost: saturated signals scale
    /// every shard uniformly instead of thrashing the plan.
    pub boost_cap: f64,
    /// Windowed miss rate at which a tenant's shedding streak grows.
    pub shed_miss_rate: f64,
    /// Windowed miss rate at which an engaged shed starts cooling off.
    pub unshed_miss_rate: f64,
    /// Consecutive violating (resp. healthy) windows before shedding
    /// engages (resp. disengages).
    pub shed_windows: u32,
    /// While shedding, the router admits only up to this fraction of
    /// the per-tenant queue cap (min 1).
    pub shed_queue_frac: f64,
}

impl Default for SloConfig {
    fn default() -> Self {
        SloConfig {
            miss_weight: 2.0,
            delay_weight: 1.0,
            boost_cap: 4.0,
            shed_miss_rate: 0.5,
            unshed_miss_rate: 0.1,
            shed_windows: 2,
            shed_queue_frac: 0.125,
        }
    }
}

impl SloConfig {
    pub fn from_json(j: &Json) -> Result<Self> {
        let mut s = SloConfig::default();
        if let Some(v) = j.get("miss_weight").as_f64() {
            s.miss_weight = v;
        }
        if let Some(v) = j.get("delay_weight").as_f64() {
            s.delay_weight = v;
        }
        if let Some(v) = j.get("boost_cap").as_f64() {
            s.boost_cap = v;
        }
        if let Some(v) = j.get("shed_miss_rate").as_f64() {
            s.shed_miss_rate = v;
        }
        if let Some(v) = j.get("unshed_miss_rate").as_f64() {
            s.unshed_miss_rate = v;
        }
        if let Some(v) = j.get("shed_windows").as_usize() {
            s.shed_windows = v as u32;
        }
        if let Some(v) = j.get("shed_queue_frac").as_f64() {
            s.shed_queue_frac = v;
        }
        s.validate()?;
        Ok(s)
    }

    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.miss_weight >= 0.0, "miss_weight must be >= 0");
        anyhow::ensure!(self.delay_weight >= 0.0, "delay_weight must be >= 0");
        anyhow::ensure!(self.boost_cap >= 0.0, "boost_cap must be >= 0");
        anyhow::ensure!(
            self.shed_miss_rate > 0.0 && self.shed_miss_rate <= 1.0,
            "shed_miss_rate must be in (0,1]"
        );
        anyhow::ensure!(
            self.unshed_miss_rate >= 0.0 && self.unshed_miss_rate < self.shed_miss_rate,
            "unshed_miss_rate must be in [0, shed_miss_rate)"
        );
        anyhow::ensure!(self.shed_windows >= 1, "shed_windows >= 1");
        anyhow::ensure!(
            self.shed_queue_frac > 0.0 && self.shed_queue_frac <= 1.0,
            "shed_queue_frac must be in (0,1]"
        );
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.insert("miss_weight", self.miss_weight);
        o.insert("delay_weight", self.delay_weight);
        o.insert("boost_cap", self.boost_cap);
        o.insert("shed_miss_rate", self.shed_miss_rate);
        o.insert("unshed_miss_rate", self.unshed_miss_rate);
        o.insert("shed_windows", self.shed_windows as usize);
        o.insert("shed_queue_frac", self.shed_queue_frac);
        Json::Obj(o)
    }

    /// The per-tenant queue cap while shedding is engaged.
    pub fn shed_queue_cap(&self, queue_cap: usize) -> usize {
        ((queue_cap as f64 * self.shed_queue_frac) as usize).max(1)
    }
}

/// Cross-tenant content-addressed slice pool knobs (the `pool`
/// subsystem, DESIGN.md §15).  Disabled by default: every shard stores
/// all of its slices privately — byte-identical to pre-pool behaviour.
#[derive(Debug, Clone)]
pub struct PoolConfig {
    pub enabled: bool,
    /// Pool capacity in bytes, reserved off the top of
    /// `global_qkv_bytes`; the governor plans the remainder across
    /// shards, so exclusive allocations + the pool reserve still sum
    /// exactly to the global budget.
    pub pool_bytes: usize,
    /// Position-aware reuse (RAGCache's reorder-vs-recompute
    /// trade-off): compose a pooled chunk's cached KV into prompts
    /// where the chunk appears at a different offset, paying the
    /// re-anchor surcharge, instead of recomputing it from scratch.
    pub reanchor: bool,
    /// Modeled re-anchor cost, as a fraction of a full prefill of the
    /// re-anchored segment.
    pub reanchor_cost_frac: f64,
}

impl Default for PoolConfig {
    fn default() -> Self {
        PoolConfig {
            enabled: false,
            pool_bytes: 16 << 20,
            reanchor: false,
            reanchor_cost_frac: 0.25,
        }
    }
}

impl PoolConfig {
    pub fn from_json(j: &Json) -> Result<Self> {
        let mut p = PoolConfig::default();
        if let Some(b) = j.get("enabled").as_bool() {
            p.enabled = b;
        }
        if let Some(v) = j.get("pool_bytes").as_usize() {
            p.pool_bytes = v;
        }
        if let Some(b) = j.get("reanchor").as_bool() {
            p.reanchor = b;
        }
        if let Some(v) = j.get("reanchor_cost_frac").as_f64() {
            p.reanchor_cost_frac = v;
        }
        p.validate()?;
        Ok(p)
    }

    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            !self.enabled || self.pool_bytes >= 1,
            "pool_bytes must be >= 1 when the pool is enabled"
        );
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.reanchor_cost_frac),
            "reanchor_cost_frac must be in [0,1]"
        );
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.insert("enabled", self.enabled);
        o.insert("pool_bytes", self.pool_bytes);
        o.insert("reanchor", self.reanchor);
        o.insert("reanchor_cost_frac", self.reanchor_cost_frac);
        Json::Obj(o)
    }
}

/// Multi-tenant serving knobs (the `tenancy` subsystem).  Disabled by
/// default: single-tenant mode is a registry with one shard holding the
/// whole budget, which leaves the paper experiments untouched.
#[derive(Debug, Clone)]
pub struct TenancyConfig {
    pub enabled: bool,
    pub max_tenants: usize,
    /// Device-wide QKV byte budget shared by all tenant shards.
    pub global_qkv_bytes: usize,
    /// QA bank budget per tenant (small, so it stays per-shard).
    pub qa_bytes_per_tenant: usize,
    /// Fraction of the fair share (global/n) guaranteed to every shard.
    pub floor_frac: f64,
    /// Governor hysteresis: skip rebalances smaller than this fraction.
    pub hysteresis_frac: f64,
    /// Governor cadence, in serves.
    pub rebalance_every: usize,
    /// Router admission control: per-tenant / global queue caps.
    pub queue_cap: usize,
    pub global_queue_cap: usize,
    /// EWMA smoothing for the per-shard utility signal.
    pub utility_alpha: f64,
    /// Queueing signal weight: a shard's governor utility is multiplied
    /// by (1 + queue_weight × queue depth), so backlogged tenants gain
    /// bytes and are never demotion candidates.
    pub queue_weight: f64,
    /// Warm/cold shard tiering (off by default).
    pub tiering: TieringConfig,
    /// SLO-aware governor boost + admission shedding (inert until SLO
    /// signals are published, see DESIGN.md §14).
    pub slo: SloConfig,
    /// Cross-tenant content-addressed slice pool (off by default).
    pub pool: PoolConfig,
}

impl Default for TenancyConfig {
    fn default() -> Self {
        TenancyConfig {
            enabled: false,
            max_tenants: 64,
            global_qkv_bytes: 80 << 20, // the single-tenant default, shared
            qa_bytes_per_tenant: 1 << 20,
            floor_frac: 0.25,
            hysteresis_frac: 0.05,
            rebalance_every: 16,
            queue_cap: 32,
            global_queue_cap: 256,
            utility_alpha: 0.2,
            queue_weight: 0.5,
            tiering: TieringConfig::default(),
            slo: SloConfig::default(),
            pool: PoolConfig::default(),
        }
    }
}

impl TenancyConfig {
    pub fn from_json(j: &Json) -> Result<Self> {
        let mut t = TenancyConfig::default();
        if let Some(b) = j.get("enabled").as_bool() {
            t.enabled = b;
        }
        if let Some(v) = j.get("max_tenants").as_usize() {
            t.max_tenants = v;
        }
        if let Some(v) = j.get("global_qkv_bytes").as_usize() {
            t.global_qkv_bytes = v;
        }
        if let Some(v) = j.get("qa_bytes_per_tenant").as_usize() {
            t.qa_bytes_per_tenant = v;
        }
        if let Some(v) = j.get("floor_frac").as_f64() {
            t.floor_frac = v;
        }
        if let Some(v) = j.get("hysteresis_frac").as_f64() {
            t.hysteresis_frac = v;
        }
        if let Some(v) = j.get("rebalance_every").as_usize() {
            t.rebalance_every = v;
        }
        if let Some(v) = j.get("queue_cap").as_usize() {
            t.queue_cap = v;
        }
        if let Some(v) = j.get("global_queue_cap").as_usize() {
            t.global_queue_cap = v;
        }
        if let Some(v) = j.get("utility_alpha").as_f64() {
            t.utility_alpha = v;
        }
        if let Some(v) = j.get("queue_weight").as_f64() {
            t.queue_weight = v;
        }
        if j.get("tiering").as_obj().is_some() {
            t.tiering = TieringConfig::from_json(j.get("tiering"))?;
        }
        if j.get("slo").as_obj().is_some() {
            t.slo = SloConfig::from_json(j.get("slo"))?;
        }
        if j.get("pool").as_obj().is_some() {
            t.pool = PoolConfig::from_json(j.get("pool"))?;
        }
        t.validate()?;
        Ok(t)
    }

    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.max_tenants >= 1, "max_tenants >= 1");
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.floor_frac),
            "floor_frac must be in [0,1]"
        );
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.hysteresis_frac),
            "hysteresis_frac must be in [0,1]"
        );
        anyhow::ensure!(self.rebalance_every >= 1, "rebalance_every >= 1");
        anyhow::ensure!(self.queue_cap >= 1, "queue_cap >= 1");
        anyhow::ensure!(self.global_queue_cap >= 1, "global_queue_cap >= 1");
        anyhow::ensure!(
            self.utility_alpha > 0.0 && self.utility_alpha <= 1.0,
            "utility_alpha must be in (0,1]"
        );
        anyhow::ensure!(self.queue_weight >= 0.0, "queue_weight must be >= 0");
        self.tiering.validate()?;
        self.slo.validate()?;
        self.pool.validate()?;
        anyhow::ensure!(
            !self.pool.enabled || self.pool.pool_bytes < self.global_qkv_bytes,
            "pool_bytes must leave shard budget under global_qkv_bytes"
        );
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.insert("enabled", self.enabled);
        o.insert("max_tenants", self.max_tenants);
        o.insert("global_qkv_bytes", self.global_qkv_bytes);
        o.insert("qa_bytes_per_tenant", self.qa_bytes_per_tenant);
        o.insert("floor_frac", self.floor_frac);
        o.insert("hysteresis_frac", self.hysteresis_frac);
        o.insert("rebalance_every", self.rebalance_every);
        o.insert("queue_cap", self.queue_cap);
        o.insert("global_queue_cap", self.global_queue_cap);
        o.insert("utility_alpha", self.utility_alpha);
        o.insert("queue_weight", self.queue_weight);
        o.insert("tiering", self.tiering.to_json());
        o.insert("slo", self.slo.to_json());
        o.insert("pool", self.pool.to_json());
        Json::Obj(o)
    }
}

#[derive(Debug, Clone)]
pub struct PerCacheConfig {
    /// Model config name from the manifest ("llama" / "qwen").
    pub model: String,

    // -- hierarchical cache -------------------------------------------------
    /// QA-bank cosine-similarity threshold τ_query (paper default 0.85).
    pub tau_query: f64,
    /// Enable the QA bank layer (ablation switch).
    pub qa_enabled: bool,
    /// Enable the QKV cache layer (ablation switch).
    pub qkv_enabled: bool,
    /// Q+K+V reuse (PerCache) vs K/V-only (RAGCache baseline).
    pub reuse_variant: ReuseVariant,
    /// QA bank storage budget in bytes (paper: ~100 MB, scaled here).
    pub qa_storage_bytes: usize,
    /// QKV cache storage budget in bytes (paper: 6–12 GB, scaled here).
    pub qkv_storage_bytes: usize,

    // -- prediction ----------------------------------------------------------
    pub population: PopulationMode,
    /// Queries generated per prediction round (paper: 1–5, default 5).
    pub prediction_stride: usize,

    // -- scheduler ------------------------------------------------------------
    /// Enable the cache scheduler (adaptive population + conversions).
    pub scheduler_enabled: bool,
    /// τ_scheduler: above this threshold, population skips decoding.
    pub tau_scheduler: f64,

    // -- RAG pipeline ----------------------------------------------------------
    /// Chunks retrieved per query (paper uses top-2; grid allows up to 3).
    pub top_k: usize,
    /// Hybrid retrieval weight: score = α·BM25 + (1-α)·cosine.
    pub hybrid_alpha: f64,
    /// k_refresh for dynamic cache refresh (§4.1.3).
    pub refresh_top_k: usize,

    // -- generation --------------------------------------------------------------
    /// Decode budget per answer.
    pub decode_tokens: usize,

    /// System prompt prepended to every RAG prompt (one segment).
    pub system_prompt: String,

    // -- persistence ---------------------------------------------------------
    /// Directory for durable cache state (slice store manifest + warm
    /// restart snapshots, DESIGN.md §10).  None = memory-only caches.
    pub persist_dir: Option<String>,

    // -- multi-tenant serving -----------------------------------------------
    pub tenancy: TenancyConfig,

    // -- telemetry ------------------------------------------------------------
    pub obs: ObsConfig,
}

impl Default for PerCacheConfig {
    fn default() -> Self {
        PerCacheConfig {
            model: "llama".to_string(),
            tau_query: 0.85,
            qa_enabled: true,
            qkv_enabled: true,
            reuse_variant: ReuseVariant::Qkv,
            // scaled budgets: one llama chunk slice is ~786 KB; defaults
            // hold ~100 slices (paper-equivalent ≈ 8.7 GB of 87 MB slices)
            qa_storage_bytes: 1 << 20,        // 1 MB
            qkv_storage_bytes: 80 << 20,      // 80 MB
            population: PopulationMode::Predictive,
            prediction_stride: 5,
            scheduler_enabled: true,
            tau_scheduler: 0.87,
            top_k: 2,
            hybrid_alpha: 0.5,
            refresh_top_k: 2,
            decode_tokens: 24,
            system_prompt: "you are a smartphone assistant answer the user \
                            question using the retrieved personal data"
                .to_string(),
            persist_dir: None,
            tenancy: TenancyConfig::default(),
            obs: ObsConfig::default(),
        }
    }
}

impl PerCacheConfig {
    /// Parse from JSON; any omitted field keeps its default.
    pub fn from_json(j: &Json) -> Result<Self> {
        let mut c = PerCacheConfig::default();
        if let Some(s) = j.get("model").as_str() {
            c.model = s.to_string();
        }
        if let Some(v) = j.get("tau_query").as_f64() {
            c.tau_query = v;
        }
        if let Some(b) = j.get("qa_enabled").as_bool() {
            c.qa_enabled = b;
        }
        if let Some(b) = j.get("qkv_enabled").as_bool() {
            c.qkv_enabled = b;
        }
        if let Some(s) = j.get("reuse_variant").as_str() {
            c.reuse_variant = match s {
                "qkv" => ReuseVariant::Qkv,
                "kv" => ReuseVariant::Kv,
                other => anyhow::bail!("reuse_variant must be qkv|kv, got {other}"),
            };
        }
        if let Some(v) = j.get("qa_storage_bytes").as_usize() {
            c.qa_storage_bytes = v;
        }
        if let Some(v) = j.get("qkv_storage_bytes").as_usize() {
            c.qkv_storage_bytes = v;
        }
        if let Some(s) = j.get("population").as_str() {
            c.population = match s {
                "reactive" => PopulationMode::Reactive,
                "predictive" => PopulationMode::Predictive,
                other => anyhow::bail!("population must be reactive|predictive, got {other}"),
            };
        }
        if let Some(v) = j.get("prediction_stride").as_usize() {
            c.prediction_stride = v;
        }
        if let Some(b) = j.get("scheduler_enabled").as_bool() {
            c.scheduler_enabled = b;
        }
        if let Some(v) = j.get("tau_scheduler").as_f64() {
            c.tau_scheduler = v;
        }
        if let Some(v) = j.get("top_k").as_usize() {
            c.top_k = v;
        }
        if let Some(v) = j.get("hybrid_alpha").as_f64() {
            c.hybrid_alpha = v;
        }
        if let Some(v) = j.get("refresh_top_k").as_usize() {
            c.refresh_top_k = v;
        }
        if let Some(v) = j.get("decode_tokens").as_usize() {
            c.decode_tokens = v;
        }
        if let Some(s) = j.get("system_prompt").as_str() {
            c.system_prompt = s.to_string();
        }
        if let Some(s) = j.get("persist_dir").as_str() {
            c.persist_dir = if s.is_empty() { None } else { Some(s.to_string()) };
        }
        if j.get("tenancy").as_obj().is_some() {
            c.tenancy = TenancyConfig::from_json(j.get("tenancy"))?;
        }
        if j.get("obs").as_obj().is_some() {
            c.obs = ObsConfig::from_json(j.get("obs"))?;
        }
        c.validate()?;
        Ok(c)
    }

    pub fn from_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        let j = Json::parse(&text).context("parsing config json")?;
        Self::from_json(&j)
    }

    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.tau_query),
            "tau_query must be in [0,1]"
        );
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.hybrid_alpha),
            "hybrid_alpha must be in [0,1]"
        );
        anyhow::ensure!(self.prediction_stride >= 1, "prediction_stride >= 1");
        anyhow::ensure!(
            (1..=crate::llm::MAX_SEGMENTS - 2).contains(&self.top_k),
            "top_k must fit the bucket grid (1..={})",
            crate::llm::MAX_SEGMENTS - 2
        );
        anyhow::ensure!(self.decode_tokens >= 1, "decode_tokens >= 1");
        self.tenancy.validate()?;
        self.obs.validate()?;
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.insert("model", self.model.as_str());
        o.insert("tau_query", self.tau_query);
        o.insert("qa_enabled", self.qa_enabled);
        o.insert("qkv_enabled", self.qkv_enabled);
        o.insert(
            "reuse_variant",
            match self.reuse_variant {
                ReuseVariant::Qkv => "qkv",
                ReuseVariant::Kv => "kv",
            },
        );
        o.insert("qa_storage_bytes", self.qa_storage_bytes);
        o.insert("qkv_storage_bytes", self.qkv_storage_bytes);
        o.insert(
            "population",
            match self.population {
                PopulationMode::Reactive => "reactive",
                PopulationMode::Predictive => "predictive",
            },
        );
        o.insert("prediction_stride", self.prediction_stride);
        o.insert("scheduler_enabled", self.scheduler_enabled);
        o.insert("tau_scheduler", self.tau_scheduler);
        o.insert("top_k", self.top_k);
        o.insert("hybrid_alpha", self.hybrid_alpha);
        o.insert("refresh_top_k", self.refresh_top_k);
        o.insert("decode_tokens", self.decode_tokens);
        o.insert("system_prompt", self.system_prompt.as_str());
        if let Some(d) = &self.persist_dir {
            o.insert("persist_dir", d.as_str());
        }
        o.insert("tenancy", self.tenancy.to_json());
        o.insert("obs", self.obs.to_json());
        Json::Obj(o)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        PerCacheConfig::default().validate().unwrap();
    }

    #[test]
    fn json_roundtrip() {
        let mut c = PerCacheConfig::default();
        c.tau_query = 0.8;
        c.model = "qwen".into();
        c.population = PopulationMode::Reactive;
        c.reuse_variant = ReuseVariant::Kv;
        let j = c.to_json();
        let c2 = PerCacheConfig::from_json(&j).unwrap();
        assert_eq!(c2.tau_query, 0.8);
        assert_eq!(c2.model, "qwen");
        assert_eq!(c2.population, PopulationMode::Reactive);
        assert_eq!(c2.reuse_variant, ReuseVariant::Kv);
    }

    #[test]
    fn persist_dir_roundtrip_and_default_off() {
        let c = PerCacheConfig::default();
        assert!(c.persist_dir.is_none(), "persistence must be opt-in");
        let mut c = c;
        c.persist_dir = Some("/tmp/percache-state".to_string());
        let j = c.to_json();
        let c2 = PerCacheConfig::from_json(&j).unwrap();
        assert_eq!(c2.persist_dir.as_deref(), Some("/tmp/percache-state"));
        // empty string means "off" (CLI-friendly)
        let j = Json::parse(r#"{"persist_dir": ""}"#).unwrap();
        assert!(PerCacheConfig::from_json(&j).unwrap().persist_dir.is_none());
    }

    #[test]
    fn partial_json_keeps_defaults() {
        let j = Json::parse(r#"{"tau_query": 0.9}"#).unwrap();
        let c = PerCacheConfig::from_json(&j).unwrap();
        assert_eq!(c.tau_query, 0.9);
        assert_eq!(c.model, "llama");
        assert_eq!(c.prediction_stride, 5);
    }

    #[test]
    fn tenancy_block_roundtrip_and_defaults() {
        let mut c = PerCacheConfig::default();
        assert!(!c.tenancy.enabled, "tenancy must default off");
        c.tenancy.enabled = true;
        c.tenancy.max_tenants = 8;
        c.tenancy.global_qkv_bytes = 123 << 20;
        let j = c.to_json();
        let c2 = PerCacheConfig::from_json(&j).unwrap();
        assert!(c2.tenancy.enabled);
        assert_eq!(c2.tenancy.max_tenants, 8);
        assert_eq!(c2.tenancy.global_qkv_bytes, 123 << 20);

        // partial tenancy block keeps the other defaults
        let j = Json::parse(r#"{"tenancy": {"max_tenants": 4}}"#).unwrap();
        let c3 = PerCacheConfig::from_json(&j).unwrap();
        assert_eq!(c3.tenancy.max_tenants, 4);
        assert_eq!(c3.tenancy.rebalance_every, 16);
        assert!(!c3.tenancy.enabled);
    }

    #[test]
    fn tiering_block_roundtrip_and_defaults() {
        let mut c = PerCacheConfig::default();
        assert!(!c.tenancy.tiering.enabled, "tiering must default off");
        c.tenancy.tiering.enabled = true;
        c.tenancy.tiering.idle_ticks_to_demote = 12;
        c.tenancy.tiering.min_resident = 2;
        c.tenancy.queue_weight = 1.5;
        let j = c.to_json();
        let c2 = PerCacheConfig::from_json(&j).unwrap();
        assert!(c2.tenancy.tiering.enabled);
        assert_eq!(c2.tenancy.tiering.idle_ticks_to_demote, 12);
        assert_eq!(c2.tenancy.tiering.min_resident, 2);
        assert_eq!(c2.tenancy.queue_weight, 1.5);

        // partial tiering block keeps the other defaults
        let j = Json::parse(r#"{"tenancy": {"tiering": {"enabled": true}}}"#).unwrap();
        let c3 = PerCacheConfig::from_json(&j).unwrap();
        assert!(c3.tenancy.tiering.enabled);
        assert_eq!(c3.tenancy.tiering.idle_ticks_to_demote, 48);
        assert_eq!(c3.tenancy.tiering.demote_watermark_frac, 0.85);
    }

    #[test]
    fn pool_block_roundtrip_and_defaults() {
        let mut c = PerCacheConfig::default();
        assert!(!c.tenancy.pool.enabled, "pool must default off");
        c.tenancy.pool.enabled = true;
        c.tenancy.pool.pool_bytes = 4 << 20;
        c.tenancy.pool.reanchor = true;
        c.tenancy.pool.reanchor_cost_frac = 0.5;
        let j = c.to_json();
        let c2 = PerCacheConfig::from_json(&j).unwrap();
        assert!(c2.tenancy.pool.enabled);
        assert_eq!(c2.tenancy.pool.pool_bytes, 4 << 20);
        assert!(c2.tenancy.pool.reanchor);
        assert_eq!(c2.tenancy.pool.reanchor_cost_frac, 0.5);

        // partial pool block keeps the other defaults
        let j = Json::parse(r#"{"tenancy": {"pool": {"enabled": true}}}"#).unwrap();
        let c3 = PerCacheConfig::from_json(&j).unwrap();
        assert!(c3.tenancy.pool.enabled);
        assert_eq!(c3.tenancy.pool.pool_bytes, 16 << 20);
        assert!(!c3.tenancy.pool.reanchor);
        assert_eq!(c3.tenancy.pool.reanchor_cost_frac, 0.25);
    }

    #[test]
    fn pool_invalid_rejected() {
        let j = Json::parse(r#"{"tenancy": {"pool": {"reanchor_cost_frac": 1.5}}}"#).unwrap();
        assert!(PerCacheConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"tenancy": {"pool": {"enabled": true, "pool_bytes": 0}}}"#)
            .unwrap();
        assert!(PerCacheConfig::from_json(&j).is_err());
        // pool must fit strictly inside the global budget
        let big = r#"{"tenancy": {"pool": {"enabled": true, "pool_bytes": 999999999999}}}"#;
        let j = Json::parse(big).unwrap();
        assert!(PerCacheConfig::from_json(&j).is_err(), "pool larger than global budget");
    }

    #[test]
    fn obs_block_roundtrip_and_defaults() {
        let mut c = PerCacheConfig::default();
        assert!(c.obs.enabled, "telemetry must default on");
        assert_eq!(c.obs.journal_capacity, 1024);
        c.obs.enabled = false;
        c.obs.journal_capacity = 64;
        let j = c.to_json();
        let c2 = PerCacheConfig::from_json(&j).unwrap();
        assert!(!c2.obs.enabled);
        assert_eq!(c2.obs.journal_capacity, 64);

        // partial obs block keeps the other defaults
        let j = Json::parse(r#"{"obs": {"journal_capacity": 256}}"#).unwrap();
        let c3 = PerCacheConfig::from_json(&j).unwrap();
        assert_eq!(c3.obs.journal_capacity, 256);
        assert!(c3.obs.enabled);

        // invalid capacity rejected
        let j = Json::parse(r#"{"obs": {"journal_capacity": 0}}"#).unwrap();
        assert!(PerCacheConfig::from_json(&j).is_err());
    }

    #[test]
    fn trace_knobs_roundtrip_and_defaults() {
        let mut c = PerCacheConfig::default();
        assert!(!c.obs.trace_enabled, "tracing must default off");
        assert_eq!(c.obs.trace_sample_every, 8);
        assert_eq!(c.obs.trace_tail_k, 4);
        assert_eq!(c.obs.trace_uniform_k, 4);
        c.obs.trace_enabled = true;
        c.obs.trace_sample_every = 2;
        c.obs.trace_tail_k = 8;
        c.obs.trace_uniform_k = 0;
        let j = c.to_json();
        let c2 = PerCacheConfig::from_json(&j).unwrap();
        assert!(c2.obs.trace_enabled);
        assert_eq!(c2.obs.trace_sample_every, 2);
        assert_eq!(c2.obs.trace_tail_k, 8);
        assert_eq!(c2.obs.trace_uniform_k, 0);

        // invalid trace knobs rejected
        let j = Json::parse(r#"{"obs": {"trace_sample_every": 0}}"#).unwrap();
        assert!(PerCacheConfig::from_json(&j).is_err());
        let j = Json::parse(
            r#"{"obs": {"trace_enabled": true, "trace_tail_k": 0, "trace_uniform_k": 0}}"#,
        )
        .unwrap();
        assert!(
            PerCacheConfig::from_json(&j).is_err(),
            "enabled tracing with zero exemplar slots"
        );
    }

    #[test]
    fn slo_block_roundtrip_and_defaults() {
        let mut c = PerCacheConfig::default();
        assert_eq!(c.tenancy.slo.shed_windows, 2);
        assert_eq!(c.tenancy.slo.shed_queue_cap(32), 4);
        c.tenancy.slo.miss_weight = 3.0;
        c.tenancy.slo.shed_miss_rate = 0.6;
        c.tenancy.tiering.cold_bytes_cap = 1 << 20;
        c.tenancy.tiering.predictor_prefetch = false;
        let j = c.to_json();
        let c2 = PerCacheConfig::from_json(&j).unwrap();
        assert_eq!(c2.tenancy.slo.miss_weight, 3.0);
        assert_eq!(c2.tenancy.slo.shed_miss_rate, 0.6);
        assert_eq!(c2.tenancy.tiering.cold_bytes_cap, 1 << 20);
        assert!(!c2.tenancy.tiering.predictor_prefetch);

        // partial slo block keeps the other defaults
        let j = Json::parse(r#"{"tenancy": {"slo": {"boost_cap": 8.0}}}"#).unwrap();
        let c3 = PerCacheConfig::from_json(&j).unwrap();
        assert_eq!(c3.tenancy.slo.boost_cap, 8.0);
        assert_eq!(c3.tenancy.slo.delay_weight, 1.0);
        assert_eq!(c3.tenancy.tiering.cold_bytes_cap, 0, "cold tier unlimited by default");
        assert!(c3.tenancy.tiering.predictor_prefetch);
    }

    #[test]
    fn slo_invalid_rejected() {
        let j = Json::parse(r#"{"tenancy": {"slo": {"shed_miss_rate": 0.0}}}"#).unwrap();
        assert!(PerCacheConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"tenancy": {"slo": {"unshed_miss_rate": 0.9}}}"#).unwrap();
        assert!(PerCacheConfig::from_json(&j).is_err(), "unshed must stay below shed");
        let j = Json::parse(r#"{"tenancy": {"slo": {"shed_queue_frac": 0.0}}}"#).unwrap();
        assert!(PerCacheConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"tenancy": {"tiering": {"slo_veto_miss_rate": 1.5}}}"#).unwrap();
        assert!(PerCacheConfig::from_json(&j).is_err());
    }

    #[test]
    fn tiering_invalid_rejected() {
        let j = Json::parse(r#"{"tenancy": {"tiering": {"min_resident": 0}}}"#).unwrap();
        assert!(PerCacheConfig::from_json(&j).is_err());
        let j =
            Json::parse(r#"{"tenancy": {"tiering": {"demote_watermark_frac": 1.5}}}"#).unwrap();
        assert!(PerCacheConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"tenancy": {"queue_weight": -0.5}}"#).unwrap();
        assert!(PerCacheConfig::from_json(&j).is_err());
    }

    #[test]
    fn tenancy_invalid_rejected() {
        let j = Json::parse(r#"{"tenancy": {"max_tenants": 0}}"#).unwrap();
        assert!(PerCacheConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"tenancy": {"floor_frac": 1.5}}"#).unwrap();
        assert!(PerCacheConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"tenancy": {"utility_alpha": 0.0}}"#).unwrap();
        assert!(PerCacheConfig::from_json(&j).is_err());
    }

    #[test]
    fn invalid_rejected() {
        let j = Json::parse(r#"{"tau_query": 1.5}"#).unwrap();
        assert!(PerCacheConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"top_k": 9}"#).unwrap();
        assert!(PerCacheConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"reuse_variant": "bogus"}"#).unwrap();
        assert!(PerCacheConfig::from_json(&j).is_err());
    }
}
