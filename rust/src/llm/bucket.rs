//! Shape-bucket planner: maps (total segments, cached-prefix segments) to
//! the AOT artifact that serves the request.
//!
//! HLO artifacts are static-shape; the grid is `prefill_full_n{2..5}` and
//! `prefill_reuse_{qkv,kv}_p{1..n-1}_n{2..5}` (DESIGN.md §2).  The planner
//! is pure logic — unit-testable without a runtime.

/// Reuse flavor (PerCache stores Q too; RAGCache baseline stores only K/V).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReuseVariant {
    Qkv,
    Kv,
}

impl ReuseVariant {
    pub fn tag(&self) -> &'static str {
        match self {
            ReuseVariant::Qkv => "reuse_qkv",
            ReuseVariant::Kv => "reuse_kv",
        }
    }
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BucketPlan {
    pub artifact: String,
    pub n_seg: usize,
    /// Cached-prefix segments actually used (may be clamped below the
    /// match length when no exact bucket exists).
    pub p_seg: usize,
}

/// Grid bounds (must match configs.N_SEGMENTS).
pub const MIN_SEGMENTS: usize = 2;
pub const MAX_SEGMENTS: usize = 5;

/// Plan a prefill call.
///
/// `n_seg` — total prompt segments (sysprompt + chunks + query);
/// `matched_seg` — cache-tree prefix match length in segments.
///
/// Returns None if the prompt doesn't fit the grid (caller must re-chunk).
pub fn plan_prefill(n_seg: usize, matched_seg: usize, variant: ReuseVariant) -> Option<BucketPlan> {
    if !(MIN_SEGMENTS..=MAX_SEGMENTS).contains(&n_seg) {
        return None;
    }
    // Reuse buckets exist for every p in 1..n, so the only clamping is
    // p <= n-1 (a full-prefix match still needs the query segment computed —
    // the query text is fresh by definition, but a predicted duplicate can
    // match all n; serve it from p = n-1).
    let p = matched_seg.min(n_seg - 1);
    if p == 0 {
        return Some(BucketPlan {
            artifact: format!("prefill_full_n{n_seg}"),
            n_seg,
            p_seg: 0,
        });
    }
    Some(BucketPlan {
        artifact: format!("prefill_{}_p{p}_n{n_seg}", variant.tag()),
        n_seg,
        p_seg: p,
    })
}

/// Clamp a desired chunk count so that sysprompt + chunks + query fits the
/// bucket grid: chunks <= MAX_SEGMENTS - 2.
pub fn max_chunks() -> usize {
    MAX_SEGMENTS - 2
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_prefill_when_no_match() {
        let p = plan_prefill(4, 0, ReuseVariant::Qkv).unwrap();
        assert_eq!(p.artifact, "prefill_full_n4");
        assert_eq!(p.p_seg, 0);
    }

    #[test]
    fn reuse_bucket_names() {
        let p = plan_prefill(4, 2, ReuseVariant::Qkv).unwrap();
        assert_eq!(p.artifact, "prefill_reuse_qkv_p2_n4");
        let p = plan_prefill(3, 1, ReuseVariant::Kv).unwrap();
        assert_eq!(p.artifact, "prefill_reuse_kv_p1_n3");
    }

    #[test]
    fn full_match_clamped_to_n_minus_1() {
        let p = plan_prefill(3, 3, ReuseVariant::Qkv).unwrap();
        assert_eq!(p.p_seg, 2);
        assert_eq!(p.artifact, "prefill_reuse_qkv_p2_n3");
        let p = plan_prefill(5, 99, ReuseVariant::Qkv).unwrap();
        assert_eq!(p.p_seg, 4);
    }

    #[test]
    fn out_of_grid_rejected() {
        assert!(plan_prefill(1, 0, ReuseVariant::Qkv).is_none());
        assert!(plan_prefill(6, 0, ReuseVariant::Qkv).is_none());
    }

    #[test]
    fn every_grid_point_plans() {
        for n in MIN_SEGMENTS..=MAX_SEGMENTS {
            for m in 0..=n {
                let p = plan_prefill(n, m, ReuseVariant::Qkv).unwrap();
                assert!(p.p_seg < n);
                assert!(p.p_seg <= m);
            }
        }
    }
}
