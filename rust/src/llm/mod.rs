//! LLM engine: prefill (full / reuse) + greedy decode over the PJRT
//! artifacts.  This is the compute the hierarchical cache exists to skip.

pub mod bucket;
pub mod qkv;

use anyhow::{Context, Result};

pub use bucket::{plan_prefill, BucketPlan, ReuseVariant, MAX_SEGMENTS, MIN_SEGMENTS};
pub use qkv::QkvTensor;

use crate::metrics::ModelDims;
use crate::runtime::{Input, Runtime};
use crate::tokenizer::{EOS, PAD, SEGMENT_TOKENS};

#[derive(Debug, Clone)]
pub struct PrefillResult {
    pub logits: Vec<f32>,
    pub qkv: QkvTensor,
    /// Analytic FLOPs of the executed artifact.
    pub flops: u64,
    /// Bucket actually used (artifact name), for metrics/debug.
    pub artifact: String,
    pub reused_segments: usize,
}

#[derive(Debug, Clone)]
pub struct DecodeResult {
    pub tokens: Vec<i32>,
    pub flops: u64,
}

/// Engine bound to one model config of a Runtime.
pub struct LlmEngine<'rt> {
    rt: &'rt Runtime,
    pub model: String,
    pub dims: ModelDims,
    pub decode_ctx: usize,
    pub gen_tokens: usize,
}

impl<'rt> LlmEngine<'rt> {
    pub fn new(rt: &'rt Runtime, model: &str) -> Result<Self> {
        let mm = rt.manifest.model(model)?;
        Ok(LlmEngine {
            rt,
            model: model.to_string(),
            dims: mm.dims,
            decode_ctx: rt.manifest.decode_ctx,
            gen_tokens: rt.manifest.decode_gen_tokens,
        })
    }

    pub fn runtime(&self) -> &Runtime {
        self.rt
    }

    /// Prefill a segment-padded prompt.  `prefix` supplies cached QKV
    /// tensors for the first `prefix.n_segments()` segments; the planner
    /// clamps to the available bucket grid.
    pub fn prefill(
        &self,
        tokens: &[i32],
        prefix: Option<(&QkvTensor, ReuseVariant)>,
    ) -> Result<PrefillResult> {
        anyhow::ensure!(
            tokens.len() % SEGMENT_TOKENS == 0,
            "prompt must be segment-padded (got {} tokens)",
            tokens.len()
        );
        let n_seg = tokens.len() / SEGMENT_TOKENS;
        let matched = prefix.map(|(t, _)| t.n_segments()).unwrap_or(0);
        let variant = prefix.map(|(_, v)| v).unwrap_or(ReuseVariant::Qkv);
        let plan = plan_prefill(n_seg, matched, variant)
            .with_context(|| format!("prompt of {n_seg} segments outside bucket grid"))?;

        let mut inputs = vec![Input::I32(tokens.to_vec(), vec![tokens.len()])];
        if plan.p_seg > 0 {
            let (qkv, _) = prefix.unwrap();
            // clamp the prefix tensor to the planned bucket length
            let p_tokens = plan.p_seg * SEGMENT_TOKENS;
            let clamped;
            let pref_data: &QkvTensor = if qkv.seq == p_tokens {
                qkv
            } else {
                clamped = qkv.slice_positions(0, p_tokens);
                &clamped
            };
            inputs.push(Input::f32_slice(&pref_data.data, pref_data.dims()));
            let out = self.rt.exec_model(&self.model, &plan.artifact, &inputs)?;
            self.unpack_prefill(out, tokens.len(), &plan, variant)
        } else {
            let out = self.rt.exec_model(&self.model, &plan.artifact, &inputs)?;
            self.unpack_prefill(out, tokens.len(), &plan, variant)
        }
    }

    fn unpack_prefill(
        &self,
        out: Vec<xla::Literal>,
        seq: usize,
        plan: &BucketPlan,
        variant: ReuseVariant,
    ) -> Result<PrefillResult> {
        anyhow::ensure!(out.len() == 2, "prefill returns (logits, qkv)");
        let logits = out[0].to_vec::<f32>().context("logits")?;
        let qkv_flat = out[1].to_vec::<f32>().context("qkv")?;
        let qkv = QkvTensor::from_flat(self.dims.layers, self.dims.d_model, seq, qkv_flat);
        let p = plan.p_seg * SEGMENT_TOKENS;
        let flops = match (plan.p_seg, variant) {
            (0, _) => self.dims.prefill_full(seq),
            (_, ReuseVariant::Qkv) => self.dims.prefill_reuse_qkv(p, seq),
            (_, ReuseVariant::Kv) => self.dims.prefill_reuse_kv(p, seq),
        };
        Ok(PrefillResult {
            logits,
            qkv,
            flops,
            artifact: plan.artifact.clone(),
            reused_segments: plan.p_seg,
        })
    }

    /// Greedy decode after a prefill.  `prompt_tokens` provides the PAD
    /// mask for the KV rows; generation stops at EOS or `max_tokens`.
    ///
    /// Uses the device-side `decode_block` artifact when the manifest has
    /// one (one KV upload per block instead of per token — see
    /// EXPERIMENTS.md §Perf); falls back to the per-token step loop
    /// otherwise.  Both paths are token-exact (pinned by python tests and
    /// `decode_paths_agree` below).
    pub fn decode(
        &self,
        prompt_tokens: &[i32],
        prefill: &PrefillResult,
        max_tokens: usize,
    ) -> Result<DecodeResult> {
        let has_block = self
            .rt
            .manifest
            .model(&self.model)
            .map(|m| m.artifacts.contains_key("decode_block"))
            .unwrap_or(false);
        if has_block {
            self.decode_blocks(prompt_tokens, prefill, max_tokens)
        } else {
            self.decode_steps(prompt_tokens, prefill, max_tokens)
        }
    }

    /// Per-token decode loop (fallback / comparison path).
    pub fn decode_steps(
        &self,
        prompt_tokens: &[i32],
        prefill: &PrefillResult,
        max_tokens: usize,
    ) -> Result<DecodeResult> {
        let ctx = self.decode_ctx;
        let d = self.dims.d_model;
        let layers = self.dims.layers;
        let s = prompt_tokens.len();
        anyhow::ensure!(s <= ctx, "prompt {s} exceeds decode ctx {ctx}");

        let mut kv = prefill.qkv.to_kv_cache(ctx);
        let mut valid = vec![0f32; ctx];
        for (i, &t) in prompt_tokens.iter().enumerate() {
            valid[i] = if t != PAD { 1.0 } else { 0.0 };
        }

        let mut tokens = Vec::with_capacity(max_tokens);
        let mut tok = argmax_antirepeat(&prefill.logits, None);
        let mut pos = s;
        let mut flops = 0u64;
        let budget = max_tokens.min(ctx - s);
        for _ in 0..budget {
            tokens.push(tok);
            if tok == EOS {
                break;
            }
            valid[pos] = 1.0;
            let out = self.rt.exec_model(
                &self.model,
                "decode_step",
                &[
                    Input::I32Scalar(tok),
                    Input::I32Scalar(pos as i32),
                    Input::f32_slice(&kv, vec![layers, 2, ctx, d]),
                    Input::F32(valid.clone(), vec![ctx]),
                ],
            )?;
            flops += self.dims.decode_step(ctx);
            anyhow::ensure!(out.len() == 3, "decode returns (logits, k, v)");
            let logits = out[0].to_vec::<f32>()?;
            let new_k = out[1].to_vec::<f32>()?;
            let new_v = out[2].to_vec::<f32>()?;
            // write new K/V rows into the host cache at `pos`
            for l in 0..layers {
                let k0 = ((l * 2) * ctx + pos) * d;
                kv[k0..k0 + d].copy_from_slice(&new_k[l * d..(l + 1) * d]);
                let v0 = ((l * 2 + 1) * ctx + pos) * d;
                kv[v0..v0 + d].copy_from_slice(&new_v[l * d..(l + 1) * d]);
            }
            pos += 1;
            tok = argmax_antirepeat(&logits, Some(tok));
        }
        Ok(DecodeResult { tokens, flops })
    }

    /// Block decode: one `decode_block` execution per `block` tokens.
    pub fn decode_blocks(
        &self,
        prompt_tokens: &[i32],
        prefill: &PrefillResult,
        max_tokens: usize,
    ) -> Result<DecodeResult> {
        let ctx = self.decode_ctx;
        let d = self.dims.d_model;
        let layers = self.dims.layers;
        let s = prompt_tokens.len();
        anyhow::ensure!(s <= ctx, "prompt {s} exceeds decode ctx {ctx}");
        let mm = self.rt.manifest.model(&self.model)?;
        let block = mm
            .artifact("decode_block")?
            .block
            .context("decode_block artifact missing block size")?;

        let mut kv = prefill.qkv.to_kv_cache(ctx);
        let mut valid = vec![0f32; ctx];
        for (i, &t) in prompt_tokens.iter().enumerate() {
            valid[i] = if t != PAD { 1.0 } else { 0.0 };
        }

        let mut tokens = Vec::with_capacity(max_tokens);
        let mut tok = argmax_antirepeat(&prefill.logits, None);
        let mut pos = s;
        let mut flops = 0u64;
        let budget = max_tokens.min(ctx - s);

        'outer: while tokens.len() < budget {
            if pos + block > ctx {
                break; // cannot fit another block (budget clamp above
                       // makes this unreachable in practice)
            }
            let out = self.rt.exec_model(
                &self.model,
                "decode_block",
                &[
                    Input::I32Scalar(tok),
                    Input::I32Scalar(pos as i32),
                    Input::f32_slice(&kv, vec![layers, 2, ctx, d]),
                    Input::F32(valid.clone(), vec![ctx]),
                ],
            )?;
            flops += (block as u64) * self.dims.decode_step(ctx);
            anyhow::ensure!(out.len() == 4, "decode_block returns 4 outputs");
            let toks = out[0].to_vec::<i32>()?;
            let ks = out[1].to_vec::<f32>()?; // [T, L, d]
            let vs = out[2].to_vec::<f32>()?;
            let next = out[3].get_first_element::<i32>()?;

            for (t, &tk) in toks.iter().enumerate().take(block) {
                tokens.push(tk);
                // write back this step's K/V rows for the next block call
                for l in 0..layers {
                    let src = (t * layers + l) * d;
                    let k0 = ((l * 2) * ctx + pos) * d;
                    kv[k0..k0 + d].copy_from_slice(&ks[src..src + d]);
                    let v0 = ((l * 2 + 1) * ctx + pos) * d;
                    kv[v0..v0 + d].copy_from_slice(&vs[src..src + d]);
                }
                valid[pos] = 1.0;
                pos += 1;
                if tk == EOS || tokens.len() >= budget {
                    break 'outer;
                }
            }
            tok = next;
        }
        Ok(DecodeResult { tokens, flops })
    }

    /// Convenience: prefill + decode in one call (the "full inference"
    /// path of the naive baseline).
    pub fn generate(
        &self,
        tokens: &[i32],
        prefix: Option<(&QkvTensor, ReuseVariant)>,
        max_tokens: usize,
    ) -> Result<(PrefillResult, DecodeResult)> {
        let pre = self.prefill(tokens, prefix)?;
        let dec = self.decode(tokens, &pre, max_tokens)?;
        Ok((pre, dec))
    }
}

/// Greedy argmax with an immediate-repeat guard: a random-weight model can
/// fall into single-token attractors; picking the runner-up on immediate
/// repeats keeps generated "answers" token-diverse enough for ROUGE/BLEU
/// comparisons to be meaningful, while staying fully deterministic.
pub fn argmax_antirepeat(logits: &[f32], last: Option<i32>) -> i32 {
    let (mut best, mut best_v) = (0usize, f32::NEG_INFINITY);
    let (mut second, mut second_v) = (0usize, f32::NEG_INFINITY);
    for (i, &v) in logits.iter().enumerate() {
        if v > best_v {
            second = best;
            second_v = best_v;
            best = i;
            best_v = v;
        } else if v > second_v {
            second = i;
            second_v = v;
        }
    }
    match last {
        Some(l) if l as usize == best && logits.len() > 1 => second as i32,
        _ => best as i32,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax_antirepeat(&[0.1, 0.9, 0.5], None), 1);
    }

    #[test]
    fn argmax_antirepeat_picks_second() {
        assert_eq!(argmax_antirepeat(&[0.1, 0.9, 0.5], Some(1)), 2);
        // different last token: keep the max
        assert_eq!(argmax_antirepeat(&[0.1, 0.9, 0.5], Some(0)), 1);
    }

    #[test]
    fn argmax_single_element() {
        assert_eq!(argmax_antirepeat(&[1.0], Some(0)), 0);
    }
}
