//! QKV tensor container + segment slicing/concatenation.
//!
//! Layout matches the artifacts: `[layers, 3(q/k/v), seq, d_model]`, f32,
//! row-major.  The cache slicer (paper §4.1.1) cuts per-segment slices out
//! of a whole-prompt tensor; the reuse path concatenates matched slices
//! back into a prefix tensor.

use crate::tokenizer::SEGMENT_TOKENS;

#[derive(Debug, Clone, PartialEq)]
pub struct QkvTensor {
    pub layers: usize,
    pub d_model: usize,
    pub seq: usize,
    /// `[layers][3][seq][d_model]` row-major.
    pub data: Vec<f32>,
}

impl QkvTensor {
    pub fn zeros(layers: usize, d_model: usize, seq: usize) -> Self {
        QkvTensor {
            layers,
            d_model,
            seq,
            data: vec![0.0; layers * 3 * seq * d_model],
        }
    }

    pub fn from_flat(layers: usize, d_model: usize, seq: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), layers * 3 * seq * d_model, "flat size mismatch");
        QkvTensor {
            layers,
            d_model,
            seq,
            data,
        }
    }

    pub fn n_segments(&self) -> usize {
        debug_assert_eq!(self.seq % SEGMENT_TOKENS, 0);
        self.seq / SEGMENT_TOKENS
    }

    pub fn byte_size(&self) -> usize {
        self.data.len() * 4
    }

    pub fn dims(&self) -> Vec<usize> {
        vec![self.layers, 3, self.seq, self.d_model]
    }

    #[inline]
    fn row_offset(&self, layer: usize, plane: usize, pos: usize) -> usize {
        ((layer * 3 + plane) * self.seq + pos) * self.d_model
    }

    /// One `[d_model]` row (q/k/v of one position in one layer).
    pub fn row(&self, layer: usize, plane: usize, pos: usize) -> &[f32] {
        let o = self.row_offset(layer, plane, pos);
        &self.data[o..o + self.d_model]
    }

    /// Copy out positions `[start, end)` into a new tensor (strided over
    /// layers/planes).
    pub fn slice_positions(&self, start: usize, end: usize) -> QkvTensor {
        assert!(start <= end && end <= self.seq, "slice out of range");
        let sub = end - start;
        let mut out = QkvTensor::zeros(self.layers, self.d_model, sub);
        for l in 0..self.layers {
            for p in 0..3 {
                let src0 = self.row_offset(l, p, start);
                let dst0 = out.row_offset(l, p, 0);
                let n = sub * self.d_model;
                out.data[dst0..dst0 + n].copy_from_slice(&self.data[src0..src0 + n]);
            }
        }
        out
    }

    /// Slice of whole segments `[seg_start, seg_end)`.
    pub fn slice_segments(&self, seg_start: usize, seg_end: usize) -> QkvTensor {
        self.slice_positions(seg_start * SEGMENT_TOKENS, seg_end * SEGMENT_TOKENS)
    }

    /// Concatenate along the sequence axis (all parts must agree on
    /// layers/d_model).
    pub fn concat(parts: &[&QkvTensor]) -> QkvTensor {
        assert!(!parts.is_empty());
        let (layers, d) = (parts[0].layers, parts[0].d_model);
        let seq: usize = parts.iter().map(|p| p.seq).sum();
        let mut out = QkvTensor::zeros(layers, d, seq);
        for l in 0..layers {
            for plane in 0..3 {
                let mut pos = 0;
                for part in parts {
                    assert_eq!(part.layers, layers);
                    assert_eq!(part.d_model, d);
                    let src0 = part.row_offset(l, plane, 0);
                    let n = part.seq * d;
                    let dst0 = out.row_offset(l, plane, pos);
                    out.data[dst0..dst0 + n].copy_from_slice(&part.data[src0..src0 + n]);
                    pos += part.seq;
                }
            }
        }
        out
    }

    /// Build a decode KV cache `[layers, 2, ctx, d_model]` from planes 1/2
    /// (K and V), zero-padded to `ctx` rows.
    pub fn to_kv_cache(&self, ctx: usize) -> Vec<f32> {
        assert!(self.seq <= ctx, "prompt longer than decode ctx");
        let d = self.d_model;
        let mut kv = vec![0f32; self.layers * 2 * ctx * d];
        for l in 0..self.layers {
            for (dst_plane, src_plane) in [(0usize, 1usize), (1, 2)] {
                let src0 = self.row_offset(l, src_plane, 0);
                let n = self.seq * d;
                let dst0 = ((l * 2 + dst_plane) * ctx) * d;
                kv[dst0..dst0 + n].copy_from_slice(&self.data[src0..src0 + n]);
            }
        }
        kv
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seq_tensor(layers: usize, d: usize, seq: usize) -> QkvTensor {
        // data[l][p][s][i] = encode a unique value per coordinate
        let mut t = QkvTensor::zeros(layers, d, seq);
        for l in 0..layers {
            for p in 0..3 {
                for s in 0..seq {
                    for i in 0..d {
                        let o = ((l * 3 + p) * seq + s) * d + i;
                        t.data[o] = (l * 1_000_000 + p * 100_000 + s * 100 + i) as f32;
                    }
                }
            }
        }
        t
    }

    #[test]
    fn slice_then_concat_roundtrips() {
        let t = seq_tensor(2, 8, 3 * SEGMENT_TOKENS);
        let a = t.slice_segments(0, 1);
        let b = t.slice_segments(1, 2);
        let c = t.slice_segments(2, 3);
        let back = QkvTensor::concat(&[&a, &b, &c]);
        assert_eq!(back, t);
        assert_eq!(a.n_segments(), 1);
    }

    #[test]
    fn slice_positions_values() {
        let t = seq_tensor(1, 4, 10);
        let s = t.slice_positions(3, 7);
        assert_eq!(s.seq, 4);
        assert_eq!(s.row(0, 2, 0), t.row(0, 2, 3));
        assert_eq!(s.row(0, 1, 3), t.row(0, 1, 6));
    }

    #[test]
    fn kv_cache_layout() {
        let t = seq_tensor(2, 4, 6);
        let ctx = 10;
        let kv = t.to_kv_cache(ctx);
        assert_eq!(kv.len(), 2 * 2 * ctx * 4);
        // layer 1, K plane (src plane 1), position 5, dim 2
        let src = t.row(1, 1, 5)[2];
        let dst = kv[((1 * 2 + 0) * ctx + 5) * 4 + 2];
        assert_eq!(src, dst);
        // padding rows are zero
        assert_eq!(kv[((0 * 2 + 0) * ctx + 9) * 4], 0.0);
    }

    #[test]
    fn byte_size() {
        let t = QkvTensor::zeros(4, 256, SEGMENT_TOKENS);
        // one segment slice for the llama config: 4*3*64*256*4 B = 786 KB
        assert_eq!(t.byte_size(), 4 * 3 * 64 * 256 * 4);
    }

    #[test]
    #[should_panic(expected = "slice out of range")]
    fn slice_bounds_checked() {
        let t = seq_tensor(1, 4, 8);
        t.slice_positions(4, 9);
    }
}
