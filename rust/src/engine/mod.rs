//! The PerCache engine (paper Fig 7): hierarchical cache reuse on the
//! serve path, predictive population + conversions on the idle path.
//!
//! Serve path (§4.2):
//! ```text
//! query → embed → QA-bank match ──hit──▶ cached answer (no inference)
//!                    │ miss
//!                    ▼
//!         hybrid retrieve top-k → tree prefix match → load slices
//!         → reuse prefill (skips prefix Q/K/V projections) → decode
//!         → [post-response] slice & insert QKV, insert QA entry
//! ```
//!
//! Idle path (§4.1.2 / §4.3): scheduler-planned — query prediction +
//! population (strategy-gated decode), QKV→QA decoding of pending
//! entries, QA→QKV restoration after storage growth.

use anyhow::{Context, Result};

use crate::cache::{slice_prompt, QaBank, QkvTree, SliceId, SliceStore, Snapshotter};
use crate::config::{PerCacheConfig, PopulationMode};
use crate::embedding::Embedder;
use crate::kb::KnowledgeBank;
use crate::llm::{LlmEngine, QkvTensor};
use crate::metrics::{blank_record, QueryRecord, ServePath, Stage};
use crate::predict::QueryPredictor;
use crate::retrieval::Retriever;
use crate::runtime::Runtime;
use crate::scheduler::{CacheScheduler, IdleAction, PopulationStrategy};
use crate::tokenizer::{self, SEGMENT_TOKENS};

/// Dedup threshold: a predicted query this close to an existing QA entry
/// is not re-populated.  Near-1.0 so only (near-)verbatim repeats of
/// earlier predictions are skipped — distinct paraphrases still populate
/// (they are what makes future QA-bank hits possible).
const PREDICT_DEDUP_SIM: f64 = 0.995;
/// Deterministic seed for the engine's query predictor.
const PREDICTOR_SEED: u64 = 0xCAC4E5EED;
/// Idle-tick work budgets (keep a tick bounded, like a real idle window).
const DECODE_PENDING_BUDGET: usize = 8;
const RESTORE_BUDGET: usize = 8;
/// QA entries *examined* per `restore_qkv` call (each examination costs
/// an embed + retrieve), so a tick stays O(budget) even over a large
/// bank; a round-robin cursor resumes where the last tick stopped.
const RESTORE_SCAN_BUDGET: usize = 32;

#[derive(Debug, Clone, Default)]
pub struct IdleReport {
    pub predicted: usize,
    pub populated: usize,
    pub decoded_pending: usize,
    pub restored_paths: usize,
    pub flops: u64,
}

pub struct PerCache<'rt> {
    pub cfg: PerCacheConfig,
    pub llm: LlmEngine<'rt>,
    pub embedder: Embedder<'rt>,
    pub kb: KnowledgeBank,
    pub retriever: Retriever,
    pub qa: QaBank,
    pub tree: QkvTree,
    pub store: SliceStore,
    pub predictor: QueryPredictor,
    pub scheduler: CacheScheduler,
    sys_tokens: Vec<i32>,
    sys_key: u64,
    query_counter: usize,
    /// Round-robin position of the QA→QKV restoration scan.
    restore_cursor: usize,
    /// Incremental snapshot writer (skips clean sections/saves).
    saver: Snapshotter,
    /// Cumulative idle-side (population) compute — the paper's Fig 15a /
    /// Fig 20 accounting.
    pub population_flops: u64,
    pub population_events: u64,
}

impl<'rt> PerCache<'rt> {
    pub fn new(rt: &'rt Runtime, cfg: PerCacheConfig) -> Result<Self> {
        cfg.validate()?;
        let llm = LlmEngine::new(rt, &cfg.model)?;
        let embedder = Embedder::new(rt);
        let scheduler = CacheScheduler::new(cfg.scheduler_enabled, cfg.tau_scheduler, cfg.tau_query);
        let sys_tokens = tokenizer::encode_segment(&cfg.system_prompt);
        let sys_key = tokenizer::fnv1a64(cfg.system_prompt.as_bytes());
        let mut eng = PerCache {
            retriever: Retriever::new(cfg.hybrid_alpha),
            qa: QaBank::new(cfg.qa_storage_bytes),
            tree: QkvTree::new(cfg.qkv_storage_bytes),
            store: SliceStore::memory(),
            predictor: QueryPredictor::new(PREDICTOR_SEED),
            scheduler,
            kb: KnowledgeBank::new(),
            sys_tokens,
            sys_key,
            query_counter: 0,
            restore_cursor: 0,
            saver: Snapshotter::new(),
            population_flops: 0,
            population_events: 0,
            llm,
            embedder,
            cfg,
        };
        // durable-persistence config: attach (and warm-restore) the
        // engine's cache directory at construction
        if let Some(dir) = eng.cfg.persist_dir.clone() {
            eng.attach_dir(std::path::PathBuf::from(dir))?;
        }
        Ok(eng)
    }

    /// Build an engine whose cache hierarchy lives at `dir`: the slice
    /// store opens on disk (resuming its manifest) and any persisted
    /// tree/QA/predictor state is restored — a warm restart when the
    /// directory was populated by an earlier process, a cold start on a
    /// fresh directory.  Equivalent to setting `cfg.persist_dir`.  Pair
    /// with [`Self::save_state`] at shutdown.
    pub fn open_or_create(
        rt: &'rt Runtime,
        mut cfg: PerCacheConfig,
        dir: std::path::PathBuf,
    ) -> Result<Self> {
        cfg.persist_dir = Some(dir.to_string_lossy().into_owned());
        Self::new(rt, cfg)
    }

    /// Switch this engine to an on-disk store at `dir`, restoring any
    /// persisted cache state (see [`Self::open_or_create`]).  Replaces
    /// whatever in-memory cache state the engine held.  Returns the
    /// restore report, or None when the directory held no snapshot.
    pub fn attach_dir(
        &mut self,
        dir: std::path::PathBuf,
    ) -> Result<Option<crate::cache::RestoreReport>> {
        // stage everything fallible against fresh state, so a failed
        // attach leaves the engine exactly as it was (all-or-nothing)
        let mut store = SliceStore::disk(dir.clone())?;
        let mut predictor = QueryPredictor::new(PREDICTOR_SEED);
        let restored = crate::cache::load_state(
            &dir,
            &mut store,
            self.cfg.qkv_storage_bytes,
            self.cfg.qa_storage_bytes,
            &mut predictor,
        )?;
        self.store = store;
        self.predictor = predictor;
        self.restore_cursor = 0;
        // different directory → the cached snapshot sections are stale
        self.saver = Snapshotter::new();
        match restored {
            Some((tree, qa, report)) => {
                self.tree = tree;
                self.qa = qa;
                Ok(Some(report))
            }
            None => {
                self.tree = QkvTree::new(self.cfg.qkv_storage_bytes);
                self.qa = QaBank::new(self.cfg.qa_storage_bytes);
                Ok(None)
            }
        }
    }

    /// Use an on-disk slice store (paper-faithful load-on-demand).  Full
    /// open-or-create semantics: an existing directory is resumed, not
    /// clobbered.
    pub fn with_disk_store(mut self, dir: std::path::PathBuf) -> Result<Self> {
        self.attach_dir(dir)?;
        Ok(self)
    }

    /// Persist the cache hierarchy next to the disk slice store (errors
    /// on a memory-backed engine).  Incremental: unchanged sections are
    /// served from the snapshotter's cache and a fully clean engine skips
    /// the write entirely, so this is cheap enough to call on a periodic
    /// checkpoint timer; at minimum call it at shutdown.  Returns whether
    /// a snapshot file was actually written.
    pub fn save_state(&mut self) -> Result<bool> {
        let dir = self
            .store
            .dir()
            .context("save_state requires a disk-backed store (open_or_create)")?
            .to_path_buf();
        self.saver
            .save(&dir, &mut self.tree, &mut self.qa, &mut self.predictor)
    }

    // ------------------------------------------------------------------
    // knowledge management
    // ------------------------------------------------------------------

    /// Add personal data; chunks it, indexes it, and runs the dynamic
    /// cache refresh (§4.1.3) against the QA bank.
    pub fn add_document(&mut self, text: &str) -> Result<Vec<usize>> {
        let ids = self.kb.add_document(text, &self.embedder)?;
        for &id in &ids {
            let chunk_text = self.kb.chunk(id).text.clone();
            self.retriever.index_chunk(id, &chunk_text);
            let emb = self.kb.chunk(id).embedding.clone();
            self.qa.refresh_for_chunk(&emb, self.cfg.refresh_top_k);
        }
        Ok(ids)
    }

    // ------------------------------------------------------------------
    // dynamic reconfiguration (scheduler triggers)
    // ------------------------------------------------------------------

    pub fn set_tau_query(&mut self, tau: f64) {
        self.cfg.tau_query = tau;
        self.scheduler.on_tau_change(tau);
    }

    pub fn set_qkv_storage(&mut self, bytes: usize) {
        let old = self.tree.byte_limit();
        self.tree.set_byte_limit(bytes, &mut self.store);
        self.cfg.qkv_storage_bytes = bytes;
        self.scheduler.on_storage_change(old, bytes);
    }

    // ------------------------------------------------------------------
    // serve path
    // ------------------------------------------------------------------

    /// Serve one user query, returning the full stage-timed record.
    pub fn serve(&mut self, query: &str) -> Result<QueryRecord> {
        // standalone engine use (no router in front) still gets stage
        // attribution: root a trace here unless one is already attached
        let _root = crate::obs::trace::root_if_unattached("engine.serve", None);
        let qid = self.query_counter;
        self.query_counter += 1;
        let mut rec = blank_record(qid);

        // 1. embed
        let t = Stage::start();
        let emb = self.embedder.embed(query)?;
        rec.embed_ms = t.ms();

        // 2. QA bank match
        if self.cfg.qa_enabled {
            let t = Stage::start();
            let hit = self.qa.match_query(&emb, self.cfg.tau_query);
            rec.qa_match_ms = t.ms();
            if let Some((_m, answer)) = hit {
                rec.path = ServePath::QaHit;
                rec.answer = tokens_to_text(&answer);
                self.predictor.observe(query);
                crate::metrics::record_query_obs(&rec);
                return Ok(rec);
            }
        }

        // 3. retrieval
        let t = Stage::start();
        let retrieved = self
            .retriever
            .retrieve(query, &emb, &self.kb, self.cfg.top_k);
        rec.retrieval_ms = t.ms();

        // 4. prompt assembly + tree match
        let (tokens, seg_keys) = self.assemble_prompt(query, &retrieved);
        rec.n_segments = seg_keys.len();

        let mut prefix: Option<QkvTensor> = None;
        if self.cfg.qkv_enabled && seg_keys.len() > 1 {
            let t = Stage::start();
            let m = self.tree.match_prefix(&seg_keys[..seg_keys.len() - 1]);
            rec.tree_match_ms = t.ms();
            if !m.is_empty() {
                let t = Stage::start();
                prefix = self.load_matched(&m.slices);
                rec.cache_load_ms = t.ms();
            }
        }

        // 5. prefill (+6. decode)
        let t = Stage::start();
        let pre = self
            .llm
            .prefill(&tokens, prefix.as_ref().map(|p| (p, self.cfg.reuse_variant)))?;
        rec.prefill_ms = t.ms();
        rec.matched_segments = pre.reused_segments;
        rec.path = if pre.reused_segments > 0 {
            ServePath::QkvHit
        } else {
            ServePath::Full
        };
        rec.flops = pre.flops;

        let t = Stage::start();
        let dec = self.llm.decode(&tokens, &pre, self.cfg.decode_tokens)?;
        rec.decode_ms = t.ms();
        rec.flops += dec.flops;
        rec.answer = tokens_to_text(&dec.tokens);

        // 7. post-response population (reactive; free — reuses the
        //    tensors this inference already produced).  Only the prefix
        //    path is inserted: matching never probes the query leaf, so
        //    caching it would burn QKV budget on unmatchable slices.
        if self.cfg.qkv_enabled {
            let slices = slice_prompt(&pre.qkv, &seg_keys);
            debug_assert_eq!(slices.len() + 1, seg_keys.len(), "query leaf must not be cached");
            let keys: Vec<u64> = slices.iter().map(|s| s.key).collect();
            let tensors: Vec<QkvTensor> = slices.into_iter().map(|s| s.tensor).collect();
            self.tree.insert_path(&keys, tensors, &mut self.store)?;
        }
        if self.cfg.qa_enabled {
            self.qa.insert(query, emb, Some(dec.tokens.clone()), false);
        }
        self.predictor.observe(query);
        crate::metrics::record_query_obs(&rec);
        Ok(rec)
    }

    /// Assemble `[sysprompt | chunk… | query]` tokens + segment keys.
    fn assemble_prompt(
        &self,
        query: &str,
        retrieved: &[crate::retrieval::Retrieved],
    ) -> (Vec<i32>, Vec<u64>) {
        let mut tokens = self.sys_tokens.clone();
        let mut keys = vec![self.sys_key];
        for r in retrieved {
            let c = self.kb.chunk(r.chunk);
            tokens.extend_from_slice(&c.tokens);
            keys.push(c.key);
        }
        tokens.extend(tokenizer::encode_segment(query));
        keys.push(tokenizer::fnv1a64(query.as_bytes()));
        debug_assert_eq!(tokens.len(), keys.len() * SEGMENT_TOKENS);
        (tokens, keys)
    }

    /// Load matched slices and concatenate them into one prefix tensor.
    /// A slice that fails to load — quarantined on a checksum mismatch,
    /// or a pooled slice whose shared bytes were evicted while this
    /// engine was cold — is dropped from the tree and the query degrades
    /// to a full prefill: cache reuse is an optimization, never a
    /// correctness risk.
    fn load_matched(&mut self, slices: &[SliceId]) -> Option<QkvTensor> {
        let mut parts = Vec::with_capacity(slices.len());
        for sid in slices {
            match self.store.get(*sid) {
                Ok(t) => parts.push(t),
                Err(_) => {
                    crate::obs_counter!("engine.slice_load_failures").inc();
                    self.tree.drop_slice(*sid, &mut self.store);
                    return None;
                }
            }
        }
        let refs: Vec<&QkvTensor> = parts.iter().map(|a| a.as_ref()).collect();
        Some(QkvTensor::concat(&refs))
    }

    // ------------------------------------------------------------------
    // population path (idle time)
    // ------------------------------------------------------------------

    /// Populate the caches with one (predicted) query.  Returns FLOPs
    /// spent, or None if deduped away.
    pub fn populate_query(
        &mut self,
        query: &str,
        strategy: PopulationStrategy,
        predicted: bool,
    ) -> Result<Option<u64>> {
        let emb = self.embedder.embed(query)?;
        if predicted {
            if let Some(m) = self.qa.best_similarity(&emb) {
                if m.similarity >= PREDICT_DEDUP_SIM {
                    return Ok(None); // already covered
                }
            }
        }
        let retrieved = self
            .retriever
            .retrieve(query, &emb, &self.kb, self.cfg.top_k);
        let (tokens, seg_keys) = self.assemble_prompt(query, &retrieved);

        // reuse whatever prefix already exists — population itself
        // benefits from the cache
        let mut prefix: Option<QkvTensor> = None;
        if self.cfg.qkv_enabled && seg_keys.len() > 1 {
            let m = self.tree.match_prefix(&seg_keys[..seg_keys.len() - 1]);
            if !m.is_empty() {
                prefix = self.load_matched(&m.slices);
            }
        }

        let pre = self
            .llm
            .prefill(&tokens, prefix.as_ref().map(|p| (p, self.cfg.reuse_variant)))?;
        let mut flops = pre.flops;

        if self.cfg.qkv_enabled {
            // prefix path only — see the serve-path comment
            let slices = slice_prompt(&pre.qkv, &seg_keys);
            debug_assert_eq!(slices.len() + 1, seg_keys.len(), "query leaf must not be cached");
            let keys: Vec<u64> = slices.iter().map(|s| s.key).collect();
            let tensors: Vec<QkvTensor> = slices.into_iter().map(|s| s.tensor).collect();
            self.tree.insert_path(&keys, tensors, &mut self.store)?;
        }

        if self.cfg.qa_enabled {
            let answer = match strategy {
                PopulationStrategy::PrefillAndDecode => {
                    let dec = self.llm.decode(&tokens, &pre, self.cfg.decode_tokens)?;
                    flops += dec.flops;
                    Some(dec.tokens)
                }
                PopulationStrategy::PrefillOnly => None,
            };
            self.qa.insert(query, emb, answer, predicted);
        }

        self.population_flops += flops;
        self.population_events += 1;
        Ok(Some(flops))
    }

    /// One idle-time tick: run the scheduler's plan.
    pub fn idle_tick(&mut self) -> Result<IdleReport> {
        let mut report = IdleReport::default();
        let flops_before = self.population_flops;

        for action in self.scheduler.plan_idle() {
            match action {
                IdleAction::PredictAndPopulate => {
                    if self.cfg.population != PopulationMode::Predictive {
                        continue;
                    }
                    // knowledge-abstract upkeep: batch-summarize pending
                    // chunks (LLM cost charged as one prefill over the
                    // abstract context)
                    if !self.kb.pending_abstract_chunks().is_empty() {
                        let ctx = self.predictor.prediction_context(&self.kb);
                        self.charge_prediction_prompt(&ctx)?;
                        self.kb.mark_abstract_refreshed();
                    }
                    let stride = self.cfg.prediction_stride;
                    let mut qs = self.predictor.predict_from_knowledge(&self.kb, stride);
                    qs.extend(self.predictor.predict_from_history(stride));
                    report.predicted += qs.len();
                    let strategy = self.scheduler.strategy();
                    for q in qs {
                        if self.populate_query(&q, strategy, true)?.is_some() {
                            report.populated += 1;
                        }
                    }
                }
                IdleAction::DecodePending => {
                    report.decoded_pending += self.decode_pending(DECODE_PENDING_BUDGET)?;
                }
                IdleAction::RestoreQkv => {
                    report.restored_paths += self.restore_qkv(RESTORE_BUDGET)?;
                }
            }
        }
        report.flops = self.population_flops - flops_before;
        Ok(report)
    }

    /// Charge the prediction/summarization prompt's LLM cost: one prefill
    /// over `[sys | context]` (substitution: the paper prompts the LLM;
    /// we run the same-shape compute and use its wall-clock/FLOPs).
    fn charge_prediction_prompt(&mut self, context: &str) -> Result<()> {
        let mut tokens = self.sys_tokens.clone();
        tokens.extend(tokenizer::encode_segment(context));
        let pre = self.llm.prefill(&tokens, None)?;
        self.population_flops += pre.flops;
        Ok(())
    }

    /// QKV→QA conversion (§4.3.3): decode answers for entries stored
    /// without one.  Returns how many were decoded.
    pub fn decode_pending(&mut self, budget: usize) -> Result<usize> {
        let pending = self.qa.undecoded();
        let mut done = 0;
        for id in pending.into_iter().take(budget) {
            let query = match self.qa.get(id) {
                Some(e) => e.query.clone(),
                None => continue,
            };
            let emb = self.embedder.embed(&query)?;
            let retrieved = self
                .retriever
                .retrieve(&query, &emb, &self.kb, self.cfg.top_k);
            let (tokens, seg_keys) = self.assemble_prompt(&query, &retrieved);

            let mut prefix: Option<QkvTensor> = None;
            if self.cfg.qkv_enabled && seg_keys.len() > 1 {
                let m = self.tree.match_prefix(&seg_keys[..seg_keys.len() - 1]);
                if !m.is_empty() {
                    prefix = self.load_matched(&m.slices);
                }
            }
            let pre = self
                .llm
                .prefill(&tokens, prefix.as_ref().map(|p| (p, self.cfg.reuse_variant)))?;
            let dec = self.llm.decode(&tokens, &pre, self.cfg.decode_tokens)?;
            self.population_flops += pre.flops + dec.flops;
            self.qa.set_answer(id, dec.tokens);
            done += 1;
        }
        Ok(done)
    }

    /// QA→QKV conversion (§4.3.3): re-prefill QA-bank queries whose tree
    /// slices were evicted, while storage headroom remains.
    ///
    /// Examines at most [`RESTORE_SCAN_BUDGET`] entries per call (every
    /// examination pays an embed + retrieve), resuming round-robin where
    /// the previous tick stopped — so an idle tick over a fully-cached
    /// bank costs O(scan budget), not O(bank).
    pub fn restore_qkv(&mut self, budget: usize) -> Result<usize> {
        if !self.cfg.qkv_enabled {
            return Ok(0);
        }
        let len = self.qa.len();
        if len == 0 {
            return Ok(0);
        }
        let scan = RESTORE_SCAN_BUDGET.min(len);
        // clone only the scan window, not the whole bank
        let window: Vec<String> = (0..scan)
            .map(|k| self.qa.entries()[(self.restore_cursor + k) % len].query.clone())
            .collect();
        let mut restored = 0;
        let mut scanned = 0;
        while scanned < scan && restored < budget {
            let query = &window[scanned];
            scanned += 1;
            let emb = self.embedder.embed(query)?;
            let retrieved = self
                .retriever
                .retrieve(query, &emb, &self.kb, self.cfg.top_k);
            let (tokens, seg_keys) = self.assemble_prompt(query, &retrieved);
            let path = &seg_keys[..seg_keys.len() - 1];
            let cached = self.tree.cached_prefix_len(path);
            if cached >= path.len() {
                continue; // fully present
            }
            // headroom check: one segment slice per missing node
            let missing = path.len() - cached;
            let slice_bytes = self.llm.dims.layers * 3 * SEGMENT_TOKENS * self.llm.dims.d_model * 4;
            if self.tree.bytes_used() + missing * slice_bytes > self.tree.byte_limit() {
                continue;
            }
            let pre = self.llm.prefill(&tokens, None)?;
            self.population_flops += pre.flops;
            let slices = slice_prompt(&pre.qkv, &seg_keys);
            let keys: Vec<u64> = slices.iter().map(|s| s.key).collect();
            let tensors: Vec<QkvTensor> = slices.into_iter().map(|s| s.tensor).collect();
            self.tree.insert_path(&keys, tensors, &mut self.store)?;
            restored += 1;
        }
        self.restore_cursor = (self.restore_cursor + scanned) % len;
        Ok(restored)
    }

    /// Probe: cached-prefix length a query would see right now (no LFU
    /// side effects).  Used by Fig 5 / scheduler analyses.
    pub fn probe_prefix(&self, query: &str, emb: &[f32]) -> (usize, usize) {
        let retrieved = self
            .retriever
            .retrieve(query, &emb.to_vec(), &self.kb, self.cfg.top_k);
        let (_, seg_keys) = self.assemble_prompt(query, &retrieved);
        let path = &seg_keys[..seg_keys.len() - 1];
        (self.tree.cached_prefix_len(path), path.len())
    }
}

/// Render generated token ids as comparable pseudo-text ("t123 t456 …")
/// — answers are sequences either way; ROUGE/BLEU operate on the tokens.
pub fn tokens_to_text(tokens: &[i32]) -> String {
    tokens
        .iter()
        .map(|t| format!("t{t}"))
        .collect::<Vec<_>>()
        .join(" ")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokens_to_text_roundtrip_shape() {
        assert_eq!(tokens_to_text(&[1, 22, 333]), "t1 t22 t333");
        assert_eq!(tokens_to_text(&[]), "");
    }
}
