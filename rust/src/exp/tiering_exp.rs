//! Warm/cold shard tiering experiment (`percache exp tiering`): does
//! demoting idle tenant shards to disk buy back resident memory without
//! hurting the hot tenants?
//!
//! Workload: a skewed on/off multi-tenant stream — tenant 0 is active in
//! every scheduling tick (the "hot" tenant), while the remaining tenants
//! take turns bursting for one phase and then going silent for a full
//! rotation, exactly the multi-app pattern mobile RAG serving sees.
//! Three arms replay the same arrivals through `tiering::replay_tiered`:
//!
//! * **baseline** — tiering disabled: every shard stays resident (the
//!   pre-tiering behaviour).
//! * **tiered** — idle shards demote after a phase of silence; a
//!   returning tenant's first request pays the measured hydration stall.
//! * **prefetched** — same, plus the forecast hook: each burst is
//!   scheduled ahead of time, so the controller warms the shard
//!   `prefetch_lead_ticks` early and the stall disappears.
//!
//! Emits the human table + CSV plus `reports/BENCH_tiering.json`:
//! resident-byte series stats, hot-tenant p50/p99 (the acceptance bar:
//! tiered must be no worse than baseline) and hydration-stall p50/p99.
//! `--smoke` (or PERCACHE_SMOKE=1) shrinks the workload for CI.

use std::path::Path;

use anyhow::Result;

use crate::config::{TenancyConfig, TieringConfig};
use crate::metrics::ServePath;
use crate::runtime::Runtime;
use crate::tenancy::sim::{sim_slice_bytes, Arrival, SimConfig};
use crate::tenancy::{RouterConfig, TenantId, TenantRegistry};
use crate::tiering::sim::{replay_tiered, TieredOutcome};
use crate::tiering::TieringController;
use crate::tokenizer::fnv1a64;
use crate::util::bench::percentile;
use crate::util::json::Json;
use crate::util::table::Table;

use super::common::reports_dir;

/// Global QKV budget in sim slices (roomy: hit behaviour identical
/// across arms, so latency deltas isolate the residency system).
const GLOBAL_SLICES: usize = 96;
/// Topics cycled per tenant (each owns a reusable 2-chunk path).
const TOPICS: usize = 2;
/// Query phrasings per topic (verbatim repeats land in the QA bank).
const VARIANTS: usize = 3;
/// Arrivals per scheduling tick: 2 from the hot tenant + 2 from the
/// phase's burst tenant.
const PER_TICK: usize = 4;

/// Workload shape (full vs `--smoke`).
#[derive(Debug, Clone, Copy)]
pub struct Shape {
    pub tenants: usize,
    /// Ticks per burst phase (also the tiered idle-demotion threshold).
    pub phase_ticks: u64,
    /// Burst phases replayed (each burst tenant gets several turns).
    pub phases: usize,
}

impl Shape {
    pub fn full() -> Self {
        Shape {
            tenants: 6,
            phase_ticks: 8,
            phases: 15,
        }
    }

    pub fn smoke() -> Self {
        Shape {
            tenants: 3,
            phase_ticks: 4,
            phases: 6,
        }
    }

    pub fn ticks(&self) -> usize {
        self.phases * self.phase_ticks as usize
    }

    /// The tenant bursting in phase `p` (never the hot tenant 0).
    pub fn burst_tenant(&self, p: usize) -> TenantId {
        (1 + p % (self.tenants - 1)) as TenantId
    }
}

/// CI/fast mode: `percache exp tiering --smoke` or PERCACHE_SMOKE=1.
pub fn smoke_mode() -> bool {
    std::env::var("PERCACHE_SMOKE").map(|v| v == "1").unwrap_or(false)
}

/// One measured arm.
#[derive(Debug, Clone)]
pub struct TieringCell {
    pub label: String,
    pub arrivals: usize,
    pub hot_p50_ms: f64,
    pub hot_p99_ms: f64,
    pub hit_rate: f64,
    pub resident_mean_bytes: f64,
    pub resident_min_bytes: usize,
    pub resident_peak_bytes: usize,
    pub demotions: u64,
    pub hydrations: u64,
    pub stalls: usize,
    pub stall_p50_ms: f64,
    pub stall_p99_ms: f64,
}

fn query_text(tenant: TenantId, i: usize) -> String {
    let topic = i % TOPICS;
    let variant = (i / TOPICS) % VARIANTS;
    format!("tenant{tenant:02} topic{topic} phrasing{variant} morning briefing request")
}

fn arrival(tenant: TenantId, i: usize) -> Arrival {
    let q = query_text(tenant, i);
    let topic = i % TOPICS;
    let tag = |part: &str| fnv1a64(format!("t{tenant}/topic{topic}/{part}").as_bytes());
    Arrival {
        seg_keys: vec![fnv1a64(b"sys"), tag("a"), tag("b"), fnv1a64(q.as_bytes())],
        tenant,
        query: q,
        shared: Vec::new(),
    }
}

/// The skewed on/off stream: every tick carries 2 hot-tenant queries and
/// 2 from the phase's burst tenant (chunks of [`PER_TICK`] = one tick).
pub fn arrivals(shape: &Shape) -> Vec<Arrival> {
    let mut seq = vec![0usize; shape.tenants];
    let mut out = Vec::with_capacity(shape.ticks() * PER_TICK);
    for p in 0..shape.phases {
        let burst = shape.burst_tenant(p);
        for _ in 0..shape.phase_ticks {
            for t in [0, burst] {
                for _ in 0..2 {
                    out.push(arrival(t, seq[t as usize]));
                    seq[t as usize] += 1;
                }
            }
        }
    }
    out
}

fn tenancy_config(shape: &Shape, tiering: TieringConfig) -> TenancyConfig {
    let mut tc = TenancyConfig::default();
    tc.enabled = true;
    tc.max_tenants = shape.tenants;
    tc.global_qkv_bytes = GLOBAL_SLICES * sim_slice_bytes();
    tc.rebalance_every = 16;
    tc.tiering = tiering;
    tc
}

fn cell(label: &str, out: &TieredOutcome) -> TieringCell {
    let mut hot: Vec<f64> = out.per_tenant[0].records.iter().map(|r| r.total_ms()).collect();
    hot.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mut stalls = out.hydration_stall_ms.clone();
    stalls.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let (served, hits) = out.per_tenant.iter().fold((0usize, 0usize), |(n, h), r| {
        (
            n + r.len(),
            h + r.records.iter().filter(|q| q.path != ServePath::Full).count(),
        )
    });
    TieringCell {
        label: label.to_string(),
        arrivals: served,
        hot_p50_ms: percentile(&hot, 50.0),
        hot_p99_ms: percentile(&hot, 99.0),
        hit_rate: hits as f64 / served.max(1) as f64,
        resident_mean_bytes: out.mean_resident_bytes(),
        resident_min_bytes: out.min_resident_bytes(),
        resident_peak_bytes: out.peak_resident_bytes(),
        demotions: out.demotions,
        hydrations: out.hydrations,
        stalls: stalls.len(),
        stall_p50_ms: if stalls.is_empty() { 0.0 } else { percentile(&stalls, 50.0) },
        stall_p99_ms: if stalls.is_empty() { 0.0 } else { percentile(&stalls, 99.0) },
    }
}

/// Run one arm over `stream` with its own persistent registry under
/// `dir`; `forecast` additionally schedules every burst phase with the
/// controller (the predictive-prefetch hook).
fn run_arm(
    dir: &Path,
    shape: &Shape,
    stream: &[Arrival],
    tiering: TieringConfig,
    forecast: bool,
    label: &str,
) -> Result<TieringCell> {
    let _ = std::fs::remove_dir_all(dir);
    let tc = tenancy_config(shape, tiering);
    let mut reg = TenantRegistry::open_or_create(&tc, dir.to_path_buf())?;
    for _ in 0..shape.tenants {
        reg.create_tenant()?;
    }
    let mut ctl = TieringController::new(tc.tiering.clone(), shape.tenants);
    if forecast {
        for p in 0..shape.phases {
            ctl.schedule_active(shape.burst_tenant(p), p as u64 * shape.phase_ticks);
        }
    }
    let out = replay_tiered(
        &mut reg,
        &mut ctl,
        RouterConfig {
            queue_cap: tc.queue_cap,
            global_cap: tc.global_queue_cap,
            ..RouterConfig::default()
        },
        &SimConfig::default(),
        stream,
        PER_TICK,
    )?;
    Ok(cell(label, &out))
}

/// Run all three arms (pure; unit-testable without a runtime).
/// Returns (baseline, tiered, prefetched).
pub fn sweep(dir: &Path, shape: &Shape) -> Result<(TieringCell, TieringCell, TieringCell)> {
    let stream = arrivals(shape);
    let off = TieringConfig::default();
    let on = TieringConfig {
        enabled: true,
        idle_ticks_to_demote: shape.phase_ticks,
        min_resident: 1,
        ..TieringConfig::default()
    };
    let baseline = run_arm(&dir.join("baseline"), shape, &stream, off, false, "baseline")?;
    let tiered = run_arm(&dir.join("tiered"), shape, &stream, on.clone(), false, "tiered")?;
    let prefetched = run_arm(&dir.join("prefetched"), shape, &stream, on, true, "prefetched")?;
    Ok((baseline, tiered, prefetched))
}

/// `percache exp tiering` entry point (runtime unused: cache-level sim).
pub fn tiering(_rt: &Runtime) -> Result<()> {
    run_and_report()
}

/// Shared by the exp registry, the offline dispatcher and tests.
pub fn run_and_report() -> Result<()> {
    let shape = if smoke_mode() { Shape::smoke() } else { Shape::full() };
    let state_dir = std::env::temp_dir().join(format!(
        "percache_tiering_exp_{}",
        std::process::id()
    ));
    let cells = sweep(&state_dir, &shape)?;
    let _ = std::fs::remove_dir_all(&state_dir);
    let (baseline, tiered, prefetched) = &cells;

    let mut table = Table::new(
        "tiering: resident memory + latency under a skewed on/off workload",
        &[
            "arm", "served", "hot p50 ms", "hot p99 ms", "hit", "resident mean KB",
            "resident min KB", "demotions", "hydrations", "stall p99 ms",
        ],
    );
    for c in [baseline, tiered, prefetched] {
        table.row(vec![
            c.label.clone(),
            c.arrivals.to_string(),
            format!("{:.3}", c.hot_p50_ms),
            format!("{:.3}", c.hot_p99_ms),
            format!("{:.0}%", c.hit_rate * 100.0),
            format!("{:.1}", c.resident_mean_bytes / 1024.0),
            format!("{:.1}", c.resident_min_bytes as f64 / 1024.0),
            c.demotions.to_string(),
            c.hydrations.to_string(),
            format!("{:.3}", c.stall_p99_ms),
        ]);
    }
    println!("{}", table.render());
    let dir = reports_dir();
    table.emit(&dir, "tiering");
    write_bench_json(&shape, baseline, tiered, prefetched, &dir)?;
    Ok(())
}

fn cell_json(c: &TieringCell) -> Json {
    let mut o = Json::obj();
    o.insert("label", c.label.as_str());
    o.insert("arrivals", c.arrivals);
    o.insert("hot_p50_ms", c.hot_p50_ms);
    o.insert("hot_p99_ms", c.hot_p99_ms);
    o.insert("hit_rate", c.hit_rate);
    o.insert("resident_mean_bytes", c.resident_mean_bytes);
    o.insert("resident_min_bytes", c.resident_min_bytes);
    o.insert("resident_peak_bytes", c.resident_peak_bytes);
    o.insert("demotions", c.demotions);
    o.insert("hydrations", c.hydrations);
    o.insert("hydration_stalls", c.stalls);
    o.insert("hydration_stall_p50_ms", c.stall_p50_ms);
    o.insert("hydration_stall_p99_ms", c.stall_p99_ms);
    Json::Obj(o)
}

/// Emit `<dir>/BENCH_tiering.json` — the acceptance artifact.
pub fn write_bench_json(
    shape: &Shape,
    baseline: &TieringCell,
    tiered: &TieringCell,
    prefetched: &TieringCell,
    dir: &Path,
) -> Result<()> {
    let mut root = Json::obj();
    root.insert("bench", "tiering");
    root.insert("tenants", shape.tenants);
    root.insert("ticks", shape.ticks());
    root.insert("global_qkv_bytes", GLOBAL_SLICES * sim_slice_bytes());
    root.insert("baseline", cell_json(baseline));
    root.insert("tiered", cell_json(tiered));
    root.insert("prefetched", cell_json(prefetched));
    root.insert(
        "resident_mean_saving_frac",
        1.0 - tiered.resident_mean_bytes / baseline.resident_mean_bytes.max(1.0),
    );
    root.insert(
        "hot_p50_ratio_tiered_vs_baseline",
        if baseline.hot_p50_ms > 0.0 {
            tiered.hot_p50_ms / baseline.hot_p50_ms
        } else {
            1.0
        },
    );

    std::fs::create_dir_all(dir)?;
    let path = dir.join("BENCH_tiering.json");
    std::fs::write(&path, Json::Obj(root).to_string_pretty())?;
    println!("[tiering] wrote {}", path.display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("percache_tierexp_{tag}_{}", std::process::id()))
    }

    #[test]
    fn workload_is_deterministic_and_tick_aligned() {
        let shape = Shape::smoke();
        let a = arrivals(&shape);
        let b = arrivals(&shape);
        assert_eq!(a.len(), shape.ticks() * PER_TICK);
        assert_eq!(a[0].seg_keys, b[0].seg_keys);
        // every tick: 2 hot-tenant arrivals + 2 burst arrivals
        for tick in a.chunks(PER_TICK) {
            assert_eq!(tick.iter().filter(|x| x.tenant == 0).count(), 2);
            assert!(tick.iter().all(|x| x.seg_keys.len() == 4));
        }
    }

    #[test]
    fn tiering_saves_memory_without_hurting_the_hot_tenant() {
        let dir = tmp("accept");
        let shape = Shape::smoke();
        let (baseline, tiered, prefetched) = sweep(&dir, &shape).unwrap();

        // demotion must actually happen and be observable in resident bytes
        assert!(tiered.demotions >= 1, "no demotions: {tiered:?}");
        assert!(tiered.hydrations >= 1, "no hydrations: {tiered:?}");
        assert!(
            tiered.resident_min_bytes < tiered.resident_peak_bytes,
            "demotion must dip the resident-byte series: {tiered:?}"
        );
        // same inserts, minus the cold windows: mean strictly drops
        assert!(
            tiered.resident_mean_bytes < baseline.resident_mean_bytes,
            "tiering must save resident memory: tiered {} vs baseline {}",
            tiered.resident_mean_bytes,
            baseline.resident_mean_bytes
        );

        // identical hit behaviour: the cold tier restores what it evicted
        assert!(
            (tiered.hit_rate - baseline.hit_rate).abs() < 1e-9,
            "hit behaviour must not change: tiered {} vs baseline {}",
            tiered.hit_rate,
            baseline.hit_rate
        );

        // the acceptance bar: hot-tenant p50 no worse than baseline
        // (modeled latency dominates and the hot tenant never demotes;
        // 10% headroom absorbs measured-stage jitter)
        assert!(
            tiered.hot_p50_ms <= baseline.hot_p50_ms * 1.10,
            "hot p50 regressed: tiered {} vs baseline {}",
            tiered.hot_p50_ms,
            baseline.hot_p50_ms
        );

        // prefetching hides the demand stall
        assert!(
            prefetched.stalls <= tiered.stalls,
            "forecast prefetch must not add stalls: {} vs {}",
            prefetched.stalls,
            tiered.stalls
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn bench_json_is_parseable() {
        let dir = tmp("json");
        let shape = Shape::smoke();
        let (b, t, p) = sweep(&dir, &shape).unwrap();
        write_bench_json(&shape, &b, &t, &p, &dir).unwrap();
        let text = std::fs::read_to_string(dir.join("BENCH_tiering.json")).unwrap();
        let j = Json::parse(&text).unwrap();
        assert_eq!(j.get("bench").as_str(), Some("tiering"));
        assert!(j.get("tiered").get("demotions").as_usize().unwrap() >= 1);
        assert!(j.get("hot_p50_ratio_tiered_vs_baseline").as_f64().unwrap() > 0.0);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
