//! Shared experiment machinery: the canonical user-replay loop, device
//! scaling, report output paths.
//!
//! Replay protocol (mirrors the paper's §5.3 setup): personal data is
//! ingested first; predictive methods then run two knowledge-based
//! prediction rounds ("PerCache performs knowledge-based query prediction
//! twice"); user queries are processed sequentially, with an idle tick
//! (history-based prediction + conversions) after each query.

use std::path::PathBuf;

use anyhow::Result;

use crate::baselines;
use crate::config::{PerCacheConfig, PopulationMode};
use crate::datasets::{self, UserData};
use crate::engine::PerCache;
use crate::metrics::{QueryRecord, Recorder};
use crate::runtime::Runtime;
use crate::sim::DeviceProfile;

/// Where CSVs land ($PERCACHE_REPORTS or ./reports).
pub fn reports_dir() -> PathBuf {
    std::env::var("PERCACHE_REPORTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("reports"))
}

/// Fast mode trims user counts for quick iterations
/// (PERCACHE_FAST=1).
pub fn fast_mode() -> bool {
    std::env::var("PERCACHE_FAST").map(|v| v == "1").unwrap_or(false)
}

pub fn users_per_dataset() -> usize {
    if fast_mode() {
        2
    } else {
        datasets::USERS_PER_DATASET
    }
}

/// Upfront knowledge-prediction rounds before queries arrive (paper §5.3).
pub const WARM_PREDICTION_ROUNDS: usize = 2;

#[derive(Debug, Clone)]
pub struct ReplayOutcome {
    pub recorder: Recorder,
    /// Idle-side compute (population/prediction/conversions).
    pub population_flops: u64,
    /// Per-query cumulative population FLOPs snapshots (Fig 15a series).
    pub population_flops_series: Vec<u64>,
}

/// Options that individual experiments tweak.
#[derive(Clone)]
pub struct ReplayOpts {
    pub device: Option<&'static DeviceProfile>,
    /// Idle tick after every n-th query (0 = never).
    pub idle_every: usize,
    /// τ_query schedule: (query_index, new_tau) applied *before* that query.
    pub tau_schedule: Vec<(usize, f64)>,
    /// QKV storage schedule: (query_index, new_bytes).
    pub storage_schedule: Vec<(usize, usize)>,
}

impl Default for ReplayOpts {
    fn default() -> Self {
        ReplayOpts {
            device: None,
            idle_every: 1,
            tau_schedule: Vec::new(),
            storage_schedule: Vec::new(),
        }
    }
}

/// Build an engine for `method`, ingest the user's documents.
pub fn build_engine<'rt>(
    rt: &'rt Runtime,
    method: &str,
    base: &PerCacheConfig,
    data: &UserData,
) -> Result<PerCache<'rt>> {
    let mut eng = baselines::build_method(rt, method, base)?;
    for doc in &data.documents {
        eng.add_document(doc)?;
    }
    Ok(eng)
}

/// The canonical replay: warm prediction (predictive methods only), then
/// serve each query with optional idle ticks and schedule events.
pub fn replay_user(
    rt: &Runtime,
    method: &str,
    base: &PerCacheConfig,
    data: &UserData,
    opts: &ReplayOpts,
) -> Result<ReplayOutcome> {
    let cfg = baselines::method_config(method, base)?;
    replay_config(rt, &cfg, data, opts)
}

/// Replay with an explicit configuration (ablations/sweeps that aren't a
/// named method).
pub fn replay_config(
    rt: &Runtime,
    cfg: &PerCacheConfig,
    data: &UserData,
    opts: &ReplayOpts,
) -> Result<ReplayOutcome> {
    let mut eng = PerCache::new(rt, cfg.clone())?;
    for doc in &data.documents {
        eng.add_document(doc)?;
    }

    if eng.cfg.population == PopulationMode::Predictive {
        for _ in 0..WARM_PREDICTION_ROUNDS {
            eng.idle_tick()?;
        }
    }

    let mut recorder = Recorder::new();
    let mut series = Vec::with_capacity(data.queries.len());
    for (i, q) in data.queries.iter().enumerate() {
        for (qi, tau) in &opts.tau_schedule {
            if *qi == i {
                eng.set_tau_query(*tau);
            }
        }
        for (qi, bytes) in &opts.storage_schedule {
            if *qi == i {
                eng.set_qkv_storage(*bytes);
            }
        }
        let r = eng.serve(&q.text)?;
        recorder.push(scale(&r, opts.device));
        if opts.idle_every > 0 && (i + 1) % opts.idle_every == 0 {
            eng.idle_tick()?;
        }
        series.push(eng.population_flops);
    }
    Ok(ReplayOutcome {
        recorder,
        population_flops: eng.population_flops,
        population_flops_series: series,
    })
}

pub fn scale(r: &QueryRecord, device: Option<&DeviceProfile>) -> QueryRecord {
    match device {
        Some(d) => d.scale_record(r),
        None => r.clone(),
    }
}

/// Mean latency over a user replay for a (method, dataset, user) cell —
/// the unit of Figs 14/21/22.
pub fn user_mean_latency(
    rt: &Runtime,
    method: &str,
    base: &PerCacheConfig,
    data: &UserData,
    device: Option<&'static DeviceProfile>,
) -> Result<(f64, Recorder)> {
    let opts = ReplayOpts {
        device,
        ..Default::default()
    };
    let out = replay_user(rt, method, base, data, &opts)?;
    Ok((out.recorder.mean_total_ms(), out.recorder))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reports_dir_env_override() {
        std::env::set_var("PERCACHE_REPORTS", "/tmp/percache-reports-test");
        assert_eq!(
            reports_dir(),
            PathBuf::from("/tmp/percache-reports-test")
        );
        std::env::remove_var("PERCACHE_REPORTS");
    }

    #[test]
    fn default_opts_sane() {
        let o = ReplayOpts::default();
        assert_eq!(o.idle_every, 1);
        assert!(o.tau_schedule.is_empty());
    }
}
