//! Multi-tenant scaling experiment (`percache exp tenancy`): tenant
//! counts vs latency/hit-rate under one global memory budget.
//!
//! Runs the cache-level tenancy replay (real shards, governor and
//! router; analytic LLM cost — no PJRT artifacts needed), sweeping the
//! tenant count at a fixed device-wide QKV budget.  Emits the human
//! table + CSV like every other experiment, plus a machine-readable
//! `BENCH_tenancy.json` (p50/p99 latency and hit rates per tenant
//! count) that seeds the performance trajectory across PRs.

use anyhow::Result;

use crate::config::TenancyConfig;
use crate::datasets;
use crate::metrics::Recorder;
use crate::runtime::Runtime;
use crate::tenancy::sim::{arrivals_from_workload, replay, sim_slice_bytes, SimConfig};
use crate::tenancy::{RouterConfig, TenantRegistry};
use crate::util::bench::percentile;
use crate::util::json::Json;
use crate::util::table::Table;

use super::common::reports_dir;

/// Tenant counts swept (the ≥8 point is the acceptance bar).
pub const TENANT_COUNTS: [usize; 5] = [1, 2, 4, 8, 16];
/// Arrivals per tenant (cycling each tenant's query stream).
const ARRIVALS_PER_TENANT: usize = 40;
/// Global QKV budget, in slices of the sim's tiny tensor shape.
const GLOBAL_SLICES: usize = 96;

#[derive(Debug, Clone)]
pub struct TenancyCell {
    pub tenants: usize,
    pub arrivals: usize,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub qa_hit_rate: f64,
    pub qkv_hit_rate: f64,
    pub hit_rate: f64,
    pub rejected: u64,
    pub rebalances: u64,
    pub per_tenant_hit_rate: Vec<f64>,
}

/// Run the sweep (pure; unit-testable without a runtime).
pub fn sweep() -> Result<Vec<TenancyCell>> {
    let slice = sim_slice_bytes();
    let sim = SimConfig::default();
    let mut cells = Vec::new();
    for &n in &TENANT_COUNTS {
        let tc = TenancyConfig {
            enabled: true,
            max_tenants: n.max(1),
            global_qkv_bytes: GLOBAL_SLICES * slice,
            rebalance_every: 16,
            ..TenancyConfig::default()
        };
        let mut reg = TenantRegistry::new(&tc);
        for _ in 0..n {
            reg.create_tenant()?;
        }
        let w = datasets::multi_tenant(n, n * ARRIVALS_PER_TENANT, 1.0, 0xBEEF + n as u64);
        let arrivals = arrivals_from_workload(&w);
        let out = replay(
            &mut reg,
            RouterConfig {
                queue_cap: tc.queue_cap,
                global_cap: tc.global_queue_cap,
                ..RouterConfig::default()
            },
            &sim,
            &arrivals,
            8,
        )?;

        let mut merged = Recorder::new();
        for r in &out.per_tenant {
            for q in &r.records {
                merged.push(q.clone());
            }
        }
        let lat = out.all_total_ms();
        cells.push(TenancyCell {
            tenants: n,
            arrivals: arrivals.len(),
            p50_ms: percentile(&lat, 50.0),
            p99_ms: percentile(&lat, 99.0),
            qa_hit_rate: merged.qa_hit_rate(),
            qkv_hit_rate: merged.qkv_hit_rate(),
            hit_rate: reg
                .shards()
                .iter()
                .map(|s| s.stats.hit_rate())
                .sum::<f64>()
                / n.max(1) as f64,
            rejected: out.rejected,
            rebalances: out.rebalances,
            per_tenant_hit_rate: out
                .per_tenant
                .iter()
                .map(|r| {
                    if r.is_empty() {
                        0.0
                    } else {
                        r.records
                            .iter()
                            .filter(|q| q.path != crate::metrics::ServePath::Full)
                            .count() as f64
                            / r.len() as f64
                    }
                })
                .collect(),
        });
    }
    Ok(cells)
}

/// `percache exp tenancy` entry point (runtime unused: cache-level sim).
pub fn tenancy(_rt: &Runtime) -> Result<()> {
    run_and_report()
}

/// Shared by the exp registry and the `percache tenants` subcommand.
pub fn run_and_report() -> Result<()> {
    let cells = sweep()?;
    let mut table = Table::new(
        "tenancy: tenants vs latency/hit-rate at fixed global budget",
        &[
            "tenants", "arrivals", "p50 ms", "p99 ms", "qa hit", "qkv hit",
            "rejected", "rebalances",
        ],
    );
    for c in &cells {
        table.row(vec![
            c.tenants.to_string(),
            c.arrivals.to_string(),
            format!("{:.2}", c.p50_ms),
            format!("{:.2}", c.p99_ms),
            format!("{:.0}%", c.qa_hit_rate * 100.0),
            format!("{:.0}%", c.qkv_hit_rate * 100.0),
            c.rejected.to_string(),
            c.rebalances.to_string(),
        ]);
    }
    println!("{}", table.render());
    let dir = reports_dir();
    table.emit(&dir, "tenancy");
    write_bench_json(&cells, &dir)?;
    Ok(())
}

/// Emit `<dir>/BENCH_tenancy.json` — the perf-trajectory seed.
pub fn write_bench_json(cells: &[TenancyCell], dir: &std::path::Path) -> Result<()> {
    let mut root = Json::obj();
    root.insert("bench", "tenancy");
    root.insert("global_qkv_bytes", GLOBAL_SLICES * sim_slice_bytes());
    let series: Vec<Json> = cells
        .iter()
        .map(|c| {
            let mut o = Json::obj();
            o.insert("tenants", c.tenants);
            o.insert("arrivals", c.arrivals);
            o.insert("p50_ms", c.p50_ms);
            o.insert("p99_ms", c.p99_ms);
            o.insert("qa_hit_rate", c.qa_hit_rate);
            o.insert("qkv_hit_rate", c.qkv_hit_rate);
            o.insert("mean_shard_hit_rate", c.hit_rate);
            o.insert("rejected", c.rejected);
            o.insert("rebalances", c.rebalances);
            o.insert(
                "per_tenant_hit_rate",
                Json::Arr(c.per_tenant_hit_rate.iter().map(|&h| Json::Num(h)).collect()),
            );
            Json::Obj(o)
        })
        .collect();
    root.insert("series", Json::Arr(series));

    std::fs::create_dir_all(dir)?;
    let path = dir.join("BENCH_tenancy.json");
    std::fs::write(&path, Json::Obj(root).to_string_pretty())?;
    println!("[tenancy] wrote {}", path.display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_covers_all_counts_and_stays_bounded() {
        let cells = sweep().unwrap();
        assert_eq!(cells.len(), TENANT_COUNTS.len());
        for (c, &n) in cells.iter().zip(&TENANT_COUNTS) {
            assert_eq!(c.tenants, n);
            assert!(c.p50_ms <= c.p99_ms, "percentiles out of order");
            assert!(c.arrivals > 0);
            assert_eq!(c.per_tenant_hit_rate.len(), n);
        }
        // cycling query streams must produce some cache hits somewhere
        assert!(
            cells.iter().any(|c| c.qa_hit_rate + c.qkv_hit_rate > 0.0),
            "no cache hits in the whole sweep"
        );
    }

    #[test]
    fn bench_json_is_parseable() {
        let tmp = std::env::temp_dir().join(format!("percache_tenexp_{}", std::process::id()));
        let cells = sweep().unwrap();
        write_bench_json(&cells, &tmp).unwrap();
        let text = std::fs::read_to_string(tmp.join("BENCH_tenancy.json")).unwrap();
        let j = Json::parse(&text).unwrap();
        assert_eq!(j.get("bench").as_str(), Some("tenancy"));
        assert_eq!(j.get("series").as_arr().unwrap().len(), TENANT_COUNTS.len());
        let _ = std::fs::remove_dir_all(&tmp);
    }
}
