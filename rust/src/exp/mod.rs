//! Paper-figure/table reproduction harness.
//!
//! One module per evaluation section; `runner` maps experiment ids
//! (fig2…fig23, table1) to implementations.  Each experiment prints a
//! plain-text table (the paper's rows/series) and writes a CSV under
//! reports/.  See DESIGN.md §5 for the full experiment index.

pub mod ablation;
pub mod common;
pub mod dedup_exp;
pub mod motivation;
pub mod obs_exp;
pub mod overall;
pub mod overhead;
pub mod persistence_exp;
pub mod runner;
pub mod scenarios_exp;
pub mod scheduler_exp;
pub mod showcase;
pub mod tenancy_exp;
pub mod tiering_exp;

pub use runner::{
    is_runtime_free, run_all, run_experiment, run_offline, APPENDIX, EXPERIMENTS, RUNTIME_FREE,
};
