//! Trace-driven scenario suite (`percache exp scenarios`): the SLO
//! co-design experiment of DESIGN.md §14.
//!
//! Four deterministic workload scenarios (`datasets::traces`: diurnal,
//! bursty, churn, adversarial) replay under a virtual clock through the
//! full control plane — router admission, SLO monitor, governor,
//! tiering controller — across a 2×2 arm grid:
//!
//! * **static** — SLO signals recorded but never actuated (the
//!   pre-§14 behaviour: plain utility governor, no shedding).
//! * **slo** — the monitor's windowed signals feed the governor boost
//!   and the router's hysteretic admission shedding.
//! * **static_tiered** / **slo_tiered** — the same pair with warm/cold
//!   shard tiering enabled (predictor-fed prefetch, cold-tier disk
//!   budget on the churn scenario).
//!
//! Time is modeled, not measured: the clock advances by the analytic
//! serve cost (`tenancy::sim`) plus a fixed per-serve overhead, and a
//! cold pop pays a hydration (or rebuild-after-eviction) stall.  Every
//! number in the report is therefore seed-deterministic, which is what
//! lets CI gate on `reports/BENCH_scenarios.json` against a committed
//! baseline (`--baseline`, 10% regression budget on miss rates and
//! p99s).
//!
//! The acceptance bar asserted in-harness: on the overload scenarios
//! (bursty, churn) the SLO arm must beat the static arm on SLO-miss
//! rate — strictly, tiered and untiered alike.

use std::path::{Path, PathBuf};

use anyhow::Result;

use crate::config::{TenancyConfig, TieringConfig};
use crate::datasets::traces::{
    modeled_full_serve_ms, scenario, ScenarioTrace, TraceSpec, SCENARIOS,
};
use crate::metrics::ServePath;
use crate::obs::{MetricsRegistry, Tracer};
use crate::runtime::Runtime;
use crate::tenancy::sim::{serve_one, sim_slice_bytes, SimConfig};
use crate::tenancy::{
    Rejection, Router, RouterConfig, SloMonitor, TenantId, TenantRegistry,
};
use crate::tiering::TieringController;
use crate::util::bench::percentile;
use crate::util::json::Json;
use crate::util::table::Table;

use super::common::reports_dir;
use super::tiering_exp::smoke_mode;

/// Fixed per-serve scheduling overhead, modeled ms (keeps QA hits from
/// being literally free, so backlogs drain in finite virtual time).
const SERVE_OVERHEAD_MS: f64 = 0.02;
/// A cold pop stalls for this multiple of one full-cost serve
/// (hydration from disk, or an empty-rebuild after cold eviction).
const HYDRATE_STALL_FACTOR: f64 = 2.0;
/// Global QKV budget in sim slices (tight enough that the governor's
/// split matters, roomy enough that pool queries stay cacheable).
const GLOBAL_SLICES: usize = 96;
/// Cold-tier disk budget applied to the churn scenario's tiered arms —
/// churn retires tenants permanently, so snapshots accumulate and the
/// budget's oldest-first eviction gets exercised.
const COLD_BYTES_CAP: usize = 32 * 1024;
/// Tiered arms: demote after this many idle ticks.
const IDLE_TICKS_TO_DEMOTE: u64 = 6;
/// Tiered arms: prefetch lead, ticks.
const PREFETCH_LEAD_TICKS: u64 = 2;
/// The deterministic trace seed shared by every arm.
const TRACE_SEED: u64 = 0x5CE7A710;

/// One tenant's latency/SLO outcome in one arm.
#[derive(Debug, Clone)]
pub struct TenantStats {
    pub served: u64,
    pub missed: u64,
    pub p50_ms: f64,
    pub p99_ms: f64,
}

/// One (scenario, arm) replay outcome.
#[derive(Debug, Clone)]
pub struct ArmOutcome {
    pub arm: String,
    pub slo_aware: bool,
    pub tiering: bool,
    pub per_tenant: Vec<TenantStats>,
    pub served: u64,
    pub missed: u64,
    /// SLO misses / serves over the whole run.
    pub miss_rate: f64,
    pub shed_rejected: u64,
    pub other_rejected: u64,
    pub qa_hits: u64,
    pub qkv_hits: u64,
    pub full_serves: u64,
    /// Cold pops that paid a synchronous hydration stall.
    pub demand_stalls: u64,
    /// Forecast-driven hydrations (off the serving clock).
    pub prefetch_hydrations: u64,
    pub cold_evictions: u64,
    /// Evicted tenants restarted empty on demand.
    pub recreations: u64,
    pub rebalances: u64,
    /// Per-tenant budget-direction reversals summed over the run — the
    /// governor-thrash proxy the adversarial scenario watches.
    pub budget_flips: u64,
    /// Resident QKV bytes sampled after every controller tick.
    pub resident_bytes_ticks: Vec<usize>,
}

/// One scenario across all four arms.
#[derive(Debug, Clone)]
pub struct ScenarioOutcome {
    pub scenario: String,
    pub tenants: usize,
    pub ticks: usize,
    pub slo_p99_ms: Vec<f64>,
    pub arms: Vec<ArmOutcome>,
}

impl ScenarioOutcome {
    pub fn arm(&self, name: &str) -> Option<&ArmOutcome> {
        self.arms.iter().find(|a| a.arm == name)
    }
}

fn arm_name(slo_aware: bool, tiering: bool) -> &'static str {
    match (slo_aware, tiering) {
        (false, false) => "static",
        (true, false) => "slo",
        (false, true) => "static_tiered",
        (true, true) => "slo_tiered",
    }
}

/// Milliseconds on the virtual clock → integer trace nanoseconds.
fn ms_ns(ms: f64) -> u64 {
    (ms * 1e6).round() as u64
}

/// A local tracer for one scenario's `slo_tiered` replay: virtual
/// clock, every request sampled, default exemplar reservoir.  Local —
/// the global tracer (and with it `percache serve`) is never touched,
/// so `BENCH_scenarios.json` stays byte-deterministic and arm-neutral.
fn scenario_tracer() -> Tracer {
    let t = Tracer::new();
    t.set_virtual_clock(true);
    t.set_sample_every(1);
    t.set_enabled(true);
    t
}

/// Count per-tenant budget-direction reversals over the per-tick budget
/// snapshots (zeros — non-resident ticks — and flat stretches ignored).
fn budget_flips(series: &[Vec<usize>], tenants: usize) -> u64 {
    let mut flips = 0u64;
    for t in 0..tenants {
        let mut last: Option<usize> = None;
        let mut last_dir = 0i8;
        for snap in series {
            let b = snap.get(t).copied().unwrap_or(0);
            if b == 0 {
                continue;
            }
            if let Some(prev) = last {
                let dir = match b.cmp(&prev) {
                    std::cmp::Ordering::Greater => 1i8,
                    std::cmp::Ordering::Less => -1i8,
                    std::cmp::Ordering::Equal => 0i8,
                };
                if dir != 0 {
                    if last_dir != 0 && dir != last_dir {
                        flips += 1;
                    }
                    last_dir = dir;
                }
            }
            last = Some(b);
        }
    }
    flips
}

/// Replay one scenario trace through one arm under the virtual clock.
///
/// Each tick: enqueue the tick's arrivals (admission control), serve
/// until the tick's deadline, close the SLO window, and run one
/// controller tick.  When `slo_aware`, the closed window's signals are
/// published to the governor and the shedding decision to the router;
/// otherwise the monitor only measures.  After the trace ends the
/// backlog drains on the same cadence with empty arrival batches.
///
/// When `tracer` is given, every serve also records a causal trace on
/// the virtual clock (root `request`, plus `queue_wait`,
/// `hydration_stall`, `prefill`, `decode` child spans — exactly the
/// intervals that advance `clock`, so attribution is near-total); the
/// tail-exemplar reservoir inside the tracer then holds the forensics
/// that `percache trace` analyses.
pub fn replay_scenario(
    trace: &ScenarioTrace,
    slo_aware: bool,
    tiering: bool,
    predictor_prefetch: bool,
    state_dir: &Path,
    tracer: Option<&Tracer>,
) -> Result<ArmOutcome> {
    let arm = arm_name(slo_aware, tiering);
    let sim = SimConfig::default();
    let n = trace.tenants;

    let mut tc = TenancyConfig::default();
    tc.enabled = true;
    tc.max_tenants = n;
    tc.global_qkv_bytes = GLOBAL_SLICES * sim_slice_bytes();
    tc.tiering = TieringConfig {
        enabled: tiering,
        idle_ticks_to_demote: IDLE_TICKS_TO_DEMOTE,
        prefetch_lead_ticks: PREFETCH_LEAD_TICKS,
        min_resident: 1,
        predictor_prefetch,
        cold_bytes_cap: if tiering && trace.name == "churn" {
            COLD_BYTES_CAP
        } else {
            0
        },
        ..TieringConfig::default()
    };

    let mut registry = if tiering {
        let dir = state_dir.join(format!("{}_{arm}", trace.name));
        let _ = std::fs::remove_dir_all(&dir);
        TenantRegistry::open_or_create(&tc, dir)?
    } else {
        TenantRegistry::new(&tc)
    };
    for _ in 0..n {
        registry.create_tenant()?;
    }

    let local_metrics = MetricsRegistry::new();
    let mut monitor = SloMonitor::new(&tc.slo, &trace.slo_p99_ms, &local_metrics);

    let mut router: Router<(crate::tenancy::sim::Arrival, f64)> = Router::new(RouterConfig {
        queue_cap: tc.queue_cap,
        global_cap: tc.global_queue_cap,
        shed_queue_cap: tc.slo.shed_queue_cap(tc.queue_cap),
    });
    for _ in 0..n {
        router.register_tenant();
    }
    let mut ctl = TieringController::new(tc.tiering.clone(), n);

    let stall_ms = HYDRATE_STALL_FACTOR * modeled_full_serve_ms();
    let tick_ms = trace.tick_ms;

    let mut clock = 0.0f64;
    let mut e2e: Vec<Vec<f64>> = vec![Vec::new(); n];
    let mut shed_rejected = 0u64;
    let mut other_rejected = 0u64;
    let (mut qa_hits, mut qkv_hits, mut full_serves) = (0u64, 0u64, 0u64);
    let mut demand_stalls = 0u64;
    let mut prefetch_hydrations = 0u64;
    let mut cold_evictions = 0u64;
    let mut recreations = 0u64;
    let mut rebalances = 0u64;
    let mut resident_bytes_ticks = Vec::new();
    let mut budget_series: Vec<Vec<usize>> = Vec::new();

    let n_ticks = trace.n_ticks();
    let mut k = 0usize;
    loop {
        let draining = k >= n_ticks;
        if draining && router.is_empty() {
            break;
        }
        anyhow::ensure!(
            k < n_ticks * 4 + 1024,
            "scenario '{}' arm '{arm}': backlog failed to drain",
            trace.name
        );
        let tick_start = k as f64 * tick_ms;
        let deadline = tick_start + tick_ms;
        if clock < tick_start {
            clock = tick_start;
        }

        if !draining {
            for a in &trace.ticks[k] {
                match router.try_push(a.tenant, (a.clone(), tick_start)) {
                    Ok(()) => {
                        ctl.note_request(a.tenant);
                        // feed the periodicity forecaster in controller
                        // tick units (the controller's `now` after this
                        // tick closes is k+1)
                        if let Some(s) = registry.shard_mut(a.tenant) {
                            s.predictor.observe_arrival(ctl.tick_count() + 1);
                        }
                    }
                    Err((Rejection::Shed, _)) => shed_rejected += 1,
                    Err(_) => other_rejected += 1,
                }
            }
        }
        registry.set_queue_depths(&router.depths());

        while clock < deadline {
            let Some((tenant, (a, arr_ms))) = router.pop() else {
                break;
            };
            // snapshot the pop instant before any hydration stall so the
            // trace splits queue_wait [arr, pop] from the stall interval
            let pop_ms = clock;
            let mut stalled = false;
            if registry.shard(tenant).is_none() {
                if registry.cold_evicted(tenant) {
                    registry.recreate_evicted(tenant)?;
                    recreations += 1;
                } else {
                    registry.hydrate_tenant(tenant)?;
                    demand_stalls += 1;
                }
                clock += stall_ms;
                stalled = true;
            }
            let queue_delay = (clock - arr_ms).max(0.0);
            let shard = registry
                .shard_mut(tenant)
                .ok_or_else(|| anyhow::anyhow!("tenant {tenant} not resident after hydration"))?;
            let rec = serve_one(&sim, shard, &a.query, &a.seg_keys)?;
            let serve_start_ms = clock;
            clock += SERVE_OVERHEAD_MS + rec.prefill_ms + rec.decode_ms;
            match rec.path {
                ServePath::QaHit => qa_hits += 1,
                ServePath::QkvHit => qkv_hits += 1,
                ServePath::Full => full_serves += 1,
            }
            let e2e_ms = clock - arr_ms;
            if let Some(tr) = tracer {
                if let Some(tctx) = tr.begin_trace("request", Some(tenant), ms_ns(arr_ms)) {
                    let root = Some(tctx.span);
                    if pop_ms > arr_ms {
                        tr.add_span(tctx.trace, root, "queue_wait", ms_ns(arr_ms), ms_ns(pop_ms));
                    }
                    if stalled {
                        tr.add_span(
                            tctx.trace,
                            root,
                            "hydration_stall",
                            ms_ns(pop_ms),
                            ms_ns(pop_ms + stall_ms),
                        );
                    }
                    let prefill_start = serve_start_ms + SERVE_OVERHEAD_MS;
                    if rec.prefill_ms > 0.0 {
                        tr.add_span(
                            tctx.trace,
                            root,
                            "prefill",
                            ms_ns(prefill_start),
                            ms_ns(prefill_start + rec.prefill_ms),
                        );
                    }
                    if rec.decode_ms > 0.0 {
                        let decode_start = prefill_start + rec.prefill_ms;
                        tr.add_span(
                            tctx.trace,
                            root,
                            "decode",
                            ms_ns(decode_start),
                            ms_ns(decode_start + rec.decode_ms),
                        );
                    }
                    tr.set_virtual_ns(ms_ns(clock));
                    tr.end_trace(tctx, ms_ns(clock));
                }
            }
            monitor.record(tenant, e2e_ms, queue_delay);
            e2e[tenant as usize].push(e2e_ms);
            if registry.note_serve() {
                rebalances += 1;
            }
        }
        registry.set_queue_depths(&router.depths());

        // close the scheduling window; in the SLO arms the signals
        // actuate the governor boost and the admission shed
        let signals = monitor.close_window();
        if slo_aware {
            registry.set_slo_signals(&signals);
            for t in 0..n as TenantId {
                router.set_shed(t, monitor.shedding(t));
            }
        }
        let rep = ctl.tick(&mut registry)?;
        cold_evictions += rep.cold_evicted.len() as u64;
        for t in rep.prefetch {
            // forecast-driven hydration happens off the serving clock —
            // that is the entire point of prefetching
            registry.hydrate_tenant(t)?;
            prefetch_hydrations += 1;
        }
        resident_bytes_ticks.push(registry.resident_bytes());
        budget_series.push(
            (0..n as TenantId)
                .map(|t| registry.shard(t).map(|s| s.qkv_budget()).unwrap_or(0))
                .collect(),
        );
        k += 1;
    }
    registry.check_invariants()?;

    let mut per_tenant = Vec::with_capacity(n);
    for t in 0..n as TenantId {
        let (served, missed) = monitor.totals(t);
        let mut lat = e2e[t as usize].clone();
        lat.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        per_tenant.push(TenantStats {
            served,
            missed,
            p50_ms: if lat.is_empty() { 0.0 } else { percentile(&lat, 50.0) },
            p99_ms: if lat.is_empty() { 0.0 } else { percentile(&lat, 99.0) },
        });
    }
    let served: u64 = per_tenant.iter().map(|t| t.served).sum();
    let missed: u64 = per_tenant.iter().map(|t| t.missed).sum();
    Ok(ArmOutcome {
        arm: arm.to_string(),
        slo_aware,
        tiering,
        budget_flips: budget_flips(&budget_series, n),
        per_tenant,
        served,
        missed,
        miss_rate: if served > 0 {
            missed as f64 / served as f64
        } else {
            0.0
        },
        shed_rejected,
        other_rejected,
        qa_hits,
        qkv_hits,
        full_serves,
        demand_stalls,
        prefetch_hydrations,
        cold_evictions,
        recreations,
        rebalances,
        resident_bytes_ticks,
    })
}

/// Replay every scenario across the four arms and assert the §14
/// acceptance bar in-harness: on bursty and churn, the SLO arm's miss
/// rate must be strictly below the static arm's (tiered pair included).
pub fn sweep(smoke: bool, state_root: &Path) -> Result<Vec<ScenarioOutcome>> {
    sweep_with_traces(smoke, state_root, None)
}

/// Like [`sweep`], but when `traces` is given the `slo_tiered` arm of
/// each scenario also records causal traces on the virtual clock; the
/// per-scenario `percache.trace/v1` dumps (tail exemplars only) are
/// pushed onto `traces`.  The traced replay is byte-identical to the
/// untraced one — the tracer only observes the virtual clock, never
/// advances it.
pub fn sweep_with_traces(
    smoke: bool,
    state_root: &Path,
    mut traces: Option<&mut Vec<(String, Json)>>,
) -> Result<Vec<ScenarioOutcome>> {
    let spec = if smoke {
        TraceSpec::smoke(TRACE_SEED)
    } else {
        TraceSpec::full(TRACE_SEED)
    };
    let mut out = Vec::new();
    for name in SCENARIOS {
        let trace = scenario(name, &spec)?;
        let tracer = traces.is_some().then(scenario_tracer);
        let arms = vec![
            replay_scenario(&trace, false, false, true, state_root, None)?,
            replay_scenario(&trace, true, false, true, state_root, None)?,
            replay_scenario(&trace, false, true, true, state_root, None)?,
            replay_scenario(&trace, true, true, true, state_root, tracer.as_ref())?,
        ];
        if let (Some(list), Some(t)) = (traces.as_deref_mut(), tracer.as_ref()) {
            list.push((name.to_string(), t.export_json()));
        }
        let sc = ScenarioOutcome {
            scenario: name.to_string(),
            tenants: trace.tenants,
            ticks: trace.n_ticks(),
            slo_p99_ms: trace.slo_p99_ms.clone(),
            arms,
        };
        if matches!(name, "bursty" | "churn") {
            for (governed, baseline) in [("slo", "static"), ("slo_tiered", "static_tiered")] {
                let g = sc.arm(governed).map(|a| a.miss_rate).unwrap_or(1.0);
                let b = sc.arm(baseline).map(|a| a.miss_rate).unwrap_or(0.0);
                anyhow::ensure!(
                    g < b,
                    "{name}: SLO arm '{governed}' miss rate {g:.4} must be strictly \
                     below '{baseline}' {b:.4}"
                );
            }
        }
        out.push(sc);
    }
    Ok(out)
}

fn tenant_json(t: &TenantStats) -> Json {
    let mut o = Json::obj();
    o.insert("served", t.served);
    o.insert("missed", t.missed);
    o.insert("p50_ms", t.p50_ms);
    o.insert("p99_ms", t.p99_ms);
    Json::Obj(o)
}

fn arm_json(a: &ArmOutcome) -> Json {
    let mut o = Json::obj();
    o.insert("arm", a.arm.as_str());
    o.insert("slo_aware", a.slo_aware);
    o.insert("tiering", a.tiering);
    o.insert("served", a.served);
    o.insert("missed", a.missed);
    o.insert("miss_rate", a.miss_rate);
    o.insert("shed_rejected", a.shed_rejected);
    o.insert("other_rejected", a.other_rejected);
    o.insert("qa_hits", a.qa_hits);
    o.insert("qkv_hits", a.qkv_hits);
    o.insert("full_serves", a.full_serves);
    o.insert("demand_stalls", a.demand_stalls);
    o.insert("prefetch_hydrations", a.prefetch_hydrations);
    o.insert("cold_evictions", a.cold_evictions);
    o.insert("recreations", a.recreations);
    o.insert("rebalances", a.rebalances);
    o.insert("budget_flips", a.budget_flips);
    o.insert(
        "per_tenant",
        Json::Arr(a.per_tenant.iter().map(tenant_json).collect()),
    );
    let rb = &a.resident_bytes_ticks;
    o.insert(
        "resident_bytes",
        Json::Arr(rb.iter().map(|&b| Json::from(b)).collect()),
    );
    Json::Obj(o)
}

/// The `BENCH_scenarios.json` document.  Deliberately timestamp-free:
/// the replay is deterministic, so byte-identical reruns are part of
/// the contract (and what the baseline gate leans on).
pub fn bench_json(outcomes: &[ScenarioOutcome], smoke: bool) -> Json {
    let mut root = Json::obj();
    root.insert("bench", "scenarios");
    root.insert("smoke", smoke);
    root.insert("seed", TRACE_SEED);
    root.insert("global_qkv_bytes", GLOBAL_SLICES * sim_slice_bytes());
    let list = outcomes
        .iter()
        .map(|sc| {
            let mut o = Json::obj();
            o.insert("scenario", sc.scenario.as_str());
            o.insert("tenants", sc.tenants);
            o.insert("ticks", sc.ticks);
            o.insert(
                "slo_p99_ms",
                Json::Arr(sc.slo_p99_ms.iter().map(|&v| Json::from(v)).collect()),
            );
            o.insert("arms", Json::Arr(sc.arms.iter().map(arm_json).collect()));
            Json::Obj(o)
        })
        .collect();
    root.insert("scenarios", Json::Arr(list));
    Json::Obj(root)
}

/// The `reports/TRACE_scenarios.json` document: one `percache.trace/v1`
/// dump per scenario (the `slo_tiered` arm's tail exemplars).  Kept out
/// of `BENCH_scenarios.json` so the committed baseline and its
/// byte-equal determinism contract are untouched; `percache trace`
/// consumes this file directly.
pub fn trace_json(per_scenario: &[(String, Json)]) -> Json {
    let mut root = Json::obj();
    root.insert("bench", "scenarios_trace");
    root.insert("arm", "slo_tiered");
    root.insert("seed", TRACE_SEED);
    let list = per_scenario
        .iter()
        .map(|(name, dump)| {
            let mut o = Json::obj();
            o.insert("scenario", name.as_str());
            o.insert("trace", dump.clone());
            Json::Obj(o)
        })
        .collect();
    root.insert("scenarios", Json::Arr(list));
    Json::Obj(root)
}

/// Regression budget: `fresh` may exceed `base` by at most 10% plus a
/// small absolute slack (so a zero baseline doesn't demand zero).
fn regressed(fresh: f64, base: f64, abs_slack: f64) -> bool {
    fresh > base * 1.10 + abs_slack
}

/// Compare a fresh bench document against the committed baseline.
/// Returns the list of violations (empty = gate passes); entries
/// present in only one document are skipped — regenerate the baseline
/// when arms or scenarios change shape.
pub fn baseline_violations(fresh: &Json, base: &Json) -> Vec<String> {
    let mut violations = Vec::new();
    let empty: &[Json] = &[];
    let base_scenarios = base.get("scenarios").as_arr().unwrap_or(empty);
    for sc in fresh.get("scenarios").as_arr().unwrap_or(empty) {
        let name = sc.get("scenario").as_str().unwrap_or("?");
        let Some(bsc) = base_scenarios
            .iter()
            .find(|b| b.get("scenario").as_str() == sc.get("scenario").as_str())
        else {
            continue;
        };
        let base_arms = bsc.get("arms").as_arr().unwrap_or(empty);
        for arm in sc.get("arms").as_arr().unwrap_or(empty) {
            let arm_name = arm.get("arm").as_str().unwrap_or("?");
            let Some(barm) = base_arms
                .iter()
                .find(|b| b.get("arm").as_str() == arm.get("arm").as_str())
            else {
                continue;
            };
            let fresh_miss = arm.get("miss_rate").as_f64().unwrap_or(0.0);
            let base_miss = barm.get("miss_rate").as_f64().unwrap_or(0.0);
            if regressed(fresh_miss, base_miss, 0.01) {
                violations.push(format!(
                    "{name}/{arm_name}: miss_rate {fresh_miss:.4} regressed past \
                     baseline {base_miss:.4} + 10%"
                ));
            }
            let max_p99 = |j: &Json| -> f64 {
                j.get("per_tenant")
                    .as_arr()
                    .unwrap_or(empty)
                    .iter()
                    .map(|t| t.get("p99_ms").as_f64().unwrap_or(0.0))
                    .fold(0.0, f64::max)
            };
            let fresh_p99 = max_p99(arm);
            let base_p99 = max_p99(barm);
            if regressed(fresh_p99, base_p99, 0.1) {
                violations.push(format!(
                    "{name}/{arm_name}: worst tenant p99 {fresh_p99:.3}ms regressed past \
                     baseline {base_p99:.3}ms + 10%"
                ));
            }
        }
    }
    violations
}

/// Gate against `path`.  A missing baseline bootstraps: the fresh
/// document is written there (commit it to arm the gate); an existing
/// baseline fails the run on any >10% miss-rate or p99 regression.
fn check_baseline(fresh: &Json, path: &Path) -> Result<()> {
    if !path.exists() {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, fresh.to_string_pretty())?;
        println!(
            "[scenarios] no baseline at {} — bootstrapped one from this run; \
             commit it to arm the regression gate",
            path.display()
        );
        return Ok(());
    }
    let text = std::fs::read_to_string(path)?;
    let base = Json::parse(&text)
        .map_err(|e| anyhow::anyhow!("unparseable baseline {}: {e:?}", path.display()))?;
    let violations = baseline_violations(fresh, &base);
    if violations.is_empty() {
        println!("[scenarios] baseline gate passed ({})", path.display());
        Ok(())
    } else {
        anyhow::bail!(
            "scenario bench regressed vs {}:\n  {}",
            path.display(),
            violations.join("\n  ")
        )
    }
}

/// Shared by the exp registry, the offline dispatcher and tests.
pub fn run_and_report() -> Result<()> {
    let smoke = smoke_mode();
    let state_dir = std::env::temp_dir().join(format!(
        "percache_scenarios_exp_{}",
        std::process::id()
    ));
    let mut scenario_traces: Vec<(String, Json)> = Vec::new();
    let outcomes = sweep_with_traces(smoke, &state_dir, Some(&mut scenario_traces))?;
    let _ = std::fs::remove_dir_all(&state_dir);

    let mut table = Table::new(
        "scenarios: SLO-aware governor/admission vs static, per scenario",
        &[
            "scenario", "arm", "served", "miss rate", "shed", "worst p99 ms", "stalls",
            "prefetches", "cold evict", "flips",
        ],
    );
    for sc in &outcomes {
        for a in &sc.arms {
            let worst_p99 = a
                .per_tenant
                .iter()
                .map(|t| t.p99_ms)
                .fold(0.0, f64::max);
            table.row(vec![
                sc.scenario.clone(),
                a.arm.clone(),
                a.served.to_string(),
                format!("{:.1}%", a.miss_rate * 100.0),
                a.shed_rejected.to_string(),
                format!("{worst_p99:.2}"),
                a.demand_stalls.to_string(),
                a.prefetch_hydrations.to_string(),
                a.cold_evictions.to_string(),
                a.budget_flips.to_string(),
            ]);
        }
    }
    println!("{}", table.render());

    let dir = reports_dir();
    table.emit(&dir, "scenarios");
    let doc = bench_json(&outcomes, smoke);
    std::fs::create_dir_all(&dir)?;
    let path = dir.join("BENCH_scenarios.json");
    std::fs::write(&path, doc.to_string_pretty())?;
    println!("[scenarios] wrote {}", path.display());
    let trace_path = dir.join("TRACE_scenarios.json");
    std::fs::write(&trace_path, trace_json(&scenario_traces).to_string_pretty())?;
    println!(
        "[scenarios] wrote {} (analyse with `percache trace {}`)",
        trace_path.display(),
        trace_path.display()
    );

    if let Ok(baseline) = std::env::var("PERCACHE_BASELINE") {
        if !baseline.is_empty() {
            check_baseline(&doc, &PathBuf::from(baseline))?;
        }
    }
    Ok(())
}

/// `percache exp scenarios` entry point (runtime unused: cache-level
/// replay under a virtual clock).
pub fn scenarios(_rt: &Runtime) -> Result<()> {
    run_and_report()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("percache_scenexp_{tag}_{}", std::process::id()))
    }

    #[test]
    fn smoke_sweep_covers_every_scenario_and_arm() {
        let dir = tmp("shape");
        let outcomes = sweep(true, &dir).unwrap();
        let _ = std::fs::remove_dir_all(&dir);
        assert_eq!(outcomes.len(), SCENARIOS.len());
        for sc in &outcomes {
            assert_eq!(sc.arms.len(), 4, "{}", sc.scenario);
            for arm in ["static", "slo", "static_tiered", "slo_tiered"] {
                let a = sc.arm(arm).unwrap_or_else(|| panic!("{arm} missing"));
                assert!(a.served > 0, "{}/{arm} served nothing", sc.scenario);
                assert_eq!(a.per_tenant.len(), sc.tenants);
                assert!(!a.resident_bytes_ticks.is_empty());
            }
        }
    }

    #[test]
    fn bench_json_is_parseable_and_deterministic() {
        let a = sweep(true, &tmp("det_a")).unwrap();
        let b = sweep(true, &tmp("det_b")).unwrap();
        let _ = std::fs::remove_dir_all(tmp("det_a"));
        let _ = std::fs::remove_dir_all(tmp("det_b"));
        let ja = bench_json(&a, true).to_string_pretty();
        let jb = bench_json(&b, true).to_string_pretty();
        assert_eq!(ja, jb, "scenario replay must be deterministic");
        let parsed = Json::parse(&ja).unwrap();
        assert_eq!(parsed.get("bench").as_str(), Some("scenarios"));
        assert_eq!(
            parsed.get("scenarios").as_arr().map(|s| s.len()),
            Some(SCENARIOS.len())
        );
    }

    #[test]
    fn baseline_gate_flags_regressions_and_tolerates_shape_drift() {
        let dir = tmp("base");
        let outcomes = sweep(true, &dir).unwrap();
        let _ = std::fs::remove_dir_all(&dir);
        let doc = bench_json(&outcomes, true);
        // identical docs pass
        assert!(baseline_violations(&doc, &doc).is_empty());
        // a worsened copy violates
        let mut worse = outcomes.clone();
        for sc in &mut worse {
            for a in &mut sc.arms {
                a.miss_rate = a.miss_rate * 2.0 + 0.5;
            }
        }
        let fresh = bench_json(&worse, true);
        assert!(!baseline_violations(&fresh, &doc).is_empty());
        // unknown scenarios/arms in the fresh doc are skipped, not fatal
        let mut empty_base = Json::obj();
        empty_base.insert("scenarios", Json::Arr(Vec::new()));
        assert!(baseline_violations(&fresh, &Json::Obj(empty_base)).is_empty());
    }

    #[test]
    fn traced_replay_is_neutral_deterministic_and_attributes_the_tail() {
        let mut ta: Vec<(String, Json)> = Vec::new();
        let a = sweep_with_traces(true, &tmp("tr_a"), Some(&mut ta)).unwrap();
        let mut tb: Vec<(String, Json)> = Vec::new();
        let b = sweep_with_traces(true, &tmp("tr_b"), Some(&mut tb)).unwrap();
        let plain = sweep(true, &tmp("tr_p")).unwrap();
        for tag in ["tr_a", "tr_b", "tr_p"] {
            let _ = std::fs::remove_dir_all(tmp(tag));
        }
        // the tracer only observes the virtual clock: bench output is
        // byte-identical with and without capture
        assert_eq!(
            bench_json(&a, true).to_string_pretty(),
            bench_json(&plain, true).to_string_pretty(),
            "trace capture must not perturb the replay"
        );
        // the trace dump itself is byte-deterministic
        assert_eq!(
            trace_json(&ta).to_string_pretty(),
            trace_json(&tb).to_string_pretty(),
            "trace capture must be deterministic"
        );
        assert_eq!(bench_json(&a, true), bench_json(&b, true));
        // every scenario captured exemplars, and every tail exemplar
        // attributes >= 95% of its end-to-end time to named stages
        assert_eq!(ta.len(), SCENARIOS.len());
        for (name, dump) in &ta {
            let entries = crate::obs::trace::parse_dump(dump).unwrap();
            assert!(!entries.is_empty(), "{name}: no exemplars captured");
            let mut tails = 0;
            for e in entries.iter().filter(|e| e.kind == "tail") {
                tails += 1;
                let att = crate::obs::trace::attribute(&e.trace)
                    .unwrap_or_else(|| panic!("{name}: empty trace"));
                assert!(
                    att.unattributed_frac() < 0.05,
                    "{name}: trace {} unattributed {:.1}% of {:.3}ms",
                    att.trace,
                    att.unattributed_frac() * 100.0,
                    att.e2e_ms
                );
            }
            assert!(tails > 0, "{name}: no tail exemplars");
        }
    }

    #[test]
    fn budget_flips_counts_direction_reversals_only() {
        // grow, grow, shrink, grow → two reversals; zeros skipped
        let series = vec![
            vec![10, 0],
            vec![20, 0],
            vec![30, 5],
            vec![25, 5],
            vec![0, 5],
            vec![40, 5],
        ];
        assert_eq!(budget_flips(&series, 2), 2);
        // monotone series never flips
        let mono = vec![vec![1], vec![2], vec![3]];
        assert_eq!(budget_flips(&mono, 1), 0);
    }
}
