//! Cache-scheduler micro-benchmarks: Figs 15a/15b/15c (paper §5.5),
//! on the MISeD user0 subset like the paper.

use anyhow::Result;

use super::common::{replay_config, reports_dir, ReplayOpts};
use crate::config::PerCacheConfig;
use crate::datasets;
use crate::runtime::Runtime;
use crate::util::table::Table;

/// Fig 15a: τ_query raised 0.85 → 0.90 after query 2; with the scheduler,
/// population switches to prefill-only and accumulated TFLOPs flatten.
pub fn fig15a(rt: &Runtime) -> Result<()> {
    let data = datasets::generate("mised", 0);
    let mut base = PerCacheConfig::default();
    base.tau_query = 0.85;

    let opts = ReplayOpts {
        tau_schedule: vec![(3, 0.90)],
        ..Default::default()
    };

    let run = |scheduler_on: bool| -> Result<(Vec<u64>, Vec<f64>)> {
        let mut cfg = base.clone();
        cfg.scheduler_enabled = scheduler_on;
        let out = replay_config(rt, &cfg, &data, &opts)?;
        let lat: Vec<f64> = out.recorder.records.iter().map(|r| r.total_ms()).collect();
        Ok((out.population_flops_series, lat))
    };

    let (with_sched, lat_on) = run(true)?;
    let (without, lat_off) = run(false)?;

    let mut t = Table::new(
        "Fig 15a — accumulated population TFLOPs (τ 0.85→0.90 after q2)",
        &["query", "with_scheduler", "without_scheduler", "lat_with_ms", "lat_without_ms"],
    );
    for i in 0..with_sched.len().min(without.len()) {
        t.row(vec![
            format!("q{i}"),
            format!("{:.3}", with_sched[i] as f64 / 1e12),
            format!("{:.3}", without[i] as f64 / 1e12),
            format!("{:.0}", lat_on[i]),
            format!("{:.0}", lat_off[i]),
        ]);
    }
    t.emit(&reports_dir(), "fig15a");

    let last = with_sched.len() - 1;
    let saving = 1.0 - with_sched[last] as f64 / without[last].max(1) as f64;
    println!(
        "[fig15a] scheduler saves {:.1}% population compute after q{last} \
         (paper: 14.12% after Query9) with comparable latency",
        saving * 100.0
    );
    anyhow::ensure!(
        with_sched[last] < without[last],
        "scheduler must reduce population compute at high τ"
    );
    Ok(())
}

/// Fig 15b: τ_query dropped 0.90 → 0.85 after query 5; the scheduler
/// decodes the pending (answer-less) QA entries so later queries hit.
pub fn fig15b(rt: &Runtime) -> Result<()> {
    let data = datasets::generate("mised", 0);
    let mut base = PerCacheConfig::default();
    base.tau_query = 0.90; // start high: population is prefill-only

    let opts = ReplayOpts {
        tau_schedule: vec![(5, 0.85)],
        ..Default::default()
    };

    let mut with_cfg = base.clone();
    with_cfg.scheduler_enabled = true;
    let with_sched = replay_config(rt, &with_cfg, &data, &opts)?;

    // baseline without scheduler: always prefill+decode population
    let mut without_cfg = base.clone();
    without_cfg.scheduler_enabled = false;
    let without = replay_config(rt, &without_cfg, &data, &opts)?;

    let mut t = Table::new(
        "Fig 15b — per-query latency after τ 0.90→0.85 at q5 (QKV→QA conversion)",
        &["query", "scheduler_ms", "no_scheduler_ms"],
    );
    for (i, (a, b)) in with_sched
        .recorder
        .records
        .iter()
        .zip(&without.recorder.records)
        .enumerate()
    {
        t.row(vec![
            format!("q{i}"),
            format!("{:.0}", a.total_ms()),
            format!("{:.0}", b.total_ms()),
        ]);
    }
    t.emit(&reports_dir(), "fig15b");

    let mean_with = with_sched.recorder.mean_total_ms();
    let mean_without = without.recorder.mean_total_ms();
    println!(
        "[fig15b] scheduler {:.0} ms vs always-decode {:.0} ms — comparable latency \
         with less upfront compute (paper: 'comparable to the baseline')",
        mean_with, mean_without
    );
    Ok(())
}

/// Fig 15c: QKV storage relaxed mid-stream; the scheduler restores
/// evicted slices from QA-bank queries, and later queries match more
/// cached segments.
pub fn fig15c(rt: &Runtime) -> Result<()> {
    let data = datasets::generate("mised", 0);
    let mut base = PerCacheConfig::default();
    // tight budget ≈ 6 "GB" paper-equivalent: only the most recent path
    // survives, so eviction churn is severe before the relax point
    let slice = 4 * 3 * 64 * 256 * 4 + 16;
    base.qkv_storage_bytes = 3 * slice;
    // isolate the QA→QKV *conversion*: reactive population (prediction
    // would refill the tree in both runs) and τ above any paraphrase so
    // every query exercises the QKV path (the layer §5.5.3 measures)
    base.population = crate::config::PopulationMode::Reactive;
    base.tau_query = 0.99;

    let grow = |on: bool| -> Result<(Vec<f64>, Vec<usize>)> {
        let mut cfg = base.clone();
        cfg.scheduler_enabled = on;
        let opts = ReplayOpts {
            storage_schedule: vec![(6, 12 * slice)], // 6GB→8GB analogue
            ..Default::default()
        };
        let out = replay_config(rt, &cfg, &data, &opts)?;
        Ok((
            out.recorder.records.iter().map(|r| r.total_ms()).collect(),
            out.recorder.records.iter().map(|r| r.matched_segments).collect(),
        ))
    };

    let (lat_on, seg_on) = grow(true)?;
    let (lat_off, seg_off) = grow(false)?;

    let mut t = Table::new(
        "Fig 15c — storage relaxed at q6 (QA→QKV restore)",
        &["query", "sched_ms", "sched_matched", "nosched_ms", "nosched_matched"],
    );
    for i in 0..lat_on.len().min(lat_off.len()) {
        t.row(vec![
            format!("q{i}"),
            format!("{:.0}", lat_on[i]),
            seg_on[i].to_string(),
            format!("{:.0}", lat_off[i]),
            seg_off[i].to_string(),
        ]);
    }
    t.emit(&reports_dir(), "fig15c");

    let tail_on: usize = seg_on[7.min(seg_on.len() - 1)..].iter().sum();
    let tail_off: usize = seg_off[7.min(seg_off.len() - 1)..].iter().sum();
    println!(
        "[fig15c] matched segments after relax: scheduler {tail_on} vs no-scheduler {tail_off} \
         (paper: 2 chunks vs 1 chunk matched for q7..q9)"
    );
    Ok(())
}
