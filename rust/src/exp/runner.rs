//! Experiment registry + dispatcher: every table and figure in the
//! paper's evaluation maps to one entry here (`percache exp <id>`).

use anyhow::Result;

use crate::runtime::Runtime;

use super::{
    ablation, dedup_exp, motivation, obs_exp, overall, overhead, persistence_exp, scenarios_exp,
    scheduler_exp, showcase, tenancy_exp, tiering_exp,
};

/// All experiment ids, in paper order.
pub const EXPERIMENTS: [&str; 18] = [
    "fig2", "fig3", "fig4", "fig5", "fig6",
    "fig11", "fig12", "fig13",
    "fig14",
    "fig15a", "fig15b", "fig15c",
    "fig16", "fig17", "fig18", "fig19",
    "fig20", "table1",
];

/// Appendix experiments (heavier; included in `exp all` but also
/// runnable individually).  `tenancy` is the multi-tenant scaling sweep
/// introduced on top of the paper's evaluation (emits the
/// machine-readable reports/BENCH_tenancy.json perf seed); `persistence`
/// is the cold-vs-warm restart comparison (reports/BENCH_persistence.json);
/// `tiering` is the warm/cold shard-residency comparison
/// (reports/BENCH_tiering.json); `obs` measures telemetry overhead,
/// enabled vs disabled, on the tenancy workload (reports/BENCH_obs.json);
/// `scenarios` is the trace-driven SLO co-design suite — four workload
/// scenarios across static/SLO × tiering-on/off arms
/// (reports/BENCH_scenarios.json, gated vs a committed baseline);
/// `dedup` compares per-tenant-copy vs cross-tenant pooled slice
/// storage over a shared corpus (reports/BENCH_dedup.json).
pub const APPENDIX: [&str; 9] = [
    "fig21",
    "fig22",
    "fig23",
    "tenancy",
    "persistence",
    "tiering",
    "obs",
    "scenarios",
    "dedup",
];

/// Experiments that run entirely at the cache level — no PJRT artifacts,
/// dispatchable without a [`Runtime`] via [`run_offline`] (the CI path).
pub const RUNTIME_FREE: [&str; 6] =
    ["tenancy", "persistence", "tiering", "obs", "scenarios", "dedup"];

pub fn is_runtime_free(name: &str) -> bool {
    RUNTIME_FREE.contains(&name)
}

/// Dispatch a [`RUNTIME_FREE`] experiment without loading artifacts.
pub fn run_offline(name: &str) -> Result<()> {
    let t0 = std::time::Instant::now();
    println!("\n=== {name} ===");
    match name {
        "tenancy" => tenancy_exp::run_and_report()?,
        "persistence" => persistence_exp::run_and_report()?,
        "tiering" => tiering_exp::run_and_report()?,
        "obs" => obs_exp::run_and_report()?,
        "scenarios" => scenarios_exp::run_and_report()?,
        "dedup" => dedup_exp::run_and_report()?,
        other => anyhow::bail!("'{other}' needs artifacts — runtime-free: {RUNTIME_FREE:?}"),
    }
    println!("[{name}] done in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}

pub fn run_experiment(rt: &Runtime, name: &str) -> Result<()> {
    let t0 = std::time::Instant::now();
    println!("\n=== {name} ===");
    match name {
        "fig2" => motivation::fig2(rt)?,
        "fig3" => motivation::fig3(rt)?,
        "fig4" => motivation::fig4(rt)?,
        "fig5" => motivation::fig5(rt)?,
        "fig6" => motivation::fig6(rt)?,
        "fig11" => showcase::fig11(rt)?,
        "fig12" => showcase::fig12(rt)?,
        "fig13" => showcase::fig13(rt)?,
        "fig14" => overall::fig14(rt)?,
        "fig15a" => scheduler_exp::fig15a(rt)?,
        "fig15b" => scheduler_exp::fig15b(rt)?,
        "fig15c" => scheduler_exp::fig15c(rt)?,
        "fig16" => ablation::fig16(rt)?,
        "fig17" => ablation::fig17(rt)?,
        "fig18" => ablation::fig18(rt)?,
        "fig19" => ablation::fig19(rt)?,
        "fig20" => overhead::fig20(rt)?,
        "fig21" => overall::fig21(rt)?,
        "fig22" => overall::fig22(rt)?,
        "fig23" => overall::fig23(rt)?,
        "table1" => overhead::table1(rt)?,
        "tenancy" => tenancy_exp::tenancy(rt)?,
        "persistence" => persistence_exp::persistence(rt)?,
        "tiering" => tiering_exp::tiering(rt)?,
        "obs" => obs_exp::obs(rt)?,
        "scenarios" => scenarios_exp::scenarios(rt)?,
        "dedup" => dedup_exp::dedup(rt)?,
        other => anyhow::bail!(
            "unknown experiment '{other}' — known: {:?} + {:?}",
            EXPERIMENTS,
            APPENDIX
        ),
    }
    println!("[{name}] done in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}

/// Everything, in order (the `exp all` target).
pub fn run_all(rt: &Runtime) -> Result<()> {
    for name in EXPERIMENTS.iter().chain(APPENDIX.iter()) {
        run_experiment(rt, name)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_has_every_paper_artifact() {
        // §2: figs 2–6 (motivation); §5: figs 11–20 + table 1; appendix:
        // figs 21–23.  Fig 7–10 are architecture diagrams (no data).
        for id in ["fig2", "fig14", "fig15a", "fig19", "fig20", "table1"] {
            assert!(EXPERIMENTS.contains(&id), "{id} missing");
        }
        for id in [
            "fig21",
            "fig22",
            "fig23",
            "tenancy",
            "persistence",
            "tiering",
            "obs",
            "scenarios",
            "dedup",
        ] {
            assert!(APPENDIX.contains(&id), "{id} missing");
        }
        for id in RUNTIME_FREE {
            assert!(APPENDIX.contains(&id), "runtime-free {id} must be registered");
        }
    }
}
