//! Motivation-section reproductions: Figs 2, 3, 4, 5, 6 (paper §2).

use anyhow::Result;

use super::common::{reports_dir, scale};
use crate::config::PerCacheConfig;
use crate::datasets;
use crate::embedding::{cosine, Embedder};
use crate::llm::ReuseVariant;
use crate::metrics::Stage;
use crate::retrieval::Retriever;
use crate::runtime::Runtime;
use crate::sim;
use crate::util::table::Table;

/// Fig 2: pairwise semantic similarity of one user's queries, for one
/// Email-dataset and one Dialog-dataset user.
pub fn fig2(rt: &Runtime) -> Result<()> {
    for (ds, user) in [("email", 1usize), ("dialog", 0usize)] {
        let data = datasets::generate(ds, user);
        let embedder = Embedder::new(rt);
        let embs: Vec<Vec<f32>> = data
            .queries
            .iter()
            .map(|q| embedder.embed(&q.text))
            .collect::<Result<_>>()?;

        let n = embs.len();
        let mut cols = vec!["q".to_string()];
        cols.extend((0..n).map(|i| format!("q{i}")));
        let col_refs: Vec<&str> = cols.iter().map(|s| s.as_str()).collect();
        let mut t = Table::new(
            &format!("Fig 2 — pairwise query similarity ({ds} user{user})"),
            &col_refs,
        );
        let mut high_pairs = 0;
        for i in 0..n {
            let mut row = vec![format!("q{i}")];
            for j in 0..n {
                let s = cosine(&embs[i], &embs[j]) as f64;
                if i < j && s > 0.8 {
                    high_pairs += 1;
                }
                row.push(format!("{s:.2}"));
            }
            t.row(row);
        }
        t.emit(&reports_dir(), &format!("fig2_{ds}_user{user}"));
        println!(
            "[fig2] {ds} user{user}: {high_pairs} off-diagonal pairs with similarity > 0.8 \
             (paper: some pairs reach 0.815+)"
        );
        anyhow::ensure!(high_pairs > 0, "fig2: expected at least one similar pair");
    }
    Ok(())
}

/// Fig 3: probability distribution of chunk retrieval frequencies
/// (top-2 retrieval per query, per user).
pub fn fig3(rt: &Runtime) -> Result<()> {
    for ds in ["email", "dialog"] {
        let mut t = Table::new(
            &format!("Fig 3 — chunk retrieval frequency density ({ds})"),
            &["user", "freq=0", "freq=1", "freq=2", "freq=3+", "mean_freq", "all_reused"],
        );
        for user in 0..super::common::users_per_dataset() {
            let data = datasets::generate(ds, user);
            let embedder = Embedder::new(rt);
            let mut kb = crate::kb::KnowledgeBank::new();
            let mut retr = Retriever::new(0.5);
            for doc in &data.documents {
                for id in kb.add_document(doc, &embedder)? {
                    let text = kb.chunk(id).text.clone();
                    retr.index_chunk(id, &text);
                }
            }
            let mut counts = vec![0usize; kb.len()];
            for q in &data.queries {
                let emb = embedder.embed(&q.text)?;
                for r in retr.retrieve(&q.text, &emb, &kb, 2) {
                    counts[r.chunk] += 1;
                }
            }
            let bucket = |pred: &dyn Fn(usize) -> bool| {
                counts.iter().filter(|&&c| pred(c)).count()
            };
            let retrieved: Vec<usize> = counts.iter().cloned().filter(|&c| c > 0).collect();
            let all_reused = !retrieved.is_empty() && retrieved.iter().all(|&c| c >= 2);
            let mean = counts.iter().sum::<usize>() as f64 / counts.len().max(1) as f64;
            t.row(vec![
                format!("user{user}"),
                bucket(&|c| c == 0).to_string(),
                bucket(&|c| c == 1).to_string(),
                bucket(&|c| c == 2).to_string(),
                bucket(&|c| c >= 3).to_string(),
                format!("{mean:.2}"),
                all_reused.to_string(),
            ]);
        }
        t.emit(&reports_dir(), &format!("fig3_{ds}"));
    }
    println!("[fig3] many chunks retrieved 2+ times — repeated-retrieval redundancy exists");
    Ok(())
}

/// Fig 4: prefill/decode latency breakdown for three query scenarios on
/// mobile vs server profiles (Naive vs KV-reuse vs semantic-similar).
pub fn fig4(rt: &Runtime) -> Result<()> {
    let data = datasets::generate("email", 0);
    let base = PerCacheConfig::default();

    // Query1 = base; Query2 = paraphrase of Query1; Query3 = different
    // query sharing retrieved chunks.  Use generator structure to find them.
    let q1 = data.queries[0].text.clone();
    let para = data
        .queries
        .iter()
        .find(|q| q.paraphrase_of == Some(0))
        .map(|q| q.text.clone())
        .unwrap_or_else(|| data.queries[1].text.clone());
    let same_topic = data
        .queries
        .iter()
        .skip(1)
        .find(|q| q.topic == data.queries[0].topic && q.paraphrase_of.is_none())
        .map(|q| q.text.clone())
        .unwrap_or_else(|| data.queries[1].text.clone());

    let mut t = Table::new(
        "Fig 4 — inference latency breakdown (ms)",
        &["scenario", "device", "prefill", "decode", "total"],
    );

    // naive run of q1 on mobile + server profiles
    let mut eng = super::common::build_engine(rt, "naive", &base, &data)?;
    let r1 = eng.serve(&q1)?;
    for dev in [&sim::PIXEL7, &sim::SERVER_A6000] {
        let s = scale(&r1, Some(dev));
        t.row(vec![
            "q1 naive".into(),
            dev.name.into(),
            format!("{:.1}", s.prefill_ms),
            format!("{:.1}", s.decode_ms),
            format!("{:.1}", s.total_ms()),
        ]);
    }

    // q2 with KV-cache reuse (RAGCache): prefill drops, decode stays
    let mut eng = super::common::build_engine(rt, "ragcache", &base, &data)?;
    let _ = eng.serve(&q1)?;
    let r2 = eng.serve(&para)?;
    let s = scale(&r2, Some(&sim::PIXEL7));
    t.row(vec![
        "q2 (≈q1) kv-reuse".into(),
        sim::PIXEL7.name.into(),
        format!("{:.1}", s.prefill_ms),
        format!("{:.1}", s.decode_ms),
        format!("{:.1}", s.total_ms()),
    ]);

    // q3 with semantic cache only (MeanCache): overlapping chunks but a
    // dissimilar query → miss → full inference
    let mut eng = super::common::build_engine(rt, "meancache", &base, &data)?;
    let _ = eng.serve(&q1)?;
    let r3 = eng.serve(&same_topic)?;
    let s = scale(&r3, Some(&sim::PIXEL7));
    t.row(vec![
        "q3 (overlap) semantic-only".into(),
        sim::PIXEL7.name.into(),
        format!("{:.1}", s.prefill_ms),
        format!("{:.1}", s.decode_ms),
        format!("{:.1}", s.total_ms()),
    ]);

    t.emit(&reports_dir(), "fig4");
    println!(
        "[fig4] mobile: prefill+decode both material; server: decode-dominant; \
         single-stage reuse leaves latency on the table"
    );
    Ok(())
}

/// Fig 5: prefix-overlap degree of retrieved chunks under *reactive*
/// KV caching (RAGCache-style), per query in sequence.
pub fn fig5(rt: &Runtime) -> Result<()> {
    let mut t = Table::new(
        "Fig 5 — cached-prefix overlap ratio per query (reactive population)",
        &["dataset", "user", "query", "matched_segs", "path_segs", "ratio"],
    );
    let mut low = 0usize;
    let mut total = 0usize;
    for (ds, user) in [("email", 0usize), ("dialog", 0usize)] {
        let data = datasets::generate(ds, user);
        let base = PerCacheConfig::default();
        let mut eng = super::common::build_engine(rt, "ragcache", &base, &data)?;
        let embedder = Embedder::new(rt);
        for (i, q) in data.queries.iter().enumerate() {
            let emb = embedder.embed(&q.text)?;
            let (matched, path) = eng.probe_prefix(&q.text, &emb);
            let ratio = matched as f64 / path.max(1) as f64;
            if ratio < 0.5 {
                low += 1;
            }
            total += 1;
            t.row(vec![
                ds.into(),
                format!("user{user}"),
                format!("q{i}"),
                matched.to_string(),
                path.to_string(),
                format!("{ratio:.2}"),
            ]);
            let _ = eng.serve(&q.text)?; // reactive update
        }
    }
    t.emit(&reports_dir(), "fig5");
    println!(
        "[fig5] {low}/{total} queries see <50% cached-prefix overlap under \
         reactive population (paper: 'quite low for most queries')"
    );
    Ok(())
}

/// Fig 6: similarity of each query to its most similar *previous* query.
pub fn fig6(rt: &Runtime) -> Result<()> {
    let mut t = Table::new(
        "Fig 6 — similarity to most similar previous query",
        &["dataset", "user", "query", "best_prev_sim"],
    );
    let mut above_09 = 0usize;
    let mut total = 0usize;
    for (ds, user) in [("email", 0usize), ("dialog", 0usize)] {
        let data = datasets::generate(ds, user);
        let embedder = Embedder::new(rt);
        let mut prev: Vec<Vec<f32>> = Vec::new();
        for (i, q) in data.queries.iter().enumerate() {
            let emb = embedder.embed(&q.text)?;
            let best = prev
                .iter()
                .map(|p| cosine(p, &emb) as f64)
                .fold(f64::NAN, f64::max);
            let cell = if best.is_nan() {
                "-".to_string()
            } else {
                if best > 0.9 {
                    above_09 += 1;
                }
                total += 1;
                format!("{best:.3}")
            };
            t.row(vec![ds.into(), format!("user{user}"), format!("q{i}"), cell]);
            prev.push(emb);
        }
    }
    t.emit(&reports_dir(), "fig6");
    println!(
        "[fig6] only {above_09}/{total} queries exceed 0.9 similarity to any previous \
         query — reactive semantic caching starves (paper: few queries above 0.8)"
    );
    Ok(())
}

/// Fig 13 companion (motivation §2.2): measured reuse-vs-full prefill
/// latency per bucket, both variants — wall-clock evidence for the
/// Q-tensor claim.  (The per-projection FLOP split is in exp::showcase.)
pub fn prefill_variants_table(rt: &Runtime) -> Result<Table> {
    let eng = crate::llm::LlmEngine::new(rt, "llama")?;
    let mut tokens = Vec::new();
    for s in 0..4 {
        tokens.extend(crate::tokenizer::encode_segment(&format!(
            "chunk {s} quarterly budget review meeting thursday finance room"
        )));
    }
    let full = eng.prefill(&tokens, None)?;
    let mut t = Table::new(
        "Prefill variants (n=4 segments, measured)",
        &["variant", "p", "mean_ms", "flops_g"],
    );
    let reps = 3;
    let timed = |f: &mut dyn FnMut() -> Result<()>| -> Result<f64> {
        f()?; // warm
        let s = Stage::start();
        for _ in 0..reps {
            f()?;
        }
        Ok(s.ms() / reps as f64)
    };
    let ms = timed(&mut || eng.prefill(&tokens, None).map(|_| ()))?;
    t.row(vec![
        "full".into(),
        "0".into(),
        format!("{ms:.1}"),
        format!("{:.2}", full.flops as f64 / 1e9),
    ]);
    for p in [2usize, 3] {
        let prefix = full.qkv.slice_segments(0, p);
        for v in [ReuseVariant::Kv, ReuseVariant::Qkv] {
            let r = eng.prefill(&tokens, Some((&prefix, v)))?;
            let ms = timed(&mut || eng.prefill(&tokens, Some((&prefix, v))).map(|_| ()))?;
            t.row(vec![
                format!("{v:?}"),
                p.to_string(),
                format!("{ms:.1}"),
                format!("{:.2}", r.flops as f64 / 1e9),
            ]);
        }
    }
    Ok(t)
}

#[cfg(test)]
mod tests {
    use crate::baselines::METHODS;

    #[test]
    fn method_list_is_paper_order() {
        assert_eq!(METHODS[0], "naive");
        assert_eq!(METHODS[6], "percache");
    }
}
