//! Cold-start vs warm-start experiment (`percache exp persistence`):
//! does durable cache state actually buy back the paper's latency wins
//! after a process restart?
//!
//! Protocol (cache-level, runtime-free like the tenancy sweep): session
//! 1 primes a disk-persisted shard over a cycling query stream and
//! snapshots it (the app is "killed").  Then the *same* first-N query
//! window is measured twice — once on a fresh memory shard (cold start:
//! everything was lost) and once on the shard reopened from disk (warm
//! restart).  Emits the human table + CSV plus the machine-readable
//! `reports/BENCH_persistence.json` (first-N p50/p99 and hit rates,
//! cold vs warm) — the acceptance artifact: warm must show a strictly
//! higher hit rate and strictly lower p50 than cold.

use std::path::Path;

use anyhow::Result;

use crate::metrics::{Recorder, ServePath};
use crate::runtime::Runtime;
use crate::tenancy::sim::{serve_one, sim_slice_bytes, SimConfig};
use crate::tenancy::TenantShard;
use crate::tokenizer::fnv1a64;
use crate::util::bench::percentile;
use crate::util::json::Json;
use crate::util::table::Table;

use super::common::reports_dir;

/// Queries served in the priming session before the simulated kill.
pub const PRIME_QUERIES: usize = 48;
/// First-N window measured after each (re)start.
pub const MEASURE_QUERIES: usize = 12;
/// Topics cycled by the workload (each owns a reusable 2-chunk path).
const TOPICS: usize = 4;
/// Query phrasings per topic (verbatim repeats land in the QA bank).
const VARIANTS: usize = 3;
/// QKV budget, in sim slices (holds every topic path: 1 + 2·TOPICS).
const BUDGET_SLICES: usize = 24;
/// QA bank budget per shard.
const QA_BYTES: usize = 1 << 20;

/// One measured start (cold or warm).
#[derive(Debug, Clone)]
pub struct PersistenceCell {
    pub label: String,
    pub queries: usize,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub hit_rate: f64,
    pub qa_hit_rate: f64,
    pub qkv_hit_rate: f64,
    pub mean_flops: f64,
}

/// Deterministic cycling stream: query `i` asks about topic `i % TOPICS`
/// with phrasing `(i / TOPICS) % VARIANTS` — every (topic, variant) pair
/// repeats verbatim once the stream wraps.
fn query_text(i: usize) -> String {
    let topic = i % TOPICS;
    let variant = (i / TOPICS) % VARIANTS;
    format!("question phrasing{variant} about subject{topic} details")
}

/// Prompt path `[sys, chunk_a(topic), chunk_b(topic), query]`.
fn seg_keys(i: usize, text: &str) -> Vec<u64> {
    let topic = i % TOPICS;
    vec![
        fnv1a64(b"sys"),
        fnv1a64(format!("persist/topic{topic}/a").as_bytes()),
        fnv1a64(format!("persist/topic{topic}/b").as_bytes()),
        fnv1a64(text.as_bytes()),
    ]
}

fn run_session(shard: &mut TenantShard, sim: &SimConfig, n: usize) -> Result<Recorder> {
    let mut rec = Recorder::new();
    for i in 0..n {
        let q = query_text(i);
        let keys = seg_keys(i, &q);
        rec.push(serve_one(sim, shard, &q, &keys)?);
    }
    Ok(rec)
}

fn cell(label: &str, rec: &Recorder) -> PersistenceCell {
    let mut lat: Vec<f64> = rec.records.iter().map(|r| r.total_ms()).collect();
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let hits = rec
        .records
        .iter()
        .filter(|r| r.path != ServePath::Full)
        .count();
    PersistenceCell {
        label: label.to_string(),
        queries: rec.len(),
        p50_ms: percentile(&lat, 50.0),
        p99_ms: percentile(&lat, 99.0),
        hit_rate: hits as f64 / rec.len().max(1) as f64,
        qa_hit_rate: rec.qa_hit_rate(),
        qkv_hit_rate: rec.qkv_hit_rate(),
        mean_flops: rec.total_flops() as f64 / rec.len().max(1) as f64,
    }
}

/// Run the cold-vs-warm comparison with persistent state under `dir`
/// (pure; unit-testable without a runtime).  Returns (cold, warm).
pub fn sweep(dir: &Path) -> Result<(PersistenceCell, PersistenceCell)> {
    let sim = SimConfig::default();
    let qkv_bytes = BUDGET_SLICES * sim_slice_bytes();
    let shard_dir = dir.join("shard_0");
    let _ = std::fs::remove_dir_all(&shard_dir);

    // session 1: prime a persistent shard, snapshot, "kill the app"
    {
        let mut shard =
            TenantShard::open_or_create(0, QA_BYTES, qkv_bytes, 0.2, shard_dir.clone())?;
        run_session(&mut shard, &sim, PRIME_QUERIES)?;
        shard.save()?;
        shard.check_invariants()?;
    }

    // cold start: a fresh memory shard — the pre-persistence behaviour
    let mut cold_shard = TenantShard::new(0, QA_BYTES, qkv_bytes, 0.2);
    let cold = cell("cold", &run_session(&mut cold_shard, &sim, MEASURE_QUERIES)?);

    // warm restart: reopen the persisted shard and serve the same window
    let mut warm_shard =
        TenantShard::open_or_create(0, QA_BYTES, qkv_bytes, 0.2, shard_dir.clone())?;
    warm_shard.check_invariants()?;
    let warm = cell("warm", &run_session(&mut warm_shard, &sim, MEASURE_QUERIES)?);
    warm_shard.check_invariants()?;

    Ok((cold, warm))
}

/// `percache exp persistence` entry point (runtime unused: cache-level).
pub fn persistence(_rt: &Runtime) -> Result<()> {
    run_and_report()
}

/// Shared by the exp registry and tests.
pub fn run_and_report() -> Result<()> {
    let state_dir = std::env::temp_dir().join(format!(
        "percache_persistence_exp_{}",
        std::process::id()
    ));
    let (cold, warm) = sweep(&state_dir)?;
    let _ = std::fs::remove_dir_all(&state_dir);

    let mut table = Table::new(
        "persistence: first-N queries after restart, cold vs warm",
        &["start", "queries", "p50 ms", "p99 ms", "hit", "qa hit", "qkv hit"],
    );
    for c in [&cold, &warm] {
        table.row(vec![
            c.label.clone(),
            c.queries.to_string(),
            format!("{:.3}", c.p50_ms),
            format!("{:.3}", c.p99_ms),
            format!("{:.0}%", c.hit_rate * 100.0),
            format!("{:.0}%", c.qa_hit_rate * 100.0),
            format!("{:.0}%", c.qkv_hit_rate * 100.0),
        ]);
    }
    println!("{}", table.render());
    let dir = reports_dir();
    table.emit(&dir, "persistence");
    write_bench_json(&cold, &warm, &dir)?;
    Ok(())
}

fn cell_json(c: &PersistenceCell) -> Json {
    let mut o = Json::obj();
    o.insert("queries", c.queries);
    o.insert("p50_ms", c.p50_ms);
    o.insert("p99_ms", c.p99_ms);
    o.insert("hit_rate", c.hit_rate);
    o.insert("qa_hit_rate", c.qa_hit_rate);
    o.insert("qkv_hit_rate", c.qkv_hit_rate);
    o.insert("mean_flops", c.mean_flops);
    Json::Obj(o)
}

/// Emit `<dir>/BENCH_persistence.json` — the warm-restart acceptance
/// artifact.
pub fn write_bench_json(
    cold: &PersistenceCell,
    warm: &PersistenceCell,
    dir: &std::path::Path,
) -> Result<()> {
    let mut root = Json::obj();
    root.insert("bench", "persistence");
    root.insert("prime_queries", PRIME_QUERIES);
    root.insert("measure_queries", MEASURE_QUERIES);
    root.insert("cold", cell_json(cold));
    root.insert("warm", cell_json(warm));
    root.insert(
        "p50_speedup",
        if warm.p50_ms > 0.0 { cold.p50_ms / warm.p50_ms } else { f64::INFINITY },
    );
    root.insert("hit_rate_delta", warm.hit_rate - cold.hit_rate);

    std::fs::create_dir_all(dir)?;
    let path = dir.join("BENCH_persistence.json");
    std::fs::write(&path, Json::Obj(root).to_string_pretty())?;
    println!("[persistence] wrote {}", path.display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("percache_pexp_{tag}_{}", std::process::id()))
    }

    #[test]
    fn warm_restart_strictly_beats_cold_start() {
        let dir = tmp("sweep");
        let (cold, warm) = sweep(&dir).unwrap();
        assert!(
            warm.hit_rate > cold.hit_rate,
            "warm hit rate {:.2} must beat cold {:.2}",
            warm.hit_rate,
            cold.hit_rate
        );
        assert!(
            warm.p50_ms < cold.p50_ms,
            "warm p50 {:.4}ms must beat cold {:.4}ms",
            warm.p50_ms,
            cold.p50_ms
        );
        assert!(warm.mean_flops < cold.mean_flops, "warm must skip compute");
        // the warm window is verbatim repeats of primed queries: all QA hits
        assert!(warm.qa_hit_rate > 0.99, "warm window must hit the QA bank");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn bench_json_is_parseable_and_ordered() {
        let dir = tmp("json");
        let (cold, warm) = sweep(&dir).unwrap();
        write_bench_json(&cold, &warm, &dir).unwrap();
        let text = std::fs::read_to_string(dir.join("BENCH_persistence.json")).unwrap();
        let j = Json::parse(&text).unwrap();
        assert_eq!(j.get("bench").as_str(), Some("persistence"));
        let dw = j.get("warm").get("hit_rate").as_f64().unwrap();
        let dc = j.get("cold").get("hit_rate").as_f64().unwrap();
        assert!(dw > dc);
        assert!(j.get("hit_rate_delta").as_f64().unwrap() > 0.0);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
