//! End-to-end showcases: Figs 11, 12, 13 (paper §5.3).

use anyhow::Result;

use super::common::{replay_user, reports_dir, ReplayOpts};
use crate::baselines::{label, METHODS};
use crate::config::PerCacheConfig;
use crate::datasets;
use crate::metrics::ServePath;
use crate::runtime::Runtime;
use crate::sim;
use crate::util::table::Table;

/// Fig 11: per-query latency for every method, two showcase users
/// (one MISeD, one EnronQA), queries processed sequentially.
pub fn fig11(rt: &Runtime) -> Result<()> {
    let base = PerCacheConfig::default();
    for (ds, user) in [("mised", 0usize), ("enronqa", 0usize)] {
        let data = datasets::generate(ds, user);
        let n = data.queries.len();
        let mut cols: Vec<String> = vec!["method".into()];
        cols.extend((0..n).map(|i| format!("q{i}")));
        cols.push("mean".into());
        cols.push("qa_hits".into());
        let col_refs: Vec<&str> = cols.iter().map(|s| s.as_str()).collect();
        let mut t = Table::new(
            &format!("Fig 11 — per-query latency ms ({ds} user{user}, pixel7-scaled)"),
            &col_refs,
        );
        let opts = ReplayOpts {
            device: Some(&sim::PIXEL7),
            ..Default::default()
        };
        let mut percache_mean = f64::NAN;
        let mut best_baseline = f64::INFINITY;
        for m in METHODS {
            let out = replay_user(rt, m, &base, &data, &opts)?;
            let mut row = vec![label(m).to_string()];
            for r in &out.recorder.records {
                row.push(format!("{:.0}", r.total_ms()));
            }
            let mean = out.recorder.mean_total_ms();
            row.push(format!("{mean:.0}"));
            let qa_hits = out
                .recorder
                .records
                .iter()
                .filter(|r| r.path == ServePath::QaHit)
                .count();
            row.push(qa_hits.to_string());
            t.row(row);
            if m == "percache" {
                percache_mean = mean;
            } else {
                best_baseline = best_baseline.min(mean);
            }
        }
        t.emit(&reports_dir(), &format!("fig11_{ds}_user{user}"));
        println!(
            "[fig11] {ds} user{user}: PerCache mean {percache_mean:.0} ms vs best baseline \
             {best_baseline:.0} ms ({:+.1}%)",
            (percache_mean / best_baseline - 1.0) * 100.0
        );
    }
    Ok(())
}

/// Fig 12: walk-through of one PerCache query — what was cached, what
/// was computed (narrative table).
pub fn fig12(rt: &Runtime) -> Result<()> {
    let base = PerCacheConfig::default();
    let data = datasets::generate("mised", 0);
    let mut eng = super::common::build_engine(rt, "percache", &base, &data)?;
    // two knowledge-prediction rounds, as in the paper's showcase
    eng.idle_tick()?;
    eng.idle_tick()?;

    let q = &data.queries[0].text;
    let r = eng.serve(q)?;
    let mut t = Table::new("Fig 12 — showcase walk-through (PerCache, q0)", &["field", "value"]);
    t.row(vec!["query".into(), q.clone()]);
    t.row(vec!["serve path".into(), format!("{:?}", r.path)]);
    t.row(vec![
        "prompt segments".into(),
        format!("{} (sys + {} chunks + query)", r.n_segments, r.n_segments - 2),
    ]);
    t.row(vec![
        "segments with cached QKV".into(),
        format!("{} (populated by prediction)", r.matched_segments),
    ]);
    t.row(vec!["embed ms".into(), format!("{:.2}", r.embed_ms)]);
    t.row(vec!["qa match ms".into(), format!("{:.2}", r.qa_match_ms)]);
    t.row(vec!["retrieval ms".into(), format!("{:.2}", r.retrieval_ms)]);
    t.row(vec!["tree match ms".into(), format!("{:.3}", r.tree_match_ms)]);
    t.row(vec!["cache load ms".into(), format!("{:.2}", r.cache_load_ms)]);
    t.row(vec!["prefill ms".into(), format!("{:.2}", r.prefill_ms)]);
    t.row(vec!["decode ms".into(), format!("{:.2}", r.decode_ms)]);
    t.emit(&reports_dir(), "fig12");
    Ok(())
}

/// Fig 13: Q/K/V projection latency breakdown, naive vs PerCache.
/// Projection work is attributed from the analytic FLOP model applied to
/// the measured prefill wall-clock (the projections are fused inside one
/// HLO; XLA doesn't expose per-op timers through PJRT).
pub fn fig13(rt: &Runtime) -> Result<()> {
    let base = PerCacheConfig::default();
    let data = datasets::generate("mised", 0);

    // naive: full prefill
    let mut naive = super::common::build_engine(rt, "naive", &base, &data)?;
    let rn = naive.serve(&data.queries[0].text)?;

    // percache with warmed caches; τ pushed high so the showcase query
    // takes the QKV path (the paper's Fig 13 measures exactly that path)
    let mut hi = base.clone();
    hi.tau_query = 0.999;
    let mut pc = super::common::build_engine(rt, "percache", &hi, &data)?;
    pc.idle_tick()?;
    pc.idle_tick()?;
    let rp = pc.serve(&data.queries[0].text)?;
    anyhow::ensure!(
        rp.matched_segments > 0,
        "showcase query should hit the QKV cache after prediction"
    );

    let dims = crate::llm::LlmEngine::new(rt, "llama")?.dims;
    let seg = crate::tokenizer::SEGMENT_TOKENS;

    // FLOP-proportional attribution of the measured prefill wall-clock to
    // each projection (the projections are fused into one HLO; PJRT does
    // not expose per-op timers).
    let project_ms = |r: &crate::metrics::QueryRecord| -> (f64, f64, f64) {
        let s = r.n_segments * seg;
        let p = r.matched_segments * seg;
        let computed = s - p;
        let (qf, kf, vf) = dims.projection_flops(computed, computed);
        let prefill_flops = if p == 0 {
            dims.prefill_full(s)
        } else {
            dims.prefill_reuse_qkv(p, s)
        } as f64;
        let layers = dims.layers as f64;
        let to_ms = |f: u64| r.prefill_ms * (layers * f as f64) / prefill_flops;
        (to_ms(qf), to_ms(kf), to_ms(vf))
    };

    let (nq, nk, nv) = project_ms(&rn);
    let (pq, pk, pv) = if rp.path == ServePath::QaHit {
        (0.0, 0.0, 0.0)
    } else {
        project_ms(&rp)
    };

    let mut t = Table::new(
        "Fig 13 — attention projection latency (ms, pixel7-scaled attribution)",
        &["method", "Q proj", "K proj", "V proj", "prefill total"],
    );
    let scale = sim::PIXEL7.prefill_scale;
    t.row(vec![
        "Naive".into(),
        format!("{:.1}", nq * scale),
        format!("{:.1}", nk * scale),
        format!("{:.1}", nv * scale),
        format!("{:.1}", rn.prefill_ms * scale),
    ]);
    t.row(vec![
        "PerCache".into(),
        format!("{:.1}", pq * scale),
        format!("{:.1}", pk * scale),
        format!("{:.1}", pv * scale),
        format!("{:.1}", rp.prefill_ms * scale),
    ]);
    if nq > 0.0 && pq >= 0.0 {
        t.row(vec![
            "reduction".into(),
            format!("{:.1}%", (1.0 - pq / nq) * 100.0),
            format!("{:.1}%", (1.0 - pk / nk) * 100.0),
            format!("{:.1}%", (1.0 - pv / nv) * 100.0),
            format!("{:.1}%", (1.0 - rp.prefill_ms / rn.prefill_ms) * 100.0),
        ]);
    }
    t.emit(&reports_dir(), "fig13");
    println!(
        "[fig13] projection latencies drop ∝ cached prefix (paper: 57.4/58.2/58.4% for 3/4 cached)"
    );
    Ok(())
}
