//! System overhead: Fig 20 (battery) and Table 1 (per-operation latency,
//! per-item storage) — paper §5.8 / App A.4.

use anyhow::Result;

use super::common::{reports_dir, ReplayOpts};
use crate::config::PerCacheConfig;
use crate::datasets;
use crate::engine::PerCache;
use crate::runtime::Runtime;
use crate::scheduler::PopulationStrategy;
use crate::sim::{Battery, ONEPLUS_ACE6};
use crate::util::table::Table;

/// Fig 20: battery level vs cache-population count (OnePlus Ace 6
/// energy model; one population = embed + retrieve + match + prefill +
/// decode + save, like the paper's measured loop).
pub fn fig20(rt: &Runtime) -> Result<()> {
    let data = datasets::generate("mised", 0);
    let cfg = PerCacheConfig::default();
    let mut eng = PerCache::new(rt, cfg)?;
    for doc in &data.documents {
        eng.add_document(doc)?;
    }

    let mut battery = Battery::new(ONEPLUS_ACE6);
    // Paper-equivalent column: scale measured FLOPs by the 3B/our-model
    // parameter ratio and use an NPU-class energy constant (~0.03 J/GFLOP)
    // — the *shape* (linear in population count) is the reproducible
    // claim; magnitude depends on these two documented constants.
    let params_ratio = 3.0e9 / eng.llm.dims.params() as f64;
    let npu_j_per_gflop = 0.03;
    let mut paper_joules = 0.0f64;
    let battery_joules = 6100.0 * 3.85;

    let mut t = Table::new(
        "Fig 20 — battery level vs cache populations (OnePlus Ace 6 model)",
        &["populations", "battery_%", "paper_equiv_%_used"],
    );
    t.row(vec!["0".into(), "100.0".into(), "0.00".into()]);

    // repeatedly populate with fresh synthetic queries (the paper reruns
    // one query's full population; we vary text to avoid dedup while
    // keeping the same prompt shape)
    let mut count = 0;
    for round in 0..60 {
        let q = format!(
            "population probe {round} about the {} status",
            ["budget", "roadmap", "sprint", "design"][round % 4]
        );
        let before = eng.population_flops;
        if eng
            .populate_query(&q, PopulationStrategy::PrefillAndDecode, false)?
            .is_some()
        {
            count += 1;
            let delta = eng.population_flops - before;
            battery.consume_flops(delta);
            paper_joules += delta as f64 / 1e9 * params_ratio * npu_j_per_gflop;
        }
        if count % 10 == 0 && count > 0 {
            t.row(vec![
                count.to_string(),
                format!("{:.2}", battery.level_percent()),
                format!("{:.2}", paper_joules / battery_joules * 100.0),
            ]);
        }
    }
    t.emit(&reports_dir(), "fig20");
    println!(
        "[fig20] {count} populations drain {:.2}% battery at our model scale; \
         {:.1}% at 3B-equivalent FLOPs — linear in count \
         (paper: 51 populations ≈ 10%; 1–5 predictions ≈ 1–2%)",
        battery.consumed_percent(),
        paper_joules / battery_joules * 100.0
    );
    Ok(())
}

/// Table 1: per-operation latency + per-item storage.
pub fn table1(rt: &Runtime) -> Result<()> {
    let data = datasets::generate("enronqa", 0);
    let cfg = PerCacheConfig::default();
    let mut eng = PerCache::new(rt, cfg)?;
    for doc in &data.documents {
        eng.add_document(doc)?;
    }
    // warm caches so matching/loading paths are exercised
    eng.idle_tick()?;
    eng.idle_tick()?;

    // measure each stage over the user's queries
    let mut sums = [0.0f64; 7]; // embed, qa, retr, tree, load, prefill, decode
    let mut n = 0.0f64;
    for q in &data.queries {
        let r = eng.serve(&q.text)?;
        if r.path == crate::metrics::ServePath::QaHit {
            continue; // paper's table measures the full pipeline ops
        }
        sums[0] += r.embed_ms;
        sums[1] += r.qa_match_ms;
        sums[2] += r.retrieval_ms;
        sums[3] += r.tree_match_ms;
        sums[4] += r.cache_load_ms;
        sums[5] += r.prefill_ms;
        sums[6] += r.decode_ms;
        n += 1.0;
    }
    for s in &mut sums {
        *s /= n.max(1.0);
    }
    let total: f64 = sums.iter().sum();

    // storage per item
    let qa_item = eng
        .qa
        .entries()
        .iter()
        .map(|e| e.bytes())
        .sum::<usize>()
        .max(1)
        / eng.qa.len().max(1);
    let dims = eng.llm.dims;
    let qkv_item = dims.layers * 3 * crate::tokenizer::SEGMENT_TOKENS * dims.d_model * 4 + 16;
    let chunk_item = eng.kb.bytes() / eng.kb.len().max(1);

    let mut t = Table::new(
        "Table 1 — system overhead (llama config, cpu-baseline ms)",
        &["operation", "time_ms", "% of total", "component", "size"],
    );
    let names = [
        "matching question (embed+QA)",
        "qa match",
        "knowledge retrieval",
        "matching QKV cache",
        "QKV cache loading",
        "LLM prefilling",
        "LLM decoding",
    ];
    let sizes = [
        format!("QA bank {qa_item} B/entry"),
        String::new(),
        String::new(),
        format!("QKV slice {:.2} MB/chunk", qkv_item as f64 / 1e6),
        String::new(),
        format!("knowledge chunk {chunk_item} B"),
        String::new(),
    ];
    for i in 0..7 {
        t.row(vec![
            names[i].into(),
            format!("{:.3}", sums[i]),
            format!("{:.1}%", sums[i] / total * 100.0),
            sizes[i].clone(),
            String::new(),
        ]);
    }
    t.emit(&reports_dir(), "table1");
    println!(
        "[table1] prefill {:.0}% + decode {:.0}% of pipeline latency (paper: 77.9% + 13.7%); \
         QKV slice dominates storage (paper: 87 MB/chunk at 3B scale)",
        sums[5] / total * 100.0,
        sums[6] / total * 100.0
    );
    let _ = ReplayOpts::default();
    Ok(())
}
