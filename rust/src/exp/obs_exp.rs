//! Telemetry overhead experiment (`percache exp obs`): what does the
//! observability layer cost on the serve path?
//!
//! Replays the multi-tenant cache-level workload (real shards, router
//! and governor — the same stream the tenancy experiment uses) three
//! times: once with the global metrics registry **enabled** (every
//! counter, histogram, span and journal emission live), once
//! **disabled** (every call site reduced to one relaxed atomic load),
//! and once **traced** (registry enabled *plus* the request-scoped
//! causal tracer sampling 1-in-[`TRACE_SAMPLE_EVERY`] requests with
//! tail exemplars on, DESIGN.md §16).  Each arm times individual
//! `serve_one` calls with a wall clock, so the deltas isolate exactly
//! the instrumentation riding the per-query path.
//!
//! Arms are interleaved across several rounds and each arm keeps its
//! best (lowest-p50) round, which suppresses scheduler noise on shared
//! CI runners.  Emits the human table + CSV plus
//! `reports/BENCH_obs.json`, then **fails** if the enabled-vs-disabled
//! p50 overhead exceeds [`GATE_P50_FRAC`] — the CI regression gate for
//! the telemetry budget (DESIGN.md §12).  `--smoke` (or
//! PERCACHE_SMOKE=1) shrinks the workload.

use std::time::Instant;

use anyhow::Result;

use crate::config::TenancyConfig;
use crate::datasets;
use crate::runtime::Runtime;
use crate::tenancy::sim::{arrivals_from_workload, serve_one, sim_slice_bytes, Arrival, SimConfig};
use crate::tenancy::{Router, RouterConfig, TenantRegistry};
use crate::util::bench::{black_box, percentile};
use crate::util::json::Json;
use crate::util::table::Table;

use super::common::reports_dir;
use super::tiering_exp::smoke_mode;

/// Maximum tolerated enabled-vs-disabled p50 latency inflation (3%).
/// The traced arm is held to the same budget.
pub const GATE_P50_FRAC: f64 = 0.03;
/// Trace sampling rate for the traced arm — the production default
/// (`ObsConfig::trace_sample_every`); the per-request cost is amortised
/// 1-in-N exactly as deployments would run it.
pub const TRACE_SAMPLE_EVERY: u64 = 8;
/// Global QKV budget in sim slices (roomy — hit behaviour identical
/// across arms, so the wall-clock delta isolates the instrumentation).
const GLOBAL_SLICES: usize = 96;
/// Arrivals enqueued per router scheduling round.
const BATCH: usize = 8;

/// Workload shape (full vs `--smoke`).
#[derive(Debug, Clone, Copy)]
pub struct Shape {
    pub tenants: usize,
    /// Total arrivals per arm per round.
    pub arrivals: usize,
    /// Interleaved measurement rounds (best round per arm kept).
    pub rounds: usize,
}

impl Shape {
    pub fn full() -> Self {
        Shape {
            tenants: 4,
            arrivals: 1600,
            rounds: 3,
        }
    }

    pub fn smoke() -> Self {
        Shape {
            tenants: 2,
            arrivals: 240,
            rounds: 2,
        }
    }
}

/// One measured arm (its best round).
#[derive(Debug, Clone)]
pub struct ObsCell {
    pub label: String,
    pub served: usize,
    pub p50_us: f64,
    pub p99_us: f64,
    pub mean_us: f64,
}

fn cell(label: &str, sorted_us: &[f64]) -> ObsCell {
    ObsCell {
        label: label.to_string(),
        served: sorted_us.len(),
        p50_us: percentile(sorted_us, 50.0),
        p99_us: percentile(sorted_us, 99.0),
        mean_us: sorted_us.iter().sum::<f64>() / sorted_us.len() as f64,
    }
}

/// Relative inflation of `on` over `off` (0 when `off` is degenerate).
pub fn overhead_frac(on: f64, off: f64) -> f64 {
    if off > 0.0 {
        (on - off) / off
    } else {
        0.0
    }
}

/// Replay the workload once with the registry toggled to `enabled` and
/// the causal tracer toggled to `traced`; returns the sorted per-query
/// serve wall-times in microseconds.
fn run_arm(shape: &Shape, enabled: bool, traced: bool) -> Result<Vec<f64>> {
    crate::obs::set_enabled(enabled);
    let tracer = crate::obs::tracer();
    tracer.set_sample_every(TRACE_SAMPLE_EVERY);
    tracer.set_enabled(traced);
    let tc = TenancyConfig {
        enabled: true,
        max_tenants: shape.tenants,
        global_qkv_bytes: GLOBAL_SLICES * sim_slice_bytes(),
        rebalance_every: 16,
        ..TenancyConfig::default()
    };
    let mut reg = TenantRegistry::new(&tc);
    for _ in 0..shape.tenants {
        reg.create_tenant()?;
    }
    let mut router: Router<Arrival> = Router::new(RouterConfig {
        queue_cap: tc.queue_cap,
        global_cap: tc.global_queue_cap,
        ..RouterConfig::default()
    });
    for _ in 0..shape.tenants {
        router.register_tenant();
    }
    let sim = SimConfig::default();
    let w = datasets::multi_tenant(shape.tenants, shape.arrivals, 1.0, 0x0B5);
    let arrivals = arrivals_from_workload(&w);

    let mut samples = Vec::with_capacity(arrivals.len());
    for chunk in arrivals.chunks(BATCH) {
        for a in chunk {
            let _ = router.try_push(a.tenant, a.clone());
        }
        while let Some((tenant, a)) = router.pop() {
            let shard = reg
                .shard_mut(tenant)
                .ok_or_else(|| anyhow::anyhow!("router/registry tenant mismatch"))?;
            let t = Instant::now();
            let ctx = if traced {
                tracer.begin_trace("request", Some(tenant), tracer.now_ns())
            } else {
                None
            };
            let rec = {
                let _attached = crate::obs::trace::attach(ctx);
                serve_one(&sim, shard, &a.query, &a.seg_keys)?
            };
            if let Some(ctx) = ctx {
                tracer.end_trace(ctx, tracer.now_ns());
            }
            samples.push(t.elapsed().as_secs_f64() * 1e6);
            black_box(rec);
            let _ = reg.note_serve();
        }
    }
    anyhow::ensure!(!samples.is_empty(), "obs arm served no queries");
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Ok(samples)
}

/// Run all three arms, interleaved; returns (enabled, disabled, traced)
/// best rounds.  Restores the registry's and tracer's prior enabled
/// state even on error — both toggles are global, and the serving stack
/// keeps running after `exp`.
pub fn sweep(shape: &Shape) -> Result<(ObsCell, ObsCell, ObsCell)> {
    let prior = crate::obs::enabled();
    let tracer = crate::obs::tracer();
    let trace_prior = tracer.enabled();
    let result = sweep_inner(shape);
    crate::obs::set_enabled(prior);
    tracer.set_enabled(trace_prior);
    result
}

fn sweep_inner(shape: &Shape) -> Result<(ObsCell, ObsCell, ObsCell)> {
    // one discarded warmup pass (allocator, page cache, branch history)
    run_arm(shape, true, false)?;
    let mut best_on: Option<ObsCell> = None;
    let mut best_off: Option<ObsCell> = None;
    let mut best_traced: Option<ObsCell> = None;
    let better = |best: &Option<ObsCell>, c: &ObsCell| match best {
        None => true,
        Some(b) => c.p50_us < b.p50_us,
    };
    for _ in 0..shape.rounds.max(1) {
        let on = cell("enabled", &run_arm(shape, true, false)?);
        let off = cell("disabled", &run_arm(shape, false, false)?);
        let traced = cell("traced", &run_arm(shape, true, true)?);
        if better(&best_on, &on) {
            best_on = Some(on);
        }
        if better(&best_off, &off) {
            best_off = Some(off);
        }
        if better(&best_traced, &traced) {
            best_traced = Some(traced);
        }
    }
    match (best_on, best_off, best_traced) {
        (Some(on), Some(off), Some(traced)) => Ok((on, off, traced)),
        _ => anyhow::bail!("obs sweep produced no rounds"),
    }
}

/// `percache exp obs` entry point (runtime unused: cache-level sim).
pub fn obs(_rt: &Runtime) -> Result<()> {
    run_and_report()
}

/// Shared by the exp registry and the offline dispatcher.  Writes the
/// report artifacts, then enforces the overhead gate.
pub fn run_and_report() -> Result<()> {
    let shape = if smoke_mode() { Shape::smoke() } else { Shape::full() };
    let (on, off, traced) = sweep(&shape)?;
    let d50 = overhead_frac(on.p50_us, off.p50_us);
    let d99 = overhead_frac(on.p99_us, off.p99_us);
    let t50 = overhead_frac(traced.p50_us, off.p50_us);
    let t99 = overhead_frac(traced.p99_us, off.p99_us);

    let mut table = Table::new(
        "obs: telemetry overhead on the tenancy workload",
        &["arm", "served", "p50 µs", "p99 µs", "mean µs"],
    );
    for c in [&on, &off, &traced] {
        table.row(vec![
            c.label.clone(),
            c.served.to_string(),
            format!("{:.2}", c.p50_us),
            format!("{:.2}", c.p99_us),
            format!("{:.2}", c.mean_us),
        ]);
    }
    println!("{}", table.render());
    println!(
        "[obs] p50 overhead {:+.2}% (budget {:.0}%), p99 overhead {:+.2}%",
        d50 * 100.0,
        GATE_P50_FRAC * 100.0,
        d99 * 100.0
    );
    println!(
        "[obs] traced (1-in-{} + exemplars) p50 overhead {:+.2}% (same {:.0}% budget), \
         p99 overhead {:+.2}%",
        TRACE_SAMPLE_EVERY,
        t50 * 100.0,
        GATE_P50_FRAC * 100.0,
        t99 * 100.0
    );
    let dir = reports_dir();
    table.emit(&dir, "obs");
    std::fs::create_dir_all(&dir)?;
    let path = dir.join("BENCH_obs.json");
    std::fs::write(&path, bench_doc(&shape, &on, &off, &traced).to_string_pretty())?;
    println!("[obs] wrote {}", path.display());

    anyhow::ensure!(
        d50 <= GATE_P50_FRAC,
        "telemetry p50 overhead {:.2}% exceeds the {:.0}% budget \
         (enabled {:.2} µs vs disabled {:.2} µs)",
        d50 * 100.0,
        GATE_P50_FRAC * 100.0,
        on.p50_us,
        off.p50_us
    );
    anyhow::ensure!(
        t50 <= GATE_P50_FRAC,
        "tracing p50 overhead {:.2}% exceeds the {:.0}% budget \
         (traced {:.2} µs vs disabled {:.2} µs)",
        t50 * 100.0,
        GATE_P50_FRAC * 100.0,
        traced.p50_us,
        off.p50_us
    );
    Ok(())
}

fn cell_json(c: &ObsCell) -> Json {
    let mut o = Json::obj();
    o.insert("label", c.label.as_str());
    o.insert("served", c.served);
    o.insert("p50_us", c.p50_us);
    o.insert("p99_us", c.p99_us);
    o.insert("mean_us", c.mean_us);
    Json::Obj(o)
}

/// Build the `BENCH_obs.json` document (pure — unit-testable without
/// touching the global registry).
pub fn bench_doc(shape: &Shape, on: &ObsCell, off: &ObsCell, traced: &ObsCell) -> Json {
    let mut root = Json::obj();
    root.insert("bench", "obs");
    root.insert("tenants", shape.tenants);
    root.insert("arrivals", shape.arrivals);
    root.insert("rounds", shape.rounds);
    root.insert("enabled", cell_json(on));
    root.insert("disabled", cell_json(off));
    root.insert("traced", cell_json(traced));
    root.insert("trace_sample_every", TRACE_SAMPLE_EVERY);
    root.insert("overhead_p50_frac", overhead_frac(on.p50_us, off.p50_us));
    root.insert("overhead_p99_frac", overhead_frac(on.p99_us, off.p99_us));
    root.insert(
        "overhead_trace_p50_frac",
        overhead_frac(traced.p50_us, off.p50_us),
    );
    root.insert(
        "overhead_trace_p99_frac",
        overhead_frac(traced.p99_us, off.p99_us),
    );
    root.insert("gate_p50_frac", GATE_P50_FRAC);
    Json::Obj(root)
}

#[cfg(test)]
mod tests {
    // NOTE: these tests never call `sweep`/`run_arm` — the bench toggles
    // the *global* registry's enabled flag, which would race with every
    // other test in the parallel harness.  Only the pure pieces run here.
    use super::*;

    fn fake_cell(label: &str, p50: f64, p99: f64) -> ObsCell {
        ObsCell {
            label: label.to_string(),
            served: 100,
            p50_us: p50,
            p99_us: p99,
            mean_us: (p50 + p99) / 2.0,
        }
    }

    #[test]
    fn overhead_frac_math() {
        assert!((overhead_frac(103.0, 100.0) - 0.03).abs() < 1e-12);
        assert!((overhead_frac(95.0, 100.0) + 0.05).abs() < 1e-12);
        assert_eq!(overhead_frac(5.0, 0.0), 0.0);
    }

    #[test]
    fn bench_doc_is_parseable_and_complete() {
        let shape = Shape::smoke();
        let on = fake_cell("enabled", 10.2, 21.0);
        let off = fake_cell("disabled", 10.0, 20.0);
        let traced = fake_cell("traced", 10.1, 22.0);
        let j = Json::parse(&bench_doc(&shape, &on, &off, &traced).to_string_pretty()).unwrap();
        assert_eq!(j.get("bench").as_str(), Some("obs"));
        assert_eq!(j.get("tenants").as_usize(), Some(shape.tenants));
        assert_eq!(j.get("enabled").get("label").as_str(), Some("enabled"));
        assert_eq!(j.get("traced").get("label").as_str(), Some("traced"));
        assert_eq!(
            j.get("trace_sample_every").as_usize(),
            Some(TRACE_SAMPLE_EVERY as usize)
        );
        let d50 = j.get("overhead_p50_frac").as_f64().unwrap();
        assert!((d50 - 0.02).abs() < 1e-9, "got {d50}");
        let t50 = j.get("overhead_trace_p50_frac").as_f64().unwrap();
        assert!((t50 - 0.01).abs() < 1e-9, "got {t50}");
        assert_eq!(j.get("gate_p50_frac").as_f64(), Some(GATE_P50_FRAC));
    }

    #[test]
    fn shapes_are_sane() {
        let full = Shape::full();
        let smoke = Shape::smoke();
        assert!(smoke.arrivals < full.arrivals);
        assert!(smoke.tenants <= full.tenants);
        assert!(full.rounds >= 1 && smoke.rounds >= 1);
    }
}
