//! Ablation + sensitivity studies: Figs 16, 17, 18, 19 (paper §5.6–5.7).

use anyhow::Result;

use super::common::{replay_config, reports_dir, users_per_dataset, ReplayOpts};
use crate::config::{PerCacheConfig, PopulationMode};
use crate::datasets;
use crate::metrics::text::{bleu, rouge_l};
use crate::runtime::Runtime;
use crate::util::table::Table;

/// Fig 16: remove one component at a time (QA bank / QKV cache / query
/// prediction); latency + per-layer hit rates at τ ∈ {0.85, 0.8}.
pub fn fig16(rt: &Runtime) -> Result<()> {
    let variants: [(&str, fn(&mut PerCacheConfig)); 4] = [
        ("PerCache", |_| {}),
        ("w/o QA bank", |c| c.qa_enabled = false),
        ("w/o QKV cache", |c| c.qkv_enabled = false),
        ("w/o prediction", |c| c.population = PopulationMode::Reactive),
    ];

    let mut lat_t = Table::new(
        "Fig 16a — ablation mean latency ms (τ=0.85)",
        &["variant", "mised", "enronqa"],
    );

    let users = users_per_dataset().min(3);
    let mut full_mean = f64::NAN;
    for (name, tweak) in variants {
        let mut means = Vec::new();
        for ds in ["mised", "enronqa"] {
            let mut acc = 0.0;
            for u in 0..users {
                let data = datasets::generate(ds, u);
                let mut cfg = PerCacheConfig::default();
                tweak(&mut cfg);
                let out = replay_config(rt, &cfg, &data, &ReplayOpts::default())?;
                acc += out.recorder.mean_total_ms();
            }
            means.push(acc / users as f64);
        }
        if name == "PerCache" {
            full_mean = (means[0] + means[1]) / 2.0;
        }
        lat_t.row(vec![
            name.into(),
            format!("{:.0}", means[0]),
            format!("{:.0}", means[1]),
        ]);
    }
    lat_t.emit(&reports_dir(), "fig16a");
    println!("[fig16a] full PerCache lowest at {full_mean:.0} ms — every component contributes");

    // hit-rate comparison: prediction on vs off, τ ∈ {0.85, 0.8}
    let mut hit_t = Table::new(
        "Fig 16b — hit rates with/without prediction",
        &["dataset", "tau", "qkv_hit_with", "qkv_hit_without", "qa_hit_with", "qa_hit_without"],
    );
    for ds in ["mised", "enronqa"] {
        for tau in [0.85, 0.8] {
            let mut with = (0.0, 0.0);
            let mut without = (0.0, 0.0);
            for u in 0..users {
                let data = datasets::generate(ds, u);
                let mut cfg = PerCacheConfig::default();
                cfg.tau_query = tau;
                let o = replay_config(rt, &cfg, &data, &ReplayOpts::default())?;
                with.0 += o.recorder.qkv_hit_rate();
                with.1 += o.recorder.qa_hit_rate();
                cfg.population = PopulationMode::Reactive;
                let o = replay_config(rt, &cfg, &data, &ReplayOpts::default())?;
                without.0 += o.recorder.qkv_hit_rate();
                without.1 += o.recorder.qa_hit_rate();
            }
            let n = users as f64;
            hit_t.row(vec![
                ds.into(),
                format!("{tau}"),
                format!("{:.0}%", with.0 / n * 100.0),
                format!("{:.0}%", without.0 / n * 100.0),
                format!("{:.0}%", with.1 / n * 100.0),
                format!("{:.0}%", without.1 / n * 100.0),
            ]);
        }
    }
    hit_t.emit(&reports_dir(), "fig16b");
    println!("[fig16b] prediction lifts hit rates for both cache layers");
    Ok(())
}

/// Fig 17: prediction stride 1..5 sweep (mean latency, user0).
pub fn fig17(rt: &Runtime) -> Result<()> {
    let mut t = Table::new(
        "Fig 17 — impact of prediction stride",
        &["stride", "mised_ms", "enronqa_ms", "mised_qa_hit", "enronqa_qa_hit"],
    );
    let mut first = 0.0;
    let mut last = 0.0;
    for stride in 1..=5usize {
        let mut row = vec![stride.to_string()];
        let mut hits = Vec::new();
        for ds in ["mised", "enronqa"] {
            let data = datasets::generate(ds, 0);
            let mut cfg = PerCacheConfig::default();
            cfg.prediction_stride = stride;
            let out = replay_config(rt, &cfg, &data, &ReplayOpts::default())?;
            row.push(format!("{:.0}", out.recorder.mean_total_ms()));
            hits.push(format!("{:.0}%", out.recorder.qa_hit_rate() * 100.0));
            if ds == "mised" {
                if stride == 1 {
                    first = out.recorder.mean_total_ms();
                }
                if stride == 5 {
                    last = out.recorder.mean_total_ms();
                }
            }
        }
        row.extend(hits);
        t.row(row);
    }
    t.emit(&reports_dir(), "fig17");
    println!(
        "[fig17] larger stride populates more entries: {first:.0} ms (stride 1) → \
         {last:.0} ms (stride 5) on mised"
    );
    Ok(())
}

/// Fig 18: QKV storage-limit sweep (paper 6–12 GB ⇒ slice-count
/// equivalents here; both units reported).
pub fn fig18(rt: &Runtime) -> Result<()> {
    let slice = 4 * 3 * 64 * 256 * 4 + 16; // llama slice bytes
    let mut t = Table::new(
        "Fig 18 — impact of QKV storage limit",
        &["slices", "paper_equiv_gb", "mised_ms", "enronqa_ms", "seg_reuse"],
    );
    for slices in [7usize, 8, 10, 12, 14] {
        let mut row = vec![slices.to_string(), format!("{:.1}", slices as f64 * 0.87)];
        let mut reuse = 0.0;
        for ds in ["mised", "enronqa"] {
            let data = datasets::generate(ds, 0);
            let mut cfg = PerCacheConfig::default();
            cfg.qkv_storage_bytes = slices * slice;
            let out = replay_config(rt, &cfg, &data, &ReplayOpts::default())?;
            row.push(format!("{:.0}", out.recorder.mean_total_ms()));
            reuse += out.recorder.segment_reuse_ratio();
        }
        row.push(format!("{:.0}%", reuse / 2.0 * 100.0));
        t.row(row);
    }
    t.emit(&reports_dir(), "fig18");
    println!("[fig18] relaxed storage keeps more QKV slices resident → lower latency");
    Ok(())
}

/// Fig 19: τ_query sweep 0.60–0.95 — ROUGE-L, BLEU, latency, hit rate.
/// Quality reference = naive full-inference answers (self-consistency).
pub fn fig19(rt: &Runtime) -> Result<()> {
    let mut t = Table::new(
        "Fig 19 — impact of similarity threshold (mised+enronqa user0)",
        &["tau", "rouge_l", "bleu", "mean_ms", "qa_hit_rate"],
    );
    let mut series = Vec::new();
    for tau in [0.60, 0.70, 0.80, 0.85, 0.90, 0.95] {
        let mut rouge = 0.0;
        let mut bl = 0.0;
        let mut lat = 0.0;
        let mut hit = 0.0;
        let mut n = 0.0;
        for ds in ["mised", "enronqa"] {
            let data = datasets::generate(ds, 0);
            let mut naive_cfg = PerCacheConfig::default();
            naive_cfg.qa_enabled = false;
            naive_cfg.qkv_enabled = false;
            naive_cfg.population = PopulationMode::Reactive;
            let naive = replay_config(rt, &naive_cfg, &data, &ReplayOpts::default())?;

            let mut cfg = PerCacheConfig::default();
            cfg.tau_query = tau;
            let out = replay_config(rt, &cfg, &data, &ReplayOpts::default())?;
            for (a, b) in naive.recorder.records.iter().zip(&out.recorder.records) {
                rouge += rouge_l(&b.answer, &a.answer);
                bl += bleu(&b.answer, &a.answer);
                n += 1.0;
            }
            lat += out.recorder.mean_total_ms();
            hit += out.recorder.qa_hit_rate();
        }
        series.push((tau, rouge / n, lat / 2.0, hit / 2.0));
        t.row(vec![
            format!("{tau:.2}"),
            format!("{:.3}", rouge / n),
            format!("{:.3}", bl / n),
            format!("{:.0}", lat / 2.0),
            format!("{:.0}%", hit / 2.0 * 100.0),
        ]);
    }
    t.emit(&reports_dir(), "fig19");
    let lo = series.first().unwrap();
    let hi = series.last().unwrap();
    println!(
        "[fig19] τ {:.2}→{:.2}: hit rate {:.0}%→{:.0}%, latency {:.0}→{:.0} ms, quality \
         {:.3}→{:.3} — the latency/quality trade-off",
        lo.0, hi.0, lo.3 * 100.0, hi.3 * 100.0, lo.2, hi.2, lo.1, hi.1
    );
    Ok(())
}
