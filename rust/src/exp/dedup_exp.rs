//! Cross-tenant dedup experiment (`percache exp dedup`): per-tenant-copy
//! vs content-addressed slice pool over a workload with a shared corpus.
//!
//! Both arms replay the *same* arrival stream (same tenants, same
//! queries, same share-eligibility flags) under the same global memory
//! budget; only the pool config differs.  The per-tenant-copy arm stores
//! every tenant's copy of the public chunks privately; the pooled arm
//! interns them once and charges each tenant an amortized share.  Emits
//! the human table + CSV plus `reports/BENCH_dedup.json`: resident
//! bytes per arm, dedup ratio, hit-rate parity, and the exact-sum
//! accounting check (private plans + pool reserve == global budget).
//! `--smoke` (or PERCACHE_SMOKE=1) shrinks the sweep for CI.

use anyhow::Result;

use crate::config::TenancyConfig;
use crate::datasets;
use crate::runtime::Runtime;
use crate::tenancy::sim::{arrivals_from_workload, replay, sim_slice_bytes, SimConfig};
use crate::tenancy::{RouterConfig, TenantRegistry};
use crate::util::json::Json;
use crate::util::sync::lock_or_recover;
use crate::util::table::Table;

use super::common::reports_dir;

/// Tenant counts swept (full mode).
pub const TENANT_COUNTS: [usize; 5] = [1, 2, 4, 8, 16];
const SMOKE_COUNTS: [usize; 3] = [1, 2, 4];
const ARRIVALS_PER_TENANT: usize = 40;
const SMOKE_ARRIVALS_PER_TENANT: usize = 12;
/// Global QKV budget, sized so the largest sweep point's working set
/// fits in both arms — the comparison measures bytes *needed*, not
/// eviction churn.
const GLOBAL_SLICES: usize = 320;
/// Pool reservation (carved out of the same global budget).
const POOL_SLICES: usize = 32;
/// Fraction of each tenant's topics drawn from the shared public corpus.
const SHARED_FRAC: f64 = 0.6;

fn smoke() -> bool {
    std::env::var("PERCACHE_SMOKE").map(|v| v == "1").unwrap_or(false)
}

fn tenant_counts() -> &'static [usize] {
    if smoke() {
        &SMOKE_COUNTS
    } else {
        &TENANT_COUNTS
    }
}

fn arrivals_per_tenant() -> usize {
    if smoke() {
        SMOKE_ARRIVALS_PER_TENANT
    } else {
        ARRIVALS_PER_TENANT
    }
}

/// One sweep point: the per-tenant-copy arm vs the pooled arm.
#[derive(Debug, Clone)]
pub struct DedupCell {
    pub tenants: usize,
    pub arrivals: usize,
    /// Resident cache bytes (shards + pool) after replay, per arm.
    pub base_resident_bytes: usize,
    pub pooled_resident_bytes: usize,
    /// base / pooled — >1 means the pool saved memory.
    pub dedup_ratio: f64,
    /// Fraction of requests served off some cache layer, per arm.
    pub base_hit_rate: f64,
    pub pooled_hit_rate: f64,
    /// Pool occupancy at the end of the pooled arm.
    pub pool_entries: usize,
    pub pool_bytes: usize,
    /// Position-aware reuses (reorder-vs-recompute) in the pooled arm.
    pub reanchored: u64,
    /// Exact-sum accounting: private plans + reserve == global.
    pub base_plan_bytes: usize,
    pub pooled_plan_bytes: usize,
    pub reserved_bytes: usize,
    pub global_bytes: usize,
}

struct ArmOutcome {
    arrivals: usize,
    resident_bytes: usize,
    hit_rate: f64,
    pool_entries: usize,
    pool_bytes: usize,
    reanchored: u64,
    plan_bytes: usize,
    reserved_bytes: usize,
}

fn run_arm(n: usize, pooled: bool) -> Result<ArmOutcome> {
    let slice = sim_slice_bytes();
    let mut tc = TenancyConfig {
        enabled: true,
        max_tenants: n.max(1),
        global_qkv_bytes: GLOBAL_SLICES * slice,
        rebalance_every: 16,
        ..TenancyConfig::default()
    };
    let mut sim = SimConfig::default();
    if pooled {
        tc.pool.enabled = true;
        tc.pool.pool_bytes = POOL_SLICES * slice;
        tc.pool.reanchor = true;
        sim.reanchor = true;
        sim.reanchor_cost_frac = tc.pool.reanchor_cost_frac;
    }
    let mut reg = TenantRegistry::new(&tc);
    for _ in 0..n {
        reg.create_tenant()?;
    }
    let w = datasets::multi_tenant_shared(
        n,
        n * arrivals_per_tenant(),
        1.0,
        0xD0D0 + n as u64,
        SHARED_FRAC,
    );
    let arrivals = arrivals_from_workload(&w);
    let reanchored_before = crate::obs_counter!("pool.reanchored").get();
    let out = replay(
        &mut reg,
        RouterConfig {
            queue_cap: tc.queue_cap,
            global_cap: tc.global_queue_cap,
            ..RouterConfig::default()
        },
        &sim,
        &arrivals,
        8,
    )?;
    reg.check_invariants()?;

    let served: usize = out.per_tenant.iter().map(|r| r.len()).sum();
    let hits: usize = out
        .per_tenant
        .iter()
        .flat_map(|r| r.records.iter())
        .filter(|q| q.path != crate::metrics::ServePath::Full)
        .count();
    Ok(ArmOutcome {
        arrivals: arrivals.len(),
        resident_bytes: reg.resident_bytes() + reg.pool_bytes_used(),
        hit_rate: if served == 0 {
            0.0
        } else {
            hits as f64 / served as f64
        },
        pool_entries: reg
            .pool()
            .map(|p| lock_or_recover(p).len())
            .unwrap_or(0),
        pool_bytes: reg.pool_bytes_used(),
        reanchored: crate::obs_counter!("pool.reanchored").get() - reanchored_before,
        plan_bytes: reg.plan().iter().map(|a| a.bytes).sum(),
        reserved_bytes: reg.governor.reserved_bytes(),
    })
}

/// Run the sweep (pure; unit-testable without a runtime).
pub fn sweep() -> Result<Vec<DedupCell>> {
    let global = GLOBAL_SLICES * sim_slice_bytes();
    let mut cells = Vec::new();
    for &n in tenant_counts() {
        let base = run_arm(n, false)?;
        let pool = run_arm(n, true)?;
        cells.push(DedupCell {
            tenants: n,
            arrivals: base.arrivals,
            base_resident_bytes: base.resident_bytes,
            pooled_resident_bytes: pool.resident_bytes,
            dedup_ratio: base.resident_bytes as f64 / pool.resident_bytes.max(1) as f64,
            base_hit_rate: base.hit_rate,
            pooled_hit_rate: pool.hit_rate,
            pool_entries: pool.pool_entries,
            pool_bytes: pool.pool_bytes,
            reanchored: pool.reanchored,
            base_plan_bytes: base.plan_bytes,
            pooled_plan_bytes: pool.plan_bytes,
            reserved_bytes: pool.reserved_bytes,
            global_bytes: global,
        });
    }
    Ok(cells)
}

/// `percache exp dedup` entry point (runtime unused: cache-level sim).
pub fn dedup(_rt: &Runtime) -> Result<()> {
    run_and_report()
}

/// Shared by the exp registry and CI.
pub fn run_and_report() -> Result<()> {
    let cells = sweep()?;
    let mut table = Table::new(
        "dedup: per-tenant-copy vs pooled resident bytes at fixed global budget",
        &[
            "tenants", "arrivals", "base KB", "pooled KB", "ratio", "base hit",
            "pool hit", "pool entries", "reanchored",
        ],
    );
    for c in &cells {
        table.row(vec![
            c.tenants.to_string(),
            c.arrivals.to_string(),
            format!("{:.0}", c.base_resident_bytes as f64 / 1024.0),
            format!("{:.0}", c.pooled_resident_bytes as f64 / 1024.0),
            format!("{:.2}x", c.dedup_ratio),
            format!("{:.0}%", c.base_hit_rate * 100.0),
            format!("{:.0}%", c.pooled_hit_rate * 100.0),
            c.pool_entries.to_string(),
            c.reanchored.to_string(),
        ]);
    }
    println!("{}", table.render());
    let dir = reports_dir();
    table.emit(&dir, "dedup");
    write_bench_json(&cells, &dir)?;
    Ok(())
}

/// Emit `<dir>/BENCH_dedup.json` — the dedup perf-trajectory seed.
pub fn write_bench_json(cells: &[DedupCell], dir: &std::path::Path) -> Result<()> {
    let mut root = Json::obj();
    root.insert("bench", "dedup");
    root.insert("global_qkv_bytes", GLOBAL_SLICES * sim_slice_bytes());
    root.insert("pool_bytes_cap", POOL_SLICES * sim_slice_bytes());
    root.insert("shared_corpus_frac", SHARED_FRAC);
    let series: Vec<Json> = cells
        .iter()
        .map(|c| {
            let mut o = Json::obj();
            o.insert("tenants", c.tenants);
            o.insert("arrivals", c.arrivals);
            o.insert("base_resident_bytes", c.base_resident_bytes);
            o.insert("pooled_resident_bytes", c.pooled_resident_bytes);
            o.insert("dedup_ratio", c.dedup_ratio);
            o.insert("base_hit_rate", c.base_hit_rate);
            o.insert("pooled_hit_rate", c.pooled_hit_rate);
            o.insert("pool_entries", c.pool_entries);
            o.insert("pool_bytes", c.pool_bytes);
            o.insert("reanchored", c.reanchored);
            o.insert("base_plan_bytes", c.base_plan_bytes);
            o.insert("pooled_plan_bytes", c.pooled_plan_bytes);
            o.insert("reserved_bytes", c.reserved_bytes);
            o.insert("base_plan_exact", c.base_plan_bytes == c.global_bytes);
            o.insert(
                "pooled_plan_exact",
                c.pooled_plan_bytes + c.reserved_bytes == c.global_bytes,
            );
            Json::Obj(o)
        })
        .collect();
    root.insert("series", Json::Arr(series));

    std::fs::create_dir_all(dir)?;
    let path = dir.join("BENCH_dedup.json");
    std::fs::write(&path, Json::Obj(root).to_string_pretty())?;
    println!("[dedup] wrote {}", path.display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_dedups_sublinearly_with_hit_parity_and_exact_plans() {
        let cells = sweep().unwrap();
        assert_eq!(cells.len(), tenant_counts().len());
        for (c, &n) in cells.iter().zip(tenant_counts()) {
            assert_eq!(c.tenants, n);
            assert!(c.arrivals > 0);
            // accounting is exact in both arms: private plans sum to the
            // global budget minus whatever the pool reserved
            assert_eq!(c.base_plan_bytes, c.global_bytes, "base plan at n={n}");
            assert_eq!(
                c.pooled_plan_bytes + c.reserved_bytes,
                c.global_bytes,
                "pooled plan + reserve at n={n}"
            );
            // hit rates no worse than the per-tenant-copy baseline
            // (reanchoring can only add reuse; tiny epsilon for jitter)
            assert!(
                c.pooled_hit_rate >= c.base_hit_rate - 0.02,
                "pooled hit {:.3} worse than base {:.3} at n={n}",
                c.pooled_hit_rate,
                c.base_hit_rate
            );
        }
        // with ≥2 tenants over a shared corpus, interning must save bytes…
        let last = cells.last().unwrap();
        assert!(
            last.dedup_ratio > 1.05,
            "no dedup at n={}: {:.3}x",
            last.tenants,
            last.dedup_ratio
        );
        assert!(last.pool_entries > 0, "pool never populated");
        // …and resident bytes must grow sublinearly in tenant count:
        // strictly below scaling the single-tenant footprint linearly
        let first = &cells[0];
        assert_eq!(first.tenants, 1);
        assert!(
            last.pooled_resident_bytes < last.tenants * first.pooled_resident_bytes,
            "pooled arm scaled linearly: {} tenants, {} vs 1-tenant {}",
            last.tenants,
            last.pooled_resident_bytes,
            first.pooled_resident_bytes
        );
    }

    #[test]
    fn bench_json_is_parseable() {
        let tmp = std::env::temp_dir().join(format!("percache_dedupexp_{}", std::process::id()));
        let cells = sweep().unwrap();
        write_bench_json(&cells, &tmp).unwrap();
        let text = std::fs::read_to_string(tmp.join("BENCH_dedup.json")).unwrap();
        let j = Json::parse(&text).unwrap();
        assert_eq!(j.get("bench").as_str(), Some("dedup"));
        let series = j.get("series").as_arr().unwrap();
        assert_eq!(series.len(), tenant_counts().len());
        for s in series {
            assert_eq!(s.get("base_plan_exact").as_bool(), Some(true));
            assert_eq!(s.get("pooled_plan_exact").as_bool(), Some(true));
        }
        let _ = std::fs::remove_dir_all(&tmp);
    }
}
