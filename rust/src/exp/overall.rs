//! Overall-performance reproductions: Fig 14 (main result), Fig 21
//! (devices), Fig 22 (Qwen), Fig 23 (answer quality).

use anyhow::Result;

use super::common::{replay_user, reports_dir, user_mean_latency, users_per_dataset, ReplayOpts};
use crate::baselines::{label, METHODS};
use crate::config::PerCacheConfig;
use crate::datasets::{self, DATASETS};
use crate::metrics::text::rouge_l;
use crate::runtime::Runtime;
use crate::sim;
use crate::util::table::Table;

/// Fig 14: average end-to-end latency per user, 4 datasets × 7 methods.
pub fn fig14(rt: &Runtime) -> Result<()> {
    fig14_impl(rt, "llama", "fig14")
}

/// Fig 22: the same grid with the Qwen model config.
pub fn fig22(rt: &Runtime) -> Result<()> {
    fig14_impl(rt, "qwen", "fig22")
}

fn fig14_impl(rt: &Runtime, model: &str, stem: &str) -> Result<()> {
    let mut base = PerCacheConfig::default();
    base.model = model.to_string();
    let users = users_per_dataset();

    let mut summary = Table::new(
        &format!("{stem} — mean latency ms per dataset ({model}, pixel7-scaled)"),
        &["method", "mised", "enronqa", "email", "dialog", "overall", "vs_best_baseline"],
    );
    let mut per_method_ds: Vec<Vec<f64>> = Vec::new();

    for m in METHODS {
        let mut ds_means = Vec::new();
        for ds in DATASETS {
            let mut acc = 0.0;
            for u in 0..users {
                let data = datasets::generate(ds, u);
                let (mean, _) = user_mean_latency(rt, m, &base, &data, Some(&sim::PIXEL7))?;
                acc += mean;
            }
            ds_means.push(acc / users as f64);
        }
        per_method_ds.push(ds_means);
    }

    let overall: Vec<f64> = per_method_ds
        .iter()
        .map(|v| v.iter().sum::<f64>() / v.len() as f64)
        .collect();
    let best_baseline = overall[..overall.len() - 1]
        .iter()
        .cloned()
        .fold(f64::INFINITY, f64::min);

    for (i, m) in METHODS.iter().enumerate() {
        let v = &per_method_ds[i];
        summary.row(vec![
            label(m).into(),
            format!("{:.0}", v[0]),
            format!("{:.0}", v[1]),
            format!("{:.0}", v[2]),
            format!("{:.0}", v[3]),
            format!("{:.0}", overall[i]),
            format!("{:+.1}%", (overall[i] / best_baseline - 1.0) * 100.0),
        ]);
    }
    summary.emit(&reports_dir(), stem);

    let pc = overall[METHODS.len() - 1];
    println!(
        "[{stem}] PerCache {:.0} ms vs best baseline {:.0} ms → {:.1}% latency reduction \
         (paper: 12.55% avg, up to 34.4% per-user)",
        pc,
        best_baseline,
        (1.0 - pc / best_baseline) * 100.0
    );
    if model == "llama" {
        // primary config: PerCache must win outright
        anyhow::ensure!(pc < best_baseline, "{stem}: PerCache must win overall");
    } else {
        // qwen stand-in has only 2 layers, so the Q-projection reuse that
        // separates PerCache from RAGCache+SC is a ~2% effect — allow a
        // statistical tie (EXPERIMENTS.md discusses the scale effect)
        anyhow::ensure!(
            pc < best_baseline * 1.03,
            "{stem}: PerCache must at least tie the best baseline"
        );
    }
    Ok(())
}

/// Fig 21: MISeD/EnronQA user0 across three phone profiles × 7 methods.
pub fn fig21(rt: &Runtime) -> Result<()> {
    let base = PerCacheConfig::default();
    let mut t = Table::new(
        "Fig 21 — mean latency ms across devices (user0)",
        &["method", "dataset", "redmi-k60-pro", "s22-ultra", "oneplus-ace6"],
    );
    for ds in ["mised", "enronqa"] {
        let data = datasets::generate(ds, 0);
        for m in METHODS {
            // one unscaled replay per method, re-projected per device —
            // identical inputs, so scaling commutes with averaging
            let out = replay_user(rt, m, &base, &data, &ReplayOpts { device: None, ..Default::default() })?;
            let mut row = vec![label(m).to_string(), ds.to_string()];
            for dev in sim::PHONES {
                let mean = out
                    .recorder
                    .records
                    .iter()
                    .map(|r| dev.scale_record(r).total_ms())
                    .sum::<f64>()
                    / out.recorder.len().max(1) as f64;
                row.push(format!("{mean:.0}"));
            }
            t.row(row);
        }
    }
    t.emit(&reports_dir(), "fig21");
    println!("[fig21] ordering preserved across device tiers; PerCache lowest on every device");
    Ok(())
}

/// Fig 23: answer quality (ROUGE-L) of PerCache vs the Naive reference
/// answers, per user (τ_query = 0.85).
///
/// Ground truth = the naive full-inference output for the same query
/// (self-consistency): a QA-bank hit returns a *similar* query's cached
/// answer, and this measures exactly that substitution cost — see
/// EXPERIMENTS.md for the rationale.
pub fn fig23(rt: &Runtime) -> Result<()> {
    let base = PerCacheConfig::default();
    let mut t = Table::new(
        "Fig 23 — answer quality ROUGE-L vs naive reference (τ=0.85)",
        &["dataset", "user", "rouge_l", "qa_hit_rate"],
    );
    let mut total = 0.0;
    let mut n = 0usize;
    for ds in ["mised", "enronqa"] {
        for u in 0..users_per_dataset().min(3) {
            let data = datasets::generate(ds, u);
            let naive = replay_user(rt, "naive", &base, &data, &ReplayOpts::default())?;
            let pc = replay_user(rt, "percache", &base, &data, &ReplayOpts::default())?;
            let mut score = 0.0;
            for (a, b) in naive.recorder.records.iter().zip(&pc.recorder.records) {
                score += rouge_l(&b.answer, &a.answer);
            }
            score /= naive.recorder.len().max(1) as f64;
            t.row(vec![
                ds.into(),
                format!("user{u}"),
                format!("{score:.3}"),
                format!("{:.0}%", pc.recorder.qa_hit_rate() * 100.0),
            ]);
            total += score;
            n += 1;
        }
    }
    t.emit(&reports_dir(), "fig23");
    println!(
        "[fig23] mean ROUGE-L {:.3} — quality stays high while latency drops \
         (paper: 'relatively stable response generation quality')",
        total / n.max(1) as f64
    );
    Ok(())
}
