//! Request-scoped causal tracing (DESIGN.md §16).
//!
//! Each sampled request gets a trace id and a tree of spans — parent
//! links, stage name, tenant, start/end nanoseconds — propagated from
//! router admission through the engine serve stages, tiering hydration
//! waits, and pool intern/re-anchor/COW.  The fast path is guarded by
//! one relaxed atomic load: while tracing is disabled (the default)
//! nothing allocates and no lock is taken.  Completed traces feed the
//! per-tenant tail-exemplar reservoir (`obs::exemplar`) and export as
//! a `percache.trace/v1` dump or Chrome `trace_event` JSON; the
//! attribution helpers at the bottom back the `percache trace`
//! analyzer subcommand.

use std::cell::Cell;
use std::collections::{BTreeMap, BTreeSet};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering::Relaxed};
use std::sync::Mutex;
use std::time::Instant;

use crate::obs::exemplar::{Exemplar, ExemplarConfig, ExemplarReservoir};
use crate::util::json::Json;
use crate::util::sync::lock_or_recover;

/// Version tag written into every trace dump.
pub const DUMP_VERSION: &str = "percache.trace/v1";

/// Open-trace table cap; admissions beyond it are counted as dropped.
const MAX_OPEN_TRACES: usize = 256;
/// Per-trace span cap; spans beyond it are silently not recorded.
const MAX_SPANS_PER_TRACE: usize = 64;

/// Lightweight handle identifying "the span I am inside of".  Copied
/// into thread-locals and across queue hand-offs; never heap-allocated.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceCtx {
    pub trace: u64,
    pub span: u64,
    pub tenant: Option<u32>,
}

/// One completed span of a trace tree.
#[derive(Debug, Clone, PartialEq)]
pub struct SpanRecord {
    pub span: u64,
    /// `None` marks the root span of the trace.
    pub parent: Option<u64>,
    pub stage: String,
    pub t_start_ns: u64,
    pub t_end_ns: u64,
}

/// A completed trace: the root span is always `spans[0]`.
#[derive(Debug, Clone, PartialEq)]
pub struct Trace {
    pub trace: u64,
    pub tenant: Option<u32>,
    pub spans: Vec<SpanRecord>,
}

/// Monotonic trace counters for snapshot export.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceStats {
    pub started: u64,
    pub completed: u64,
    pub dropped: u64,
}

#[derive(Debug)]
struct OpenTrace {
    tenant: Option<u32>,
    spans: Vec<SpanRecord>,
}

/// The tracing engine.  One global instance lives behind
/// `obs::tracer()`; experiments that need deterministic ids build
/// local instances with a virtual clock.
#[derive(Debug)]
pub struct Tracer {
    enabled: AtomicBool,
    sample_every: AtomicU64,
    tick: AtomicU64,
    next_id: AtomicU64,
    virtual_mode: AtomicBool,
    virtual_ns: AtomicU64,
    t0: Instant,
    started: AtomicU64,
    completed: AtomicU64,
    dropped: AtomicU64,
    open: Mutex<BTreeMap<u64, OpenTrace>>,
    reservoir: Mutex<ExemplarReservoir>,
}

impl Default for Tracer {
    fn default() -> Self {
        Self::new()
    }
}

impl Tracer {
    /// Disabled tracer on the real clock with default sampling (1-in-8)
    /// and exemplar sizing.
    pub fn new() -> Self {
        Self {
            enabled: AtomicBool::new(false),
            sample_every: AtomicU64::new(8),
            tick: AtomicU64::new(0),
            next_id: AtomicU64::new(1),
            virtual_mode: AtomicBool::new(false),
            virtual_ns: AtomicU64::new(0),
            t0: Instant::now(),
            started: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
            open: Mutex::new(BTreeMap::new()),
            reservoir: Mutex::new(ExemplarReservoir::new(ExemplarConfig::default())),
        }
    }

    // -- configuration -----------------------------------------------------

    pub fn enabled(&self) -> bool {
        self.enabled.load(Relaxed)
    }

    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Relaxed);
    }

    /// Trace 1 in `every` admitted requests (clamped to at least 1).
    pub fn set_sample_every(&self, every: u64) {
        self.sample_every.store(every.max(1), Relaxed);
    }

    pub fn sample_every(&self) -> u64 {
        self.sample_every.load(Relaxed).max(1)
    }

    /// Replace the exemplar reservoir (drops currently kept traces).
    pub fn set_exemplar_config(&self, cfg: ExemplarConfig) {
        *lock_or_recover(&self.reservoir) = ExemplarReservoir::new(cfg);
    }

    /// Switch between the process monotonic clock and an externally
    /// driven virtual clock (`set_virtual_ns`).
    pub fn set_virtual_clock(&self, on: bool) {
        self.virtual_mode.store(on, Relaxed);
    }

    pub fn set_virtual_ns(&self, ns: u64) {
        self.virtual_ns.store(ns, Relaxed);
    }

    pub fn now_ns(&self) -> u64 {
        if self.virtual_mode.load(Relaxed) {
            self.virtual_ns.load(Relaxed)
        } else {
            self.t0.elapsed().as_nanos() as u64
        }
    }

    pub fn stats(&self) -> TraceStats {
        TraceStats {
            started: self.started.load(Relaxed),
            completed: self.completed.load(Relaxed),
            dropped: self.dropped.load(Relaxed),
        }
    }

    // -- span lifecycle ----------------------------------------------------

    /// Start a new trace rooted at `stage`.  Returns `None` when
    /// tracing is disabled, the request lost the sampling draw, or the
    /// open-trace table is full (counted in `trace.dropped`).
    pub fn begin_trace(
        &self,
        stage: &'static str,
        tenant: Option<u32>,
        t_start_ns: u64,
    ) -> Option<TraceCtx> {
        if !self.enabled.load(Relaxed) {
            return None;
        }
        let every = self.sample_every();
        let tick = self.tick.fetch_add(1, Relaxed);
        if tick % every != 0 {
            return None;
        }
        let trace = self.reserve_id();
        let span = self.reserve_id();
        {
            let mut open = lock_or_recover(&self.open);
            if open.len() >= MAX_OPEN_TRACES {
                self.dropped.fetch_add(1, Relaxed);
                return None;
            }
            open.insert(
                trace,
                OpenTrace {
                    tenant,
                    spans: vec![SpanRecord {
                        span,
                        parent: None,
                        stage: stage.to_string(),
                        t_start_ns,
                        t_end_ns: t_start_ns,
                    }],
                },
            );
        }
        self.started.fetch_add(1, Relaxed);
        Some(TraceCtx {
            trace,
            span,
            tenant,
        })
    }

    /// Record a completed child span on an open trace.
    pub fn add_span(
        &self,
        trace: u64,
        parent: Option<u64>,
        stage: &str,
        t_start_ns: u64,
        t_end_ns: u64,
    ) -> Option<u64> {
        if !self.enabled.load(Relaxed) {
            return None;
        }
        let span = self.reserve_id();
        if self.add_span_with_id(trace, parent, span, stage, t_start_ns, t_end_ns) {
            Some(span)
        } else {
            None
        }
    }

    /// Span-id allocation is split from recording so RAII guards can
    /// expose their id to children before the span body has finished.
    pub fn reserve_id(&self) -> u64 {
        self.next_id.fetch_add(1, Relaxed)
    }

    pub fn add_span_with_id(
        &self,
        trace: u64,
        parent: Option<u64>,
        span: u64,
        stage: &str,
        t_start_ns: u64,
        t_end_ns: u64,
    ) -> bool {
        let mut open = lock_or_recover(&self.open);
        let Some(entry) = open.get_mut(&trace) else {
            return false;
        };
        if entry.spans.len() >= MAX_SPANS_PER_TRACE {
            return false;
        }
        entry.spans.push(SpanRecord {
            span,
            parent,
            stage: stage.to_string(),
            t_start_ns,
            t_end_ns: t_end_ns.max(t_start_ns),
        });
        true
    }

    /// Close a trace: fixes the root span's end time, removes it from
    /// the open table, and offers it to the exemplar reservoir.
    pub fn end_trace(&self, ctx: TraceCtx, t_end_ns: u64) {
        let finished = lock_or_recover(&self.open).remove(&ctx.trace);
        let Some(open_trace) = finished else {
            return;
        };
        let mut spans = open_trace.spans;
        if let Some(root) = spans.first_mut() {
            root.t_end_ns = t_end_ns.max(root.t_start_ns);
        }
        self.completed.fetch_add(1, Relaxed);
        lock_or_recover(&self.reservoir).offer(Trace {
            trace: ctx.trace,
            tenant: open_trace.tenant,
            spans,
        });
    }

    /// Archive the current exemplar window (called from the periodic
    /// metrics dump so each dump covers a full window plus the tail).
    pub fn roll_window(&self) {
        lock_or_recover(&self.reservoir).roll_window();
    }

    pub fn exemplars(&self) -> Vec<Exemplar> {
        lock_or_recover(&self.reservoir).export()
    }

    // -- export ------------------------------------------------------------

    /// `percache.trace/v1` dump document.
    pub fn export_json(&self) -> Json {
        let stats = self.stats();
        let mut doc = Json::obj();
        doc.insert("version", DUMP_VERSION);
        doc.insert(
            "clock",
            if self.virtual_mode.load(Relaxed) {
                "virtual"
            } else {
                "real"
            },
        );
        doc.insert("started", stats.started);
        doc.insert("completed", stats.completed);
        doc.insert("dropped", stats.dropped);
        let mut arr: Vec<Json> = Vec::new();
        for ex in self.exemplars() {
            let mut t = Json::obj();
            t.insert("trace", ex.trace.trace);
            match ex.trace.tenant {
                Some(n) => t.insert("tenant", n as u64),
                None => t.insert("tenant", Json::Null),
            }
            t.insert("kind", ex.kind);
            t.insert("e2e_ms", ex.e2e_ms);
            let spans: Vec<Json> = ex.trace.spans.iter().map(span_json).collect();
            t.insert("spans", spans);
            arr.push(Json::from(t));
        }
        doc.insert("traces", arr);
        Json::from(doc)
    }

    /// Chrome `trace_event` JSON (array form, complete events):
    /// pid = tenant + 1 (0 for tenantless), tid = trace id, ts/dur µs.
    pub fn export_chrome(&self) -> Json {
        let mut events: Vec<Json> = Vec::new();
        for ex in self.exemplars() {
            let pid = ex.trace.tenant.map(|t| t as u64 + 1).unwrap_or(0);
            let mut spans = ex.trace.spans.clone();
            spans.sort_by(|a, b| (a.t_start_ns, a.span).cmp(&(b.t_start_ns, b.span)));
            for s in &spans {
                let mut e = Json::obj();
                e.insert("name", s.stage.as_str());
                e.insert("cat", ex.kind);
                e.insert("ph", "X");
                e.insert("ts", s.t_start_ns as f64 / 1000.0);
                e.insert("dur", dur_ns(s) as f64 / 1000.0);
                e.insert("pid", pid);
                e.insert("tid", ex.trace.trace);
                let mut args = Json::obj();
                args.insert("span", s.span);
                match s.parent {
                    Some(p) => args.insert("parent", p),
                    None => args.insert("parent", Json::Null),
                }
                e.insert("args", args);
                events.push(Json::from(e));
            }
        }
        Json::Arr(events)
    }
}

fn span_json(s: &SpanRecord) -> Json {
    let mut o = Json::obj();
    o.insert("span", s.span);
    match s.parent {
        Some(p) => o.insert("parent", p),
        None => o.insert("parent", Json::Null),
    }
    o.insert("stage", s.stage.as_str());
    o.insert("t_start_ns", s.t_start_ns);
    o.insert("t_end_ns", s.t_end_ns);
    Json::from(o)
}

// ---------------------------------------------------------------------------
// Thread-local current-span context + RAII guards
// ---------------------------------------------------------------------------

thread_local! {
    static CURRENT: Cell<Option<TraceCtx>> = const { Cell::new(None) };
}

/// The span context the current thread is inside of, if any.
pub fn current() -> Option<TraceCtx> {
    CURRENT.with(|c| c.get())
}

/// Make `ctx` the current span context for this thread until the guard
/// drops (restores the previous context).  Used to hand a trace across
/// queue/thread boundaries: the popping thread attaches the context
/// that admission created.
pub fn attach(ctx: Option<TraceCtx>) -> AttachGuard {
    let prev = CURRENT.with(|c| c.replace(ctx));
    AttachGuard { prev }
}

#[derive(Debug)]
pub struct AttachGuard {
    prev: Option<TraceCtx>,
}

impl Drop for AttachGuard {
    fn drop(&mut self) {
        let prev = self.prev;
        CURRENT.with(|c| c.set(prev));
    }
}

#[derive(Debug)]
struct ChildActive {
    ctx: TraceCtx,
    parent: u64,
    stage: &'static str,
    t_start_ns: u64,
    prev: Option<TraceCtx>,
}

/// RAII child span on the global tracer.  Inert (no allocation, no
/// lock) when tracing is disabled or the thread has no current context.
#[derive(Debug)]
pub struct ChildGuard {
    active: Option<ChildActive>,
}

impl ChildGuard {
    /// Context of the child span itself (None when inert).
    pub fn ctx(&self) -> Option<TraceCtx> {
        self.active.as_ref().map(|a| a.ctx)
    }

    /// Span id of the parent this child hangs off (None when inert).
    pub fn parent(&self) -> Option<u64> {
        self.active.as_ref().map(|a| a.parent)
    }
}

/// Open a child span under the thread's current context.
pub fn child(stage: &'static str) -> ChildGuard {
    match current() {
        Some(parent) => child_under(stage, parent),
        None => ChildGuard { active: None },
    }
}

/// Open a child span under an explicit parent context.
pub fn child_under(stage: &'static str, parent: TraceCtx) -> ChildGuard {
    let tracer = crate::obs::tracer();
    if !tracer.enabled() {
        return ChildGuard { active: None };
    }
    let span = tracer.reserve_id();
    let ctx = TraceCtx {
        trace: parent.trace,
        span,
        tenant: parent.tenant,
    };
    let prev = CURRENT.with(|c| c.replace(Some(ctx)));
    ChildGuard {
        active: Some(ChildActive {
            ctx,
            parent: parent.span,
            stage,
            t_start_ns: tracer.now_ns(),
            prev,
        }),
    }
}

impl Drop for ChildGuard {
    fn drop(&mut self) {
        if let Some(a) = self.active.take() {
            let tracer = crate::obs::tracer();
            tracer.add_span_with_id(
                a.ctx.trace,
                Some(a.parent),
                a.ctx.span,
                a.stage,
                a.t_start_ns,
                tracer.now_ns(),
            );
            CURRENT.with(|c| c.set(a.prev));
        }
    }
}

/// Start a root trace on the global tracer if the thread is not already
/// inside one — lets the standalone engine path get stage attribution
/// without a router in front.  Ends the trace when the guard drops.
pub fn root_if_unattached(stage: &'static str, tenant: Option<u32>) -> RootGuard {
    let tracer = crate::obs::tracer();
    if !tracer.enabled() || current().is_some() {
        return RootGuard { active: None };
    }
    let now = tracer.now_ns();
    let Some(ctx) = tracer.begin_trace(stage, tenant, now) else {
        return RootGuard { active: None };
    };
    let prev = CURRENT.with(|c| c.replace(Some(ctx)));
    RootGuard {
        active: Some(RootActive { ctx, prev }),
    }
}

#[derive(Debug)]
struct RootActive {
    ctx: TraceCtx,
    prev: Option<TraceCtx>,
}

#[derive(Debug)]
pub struct RootGuard {
    active: Option<RootActive>,
}

impl Drop for RootGuard {
    fn drop(&mut self) {
        if let Some(a) = self.active.take() {
            let tracer = crate::obs::tracer();
            tracer.end_trace(a.ctx, tracer.now_ns());
            CURRENT.with(|c| c.set(a.prev));
        }
    }
}

/// Record already-measured serve stages as children of the current
/// context, laid back-to-back ending at "now".  The engine measures its
/// stage durations itself (`QueryRecord`); this projects them into the
/// trace without double instrumentation.  No-op unless the global
/// tracer is enabled and the thread carries a context.
pub fn emit_stages_ending_now(stages: &[(&'static str, f64)]) {
    let tracer = crate::obs::tracer();
    if !tracer.enabled() {
        return;
    }
    let Some(ctx) = current() else {
        return;
    };
    let mut cursor = tracer.now_ns();
    for (stage, ms) in stages.iter().rev() {
        if *ms <= 0.0 {
            continue;
        }
        let ns = ((*ms * 1e6).round() as u64).max(1);
        let start = cursor.saturating_sub(ns);
        tracer.add_span(ctx.trace, Some(ctx.span), stage, start, cursor);
        cursor = start;
    }
}

// ---------------------------------------------------------------------------
// Dump parsing + attribution (the `percache trace` analyzer core)
// ---------------------------------------------------------------------------

/// One trace parsed back out of a `percache.trace/v1` dump.
#[derive(Debug, Clone)]
pub struct DumpEntry {
    pub kind: String,
    pub e2e_ms: f64,
    pub trace: Trace,
}

/// Parse the `traces` array of a dump document.
pub fn parse_dump(doc: &Json) -> Result<Vec<DumpEntry>, String> {
    let traces = doc
        .get("traces")
        .as_arr()
        .ok_or_else(|| "dump has no 'traces' array".to_string())?;
    let mut out = Vec::new();
    for t in traces {
        let id = t
            .get("trace")
            .as_f64()
            .ok_or_else(|| "trace entry missing 'trace' id".to_string())? as u64;
        let tenant = t.get("tenant").as_f64().map(|v| v as u32);
        let kind = t.get("kind").as_str().unwrap_or("tail").to_string();
        let e2e_ms = t.get("e2e_ms").as_f64().unwrap_or(0.0);
        let mut spans = Vec::new();
        for s in t.get("spans").as_arr().unwrap_or(&[]) {
            spans.push(SpanRecord {
                span: s.get("span").as_f64().unwrap_or(0.0) as u64,
                parent: s.get("parent").as_f64().map(|v| v as u64),
                stage: s.get("stage").as_str().unwrap_or("?").to_string(),
                t_start_ns: s.get("t_start_ns").as_f64().unwrap_or(0.0) as u64,
                t_end_ns: s.get("t_end_ns").as_f64().unwrap_or(0.0) as u64,
            });
        }
        out.push(DumpEntry {
            kind,
            e2e_ms,
            trace: Trace {
                trace: id,
                tenant,
                spans,
            },
        });
    }
    Ok(out)
}

/// Per-trace stage attribution: self time (duration minus children) per
/// stage name, plus the root time no child covered.
#[derive(Debug, Clone)]
pub struct Attribution {
    pub trace: u64,
    pub tenant: Option<u32>,
    pub e2e_ms: f64,
    /// Per-stage self-time in ms, sorted by stage name.
    pub stages: Vec<(String, f64)>,
    pub unattributed_ms: f64,
}

impl Attribution {
    pub fn unattributed_frac(&self) -> f64 {
        if self.e2e_ms <= 0.0 {
            0.0
        } else {
            self.unattributed_ms / self.e2e_ms
        }
    }
}

/// Attribute a trace's end-to-end time to its stages by self time.
/// Spans whose parent id does not resolve within the trace are adopted
/// by the root so their time is never lost.  Returns `None` for a
/// trace with no spans.
pub fn attribute(trace: &Trace) -> Option<Attribution> {
    let root = trace.spans.first()?;
    let root_id = root.span;
    let ids: BTreeSet<u64> = trace.spans.iter().map(|s| s.span).collect();
    let mut child_sum: BTreeMap<u64, u64> = BTreeMap::new();
    for s in trace.spans.iter().skip(1) {
        let parent = match s.parent {
            Some(p) if ids.contains(&p) => p,
            _ => root_id,
        };
        *child_sum.entry(parent).or_insert(0) += dur_ns(s);
    }
    let mut stages: BTreeMap<String, u64> = BTreeMap::new();
    for s in trace.spans.iter().skip(1) {
        let own = dur_ns(s);
        let children = child_sum.get(&s.span).copied().unwrap_or(0).min(own);
        *stages.entry(s.stage.clone()).or_insert(0) += own - children;
    }
    let root_dur = dur_ns(root);
    let covered = child_sum.get(&root_id).copied().unwrap_or(0).min(root_dur);
    Some(Attribution {
        trace: trace.trace,
        tenant: trace.tenant,
        e2e_ms: root_dur as f64 / 1e6,
        stages: stages
            .into_iter()
            .map(|(k, v)| (k, v as f64 / 1e6))
            .collect(),
        unattributed_ms: (root_dur - covered) as f64 / 1e6,
    })
}

fn dur_ns(s: &SpanRecord) -> u64 {
    s.t_end_ns.saturating_sub(s.t_start_ns)
}

/// One row of the per-stage attribution table.
#[derive(Debug, Clone)]
pub struct StageRow {
    pub stage: String,
    pub count: usize,
    pub total_ms: f64,
    pub p50_ms: f64,
    pub p_hi_ms: f64,
    /// Share of the summed end-to-end time across all traces.
    pub frac: f64,
}

/// Aggregate attributions into per-stage rows (sorted by total time,
/// largest first).  `p_hi` is the tail percentile column (e.g. 99).
pub fn stage_rows(atts: &[Attribution], p_hi: f64) -> Vec<StageRow> {
    let mut per_stage: BTreeMap<&str, Vec<f64>> = BTreeMap::new();
    let mut e2e_total = 0.0;
    for a in atts {
        e2e_total += a.e2e_ms;
        for (stage, ms) in &a.stages {
            per_stage.entry(stage.as_str()).or_default().push(*ms);
        }
    }
    let mut rows = Vec::new();
    for (stage, mut ms) in per_stage {
        ms.sort_by(f64::total_cmp);
        let total: f64 = ms.iter().sum();
        rows.push(StageRow {
            stage: stage.to_string(),
            count: ms.len(),
            total_ms: total,
            p50_ms: crate::util::bench::percentile(&ms, 50.0),
            p_hi_ms: crate::util::bench::percentile(&ms, p_hi),
            frac: if e2e_total > 0.0 { total / e2e_total } else { 0.0 },
        });
    }
    rows.sort_by(|a, b| b.total_ms.total_cmp(&a.total_ms));
    rows
}

/// Human-readable critical-path line for one trace, e.g.
/// `trace 17 (tenant 2, 41.03ms): 71% hydration_stall + 22% queue_wait`.
pub fn critical_path_line(a: &Attribution) -> String {
    let mut parts = a.stages.clone();
    parts.sort_by(|x, y| y.1.total_cmp(&x.1));
    let mut segs = Vec::new();
    for (stage, ms) in parts.iter().take(3) {
        if *ms <= 0.0 {
            break;
        }
        let pct = if a.e2e_ms > 0.0 {
            ms / a.e2e_ms * 100.0
        } else {
            0.0
        };
        segs.push(format!("{pct:.0}% {stage}"));
    }
    if segs.is_empty() {
        segs.push("100% unattributed".to_string());
    }
    let tenant = a
        .tenant
        .map(|t| t.to_string())
        .unwrap_or_else(|| "-".to_string());
    format!(
        "trace {} (tenant {}, {:.2}ms): {} (unattributed {:.0}%)",
        a.trace,
        tenant,
        a.e2e_ms,
        segs.join(" + "),
        a.unattributed_frac() * 100.0
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ms_ns(ms: f64) -> u64 {
        (ms * 1e6).round() as u64
    }

    /// Local tracer, virtual clock, sample everything — never touches
    /// the global tracer (parallel tests share it).
    fn local_tracer() -> Tracer {
        let t = Tracer::new();
        t.set_enabled(true);
        t.set_sample_every(1);
        t.set_virtual_clock(true);
        t
    }

    #[test]
    fn disabled_tracer_admits_nothing() {
        let t = Tracer::new();
        assert!(t.begin_trace("request", None, 0).is_none());
        assert_eq!(t.stats().started, 0);
        assert!(t.exemplars().is_empty());
    }

    #[test]
    fn sampling_admits_one_in_n() {
        let t = Tracer::new();
        t.set_enabled(true);
        t.set_sample_every(4);
        t.set_virtual_clock(true);
        let mut admitted = 0;
        for _ in 0..16 {
            if let Some(ctx) = t.begin_trace("request", None, 0) {
                admitted += 1;
                t.end_trace(ctx, 10);
            }
        }
        assert_eq!(admitted, 4);
        assert_eq!(t.stats().completed, 4);
    }

    #[test]
    fn span_tree_round_trips_through_dump() {
        let t = local_tracer();
        let ctx = t.begin_trace("request", Some(3), ms_ns(0.0)).expect("sampled");
        let queue = t
            .add_span(ctx.trace, Some(ctx.span), "queue_wait", ms_ns(0.0), ms_ns(2.0))
            .expect("span");
        t.add_span(ctx.trace, Some(queue), "queue_poll", ms_ns(1.0), ms_ns(2.0))
            .expect("span");
        t.end_trace(ctx, ms_ns(5.0));

        let dump = t.export_json();
        assert_eq!(dump.get("version").as_str(), Some(DUMP_VERSION));
        assert_eq!(dump.get("clock").as_str(), Some("virtual"));
        let entries = parse_dump(&dump).expect("parse");
        assert_eq!(entries.len(), 1);
        let trace = &entries[0].trace;
        assert_eq!(trace.tenant, Some(3));
        assert_eq!(trace.spans.len(), 3);
        // every non-root parent resolves
        let ids: Vec<u64> = trace.spans.iter().map(|s| s.span).collect();
        for s in trace.spans.iter().skip(1) {
            let p = s.parent.expect("non-root span has a parent");
            assert!(ids.contains(&p), "orphan span {}", s.span);
        }
    }

    #[test]
    fn attribution_self_time_and_unattributed_gap() {
        let t = local_tracer();
        let ctx = t.begin_trace("request", Some(0), 0).expect("sampled");
        // 10ms request: 4ms queue_wait, 5ms prefill (1ms of it slice_load)
        t.add_span(ctx.trace, Some(ctx.span), "queue_wait", 0, ms_ns(4.0));
        let pf = t
            .add_span(ctx.trace, Some(ctx.span), "prefill", ms_ns(4.0), ms_ns(9.0))
            .expect("span");
        t.add_span(ctx.trace, Some(pf), "slice_load", ms_ns(4.0), ms_ns(5.0));
        t.end_trace(ctx, ms_ns(10.0));

        let ex = t.exemplars();
        let a = attribute(&ex[0].trace).expect("attribution");
        let get = |name: &str| {
            a.stages
                .iter()
                .find(|(s, _)| s == name)
                .map(|(_, ms)| *ms)
                .unwrap_or(0.0)
        };
        assert!((get("queue_wait") - 4.0).abs() < 1e-9);
        assert!((get("prefill") - 4.0).abs() < 1e-9, "self time excludes child");
        assert!((get("slice_load") - 1.0).abs() < 1e-9);
        assert!((a.unattributed_ms - 1.0).abs() < 1e-9);
        assert!((a.unattributed_frac() - 0.1).abs() < 1e-9);
        let rows = stage_rows(&[a.clone()], 99.0);
        assert_eq!(rows[0].stage, "queue_wait");
        assert!(critical_path_line(&a).contains("queue_wait"));
    }

    #[test]
    fn orphan_spans_adopt_the_root() {
        let trace = Trace {
            trace: 1,
            tenant: None,
            spans: vec![
                SpanRecord {
                    span: 1,
                    parent: None,
                    stage: "request".into(),
                    t_start_ns: 0,
                    t_end_ns: ms_ns(10.0),
                },
                SpanRecord {
                    span: 2,
                    parent: Some(99), // never recorded
                    stage: "decode".into(),
                    t_start_ns: 0,
                    t_end_ns: ms_ns(6.0),
                },
            ],
        };
        let a = attribute(&trace).expect("attribution");
        assert!((a.unattributed_ms - 4.0).abs() < 1e-9);
    }

    #[test]
    fn cross_thread_spans_keep_parent_links() {
        let t = std::sync::Arc::new(local_tracer());
        let ctx = t.begin_trace("request", Some(1), 0).expect("sampled");
        let t2 = std::sync::Arc::clone(&t);
        std::thread::spawn(move || {
            t2.add_span(ctx.trace, Some(ctx.span), "hydration_stall", 0, ms_ns(3.0));
        })
        .join()
        .expect("worker");
        t.end_trace(ctx, ms_ns(4.0));
        let ex = t.exemplars();
        let a = attribute(&ex[0].trace).expect("attribution");
        assert_eq!(a.stages.len(), 1);
        assert!((a.stages[0].1 - 3.0).abs() < 1e-9);
    }

    #[test]
    fn open_table_overflow_counts_dropped() {
        let t = local_tracer();
        let mut held = Vec::new();
        for i in 0..(MAX_OPEN_TRACES as u64 + 5) {
            if let Some(ctx) = t.begin_trace("request", None, i) {
                held.push(ctx);
            }
        }
        assert_eq!(held.len(), MAX_OPEN_TRACES);
        assert_eq!(t.stats().dropped, 5);
    }

    #[test]
    fn chrome_export_is_deterministic_for_identical_runs() {
        let run = || {
            let t = local_tracer();
            for i in 0..10u64 {
                let ctx = t
                    .begin_trace("request", Some((i % 2) as u32), ms_ns(i as f64))
                    .expect("sampled");
                t.add_span(
                    ctx.trace,
                    Some(ctx.span),
                    "prefill",
                    ms_ns(i as f64),
                    ms_ns(i as f64 + 1.5),
                );
                t.end_trace(ctx, ms_ns(i as f64 + 2.0));
            }
            t.export_chrome().to_string_pretty()
        };
        let a = run();
        assert_eq!(a, run(), "chrome export not byte-stable");
        assert!(a.contains("\"ph\": \"X\""));
        assert!(a.contains("\"name\": \"prefill\""));
    }
}
