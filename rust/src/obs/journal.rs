//! Bounded lock-striped ring-buffer event journal (DESIGN.md §12).
//!
//! Instrumentation sites emit structured [`Event`]s (tenant demoted,
//! hydration finished with its stall, admission rejected with a reason,
//! governor rebalance with per-shard deltas, checkpoint written, slice
//! evicted).  The journal stamps each one with a global sequence number
//! and a relative timestamp, then appends it to one of
//! [`JOURNAL_STRIPES`] independently-locked rings so concurrent
//! emitters rarely contend.  Overflow drops the oldest record in the
//! stripe and counts it — the journal is a flight recorder, never a
//! backpressure source.
//!
//! With `--verbose` the journal also echoes each record to stderr,
//! which replaces the ad-hoc `println!`/`eprintln!` diagnostics the
//! tiering and tenancy layers used to carry.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::util::json::Json;

/// Number of independently-locked rings; emitters hash by sequence
/// number, so bursts spread across stripes instead of serializing.
pub const JOURNAL_STRIPES: usize = 8;

/// Default total capacity across all stripes.
pub const DEFAULT_CAPACITY: usize = 1024;

/// Causal-trace linkage carried by an event: which trace/span the
/// emitting stage ran under and its parent span (DESIGN.md §16).
/// Span events used to be flat name-only records; with this attached
/// they can be joined back onto the request tree they belong to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceRef {
    pub trace: u64,
    pub span: u64,
    pub parent: Option<u64>,
}

/// One structured event as built at an instrumentation site.
#[derive(Debug, Clone)]
pub struct Event {
    pub kind: &'static str,
    pub tenant: Option<usize>,
    pub fields: Vec<(String, f64)>,
    pub msg: String,
    pub trace: Option<TraceRef>,
}

impl Event {
    pub fn new(kind: &'static str) -> Self {
        Event {
            kind,
            tenant: None,
            fields: Vec::new(),
            msg: String::new(),
            trace: None,
        }
    }

    pub fn tenant(mut self, t: usize) -> Self {
        self.tenant = Some(t);
        self
    }

    pub fn trace_ref(mut self, r: TraceRef) -> Self {
        self.trace = Some(r);
        self
    }

    pub fn field(mut self, key: &str, value: f64) -> Self {
        self.fields.push((key.to_string(), value));
        self
    }

    pub fn msg(mut self, m: impl Into<String>) -> Self {
        self.msg = m.into();
        self
    }
}

/// A journaled event: an [`Event`] plus its sequence number and the
/// milliseconds since the journal was created.
#[derive(Debug, Clone, PartialEq)]
pub struct EventRecord {
    pub seq: u64,
    pub t_ms: f64,
    pub kind: String,
    pub tenant: Option<usize>,
    pub fields: Vec<(String, f64)>,
    pub msg: String,
    pub trace: Option<TraceRef>,
}

impl EventRecord {
    /// Single-line rendering for the `--verbose` stderr tail.
    pub fn render(&self) -> String {
        let mut s = format!("[obs] #{} +{:.1}ms {}", self.seq, self.t_ms, self.kind);
        if let Some(t) = self.tenant {
            s.push_str(&format!(" tenant={t}"));
        }
        for (k, v) in &self.fields {
            s.push_str(&format!(" {k}={v:.3}"));
        }
        if let Some(tr) = self.trace {
            s.push_str(&format!(" trace={} span={}", tr.trace, tr.span));
            if let Some(p) = tr.parent {
                s.push_str(&format!(" parent={p}"));
            }
        }
        if !self.msg.is_empty() {
            s.push_str(&format!(" — {}", self.msg));
        }
        s
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.insert("seq", self.seq);
        o.insert("t_ms", self.t_ms);
        o.insert("kind", self.kind.as_str());
        if let Some(t) = self.tenant {
            o.insert("tenant", t);
        }
        let mut fields = Json::obj();
        for (k, v) in &self.fields {
            fields.insert(k.as_str(), *v);
        }
        o.insert("fields", fields);
        if !self.msg.is_empty() {
            o.insert("msg", self.msg.as_str());
        }
        if let Some(tr) = self.trace {
            o.insert("trace", tr.trace);
            o.insert("span", tr.span);
            if let Some(p) = tr.parent {
                o.insert("parent", p);
            }
        }
        Json::Obj(o)
    }

    pub fn from_json(j: &Json) -> Result<EventRecord> {
        let seq = j.get("seq").as_i64().context("event record: seq")? as u64;
        let t_ms = j.get("t_ms").as_f64().context("event record: t_ms")?;
        let kind = j
            .get("kind")
            .as_str()
            .context("event record: kind")?
            .to_string();
        let tenant = j.get("tenant").as_usize();
        let mut fields = Vec::new();
        if let Some(o) = j.get("fields").as_obj() {
            for (k, v) in o.iter() {
                fields.push((k.to_string(), v.as_f64().context("event field")?));
            }
        }
        let msg = j.get("msg").as_str().unwrap_or("").to_string();
        let trace = j.get("trace").as_i64().map(|t| TraceRef {
            trace: t as u64,
            span: j.get("span").as_i64().unwrap_or(0) as u64,
            parent: j.get("parent").as_i64().map(|p| p as u64),
        });
        Ok(EventRecord {
            seq,
            t_ms,
            kind,
            tenant,
            fields,
            msg,
            trace,
        })
    }
}

/// The journal itself.  All configuration lives in atomics so emitters
/// never take a lock just to discover the journal is quiet.
pub struct Journal {
    start: Instant,
    seq: AtomicU64,
    echo: AtomicBool,
    trace_spans: AtomicBool,
    cap_per_stripe: AtomicUsize,
    stripes: Vec<Mutex<VecDeque<EventRecord>>>,
    dropped: AtomicU64,
}

impl Default for Journal {
    fn default() -> Self {
        Journal {
            start: Instant::now(),
            seq: AtomicU64::new(0),
            echo: AtomicBool::new(false),
            trace_spans: AtomicBool::new(false),
            cap_per_stripe: AtomicUsize::new((DEFAULT_CAPACITY / JOURNAL_STRIPES).max(1)),
            stripes: (0..JOURNAL_STRIPES)
                .map(|_| Mutex::new(VecDeque::new()))
                .collect(),
            dropped: AtomicU64::new(0),
        }
    }
}

impl Journal {
    pub fn new() -> Self {
        Journal::default()
    }

    /// Echo every record to stderr (the `--verbose` tail).
    pub fn set_echo(&self, on: bool) {
        self.echo.store(on, Ordering::Relaxed);
    }

    pub fn echo(&self) -> bool {
        self.echo.load(Ordering::Relaxed)
    }

    /// Also journal span completions (noisy; tied to `--verbose`).
    pub fn set_trace_spans(&self, on: bool) {
        self.trace_spans.store(on, Ordering::Relaxed);
    }

    pub fn trace_spans(&self) -> bool {
        self.trace_spans.load(Ordering::Relaxed)
    }

    /// Resize the total capacity (split evenly across stripes).
    pub fn set_capacity(&self, total: usize) {
        self.cap_per_stripe
            .store((total / JOURNAL_STRIPES).max(1), Ordering::Relaxed);
    }

    /// Records dropped to stay within capacity.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Total sequence numbers handed out (= events ever emitted).
    pub fn emitted(&self) -> u64 {
        self.seq.load(Ordering::Relaxed)
    }

    /// Stamp and append one event.
    pub fn emit(&self, ev: Event) {
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        let rec = EventRecord {
            seq,
            t_ms: self.start.elapsed().as_secs_f64() * 1e3,
            kind: ev.kind.to_string(),
            tenant: ev.tenant,
            fields: ev.fields,
            msg: ev.msg,
            trace: ev.trace,
        };
        if self.echo() {
            eprintln!("{}", rec.render());
        }
        let cap = self.cap_per_stripe.load(Ordering::Relaxed);
        let mut ring = crate::util::sync::lock_or_recover(
            // percache-allow(panic_path): index is modulo JOURNAL_STRIPES, the fixed length of `stripes`
            &self.stripes[seq as usize % JOURNAL_STRIPES],
        );
        ring.push_back(rec);
        while ring.len() > cap {
            ring.pop_front();
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Copy of every retained record, in emission order.
    pub fn snapshot_events(&self) -> Vec<EventRecord> {
        let mut out = Vec::new();
        for stripe in &self.stripes {
            let ring = crate::util::sync::lock_or_recover(stripe);
            out.extend(ring.iter().cloned());
        }
        out.sort_by_key(|r| r.seq);
        out
    }

    /// Drain every retained record, in emission order.
    pub fn drain(&self) -> Vec<EventRecord> {
        let mut out = Vec::new();
        for stripe in &self.stripes {
            let mut ring = crate::util::sync::lock_or_recover(stripe);
            out.extend(ring.drain(..));
        }
        out.sort_by_key(|r| r.seq);
        out
    }

    /// Retained records as a JSON array (newest state, debugging dumps).
    pub fn to_json(&self) -> Json {
        Json::Arr(self.snapshot_events().iter().map(|r| r.to_json()).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn emits_in_sequence_and_drains() {
        let j = Journal::new();
        j.emit(Event::new("a").tenant(1).field("x", 2.5));
        j.emit(Event::new("b").msg("hello"));
        let recs = j.drain();
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[0].seq, 0);
        assert_eq!(recs[0].kind, "a");
        assert_eq!(recs[0].tenant, Some(1));
        assert_eq!(recs[0].fields, vec![("x".to_string(), 2.5)]);
        assert_eq!(recs[1].kind, "b");
        assert_eq!(recs[1].msg, "hello");
        assert!(j.drain().is_empty(), "drain must empty the journal");
        assert_eq!(j.emitted(), 2);
    }

    #[test]
    fn overflow_drops_oldest_and_counts() {
        let j = Journal::new();
        j.set_capacity(JOURNAL_STRIPES); // one record per stripe
        for _ in 0..4 * JOURNAL_STRIPES {
            j.emit(Event::new("tick"));
        }
        let recs = j.snapshot_events();
        assert_eq!(recs.len(), JOURNAL_STRIPES);
        assert_eq!(j.dropped(), 3 * JOURNAL_STRIPES as u64);
        // the survivors are the newest record in each stripe
        assert!(recs.iter().all(|r| r.seq >= 3 * JOURNAL_STRIPES as u64));
    }

    #[test]
    fn record_json_round_trip() {
        let j = Journal::new();
        j.emit(
            Event::new("governor.rebalance")
                .tenant(3)
                .field("delta_bytes", -4096.0)
                .field("utility", 0.125)
                .msg("shrink before grow"),
        );
        let rec = j.drain().remove(0);
        let parsed = Json::parse(&rec.to_json().to_string()).unwrap();
        let back = EventRecord::from_json(&parsed).unwrap();
        assert_eq!(back, rec);
    }

    #[test]
    fn trace_ref_round_trips_and_renders() {
        let j = Journal::new();
        j.emit(
            Event::new("span").field("ms", 1.25).msg("prefill").trace_ref(TraceRef {
                trace: 11,
                span: 12,
                parent: Some(10),
            }),
        );
        let rec = j.drain().remove(0);
        assert_eq!(
            rec.trace,
            Some(TraceRef {
                trace: 11,
                span: 12,
                parent: Some(10)
            })
        );
        let line = rec.render();
        assert!(line.contains("trace=11"));
        assert!(line.contains("span=12"));
        assert!(line.contains("parent=10"));
        let parsed = Json::parse(&rec.to_json().to_string()).unwrap();
        let back = EventRecord::from_json(&parsed).unwrap();
        assert_eq!(back, rec);
        // a ref without a parent (root span) also survives the trip
        j.emit(Event::new("span").trace_ref(TraceRef {
            trace: 3,
            span: 4,
            parent: None,
        }));
        let rec = j.drain().remove(0);
        let back = EventRecord::from_json(&Json::parse(&rec.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back.trace, rec.trace);
    }

    #[test]
    fn render_mentions_kind_tenant_and_fields() {
        let j = Journal::new();
        j.emit(Event::new("tenant.demoted").tenant(7).field("freed", 123.0));
        let line = j.snapshot_events()[0].render();
        assert!(line.contains("tenant.demoted"));
        assert!(line.contains("tenant=7"));
        assert!(line.contains("freed=123.000"));
    }
}
