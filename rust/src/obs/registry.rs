//! The metrics registry: named metric lookup, cheap cloneable handles,
//! and span-style stage tracing (DESIGN.md §12).
//!
//! Call sites resolve a metric once (`obs::counter("router.admitted")`)
//! and keep the returned handle; every later `inc()` is one relaxed
//! atomic load (the enabled check) plus one relaxed `fetch_add`.  A
//! disabled registry therefore costs a few nanoseconds per call site.
//!
//! Metric names are dot-separated `layer.metric` (e.g.
//! `tiering.hydration_stall_ms`); labels are sorted key/value pairs so
//! `router.rejected{reason="queue_full"}` and its sibling reasons are
//! distinct series under one family name.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, RwLock};
use std::time::Instant;

use super::journal::{Event, Journal, TraceRef};
use super::metric::{Counter, Gauge, Histogram};
use super::trace::{ChildGuard, TraceCtx};

/// A metric series identity: family name plus sorted labels.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct MetricKey {
    pub name: String,
    pub labels: Vec<(String, String)>,
}

impl MetricKey {
    pub fn plain(name: &str) -> Self {
        MetricKey {
            name: name.to_string(),
            labels: Vec::new(),
        }
    }

    pub fn labeled(name: &str, labels: &[(&str, &str)]) -> Self {
        let mut labels: Vec<(String, String)> = labels
            .iter()
            .map(|(k, v)| (k.to_string(), v.to_string()))
            .collect();
        labels.sort();
        MetricKey {
            name: name.to_string(),
            labels,
        }
    }
}

/// Cloneable handle to one counter series.
#[derive(Clone)]
pub struct CounterHandle {
    enabled: Arc<AtomicBool>,
    ctr: Arc<Counter>,
}

impl CounterHandle {
    #[inline]
    pub fn inc(&self) {
        if self.enabled.load(Ordering::Relaxed) {
            self.ctr.inc();
        }
    }

    #[inline]
    pub fn add(&self, n: u64) {
        if self.enabled.load(Ordering::Relaxed) {
            self.ctr.add(n);
        }
    }

    pub fn get(&self) -> u64 {
        self.ctr.get()
    }
}

/// Cloneable handle to one gauge series.
#[derive(Clone)]
pub struct GaugeHandle {
    enabled: Arc<AtomicBool>,
    gauge: Arc<Gauge>,
}

impl GaugeHandle {
    #[inline]
    pub fn set(&self, v: i64) {
        if self.enabled.load(Ordering::Relaxed) {
            self.gauge.set(v);
        }
    }

    #[inline]
    pub fn add(&self, n: i64) {
        if self.enabled.load(Ordering::Relaxed) {
            self.gauge.add(n);
        }
    }

    #[inline]
    pub fn sub(&self, n: i64) {
        if self.enabled.load(Ordering::Relaxed) {
            self.gauge.sub(n);
        }
    }

    pub fn get(&self) -> i64 {
        self.gauge.get()
    }
}

/// Cloneable handle to one histogram series.
#[derive(Clone)]
pub struct HistogramHandle {
    enabled: Arc<AtomicBool>,
    hist: Arc<Histogram>,
}

impl HistogramHandle {
    #[inline]
    pub fn record(&self, ms: f64) {
        if self.enabled.load(Ordering::Relaxed) {
            self.hist.record(ms);
        }
    }

    pub fn count(&self) -> u64 {
        self.hist.count()
    }

    pub fn quantile(&self, q: f64) -> f64 {
        self.hist.quantile(q)
    }
}

/// Times a stage, records the latency into a histogram on drop (or via
/// [`SpanGuard::finish`] when the caller wants the measured value), and
/// journals a `span` event when span tracing is on.  Generalizes
/// `metrics::Stage`, which measures but records nowhere.  When the
/// thread carries a causal-trace context the span also lands as a child
/// in the request's trace tree, and the journal event carries the
/// trace/span/parent ids instead of being a flat name-only record.
pub struct SpanGuard {
    start: Instant,
    name: &'static str,
    hist: HistogramHandle,
    journal: Arc<Journal>,
    trace: bool,
    done: bool,
    child: Option<ChildGuard>,
}

impl SpanGuard {
    /// Span on the global registry recorded as a trace child of an
    /// explicit context — for work that runs on a different thread from
    /// the request it serves (e.g. a hydration wait completed on behalf
    /// of a parked tenant), where the thread-local context is absent.
    pub fn child_of(name: &'static str, ctx: TraceCtx) -> SpanGuard {
        let reg = crate::obs::registry();
        SpanGuard {
            start: Instant::now(),
            name,
            hist: reg.histogram(name),
            journal: reg.journal().clone(),
            trace: reg.enabled() && reg.journal().trace_spans(),
            done: false,
            child: Some(crate::obs::trace::child_under(name, ctx)),
        }
    }

    /// Stop the span explicitly and return the elapsed milliseconds.
    pub fn finish(mut self) -> f64 {
        self.end()
    }

    fn end(&mut self) -> f64 {
        if self.done {
            return 0.0;
        }
        self.done = true;
        let ms = self.start.elapsed().as_secs_f64() * 1e3;
        self.hist.record(ms);
        let link = self.child.take();
        if self.trace {
            let mut ev = Event::new("span").field("ms", ms).msg(self.name);
            if let Some(guard) = &link {
                if let (Some(ctx), Some(parent)) = (guard.ctx(), guard.parent()) {
                    ev = ev.trace_ref(TraceRef {
                        trace: ctx.trace,
                        span: ctx.span,
                        parent: Some(parent),
                    });
                }
            }
            self.journal.emit(ev);
        }
        drop(link); // records the trace child span with its real end time
        ms
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        self.end();
    }
}

/// All metric series plus the event journal for one process (or one
/// test, which builds its own registry to stay isolated).
pub struct MetricsRegistry {
    enabled: Arc<AtomicBool>,
    start: Instant,
    counters: RwLock<BTreeMap<MetricKey, Arc<Counter>>>,
    gauges: RwLock<BTreeMap<MetricKey, Arc<Gauge>>>,
    hists: RwLock<BTreeMap<MetricKey, Arc<Histogram>>>,
    journal: Arc<Journal>,
}

impl Default for MetricsRegistry {
    fn default() -> Self {
        MetricsRegistry {
            enabled: Arc::new(AtomicBool::new(true)),
            start: Instant::now(),
            counters: RwLock::new(BTreeMap::new()),
            gauges: RwLock::new(BTreeMap::new()),
            hists: RwLock::new(BTreeMap::new()),
            journal: Arc::new(Journal::new()),
        }
    }
}

impl MetricsRegistry {
    pub fn new() -> Self {
        MetricsRegistry::default()
    }

    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// Milliseconds since the registry was created.
    pub fn uptime_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }

    pub fn journal(&self) -> &Arc<Journal> {
        &self.journal
    }

    /// Journal one event (no-op while the registry is disabled).
    pub fn emit(&self, ev: Event) {
        if self.enabled() {
            self.journal.emit(ev);
        }
    }

    pub fn counter(&self, name: &str) -> CounterHandle {
        self.counter_with(MetricKey::plain(name))
    }

    pub fn counter_labeled(&self, name: &str, labels: &[(&str, &str)]) -> CounterHandle {
        self.counter_with(MetricKey::labeled(name, labels))
    }

    fn counter_with(&self, key: MetricKey) -> CounterHandle {
        CounterHandle {
            enabled: self.enabled.clone(),
            ctr: lookup(&self.counters, key),
        }
    }

    pub fn gauge(&self, name: &str) -> GaugeHandle {
        self.gauge_with(MetricKey::plain(name))
    }

    pub fn gauge_labeled(&self, name: &str, labels: &[(&str, &str)]) -> GaugeHandle {
        self.gauge_with(MetricKey::labeled(name, labels))
    }

    fn gauge_with(&self, key: MetricKey) -> GaugeHandle {
        GaugeHandle {
            enabled: self.enabled.clone(),
            gauge: lookup(&self.gauges, key),
        }
    }

    pub fn histogram(&self, name: &str) -> HistogramHandle {
        self.histogram_with(MetricKey::plain(name))
    }

    pub fn histogram_labeled(&self, name: &str, labels: &[(&str, &str)]) -> HistogramHandle {
        self.histogram_with(MetricKey::labeled(name, labels))
    }

    fn histogram_with(&self, key: MetricKey) -> HistogramHandle {
        HistogramHandle {
            enabled: self.enabled.clone(),
            hist: lookup(&self.hists, key),
        }
    }

    /// Start timing a stage; the latency lands in histogram `name` when
    /// the guard drops (or `finish()`es).
    pub fn span(&self, name: &'static str) -> SpanGuard {
        SpanGuard {
            start: Instant::now(),
            name,
            hist: self.histogram(name),
            journal: self.journal.clone(),
            trace: self.enabled() && self.journal.trace_spans(),
            done: false,
            // inert unless the thread is inside a traced request
            child: Some(crate::obs::trace::child(name)),
        }
    }

    /// Visit every series (snapshot/exposition walks).
    pub fn visit(
        &self,
        mut on_counter: impl FnMut(&MetricKey, &Counter),
        mut on_gauge: impl FnMut(&MetricKey, &Gauge),
        mut on_hist: impl FnMut(&MetricKey, &Histogram),
    ) {
        for (k, c) in crate::util::sync::read_or_recover(&self.counters).iter() {
            on_counter(k, c);
        }
        for (k, g) in crate::util::sync::read_or_recover(&self.gauges).iter() {
            on_gauge(k, g);
        }
        for (k, h) in crate::util::sync::read_or_recover(&self.hists).iter() {
            on_hist(k, h);
        }
    }
}

/// Get-or-create under a read-mostly lock: the fast path is a shared
/// read; only a genuinely new series takes the write lock.
fn lookup<T: Default>(map: &RwLock<BTreeMap<MetricKey, Arc<T>>>, key: MetricKey) -> Arc<T> {
    if let Some(v) = crate::util::sync::read_or_recover(map).get(&key) {
        return v.clone();
    }
    crate::util::sync::write_or_recover(map)
        .entry(key)
        .or_default()
        .clone()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_name_shares_one_series() {
        let r = MetricsRegistry::new();
        r.counter("a.b").inc();
        r.counter("a.b").add(2);
        assert_eq!(r.counter("a.b").get(), 3);
        r.counter_labeled("a.b", &[("t", "0")]).inc();
        assert_eq!(r.counter("a.b").get(), 3, "labels split the series");
        assert_eq!(r.counter_labeled("a.b", &[("t", "0")]).get(), 1);
    }

    #[test]
    fn disabled_registry_records_nothing() {
        let r = MetricsRegistry::new();
        let c = r.counter("x");
        let g = r.gauge("y");
        let h = r.histogram("z");
        r.set_enabled(false);
        c.inc();
        g.set(5);
        h.record(1.0);
        r.emit(Event::new("quiet"));
        assert_eq!(c.get(), 0);
        assert_eq!(g.get(), 0);
        assert_eq!(h.count(), 0);
        assert_eq!(r.journal().emitted(), 0);
        r.set_enabled(true);
        c.inc();
        assert_eq!(c.get(), 1, "existing handles observe re-enable");
    }

    #[test]
    fn span_records_latency_into_histogram() {
        let r = MetricsRegistry::new();
        let ms = r.span("stage.test_ms").finish();
        assert!(ms >= 0.0);
        assert_eq!(r.histogram("stage.test_ms").count(), 1);
        {
            let _g = r.span("stage.test_ms");
        } // drop path
        assert_eq!(r.histogram("stage.test_ms").count(), 2);
    }

    #[test]
    fn span_tracing_journals_when_enabled() {
        let r = MetricsRegistry::new();
        r.span("quiet_ms").finish();
        assert_eq!(r.journal().emitted(), 0);
        r.journal().set_trace_spans(true);
        r.span("loud_ms").finish();
        let recs = r.journal().drain();
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].kind, "span");
        assert_eq!(recs[0].msg, "loud_ms");
    }

    #[test]
    fn label_order_is_canonical() {
        let a = MetricKey::labeled("m", &[("b", "2"), ("a", "1")]);
        let b = MetricKey::labeled("m", &[("a", "1"), ("b", "2")]);
        assert_eq!(a, b);
    }
}
