//! Lock-free metric primitives: counters, gauges, and log-scale
//! fixed-bucket histograms (DESIGN.md §12).
//!
//! Everything here is a plain bag of atomics, so handles can be cloned
//! into hot loops and bumped with `Ordering::Relaxed` operations: no
//! locks, no allocation, no syscalls on the record path.

use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::OnceLock;

/// Number of histogram buckets: half-powers of two from ~1.4 µs to
/// ~268 s, which spans everything the serving stack records (stage
/// latencies, queue waits, hydration stalls).
pub const N_BUCKETS: usize = 56;

/// Inclusive upper bound of each bucket, in milliseconds:
/// `bounds[i] = 1e-3 · 2^((i + 1) / 2)`.  Consecutive bounds differ by
/// a factor of √2, so a quantile estimate taken from a bucket's
/// midpoint is always within one bucket width of the exact value.
pub fn bucket_bounds() -> &'static [f64; N_BUCKETS] {
    static BOUNDS: OnceLock<[f64; N_BUCKETS]> = OnceLock::new();
    BOUNDS.get_or_init(|| std::array::from_fn(|i| 1e-3 * 2f64.powf((i as f64 + 1.0) / 2.0)))
}

/// Bucket index for a recorded value.  Bucket `i` covers
/// `(bounds[i-1], bounds[i]]`; NaN and tiny values land in bucket 0,
/// +inf and huge values in the last bucket.
pub fn bucket_index(v: f64) -> usize {
    let bounds = bucket_bounds();
    if v.is_nan() || v <= bounds[0] {
        return 0;
    }
    bounds.partition_point(|&u| u < v).min(N_BUCKETS - 1)
}

/// Geometric midpoint of bucket `i` — guaranteed to lie inside the
/// bucket, so quantile estimates built from it inherit the one-bucket
/// error bound.
pub fn representative(i: usize) -> f64 {
    let bounds = bucket_bounds();
    if i == 0 {
        bounds[0]
    } else {
        // percache-allow(panic_path): callers pass bucket indices < N_BUCKETS (array length) by construction
        (bounds[i - 1] * bounds[i]).sqrt()
    }
}

/// Monotone counter.
#[derive(Debug, Default)]
pub struct Counter {
    value: AtomicU64,
}

impl Counter {
    pub fn new() -> Self {
        Counter::default()
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.value.fetch_add(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// Signed gauge (resident bytes, queue depth, residency state, ...).
#[derive(Debug, Default)]
pub struct Gauge {
    value: AtomicI64,
}

impl Gauge {
    pub fn new() -> Self {
        Gauge::default()
    }

    #[inline]
    pub fn set(&self, v: i64) {
        self.value.store(v, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, n: i64) {
        self.value.fetch_add(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn sub(&self, n: i64) {
        self.value.fetch_sub(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// Fixed-bucket log-scale histogram of millisecond samples.
///
/// The sum is kept in integer nanoseconds so concurrent recorders never
/// need a CAS loop over a float and never lose fractional mass.
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; N_BUCKETS],
    count: AtomicU64,
    sum_nanos: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_nanos: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram::default()
    }

    /// Record one sample, in milliseconds.
    #[inline]
    pub fn record(&self, ms: f64) {
        // bucket_index clamps to N_BUCKETS - 1; .get() keeps the hot
        // path panic-free even if that invariant ever regresses
        if let Some(b) = self.buckets.get(bucket_index(ms)) {
            b.fetch_add(1, Ordering::Relaxed);
        }
        self.count.fetch_add(1, Ordering::Relaxed);
        if ms.is_finite() && ms > 0.0 {
            self.sum_nanos
                .fetch_add((ms * 1e6).round() as u64, Ordering::Relaxed);
        }
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Sum of all recorded samples, in milliseconds.
    pub fn sum_ms(&self) -> f64 {
        self.sum_nanos.load(Ordering::Relaxed) as f64 / 1e6
    }

    /// Point-in-time copy of the per-bucket counts.
    pub fn bucket_counts(&self) -> [u64; N_BUCKETS] {
        // percache-allow(panic_path): from_fn indices are < N_BUCKETS, the array length, by construction
        std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed))
    }

    /// Nearest-rank quantile estimate for `q` in `[0, 1]`, using each
    /// bucket's geometric midpoint as its representative value.
    pub fn quantile(&self, q: f64) -> f64 {
        quantile_from_buckets(&self.bucket_counts(), q)
    }
}

/// Nearest-rank quantile over a bucket-count vector (shared between the
/// live histogram and its serialized snapshot form).
pub fn quantile_from_buckets(counts: &[u64; N_BUCKETS], q: f64) -> f64 {
    let total: u64 = counts.iter().sum();
    if total == 0 {
        return 0.0;
    }
    let target = ((q * total as f64).ceil() as u64).clamp(1, total);
    let mut seen = 0u64;
    for (i, &c) in counts.iter().enumerate() {
        seen += c;
        if seen >= target {
            return representative(i);
        }
    }
    representative(N_BUCKETS - 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bounds_are_strictly_increasing_half_powers() {
        let b = bucket_bounds();
        for i in 1..N_BUCKETS {
            assert!(b[i] > b[i - 1]);
            let ratio = b[i] / b[i - 1];
            assert!((ratio - 2f64.sqrt()).abs() < 1e-12, "ratio {ratio}");
        }
        assert!(b[0] < 2e-3, "lowest bound must be ~µs scale");
        assert!(b[N_BUCKETS - 1] > 1e5, "highest bound must exceed 100 s");
    }

    #[test]
    fn bucket_index_edges() {
        let b = bucket_bounds();
        assert_eq!(bucket_index(0.0), 0);
        assert_eq!(bucket_index(-1.0), 0);
        assert_eq!(bucket_index(f64::NAN), 0);
        assert_eq!(bucket_index(b[0]), 0);
        assert_eq!(bucket_index(b[3]), 3, "upper bound is inclusive");
        assert_eq!(bucket_index(b[3] * 1.0001), 4);
        assert_eq!(bucket_index(f64::INFINITY), N_BUCKETS - 1);
        assert_eq!(bucket_index(1e12), N_BUCKETS - 1);
    }

    #[test]
    fn counter_and_gauge_basics() {
        let c = Counter::new();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        let g = Gauge::new();
        g.set(10);
        g.add(5);
        g.sub(7);
        assert_eq!(g.get(), 8);
    }

    #[test]
    fn histogram_counts_and_sum() {
        let h = Histogram::new();
        h.record(1.0);
        h.record(2.0);
        h.record(4.0);
        assert_eq!(h.count(), 3);
        assert!((h.sum_ms() - 7.0).abs() < 1e-6);
        let counts = h.bucket_counts();
        assert_eq!(counts.iter().sum::<u64>(), 3);
    }

    #[test]
    fn quantile_of_empty_is_zero() {
        assert_eq!(Histogram::new().quantile(0.5), 0.0);
    }
}
