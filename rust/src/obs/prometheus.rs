//! Prometheus text-format exposition for a [`MetricsSnapshot`]
//! (DESIGN.md §12).
//!
//! Dotted metric names become underscore-mangled families under the
//! `percache_` prefix: `router.wait_ms` → `percache_router_wait_ms`.
//! Counters get the conventional `_total` suffix, histograms expand to
//! the cumulative `_bucket{le=...}` / `_sum` / `_count` triplet, and
//! labels render sorted so the output is byte-stable for tests.

use std::fmt::Write as _;

use super::metric::bucket_bounds;
use super::snapshot::MetricsSnapshot;

/// `router.wait_ms` → `percache_router_wait_ms`.
pub fn family_name(name: &str) -> String {
    let mangled: String = name
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() { c } else { '_' })
        .collect();
    format!("percache_{mangled}")
}

fn label_block(labels: &[(String, String)], extra: Option<(&str, &str)>) -> String {
    let mut parts: Vec<String> = labels
        .iter()
        .map(|(k, v)| format!("{k}=\"{}\"", v.replace('\\', "\\\\").replace('"', "\\\"")))
        .collect();
    if let Some((k, v)) = extra {
        parts.push(format!("{k}=\"{v}\""));
    }
    if parts.is_empty() {
        String::new()
    } else {
        format!("{{{}}}", parts.join(","))
    }
}

fn fmt_bound(b: f64) -> String {
    format!("{b:.6}")
}

/// Encode a snapshot in the Prometheus text exposition format.
pub fn encode(snap: &MetricsSnapshot) -> String {
    let mut out = String::new();
    let mut last_family = String::new();
    let mut type_line = |out: &mut String, fam: &str, kind: &str| {
        if fam != last_family {
            let _ = writeln!(out, "# TYPE {fam} {kind}");
            last_family = fam.to_string();
        }
    };

    for c in &snap.counters {
        let fam = format!("{}_total", family_name(&c.name));
        type_line(&mut out, &fam, "counter");
        let _ = writeln!(out, "{fam}{} {}", label_block(&c.labels, None), c.value);
    }
    for g in &snap.gauges {
        let fam = family_name(&g.name);
        type_line(&mut out, &fam, "gauge");
        let _ = writeln!(out, "{fam}{} {}", label_block(&g.labels, None), g.value);
    }
    let bounds = bucket_bounds();
    for h in &snap.hists {
        let fam = family_name(&h.name);
        type_line(&mut out, &fam, "histogram");
        let mut cumulative = 0u64;
        for &(i, c) in &h.buckets {
            cumulative += c;
            // percache-allow(panic_path): index explicitly clamped to the last bound
            let le = fmt_bound(bounds[i.min(bounds.len() - 1)]);
            let _ = writeln!(
                out,
                "{fam}_bucket{} {cumulative}",
                label_block(&h.labels, Some(("le", &le)))
            );
        }
        let _ = writeln!(
            out,
            "{fam}_bucket{} {}",
            label_block(&h.labels, Some(("le", "+Inf"))),
            h.count
        );
        let _ = writeln!(
            out,
            "{fam}_sum{} {}",
            label_block(&h.labels, None),
            h.sum_ms
        );
        let _ = writeln!(
            out,
            "{fam}_count{} {}",
            label_block(&h.labels, None),
            h.count
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::registry::MetricsRegistry;

    #[test]
    fn mangles_names_under_prefix() {
        assert_eq!(family_name("router.wait_ms"), "percache_router_wait_ms");
        assert_eq!(family_name("a-b.c"), "percache_a_b_c");
    }

    #[test]
    fn encodes_all_three_kinds() {
        let r = MetricsRegistry::new();
        r.counter("router.admitted").add(7);
        r.counter_labeled("router.rejected", &[("reason", "queue_full")])
            .inc();
        r.gauge("router.queue_depth").set(3);
        r.histogram("router.wait_ms").record(2.0);
        let text = encode(&r.snapshot());
        assert!(text.contains("# TYPE percache_router_admitted_total counter"));
        assert!(text.contains("percache_router_admitted_total 7"));
        assert!(text.contains("percache_router_rejected_total{reason=\"queue_full\"} 1"));
        assert!(text.contains("# TYPE percache_router_queue_depth gauge"));
        assert!(text.contains("percache_router_queue_depth 3"));
        assert!(text.contains("# TYPE percache_router_wait_ms histogram"));
        assert!(text.contains("percache_router_wait_ms_bucket{le=\"+Inf\"} 1"));
        assert!(text.contains("percache_router_wait_ms_count 1"));
        assert!(text.contains("percache_router_wait_ms_sum 2"));
    }

    #[test]
    fn one_type_line_per_family() {
        let r = MetricsRegistry::new();
        r.counter_labeled("m.x", &[("t", "0")]).inc();
        r.counter_labeled("m.x", &[("t", "1")]).inc();
        let text = encode(&r.snapshot());
        let type_lines = text
            .lines()
            .filter(|l| l.starts_with("# TYPE percache_m_x_total"))
            .count();
        assert_eq!(type_lines, 1);
    }
}
