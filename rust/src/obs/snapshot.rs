//! Point-in-time metric snapshots and their JSON form (DESIGN.md §12).
//!
//! `MetricsRegistry::snapshot()` walks every series into a typed
//! [`MetricsSnapshot`] that serializes via `util/json.rs` and parses
//! back to an equal value, so periodic `--metrics-file` dumps can be
//! diffed, replayed, and pretty-printed by `percache metrics`.

use anyhow::{Context, Result};

use crate::util::json::Json;

use super::metric::{quantile_from_buckets, N_BUCKETS};
use super::registry::{MetricKey, MetricsRegistry};

/// One counter series at snapshot time.
#[derive(Debug, Clone, PartialEq)]
pub struct CounterSnap {
    pub name: String,
    pub labels: Vec<(String, String)>,
    pub value: u64,
}

/// One gauge series at snapshot time.
#[derive(Debug, Clone, PartialEq)]
pub struct GaugeSnap {
    pub name: String,
    pub labels: Vec<(String, String)>,
    pub value: i64,
}

/// One histogram series at snapshot time.  Buckets are sparse
/// `(index, count)` pairs over the fixed log-scale bounds.
#[derive(Debug, Clone, PartialEq)]
pub struct HistSnap {
    pub name: String,
    pub labels: Vec<(String, String)>,
    pub count: u64,
    pub sum_ms: f64,
    pub buckets: Vec<(usize, u64)>,
    pub p50: f64,
    pub p99: f64,
}

impl HistSnap {
    /// Dense bucket counts rebuilt from the sparse form.
    pub fn dense_buckets(&self) -> [u64; N_BUCKETS] {
        let mut dense = [0u64; N_BUCKETS];
        for &(i, c) in &self.buckets {
            if let Some(slot) = dense.get_mut(i) {
                *slot = c;
            }
        }
        dense
    }

    /// Quantile estimate recomputed from the snapshot's buckets.
    pub fn quantile(&self, q: f64) -> f64 {
        quantile_from_buckets(&self.dense_buckets(), q)
    }
}

/// Every series in the registry at one instant.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct MetricsSnapshot {
    /// Registry uptime when the snapshot was taken, in milliseconds.
    pub t_ms: f64,
    pub counters: Vec<CounterSnap>,
    pub gauges: Vec<GaugeSnap>,
    pub hists: Vec<HistSnap>,
}

/// Synthesize a counter series for a value that lives outside the
/// registry's own store (journal drop counts, tracer totals).  Returns
/// `None` for zero so an untouched registry snapshots exactly its own
/// series (tests pin that).
pub fn synth(name: &str, value: u64) -> Option<CounterSnap> {
    if value == 0 {
        return None;
    }
    Some(CounterSnap {
        name: name.to_string(),
        labels: Vec::new(),
        value,
    })
}

/// Insert a synthesized counter at its sorted position (no-op for
/// `None`), preserving the snapshot's series-for-series ordering.
pub fn merge_synth(snap: &mut MetricsSnapshot, c: Option<CounterSnap>) {
    let Some(c) = c else { return };
    let pos = snap
        .counters
        .iter()
        .position(|e| (e.name.as_str(), &e.labels) > (c.name.as_str(), &c.labels))
        .unwrap_or(snap.counters.len());
    snap.counters.insert(pos, c);
}

impl MetricsRegistry {
    /// Walk every series into a typed snapshot (sorted by key, so two
    /// snapshots of the same registry line up series-for-series).
    pub fn snapshot(&self) -> MetricsSnapshot {
        let mut snap = MetricsSnapshot {
            t_ms: self.uptime_ms(),
            ..MetricsSnapshot::default()
        };
        self.visit(
            |k, c| {
                snap.counters.push(CounterSnap {
                    name: k.name.clone(),
                    labels: k.labels.clone(),
                    value: c.get(),
                });
            },
            |k, g| {
                snap.gauges.push(GaugeSnap {
                    name: k.name.clone(),
                    labels: k.labels.clone(),
                    value: g.get(),
                });
            },
            |k, h| {
                let counts = h.bucket_counts();
                let buckets: Vec<(usize, u64)> = counts
                    .iter()
                    .enumerate()
                    .filter(|(_, &c)| c > 0)
                    .map(|(i, &c)| (i, c))
                    .collect();
                snap.hists.push(HistSnap {
                    name: k.name.clone(),
                    labels: k.labels.clone(),
                    count: h.count(),
                    sum_ms: h.sum_ms(),
                    buckets,
                    p50: quantile_from_buckets(&counts, 0.50),
                    p99: quantile_from_buckets(&counts, 0.99),
                });
            },
        );
        // journal overflow drops were previously invisible outside the
        // struct; surface them as a counter series (absent while zero)
        merge_synth(&mut snap, synth("journal.dropped", self.journal().dropped()));
        snap
    }
}

fn labels_to_json(labels: &[(String, String)]) -> Json {
    let mut o = Json::obj();
    for (k, v) in labels {
        o.insert(k.as_str(), v.as_str());
    }
    Json::Obj(o)
}

fn labels_from_json(j: &Json) -> Vec<(String, String)> {
    let mut out = Vec::new();
    if let Some(o) = j.as_obj() {
        for (k, v) in o.iter() {
            out.push((k.to_string(), v.as_str().unwrap_or("").to_string()));
        }
    }
    out
}

impl MetricsSnapshot {
    pub fn to_json(&self) -> Json {
        let mut root = Json::obj();
        root.insert("t_ms", self.t_ms);
        let counters: Vec<Json> = self
            .counters
            .iter()
            .map(|c| {
                let mut o = Json::obj();
                o.insert("name", c.name.as_str());
                o.insert("labels", labels_to_json(&c.labels));
                o.insert("value", c.value);
                Json::Obj(o)
            })
            .collect();
        root.insert("counters", Json::Arr(counters));
        let gauges: Vec<Json> = self
            .gauges
            .iter()
            .map(|g| {
                let mut o = Json::obj();
                o.insert("name", g.name.as_str());
                o.insert("labels", labels_to_json(&g.labels));
                o.insert("value", g.value);
                Json::Obj(o)
            })
            .collect();
        root.insert("gauges", Json::Arr(gauges));
        let hists: Vec<Json> = self
            .hists
            .iter()
            .map(|h| {
                let mut o = Json::obj();
                o.insert("name", h.name.as_str());
                o.insert("labels", labels_to_json(&h.labels));
                o.insert("count", h.count);
                o.insert("sum_ms", h.sum_ms);
                let buckets: Vec<Json> = h
                    .buckets
                    .iter()
                    .map(|&(i, c)| Json::Arr(vec![Json::from(i), Json::from(c)]))
                    .collect();
                o.insert("buckets", Json::Arr(buckets));
                o.insert("p50", h.p50);
                o.insert("p99", h.p99);
                Json::Obj(o)
            })
            .collect();
        root.insert("hists", Json::Arr(hists));
        Json::Obj(root)
    }

    pub fn from_json(j: &Json) -> Result<MetricsSnapshot> {
        let t_ms = j.get("t_ms").as_f64().context("snapshot: t_ms")?;
        let mut snap = MetricsSnapshot {
            t_ms,
            ..MetricsSnapshot::default()
        };
        for c in j.get("counters").as_arr().unwrap_or(&[]) {
            snap.counters.push(CounterSnap {
                name: c.get("name").as_str().context("counter: name")?.to_string(),
                labels: labels_from_json(c.get("labels")),
                value: c.get("value").as_i64().context("counter: value")? as u64,
            });
        }
        for g in j.get("gauges").as_arr().unwrap_or(&[]) {
            snap.gauges.push(GaugeSnap {
                name: g.get("name").as_str().context("gauge: name")?.to_string(),
                labels: labels_from_json(g.get("labels")),
                value: g.get("value").as_i64().context("gauge: value")?,
            });
        }
        for h in j.get("hists").as_arr().unwrap_or(&[]) {
            let mut buckets = Vec::new();
            for b in h.get("buckets").as_arr().unwrap_or(&[]) {
                let i = b.idx(0).as_usize().context("hist bucket: index")?;
                let c = b.idx(1).as_i64().context("hist bucket: count")? as u64;
                buckets.push((i, c));
            }
            snap.hists.push(HistSnap {
                name: h.get("name").as_str().context("hist: name")?.to_string(),
                labels: labels_from_json(h.get("labels")),
                count: h.get("count").as_i64().context("hist: count")? as u64,
                sum_ms: h.get("sum_ms").as_f64().context("hist: sum_ms")?,
                buckets,
                p50: h.get("p50").as_f64().context("hist: p50")?,
                p99: h.get("p99").as_f64().context("hist: p99")?,
            });
        }
        Ok(snap)
    }

    /// Find one counter by family name (tests, CLI summaries).
    pub fn counter_value(&self, name: &str) -> u64 {
        self.counters
            .iter()
            .filter(|c| c.name == name)
            .map(|c| c.value)
            .sum()
    }

    /// Find one gauge by family name (sums labeled series).
    pub fn gauge_value(&self, name: &str) -> i64 {
        self.gauges
            .iter()
            .filter(|g| g.name == name)
            .map(|g| g.value)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_walks_all_series_sorted() {
        let r = MetricsRegistry::new();
        r.counter("b.second").inc();
        r.counter("a.first").add(2);
        r.gauge("g.depth").set(-3);
        r.histogram("h.lat_ms").record(1.5);
        let snap = r.snapshot();
        let names: Vec<&str> = snap.counters.iter().map(|c| c.name.as_str()).collect();
        assert_eq!(names, vec!["a.first", "b.second"], "BTreeMap order");
        assert_eq!(snap.counter_value("a.first"), 2);
        assert_eq!(snap.gauge_value("g.depth"), -3);
        assert_eq!(snap.hists.len(), 1);
        assert_eq!(snap.hists[0].count, 1);
        assert!(snap.t_ms >= 0.0);
    }

    #[test]
    fn hist_snap_quantile_matches_live() {
        let r = MetricsRegistry::new();
        let h = r.histogram("q_ms");
        for v in [0.5, 1.0, 2.0, 4.0, 8.0] {
            h.record(v);
        }
        let snap = r.snapshot();
        assert_eq!(snap.hists[0].quantile(0.5), h.quantile(0.5));
        assert_eq!(snap.hists[0].p50, h.quantile(0.5));
        assert_eq!(snap.hists[0].p99, h.quantile(0.99));
    }

    #[test]
    fn journal_drops_surface_as_sorted_synth_counter() {
        use crate::obs::journal::{Event, JOURNAL_STRIPES};
        let r = MetricsRegistry::new();
        r.counter("a.first").inc();
        r.counter("z.last").inc();
        assert_eq!(
            r.snapshot().counter_value("journal.dropped"),
            0,
            "absent while zero"
        );
        r.journal().set_capacity(JOURNAL_STRIPES);
        for _ in 0..3 * JOURNAL_STRIPES {
            r.journal().emit(Event::new("tick"));
        }
        let snap = r.snapshot();
        assert!(snap.counter_value("journal.dropped") > 0);
        let names: Vec<&str> = snap.counters.iter().map(|c| c.name.as_str()).collect();
        let mut sorted = names.clone();
        sorted.sort();
        assert_eq!(names, sorted, "synth insert keeps sorted order");
    }

    #[test]
    fn labeled_series_round_trip() {
        let r = MetricsRegistry::new();
        r.counter_labeled("router.rejected", &[("reason", "queue_full")])
            .add(4);
        r.gauge_labeled("governor.shard_bytes", &[("tenant", "2")])
            .set(4096);
        let snap = r.snapshot();
        let parsed = Json::parse(&snap.to_json().to_string()).unwrap();
        let back = MetricsSnapshot::from_json(&parsed).unwrap();
        assert_eq!(back, snap);
    }
}
