//! Runtime telemetry: metrics registry, stage tracing, and the event
//! journal (DESIGN.md §12).
//!
//! The serving stack used to run blind — measurement lived only in the
//! experiment-side `metrics::recorder`, and the router, governor, and
//! tiering controller logged through scattered `eprintln!`s.  This
//! module is the sensor layer: every component records into one global
//! [`MetricsRegistry`] of atomic counters, gauges, and log-scale
//! histograms, emits structured events into a bounded lock-striped
//! [`Journal`], and the serving loop periodically dumps typed
//! snapshots (JSON via `util/json.rs`, Prometheus text via
//! [`prometheus::encode`]) that `percache metrics` pretty-prints.
//!
//! Cost model: call sites cache a handle once (the `obs_counter!`
//! family of macros does this with a `OnceLock` per call site), after
//! which each record is one relaxed atomic load — the enabled check —
//! plus one relaxed read-modify-write.  `percache exp obs` measures
//! the end-to-end overhead on the tenancy workload and CI holds the
//! enabled-vs-disabled p50 delta under 3%.

pub mod exemplar;
pub mod journal;
pub mod metric;
pub mod prometheus;
pub mod registry;
pub mod snapshot;
pub mod trace;

use std::sync::OnceLock;

pub use exemplar::{Exemplar, ExemplarConfig, ExemplarReservoir};
pub use journal::{Event, EventRecord, Journal, TraceRef};
pub use metric::{bucket_bounds, bucket_index, Counter, Gauge, Histogram, N_BUCKETS};
pub use registry::{CounterHandle, GaugeHandle, HistogramHandle, MetricsRegistry, SpanGuard};
pub use snapshot::{CounterSnap, GaugeSnap, HistSnap, MetricsSnapshot};
pub use trace::{TraceCtx, Tracer};

/// The process-wide registry every instrumentation site records into.
/// Tests that need isolation build their own [`MetricsRegistry`].
pub fn registry() -> &'static MetricsRegistry {
    static GLOBAL: OnceLock<MetricsRegistry> = OnceLock::new();
    GLOBAL.get_or_init(MetricsRegistry::new)
}

/// The process-wide causal tracer (DESIGN.md §16).  Disabled by
/// default; `ObsConfig::apply` or the traced experiment arm turn it
/// on.  Tests and deterministic replays build local [`Tracer`]s.
pub fn tracer() -> &'static Tracer {
    static GLOBAL: OnceLock<Tracer> = OnceLock::new();
    GLOBAL.get_or_init(Tracer::new)
}

/// Enable/disable all recording on the global registry.
pub fn set_enabled(on: bool) {
    registry().set_enabled(on);
}

pub fn enabled() -> bool {
    registry().enabled()
}

/// `--verbose`: tail the event journal to stderr and journal spans too.
pub fn set_verbose(on: bool) {
    registry().journal().set_echo(on);
    registry().journal().set_trace_spans(on);
}

/// Resolve a counter handle on the global registry.
pub fn counter(name: &str) -> CounterHandle {
    registry().counter(name)
}

pub fn counter_labeled(name: &str, labels: &[(&str, &str)]) -> CounterHandle {
    registry().counter_labeled(name, labels)
}

/// Resolve a gauge handle on the global registry.
pub fn gauge(name: &str) -> GaugeHandle {
    registry().gauge(name)
}

pub fn gauge_labeled(name: &str, labels: &[(&str, &str)]) -> GaugeHandle {
    registry().gauge_labeled(name, labels)
}

/// Resolve a histogram handle on the global registry.
pub fn histogram(name: &str) -> HistogramHandle {
    registry().histogram(name)
}

/// Start a stage span on the global registry.
pub fn span(name: &'static str) -> SpanGuard {
    registry().span(name)
}

/// Journal one structured event on the global registry.
pub fn emit(ev: Event) {
    registry().emit(ev);
}

/// Snapshot the global registry, folding in the tracer's synthesized
/// counter series (absent while zero, like all synth series).
pub fn snapshot() -> MetricsSnapshot {
    let mut snap = registry().snapshot();
    let stats = tracer().stats();
    snapshot::merge_synth(&mut snap, snapshot::synth("trace.completed", stats.completed));
    snapshot::merge_synth(&mut snap, snapshot::synth("trace.dropped", stats.dropped));
    snapshot::merge_synth(&mut snap, snapshot::synth("trace.started", stats.started));
    snap
}

/// Serialize the global registry's current state to `path`: the typed
/// snapshot as JSON plus its Prometheus text encoding, with optional
/// extra sections (the tiered server folds its residency report in so
/// it survives non-graceful exits).  Written atomically (tmp + rename).
pub fn dump_metrics_file(
    path: &std::path::Path,
    extra: &[(&str, crate::util::json::Json)],
) -> std::io::Result<()> {
    let snap = snapshot();
    let mut doc = crate::util::json::Json::obj();
    doc.insert("uptime_ms", registry().uptime_ms());
    doc.insert("metrics", snap.to_json());
    doc.insert("prometheus", prometheus::encode(&snap));
    let tr = tracer();
    if tr.enabled() {
        // exemplar traces ride along with every dump; rolling the
        // window afterwards means each dump covers the last complete
        // window plus whatever accumulated since
        doc.insert("trace", tr.export_json());
        tr.roll_window();
    }
    for (k, v) in extra {
        doc.insert(*k, v.clone());
    }
    let tmp = path.with_extension("tmp");
    std::fs::write(&tmp, crate::util::json::Json::Obj(doc).to_string_pretty())?;
    std::fs::rename(&tmp, path)
}

/// Counter on the global registry, resolved once per call site.
#[macro_export]
macro_rules! obs_counter {
    ($name:expr) => {{
        static HANDLE: std::sync::OnceLock<$crate::obs::CounterHandle> =
            std::sync::OnceLock::new();
        HANDLE.get_or_init(|| $crate::obs::counter($name))
    }};
}

/// Gauge on the global registry, resolved once per call site.
#[macro_export]
macro_rules! obs_gauge {
    ($name:expr) => {{
        static HANDLE: std::sync::OnceLock<$crate::obs::GaugeHandle> =
            std::sync::OnceLock::new();
        HANDLE.get_or_init(|| $crate::obs::gauge($name))
    }};
}

/// Histogram on the global registry, resolved once per call site.
#[macro_export]
macro_rules! obs_hist {
    ($name:expr) => {{
        static HANDLE: std::sync::OnceLock<$crate::obs::HistogramHandle> =
            std::sync::OnceLock::new();
        HANDLE.get_or_init(|| $crate::obs::histogram($name))
    }};
}

#[cfg(test)]
mod tests {
    #[test]
    fn macros_cache_one_handle_per_site() {
        let before = crate::obs_counter!("obs.self_test").get();
        for _ in 0..3 {
            crate::obs_counter!("obs.self_test").inc();
        }
        // global registry: other tests may run concurrently, so only
        // assert on this site's own delta
        assert!(crate::obs_counter!("obs.self_test").get() >= before + 3);
        crate::obs_gauge!("obs.self_gauge").set(11);
        crate::obs_hist!("obs.self_hist_ms").record(0.25);
        assert!(crate::obs_hist!("obs.self_hist_ms").count() >= 1);
    }
}
