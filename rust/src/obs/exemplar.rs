//! Per-window tail-exemplar reservoir (DESIGN.md §16).
//!
//! Keeps, per tenant and per export window, the K slowest completed
//! traces plus a uniform reservoir sample of K more, so latency
//! histograms can carry exemplar trace ids without unbounded memory.
//! Sampling is driven by a seeded PCG32 stream per tenant, which makes
//! the kept set a pure function of the offered sequence — deterministic
//! under the virtual clock.  `roll_window` archives the current window
//! so exports always cover the last complete window plus whatever has
//! accumulated since.

use std::collections::BTreeMap;

use crate::obs::trace::Trace;
use crate::util::rng::Rng;

/// Reservoir sizing and seeding knobs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ExemplarConfig {
    /// Slowest-trace slots kept per tenant per window.
    pub tail_k: usize,
    /// Uniform reservoir slots kept per tenant per window.
    pub uniform_k: usize,
    /// Seed for the per-tenant sampling streams.
    pub seed: u64,
}

impl Default for ExemplarConfig {
    fn default() -> Self {
        Self {
            tail_k: 4,
            uniform_k: 4,
            seed: 0x7E1A_C0DE,
        }
    }
}

/// A trace selected for export, tagged with how it was kept.
#[derive(Debug, Clone)]
pub struct Exemplar {
    /// `"tail"` (one of the K slowest) or `"uniform"` (reservoir pick).
    pub kind: &'static str,
    /// Root-span duration in milliseconds.
    pub e2e_ms: f64,
    pub trace: Trace,
}

#[derive(Debug, Clone)]
struct Entry {
    dur_ns: u64,
    trace: Trace,
}

#[derive(Debug)]
struct TenantWindow {
    offered: u64,
    rng: Rng,
    tail: Vec<Entry>,
    uniform: Vec<Entry>,
}

/// Bounded per-tenant exemplar store: `current` accumulates, `last`
/// holds the previous window after a `roll_window`.
#[derive(Debug)]
pub struct ExemplarReservoir {
    cfg: ExemplarConfig,
    current: BTreeMap<Option<u32>, TenantWindow>,
    last: BTreeMap<Option<u32>, TenantWindow>,
}

impl ExemplarReservoir {
    pub fn new(cfg: ExemplarConfig) -> Self {
        Self {
            cfg,
            current: BTreeMap::new(),
            last: BTreeMap::new(),
        }
    }

    pub fn config(&self) -> ExemplarConfig {
        self.cfg
    }

    /// Offer a completed trace to the current window.
    pub fn offer(&mut self, trace: Trace) {
        let dur_ns = root_dur_ns(&trace);
        let cfg = self.cfg;
        let window = self
            .current
            .entry(trace.tenant)
            .or_insert_with(|| TenantWindow {
                offered: 0,
                rng: Rng::seeded(cfg.seed, tenant_stream(trace.tenant)),
                tail: Vec::new(),
                uniform: Vec::new(),
            });
        window.offered += 1;
        let entry = Entry { dur_ns, trace };
        if cfg.uniform_k > 0 {
            if window.uniform.len() < cfg.uniform_k {
                window.uniform.push(entry.clone());
            } else {
                // Algorithm R: the i-th offer replaces a slot with
                // probability k/i; `offered` already counts this one.
                let j = window.rng.below(window.offered as usize);
                if let Some(slot) = window.uniform.get_mut(j) {
                    *slot = entry.clone();
                }
            }
        }
        if cfg.tail_k > 0 {
            window.tail.push(entry);
            window
                .tail
                .sort_by(|a, b| b.dur_ns.cmp(&a.dur_ns).then(a.trace.trace.cmp(&b.trace.trace)));
            window.tail.truncate(cfg.tail_k);
        }
    }

    /// Archive the current window; exports now cover it as `last`.
    pub fn roll_window(&mut self) {
        self.last = std::mem::take(&mut self.current);
    }

    /// Drop all kept traces (both windows).
    pub fn clear(&mut self) {
        self.current.clear();
        self.last.clear();
    }

    /// Union of the last and current windows, deduplicated by trace id
    /// (tail membership wins over uniform), sorted by (tenant, trace).
    pub fn export(&self) -> Vec<Exemplar> {
        let mut picked: BTreeMap<(Option<u32>, u64), Exemplar> = BTreeMap::new();
        for window in self.last.values().chain(self.current.values()) {
            for e in &window.tail {
                picked.insert((e.trace.tenant, e.trace.trace), to_exemplar("tail", e));
            }
        }
        for window in self.last.values().chain(self.current.values()) {
            for e in &window.uniform {
                picked
                    .entry((e.trace.tenant, e.trace.trace))
                    .or_insert_with(|| to_exemplar("uniform", e));
            }
        }
        picked.into_values().collect()
    }
}

fn to_exemplar(kind: &'static str, e: &Entry) -> Exemplar {
    Exemplar {
        kind,
        e2e_ms: e.dur_ns as f64 / 1e6,
        trace: e.trace.clone(),
    }
}

fn root_dur_ns(trace: &Trace) -> u64 {
    trace
        .spans
        .first()
        .map(|s| s.t_end_ns.saturating_sub(s.t_start_ns))
        .unwrap_or(0)
}

fn tenant_stream(tenant: Option<u32>) -> u64 {
    match tenant {
        Some(t) => t as u64 + 2,
        None => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::SpanRecord;

    fn mk(trace: u64, tenant: Option<u32>, dur_ns: u64) -> Trace {
        Trace {
            trace,
            tenant,
            spans: vec![SpanRecord {
                span: trace * 10,
                parent: None,
                stage: "request".to_string(),
                t_start_ns: 0,
                t_end_ns: dur_ns,
            }],
        }
    }

    #[test]
    fn tail_keeps_the_k_slowest() {
        let mut r = ExemplarReservoir::new(ExemplarConfig {
            tail_k: 2,
            uniform_k: 0,
            seed: 1,
        });
        for (id, dur) in [(1u64, 5u64), (2, 50), (3, 10), (4, 40)] {
            r.offer(mk(id, Some(0), dur));
        }
        let out = r.export();
        let ids: Vec<u64> = out.iter().map(|e| e.trace.trace).collect();
        assert_eq!(ids, vec![2, 4]);
        assert!(out.iter().all(|e| e.kind == "tail"));
    }

    #[test]
    fn deterministic_across_identical_runs() {
        let cfg = ExemplarConfig {
            tail_k: 2,
            uniform_k: 2,
            seed: 9,
        };
        let run = || {
            let mut r = ExemplarReservoir::new(cfg);
            for id in 0..100u64 {
                r.offer(mk(id, Some((id % 3) as u32), (id * 37) % 101));
            }
            r.export()
                .iter()
                .map(|e| (e.trace.tenant, e.trace.trace, e.kind, e.e2e_ms.to_bits()))
                .collect::<Vec<_>>()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn bounded_per_tenant_and_dedup_tail_wins() {
        let cfg = ExemplarConfig {
            tail_k: 3,
            uniform_k: 3,
            seed: 4,
        };
        let mut r = ExemplarReservoir::new(cfg);
        for id in 0..1000u64 {
            r.offer(mk(id, Some(7), id));
        }
        let out = r.export();
        assert!(out.len() <= cfg.tail_k + cfg.uniform_k, "{}", out.len());
        // the very slowest must be present and tagged tail even if the
        // uniform reservoir also sampled it
        let slowest = out.iter().find(|e| e.trace.trace == 999).expect("tail lost");
        assert_eq!(slowest.kind, "tail");
        let mut ids: Vec<u64> = out.iter().map(|e| e.trace.trace).collect();
        ids.dedup();
        assert_eq!(ids.len(), out.len(), "duplicate trace ids in export");
    }

    #[test]
    fn roll_window_archives_and_export_unions() {
        let mut r = ExemplarReservoir::new(ExemplarConfig {
            tail_k: 1,
            uniform_k: 0,
            seed: 2,
        });
        r.offer(mk(1, None, 100));
        r.roll_window();
        r.offer(mk(2, None, 50));
        let ids: Vec<u64> = r.export().iter().map(|e| e.trace.trace).collect();
        assert_eq!(ids, vec![1, 2]);
        r.roll_window(); // window 2 becomes last, trace 1 ages out
        let ids: Vec<u64> = r.export().iter().map(|e| e.trace.trace).collect();
        assert_eq!(ids, vec![2]);
        r.clear();
        assert!(r.export().is_empty());
    }
}
