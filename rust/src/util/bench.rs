//! Criterion-lite benchmark harness (no `criterion` in the vendored set).
//!
//! Drives the `cargo bench` targets (`harness = false`): warmup, adaptive
//! iteration count, mean/median/p95, and a plain-text report compatible
//! with redirecting into bench_output.txt.

use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
    pub std_ns: f64,
}

impl Stats {
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }

    pub fn throughput_per_s(&self) -> f64 {
        1e9 / self.mean_ns
    }

    pub fn report_line(&self) -> String {
        format!(
            "{:<44} {:>10} iters  mean {:>12}  median {:>12}  p95 {:>12}  ±{:>9}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.median_ns),
            fmt_ns(self.p95_ns),
            fmt_ns(self.std_ns),
        )
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.0} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Harness with a global time budget per benchmark.
pub struct Bench {
    pub warmup: Duration,
    pub measure: Duration,
    pub min_iters: usize,
    pub max_iters: usize,
    results: Vec<Stats>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup: Duration::from_millis(300),
            measure: Duration::from_secs(2),
            min_iters: 5,
            max_iters: 100_000,
            results: Vec::new(),
        }
    }
}

impl Bench {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn quick() -> Self {
        Bench {
            warmup: Duration::from_millis(50),
            measure: Duration::from_millis(300),
            min_iters: 3,
            max_iters: 10_000,
            results: Vec::new(),
        }
    }

    /// Measure `f`; the closure's return value is black-boxed to prevent
    /// the optimizer from deleting the work.
    pub fn bench<R>(&mut self, name: &str, mut f: impl FnMut() -> R) -> &Stats {
        // Warmup.
        let w0 = Instant::now();
        let mut warm_iters = 0usize;
        while w0.elapsed() < self.warmup && warm_iters < self.max_iters {
            black_box(f());
            warm_iters += 1;
        }

        // Measure individual iterations until the budget runs out.
        let mut samples: Vec<f64> = Vec::new();
        let m0 = Instant::now();
        while (m0.elapsed() < self.measure || samples.len() < self.min_iters)
            && samples.len() < self.max_iters
        {
            let t = Instant::now();
            black_box(f());
            samples.push(t.elapsed().as_nanos() as f64);
        }

        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        let stats = Stats {
            name: name.to_string(),
            iters: n,
            mean_ns: mean,
            median_ns: percentile(&samples, 50.0),
            p95_ns: percentile(&samples, 95.0),
            min_ns: samples[0],
            max_ns: samples[n - 1],
            std_ns: var.sqrt(),
        };
        println!("{}", stats.report_line());
        self.results.push(stats);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[Stats] {
        &self.results
    }

    pub fn summary(&self) -> String {
        let mut s = String::from("\n== summary ==\n");
        for r in &self.results {
            s.push_str(&r.report_line());
            s.push('\n');
        }
        s
    }
}

/// Percentile over a pre-sorted sample vector (linear interpolation).
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// `std::hint::black_box` stand-in stable across toolchains.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut b = Bench::quick();
        let s = b.bench("noop-ish", || (0..100).sum::<u64>());
        assert!(s.iters >= 3);
        assert!(s.mean_ns > 0.0);
        assert!(s.median_ns <= s.p95_ns + 1.0);
        assert!(s.min_ns <= s.median_ns && s.median_ns <= s.max_ns);
    }

    #[test]
    fn percentile_interpolates() {
        let v = vec![0.0, 10.0, 20.0, 30.0, 40.0];
        assert_eq!(percentile(&v, 0.0), 0.0);
        assert_eq!(percentile(&v, 100.0), 40.0);
        assert_eq!(percentile(&v, 50.0), 20.0);
        assert_eq!(percentile(&v, 25.0), 10.0);
    }

    #[test]
    fn fmt_ns_scales() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert_eq!(fmt_ns(1500.0), "1.50 µs");
        assert_eq!(fmt_ns(2_500_000.0), "2.50 ms");
        assert_eq!(fmt_ns(3.2e9), "3.200 s");
    }

    #[test]
    fn summary_contains_all() {
        let mut b = Bench::quick();
        b.bench("a", || 1 + 1);
        b.bench("b", || 2 + 2);
        let s = b.summary();
        assert!(s.contains("a") && s.contains("b"));
        assert_eq!(b.results().len(), 2);
    }
}
