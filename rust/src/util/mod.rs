//! Substrate utilities built from scratch for the offline environment
//! (no serde/clap/rand/criterion/tokio in the vendored crate set).

pub mod bench;
pub mod cli;
pub mod json;
pub mod pool;
pub mod rng;
pub mod sync;
pub mod table;
