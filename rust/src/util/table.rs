//! Plain-text table + CSV writers for the experiment harness.
//!
//! Every paper figure/table reproduction prints one of these and also
//! drops a CSV under reports/ so the series can be re-plotted.

use std::fmt::Write as _;
use std::io::Write as _;
use std::path::Path;

#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, columns: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            columns: columns.iter().map(|c| c.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(
            cells.len(),
            self.columns.len(),
            "row width mismatch in table '{}'",
            self.title
        );
        self.rows.push(cells);
    }

    /// Column-aligned plain-text rendering.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.columns.iter().map(|c| c.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "\n## {}", self.title);
        let line = |out: &mut String, cells: &[String]| {
            let mut s = String::from("| ");
            for (i, c) in cells.iter().enumerate() {
                let _ = write!(s, "{:<w$} | ", c, w = widths[i]);
            }
            let _ = writeln!(out, "{}", s.trim_end());
        };
        line(&mut out, &self.columns);
        let mut sep = String::from("|");
        for w in &widths {
            let _ = write!(sep, "{:-<w$}|", "", w = w + 2);
        }
        let _ = writeln!(out, "{sep}");
        for row in &self.rows {
            line(&mut out, row);
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let esc = |c: &str| {
            if c.contains(',') || c.contains('"') || c.contains('\n') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        };
        let _ = writeln!(
            out,
            "{}",
            self.columns.iter().map(|c| esc(c)).collect::<Vec<_>>().join(",")
        );
        for row in &self.rows {
            let _ = writeln!(
                out,
                "{}",
                row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(",")
            );
        }
        out
    }

    /// Print to stdout and persist a CSV under `dir` (created on demand).
    pub fn emit(&self, dir: &Path, stem: &str) {
        print!("{}", self.render());
        if std::fs::create_dir_all(dir).is_ok() {
            let path = dir.join(format!("{stem}.csv"));
            if let Ok(mut f) = std::fs::File::create(&path) {
                let _ = f.write_all(self.to_csv().as_bytes());
                println!("[csv] {}", path.display());
            }
        }
    }
}

pub fn fmt_ms(v: f64) -> String {
    format!("{v:.2}")
}

pub fn fmt_pct(v: f64) -> String {
    format!("{:.1}%", v * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("Fig X", &["method", "latency"]);
        t.row(vec!["percache".into(), "1.25".into()]);
        t.row(vec!["naive".into(), "10.50".into()]);
        let r = t.render();
        assert!(r.contains("## Fig X"));
        assert!(r.contains("| percache |"));
        assert!(r.lines().count() >= 5);
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn rejects_bad_width() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(vec!["x,y".into(), "he said \"hi\"".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"he said \"\"hi\"\"\""));
    }

    #[test]
    fn emit_writes_csv() {
        let dir = std::env::temp_dir().join("percache_table_test");
        let mut t = Table::new("t", &["a"]);
        t.row(vec!["1".into()]);
        t.emit(&dir, "unit");
        let data = std::fs::read_to_string(dir.join("unit.csv")).unwrap();
        assert!(data.starts_with("a\n"));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
