//! Declarative command-line parser (no `clap` in the vendored set).
//!
//! Supports subcommands, `--flag value`, `--flag=value`, boolean switches,
//! defaults, and auto-generated `--help`.  Just enough structure for the
//! `percache` binary, examples and bench harness to share.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct FlagSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<String>,
    pub is_switch: bool,
}

#[derive(Debug, Default)]
pub struct Cli {
    pub about: &'static str,
    flags: Vec<FlagSpec>,
}

#[derive(Debug, Clone)]
pub struct Args {
    values: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

#[derive(Debug)]
pub enum CliError {
    UnknownFlag(String),
    MissingValue(String),
    Help,
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::UnknownFlag(name) => write!(f, "unknown flag --{name}"),
            CliError::MissingValue(name) => write!(f, "flag --{name} needs a value"),
            CliError::Help => write!(f, "help requested"),
        }
    }
}

impl std::error::Error for CliError {}

impl Cli {
    pub fn new(about: &'static str) -> Self {
        Cli {
            about,
            flags: Vec::new(),
        }
    }

    pub fn flag(mut self, name: &'static str, default: &str, help: &'static str) -> Self {
        self.flags.push(FlagSpec {
            name,
            help,
            default: Some(default.to_string()),
            is_switch: false,
        });
        self
    }

    pub fn required(mut self, name: &'static str, help: &'static str) -> Self {
        self.flags.push(FlagSpec {
            name,
            help,
            default: None,
            is_switch: false,
        });
        self
    }

    pub fn switch(mut self, name: &'static str, help: &'static str) -> Self {
        self.flags.push(FlagSpec {
            name,
            help,
            default: Some("false".to_string()),
            is_switch: true,
        });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{}\n\nflags:\n", self.about);
        for f in &self.flags {
            let d = match (&f.default, f.is_switch) {
                (_, true) => " (switch)".to_string(),
                (Some(d), _) if !d.is_empty() => format!(" (default: {d})"),
                _ => " (required)".to_string(),
            };
            s.push_str(&format!("  --{:<18} {}{}\n", f.name, f.help, d));
        }
        s
    }

    /// Parse an argv slice (excluding the program name).
    pub fn parse(&self, argv: &[String]) -> Result<Args, CliError> {
        let mut values: BTreeMap<String, String> = BTreeMap::new();
        for f in &self.flags {
            if let Some(d) = &f.default {
                values.insert(f.name.to_string(), d.clone());
            }
        }
        let mut positional = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if a == "--help" || a == "-h" {
                return Err(CliError::Help);
            }
            if let Some(stripped) = a.strip_prefix("--") {
                let (name, inline) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .flags
                    .iter()
                    .find(|f| f.name == name)
                    .ok_or_else(|| CliError::UnknownFlag(name.clone()))?;
                let value = if let Some(v) = inline {
                    v
                } else if spec.is_switch {
                    "true".to_string()
                } else {
                    i += 1;
                    argv.get(i)
                        .cloned()
                        .ok_or_else(|| CliError::MissingValue(name.clone()))?
                };
                values.insert(name, value);
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        Ok(Args { values, positional })
    }

    /// Parse process args after a number of already-consumed positionals.
    pub fn parse_env(&self, skip: usize) -> Args {
        let argv: Vec<String> = std::env::args().skip(1 + skip).collect();
        match self.parse(&argv) {
            Ok(a) => a,
            Err(CliError::Help) => {
                println!("{}", self.usage());
                std::process::exit(0);
            }
            Err(e) => {
                eprintln!("error: {e}\n\n{}", self.usage());
                std::process::exit(2);
            }
        }
    }
}

impl Args {
    pub fn get(&self, name: &str) -> &str {
        self.values
            .get(name)
            .map(|s| s.as_str())
            .unwrap_or_else(|| panic!("flag --{name} not declared/provided"))
    }

    pub fn get_usize(&self, name: &str) -> usize {
        self.get(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} must be an integer"))
    }

    pub fn get_f64(&self, name: &str) -> f64 {
        self.get(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} must be a number"))
    }

    pub fn get_bool(&self, name: &str) -> bool {
        self.get(name) == "true"
    }

    pub fn get_list(&self, name: &str) -> Vec<String> {
        let v = self.get(name);
        if v.is_empty() {
            vec![]
        } else {
            v.split(',').map(|s| s.trim().to_string()).collect()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    fn cli() -> Cli {
        Cli::new("test")
            .flag("model", "llama", "model name")
            .flag("users", "5", "user count")
            .switch("verbose", "log more")
            .required("dataset", "dataset id")
    }

    #[test]
    fn defaults_and_overrides() {
        let a = cli().parse(&argv("--dataset mised --users 3")).unwrap();
        assert_eq!(a.get("model"), "llama");
        assert_eq!(a.get_usize("users"), 3);
        assert_eq!(a.get("dataset"), "mised");
        assert!(!a.get_bool("verbose"));
    }

    #[test]
    fn equals_form_and_switch() {
        let a = cli()
            .parse(&argv("--dataset=enron --verbose --model=qwen"))
            .unwrap();
        assert_eq!(a.get("dataset"), "enron");
        assert_eq!(a.get("model"), "qwen");
        assert!(a.get_bool("verbose"));
    }

    #[test]
    fn positionals_collected() {
        let a = cli().parse(&argv("fig14 --dataset x run")).unwrap();
        assert_eq!(a.positional, vec!["fig14", "run"]);
    }

    #[test]
    fn unknown_flag_rejected() {
        assert!(matches!(
            cli().parse(&argv("--nope 1")),
            Err(CliError::UnknownFlag(_))
        ));
    }

    #[test]
    fn missing_value_rejected() {
        assert!(matches!(
            cli().parse(&argv("--dataset")),
            Err(CliError::MissingValue(_))
        ));
    }

    #[test]
    fn help_detected() {
        assert!(matches!(cli().parse(&argv("-h")), Err(CliError::Help)));
        let u = cli().usage();
        assert!(u.contains("--model") && u.contains("default: llama"));
    }

    #[test]
    fn list_flag() {
        let c = Cli::new("t").flag("ids", "a,b", "list");
        let a = c.parse(&argv("--ids x,y,z")).unwrap();
        assert_eq!(a.get_list("ids"), vec!["x", "y", "z"]);
    }
}
