//! Minimal JSON implementation (parser + serializer).
//!
//! The offline vendored crate set has no `serde`/`serde_json`, so this is a
//! from-scratch substrate (DESIGN.md §4).  It covers the full JSON grammar
//! (objects, arrays, strings with escapes incl. `\uXXXX` surrogate pairs,
//! numbers, bools, null) and preserves object insertion order, which keeps
//! manifest round-trips stable.

use std::collections::BTreeMap;

/// A JSON value.  Object keys keep insertion order via a side vector.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(JsonObj),
}

/// Order-preserving string→Json map (small maps; linear lookup is fine and
/// avoids hashing).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct JsonObj {
    entries: Vec<(String, Json)>,
}

impl JsonObj {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, key: impl Into<String>, value: impl Into<Json>) {
        let key = key.into();
        if let Some(e) = self.entries.iter_mut().find(|(k, _)| *k == key) {
            e.1 = value.into();
        } else {
            self.entries.push((key, value.into()));
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        self.entries.iter().find(|(k, _)| k == key).map(|(_, v)| v)
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, &Json)> {
        self.entries.iter().map(|(k, v)| (k.as_str(), v))
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.entries.iter().map(|(k, _)| k.as_str())
    }

    /// Sorted copy of the entries (used by tests for canonical comparison).
    pub fn sorted(&self) -> BTreeMap<String, Json> {
        self.entries.iter().cloned().collect()
    }
}

impl Json {
    // -- constructors ------------------------------------------------------
    pub fn obj() -> JsonObj {
        JsonObj::new()
    }

    // -- accessors ---------------------------------------------------------
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&JsonObj> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` style access; returns Null for missing paths.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn idx(&self, i: usize) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Arr(a) => a.get(i).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    // -- parsing -----------------------------------------------------------
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- serialization -----------------------------------------------------
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_str(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.is_finite() && n == n.trunc() && n.abs() < 9e15 {
        out.push_str(&format!("{}", n as i64));
    } else if n.is_finite() {
        out.push_str(&format!("{n}"));
    } else {
        out.push_str("null"); // JSON has no Inf/NaN
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            '\u{08}' => out.push_str("\\b"),
            '\u{0c}' => out.push_str("\\f"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---------------------------------------------------------------------------
// From impls for ergonomic construction
// ---------------------------------------------------------------------------

impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<f32> for Json {
    fn from(n: f32) -> Self {
        Json::Num(n as f64)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Self {
        Json::Num(n as f64)
    }
}
impl From<i32> for Json {
    fn from(n: i32) -> Self {
        Json::Num(n as f64)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Self {
        Json::Num(n as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl From<JsonObj> for Json {
    fn from(o: JsonObj) -> Self {
        Json::Obj(o)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

// ---------------------------------------------------------------------------
// Parser
// ---------------------------------------------------------------------------

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut obj = JsonObj::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(obj));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            obj.entries.push((key, val));
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(obj)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            self.skip_ws();
            arr.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(arr)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{08}'),
                    Some(b'f') => s.push('\u{0c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        if (0xD800..0xDC00).contains(&cp) {
                            // high surrogate: must be followed by \uDC00..
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("lone high surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            s.push(char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?);
                        } else if (0xDC00..0xE000).contains(&cp) {
                            return Err(self.err("lone low surrogate"));
                        } else {
                            s.push(char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?);
                        }
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(b) if b < 0x20 => return Err(self.err("control char in string")),
                Some(b) => {
                    // re-assemble UTF-8 multibyte sequences
                    let len = utf8_len(b);
                    if len == 1 {
                        s.push(b as char);
                    } else {
                        let start = self.pos - 1;
                        for _ in 1..len {
                            self.bump();
                        }
                        let chunk = &self.bytes[start..self.pos];
                        s.push_str(
                            std::str::from_utf8(chunk)
                                .map_err(|_| self.err("invalid utf-8"))?,
                        );
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let b = self.bump().ok_or_else(|| self.err("eof in \\u"))?;
            let d = (b as char)
                .to_digit(16)
                .ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(b: u8) -> usize {
    if b < 0x80 {
        1
    } else if b >> 5 == 0b110 {
        2
    } else if b >> 4 == 0b1110 {
        3
    } else {
        4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a":[1,2,{"b":null}],"c":"x"}"#).unwrap();
        assert_eq!(j.get("a").idx(2).get("b"), &Json::Null);
        assert_eq!(j.get("c").as_str(), Some("x"));
        assert_eq!(j.get("missing"), &Json::Null);
    }

    #[test]
    fn unicode_escapes() {
        let j = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(j.as_str(), Some("é😀"));
    }

    #[test]
    fn raw_utf8_passthrough() {
        let j = Json::parse("\"straße 北京\"").unwrap();
        assert_eq!(j.as_str(), Some("straße 北京"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("02").is_ok()); // lenient: parses as number then trailing -> err
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("\"\\ud800\"").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"name":"percache","n":3,"x":-0.25,"flags":[true,false,null],"nested":{"deep":{"s":"\"quoted\"\n"}}}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
        let j3 = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(j, j3);
    }

    #[test]
    fn object_order_preserved() {
        let j = Json::parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        let keys: Vec<_> = j.as_obj().unwrap().keys().collect();
        assert_eq!(keys, vec!["z", "a", "m"]);
    }

    #[test]
    fn obj_builder() {
        let mut o = Json::obj();
        o.insert("a", 1i64);
        o.insert("b", vec![1i64, 2]);
        o.insert("a", 9i64); // overwrite
        let j = Json::from(o);
        assert_eq!(j.get("a").as_i64(), Some(9));
        assert_eq!(j.get("b").idx(1).as_i64(), Some(2));
    }

    #[test]
    fn integers_serialized_without_decimal() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }
}
