//! Thread pool + scoped parallel map (no `tokio`/`rayon` in the vendored
//! set).  The request loop, idle-time population worker and the bench
//! harness all run on this substrate.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size worker pool with a pending-job counter so callers can block
/// until quiescent (`wait_idle`).
pub struct Pool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
    pending: Arc<(Mutex<usize>, Condvar)>,
}

impl Pool {
    pub fn new(threads: usize) -> Self {
        assert!(threads > 0);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let pending = Arc::new((Mutex::new(0usize), Condvar::new()));
        let mut workers = Vec::with_capacity(threads);
        for i in 0..threads {
            let rx = Arc::clone(&rx);
            let pending = Arc::clone(&pending);
            workers.push(
                thread::Builder::new()
                    .name(format!("percache-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                job();
                                let (lock, cvar) = &*pending;
                                let mut n = lock.lock().unwrap();
                                *n -= 1;
                                if *n == 0 {
                                    cvar.notify_all();
                                }
                            }
                            Err(_) => break, // sender dropped: shutdown
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        Pool {
            tx: Some(tx),
            workers,
            pending,
        }
    }

    /// Pool sized to the machine, capped (PJRT CPU already parallelizes
    /// inside a single execute; the pool is for coordination concurrency).
    pub fn default_size() -> usize {
        thread::available_parallelism()
            .map(|n| n.get().min(8))
            .unwrap_or(4)
    }

    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        {
            let (lock, _) = &*self.pending;
            *lock.lock().unwrap() += 1;
        }
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(job))
            .expect("worker channel closed");
    }

    /// Block until every submitted job has finished.
    pub fn wait_idle(&self) {
        let (lock, cvar) = &*self.pending;
        let mut n = lock.lock().unwrap();
        while *n > 0 {
            n = cvar.wait(n).unwrap();
        }
    }

    pub fn pending(&self) -> usize {
        *self.pending.0.lock().unwrap()
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        self.tx.take(); // close channel; workers drain and exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Scoped parallel map preserving input order.  Spawns up to `threads`
/// OS threads over chunks of `items`; panics in workers propagate.
pub fn parallel_map<T, R, F>(items: Vec<T>, threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.clamp(1, n);
    let next = AtomicUsize::new(0);
    let items: Vec<Mutex<Option<T>>> = items.into_iter().map(|t| Mutex::new(Some(t))).collect();
    let results: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();

    thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let item = items[i].lock().unwrap().take().unwrap();
                let r = f(item);
                *results[i].lock().unwrap() = Some(r);
            });
        }
    });

    results
        .into_iter()
        .map(|m| m.into_inner().unwrap().unwrap())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_all_jobs() {
        let pool = Pool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
        assert_eq!(pool.pending(), 0);
    }

    #[test]
    fn wait_idle_on_empty_pool_returns() {
        let pool = Pool::new(2);
        pool.wait_idle();
    }

    #[test]
    fn drop_joins_workers() {
        let pool = Pool::new(2);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..10 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                std::thread::sleep(std::time::Duration::from_millis(1));
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool);
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn parallel_map_preserves_order() {
        let out = parallel_map((0..500).collect::<Vec<i32>>(), 8, |x| x * 2);
        assert_eq!(out, (0..500).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_empty() {
        let out: Vec<i32> = parallel_map(Vec::<i32>::new(), 4, |x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn parallel_map_single_thread() {
        let out = parallel_map(vec![1, 2, 3], 1, |x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }
}
