//! Deterministic PCG32 random number generator.
//!
//! The vendored crate set has no `rand`, so this substrate provides the
//! reproducible randomness the dataset generators, property tests and
//! benchmarks need.  PCG-XSH-RR 64/32 (O'Neill 2014) — small, fast,
//! statistically solid for simulation purposes.

/// PCG-XSH-RR 64/32.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    inc: u64,
}

const MULT: u64 = 6364136223846793005;

impl Rng {
    /// Seeded constructor; distinct `stream` values give independent
    /// sequences for the same seed.
    pub fn seeded(seed: u64, stream: u64) -> Self {
        let mut rng = Rng {
            state: 0,
            inc: (stream << 1) | 1,
        };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    pub fn new(seed: u64) -> Self {
        Self::seeded(seed, 0xda3e39cb94b95bdb)
    }

    /// Derive an independent child generator (for per-user / per-module
    /// streams that must not perturb each other).
    pub fn fork(&mut self, tag: u64) -> Rng {
        let seed = self.next_u64() ^ tag.wrapping_mul(0x9e3779b97f4a7c15);
        Rng::seeded(seed, tag | 1)
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, bound) without modulo bias (Lemire).
    pub fn below(&mut self, bound: usize) -> usize {
        assert!(bound > 0, "below(0)");
        let bound = bound as u64;
        loop {
            let x = self.next_u64();
            let (hi, lo) = mul_hilo(x, bound);
            if lo >= bound || lo >= x.wrapping_neg() % bound {
                return hi as usize;
            }
        }
    }

    /// Uniform in [lo, hi] inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick a reference to a random element.
    pub fn pick<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len())]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            items.swap(i, self.below(i + 1));
        }
    }

    /// Sample `k` distinct indices from 0..n (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Geometric-ish integer: number of successes before failure, capped.
    pub fn geometric(&mut self, p: f64, cap: usize) -> usize {
        let mut n = 0;
        while n < cap && self.chance(p) {
            n += 1;
        }
        n
    }

    /// Weighted index pick; weights need not be normalized.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "all-zero weights");
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[inline]
fn mul_hilo(a: u64, b: u64) -> (u64, u64) {
    let wide = (a as u128) * (b as u128);
    ((wide >> 64) as u64, wide as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u32()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u32()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_unbiased_coverage() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.below(7)] += 1;
        }
        for c in counts {
            assert!((8500..11500).contains(&c), "skewed: {counts:?}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn weighted_respects_weights() {
        let mut r = Rng::new(13);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.weighted(&[1.0, 2.0, 7.0])] += 1;
        }
        assert!(counts[2] > counts[1] && counts[1] > counts[0]);
        assert!((counts[2] as f64 / 30_000.0 - 0.7).abs() < 0.03);
    }

    #[test]
    fn fork_independent() {
        let mut root = Rng::new(5);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(17);
        let s = r.sample_indices(20, 10);
        let mut d = s.clone();
        d.sort();
        d.dedup();
        assert_eq!(d.len(), 10);
    }
}
