//! Poison-recovering lock acquisition.
//!
//! A `std::sync` lock is poisoned when a holder panics.  On our serve
//! paths that must not cascade: the data a panicking holder was
//! mutating is per-request scratch or monotonic telemetry, and the
//! surviving threads (router loops, the hydration worker, metric
//! scrapes) are more useful running with possibly-stale state than
//! dead.  These helpers recover the guard from a poisoned lock
//! instead of propagating the panic, which is the crate-wide policy
//! the `panic_path` analysis rule enforces (DESIGN.md §13).
//!
//! Deliberately metric-free: the obs registry itself locks through
//! these helpers, so emitting telemetry here could recurse.

use std::sync::{Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard};

/// Lock a mutex, recovering the guard if a previous holder panicked.
pub fn lock_or_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Read-lock an `RwLock`, recovering the guard if poisoned.
pub fn read_or_recover<T>(l: &RwLock<T>) -> RwLockReadGuard<'_, T> {
    l.read().unwrap_or_else(|e| e.into_inner())
}

/// Write-lock an `RwLock`, recovering the guard if poisoned.
pub fn write_or_recover<T>(l: &RwLock<T>) -> RwLockWriteGuard<'_, T> {
    l.write().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex, RwLock};

    #[test]
    fn recovers_poisoned_mutex() {
        let m = Arc::new(Mutex::new(7u32));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(m.is_poisoned());
        assert_eq!(*lock_or_recover(&m), 7);
        *lock_or_recover(&m) = 8;
        assert_eq!(*lock_or_recover(&m), 8);
    }

    #[test]
    fn recovers_poisoned_rwlock() {
        let l = Arc::new(RwLock::new(1u32));
        let l2 = Arc::clone(&l);
        let _ = std::thread::spawn(move || {
            let _g = l2.write().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(l.is_poisoned());
        assert_eq!(*read_or_recover(&l), 1);
        *write_or_recover(&l) = 2;
        assert_eq!(*read_or_recover(&l), 2);
    }

    #[test]
    fn plain_path_unaffected() {
        let m = Mutex::new(vec![1, 2]);
        lock_or_recover(&m).push(3);
        assert_eq!(lock_or_recover(&m).len(), 3);
    }
}
