//! Cross-tenant content-addressed slice pool (DESIGN.md §15).
//!
//! Identical public chunks (same segment content hash) used to be
//! cached once *per tenant shard*; this module stores each such slice
//! exactly once, device-wide, beneath the per-tenant [`SliceStore`]s.
//! Shards intern shared-eligible slices here (refcounted per tenant),
//! keep a tiny fixed-size handle in their own accounting, and copy a
//! slice back out (copy-on-write) if they ever need a private mutable
//! version.  The governor charges each tenant its exclusive bytes plus
//! an amortized share of pooled bytes (`bytes / refcount`, largest-
//! remainder rounded so shares sum exactly), which is what keeps plans
//! summing exactly to the global budget.
//!
//! Eviction is refcount-and-LFU aware: only zero-reference entries are
//! evictable (an entry a live tree still points at is never dropped
//! under it), least-frequently-used first.  When the pool is full of
//! referenced entries an intern is *rejected* and the caller falls back
//! to a private copy — correctness never depends on pool admission.
//!
//! On-disk pools carry their own versioned manifest
//! (`pool_manifest.json`) with per-entry content key, byte size and
//! checksum.  Refcounts are deliberately *not* persisted: on a warm
//! restart every entry reopens at zero references and each shard's own
//! manifest re-acquires its references as it reopens (per-tenant
//! refcount rebuild), so a tenant that never comes back can never strand
//! pool bytes.
//!
//! [`SliceStore`]: crate::cache::SliceStore

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::llm::QkvTensor;
use crate::tokenizer::fnv1a64;
use crate::util::json::Json;
use crate::util::sync::lock_or_recover;

/// Segment content hash (the QKV tree's `SegKey`).
pub type PoolKey = u64;
/// Tenant identity as the pool sees it (matches `tenancy::TenantId`).
pub type PoolTenant = u32;

/// Bytes a pooled slice charges to its owning shard's budget: the
/// handle (id → content key mapping + refcount), not the payload.  The
/// payload is charged once, globally, via the governor's reserve.
pub const HANDLE_BYTES: usize = 16;

/// Pool manifest schema version; readers reject anything else.
pub const POOL_MANIFEST_VERSION: usize = 1;
/// Manifest file name inside a pool directory.
pub const POOL_MANIFEST_FILE: &str = "pool_manifest.json";
const POOL_MANIFEST_MAGIC: &str = "percache-pool";

/// One pooled slice: payload (lazily loaded for disk pools), encoded
/// byte size, per-tenant reference counts and an LFU frequency.
struct PoolEntry {
    tensor: Option<Arc<QkvTensor>>,
    bytes: usize,
    checksum: u64,
    refs: HashMap<PoolTenant, usize>,
    freq: u64,
}

impl PoolEntry {
    fn refcount(&self) -> usize {
        self.refs.values().sum()
    }
}

/// Global content-addressed, read-only slice pool.
pub struct SlicePool {
    dir: Option<PathBuf>,
    cap_bytes: usize,
    entries: HashMap<PoolKey, PoolEntry>,
    bytes_used: usize,
    /// Interns rejected because the pool was full of referenced entries.
    pub rejected: u64,
    /// Entries dropped for a payload checksum mismatch.
    pub quarantined: u64,
}

impl SlicePool {
    /// In-memory pool (the sim / single-process path).
    pub fn memory(cap_bytes: usize) -> Self {
        SlicePool {
            dir: None,
            cap_bytes,
            entries: HashMap::new(),
            bytes_used: 0,
            rejected: 0,
            quarantined: 0,
        }
    }

    /// Open (or create) an on-disk pool.  An existing directory is
    /// resumed from its manifest; every entry reopens at zero
    /// references (shards re-acquire theirs as they reopen).  If the
    /// cap shrank since the manifest was written, excess entries are
    /// evicted LFU-first right away.
    pub fn disk(dir: PathBuf, cap_bytes: usize) -> Result<Self> {
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating pool dir {}", dir.display()))?;
        let mut pool = SlicePool {
            dir: Some(dir),
            cap_bytes,
            entries: HashMap::new(),
            bytes_used: 0,
            rejected: 0,
            quarantined: 0,
        };
        pool.open_dir()?;
        Ok(pool)
    }

    /// Wrap a pool for sharing across shards.
    pub fn shared(self) -> Arc<Mutex<SlicePool>> {
        Arc::new(Mutex::new(self))
    }

    fn open_dir(&mut self) -> Result<()> {
        let dir = match &self.dir {
            None => return Ok(()),
            Some(d) => d.clone(),
        };
        let manifest = dir.join(POOL_MANIFEST_FILE);
        if manifest.exists() {
            let text = std::fs::read_to_string(&manifest)
                .with_context(|| format!("reading {}", manifest.display()))?;
            self.load_manifest(&text)
                .with_context(|| format!("invalid pool manifest {}", manifest.display()))?;
        }
        // drop entries whose payload file is missing or mis-sized, and
        // payload files with no manifest entry
        let keys: Vec<PoolKey> = self.entries.keys().copied().collect();
        for key in keys {
            let p = dir.join(pool_file_name(key));
            let ok = match std::fs::metadata(&p) {
                Ok(m) => m.len() as usize == self.entries[&key].bytes,
                Err(_) => false,
            };
            if !ok {
                let e = self.entries.remove(&key).expect("key from entries");
                self.bytes_used -= e.bytes;
                let _ = std::fs::remove_file(&p);
            }
        }
        for entry in
            std::fs::read_dir(&dir).with_context(|| format!("listing {}", dir.display()))?
        {
            let entry = entry?;
            let name = entry.file_name().to_string_lossy().into_owned();
            if let Some(key) = parse_pool_file_name(&name) {
                if !self.entries.contains_key(&key) {
                    let _ = std::fs::remove_file(entry.path());
                }
            }
        }
        // a shrunk cap evicts (everything is zero-ref at open)
        while self.bytes_used > self.cap_bytes {
            if !self.evict_one() {
                break;
            }
        }
        if self.bytes_used != 0 {
            crate::obs_gauge!("pool.resident_bytes").add(self.bytes_used as i64);
            crate::obs_gauge!("pool.entries").add(self.entries.len() as i64);
        }
        self.write_manifest()
    }

    fn load_manifest(&mut self, text: &str) -> Result<()> {
        let j = Json::parse(text).context("parsing json")?;
        anyhow::ensure!(
            j.get("magic").as_str() == Some(POOL_MANIFEST_MAGIC),
            "missing or wrong magic (want {POOL_MANIFEST_MAGIC:?})"
        );
        let version = j.get("version").as_usize().context("missing version")?;
        anyhow::ensure!(
            version == POOL_MANIFEST_VERSION,
            "unsupported pool manifest version {version} (reader supports {POOL_MANIFEST_VERSION})"
        );
        let entries = j.get("entries").as_arr().context("missing entries array")?;
        for e in entries {
            let key_hex = e.get("key").as_str().context("entry missing key")?;
            let key = PoolKey::from_str_radix(key_hex, 16)
                .with_context(|| format!("bad key hex {key_hex:?}"))?;
            let bytes = e.get("bytes").as_usize().context("entry missing bytes")?;
            let sum_hex = e.get("checksum").as_str().context("entry missing checksum")?;
            let checksum = u64::from_str_radix(sum_hex, 16)
                .with_context(|| format!("bad checksum hex {sum_hex:?}"))?;
            let freq = e.get("freq").as_usize().unwrap_or(0) as u64;
            anyhow::ensure!(
                !self.entries.contains_key(&key),
                "duplicate pool key {key:016x}"
            );
            self.entries.insert(
                key,
                PoolEntry {
                    tensor: None,
                    bytes,
                    checksum,
                    refs: HashMap::new(),
                    freq,
                },
            );
            self.bytes_used += bytes;
        }
        Ok(())
    }

    /// Atomically (tmp + rename) persist the manifest.  No-op in memory.
    fn write_manifest(&self) -> Result<()> {
        let dir = match &self.dir {
            None => return Ok(()),
            Some(d) => d,
        };
        let mut root = Json::obj();
        root.insert("magic", POOL_MANIFEST_MAGIC);
        root.insert("version", POOL_MANIFEST_VERSION);
        let mut keys: Vec<PoolKey> = self.entries.keys().copied().collect();
        keys.sort_unstable();
        let entries: Vec<Json> = keys
            .iter()
            .map(|key| {
                let e = &self.entries[key];
                let mut o = Json::obj();
                o.insert("key", format!("{key:016x}"));
                o.insert("bytes", e.bytes);
                o.insert("checksum", format!("{:016x}", e.checksum));
                o.insert("freq", e.freq as usize);
                Json::Obj(o)
            })
            .collect();
        root.insert("entries", Json::Arr(entries));

        let tmp = dir.join(format!("{POOL_MANIFEST_FILE}.tmp"));
        let fin = dir.join(POOL_MANIFEST_FILE);
        std::fs::write(&tmp, Json::Obj(root).to_string_pretty())
            .with_context(|| format!("writing {}", tmp.display()))?;
        std::fs::rename(&tmp, &fin)
            .with_context(|| format!("committing {}", fin.display()))?;
        Ok(())
    }

    /// Intern a slice under its content key for `tenant`.  Returns true
    /// if the pool now holds a reference for the caller (existing entry
    /// → refcount bump; new entry → admitted under the cap).  False
    /// means rejected — the caller must keep a private copy.
    pub fn intern(&mut self, key: PoolKey, tensor: &QkvTensor, tenant: PoolTenant) -> bool {
        if let Some(e) = self.entries.get_mut(&key) {
            *e.refs.entry(tenant).or_insert(0) += 1;
            e.freq += 1;
            crate::obs_counter!("pool.ref_hits").inc();
            return true;
        }
        let bytes = tensor.byte_size() + 16;
        while self.bytes_used + bytes > self.cap_bytes {
            if !self.evict_one() {
                self.rejected += 1;
                crate::obs_counter!("pool.rejected").inc();
                return false;
            }
        }
        let payload = encode_pool_slice(tensor);
        debug_assert_eq!(payload.len(), bytes);
        let checksum = fnv1a64(&payload);
        if let Some(dir) = &self.dir {
            let p = dir.join(pool_file_name(key));
            if std::fs::write(&p, &payload).is_err() {
                let _ = std::fs::remove_file(&p);
                self.rejected += 1;
                crate::obs_counter!("pool.rejected").inc();
                return false;
            }
        }
        let mut refs = HashMap::new();
        refs.insert(tenant, 1usize);
        self.entries.insert(
            key,
            PoolEntry {
                tensor: Some(Arc::new(tensor.clone())),
                bytes,
                checksum,
                refs,
                freq: 1,
            },
        );
        self.bytes_used += bytes;
        // best-effort: a failed manifest write self-heals at the next
        // open (the payload file is adopted or GC'd there)
        let _ = self.write_manifest();
        crate::obs_counter!("pool.interns").inc();
        crate::obs_gauge!("pool.resident_bytes").add(bytes as i64);
        crate::obs_gauge!("pool.entries").add(1);
        true
    }

    /// Re-acquire a reference to an existing entry without a payload
    /// (the warm-restart refcount rebuild).  Returns the entry's byte
    /// size, or None if the pool no longer holds the key.
    pub fn acquire(&mut self, key: PoolKey, tenant: PoolTenant) -> Option<usize> {
        let e = self.entries.get_mut(&key)?;
        *e.refs.entry(tenant).or_insert(0) += 1;
        Some(e.bytes)
    }

    /// Load a pooled slice (lazily from disk for on-disk pools, with
    /// checksum verification; a corrupt payload is quarantined — entry
    /// and file dropped — rather than left to fail forever).
    pub fn get(&mut self, key: PoolKey) -> Option<Arc<QkvTensor>> {
        let dir = self.dir.clone();
        let e = self.entries.get_mut(&key)?;
        e.freq += 1;
        if let Some(t) = &e.tensor {
            crate::obs_counter!("pool.ref_hits").inc();
            return Some(Arc::clone(t));
        }
        let p = dir.as_deref()?.join(pool_file_name(key));
        let buf = std::fs::read(&p).ok();
        let decoded = buf.and_then(|buf| {
            if fnv1a64(&buf) != e.checksum {
                return None;
            }
            decode_pool_slice(&buf).ok()
        });
        match decoded {
            Some(t) => {
                let arc = Arc::new(t);
                e.tensor = Some(Arc::clone(&arc));
                crate::obs_counter!("pool.ref_hits").inc();
                Some(arc)
            }
            None => {
                // quarantine: a torn/corrupt payload must not wedge
                // every referencing tenant forever
                let e = self.entries.remove(&key).expect("entry exists");
                self.bytes_used -= e.bytes;
                let _ = std::fs::remove_file(&p);
                let _ = self.write_manifest();
                self.quarantined += 1;
                crate::obs_gauge!("pool.resident_bytes").sub(e.bytes as i64);
                crate::obs_gauge!("pool.entries").sub(1);
                crate::obs::emit(
                    crate::obs::Event::new("pool.quarantined")
                        .field("key", key as f64)
                        .field("bytes", e.bytes as f64),
                );
                None
            }
        }
    }

    /// Drop one of `tenant`'s references to `key`.  A zero-reference
    /// entry stays resident (warm) until capacity pressure evicts it.
    pub fn release(&mut self, key: PoolKey, tenant: PoolTenant) {
        if let Some(e) = self.entries.get_mut(&key) {
            if let Some(n) = e.refs.get_mut(&tenant) {
                *n -= 1;
                if *n == 0 {
                    e.refs.remove(&tenant);
                }
                crate::obs_counter!("pool.releases").inc();
            }
        }
    }

    /// Evict the least-frequently-used zero-reference entry.  Returns
    /// false when every resident entry is still referenced.
    fn evict_one(&mut self) -> bool {
        let victim = self
            .entries
            .iter()
            .filter(|(_, e)| e.refs.is_empty())
            .min_by_key(|(k, e)| (e.freq, **k))
            .map(|(k, _)| *k);
        let key = match victim {
            None => return false,
            Some(k) => k,
        };
        let e = self.entries.remove(&key).expect("victim exists");
        self.bytes_used -= e.bytes;
        if let Some(dir) = &self.dir {
            let _ = std::fs::remove_file(dir.join(pool_file_name(key)));
            let _ = self.write_manifest();
        }
        crate::obs_counter!("pool.evictions").inc();
        crate::obs_gauge!("pool.resident_bytes").sub(e.bytes as i64);
        crate::obs_gauge!("pool.entries").sub(1);
        crate::obs::emit(
            crate::obs::Event::new("pool.evicted")
                .field("key", key as f64)
                .field("freed_bytes", e.bytes as f64),
        );
        true
    }

    /// Trim zero-reference entries until the pool fits its cap (called
    /// after the cap shrinks or a big release wave, e.g. a demotion).
    pub fn enforce(&mut self) {
        while self.bytes_used > self.cap_bytes {
            if !self.evict_one() {
                break;
            }
        }
    }

    pub fn contains(&self, key: PoolKey) -> bool {
        self.entries.contains_key(&key)
    }

    /// Total references to `key` across all tenants (0 if absent).
    pub fn refcount(&self, key: PoolKey) -> usize {
        self.entries.get(&key).map(|e| e.refcount()).unwrap_or(0)
    }

    /// Total references `tenant` holds across all entries — must equal
    /// the tenant store's live pooled-slice count at every quiescent
    /// point (the no-leak/no-premature-free property tests key on it).
    pub fn refs_of(&self, tenant: PoolTenant) -> usize {
        self.entries
            .values()
            .map(|e| e.refs.get(&tenant).copied().unwrap_or(0))
            .sum()
    }

    pub fn bytes_used(&self) -> usize {
        self.bytes_used
    }

    /// Bytes of entries at least one tenant still references.
    pub fn referenced_bytes(&self) -> usize {
        self.entries
            .values()
            .filter(|e| !e.refs.is_empty())
            .map(|e| e.bytes)
            .sum()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn cap_bytes(&self) -> usize {
        self.cap_bytes
    }

    /// Amortized per-tenant shares of referenced pool bytes: each entry
    /// charges `bytes × tenant_refs / refcount` per tenant, rounded by
    /// largest remainder (deterministic: ties to the lower tenant id)
    /// so per-entry shares sum *exactly* to the entry's bytes — and the
    /// map's values sum exactly to [`Self::referenced_bytes`].
    pub fn amortized_shares(&self) -> HashMap<PoolTenant, usize> {
        let mut shares: HashMap<PoolTenant, usize> = HashMap::new();
        for e in self.entries.values() {
            let total = e.refcount();
            if total == 0 {
                continue;
            }
            let mut tenants: Vec<(PoolTenant, usize)> =
                e.refs.iter().map(|(&t, &n)| (t, n)).collect();
            tenants.sort_unstable_by_key(|&(t, _)| t);
            let mut assigned = 0usize;
            // base share per tenant, remainder tracked for rounding
            let mut rema: Vec<(usize, PoolTenant)> = Vec::with_capacity(tenants.len());
            for &(t, n) in &tenants {
                let exact = e.bytes * n;
                let base = exact / total;
                *shares.entry(t).or_insert(0) += base;
                assigned += base;
                rema.push((exact % total, t));
            }
            // largest remainder first; ties broken toward lower ids
            rema.sort_unstable_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
            let mut leftover = e.bytes - assigned;
            for &(_, t) in &rema {
                if leftover == 0 {
                    break;
                }
                *shares.entry(t).or_insert(0) += 1;
                leftover -= 1;
            }
        }
        shares
    }

    /// Internal-consistency audit (tests + debug builds).
    pub fn check_invariants(&self) -> Result<()> {
        let sum: usize = self.entries.values().map(|e| e.bytes).sum();
        anyhow::ensure!(
            sum == self.bytes_used,
            "pool bytes_used {} != entry sum {}",
            self.bytes_used,
            sum
        );
        for (k, e) in &self.entries {
            anyhow::ensure!(
                e.refs.values().all(|&n| n > 0),
                "pool entry {k:016x} holds a zero refcount"
            );
        }
        let shares: usize = self.amortized_shares().values().sum();
        anyhow::ensure!(
            shares == self.referenced_bytes(),
            "amortized shares {} != referenced bytes {}",
            shares,
            self.referenced_bytes()
        );
        Ok(())
    }
}

impl Drop for SlicePool {
    fn drop(&mut self) {
        // keep the global gauges consistent when a pool goes away
        if self.bytes_used != 0 {
            crate::obs_gauge!("pool.resident_bytes").sub(self.bytes_used as i64);
            crate::obs_gauge!("pool.entries").sub(self.entries.len() as i64);
        }
    }
}

/// A tenant-scoped handle to the shared pool: what a [`SliceStore`]
/// holds.  Cheap to clone; all methods lock internally (poison-
/// recovering, per the crate-wide policy).
///
/// [`SliceStore`]: crate::cache::SliceStore
#[derive(Clone)]
pub struct PoolHandle {
    pool: Arc<Mutex<SlicePool>>,
    tenant: PoolTenant,
}

impl PoolHandle {
    pub fn new(pool: Arc<Mutex<SlicePool>>, tenant: PoolTenant) -> Self {
        PoolHandle { pool, tenant }
    }

    pub fn tenant(&self) -> PoolTenant {
        self.tenant
    }

    pub fn intern(&self, key: PoolKey, tensor: &QkvTensor) -> bool {
        lock_or_recover(&self.pool).intern(key, tensor, self.tenant)
    }

    pub fn acquire(&self, key: PoolKey) -> Option<usize> {
        lock_or_recover(&self.pool).acquire(key, self.tenant)
    }

    pub fn get(&self, key: PoolKey) -> Option<Arc<QkvTensor>> {
        lock_or_recover(&self.pool).get(key)
    }

    /// Position-aware reuse probe: is this chunk's KV resident and
    /// composable, regardless of which offset it was cached at?
    pub fn probe(&self, key: PoolKey) -> Option<Arc<QkvTensor>> {
        lock_or_recover(&self.pool).get(key)
    }

    pub fn release(&self, key: PoolKey) {
        lock_or_recover(&self.pool).release(key, self.tenant)
    }
}

impl std::fmt::Debug for PoolHandle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PoolHandle").field("tenant", &self.tenant).finish()
    }
}

fn pool_file_name(key: PoolKey) -> String {
    format!("pool_{key:016x}.qkv")
}

fn parse_pool_file_name(name: &str) -> Option<PoolKey> {
    let hex = name.strip_prefix("pool_")?.strip_suffix(".qkv")?;
    PoolKey::from_str_radix(hex, 16).ok()
}

// Pool payload files reuse the slice store's wire format (16-byte
// header + f32 LE data) via these thin wrappers so the two never drift.
fn encode_pool_slice(tensor: &QkvTensor) -> Vec<u8> {
    crate::cache::store::encode_slice(tensor)
}

fn decode_pool_slice(buf: &[u8]) -> Result<QkvTensor> {
    crate::cache::store::decode_slice(buf)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tensor(seed: f32) -> QkvTensor {
        let mut t = QkvTensor::zeros(1, 4, 8);
        for (i, v) in t.data.iter_mut().enumerate() {
            *v = seed + i as f32;
        }
        t
    }

    fn slice_bytes() -> usize {
        tensor(0.0).byte_size() + 16
    }

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("percache_pool_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn intern_dedups_and_refcounts() {
        let mut p = SlicePool::memory(10 * slice_bytes());
        let t = tensor(1.0);
        assert!(p.intern(42, &t, 0));
        assert!(p.intern(42, &t, 1));
        assert!(p.intern(42, &t, 1));
        assert_eq!(p.len(), 1, "same content stored once");
        assert_eq!(p.refcount(42), 3);
        assert_eq!(p.bytes_used(), slice_bytes());
        p.release(42, 1);
        assert_eq!(p.refcount(42), 2);
        p.check_invariants().unwrap();
    }

    #[test]
    fn get_shares_one_allocation() {
        let mut p = SlicePool::memory(10 * slice_bytes());
        p.intern(7, &tensor(2.0), 0);
        let a = p.get(7).unwrap();
        let b = p.get(7).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "gets must share the pooled payload");
        assert_eq!(*a, tensor(2.0));
    }

    #[test]
    fn referenced_entries_never_evict() {
        let mut p = SlicePool::memory(2 * slice_bytes());
        assert!(p.intern(1, &tensor(1.0), 0));
        assert!(p.intern(2, &tensor(2.0), 0));
        // full of referenced entries: a third intern is rejected
        assert!(!p.intern(3, &tensor(3.0), 0));
        assert_eq!(p.rejected, 1);
        assert!(p.contains(1) && p.contains(2));
        // release one → it becomes the LFU victim and 3 fits
        p.release(1, 0);
        assert!(p.intern(3, &tensor(3.0), 0));
        assert!(!p.contains(1), "zero-ref LFU entry evicted");
        assert!(p.contains(2) && p.contains(3));
        p.check_invariants().unwrap();
    }

    #[test]
    fn lfu_picks_coldest_zero_ref_victim() {
        let mut p = SlicePool::memory(2 * slice_bytes());
        p.intern(1, &tensor(1.0), 0);
        p.intern(2, &tensor(2.0), 0);
        // heat up 2, then drop all refs
        let _ = p.get(2);
        let _ = p.get(2);
        p.release(1, 0);
        p.release(2, 0);
        assert!(p.intern(3, &tensor(3.0), 0));
        assert!(!p.contains(1), "colder entry is the victim");
        assert!(p.contains(2));
    }

    #[test]
    fn amortized_shares_sum_exactly() {
        let mut p = SlicePool::memory(100 * slice_bytes());
        // entry A: 3 tenants; entry B: 2 tenants (one twice); C: zero-ref
        p.intern(1, &tensor(1.0), 0);
        p.intern(1, &tensor(1.0), 1);
        p.intern(1, &tensor(1.0), 2);
        p.intern(2, &tensor(2.0), 0);
        p.intern(2, &tensor(2.0), 0);
        p.intern(2, &tensor(2.0), 3);
        p.intern(3, &tensor(3.0), 5);
        p.release(3, 5);
        let shares = p.amortized_shares();
        let total: usize = shares.values().sum();
        assert_eq!(total, p.referenced_bytes());
        assert_eq!(p.referenced_bytes(), 2 * slice_bytes());
        // tenant 0 holds 1/3 of A and 2/3 of B → the largest share
        assert!(shares[&0] > shares[&3]);
        assert!(!shares.contains_key(&5), "zero-ref entry charges nobody");
        p.check_invariants().unwrap();
    }

    #[test]
    fn disk_pool_survives_reopen_at_zero_refs() {
        let dir = tmp_dir("reopen");
        let t = tensor(4.0);
        {
            let mut p = SlicePool::disk(dir.clone(), 10 * slice_bytes()).unwrap();
            assert!(p.intern(0xAB, &t, 0));
            assert_eq!(p.refcount(0xAB), 1);
        }
        let mut p = SlicePool::disk(dir.clone(), 10 * slice_bytes()).unwrap();
        assert!(p.contains(0xAB));
        assert_eq!(p.refcount(0xAB), 0, "refcounts are rebuilt by shards");
        assert_eq!(p.acquire(0xAB, 3), Some(slice_bytes()));
        assert_eq!(*p.get(0xAB).unwrap(), t, "payload reloads from disk");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn disk_pool_quarantines_corrupt_payload() {
        let dir = tmp_dir("corrupt");
        {
            let mut p = SlicePool::disk(dir.clone(), 10 * slice_bytes()).unwrap();
            assert!(p.intern(9, &tensor(1.0), 0));
        }
        // corrupt the payload, keeping the length (reopen validates len)
        let p_file = dir.join(pool_file_name(9));
        let mut buf = std::fs::read(&p_file).unwrap();
        let last = buf.len() - 1;
        buf[last] ^= 0xFF;
        std::fs::write(&p_file, &buf).unwrap();
        let mut p = SlicePool::disk(dir.clone(), 10 * slice_bytes()).unwrap();
        assert!(p.get(9).is_none(), "corrupt payload must not decode");
        assert_eq!(p.quarantined, 1);
        assert!(!p.contains(9), "quarantined entry is gone");
        assert!(!p_file.exists(), "quarantined payload file is GC'd");
        assert!(p.get(9).is_none(), "and it stays gone");
        p.check_invariants().unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn shrunk_cap_evicts_at_open() {
        let dir = tmp_dir("shrink");
        {
            let mut p = SlicePool::disk(dir.clone(), 10 * slice_bytes()).unwrap();
            for k in 0..4u64 {
                assert!(p.intern(k, &tensor(k as f32), 0));
            }
        }
        let p = SlicePool::disk(dir.clone(), 2 * slice_bytes()).unwrap();
        assert_eq!(p.len(), 2, "reopen under a smaller cap trims LFU-first");
        assert!(p.bytes_used() <= p.cap_bytes());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn handle_routes_tenant_identity() {
        let pool = SlicePool::memory(10 * slice_bytes()).shared();
        let h0 = PoolHandle::new(Arc::clone(&pool), 0);
        let h1 = PoolHandle::new(Arc::clone(&pool), 1);
        assert!(h0.intern(5, &tensor(0.5)));
        assert!(h1.intern(5, &tensor(0.5)));
        assert_eq!(lock_or_recover(&pool).refcount(5), 2);
        h0.release(5);
        assert_eq!(lock_or_recover(&pool).refcount(5), 1);
        assert!(h1.probe(5).is_some());
    }
}
