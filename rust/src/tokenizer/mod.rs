//! Deterministic hashing word tokenizer — byte-identical mirror of
//! `python/compile/tokenizer.py`.
//!
//! Parity is enforced two ways: a pinned FNV test vector here, and the
//! `artifacts/tokenizer_fixtures.json` vectors generated at AOT time and
//! replayed by `rust/tests/integration.rs`.  The tokenizer must never
//! drift between the build path (python encodes goldens/fixtures) and the
//! serve path (rust encodes every prompt).

/// Reserved token ids (must match python/compile/configs.py).
pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const EOS: i32 = 2;
pub const UNK: i32 = 3;
pub const RESERVED: i32 = 16;

pub const VOCAB: i32 = 8192;

/// One prompt segment in tokens (system prompt / chunk / query unit).
pub const SEGMENT_TOKENS: usize = 64;

const FNV_OFFSET: u64 = 0xCBF2_9CE4_8422_2325;
const FNV_PRIME: u64 = 0x1_0000_0001_B3;

/// FNV-1a 64-bit over raw bytes.
pub fn fnv1a64(data: &[u8]) -> u64 {
    let mut h = FNV_OFFSET;
    for &b in data {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Lowercase alphanumeric word split (mirror of tokenizer.words).
pub fn words(text: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut cur = String::new();
    for ch in text.chars().flat_map(|c| c.to_lowercase()) {
        if ch.is_ascii_lowercase() || ch.is_ascii_digit() {
            cur.push(ch);
        } else if !cur.is_empty() {
            out.push(std::mem::take(&mut cur));
        }
    }
    if !cur.is_empty() {
        out.push(cur);
    }
    out
}

/// Stable id for one word.
pub fn word_id(word: &str) -> i32 {
    (fnv1a64(word.as_bytes()) % (VOCAB - RESERVED) as u64) as i32 + RESERVED
}

/// Encode text to token ids (no padding).
pub fn encode(text: &str) -> Vec<i32> {
    words(text).iter().map(|w| word_id(w)).collect()
}

/// Encode into exactly one segment: truncate or right-pad with PAD.
pub fn encode_segment(text: &str) -> Vec<i32> {
    let mut ids = encode(text);
    ids.truncate(SEGMENT_TOKENS);
    ids.resize(SEGMENT_TOKENS, PAD);
    ids
}

/// Number of real (non-PAD) tokens in a segment-padded slice.
pub fn real_len(tokens: &[i32]) -> usize {
    tokens.iter().filter(|&&t| t != PAD).count()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv_pinned_vectors() {
        // Same vectors as python/tests/test_tokenizer.py — pins the hash.
        assert_eq!(fnv1a64(b"a"), 0xAF63_DC4C_8601_EC8C);
        assert_eq!(fnv1a64(b""), 0xCBF2_9CE4_8422_2325);
    }

    #[test]
    fn case_and_punct_insensitive() {
        assert_eq!(encode("Hello, WORLD!"), encode("hello world"));
    }

    #[test]
    fn splits_numbers_and_words() {
        assert_eq!(words("meeting at 3pm room B-12"),
                   vec!["meeting", "at", "3pm", "room", "b", "12"]);
    }

    #[test]
    fn segment_pads_and_truncates() {
        let seg = encode_segment("one two three");
        assert_eq!(seg.len(), SEGMENT_TOKENS);
        assert_eq!(&seg[3..], vec![PAD; SEGMENT_TOKENS - 3].as_slice());
        let long = encode_segment(&"w ".repeat(200));
        assert_eq!(long.len(), SEGMENT_TOKENS);
        assert!(!long.contains(&PAD));
    }

    #[test]
    fn ids_in_range() {
        for id in encode("the quick brown fox 42 jumps") {
            assert!((RESERVED..VOCAB).contains(&id));
        }
    }

    #[test]
    fn unicode_words_filtered_consistently() {
        // Only ASCII alnum survives; multi-byte letters act as separators.
        assert_eq!(words("café straße 北京"), vec!["caf", "stra", "e"]);
    }

    #[test]
    fn empty_input() {
        assert!(encode("").is_empty());
        assert_eq!(encode_segment(""), vec![PAD; SEGMENT_TOKENS]);
        assert_eq!(real_len(&encode_segment("")), 0);
    }
}
