//! Knowledge bank: the user's personal data, segmented into fixed-length
//! chunks with embeddings, plus the knowledge abstract used by
//! knowledge-based query prediction (paper §4.1.1–4.1.2).
//!
//! A chunk is exactly one 64-token prompt segment; the chunk is also the
//! node unit of the QKV cache tree, so "chunk" and "cacheable segment" are
//! the same thing throughout the system.

use std::collections::HashMap;

use anyhow::Result;

use crate::embedding::{Embedder, Embedding};
use crate::tokenizer::{self, SEGMENT_TOKENS};

pub type ChunkId = usize;

/// Words per chunk when splitting documents.  Kept below SEGMENT_TOKENS so
/// the encoded segment never truncates (the paper fixes 100-word chunks
/// for a larger token budget; the ratio is the same).
pub const CHUNK_WORDS: usize = 48;

#[derive(Debug, Clone)]
pub struct Chunk {
    pub id: ChunkId,
    pub text: String,
    /// Segment-padded token ids (length SEGMENT_TOKENS).
    pub tokens: Vec<i32>,
    pub embedding: Embedding,
    /// Content hash — the QKV tree's node key (§4.2.2 matches by text).
    pub key: u64,
}

#[derive(Debug, Default)]
pub struct KnowledgeBank {
    chunks: Vec<Chunk>,
    /// Document-frequency table over chunk words (for TF-IDF abstracts).
    df: HashMap<String, usize>,
    /// Chunks added since the last abstract refresh (batch processing —
    /// §4.1.2 "batch-processes multiple chunks").
    pending_abstract: Vec<ChunkId>,
}

impl KnowledgeBank {
    pub fn new() -> Self {
        Self::default()
    }

    /// Split a document into CHUNK_WORDS-word chunks and add each.
    pub fn add_document(&mut self, text: &str, embedder: &Embedder) -> Result<Vec<ChunkId>> {
        let words = tokenizer::words(text);
        let mut ids = Vec::new();
        for window in words.chunks(CHUNK_WORDS) {
            let chunk_text = window.join(" ");
            ids.push(self.add_chunk(&chunk_text, embedder)?);
        }
        Ok(ids)
    }

    /// Add one pre-chunked text.
    pub fn add_chunk(&mut self, text: &str, embedder: &Embedder) -> Result<ChunkId> {
        let id = self.chunks.len();
        let tokens = tokenizer::encode_segment(text);
        let embedding = embedder.embed(text)?;
        let key = tokenizer::fnv1a64(text.as_bytes());
        let mut seen = std::collections::HashSet::new();
        for w in tokenizer::words(text) {
            if seen.insert(w.clone()) {
                *self.df.entry(w).or_insert(0) += 1;
            }
        }
        self.chunks.push(Chunk {
            id,
            text: text.to_string(),
            tokens,
            embedding,
            key,
        });
        self.pending_abstract.push(id);
        Ok(id)
    }

    /// Insert a pre-built chunk without an embedder — for tests and for
    /// dataset tooling that computes embeddings in batch elsewhere.
    #[doc(hidden)]
    pub fn test_insert_chunk(&mut self, chunk: Chunk) {
        assert_eq!(chunk.id, self.chunks.len(), "chunk id must be dense");
        let mut seen = std::collections::HashSet::new();
        for w in tokenizer::words(&chunk.text) {
            if seen.insert(w.clone()) {
                *self.df.entry(w).or_insert(0) += 1;
            }
        }
        self.pending_abstract.push(chunk.id);
        self.chunks.push(chunk);
    }

    pub fn len(&self) -> usize {
        self.chunks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.chunks.is_empty()
    }

    pub fn chunk(&self, id: ChunkId) -> &Chunk {
        &self.chunks[id]
    }

    pub fn chunks(&self) -> &[Chunk] {
        &self.chunks
    }

    /// Estimated storage of the raw knowledge bank (text + tokens +
    /// embeddings), for Table 1's per-item numbers.
    pub fn bytes(&self) -> usize {
        self.chunks
            .iter()
            .map(|c| c.text.len() + SEGMENT_TOKENS * 4 + c.embedding.len() * 4)
            .sum()
    }

    // -- knowledge abstract ---------------------------------------------------

    /// Chunks whose content hasn't been folded into the abstract yet.
    pub fn pending_abstract_chunks(&self) -> &[ChunkId] {
        &self.pending_abstract
    }

    /// Mark pending chunks processed (the engine charges the LLM
    /// summarization cost when it calls this).
    pub fn mark_abstract_refreshed(&mut self) -> usize {
        let n = self.pending_abstract.len();
        self.pending_abstract.clear();
        n
    }

    /// The knowledge abstract: top-`n` TF-IDF terms across the bank.  This
    /// is the "collection of key content" the paper's LLM summarizer
    /// produces; here key terms are extracted statistically (DESIGN.md §3
    /// substitution) and the LLM cost is still charged by the engine.
    pub fn abstract_terms(&self, n: usize) -> Vec<String> {
        let total = self.chunks.len().max(1) as f64;
        let mut tf: HashMap<String, usize> = HashMap::new();
        for c in &self.chunks {
            for w in tokenizer::words(&c.text) {
                *tf.entry(w).or_insert(0) += 1;
            }
        }
        let mut scored: Vec<(f64, String)> = tf
            .into_iter()
            .map(|(w, f)| {
                let df = self.df.get(&w).copied().unwrap_or(1) as f64;
                let idf = (total / df).ln() + 1.0;
                (f as f64 * idf, w)
            })
            .collect();
        scored.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap().then(a.1.cmp(&b.1)));
        scored
            .into_iter()
            .filter(|(_, w)| w.len() > 2) // drop degenerate fragments
            .take(n)
            .map(|(_, w)| w)
            .collect()
    }

    /// Top terms of a single chunk (detail questions in prediction).
    pub fn chunk_terms(&self, id: ChunkId, n: usize) -> Vec<String> {
        let total = self.chunks.len().max(1) as f64;
        let mut tf: HashMap<String, usize> = HashMap::new();
        for w in tokenizer::words(&self.chunks[id].text) {
            *tf.entry(w).or_insert(0) += 1;
        }
        let mut scored: Vec<(f64, String)> = tf
            .into_iter()
            .map(|(w, f)| {
                let df = self.df.get(&w).copied().unwrap_or(1) as f64;
                let idf = (total / df).ln() + 1.0;
                (f as f64 * idf, w)
            })
            .collect();
        scored.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap().then(a.1.cmp(&b.1)));
        scored
            .into_iter()
            .filter(|(_, w)| w.len() > 2)
            .take(n)
            .map(|(_, w)| w)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Tests that need an Embedder run in rust/tests/ (they require
    // artifacts); here we exercise the embedder-free logic through a
    // manual chunk constructor.
    fn push_raw(kb: &mut KnowledgeBank, text: &str) {
        let id = kb.chunks.len();
        let mut seen = std::collections::HashSet::new();
        for w in tokenizer::words(text) {
            if seen.insert(w.clone()) {
                *kb.df.entry(w).or_insert(0) += 1;
            }
        }
        kb.chunks.push(Chunk {
            id,
            text: text.to_string(),
            tokens: tokenizer::encode_segment(text),
            embedding: vec![0.0; 4],
            key: tokenizer::fnv1a64(text.as_bytes()),
        });
        kb.pending_abstract.push(id);
    }

    #[test]
    fn abstract_terms_prefer_distinctive_words() {
        let mut kb = KnowledgeBank::new();
        push_raw(&mut kb, "the meeting covered budget budget budget topics");
        push_raw(&mut kb, "the meeting covered travel plans for the offsite");
        push_raw(&mut kb, "the meeting covered hiring for the design team");
        let terms = kb.abstract_terms(4);
        assert!(terms.contains(&"budget".to_string()), "{terms:?}");
        // "meeting"/"covered" appear in every chunk → low idf, high tf;
        // budget (tf 3, df 1) must outrank "the" is filtered by len? no,
        // 'the' has len 3 and df 3 → low idf. Just check budget is first.
        assert_eq!(terms[0], "budget");
    }

    #[test]
    fn chunk_keys_differ_by_content() {
        let mut kb = KnowledgeBank::new();
        push_raw(&mut kb, "alpha beta");
        push_raw(&mut kb, "alpha gamma");
        assert_ne!(kb.chunk(0).key, kb.chunk(1).key);
    }

    #[test]
    fn pending_abstract_batching() {
        let mut kb = KnowledgeBank::new();
        push_raw(&mut kb, "one");
        push_raw(&mut kb, "two");
        assert_eq!(kb.pending_abstract_chunks().len(), 2);
        assert_eq!(kb.mark_abstract_refreshed(), 2);
        assert!(kb.pending_abstract_chunks().is_empty());
    }

    #[test]
    fn chunk_terms_top_n() {
        let mut kb = KnowledgeBank::new();
        push_raw(&mut kb, "flight booking reference code xk42 flight departs monday");
        let t = kb.chunk_terms(0, 3);
        assert!(t.contains(&"flight".to_string()));
        assert!(t.len() <= 3);
    }
}
