//! One tenant's cache shard: the per-user state of the hierarchical
//! cache, bundled so the registry can own many of them and the governor
//! can move bytes between them.

use anyhow::{Context, Result};

use crate::cache::{PrefixMatch, QaBank, QkvTree, SegKey, SliceStore, Snapshotter};
use crate::embedding::Embedding;
use crate::llm::QkvTensor;
use crate::metrics::{QueryRecord, ServePath};
use crate::pool::PoolHandle;
use crate::predict::QueryPredictor;
use crate::util::json::Json;

pub type TenantId = u32;

/// Sidecar file persisting [`ShardStats`] next to `cache_state.json`, so
/// a demoted shard's governor utility signal survives the cold tier and
/// rehydration restores the same byte allocation a never-demoted shard
/// would hold.
pub const STATS_FILE: &str = "shard_stats.json";
const STATS_MAGIC: &str = "percache-shard-stats";
const STATS_VERSION: usize = 1;

/// Per-shard serving statistics — the governor's utility signal.
///
/// Utility follows the issue's formula: smoothed hit rate × FLOPs saved
/// per byte of cache held.  Both factors are EWMA-smoothed so a shard's
/// allocation tracks its *recent* value, not its lifetime average; the
/// raw counters stay available for reporting.
#[derive(Debug, Clone)]
pub struct ShardStats {
    pub serves: u64,
    pub qa_hits: u64,
    pub qkv_hits: u64,
    pub flops_saved_total: u64,
    /// EWMA of the per-serve hit indicator (any cache layer).
    ewma_hit: f64,
    /// EWMA of per-serve FLOPs saved.
    ewma_saved: f64,
    alpha: f64,
}

impl ShardStats {
    pub fn new(alpha: f64) -> Self {
        ShardStats {
            serves: 0,
            qa_hits: 0,
            qkv_hits: 0,
            flops_saved_total: 0,
            ewma_hit: 0.0,
            ewma_saved: 0.0,
            alpha: alpha.clamp(1e-6, 1.0),
        }
    }

    /// Record one serve outcome.
    pub fn note(&mut self, path: ServePath, flops_saved: u64) {
        self.serves += 1;
        match path {
            ServePath::QaHit => self.qa_hits += 1,
            ServePath::QkvHit => self.qkv_hits += 1,
            ServePath::Full => {}
        }
        let hit = if path == ServePath::Full { 0.0 } else { 1.0 };
        self.flops_saved_total += flops_saved;
        self.ewma_hit += self.alpha * (hit - self.ewma_hit);
        self.ewma_saved += self.alpha * (flops_saved as f64 - self.ewma_saved);
    }

    /// Feed a recorder-style query record; `full_flops` is the analytic
    /// cost the same query would have paid with cold caches.
    pub fn note_record(&mut self, rec: &QueryRecord, full_flops: u64) {
        self.note(rec.path, full_flops.saturating_sub(rec.flops));
    }

    /// Lifetime hit rate (reporting).
    pub fn hit_rate(&self) -> f64 {
        if self.serves == 0 {
            0.0
        } else {
            (self.qa_hits + self.qkv_hits) as f64 / self.serves as f64
        }
    }

    /// Smoothed hit rate (governor input).
    pub fn ewma_hit_rate(&self) -> f64 {
        self.ewma_hit
    }

    /// Caching utility given the bytes this shard currently occupies.
    pub fn utility(&self, bytes_held: usize) -> f64 {
        self.ewma_hit * self.ewma_saved / bytes_held.max(1) as f64
    }

    /// Serializable view (the `shard_stats.json` sidecar).
    pub fn export(&self) -> Json {
        let mut o = Json::obj();
        o.insert("serves", self.serves);
        o.insert("qa_hits", self.qa_hits);
        o.insert("qkv_hits", self.qkv_hits);
        o.insert("flops_saved_total", self.flops_saved_total);
        o.insert("ewma_hit", self.ewma_hit);
        o.insert("ewma_saved", self.ewma_saved);
        Json::Obj(o)
    }

    /// Rebuild from an [`Self::export`] snapshot; missing fields fall
    /// back to a fresh tracker (degrade, never corrupt).
    pub fn restore(alpha: f64, j: &Json) -> Self {
        let mut s = ShardStats::new(alpha);
        s.serves = j.get("serves").as_usize().unwrap_or(0) as u64;
        s.qa_hits = j.get("qa_hits").as_usize().unwrap_or(0) as u64;
        s.qkv_hits = j.get("qkv_hits").as_usize().unwrap_or(0) as u64;
        s.flops_saved_total = j.get("flops_saved_total").as_usize().unwrap_or(0) as u64;
        s.ewma_hit = j.get("ewma_hit").as_f64().unwrap_or(0.0);
        s.ewma_saved = j.get("ewma_saved").as_f64().unwrap_or(0.0);
        s
    }
}

/// One tenant's slice of the hierarchical cache.
///
/// Composition, not reimplementation: the shard reuses [`QaBank`],
/// [`QkvTree`], [`SliceStore`] and [`QueryPredictor`] exactly as the
/// single-tenant engine does, and adds the identity + accounting the
/// registry and governor need.
pub struct TenantShard {
    pub id: TenantId,
    pub qa: QaBank,
    pub tree: QkvTree,
    pub store: SliceStore,
    pub predictor: QueryPredictor,
    pub stats: ShardStats,
    /// Incremental snapshot writer (skips clean sections/saves).
    saver: Snapshotter,
}

impl TenantShard {
    pub fn new(id: TenantId, qa_bytes: usize, qkv_bytes: usize, utility_alpha: f64) -> Self {
        Self::with_pool(id, qa_bytes, qkv_bytes, utility_alpha, None)
    }

    /// Like [`Self::new`], but the slice store interns shared-eligible
    /// slices into the given cross-tenant pool (DESIGN.md §15).
    pub fn with_pool(
        id: TenantId,
        qa_bytes: usize,
        qkv_bytes: usize,
        utility_alpha: f64,
        pool: Option<PoolHandle>,
    ) -> Self {
        let store = match pool {
            Some(handle) => SliceStore::memory_with_pool(handle),
            None => SliceStore::memory(),
        };
        TenantShard {
            id,
            qa: QaBank::new(qa_bytes),
            tree: QkvTree::new(qkv_bytes),
            store,
            // distinct deterministic stream per tenant
            predictor: QueryPredictor::new(0xCAC4E5EED ^ (id as u64).wrapping_mul(0x9E3779B97F4A7C15)),
            stats: ShardStats::new(utility_alpha),
            saver: Snapshotter::new(),
        }
    }

    /// Open (or create) a shard persisted at `dir`: the slice store
    /// resumes its manifest and any `cache_state.json` snapshot restores
    /// the QA bank, tree structure and predictor history — tenants
    /// survive process restarts.  Pair with [`Self::save`].
    pub fn open_or_create(
        id: TenantId,
        qa_bytes: usize,
        qkv_bytes: usize,
        utility_alpha: f64,
        dir: std::path::PathBuf,
    ) -> Result<Self> {
        Self::open_or_create_pooled(id, qa_bytes, qkv_bytes, utility_alpha, dir, None)
    }

    /// [`Self::open_or_create`] with an optional cross-tenant pool: the
    /// shard's manifest re-acquires its pooled references at open, which
    /// is how per-tenant refcounts are rebuilt after a warm restart.
    pub fn open_or_create_pooled(
        id: TenantId,
        qa_bytes: usize,
        qkv_bytes: usize,
        utility_alpha: f64,
        dir: std::path::PathBuf,
        pool: Option<PoolHandle>,
    ) -> Result<Self> {
        let mut shard = Self::new(id, qa_bytes, qkv_bytes, utility_alpha);
        let mut store = match pool {
            Some(handle) => SliceStore::disk_with_pool(dir.clone(), handle)?,
            None => SliceStore::disk(dir.clone())?,
        };
        if let Some((tree, qa, _report)) = crate::cache::load_state(
            &dir,
            &mut store,
            qkv_bytes,
            qa_bytes,
            &mut shard.predictor,
        )? {
            shard.tree = tree;
            shard.qa = qa;
        }
        shard.store = store;
        // the utility signal survives demotion: restore the stats sidecar
        let stats_path = dir.join(STATS_FILE);
        if stats_path.exists() {
            let text = std::fs::read_to_string(&stats_path)
                .with_context(|| format!("reading {}", stats_path.display()))?;
            let j = crate::util::json::Json::parse(&text)
                .with_context(|| format!("invalid shard stats {}", stats_path.display()))?;
            anyhow::ensure!(
                j.get("magic").as_str() == Some(STATS_MAGIC),
                "shard stats missing magic {STATS_MAGIC:?}"
            );
            let version = j.get("version").as_usize().context("stats missing version")?;
            anyhow::ensure!(
                version == STATS_VERSION,
                "unsupported shard-stats version {version} (reader supports {STATS_VERSION})"
            );
            shard.stats = ShardStats::restore(utility_alpha, j.get("stats"));
        }
        Ok(shard)
    }

    /// Persist this shard's cache state next to its disk store (errors
    /// on a memory-backed shard).  Incremental: unchanged sections come
    /// from the snapshotter's cache, and saving a fully clean shard is a
    /// no-op.  Returns whether a snapshot write happened.
    pub fn save(&mut self) -> Result<bool> {
        let dir = self
            .store
            .dir()
            .with_context(|| format!("shard {}: save requires a disk store (open_or_create)", self.id))?
            .to_path_buf();
        let wrote = self
            .saver
            .save(&dir, &mut self.tree, &mut self.qa, &mut self.predictor)?;
        // the stats sidecar rides along with snapshot writes (stats only
        // drift when serves happen, and serves dirty a snapshot section)
        let stats_path = dir.join(STATS_FILE);
        if wrote || !stats_path.exists() {
            let mut root = Json::obj();
            root.insert("magic", STATS_MAGIC);
            root.insert("version", STATS_VERSION);
            root.insert("stats", self.stats.export());
            let tmp = dir.join(format!("{STATS_FILE}.tmp"));
            std::fs::write(&tmp, Json::Obj(root).to_string_pretty())
                .with_context(|| format!("writing {}", tmp.display()))?;
            std::fs::rename(&tmp, &stats_path)
                .with_context(|| format!("committing {}", stats_path.display()))?;
        }
        Ok(wrote)
    }

    // -- cache operations (PJRT-free; embeddings supplied by the caller) --

    /// QA-bank lookup at threshold `tau`.
    pub fn qa_lookup(&mut self, emb: &Embedding, tau: f64) -> Option<Vec<i32>> {
        self.qa.match_query(emb, tau).map(|(_, answer)| answer)
    }

    /// Longest cached QKV prefix for a segment-key path.
    pub fn prefix_match(&mut self, keys: &[SegKey]) -> PrefixMatch {
        self.tree.match_prefix(keys)
    }

    /// Insert a path of segment slices into this shard's tree/store.
    pub fn insert_path(&mut self, keys: &[SegKey], slices: Vec<QkvTensor>) -> Result<()> {
        self.tree.insert_path(keys, slices, &mut self.store)
    }

    /// [`Self::insert_path`] with per-segment share-eligibility flags:
    /// flagged slices intern into the cross-tenant pool (when one is
    /// attached) instead of occupying private bytes.  `shared` may be
    /// shorter than `keys`; missing entries mean private.
    pub fn insert_path_shared(
        &mut self,
        keys: &[SegKey],
        slices: Vec<QkvTensor>,
        shared: &[bool],
    ) -> Result<()> {
        self.tree.insert_path_shared(keys, slices, shared, &mut self.store)
    }

    // -- budgets (governor interface) ------------------------------------

    pub fn qkv_budget(&self) -> usize {
        self.tree.byte_limit()
    }

    /// Apply a new QKV budget; shrinking evicts immediately through the
    /// tree's LFU `enforce_budget` path.
    pub fn set_qkv_budget(&mut self, bytes: usize) {
        self.tree.set_byte_limit(bytes, &mut self.store);
    }

    pub fn bytes_used(&self) -> usize {
        self.tree.bytes_used() + self.qa.bytes_used()
    }

    /// Current caching utility (see [`ShardStats::utility`]).
    pub fn utility(&self) -> f64 {
        self.stats.utility(self.bytes_used())
    }

    pub fn check_invariants(&self) -> Result<()> {
        self.tree.check_invariants()?;
        self.qa.check_invariants()?;
        anyhow::ensure!(
            self.store.count() == self.tree.slice_count(),
            "shard {}: store has {} slices, tree accounts {}",
            self.id,
            self.store.count(),
            self.tree.slice_count()
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tensor() -> QkvTensor {
        QkvTensor::zeros(1, 4, 64)
    }

    #[test]
    fn shard_caches_independently() {
        let mut a = TenantShard::new(0, 4096, 1 << 20, 0.2);
        let mut b = TenantShard::new(1, 4096, 1 << 20, 0.2);
        a.insert_path(&[1, 2], vec![tensor(), tensor()]).unwrap();
        assert_eq!(a.prefix_match(&[1, 2]).len(), 2);
        assert_eq!(b.prefix_match(&[1, 2]).len(), 0, "no cross-tenant leakage");
        a.check_invariants().unwrap();
        b.check_invariants().unwrap();
    }

    #[test]
    fn pooled_shards_dedup_shared_slices() {
        let pool = crate::pool::SlicePool::memory(1 << 20).shared();
        let mut a = TenantShard::with_pool(
            0,
            4096,
            1 << 20,
            0.2,
            Some(PoolHandle::new(pool.clone(), 0)),
        );
        let mut b = TenantShard::with_pool(
            1,
            4096,
            1 << 20,
            0.2,
            Some(PoolHandle::new(pool.clone(), 1)),
        );
        a.insert_path_shared(&[1, 2], vec![tensor(), tensor()], &[true, true])
            .unwrap();
        b.insert_path_shared(&[1, 2], vec![tensor(), tensor()], &[true, true])
            .unwrap();
        {
            let p = crate::util::sync::lock_or_recover(&pool);
            assert_eq!(p.len(), 2, "identical content stored once");
            assert_eq!(p.refcount(1), 2, "both tenants hold a reference");
            assert_eq!(p.refcount(2), 2);
        }
        assert_eq!(a.prefix_match(&[1, 2]).len(), 2);
        assert_eq!(b.prefix_match(&[1, 2]).len(), 2);
        a.check_invariants().unwrap();
        b.check_invariants().unwrap();
        // evicting tenant B's handles releases, never strands, pool refs
        b.set_qkv_budget(0);
        let p = crate::util::sync::lock_or_recover(&pool);
        assert_eq!(p.refcount(1), 1);
        assert_eq!(p.refcount(2), 1);
    }

    #[test]
    fn stats_ewma_tracks_hits() {
        let mut s = ShardStats::new(0.5);
        s.note(ServePath::Full, 0);
        assert_eq!(s.hit_rate(), 0.0);
        for _ in 0..8 {
            s.note(ServePath::QkvHit, 100);
        }
        assert!(s.ewma_hit_rate() > 0.9, "{}", s.ewma_hit_rate());
        assert!(s.utility(100) > 0.0);
        assert_eq!(s.serves, 9);
        assert_eq!(s.qkv_hits, 8);
    }

    #[test]
    fn utility_zero_without_hits() {
        let mut s = ShardStats::new(0.2);
        for _ in 0..5 {
            s.note(ServePath::Full, 0);
        }
        assert_eq!(s.utility(1024), 0.0);
    }

    #[test]
    fn note_record_derives_saving() {
        let mut s = ShardStats::new(0.2);
        let mut r = crate::metrics::blank_record(0);
        r.path = ServePath::QkvHit;
        r.flops = 300;
        s.note_record(&r, 1000);
        assert_eq!(s.flops_saved_total, 700);
    }

    #[test]
    fn save_is_incremental_and_stats_survive_reopen() {
        let dir = std::env::temp_dir().join(format!(
            "percache_shard_stats_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let qkv = 1 << 20;
        {
            let mut sh = TenantShard::open_or_create(0, 4096, qkv, 0.2, dir.clone()).unwrap();
            sh.insert_path(&[1, 2], vec![tensor(), tensor()]).unwrap();
            sh.stats.note(ServePath::QkvHit, 500);
            assert!(sh.save().unwrap(), "first save must write");
            assert!(!sh.save().unwrap(), "clean shard save must be a no-op");
            sh.prefix_match(&[1, 2]); // LFU freq bump dirties the tree
            assert!(sh.save().unwrap());
        }
        let sh = TenantShard::open_or_create(0, 4096, qkv, 0.2, dir.clone()).unwrap();
        assert_eq!(sh.stats.serves, 1, "stats must survive the restart");
        assert_eq!(sh.stats.qkv_hits, 1);
        assert!(sh.stats.ewma_hit_rate() > 0.0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn budget_shrink_evicts_through_lfu() {
        let mut sh = TenantShard::new(3, 4096, 1 << 20, 0.2);
        let one = tensor().byte_size() + 16;
        sh.insert_path(&[1, 2, 3], vec![tensor(), tensor(), tensor()]).unwrap();
        assert_eq!(sh.tree.slice_count(), 3);
        sh.set_qkv_budget(one);
        assert_eq!(sh.tree.slice_count(), 1);
        assert!(sh.tree.bytes_used() <= sh.qkv_budget());
        sh.check_invariants().unwrap();
    }
}
