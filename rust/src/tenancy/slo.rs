//! SLO monitoring: the sensor-to-actuator bridge of the SLO-aware
//! control plane (DESIGN.md §14).
//!
//! [`SloMonitor`] records every served request's end-to-end latency and
//! queue delay into an obs [`MetricsRegistry`] (`slo.e2e_ms` /
//! `slo.queue_delay_ms` histograms, `slo.served` / `slo.misses`
//! counters, all labeled by tenant), then — once per scheduling window
//! — reads those series *back from the registry* to derive per-tenant
//! [`SloSignal`]s: the windowed SLO-miss rate and the queue-delay
//! quantile.  The signals feed three actuators:
//!
//! * the governor's utility boost (`TenantRegistry::set_slo_signals`),
//! * router admission shedding (`Router::set_shed`, driven by the
//!   sustained-violation state machine here), and
//! * tiering demotion/prefetch vetoes (the `TieringController` reads
//!   the same signals back through `TenantRegistry::slo_signal`).
//!
//! Shedding is hysteretic: it engages only after `shed_windows`
//! consecutive windows at or above `shed_miss_rate`, and disengages
//! only after the same number of windows at or below
//! `unshed_miss_rate`, so a single bad window never flaps admission.

use crate::config::SloConfig;
use crate::obs::{CounterHandle, GaugeHandle, HistogramHandle, MetricsRegistry};
use crate::tenancy::TenantId;

/// One tenant's windowed SLO state, as consumed by governor, router and
/// tiering controller.
#[derive(Debug, Clone, Copy, Default)]
pub struct SloSignal {
    /// SLO misses / serves over the last closed window (carries the
    /// previous value through empty windows).
    pub miss_rate: f64,
    /// p90 queue delay, modeled ms (cumulative histogram quantile).
    pub queue_delay_ms: f64,
    /// The tenant's p99 end-to-end SLO bound, ms.
    pub target_ms: f64,
    /// Serves inside the window (0 = signal carried over).
    pub window_served: u64,
}

/// Per-tenant handle bundle into the metrics registry.
struct TenantSeries {
    served: CounterHandle,
    misses: CounterHandle,
    e2e: HistogramHandle,
    delay: HistogramHandle,
    rate_milli: GaugeHandle,
}

/// Records per-request SLO outcomes and closes scheduling windows into
/// [`SloSignal`]s plus a hysteretic load-shedding decision per tenant.
pub struct SloMonitor {
    cfg: SloConfig,
    targets: Vec<f64>,
    series: Vec<TenantSeries>,
    shed_active: GaugeHandle,
    shed_engaged: CounterHandle,
    // counter values at the last window close (for windowed deltas)
    base_served: Vec<u64>,
    base_missed: Vec<u64>,
    last_rate: Vec<f64>,
    hot_streak: Vec<u32>,
    cool_streak: Vec<u32>,
    shedding: Vec<bool>,
}

impl SloMonitor {
    /// One monitor per replay/serving loop; `targets[t]` is tenant t's
    /// p99 SLO bound in ms.  The registry is usually a local one so
    /// runs stay isolated, but the global registry works too.
    pub fn new(cfg: &SloConfig, targets: &[f64], reg: &MetricsRegistry) -> Self {
        let series = (0..targets.len())
            .map(|t| {
                let tenant = t.to_string();
                let labels: &[(&str, &str)] = &[("tenant", tenant.as_str())];
                TenantSeries {
                    served: reg.counter_labeled("slo.served", labels),
                    misses: reg.counter_labeled("slo.misses", labels),
                    e2e: reg.histogram_labeled("slo.e2e_ms", labels),
                    delay: reg.histogram_labeled("slo.queue_delay_ms", labels),
                    rate_milli: reg.gauge_labeled("slo.miss_rate_milli", labels),
                }
            })
            .collect();
        let n = targets.len();
        SloMonitor {
            cfg: cfg.clone(),
            targets: targets.to_vec(),
            series,
            shed_active: reg.gauge("shed.active"),
            shed_engaged: reg.counter("shed.engaged"),
            base_served: vec![0; n],
            base_missed: vec![0; n],
            last_rate: vec![0.0; n],
            hot_streak: vec![0; n],
            cool_streak: vec![0; n],
            shedding: vec![false; n],
        }
    }

    pub fn tenants(&self) -> usize {
        self.targets.len()
    }

    pub fn target_ms(&self, tenant: TenantId) -> f64 {
        self.targets.get(tenant as usize).copied().unwrap_or(0.0)
    }

    /// Record one served request: end-to-end latency vs the tenant's
    /// target, plus the share of it spent queued.
    pub fn record(&self, tenant: TenantId, e2e_ms: f64, queue_delay_ms: f64) {
        let Some(s) = self.series.get(tenant as usize) else {
            return;
        };
        s.e2e.record(e2e_ms);
        s.delay.record(queue_delay_ms);
        s.served.inc();
        if e2e_ms > self.target_ms(tenant) {
            s.misses.inc();
        }
    }

    /// Close the current window: read the counters back from the
    /// registry, derive per-tenant signals, and advance the shedding
    /// state machine.
    pub fn close_window(&mut self) -> Vec<SloSignal> {
        let mut signals = Vec::with_capacity(self.series.len());
        for t in 0..self.series.len() {
            let s = &self.series[t];
            let served = s.served.get();
            let missed = s.misses.get();
            let d_served = served.saturating_sub(self.base_served[t]);
            let d_missed = missed.saturating_sub(self.base_missed[t]);
            self.base_served[t] = served;
            self.base_missed[t] = missed;
            let rate = if d_served > 0 {
                d_missed as f64 / d_served as f64
            } else {
                // empty window: carry the last evidence forward
                self.last_rate[t]
            };
            self.last_rate[t] = rate;
            s.rate_milli.set((rate * 1e3) as i64);

            if d_served > 0 {
                if rate >= self.cfg.shed_miss_rate {
                    self.hot_streak[t] += 1;
                    self.cool_streak[t] = 0;
                } else if rate <= self.cfg.unshed_miss_rate {
                    self.cool_streak[t] += 1;
                    self.hot_streak[t] = 0;
                } else {
                    self.hot_streak[t] = 0;
                    self.cool_streak[t] = 0;
                }
            } else {
                // no traffic: an idle tenant cannot be violating
                self.hot_streak[t] = 0;
                self.cool_streak[t] += 1;
            }
            if !self.shedding[t] && self.hot_streak[t] >= self.cfg.shed_windows {
                self.shedding[t] = true;
                self.shed_engaged.inc();
            } else if self.shedding[t] && self.cool_streak[t] >= self.cfg.shed_windows {
                self.shedding[t] = false;
            }

            signals.push(SloSignal {
                miss_rate: rate,
                queue_delay_ms: s.delay.quantile(0.9),
                target_ms: self.targets[t],
                window_served: d_served,
            });
        }
        let active = self.shedding.iter().filter(|&&b| b).count();
        self.shed_active.set(active as i64);
        signals
    }

    /// Is admission shedding currently engaged for this tenant?
    pub fn shedding(&self, tenant: TenantId) -> bool {
        self.shedding.get(tenant as usize).copied().unwrap_or(false)
    }

    pub fn any_shedding(&self) -> bool {
        self.shedding.iter().any(|&b| b)
    }

    /// Cumulative (whole-run) serve / miss counts for reporting.
    pub fn totals(&self, tenant: TenantId) -> (u64, u64) {
        self.series
            .get(tenant as usize)
            .map(|s| (s.served.get(), s.misses.get()))
            .unwrap_or((0, 0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn monitor(targets: &[f64]) -> (SloMonitor, MetricsRegistry) {
        let reg = MetricsRegistry::new();
        let m = SloMonitor::new(&SloConfig::default(), targets, &reg);
        (m, reg)
    }

    #[test]
    fn windowed_miss_rate_reads_back_from_the_registry() {
        let (mut m, _reg) = monitor(&[10.0, 20.0]);
        m.record(0, 5.0, 1.0); // meets
        m.record(0, 15.0, 9.0); // misses
        m.record(1, 19.0, 2.0); // meets
        let sig = m.close_window();
        assert_eq!(sig.len(), 2);
        assert!((sig[0].miss_rate - 0.5).abs() < 1e-9);
        assert_eq!(sig[0].window_served, 2);
        assert!((sig[1].miss_rate - 0.0).abs() < 1e-9);
        // an empty window carries the previous rate forward
        let sig = m.close_window();
        assert!((sig[0].miss_rate - 0.5).abs() < 1e-9);
        assert_eq!(sig[0].window_served, 0);
    }

    #[test]
    fn shedding_engages_after_sustained_violation_and_cools_off() {
        let (mut m, _reg) = monitor(&[10.0]);
        // one violating window is not enough
        m.record(0, 50.0, 40.0);
        m.close_window();
        assert!(!m.shedding(0));
        // a second consecutive violating window engages
        m.record(0, 50.0, 40.0);
        m.close_window();
        assert!(m.shedding(0), "two violating windows must engage shedding");
        assert_eq!(m.totals(0), (2, 2));
        // healthy windows cool it off after the same streak length
        m.record(0, 1.0, 0.0);
        m.close_window();
        assert!(m.shedding(0), "one healthy window must not disengage");
        m.record(0, 1.0, 0.0);
        m.close_window();
        assert!(!m.shedding(0), "sustained health must disengage");
    }

    #[test]
    fn idle_windows_cool_shedding_down() {
        let (mut m, _reg) = monitor(&[10.0]);
        for _ in 0..2 {
            m.record(0, 99.0, 90.0);
            m.close_window();
        }
        assert!(m.shedding(0));
        m.close_window();
        m.close_window();
        assert!(!m.shedding(0), "an idle tenant cannot stay shed");
    }
}
