//! Multi-tenant engine: one [`PerCache`] instance per tenant over a
//! single shared PJRT [`Runtime`] (weights and compiled executables are
//! cached per-runtime, so tenants share them), governed by the same
//! utility-proportional byte allocator as the cache-level shards.
//!
//! The utility signal is fed from `metrics::recorder` query records: each
//! serve's measured FLOPs are compared against the analytic cold-cache
//! cost of the same prompt, and the EWMA of (hit, FLOPs saved) drives the
//! governor exactly as in [`super::shard::ShardStats`].

use anyhow::Result;

use crate::config::PerCacheConfig;
use crate::engine::{IdleReport, PerCache};
use crate::metrics::{ModelDims, QueryRecord, Recorder, ServePath};
use crate::runtime::Runtime;
use crate::tokenizer::SEGMENT_TOKENS;

use super::governor::{GovernorConfig, MemoryGovernor};
use super::shard::{ShardStats, TenantId};

pub struct MultiTenantEngine<'rt> {
    rt: &'rt Runtime,
    base: PerCacheConfig,
    engines: Vec<PerCache<'rt>>,
    stats: Vec<ShardStats>,
    /// Per-tenant measurement streams (Fig 14-style comparisons per user).
    pub recorders: Vec<Recorder>,
    pub governor: MemoryGovernor,
    serves: u64,
}

impl<'rt> MultiTenantEngine<'rt> {
    pub fn new(rt: &'rt Runtime, base: PerCacheConfig) -> Self {
        let t = &base.tenancy;
        MultiTenantEngine {
            rt,
            governor: MemoryGovernor::new(GovernorConfig {
                global_qkv_bytes: t.global_qkv_bytes,
                floor_frac: t.floor_frac,
                hysteresis_frac: t.hysteresis_frac,
            }),
            base,
            engines: Vec::new(),
            stats: Vec::new(),
            recorders: Vec::new(),
            serves: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.engines.len()
    }

    pub fn is_empty(&self) -> bool {
        self.engines.is_empty()
    }

    pub fn engine(&self, tenant: TenantId) -> Option<&PerCache<'rt>> {
        self.engines.get(tenant as usize)
    }

    pub fn engine_mut(&mut self, tenant: TenantId) -> Option<&mut PerCache<'rt>> {
        self.engines.get_mut(tenant as usize)
    }

    pub fn stats(&self, tenant: TenantId) -> Option<&ShardStats> {
        self.stats.get(tenant as usize)
    }

    /// Add a tenant (own KB, retriever, caches, predictor); budgets are
    /// re-planned across all tenants.
    pub fn add_tenant(&mut self) -> Result<TenantId> {
        let tc = &self.base.tenancy;
        anyhow::ensure!(
            self.engines.len() < tc.max_tenants,
            "tenant limit reached ({})",
            tc.max_tenants
        );
        let mut cfg = self.base.clone();
        cfg.qa_storage_bytes = tc.qa_bytes_per_tenant;
        // start from zero; the forced rebalance below hands out budgets
        cfg.qkv_storage_bytes = 0;
        let alpha = tc.utility_alpha;
        self.engines.push(PerCache::new(self.rt, cfg)?);
        self.stats.push(ShardStats::new(alpha));
        self.recorders.push(Recorder::new());
        self.rebalance(true);
        Ok((self.engines.len() - 1) as TenantId)
    }

    pub fn add_document(&mut self, tenant: TenantId, text: &str) -> Result<Vec<usize>> {
        self.engine_checked(tenant)?.add_document(text)
    }

    /// Serve one query for `tenant`, feeding the governor's utility
    /// signal from the resulting record.
    pub fn serve(&mut self, tenant: TenantId, query: &str) -> Result<QueryRecord> {
        let rec = self.engine_checked(tenant)?.serve(query)?;
        let full = self.cold_cost(tenant, &rec);
        let idx = tenant as usize;
        self.stats[idx].note_record(&rec, full);
        self.recorders[idx].push(rec.clone());
        self.serves += 1;
        if self.serves % self.base.tenancy.rebalance_every as u64 == 0 {
            self.rebalance(false);
        }
        Ok(rec)
    }

    pub fn idle_tick(&mut self, tenant: TenantId) -> Result<IdleReport> {
        self.engine_checked(tenant)?.idle_tick()
    }

    /// Utility-proportional budget re-plan across all tenants, through
    /// the governor's shared hysteresis + shrink-first apply path.
    /// Returns true when budgets moved.
    pub fn rebalance(&mut self, force: bool) -> bool {
        let entries: Vec<(TenantId, f64, usize)> = self
            .engines
            .iter()
            .zip(&self.stats)
            .enumerate()
            .map(|(i, (e, s))| {
                (
                    i as TenantId,
                    s.utility(e.tree.bytes_used() + e.qa.bytes_used()),
                    e.tree.byte_limit(),
                )
            })
            .collect();
        let engines = &mut self.engines;
        self.governor.rebalance_entries(
            &entries,
            |tenant, bytes| engines[tenant as usize].set_qkv_storage(bytes),
            force,
        )
    }

    pub fn total_qkv_budget(&self) -> usize {
        self.engines.iter().map(|e| e.tree.byte_limit()).sum()
    }

    fn engine_checked(&mut self, tenant: TenantId) -> Result<&mut PerCache<'rt>> {
        let n = self.engines.len();
        self.engines
            .get_mut(tenant as usize)
            .ok_or_else(|| anyhow::anyhow!("unknown tenant {tenant} (have {n})"))
    }

    /// Analytic cost the query would have paid with cold caches — the
    /// "FLOPs saved" reference for the utility signal.
    fn cold_cost(&self, tenant: TenantId, rec: &QueryRecord) -> u64 {
        let eng = &self.engines[tenant as usize];
        let dims: ModelDims = eng.llm.dims;
        // a QA hit skips prompt assembly, so fall back to the configured
        // prompt shape (sys + top_k chunks + query)
        let n_seg = if rec.path == ServePath::QaHit || rec.n_segments == 0 {
            2 + eng.cfg.top_k.min(eng.kb.len())
        } else {
            rec.n_segments
        };
        let s = n_seg * SEGMENT_TOKENS;
        dims.prefill_full(s)
            + eng.cfg.decode_tokens as u64 * dims.decode_step(eng.llm.decode_ctx)
    }
}
