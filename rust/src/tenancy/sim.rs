//! Runtime-free multi-tenant replay: drives tenant shards at the cache
//! level (real QA-bank/tree/store/governor/router code, analytic LLM
//! cost model, hash embeddings) so the tenancy experiment, bench, CLI
//! and integration tests run without PJRT artifacts.
//!
//! What is real here: every cache data structure, eviction, the governor
//! and the router — the subsystem under test.  What is modeled: LLM
//! latency (analytic FLOPs over a device throughput) and embeddings
//! (content-word feature hashing, the same basis the embed artifact
//! normalizes over), both deterministic.

use anyhow::Result;

use crate::datasets::MultiTenantWorkload;
use crate::embedding::hash_embed;
use crate::llm::QkvTensor;
use crate::metrics::{
    blank_record, record_query_obs, ModelDims, QueryRecord, Recorder, ServePath, Stage,
};
use crate::tokenizer::{fnv1a64, SEGMENT_TOKENS};

use super::registry::TenantRegistry;
use super::router::{Router, RouterConfig};
use super::shard::{TenantId, TenantShard};

/// Cost/embedding model for the cache-level replay.
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// QA-bank similarity threshold τ_query.
    pub tau_query: f64,
    pub dims: ModelDims,
    pub decode_tokens: usize,
    /// Modeled device throughput (GFLOP/s) for latency conversion.
    pub gflops: f64,
    pub embed_dim: usize,
    /// Position-aware reuse (RAGCache's reorder-vs-recompute): compose a
    /// pooled segment's KV at a different prompt offset, paying
    /// `reanchor_cost_frac` × one segment's full prefill instead of
    /// recomputing it (mirrors `PoolConfig::{reanchor, reanchor_cost_frac}`).
    pub reanchor: bool,
    pub reanchor_cost_frac: f64,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            tau_query: 0.85,
            // the seed's llama-config dimensions
            dims: ModelDims {
                layers: 4,
                d_model: 256,
                heads: 8,
                ffn: 1024,
                vocab: 8192,
            },
            decode_tokens: 24,
            gflops: 50.0,
            embed_dim: 64,
            reanchor: false,
            reanchor_cost_frac: 0.25,
        }
    }
}

/// Byte size one sim slice occupies in a shard's store (tiny test-model
/// tensor + the store's per-slice header) — the unit behind every
/// "budget in slices" knob in the CLI, sweep, bench and tests.  Must
/// track `SliceStore::put`'s accounting.
pub fn sim_slice_bytes() -> usize {
    QkvTensor::zeros(1, 4, SEGMENT_TOKENS).byte_size() + 16
}

/// One routed request: a tenant, its query text, and the prompt's
/// segment-key path (`[sys, chunk…, query]`).
#[derive(Debug, Clone)]
pub struct Arrival {
    pub tenant: TenantId,
    pub query: String,
    pub seg_keys: Vec<u64>,
    /// Per-segment share-eligibility, aligned with `seg_keys` (may be
    /// shorter; missing = private).  Empty — the default everywhere a
    /// workload has no public corpus — replays byte-identically to the
    /// pre-pool path.
    pub shared: Vec<bool>,
}

/// Replay result: one measurement stream per tenant + admission stats.
#[derive(Debug)]
pub struct SimOutcome {
    pub per_tenant: Vec<Recorder>,
    pub rejected: u64,
    pub rebalances: u64,
}

impl SimOutcome {
    /// All records flattened (global latency distribution).
    pub fn all_total_ms(&self) -> Vec<f64> {
        let mut v: Vec<f64> = self
            .per_tenant
            .iter()
            .flat_map(|r| r.records.iter().map(|q| q.total_ms()))
            .collect();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        v
    }
}

/// Serve one query against a shard: QA lookup, tree prefix match,
/// analytic LLM cost for the remainder, post-response population.
/// Cache-structure timings are measured; LLM stages are modeled.
pub fn serve_one(
    cfg: &SimConfig,
    shard: &mut TenantShard,
    query: &str,
    seg_keys: &[u64],
) -> Result<QueryRecord> {
    serve_one_shared(cfg, shard, query, seg_keys, &[])
}

/// [`serve_one`] with per-segment share-eligibility flags: flagged
/// slices populate through the cross-tenant pool, and (with
/// `cfg.reanchor`) unmatched shared segments already pooled by *any*
/// tenant compose at this prompt's offset for a modeled re-anchor
/// surcharge instead of a full recompute (DESIGN.md §15).
pub fn serve_one_shared(
    cfg: &SimConfig,
    shard: &mut TenantShard,
    query: &str,
    seg_keys: &[u64],
    shared: &[bool],
) -> Result<QueryRecord> {
    let mut rec = blank_record(shard.stats.serves as usize);
    rec.n_segments = seg_keys.len();
    let s_tokens = seg_keys.len() * SEGMENT_TOKENS;
    let flops_ms = |flops: u64| flops as f64 / (cfg.gflops * 1e6);
    let full_prefill = cfg.dims.prefill_full(s_tokens);
    let decode_flops = cfg.decode_tokens as u64 * cfg.dims.decode_step(s_tokens);

    let t = Stage::start();
    let emb = hash_embed(query, cfg.embed_dim);
    rec.embed_ms = t.ms();

    let t = Stage::start();
    let qa_hit = shard.qa_lookup(&emb, cfg.tau_query);
    rec.qa_match_ms = t.ms();
    if let Some(answer) = qa_hit {
        rec.path = ServePath::QaHit;
        rec.answer = crate::engine::tokens_to_text(&answer);
        shard.predictor.observe(query);
        shard.stats.note(ServePath::QaHit, full_prefill + decode_flops);
        record_query_obs(&rec);
        return Ok(rec);
    }

    // tree prefix match over everything but the query segment
    let mut matched = 0usize;
    if seg_keys.len() > 1 {
        let t = Stage::start();
        matched = shard.prefix_match(&seg_keys[..seg_keys.len() - 1]).len();
        rec.tree_match_ms = t.ms();
    }
    rec.matched_segments = matched;

    // position-aware reuse: an unmatched shared segment whose content is
    // already pooled (interned by any tenant, at any prompt offset)
    // composes here for a re-anchor surcharge instead of a recompute
    let mut reanchored = 0usize;
    if cfg.reanchor && seg_keys.len() > 1 && shard.store.has_pool() {
        let _t = crate::obs::trace::child("pool_reanchor");
        for (i, key) in seg_keys[..seg_keys.len() - 1]
            .iter()
            .enumerate()
            .skip(matched)
        {
            if shared.get(i).copied().unwrap_or(false)
                && shard.store.pool_probe(*key).is_some()
            {
                reanchored += 1;
            }
        }
        if reanchored > 0 {
            crate::obs_counter!("pool.reanchored").add(reanchored as u64);
        }
    }

    rec.path = if matched + reanchored > 0 {
        ServePath::QkvHit
    } else {
        ServePath::Full
    };

    let prefill_flops = if matched + reanchored > 0 {
        let reuse = cfg
            .dims
            .prefill_reuse_qkv((matched + reanchored) * SEGMENT_TOKENS, s_tokens);
        let surcharge = (reanchored as f64
            * cfg.reanchor_cost_frac
            * cfg.dims.prefill_full(SEGMENT_TOKENS) as f64) as u64;
        reuse + surcharge
    } else {
        full_prefill
    };
    rec.prefill_ms = flops_ms(prefill_flops);
    rec.decode_ms = flops_ms(decode_flops);
    rec.flops = prefill_flops + decode_flops;
    rec.answer = format!("t{} a{}", shard.id, fnv1a64(query.as_bytes()) % 997);

    // post-response population (tensors shaped like the tiny test model:
    // what matters to the governor is the byte accounting, not values)
    if seg_keys.len() > 1 {
        let t = Stage::start();
        let prefix = &seg_keys[..seg_keys.len() - 1];
        let tensors: Vec<QkvTensor> = prefix
            .iter()
            .map(|_| QkvTensor::zeros(1, 4, SEGMENT_TOKENS))
            .collect();
        shard.insert_path_shared(prefix, tensors, shared)?;
        rec.cache_load_ms = t.ms();
    }
    shard
        .qa
        .insert(query, emb, Some(vec![1, 2, 3]), false);
    shard.predictor.observe(query);
    shard
        .stats
        .note(rec.path, (full_prefill + decode_flops).saturating_sub(rec.flops));
    record_query_obs(&rec);
    Ok(rec)
}

/// Replay a stream of arrivals through the router (admission + fair
/// scheduling) into the registry's shards, with the governor running its
/// periodic passes.  `batch` arrivals are enqueued per scheduling round,
/// modeling concurrent clients.
pub fn replay(
    registry: &mut TenantRegistry,
    router_cfg: RouterConfig,
    cfg: &SimConfig,
    arrivals: &[Arrival],
    batch: usize,
) -> Result<SimOutcome> {
    let mut router: Router<Arrival> = Router::new(router_cfg);
    for _ in 0..registry.len() {
        router.register_tenant();
    }
    let mut per_tenant: Vec<Recorder> = (0..registry.len()).map(|_| Recorder::new()).collect();
    let mut rebalances = 0u64;

    for chunk in arrivals.chunks(batch.max(1)) {
        for a in chunk {
            // admission rejection is already counted by the router
            let _ = router.try_push(a.tenant, a.clone());
        }
        while let Some((tenant, a)) = router.pop() {
            let shard = registry
                .shard_mut(tenant)
                .ok_or_else(|| anyhow::anyhow!("router/registry tenant mismatch"))?;
            let rec = serve_one_shared(cfg, shard, &a.query, &a.seg_keys, &a.shared)?;
            per_tenant[tenant as usize].push(rec);
            if registry.note_serve() {
                rebalances += 1;
            }
        }
    }
    registry.check_invariants()?;
    Ok(SimOutcome {
        per_tenant,
        rejected: router.rejected,
        rebalances,
    })
}

/// Expand a dataset-level multi-tenant workload into routed arrivals:
/// the prompt path is `[sys, chunk_a(topic), chunk_b(topic), query]`.
/// Private topics get per-tenant chunk keys (tenants never share tree
/// paths); topics below `w.shared_topics` come from the public corpus —
/// their chunk keys are tenant-independent and flagged share-eligible,
/// the overlap the cross-tenant pool dedups.
pub fn arrivals_from_workload(w: &MultiTenantWorkload) -> Vec<Arrival> {
    let sys = fnv1a64(b"sys");
    w.arrivals
        .iter()
        .map(|&(tenant, seq)| {
            let trace = &w.tenants[tenant];
            let q = &trace.data.queries[seq % trace.data.queries.len()];
            let public = q.topic < w.shared_topics;
            let tag = |part: &str| {
                if public {
                    fnv1a64(format!("public/topic{}/{part}", q.topic).as_bytes())
                } else {
                    fnv1a64(
                        format!(
                            "{}/{}/t{}/topic{}/{part}",
                            trace.dataset, trace.user, tenant, q.topic
                        )
                        .as_bytes(),
                    )
                }
            };
            Arrival {
                tenant: tenant as TenantId,
                query: q.text.clone(),
                seg_keys: vec![sys, tag("a"), tag("b"), fnv1a64(q.text.as_bytes())],
                shared: if public {
                    vec![false, true, true, false]
                } else {
                    Vec::new()
                },
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TenancyConfig;

    fn small_registry(n: usize, slices_global: usize) -> TenantRegistry {
        let mut tc = TenancyConfig::default();
        tc.global_qkv_bytes = slices_global * sim_slice_bytes();
        tc.rebalance_every = 8;
        let mut reg = TenantRegistry::new(&tc);
        for _ in 0..n {
            reg.create_tenant().unwrap();
        }
        reg
    }

    fn arrival(tenant: TenantId, q: &str, topic: u64) -> Arrival {
        Arrival {
            tenant,
            query: q.to_string(),
            seg_keys: vec![
                fnv1a64(b"sys"),
                fnv1a64(format!("t{tenant}/c{topic}a").as_bytes()),
                fnv1a64(format!("t{tenant}/c{topic}b").as_bytes()),
                fnv1a64(q.as_bytes()),
            ],
            shared: Vec::new(),
        }
    }

    #[test]
    fn repeat_queries_become_cache_hits() {
        let mut reg = small_registry(1, 64);
        let cfg = SimConfig::default();
        let shard = reg.shard_mut(0).unwrap();
        // word choice pinned against feature-hash collisions at dim 64:
        // the two serial words land in distinct buckets, so the pair's
        // cosine is exactly 4/5 = 0.8 < τ
        let a = arrival(0, "question number0001 about budget review", 0);
        let r1 = serve_one(&cfg, shard, &a.query, &a.seg_keys).unwrap();
        assert_eq!(r1.path, ServePath::Full);
        // same prompt path, new query text → QKV prefix hit
        let b = arrival(0, "question number0002 about budget review", 0);
        let r2 = serve_one(&cfg, shard, &b.query, &b.seg_keys).unwrap();
        assert!(r2.matched_segments > 0, "prefix should be cached");
        assert!(r2.flops < r1.flops, "reuse must cut modeled FLOPs");
        // verbatim repeat → QA hit
        let r3 = serve_one(&cfg, shard, &a.query, &a.seg_keys).unwrap();
        assert_eq!(r3.path, ServePath::QaHit);
        assert_eq!(r3.flops, 0);
    }

    #[test]
    fn replay_routes_and_records_per_tenant() {
        let mut reg = small_registry(4, 64);
        let cfg = SimConfig::default();
        let mut arrivals = Vec::new();
        for i in 0..40u64 {
            let t = (i % 4) as TenantId;
            arrivals.push(arrival(t, &format!("query item{i:04} topic{}", i % 3), i % 3));
        }
        let out = replay(&mut reg, RouterConfig::default(), &cfg, &arrivals, 8).unwrap();
        assert_eq!(out.per_tenant.len(), 4);
        for r in &out.per_tenant {
            assert_eq!(r.len(), 10);
        }
        assert_eq!(out.rejected, 0);
    }

    #[test]
    fn admission_rejections_are_counted() {
        let mut reg = small_registry(2, 64);
        let cfg = SimConfig::default();
        let arrivals: Vec<Arrival> = (0..20)
            .map(|i| arrival(0, &format!("q item{i:04}"), 0))
            .collect();
        let rc = RouterConfig {
            queue_cap: 4,
            global_cap: 8,
            ..RouterConfig::default()
        };
        // one big batch: only 4 of 20 fit tenant 0's queue per round
        let out = replay(&mut reg, rc, &cfg, &arrivals, 20).unwrap();
        assert!(out.rejected > 0);
        assert!(out.per_tenant[0].len() < 20);
    }

    #[test]
    fn workload_expansion_is_deterministic() {
        let w = crate::datasets::multi_tenant(4, 32, 1.0, 7);
        let a1 = arrivals_from_workload(&w);
        let a2 = arrivals_from_workload(&w);
        assert_eq!(a1.len(), 32);
        assert_eq!(a1[0].seg_keys, a2[0].seg_keys);
        assert!(a1.iter().all(|a| a.seg_keys.len() == 4));
        // no public corpus: nothing is flagged share-eligible
        assert!(a1.iter().all(|a| a.shared.is_empty()));
    }

    #[test]
    fn shared_workload_collides_public_chunk_keys_across_tenants() {
        let w = crate::datasets::multi_tenant_shared(4, 200, 0.0, 7, 1.0);
        assert!(w.shared_topics > 0, "frac 1.0 must mark topics public");
        let arrivals = arrivals_from_workload(&w);
        assert!(
            arrivals
                .iter()
                .all(|a| a.shared == vec![false, true, true, false]),
            "fully public workload: every chunk is share-eligible"
        );
        // the same public topic served to two tenants uses one chunk key
        let mut owner = std::collections::HashMap::new();
        let cross = arrivals.iter().any(|a| {
            *owner.entry(a.seg_keys[1]).or_insert(a.tenant) != a.tenant
        });
        assert!(cross, "public chunk keys must collide across tenants");
    }

    #[test]
    fn reanchor_composes_pooled_segments_across_tenants() {
        let mut tc = TenancyConfig::default();
        tc.global_qkv_bytes = 64 * sim_slice_bytes();
        tc.pool.enabled = true;
        tc.pool.pool_bytes = 16 * sim_slice_bytes();
        let mut reg = TenantRegistry::new(&tc);
        reg.create_tenant().unwrap();
        reg.create_tenant().unwrap();
        let mut cfg = SimConfig::default();
        cfg.reanchor = true;

        let pub_a = fnv1a64(b"public/x/a");
        let pub_b = fnv1a64(b"public/x/b");
        let shared = vec![false, true, true, false];
        // tenant 0 populates the pool from its own prompt
        let keys0 = vec![fnv1a64(b"sys"), pub_a, pub_b, fnv1a64(b"q one")];
        serve_one_shared(
            &cfg,
            reg.shard_mut(0).unwrap(),
            "question alpha one",
            &keys0,
            &shared,
        )
        .unwrap();
        // tenant 1 places the same public chunks after a *different* sys
        // segment: no tree prefix match, but the pooled KV re-anchors
        let keys1 = vec![fnv1a64(b"sys-b"), pub_a, pub_b, fnv1a64(b"q two")];
        let rec = serve_one_shared(
            &cfg,
            reg.shard_mut(1).unwrap(),
            "question beta two",
            &keys1,
            &shared,
        )
        .unwrap();
        assert_eq!(rec.path, ServePath::QkvHit, "re-anchored reuse is a hit");
        let s_tokens = 4 * SEGMENT_TOKENS;
        let full = cfg.dims.prefill_full(s_tokens)
            + cfg.decode_tokens as u64 * cfg.dims.decode_step(s_tokens);
        assert!(
            rec.flops < full,
            "re-anchoring must cost less than recompute ({} vs {full})",
            rec.flops
        );
        // both tenants now hold references to the one pooled copy
        let pool = reg.pool().unwrap();
        let p = crate::util::sync::lock_or_recover(pool);
        assert_eq!(p.refcount(pub_a), 2);
        assert_eq!(p.refcount(pub_b), 2);
    }
}
