//! The global memory governor: divides one device-wide QKV byte budget
//! across tenant shards by caching utility.
//!
//! Allocation = guaranteed floor + utility-proportional share of the
//! remainder (utility = EWMA hit rate × FLOPs saved per byte, see
//! [`crate::tenancy::ShardStats`]).  Two hard properties, both covered by
//! the property suite in rust/tests/properties.rs:
//!
//! 1. the planned budgets never sum above the global budget;
//! 2. every shard receives at least the floor — in particular a shard
//!    with nonzero utility is never starved to zero bytes.
//!
//! The utilities this module receives are already *boosted* by the
//! registry: `TenantRegistry::boosted_utility` multiplies each shard's
//! raw utility by its queue depth and by its windowed SLO signal
//! (miss rate + queue delay, published per scheduling window via
//! `TenantRegistry::set_slo_signals` — the §14 sensor path).  The boost
//! is capped, so saturated overload scales every shard uniformly and
//! the plan holds instead of thrashing; the exact-sum and floor
//! properties below are weight-independent, which is what the scenario
//! suite's saturated-signal property test pins down.
//!
//! A hysteresis band suppresses rebalances whose largest relative budget
//! move is below a threshold, so LFU state is not churned by noise.
//! Budget application goes through `TenantShard::set_qkv_budget`, i.e.
//! the existing `QkvTree::enforce_budget` LFU eviction path; shrinks are
//! applied before grows so global residency never overshoots.

use super::shard::{TenantId, TenantShard};

#[derive(Debug, Clone)]
pub struct GovernorConfig {
    /// Device-wide QKV cache budget shared by all shards.
    pub global_qkv_bytes: usize,
    /// Fraction of the fair share (global/n) guaranteed to every shard.
    pub floor_frac: f64,
    /// Skip a rebalance whose max relative budget change is below this.
    pub hysteresis_frac: f64,
}

impl Default for GovernorConfig {
    fn default() -> Self {
        GovernorConfig {
            global_qkv_bytes: 80 << 20,
            floor_frac: 0.25,
            hysteresis_frac: 0.05,
        }
    }
}

/// One shard's planned budget.
#[derive(Debug, Clone, PartialEq)]
pub struct Allocation {
    pub tenant: TenantId,
    pub bytes: usize,
    pub utility: f64,
}

#[derive(Debug, Clone)]
pub struct MemoryGovernor {
    pub cfg: GovernorConfig,
    /// Rebalances applied / skipped by hysteresis (reporting).
    pub rebalances: u64,
    pub skipped: u64,
    /// Bytes reserved off the top of the global budget before planning —
    /// the cross-tenant slice pool's capacity (DESIGN.md §15).  Private
    /// allocations sum to exactly `global_qkv_bytes - reserved_bytes`,
    /// so exclusive bytes + the pool reserve still sum to the budget.
    reserved_bytes: usize,
}

impl MemoryGovernor {
    pub fn new(cfg: GovernorConfig) -> Self {
        MemoryGovernor {
            cfg,
            rebalances: 0,
            skipped: 0,
            reserved_bytes: 0,
        }
    }

    /// Reserve `bytes` off the top of the global budget (the slice-pool
    /// capacity); planning divides only the remainder across shards.
    pub fn set_reserved_bytes(&mut self, bytes: usize) {
        self.reserved_bytes = bytes.min(self.cfg.global_qkv_bytes);
    }

    pub fn reserved_bytes(&self) -> usize {
        self.reserved_bytes
    }

    /// Pure allocation over (tenant, utility) pairs.  With no utility
    /// signal anywhere (cold start) the split is uniform.
    pub fn plan_weights(&self, entries: &[(TenantId, f64)]) -> Vec<Allocation> {
        let n = entries.len();
        if n == 0 {
            return Vec::new();
        }
        let global = self.cfg.global_qkv_bytes - self.reserved_bytes;
        if n == 1 {
            // single-tenant mode: the whole budget, always
            return vec![Allocation {
                tenant: entries[0].0,
                bytes: global,
                utility: entries[0].1,
            }];
        }
        let fair = global / n;
        let floor = (fair as f64 * self.cfg.floor_frac) as usize;
        let remainder = global.saturating_sub(floor * n);
        let total_u: f64 = entries.iter().map(|(_, u)| u.max(0.0)).sum();
        let mut plan: Vec<Allocation> = entries
            .iter()
            .map(|&(tenant, u)| {
                let share = if total_u > 0.0 {
                    (remainder as f64 * u.max(0.0) / total_u) as usize
                } else {
                    remainder / n
                };
                Allocation {
                    tenant,
                    bytes: floor + share,
                    utility: u,
                }
            })
            .collect();
        // Integer truncation of the floor and of each share strands up to
        // n + total_u bytes; hand the leftover to the highest-utility
        // shard (first on ties) so the plan sums to exactly `global`.
        let allocated: usize = plan.iter().map(|a| a.bytes).sum();
        let leftover = global.saturating_sub(allocated);
        if leftover > 0 {
            let best = plan
                .iter()
                .enumerate()
                .max_by(|(ia, a), (ib, b)| {
                    a.utility
                        .partial_cmp(&b.utility)
                        .unwrap_or(std::cmp::Ordering::Equal)
                        .then(ib.cmp(ia)) // earlier index wins ties
                })
                .map(|(i, _)| i)
                .unwrap_or(0);
            plan[best].bytes += leftover;
        }
        plan
    }

    /// Plan over `(tenant, utility, current_budget)` entries and apply
    /// through `set` — the one implementation of the hysteresis band and
    /// the shrinks-before-grows ordering (so the global working set never
    /// overshoots), shared by every governed backend (cache-level shards
    /// and full `PerCache` engines alike).  Returns true when budgets
    /// actually moved; a plan inside the hysteresis band is skipped
    /// unless `force`.
    pub fn rebalance_entries(
        &mut self,
        entries: &[(TenantId, f64, usize)],
        mut set: impl FnMut(TenantId, usize),
        force: bool,
    ) -> bool {
        let _span = crate::obs::span("governor.rebalance_ms");
        let weights: Vec<(TenantId, f64)> =
            entries.iter().map(|&(t, u, _)| (t, u)).collect();
        let plan = self.plan_weights(&weights);
        let current = |tenant: TenantId| {
            entries
                .iter()
                .find(|e| e.0 == tenant)
                .map(|e| e.2)
                .unwrap_or(0)
        };
        let moved = plan.iter().any(|alloc| {
            let cur = current(alloc.tenant);
            alloc.bytes.abs_diff(cur) as f64 > self.cfg.hysteresis_frac * cur.max(1) as f64
        });
        if !force && !moved {
            self.skipped += 1;
            crate::obs_counter!("governor.rebalance_skipped").inc();
            return false;
        }
        // shrinks first so the global working set never overshoots
        for pass in 0..2 {
            for alloc in &plan {
                let cur = current(alloc.tenant);
                let shrink = alloc.bytes < cur;
                if (pass == 0) == shrink && alloc.bytes != cur {
                    set(alloc.tenant, alloc.bytes);
                }
            }
        }
        self.rebalances += 1;
        crate::obs_counter!("governor.rebalances").inc();
        if crate::obs::enabled() {
            let mut ev = crate::obs::Event::new("governor.rebalance");
            for alloc in &plan {
                let delta = alloc.bytes as f64 - current(alloc.tenant) as f64;
                ev = ev.field(&format!("t{}_delta_bytes", alloc.tenant), delta);
            }
            crate::obs::emit(ev);
        }
        true
    }

    /// Plan and apply over live shards (see [`Self::rebalance_entries`]).
    pub fn rebalance(&mut self, shards: &mut [TenantShard], force: bool) -> bool {
        let entries: Vec<(TenantId, f64, usize)> = shards
            .iter()
            .map(|s| (s.id, s.utility(), s.qkv_budget()))
            .collect();
        self.rebalance_entries(
            &entries,
            |tenant, bytes| {
                if let Some(s) = shards.iter_mut().find(|s| s.id == tenant) {
                    s.set_qkv_budget(bytes);
                }
            },
            force,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn governor(global: usize) -> MemoryGovernor {
        MemoryGovernor::new(GovernorConfig {
            global_qkv_bytes: global,
            floor_frac: 0.25,
            hysteresis_frac: 0.05,
        })
    }

    #[test]
    fn single_tenant_gets_everything() {
        let g = governor(1000);
        let plan = g.plan_weights(&[(0, 0.0)]);
        assert_eq!(plan.len(), 1);
        assert_eq!(plan[0].bytes, 1000);
    }

    #[test]
    fn cold_start_is_uniform() {
        let g = governor(1200);
        let plan = g.plan_weights(&[(0, 0.0), (1, 0.0), (2, 0.0)]);
        let total: usize = plan.iter().map(|a| a.bytes).sum();
        assert_eq!(total, 1200, "plan must sum to exactly the global budget");
        assert_eq!(plan[0].bytes, plan[1].bytes);
        assert_eq!(plan[1].bytes, plan[2].bytes);
    }

    #[test]
    fn truncation_leftover_goes_to_highest_utility() {
        // 1000 over 3 shards: fair 333, floor 83, remainder 751; the
        // truncated shares strand bytes that must land on the top shard
        let g = governor(1000);
        let plan = g.plan_weights(&[(0, 1.0), (1, 5.0), (2, 1.0)]);
        let total: usize = plan.iter().map(|a| a.bytes).sum();
        assert_eq!(total, 1000, "no stranded bytes: {plan:?}");
        let top = plan.iter().max_by_key(|a| a.bytes).unwrap();
        assert_eq!(top.tenant, 1, "leftover must go to the highest utility");
    }

    #[test]
    fn utility_skews_allocation_with_floor() {
        let g = governor(8000);
        let plan = g.plan_weights(&[(0, 9.0), (1, 1.0), (2, 0.0), (3, 0.0)]);
        let total: usize = plan.iter().map(|a| a.bytes).sum();
        assert_eq!(total, 8000, "plan must sum to exactly the global budget");
        assert!(plan[0].bytes > plan[1].bytes);
        assert!(plan[1].bytes > plan[2].bytes);
        // floor: fair share 2000 × 0.25 = 500 — nobody starves
        for a in &plan {
            assert!(a.bytes >= 500, "{a:?} starved");
        }
    }

    #[test]
    fn pool_reserve_shrinks_planning_budget_exactly() {
        let mut g = governor(1000);
        g.set_reserved_bytes(200);
        let plan = g.plan_weights(&[(0, 1.0), (1, 3.0), (2, 0.0)]);
        let total: usize = plan.iter().map(|a| a.bytes).sum();
        assert_eq!(total, 800, "private allocations sum to global - reserve");
        // single-tenant mode still hands over the whole (reduced) budget
        let plan = g.plan_weights(&[(7, 0.0)]);
        assert_eq!(plan[0].bytes, 800);
        // a reserve can never exceed the global budget
        g.set_reserved_bytes(usize::MAX);
        assert_eq!(g.reserved_bytes(), 1000);
        assert_eq!(g.plan_weights(&[(0, 1.0)])[0].bytes, 0);
    }

    #[test]
    fn rebalance_applies_and_hysteresis_skips() {
        let mut g = governor(8 * 4096);
        let mut shards: Vec<TenantShard> =
            (0..4).map(|i| TenantShard::new(i, 1024, 4096, 0.5)).collect();
        // first rebalance from uniform cold start: forced
        assert!(g.rebalance(&mut shards, true));
        assert_eq!(g.rebalances, 1);
        // no utility change → plan identical → hysteresis skips
        assert!(!g.rebalance(&mut shards, false));
        assert_eq!(g.skipped, 1);
        // a shard becomes clearly useful → budgets move
        for _ in 0..32 {
            shards[0]
                .stats
                .note(crate::metrics::ServePath::QkvHit, 1_000_000);
        }
        assert!(g.rebalance(&mut shards, false));
        assert!(shards[0].qkv_budget() > shards[1].qkv_budget());
    }
}
