//! The tenant registry: owns every tenant's cache shard and the memory
//! governor that arbitrates bytes between them.  With a persistence
//! directory attached ([`TenantRegistry::open_or_create`]) every shard
//! lives in its own `shard_<id>/` subdirectory and survives process
//! restarts.
//!
//! Shards have a residency state ([`crate::tiering::Residency`]): a Hot
//! shard is fully in RAM; a Cold shard exists only as its on-disk
//! snapshot (the warm/cold tiering subsystem, DESIGN.md §11).  The
//! registry owns the *mechanics* — [`Self::demote_tenant`] snapshots a
//! shard and drops it, [`Self::begin_hydration`]/[`Self::finish_hydration`]
//! page it back in — while the demotion/prefetch *policy* lives in
//! [`crate::tiering::TieringController`].  The governor plans only over
//! resident shards, so demoting a shard returns its bytes to the global
//! pool for the hot shards to absorb.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::config::TenancyConfig;
use crate::pool::{PoolHandle, PoolTenant, SlicePool};
use crate::tiering::Residency;
use crate::util::sync::lock_or_recover;

use super::governor::{Allocation, GovernorConfig, MemoryGovernor};
use super::shard::{TenantId, TenantShard};
use super::slo::SloSignal;

/// Everything a (possibly background) hydration needs to rebuild a cold
/// shard from its snapshot directory.
#[derive(Debug, Clone)]
pub struct HydrationSpec {
    pub tenant: TenantId,
    pub dir: PathBuf,
    pub qa_bytes: usize,
    /// Restore under the full global budget so the warm tree pages in
    /// intact; the post-install rebalance shrinks it to the governed
    /// share through the LFU path.
    pub qkv_bytes: usize,
    pub utility_alpha: f64,
    /// Tenant-scoped handle into the shared slice pool, when enabled —
    /// the rebuild re-acquires the manifest's pooled references with it.
    pub pool: Option<PoolHandle>,
}

/// One tenant's slot: residency state + the shard when resident, plus
/// cold-tier accounting for the disk budget.
struct Slot {
    residency: Residency,
    shard: Option<TenantShard>,
    /// On-disk snapshot size measured at demotion (0 while resident).
    cold_bytes: u64,
    /// Monotonic demotion stamp: the cold-tier LRU order.
    demote_seq: u64,
    /// The cold snapshot was evicted by the disk budget; hydration must
    /// fail loudly and [`TenantRegistry::recreate_evicted`] is the only
    /// way back.
    evicted: bool,
}

/// Total bytes under a directory tree (0 on any I/O error: sizing is
/// accounting, not correctness).
fn dir_bytes(path: &Path) -> u64 {
    let Ok(entries) = std::fs::read_dir(path) else {
        return 0;
    };
    let mut total = 0u64;
    for entry in entries.flatten() {
        let Ok(meta) = entry.metadata() else {
            continue;
        };
        if meta.is_dir() {
            total += dir_bytes(&entry.path());
        } else {
            total += meta.len();
        }
    }
    total
}

/// Numeric code for the per-tenant `tiering.residency` gauge
/// (0 = hot, 1 = demoting, 2 = cold, 3 = hydrating).
fn residency_code(r: Residency) -> i64 {
    match r {
        Residency::Hot => 0,
        Residency::Demoting => 1,
        Residency::Cold => 2,
        Residency::Hydrating => 3,
    }
}

fn note_residency(id: TenantId, r: Residency) {
    if crate::obs::enabled() {
        crate::obs::gauge_labeled("tiering.residency", &[("tenant", &format!("{id}"))])
            .set(residency_code(r));
    }
}

pub struct TenantRegistry {
    slots: Vec<Slot>,
    pub governor: MemoryGovernor,
    cfg: TenancyConfig,
    /// Serves since the last governor pass (drives `rebalance_every`).
    serves_since_rebalance: u64,
    /// Base directory for per-shard persistence (None = memory shards).
    dir: Option<PathBuf>,
    /// Router queue depths, fed via [`Self::set_queue_depths`]; boosts
    /// the governor utility of backlogged tenants.
    queue_depths: Vec<usize>,
    /// Per-tenant SLO signals, fed via [`Self::set_slo_signals`]; boosts
    /// governor utility for tenants missing their latency targets.
    slo_signals: Vec<SloSignal>,
    /// Monotonic demotion counter stamping cold-tier LRU order.
    demote_stamp: u64,
    /// Tiering counters (reporting).
    pub demotions: u64,
    pub hydrations: u64,
    pub cold_evictions: u64,
    /// Cross-tenant content-addressed slice pool (DESIGN.md §15), when
    /// `cfg.pool.enabled`.  Every shard's store holds a [`PoolHandle`]
    /// into this one pool; the governor reserves its capacity off the
    /// top of the global budget.
    pool: Option<Arc<Mutex<SlicePool>>>,
}

impl TenantRegistry {
    pub fn new(cfg: &TenancyConfig) -> Self {
        let mut governor = MemoryGovernor::new(GovernorConfig {
            global_qkv_bytes: cfg.global_qkv_bytes,
            floor_frac: cfg.floor_frac,
            hysteresis_frac: cfg.hysteresis_frac,
        });
        let pool = if cfg.pool.enabled {
            governor.set_reserved_bytes(cfg.pool.pool_bytes);
            Some(SlicePool::memory(cfg.pool.pool_bytes).shared())
        } else {
            None
        };
        TenantRegistry {
            slots: Vec::new(),
            governor,
            cfg: cfg.clone(),
            serves_since_rebalance: 0,
            dir: None,
            queue_depths: Vec::new(),
            slo_signals: Vec::new(),
            demote_stamp: 0,
            demotions: 0,
            hydrations: 0,
            cold_evictions: 0,
            pool,
        }
    }

    /// Open (or create) a persistent registry at `dir`: existing
    /// `shard_<id>/` subdirectories are resumed in id order (warm
    /// restart for every tenant), and tenants created later get their
    /// own persistent subdirectory.  Pair with [`Self::save_all`].
    pub fn open_or_create(cfg: &TenancyConfig, dir: PathBuf) -> Result<Self> {
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating tenant dir {}", dir.display()))?;
        let mut reg = Self::new(cfg);
        reg.dir = Some(dir.clone());
        // persistent registries get a persistent pool: payloads + manifest
        // live in `pool/`, and resumed shard manifests below re-acquire
        // their references (the per-tenant refcount rebuild)
        if cfg.pool.enabled {
            reg.pool =
                Some(SlicePool::disk(dir.join("pool"), cfg.pool.pool_bytes)?.shared());
        }
        let mut ids: Vec<u32> = Vec::new();
        for entry in
            std::fs::read_dir(&dir).with_context(|| format!("listing {}", dir.display()))?
        {
            let entry = entry?;
            let name = entry.file_name().to_string_lossy().into_owned();
            if let Some(id) = name.strip_prefix("shard_").and_then(|s| s.parse::<u32>().ok()) {
                ids.push(id);
            }
        }
        ids.sort_unstable();
        for (i, id) in ids.iter().enumerate() {
            anyhow::ensure!(
                *id == i as u32,
                "shard directories must be contiguous from 0 (found shard_{id} at position {i})"
            );
            reg.create_tenant()?;
        }
        Ok(reg)
    }

    /// Base persistence directory, when attached.
    pub fn persist_dir(&self) -> Option<&PathBuf> {
        self.dir.as_ref()
    }

    pub fn config(&self) -> &TenancyConfig {
        &self.cfg
    }

    // -- the cross-tenant slice pool (DESIGN.md §15) ----------------------

    /// The shared slice pool, when `cfg.pool.enabled`.
    pub fn pool(&self) -> Option<&Arc<Mutex<SlicePool>>> {
        self.pool.as_ref()
    }

    /// A tenant-scoped handle into the shared pool (None when disabled).
    fn pool_handle(&self, id: TenantId) -> Option<PoolHandle> {
        self.pool
            .as_ref()
            .map(|p| PoolHandle::new(Arc::clone(p), id))
    }

    /// Bytes resident in the pool (0 when disabled).
    pub fn pool_bytes_used(&self) -> usize {
        self.pool
            .as_ref()
            .map(|p| lock_or_recover(p).bytes_used())
            .unwrap_or(0)
    }

    /// Each tenant's amortized share of the pooled bytes it references
    /// (`bytes × tenant_refs / refcount`, largest-remainder rounded so
    /// the shares sum exactly to the referenced pool bytes).  Empty when
    /// the pool is disabled.
    pub fn pool_shares(&self) -> HashMap<PoolTenant, usize> {
        self.pool
            .as_ref()
            .map(|p| lock_or_recover(p).amortized_shares())
            .unwrap_or_default()
    }

    /// What the governor charges one tenant: its exclusive bytes (QKV
    /// tree including pooled-slice handles, plus QA bank) plus its
    /// amortized share of the pooled bytes it references.
    pub fn charged_bytes(&self, id: TenantId) -> usize {
        let exclusive = self.shard(id).map(|s| s.bytes_used()).unwrap_or(0);
        exclusive + self.pool_shares().get(&id).copied().unwrap_or(0)
    }

    /// Snapshot every resident shard's cache state (persistent
    /// registries only).  Cold shards were snapshotted at demotion and
    /// hold no newer state.  Returns how many shards were saved.
    pub fn save_all(&mut self) -> Result<usize> {
        anyhow::ensure!(
            self.dir.is_some(),
            "save_all requires a persistent registry (open_or_create)"
        );
        let mut saved = 0;
        for slot in &mut self.slots {
            if let Some(shard) = slot.shard.as_mut() {
                shard.save()?;
                saved += 1;
            }
        }
        Ok(saved)
    }

    /// Single-tenant mode: one shard holding the whole global budget —
    /// the configuration under which the paper experiments run unchanged.
    pub fn single_tenant(cfg: &TenancyConfig) -> Self {
        let mut reg = Self::new(cfg);
        // percache-allow(panic_path): constructor precondition — create_tenant on a fresh registry only fails if max_tenants == 0, a config bug worth dying on
        reg.create_tenant().expect("max_tenants >= 1");
        reg
    }

    /// Register a new tenant; every shard's budget is re-planned so the
    /// newcomer starts from its governed share (cold start: uniform).
    pub fn create_tenant(&mut self) -> Result<TenantId> {
        anyhow::ensure!(
            self.slots.len() < self.cfg.max_tenants,
            "tenant limit reached ({})",
            self.cfg.max_tenants
        );
        let id = self.slots.len() as TenantId;
        let shard = match &self.dir {
            None => TenantShard::with_pool(
                id,
                self.cfg.qa_bytes_per_tenant,
                0, // budget assigned by the forced rebalance below
                self.cfg.utility_alpha,
                self.pool_handle(id),
            ),
            // persistent shard: restore under the full global budget so a
            // warm tree is paged in intact, then let the forced rebalance
            // below shrink it to the governed share through the LFU path
            Some(base) => TenantShard::open_or_create_pooled(
                id,
                self.cfg.qa_bytes_per_tenant,
                self.cfg.global_qkv_bytes,
                self.cfg.utility_alpha,
                base.join(format!("shard_{id}")),
                self.pool_handle(id),
            )?,
        };
        self.slots.push(Slot {
            residency: Residency::Hot,
            shard: Some(shard),
            cold_bytes: 0,
            demote_seq: 0,
            evicted: false,
        });
        self.queue_depths.push(0);
        self.rebalance_resident(true);
        Ok(id)
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// The tenant's shard, when resident (None for cold/hydrating
    /// shards and unknown tenants).
    pub fn shard(&self, id: TenantId) -> Option<&TenantShard> {
        self.slots.get(id as usize).and_then(|s| s.shard.as_ref())
    }

    pub fn shard_mut(&mut self, id: TenantId) -> Option<&mut TenantShard> {
        self.slots
            .get_mut(id as usize)
            .and_then(|s| s.shard.as_mut())
    }

    /// Resident shards in id order (every shard, when tiering never
    /// demoted anything).
    pub fn shards(&self) -> Vec<&TenantShard> {
        self.slots.iter().filter_map(|s| s.shard.as_ref()).collect()
    }

    /// The tenant's residency state (None for unknown tenants).
    pub fn residency(&self, id: TenantId) -> Option<Residency> {
        self.slots.get(id as usize).map(|s| s.residency)
    }

    pub fn resident_count(&self) -> usize {
        self.slots.iter().filter(|s| s.shard.is_some()).count()
    }

    /// RAM held by resident shards (QKV tree + QA bank) — the byte count
    /// demotion observably shrinks.
    pub fn resident_bytes(&self) -> usize {
        self.slots
            .iter()
            .filter_map(|s| s.shard.as_ref())
            .map(|s| s.bytes_used())
            .sum()
    }

    /// Feed per-tenant router queue depths: the governor boosts the
    /// utility of backlogged tenants (`queue_weight`) so overload grows a
    /// shard's allocation, and the tiering controller refuses to demote a
    /// tenant with queued work even when its hit rate dips.
    pub fn set_queue_depths(&mut self, depths: &[usize]) {
        self.queue_depths.resize(self.slots.len(), 0);
        for (i, d) in self.queue_depths.iter_mut().enumerate() {
            *d = depths.get(i).copied().unwrap_or(0);
        }
    }

    pub fn queue_depth(&self, id: TenantId) -> usize {
        self.queue_depths.get(id as usize).copied().unwrap_or(0)
    }

    /// Feed per-tenant SLO signals (windowed miss rate + queue-delay
    /// quantile, read back from the obs registry by the SLO monitor):
    /// tenants missing their latency targets gain governor utility.
    /// Never calling this (or passing an empty slice) leaves the
    /// pre-SLO behaviour untouched.
    pub fn set_slo_signals(&mut self, signals: &[SloSignal]) {
        self.slo_signals.resize(self.slots.len(), SloSignal::default());
        for (i, s) in self.slo_signals.iter_mut().enumerate() {
            *s = signals.get(i).copied().unwrap_or_default();
        }
    }

    pub fn slo_signal(&self, id: TenantId) -> SloSignal {
        self.slo_signals
            .get(id as usize)
            .copied()
            .unwrap_or_default()
    }

    /// Multiplicative SLO boost for one tenant's governor utility:
    /// `1 + min(miss_weight·miss_rate + delay_weight·delay_ratio,
    /// boost_cap)`.  The cap is what keeps saturated overload stable —
    /// when every tenant pegs its signals the boost is uniform, relative
    /// weights are unchanged, and the governor's hysteresis holds the
    /// plan instead of thrashing it.
    fn slo_boost(&self, idx: usize) -> f64 {
        let Some(sig) = self.slo_signals.get(idx) else {
            return 1.0;
        };
        let delay_ratio = if sig.target_ms > 0.0 {
            (sig.queue_delay_ms / sig.target_ms).min(1.0)
        } else {
            0.0
        };
        let raw = self.cfg.slo.miss_weight * sig.miss_rate.clamp(0.0, 1.0)
            + self.cfg.slo.delay_weight * delay_ratio;
        1.0 + raw.min(self.cfg.slo.boost_cap)
    }

    /// Governor utility of one resident shard, boosted by its queue
    /// depth (the queueing signal from the router) and its SLO signal
    /// (miss rate + queue delay, from the SLO monitor).  `pool_share` is
    /// the tenant's amortized share of pooled bytes — pooled capacity is
    /// charged into the utility denominator exactly like exclusive bytes,
    /// so dedup makes a shard look (correctly) cheaper, not free.
    fn boosted_utility(&self, idx: usize, shard: &TenantShard, pool_share: usize) -> f64 {
        let depth = self.queue_depths.get(idx).copied().unwrap_or(0);
        shard.stats.utility(shard.bytes_used() + pool_share)
            * (1.0 + self.cfg.queue_weight * depth as f64)
            * self.slo_boost(idx)
    }

    /// Plan + apply budgets over the resident shards through the
    /// governor's shared hysteresis/shrink-first path.
    fn rebalance_resident(&mut self, force: bool) -> bool {
        let shares = self.pool_shares();
        let entries: Vec<(TenantId, f64, usize)> = self
            .slots
            .iter()
            .enumerate()
            .filter_map(|(i, slot)| {
                slot.shard.as_ref().map(|s| {
                    let share = shares.get(&s.id).copied().unwrap_or(0);
                    (s.id, self.boosted_utility(i, s, share), s.qkv_budget())
                })
            })
            .collect();
        if crate::obs::enabled() {
            for &(t, u, _) in &entries {
                let tenant = format!("{t}");
                crate::obs::gauge_labeled("governor.utility_milli", &[("tenant", &tenant)])
                    .set((u * 1e3) as i64);
            }
        }
        let TenantRegistry { slots, governor, .. } = self;
        governor.rebalance_entries(
            &entries,
            |tenant, bytes| {
                if let Some(s) = slots
                    .get_mut(tenant as usize)
                    .and_then(|sl| sl.shard.as_mut())
                {
                    s.set_qkv_budget(bytes);
                    if crate::obs::enabled() {
                        let label = format!("{tenant}");
                        crate::obs::gauge_labeled("governor.shard_bytes", &[("tenant", &label)])
                            .set(bytes as i64);
                    }
                }
            },
            force,
        )
    }

    /// Count one serve; every `rebalance_every` serves the governor gets
    /// a chance to move bytes.  Returns true when a rebalance applied.
    pub fn note_serve(&mut self) -> bool {
        self.serves_since_rebalance += 1;
        if self.serves_since_rebalance >= self.cfg.rebalance_every as u64 {
            self.serves_since_rebalance = 0;
            return self.rebalance_resident(false);
        }
        false
    }

    /// Force an immediate governor pass (bypasses cadence + hysteresis).
    pub fn rebalance_now(&mut self) -> bool {
        self.serves_since_rebalance = 0;
        self.rebalance_resident(true)
    }

    /// Current governed plan over resident shards (reporting / tests).
    pub fn plan(&self) -> Vec<Allocation> {
        let shares = self.pool_shares();
        let weights: Vec<(TenantId, f64)> = self
            .slots
            .iter()
            .enumerate()
            .filter_map(|(i, slot)| {
                slot.shard.as_ref().map(|s| {
                    let share = shares.get(&s.id).copied().unwrap_or(0);
                    (s.id, self.boosted_utility(i, s, share))
                })
            })
            .collect();
        self.governor.plan_weights(&weights)
    }

    pub fn total_qkv_used(&self) -> usize {
        self.slots
            .iter()
            .filter_map(|s| s.shard.as_ref())
            .map(|s| s.tree.bytes_used())
            .sum()
    }

    pub fn total_qkv_budget(&self) -> usize {
        self.slots
            .iter()
            .filter_map(|s| s.shard.as_ref())
            .map(|s| s.qkv_budget())
            .sum()
    }

    // -- warm/cold tiering mechanics (policy in tiering::controller) ------

    /// Demote a Hot shard to the cold tier: snapshot it into its
    /// `shard_<id>/` directory, drop the in-RAM shard, and hand its
    /// budget back to the resident shards.  Returns the resident bytes
    /// freed.  A failed snapshot leaves the shard Hot and resident.
    pub fn demote_tenant(&mut self, id: TenantId) -> Result<usize> {
        let shard_dir = self
            .dir
            .as_ref()
            .map(|base| base.join(format!("shard_{id}")))
            .context("demotion requires a persistent registry (open_or_create)")?;
        let slot = self
            .slots
            .get_mut(id as usize)
            .with_context(|| format!("unknown tenant {id}"))?;
        anyhow::ensure!(
            slot.residency == Residency::Hot,
            "tenant {id} is {}, only hot shards demote",
            slot.residency.label()
        );
        slot.residency = Residency::Demoting;
        let Some(shard) = slot.shard.as_mut() else {
            // a Hot slot without a shard is an invariant breach, but a
            // refused demotion degrades better than a dead router loop
            slot.residency = Residency::Hot;
            anyhow::bail!("tenant {id} slot is hot but holds no shard");
        };
        match shard.save() {
            Ok(_wrote) => {
                let freed = shard.bytes_used();
                slot.shard = None;
                slot.residency = Residency::Cold;
                slot.cold_bytes = dir_bytes(&shard_dir);
                slot.evicted = false;
                self.demote_stamp += 1;
                slot.demote_seq = self.demote_stamp;
                self.demotions += 1;
                crate::obs_counter!("tiering.demotions").inc();
                note_residency(id, Residency::Cold);
                crate::obs::emit(
                    crate::obs::Event::new("tenant.demoted")
                        .tenant(id as usize)
                        .field("freed_bytes", freed as f64),
                );
                // the freed budget flows to the remaining resident shards
                self.rebalance_resident(true);
                // dropping the shard's store released its pool refs (the
                // manifest re-acquires them at hydration); entries it was
                // the last holder of are zero-ref now, never stranded
                if let Some(pool) = &self.pool {
                    lock_or_recover(pool).enforce();
                }
                crate::obs_gauge!("tiering.resident_shards").set(self.resident_count() as i64);
                crate::obs_gauge!("tiering.resident_bytes").set(self.resident_bytes() as i64);
                crate::obs_gauge!("tiering.cold_bytes").set(self.cold_bytes() as i64);
                Ok(freed)
            }
            Err(e) => {
                slot.residency = Residency::Hot;
                Err(e.context(format!("demoting tenant {id}")))
            }
        }
    }

    /// Cold-tier footprint: snapshot bytes of every cold, non-evicted
    /// shard (measured at demotion time).
    pub fn cold_bytes(&self) -> u64 {
        self.slots
            .iter()
            .filter(|s| s.residency == Residency::Cold && !s.evicted)
            .map(|s| s.cold_bytes)
            .sum()
    }

    /// The cold shard demoted longest ago (the disk budget's LRU
    /// victim); None when the cold tier is empty.
    pub fn oldest_cold(&self) -> Option<TenantId> {
        self.slots
            .iter()
            .enumerate()
            .filter(|(_, s)| s.residency == Residency::Cold && !s.evicted)
            .min_by_key(|(_, s)| s.demote_seq)
            .map(|(i, _)| i as TenantId)
    }

    /// Was this tenant's cold snapshot evicted by the disk budget?
    pub fn cold_evicted(&self, id: TenantId) -> bool {
        matches!(self.slots.get(id as usize), Some(s) if s.evicted)
    }

    /// Evict a cold shard's snapshot from disk (the cold-tier budget's
    /// LRU victim).  The tenant stays Cold but marked evicted: a later
    /// hydration fails loudly, and [`Self::recreate_evicted`] is the
    /// explicit restart path.  Returns the snapshot bytes freed.
    pub fn evict_cold(&mut self, id: TenantId) -> Result<u64> {
        let shard_dir = self
            .dir
            .as_ref()
            .map(|base| base.join(format!("shard_{id}")))
            .context("cold eviction requires a persistent registry")?;
        let slot = self
            .slots
            .get_mut(id as usize)
            .with_context(|| format!("unknown tenant {id}"))?;
        anyhow::ensure!(
            slot.residency == Residency::Cold && !slot.evicted,
            "tenant {id} is {}{}, only cold snapshots evict",
            slot.residency.label(),
            if slot.evicted { " (already evicted)" } else { "" }
        );
        std::fs::remove_dir_all(&shard_dir)
            .with_context(|| format!("evicting cold snapshot {}", shard_dir.display()))?;
        let freed = slot.cold_bytes;
        slot.cold_bytes = 0;
        slot.evicted = true;
        self.cold_evictions += 1;
        crate::obs_counter!("tiering.cold_evictions").inc();
        crate::obs_gauge!("tiering.cold_bytes").set(self.cold_bytes() as i64);
        crate::obs::emit(
            crate::obs::Event::new("tenant.cold_evicted")
                .tenant(id as usize)
                .field("freed_bytes", freed as f64),
        );
        Ok(freed)
    }

    /// Restart an evicted tenant from scratch: installs a fresh, empty
    /// Hot shard in a new snapshot directory.  The cache contents are
    /// gone — that is the disk budget's explicit cost — but the tenant
    /// serves again.
    pub fn recreate_evicted(&mut self, id: TenantId) -> Result<()> {
        let shard_dir = self
            .dir
            .as_ref()
            .map(|base| base.join(format!("shard_{id}")))
            .context("recreate_evicted requires a persistent registry")?;
        let slot = self
            .slots
            .get_mut(id as usize)
            .with_context(|| format!("unknown tenant {id}"))?;
        anyhow::ensure!(
            slot.residency == Residency::Cold && slot.evicted,
            "tenant {id} is {}, recreate_evicted is only for evicted cold tenants",
            slot.residency.label()
        );
        let shard = TenantShard::open_or_create_pooled(
            id,
            self.cfg.qa_bytes_per_tenant,
            self.cfg.global_qkv_bytes,
            self.cfg.utility_alpha,
            shard_dir,
            self.pool_handle(id),
        )?;
        let slot = self
            .slots
            .get_mut(id as usize)
            .with_context(|| format!("unknown tenant {id}"))?;
        slot.shard = Some(shard);
        slot.residency = Residency::Hot;
        slot.evicted = false;
        note_residency(id, Residency::Hot);
        crate::obs::emit(crate::obs::Event::new("tenant.recreated").tenant(id as usize));
        self.rebalance_resident(true);
        crate::obs_gauge!("tiering.resident_shards").set(self.resident_count() as i64);
        crate::obs_gauge!("tiering.resident_bytes").set(self.resident_bytes() as i64);
        Ok(())
    }

    /// Start paging a Cold shard back in: marks it Hydrating and returns
    /// the spec a (background) worker needs to rebuild it.  Complete with
    /// [`Self::finish_hydration`] or roll back with
    /// [`Self::abort_hydration`].
    pub fn begin_hydration(&mut self, id: TenantId) -> Result<HydrationSpec> {
        let base = self
            .dir
            .clone()
            .context("hydration requires a persistent registry (open_or_create)")?;
        let slot = self
            .slots
            .get_mut(id as usize)
            .with_context(|| format!("unknown tenant {id}"))?;
        anyhow::ensure!(
            slot.residency == Residency::Cold,
            "tenant {id} is {}, only cold shards hydrate",
            slot.residency.label()
        );
        anyhow::ensure!(
            !slot.evicted,
            "tenant {id} cold snapshot was evicted by the cold-tier disk \
             budget; recreate_evicted starts it fresh"
        );
        slot.residency = Residency::Hydrating;
        Ok(HydrationSpec {
            tenant: id,
            dir: base.join(format!("shard_{id}")),
            qa_bytes: self.cfg.qa_bytes_per_tenant,
            qkv_bytes: self.cfg.global_qkv_bytes,
            utility_alpha: self.cfg.utility_alpha,
            pool: self.pool_handle(id),
        })
    }

    /// Install a rebuilt shard (the other half of
    /// [`Self::begin_hydration`]); the forced rebalance shrinks the
    /// restored tree to the shard's governed share through the LFU path.
    pub fn finish_hydration(&mut self, id: TenantId, shard: TenantShard) -> Result<()> {
        anyhow::ensure!(
            shard.id == id,
            "hydrated shard id {} does not match tenant {id}",
            shard.id
        );
        let slot = self
            .slots
            .get_mut(id as usize)
            .with_context(|| format!("unknown tenant {id}"))?;
        anyhow::ensure!(
            slot.residency == Residency::Hydrating,
            "tenant {id} is {}, expected hydrating",
            slot.residency.label()
        );
        slot.shard = Some(shard);
        slot.residency = Residency::Hot;
        self.hydrations += 1;
        crate::obs_counter!("tiering.hydrations").inc();
        note_residency(id, Residency::Hot);
        self.rebalance_resident(true);
        crate::obs_gauge!("tiering.resident_shards").set(self.resident_count() as i64);
        crate::obs_gauge!("tiering.resident_bytes").set(self.resident_bytes() as i64);
        Ok(())
    }

    /// Roll a failed hydration back to Cold (the snapshot on disk is
    /// untouched; a later request may retry).
    pub fn abort_hydration(&mut self, id: TenantId) -> Result<()> {
        let slot = self
            .slots
            .get_mut(id as usize)
            .with_context(|| format!("unknown tenant {id}"))?;
        anyhow::ensure!(
            slot.residency == Residency::Hydrating,
            "tenant {id} is {}, expected hydrating",
            slot.residency.label()
        );
        slot.residency = Residency::Cold;
        Ok(())
    }

    /// Synchronous demote→hydrate round trip for callers without a
    /// background worker (CLI paths, shutdown drains, tests).
    pub fn hydrate_tenant(&mut self, id: TenantId) -> Result<()> {
        let spec = self.begin_hydration(id)?;
        let pool = spec.pool.clone();
        match TenantShard::open_or_create_pooled(
            spec.tenant,
            spec.qa_bytes,
            spec.qkv_bytes,
            spec.utility_alpha,
            spec.dir,
            pool,
        ) {
            Ok(shard) => self.finish_hydration(id, shard),
            Err(e) => {
                let _ = self.abort_hydration(id);
                Err(e.context(format!("hydrating tenant {id}")))
            }
        }
    }

    /// Registry-wide invariants: per-shard consistency, the global
    /// budget bound (budgets and residency never exceed the governed
    /// global byte budget), and residency/slot agreement.
    pub fn check_invariants(&self) -> Result<()> {
        for slot in &self.slots {
            anyhow::ensure!(
                slot.residency.is_resident() == slot.shard.is_some(),
                "slot residency {} disagrees with shard presence {}",
                slot.residency.label(),
                slot.shard.is_some()
            );
            if let Some(s) = &slot.shard {
                s.check_invariants()?;
            }
        }
        anyhow::ensure!(
            self.total_qkv_budget() <= self.governor.cfg.global_qkv_bytes,
            "shard budgets {} exceed global {}",
            self.total_qkv_budget(),
            self.governor.cfg.global_qkv_bytes
        );
        anyhow::ensure!(
            self.total_qkv_used() <= self.governor.cfg.global_qkv_bytes,
            "shard residency {} exceeds global {}",
            self.total_qkv_used(),
            self.governor.cfg.global_qkv_bytes
        );
        if let Some(pool) = &self.pool {
            let p = lock_or_recover(pool);
            p.check_invariants()?;
            anyhow::ensure!(
                p.bytes_used() <= p.cap_bytes(),
                "pool residency {} exceeds its cap {}",
                p.bytes_used(),
                p.cap_bytes()
            );
            drop(p);
            anyhow::ensure!(
                self.total_qkv_budget() + self.governor.reserved_bytes()
                    <= self.governor.cfg.global_qkv_bytes,
                "shard budgets {} + pool reserve {} exceed global {}",
                self.total_qkv_budget(),
                self.governor.reserved_bytes(),
                self.governor.cfg.global_qkv_bytes
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::llm::QkvTensor;
    use crate::metrics::ServePath;

    fn cfg(global: usize) -> TenancyConfig {
        TenancyConfig {
            global_qkv_bytes: global,
            ..TenancyConfig::default()
        }
    }

    fn tmp(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "percache_registry_{tag}_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn single_tenant_holds_whole_budget() {
        let reg = TenantRegistry::single_tenant(&cfg(1 << 20));
        assert_eq!(reg.len(), 1);
        assert_eq!(reg.shard(0).unwrap().qkv_budget(), 1 << 20);
        reg.check_invariants().unwrap();
    }

    #[test]
    fn create_many_respects_global_budget() {
        let mut reg = TenantRegistry::new(&cfg(64 * 4096));
        for _ in 0..8 {
            reg.create_tenant().unwrap();
        }
        assert_eq!(reg.len(), 8);
        reg.check_invariants().unwrap();
        // cold start: equal budgets
        let b0 = reg.shard(0).unwrap().qkv_budget();
        assert!(reg.shards().iter().all(|s| s.qkv_budget() == b0));
    }

    #[test]
    fn tenant_limit_enforced() {
        let mut tc = cfg(1 << 20);
        tc.max_tenants = 2;
        let mut reg = TenantRegistry::new(&tc);
        reg.create_tenant().unwrap();
        reg.create_tenant().unwrap();
        assert!(reg.create_tenant().is_err());
    }

    #[test]
    fn note_serve_triggers_periodic_rebalance() {
        let mut tc = cfg(32 * 3088);
        tc.rebalance_every = 4;
        let mut reg = TenantRegistry::new(&tc);
        for _ in 0..2 {
            reg.create_tenant().unwrap();
        }
        // make tenant 0 useful so the periodic pass has something to move
        let t = QkvTensor::zeros(1, 4, 64);
        reg.shard_mut(0).unwrap().insert_path(&[1], vec![t]).unwrap();
        for _ in 0..32 {
            reg.shard_mut(0).unwrap().prefix_match(&[1]);
            reg.shard_mut(0)
                .unwrap()
                .stats
                .note(crate::metrics::ServePath::QkvHit, 1_000_000);
        }
        let mut applied = false;
        for _ in 0..8 {
            applied |= reg.note_serve();
        }
        assert!(applied, "periodic rebalance never applied");
        assert!(
            reg.shard(0).unwrap().qkv_budget() > reg.shard(1).unwrap().qkv_budget(),
            "useful shard did not grow"
        );
        reg.check_invariants().unwrap();
    }

    #[test]
    fn demote_requires_persistence_and_hot_state() {
        let mut reg = TenantRegistry::new(&cfg(1 << 20));
        reg.create_tenant().unwrap();
        assert!(
            reg.demote_tenant(0).is_err(),
            "memory registries must refuse demotion"
        );
        assert_eq!(reg.residency(0), Some(Residency::Hot));
    }

    #[test]
    fn demote_then_hydrate_roundtrip() {
        let dir = tmp("roundtrip");
        let tc = cfg(64 * 3088);
        let mut reg = TenantRegistry::open_or_create(&tc, dir.clone()).unwrap();
        reg.create_tenant().unwrap();
        reg.create_tenant().unwrap();
        let t = QkvTensor::zeros(1, 4, 64);
        reg.shard_mut(1)
            .unwrap()
            .insert_path(&[7, 8], vec![t.clone(), t])
            .unwrap();
        let before = reg.resident_bytes();
        assert_eq!(reg.resident_count(), 2);

        let freed = reg.demote_tenant(1).unwrap();
        assert!(freed > 0, "demotion must free resident bytes");
        assert_eq!(reg.residency(1), Some(Residency::Cold));
        assert!(reg.shard(1).is_none(), "cold shard is not resident");
        assert_eq!(reg.resident_count(), 1);
        assert!(reg.resident_bytes() < before);
        assert_eq!(reg.demotions, 1);
        // double demotion is rejected
        assert!(reg.demote_tenant(1).is_err());
        reg.check_invariants().unwrap();

        reg.hydrate_tenant(1).unwrap();
        assert_eq!(reg.residency(1), Some(Residency::Hot));
        assert_eq!(reg.hydrations, 1);
        let shard = reg.shard_mut(1).unwrap();
        assert_eq!(
            shard.prefix_match(&[7, 8]).len(),
            2,
            "rehydrated shard must serve its cached path"
        );
        reg.check_invariants().unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn queue_depth_boosts_the_governed_plan() {
        let mut tc = cfg(1 << 20);
        tc.queue_weight = 1.0;
        let mut reg = TenantRegistry::new(&tc);
        for _ in 0..2 {
            reg.create_tenant().unwrap();
        }
        // identical utility signals on both shards
        for id in 0..2u32 {
            for _ in 0..16 {
                reg.shard_mut(id)
                    .unwrap()
                    .stats
                    .note(ServePath::QkvHit, 1_000_000);
            }
        }
        // tenant 1 is backlogged: its planned share must grow past 0's
        reg.set_queue_depths(&[0, 8]);
        let plan = reg.plan();
        let b0 = plan.iter().find(|a| a.tenant == 0).unwrap().bytes;
        let b1 = plan.iter().find(|a| a.tenant == 1).unwrap().bytes;
        assert!(
            b1 > b0,
            "backlogged tenant must out-plan the idle one ({b1} vs {b0})"
        );
        assert_eq!(reg.queue_depth(1), 8);
        reg.check_invariants().unwrap();
    }

    #[test]
    fn slo_misses_boost_the_governed_plan() {
        let mut reg = TenantRegistry::new(&cfg(1 << 20));
        for _ in 0..2 {
            reg.create_tenant().unwrap();
        }
        // identical utility signals on both shards
        for id in 0..2u32 {
            for _ in 0..16 {
                reg.shard_mut(id)
                    .unwrap()
                    .stats
                    .note(ServePath::QkvHit, 1_000_000);
            }
        }
        // tenant 1 is blowing its SLO: planned share must grow past 0's
        reg.set_slo_signals(&[
            SloSignal::default(),
            SloSignal {
                miss_rate: 0.8,
                queue_delay_ms: 40.0,
                target_ms: 20.0,
                window_served: 16,
            },
        ]);
        let plan = reg.plan();
        let b0 = plan.iter().find(|a| a.tenant == 0).unwrap().bytes;
        let b1 = plan.iter().find(|a| a.tenant == 1).unwrap().bytes;
        assert!(
            b1 > b0,
            "SLO-missing tenant must out-plan the healthy one ({b1} vs {b0})"
        );
        assert!(reg.slo_signal(1).miss_rate > 0.0);

        // saturated signals on every tenant boost uniformly: the plan
        // returns to parity instead of amplifying noise (anti-thrash)
        reg.set_slo_signals(&[
            SloSignal {
                miss_rate: 1.0,
                queue_delay_ms: 100.0,
                target_ms: 20.0,
                window_served: 16,
            },
            SloSignal {
                miss_rate: 1.0,
                queue_delay_ms: 100.0,
                target_ms: 20.0,
                window_served: 16,
            },
        ]);
        let plan = reg.plan();
        let b0 = plan.iter().find(|a| a.tenant == 0).unwrap().bytes;
        let b1 = plan.iter().find(|a| a.tenant == 1).unwrap().bytes;
        assert!(
            b0.abs_diff(b1) <= 1,
            "uniformly saturated SLO signals must keep parity ({b0} vs {b1})"
        );
        reg.check_invariants().unwrap();
    }

    fn pooled_cfg(global: usize, pool: usize) -> TenancyConfig {
        let mut tc = cfg(global);
        tc.pool.enabled = true;
        tc.pool.pool_bytes = pool;
        tc
    }

    #[test]
    fn pooled_registry_dedups_and_plans_to_reduced_budget() {
        let tc = pooled_cfg(1 << 20, 1 << 18);
        let mut reg = TenantRegistry::new(&tc);
        for _ in 0..2 {
            reg.create_tenant().unwrap();
        }
        let t = QkvTensor::zeros(1, 4, 64);
        reg.shard_mut(0)
            .unwrap()
            .insert_path_shared(&[5], vec![t.clone()], &[true])
            .unwrap();
        reg.shard_mut(1)
            .unwrap()
            .insert_path_shared(&[5], vec![t], &[true])
            .unwrap();
        assert!(reg.pool_bytes_used() > 0, "shared slice landed in the pool");
        let shares = reg.pool_shares();
        let total_share: usize = shares.values().sum();
        assert_eq!(
            total_share,
            reg.pool_bytes_used(),
            "amortized shares sum exactly to the referenced pool bytes"
        );
        assert_eq!(shares.get(&0), shares.get(&1), "equal refs, equal shares");
        assert!(reg.charged_bytes(0) > reg.shard(0).unwrap().bytes_used());
        // private allocations + the pool reserve sum exactly to global
        let planned: usize = reg.plan().iter().map(|a| a.bytes).sum();
        assert_eq!(planned + reg.governor.reserved_bytes(), 1 << 20);
        reg.check_invariants().unwrap();
    }

    #[test]
    fn pooled_refcounts_survive_demote_hydrate_and_restart() {
        let dir = tmp("pool_restart");
        let tc = pooled_cfg(1 << 20, 1 << 18);
        {
            let mut reg = TenantRegistry::open_or_create(&tc, dir.clone()).unwrap();
            reg.create_tenant().unwrap();
            reg.create_tenant().unwrap();
            let t = QkvTensor::zeros(1, 4, 64);
            reg.shard_mut(0)
                .unwrap()
                .insert_path_shared(&[9], vec![t.clone()], &[true])
                .unwrap();
            reg.shard_mut(1)
                .unwrap()
                .insert_path_shared(&[9], vec![t], &[true])
                .unwrap();
            assert_eq!(
                crate::util::sync::lock_or_recover(reg.pool().unwrap()).refcount(9),
                2
            );
            reg.save_all().unwrap();
        }
        let mut reg = TenantRegistry::open_or_create(&tc, dir.clone()).unwrap();
        {
            let p = crate::util::sync::lock_or_recover(reg.pool().unwrap());
            assert_eq!(p.len(), 1, "one payload for both tenants after restart");
            assert_eq!(p.refcount(9), 2, "manifests rebuilt both tenants' refs");
        }
        assert_eq!(reg.shard_mut(0).unwrap().prefix_match(&[9]).len(), 1);
        reg.check_invariants().unwrap();

        // demotion releases the reference; hydration re-acquires it
        reg.demote_tenant(1).unwrap();
        assert_eq!(
            crate::util::sync::lock_or_recover(reg.pool().unwrap()).refcount(9),
            1,
            "demoted shard must not strand pool refs"
        );
        reg.hydrate_tenant(1).unwrap();
        assert_eq!(
            crate::util::sync::lock_or_recover(reg.pool().unwrap()).refcount(9),
            2
        );
        assert_eq!(reg.shard_mut(1).unwrap().prefix_match(&[9]).len(), 1);
        reg.check_invariants().unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn cold_eviction_is_oldest_first_and_hydration_fails_loudly() {
        let dir = tmp("cold_evict");
        let tc = cfg(64 * 3088);
        let mut reg = TenantRegistry::open_or_create(&tc, dir.clone()).unwrap();
        for _ in 0..3 {
            reg.create_tenant().unwrap();
        }
        let t = QkvTensor::zeros(1, 4, 64);
        for id in 0..3u32 {
            reg.shard_mut(id)
                .unwrap()
                .insert_path(&[id as u64 + 1], vec![t.clone()])
                .unwrap();
        }
        // demote in the order 1, 0 — tenant 1 is the oldest cold shard
        reg.demote_tenant(1).unwrap();
        reg.demote_tenant(0).unwrap();
        assert!(reg.cold_bytes() > 0, "cold snapshots must have bytes");
        assert_eq!(reg.oldest_cold(), Some(1), "LRU victim is first-demoted");

        let freed = reg.evict_cold(1).unwrap();
        assert!(freed > 0, "eviction must report freed snapshot bytes");
        assert!(reg.cold_evicted(1));
        assert_eq!(reg.cold_evictions, 1);
        assert_eq!(
            reg.oldest_cold(),
            Some(0),
            "evicted shards leave the LRU order"
        );
        assert!(
            !dir.join("shard_1").exists(),
            "eviction must remove the snapshot directory"
        );

        // hydrating the evicted shard fails loudly...
        let err = reg.hydrate_tenant(1).unwrap_err().to_string();
        assert!(
            err.contains("evicted"),
            "hydration error must name the eviction, got: {err}"
        );
        // ...double eviction is refused, hot tenants are refused...
        assert!(reg.evict_cold(1).is_err());
        assert!(reg.evict_cold(2).is_err());
        // ...and recreate_evicted is the explicit way back (fresh cache)
        reg.recreate_evicted(1).unwrap();
        assert_eq!(reg.residency(1), Some(Residency::Hot));
        assert!(
            reg.shard_mut(1).unwrap().prefix_match(&[2]).is_empty(),
            "recreated shard starts empty — the eviction's explicit cost"
        );
        reg.check_invariants().unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }
}
