//! The tenant registry: owns every tenant's cache shard and the memory
//! governor that arbitrates bytes between them.  With a persistence
//! directory attached ([`TenantRegistry::open_or_create`]) every shard
//! lives in its own `shard_<id>/` subdirectory and survives process
//! restarts.

use std::path::PathBuf;

use anyhow::{Context, Result};

use crate::config::TenancyConfig;

use super::governor::{Allocation, GovernorConfig, MemoryGovernor};
use super::shard::{TenantId, TenantShard};

pub struct TenantRegistry {
    shards: Vec<TenantShard>,
    pub governor: MemoryGovernor,
    cfg: TenancyConfig,
    /// Serves since the last governor pass (drives `rebalance_every`).
    serves_since_rebalance: u64,
    /// Base directory for per-shard persistence (None = memory shards).
    dir: Option<PathBuf>,
}

impl TenantRegistry {
    pub fn new(cfg: &TenancyConfig) -> Self {
        TenantRegistry {
            shards: Vec::new(),
            governor: MemoryGovernor::new(GovernorConfig {
                global_qkv_bytes: cfg.global_qkv_bytes,
                floor_frac: cfg.floor_frac,
                hysteresis_frac: cfg.hysteresis_frac,
            }),
            cfg: cfg.clone(),
            serves_since_rebalance: 0,
            dir: None,
        }
    }

    /// Open (or create) a persistent registry at `dir`: existing
    /// `shard_<id>/` subdirectories are resumed in id order (warm
    /// restart for every tenant), and tenants created later get their
    /// own persistent subdirectory.  Pair with [`Self::save_all`].
    pub fn open_or_create(cfg: &TenancyConfig, dir: PathBuf) -> Result<Self> {
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating tenant dir {}", dir.display()))?;
        let mut reg = Self::new(cfg);
        reg.dir = Some(dir.clone());
        let mut ids: Vec<u32> = Vec::new();
        for entry in
            std::fs::read_dir(&dir).with_context(|| format!("listing {}", dir.display()))?
        {
            let entry = entry?;
            let name = entry.file_name().to_string_lossy().into_owned();
            if let Some(id) = name.strip_prefix("shard_").and_then(|s| s.parse::<u32>().ok()) {
                ids.push(id);
            }
        }
        ids.sort_unstable();
        for (i, id) in ids.iter().enumerate() {
            anyhow::ensure!(
                *id == i as u32,
                "shard directories must be contiguous from 0 (found shard_{id} at position {i})"
            );
            reg.create_tenant()?;
        }
        Ok(reg)
    }

    /// Base persistence directory, when attached.
    pub fn persist_dir(&self) -> Option<&PathBuf> {
        self.dir.as_ref()
    }

    /// Snapshot every shard's cache state (persistent registries only).
    /// Returns how many shards were saved.
    pub fn save_all(&self) -> Result<usize> {
        anyhow::ensure!(
            self.dir.is_some(),
            "save_all requires a persistent registry (open_or_create)"
        );
        for shard in &self.shards {
            shard.save()?;
        }
        Ok(self.shards.len())
    }

    /// Single-tenant mode: one shard holding the whole global budget —
    /// the configuration under which the paper experiments run unchanged.
    pub fn single_tenant(cfg: &TenancyConfig) -> Self {
        let mut reg = Self::new(cfg);
        reg.create_tenant().expect("max_tenants >= 1");
        reg
    }

    /// Register a new tenant; every shard's budget is re-planned so the
    /// newcomer starts from its governed share (cold start: uniform).
    pub fn create_tenant(&mut self) -> Result<TenantId> {
        anyhow::ensure!(
            self.shards.len() < self.cfg.max_tenants,
            "tenant limit reached ({})",
            self.cfg.max_tenants
        );
        let id = self.shards.len() as TenantId;
        let shard = match &self.dir {
            None => TenantShard::new(
                id,
                self.cfg.qa_bytes_per_tenant,
                0, // budget assigned by the forced rebalance below
                self.cfg.utility_alpha,
            ),
            // persistent shard: restore under the full global budget so a
            // warm tree is paged in intact, then let the forced rebalance
            // below shrink it to the governed share through the LFU path
            Some(base) => TenantShard::open_or_create(
                id,
                self.cfg.qa_bytes_per_tenant,
                self.cfg.global_qkv_bytes,
                self.cfg.utility_alpha,
                base.join(format!("shard_{id}")),
            )?,
        };
        self.shards.push(shard);
        self.governor.rebalance(&mut self.shards, true);
        Ok(id)
    }

    pub fn len(&self) -> usize {
        self.shards.len()
    }

    pub fn is_empty(&self) -> bool {
        self.shards.is_empty()
    }

    pub fn shard(&self, id: TenantId) -> Option<&TenantShard> {
        self.shards.get(id as usize)
    }

    pub fn shard_mut(&mut self, id: TenantId) -> Option<&mut TenantShard> {
        self.shards.get_mut(id as usize)
    }

    pub fn shards(&self) -> &[TenantShard] {
        &self.shards
    }

    /// Count one serve; every `rebalance_every` serves the governor gets
    /// a chance to move bytes.  Returns true when a rebalance applied.
    pub fn note_serve(&mut self) -> bool {
        self.serves_since_rebalance += 1;
        if self.serves_since_rebalance >= self.cfg.rebalance_every as u64 {
            self.serves_since_rebalance = 0;
            return self.governor.rebalance(&mut self.shards, false);
        }
        false
    }

    /// Force an immediate governor pass (bypasses cadence + hysteresis).
    pub fn rebalance_now(&mut self) -> bool {
        self.serves_since_rebalance = 0;
        self.governor.rebalance(&mut self.shards, true)
    }

    /// Current governed plan (reporting / tests).
    pub fn plan(&self) -> Vec<Allocation> {
        self.governor.plan(&self.shards)
    }

    pub fn total_qkv_used(&self) -> usize {
        self.shards.iter().map(|s| s.tree.bytes_used()).sum()
    }

    pub fn total_qkv_budget(&self) -> usize {
        self.shards.iter().map(|s| s.qkv_budget()).sum()
    }

    /// Registry-wide invariants: per-shard consistency plus the global
    /// budget bound (budgets and residency never exceed the governed
    /// global byte budget).
    pub fn check_invariants(&self) -> Result<()> {
        for s in &self.shards {
            s.check_invariants()?;
        }
        anyhow::ensure!(
            self.total_qkv_budget() <= self.governor.cfg.global_qkv_bytes,
            "shard budgets {} exceed global {}",
            self.total_qkv_budget(),
            self.governor.cfg.global_qkv_bytes
        );
        anyhow::ensure!(
            self.total_qkv_used() <= self.governor.cfg.global_qkv_bytes,
            "shard residency {} exceeds global {}",
            self.total_qkv_used(),
            self.governor.cfg.global_qkv_bytes
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::llm::QkvTensor;

    fn cfg(global: usize) -> TenancyConfig {
        TenancyConfig {
            global_qkv_bytes: global,
            ..TenancyConfig::default()
        }
    }

    #[test]
    fn single_tenant_holds_whole_budget() {
        let reg = TenantRegistry::single_tenant(&cfg(1 << 20));
        assert_eq!(reg.len(), 1);
        assert_eq!(reg.shard(0).unwrap().qkv_budget(), 1 << 20);
        reg.check_invariants().unwrap();
    }

    #[test]
    fn create_many_respects_global_budget() {
        let mut reg = TenantRegistry::new(&cfg(64 * 4096));
        for _ in 0..8 {
            reg.create_tenant().unwrap();
        }
        assert_eq!(reg.len(), 8);
        reg.check_invariants().unwrap();
        // cold start: equal budgets
        let b0 = reg.shard(0).unwrap().qkv_budget();
        assert!(reg.shards().iter().all(|s| s.qkv_budget() == b0));
    }

    #[test]
    fn tenant_limit_enforced() {
        let mut tc = cfg(1 << 20);
        tc.max_tenants = 2;
        let mut reg = TenantRegistry::new(&tc);
        reg.create_tenant().unwrap();
        reg.create_tenant().unwrap();
        assert!(reg.create_tenant().is_err());
    }

    #[test]
    fn note_serve_triggers_periodic_rebalance() {
        let mut tc = cfg(32 * 3088);
        tc.rebalance_every = 4;
        let mut reg = TenantRegistry::new(&tc);
        for _ in 0..2 {
            reg.create_tenant().unwrap();
        }
        // make tenant 0 useful so the periodic pass has something to move
        let t = QkvTensor::zeros(1, 4, 64);
        reg.shard_mut(0).unwrap().insert_path(&[1], vec![t]).unwrap();
        for _ in 0..32 {
            reg.shard_mut(0).unwrap().prefix_match(&[1]);
            reg.shard_mut(0)
                .unwrap()
                .stats
                .note(crate::metrics::ServePath::QkvHit, 1_000_000);
        }
        let mut applied = false;
        for _ in 0..8 {
            applied |= reg.note_serve();
        }
        assert!(applied, "periodic rebalance never applied");
        assert!(
            reg.shard(0).unwrap().qkv_budget() > reg.shard(1).unwrap().qkv_budget(),
            "useful shard did not grow"
        );
        reg.check_invariants().unwrap();
    }
}
