//! Multi-tenant cache sharding under one device-wide memory budget
//! (DESIGN.md §6).
//!
//! PerCache is inherently personal — every user owns a knowledge bank,
//! query history and predictive cache — but the paper's engine serves one
//! tenant.  This subsystem converts it into a multi-user serving system
//! without touching the single-tenant serve path:
//!
//! * [`shard`] — [`TenantShard`]: one tenant's cache state (QA bank +
//!   QKV prefix tree + slice store + query predictor, reusing the
//!   `cache`/`predict` types verbatim) plus the [`ShardStats`] utility
//!   signal fed from `metrics::recorder`-style query records.
//! * [`governor`] — [`MemoryGovernor`]: divides a global byte budget
//!   across shards proportionally to caching utility (EWMA hit rate ×
//!   FLOPs saved per byte, after RAGCache's reuse-value replacement and
//!   Cache-Craft's recomputation-cost budgeting), with a per-shard floor
//!   so no shard with nonzero utility is ever starved.  Budget changes
//!   drive the existing LFU `enforce_budget` eviction path.
//! * [`registry`] — [`TenantRegistry`]: owns the shards and the
//!   governor; single-tenant mode is a registry with one shard holding
//!   the whole budget, which keeps the paper experiments bit-identical.
//!   Shards carry a residency state (`crate::tiering::Residency`): the
//!   registry provides the demote/hydrate mechanics the warm/cold
//!   tiering controller drives (DESIGN.md §11).
//! * [`router`] — [`Router`]: per-tenant request queues with round-robin
//!   fair scheduling and admission control (per-tenant + global queue
//!   caps), plus a threaded serving loop fronting `server::run_loop`'s
//!   coordination shape.
//! * [`multi`] — [`MultiTenantEngine`]: per-tenant [`crate::engine::PerCache`]
//!   instances over one shared PJRT runtime, governed the same way.
//! * [`sim`] — runtime-free cache-level replay used by the tenancy
//!   experiment, bench, CLI and integration tests (no PJRT artifacts
//!   required).

pub mod governor;
pub mod multi;
pub mod registry;
pub mod router;
pub mod shard;
pub mod sim;
pub mod slo;

pub use governor::{Allocation, GovernorConfig, MemoryGovernor};
pub use multi::MultiTenantEngine;
pub use registry::{HydrationSpec, TenantRegistry};
pub use router::{Rejection, Router, RouterConfig, TenantCommand, TenantServerHandle};
pub use shard::{ShardStats, TenantId, TenantShard};
pub use slo::{SloMonitor, SloSignal};
