//! Request routing for multi-tenant serving: per-tenant FIFO queues,
//! round-robin fair scheduling across tenants, and admission control
//! under load (per-tenant and global queue caps).
//!
//! [`Router`] is a pure data structure (unit-testable); the
//! [`spawn_tenant_server`] loop wires it in front of a single inference
//! thread using the same coordination shape as `server::spawn_with` —
//! commands arrive over a channel, the router reorders them fairly, and
//! one request is served between channel drains so a chatty tenant can
//! never occupy the engine back-to-back while others wait.

use std::collections::VecDeque;
use std::sync::mpsc;
use std::thread;
use std::time::Instant;

use crate::metrics::{blank_record, QueryRecord};
use crate::server::{JoinCell, Request, Response};

use super::shard::TenantId;

#[derive(Debug, Clone)]
pub struct RouterConfig {
    /// Max queued requests per tenant.
    pub queue_cap: usize,
    /// Max queued requests across all tenants.
    pub global_cap: usize,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            queue_cap: 32,
            global_cap: 256,
        }
    }
}

/// Why admission control turned a request away.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Rejection {
    QueueFull,
    GlobalFull,
    UnknownTenant,
}

impl std::fmt::Display for Rejection {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Rejection::QueueFull => write!(f, "per-tenant queue full"),
            Rejection::GlobalFull => write!(f, "global queue full"),
            Rejection::UnknownTenant => write!(f, "unknown tenant"),
        }
    }
}

/// Per-tenant queues + fair scheduler.
pub struct Router<T> {
    cfg: RouterConfig,
    queues: Vec<VecDeque<T>>,
    /// Next tenant the scheduler looks at (rotates on every pop).
    cursor: usize,
    queued: usize,
    pub enqueued: u64,
    pub rejected: u64,
    pub popped: u64,
}

impl<T> Router<T> {
    pub fn new(cfg: RouterConfig) -> Self {
        Router {
            cfg,
            queues: Vec::new(),
            cursor: 0,
            queued: 0,
            enqueued: 0,
            rejected: 0,
            popped: 0,
        }
    }

    /// Register the next tenant; ids align with the registry's.
    pub fn register_tenant(&mut self) -> TenantId {
        self.queues.push(VecDeque::new());
        (self.queues.len() - 1) as TenantId
    }

    pub fn tenants(&self) -> usize {
        self.queues.len()
    }

    pub fn len(&self) -> usize {
        self.queued
    }

    pub fn is_empty(&self) -> bool {
        self.queued == 0
    }

    pub fn queue_len(&self, tenant: TenantId) -> usize {
        self.queues.get(tenant as usize).map_or(0, |q| q.len())
    }

    /// Admission-controlled enqueue; a rejected item is handed back so
    /// the caller can answer the client.
    pub fn try_push(&mut self, tenant: TenantId, item: T) -> Result<(), (Rejection, T)> {
        let Some(q) = self.queues.get_mut(tenant as usize) else {
            self.rejected += 1;
            return Err((Rejection::UnknownTenant, item));
        };
        if self.queued >= self.cfg.global_cap {
            self.rejected += 1;
            return Err((Rejection::GlobalFull, item));
        }
        if q.len() >= self.cfg.queue_cap {
            self.rejected += 1;
            return Err((Rejection::QueueFull, item));
        }
        q.push_back(item);
        self.queued += 1;
        self.enqueued += 1;
        Ok(())
    }

    /// Round-robin pop: take the head of the first non-empty queue at or
    /// after the cursor, then advance the cursor past it.  Backlogged
    /// tenants therefore get equal service regardless of arrival rate;
    /// within a tenant, order stays FIFO.
    pub fn pop(&mut self) -> Option<(TenantId, T)> {
        let n = self.queues.len();
        if n == 0 || self.queued == 0 {
            return None;
        }
        for step in 0..n {
            let t = (self.cursor + step) % n;
            if let Some(item) = self.queues[t].pop_front() {
                self.cursor = (t + 1) % n;
                self.queued -= 1;
                self.popped += 1;
                return Some((t as TenantId, item));
            }
        }
        None
    }
}

// ---------------------------------------------------------------------------
// threaded serving loop
// ---------------------------------------------------------------------------

/// Commands accepted by the multi-tenant serving loop.
pub enum TenantCommand {
    Serve { tenant: TenantId, req: Request },
    /// Run one idle tick for a tenant (population/conversions).
    IdleTick { tenant: TenantId },
    Shutdown,
}

/// Client handle to a multi-tenant serving thread.
#[derive(Clone)]
pub struct TenantServerHandle {
    tx: mpsc::Sender<TenantCommand>,
    join: JoinCell,
}

impl TenantServerHandle {
    /// Blocking query on behalf of `tenant`.
    pub fn query(&self, tenant: TenantId, id: usize, query: &str) -> anyhow::Result<Response> {
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(TenantCommand::Serve {
                tenant,
                req: Request {
                    id,
                    query: query.to_string(),
                    submitted: Instant::now(),
                    respond: rtx,
                },
            })
            .map_err(|_| anyhow::anyhow!("tenant server is down"))?;
        rrx.recv()
            .map_err(|_| anyhow::anyhow!("tenant server dropped request"))
    }

    pub fn idle_tick(&self, tenant: TenantId) -> anyhow::Result<()> {
        self.tx
            .send(TenantCommand::IdleTick { tenant })
            .map_err(|_| anyhow::anyhow!("tenant server is down"))
    }

    pub fn shutdown(&self) {
        let _ = self.tx.send(TenantCommand::Shutdown);
    }

    /// Wait for the serving thread to exit (idempotent).
    pub fn join(&self) -> anyhow::Result<()> {
        self.join.join()
    }
}

/// Run the routed serving loop on the current thread.  Commands are
/// drained into the router between serves; `Shutdown` stops admission
/// and drains everything already queued before returning.
pub fn run_tenant_loop(
    rx: mpsc::Receiver<TenantCommand>,
    cfg: RouterConfig,
    n_tenants: usize,
    mut serve_fn: impl FnMut(TenantId, &str) -> anyhow::Result<QueryRecord>,
    mut idle_fn: impl FnMut(TenantId),
) {
    let mut router: Router<Request> = Router::new(cfg);
    for _ in 0..n_tenants {
        router.register_tenant();
    }
    let mut shutting_down = false;
    let mut disconnected = false;

    let handle = |cmd: TenantCommand,
                      router: &mut Router<Request>,
                      shutting_down: &mut bool,
                      idle_fn: &mut dyn FnMut(TenantId)| {
        match cmd {
            TenantCommand::Serve { tenant, req } => {
                if *shutting_down {
                    respond_error(req, "server shutting down");
                } else if let Err((why, req)) = router.try_push(tenant, req) {
                    respond_error(req, &format!("admission rejected: {why}"));
                }
            }
            TenantCommand::IdleTick { tenant } => {
                if !*shutting_down {
                    idle_fn(tenant);
                }
            }
            TenantCommand::Shutdown => *shutting_down = true,
        }
    };

    loop {
        // block only when there is nothing to serve
        if router.is_empty() && !disconnected {
            if shutting_down {
                break;
            }
            match rx.recv() {
                Ok(cmd) => handle(cmd, &mut router, &mut shutting_down, &mut idle_fn),
                Err(_) => break,
            }
        }
        // drain whatever else is pending without blocking
        loop {
            match rx.try_recv() {
                Ok(cmd) => handle(cmd, &mut router, &mut shutting_down, &mut idle_fn),
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => {
                    disconnected = true;
                    break;
                }
            }
        }
        // serve one request, picked fairly across tenants
        match router.pop() {
            Some((tenant, req)) => {
                let record = serve_fn(tenant, &req.query).unwrap_or_else(|e| {
                    let mut r = blank_record(req.id);
                    r.answer = format!("error: {e:#}");
                    r
                });
                let e2e_ms = req.submitted.elapsed().as_secs_f64() * 1e3;
                let _ = req.respond.send(Response {
                    id: req.id,
                    record,
                    e2e_ms,
                });
            }
            None => {
                if shutting_down || disconnected {
                    break;
                }
            }
        }
    }
}

fn respond_error(req: Request, msg: &str) {
    let mut r = blank_record(req.id);
    r.answer = format!("error: {msg}");
    let e2e_ms = req.submitted.elapsed().as_secs_f64() * 1e3;
    let _ = req.respond.send(Response {
        id: req.id,
        record: r,
        e2e_ms,
    });
}

/// Spawn a multi-tenant serving thread whose state is built inside the
/// thread (non-Send engine state never crosses threads), mirroring
/// `server::spawn_with`.
pub fn spawn_tenant_server<S: 'static>(
    cfg: RouterConfig,
    n_tenants: usize,
    make_state: impl FnOnce() -> anyhow::Result<S> + Send + 'static,
    serve_fn: impl Fn(&mut S, TenantId, &str) -> anyhow::Result<QueryRecord> + Send + 'static,
    idle_fn: impl Fn(&mut S, TenantId) + Send + 'static,
) -> TenantServerHandle {
    let (tx, rx) = mpsc::channel();
    let join = thread::Builder::new()
        .name("percache-tenant-server".into())
        .spawn(move || -> anyhow::Result<()> {
            let state = std::cell::RefCell::new(make_state()?);
            run_tenant_loop(
                rx,
                cfg,
                n_tenants,
                |t, q| serve_fn(&mut state.borrow_mut(), t, q),
                |t| idle_fn(&mut state.borrow_mut(), t),
            );
            Ok(())
        })
        .expect("spawn tenant server thread");
    TenantServerHandle {
        tx,
        join: JoinCell::new(join),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn router(queue_cap: usize, global_cap: usize, tenants: usize) -> Router<usize> {
        let mut r = Router::new(RouterConfig {
            queue_cap,
            global_cap,
        });
        for _ in 0..tenants {
            r.register_tenant();
        }
        r
    }

    #[test]
    fn round_robin_is_fair_under_backlog() {
        let mut r = router(16, 64, 3);
        // tenant 0 floods, tenants 1/2 trickle
        for i in 0..9 {
            r.try_push(0, i).unwrap();
        }
        for i in 0..3 {
            r.try_push(1, 100 + i).unwrap();
            r.try_push(2, 200 + i).unwrap();
        }
        let mut served = [0usize; 3];
        for _ in 0..9 {
            let (t, _) = r.pop().unwrap();
            served[t as usize] += 1;
        }
        // first 9 pops: each backlogged tenant gets exactly 3
        assert_eq!(served, [3, 3, 3], "unfair service: {served:?}");
    }

    #[test]
    fn fifo_within_tenant() {
        let mut r = router(16, 64, 2);
        for i in 0..5 {
            r.try_push(0, i).unwrap();
        }
        let mut got = Vec::new();
        while let Some((_, v)) = r.pop() {
            got.push(v);
        }
        assert_eq!(got, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn admission_control_rejects() {
        let mut r = router(2, 3, 2);
        r.try_push(0, 1).unwrap();
        r.try_push(0, 2).unwrap();
        assert_eq!(r.try_push(0, 3).unwrap_err().0, Rejection::QueueFull);
        r.try_push(1, 4).unwrap();
        assert_eq!(r.try_push(1, 5).unwrap_err().0, Rejection::GlobalFull);
        assert_eq!(r.try_push(9, 6).unwrap_err().0, Rejection::UnknownTenant);
        assert_eq!(r.rejected, 3);
        assert_eq!(r.len(), 3);
    }

    #[test]
    fn empty_router_pops_nothing() {
        let mut r = router(4, 8, 2);
        assert!(r.pop().is_none());
        assert!(r.is_empty());
    }

    #[test]
    fn threaded_loop_serves_and_drains_on_shutdown() {
        let handle = spawn_tenant_server(
            RouterConfig::default(),
            2,
            || Ok(Vec::<(TenantId, String)>::new()),
            |seen, t, q| {
                seen.push((t, q.to_string()));
                let mut r = blank_record(seen.len());
                r.answer = format!("t{t}: {q}");
                Ok(r)
            },
            |_, _| {},
        );
        let a = handle.query(0, 1, "hello").unwrap();
        assert_eq!(a.record.answer, "t0: hello");
        let b = handle.query(1, 2, "world").unwrap();
        assert_eq!(b.record.answer, "t1: world");
        handle.shutdown();
        handle.join().unwrap();
        // join is idempotent
        handle.join().unwrap();
    }

    #[test]
    fn unknown_tenant_gets_error_response() {
        let handle = spawn_tenant_server(
            RouterConfig::default(),
            1,
            || Ok(()),
            |_, _, _| Ok(blank_record(0)),
            |_, _| {},
        );
        let resp = handle.query(7, 1, "hi").unwrap();
        assert!(resp.record.answer.contains("unknown tenant"), "{}", resp.record.answer);
        handle.shutdown();
        handle.join().unwrap();
    }
}
