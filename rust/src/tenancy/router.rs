//! Request routing for multi-tenant serving: per-tenant FIFO queues,
//! round-robin fair scheduling across tenants, and admission control
//! under load (per-tenant and global queue caps).
//!
//! [`Router`] is a pure data structure (unit-testable); the
//! [`spawn_tenant_server`] loop wires it in front of a single inference
//! thread using the same coordination shape as `server::spawn_with` —
//! commands arrive over a channel, the router reorders them fairly, and
//! one request is served between channel drains so a chatty tenant can
//! never occupy the engine back-to-back while others wait.

use std::collections::VecDeque;
use std::sync::mpsc;
use std::thread;
use std::time::{Duration, Instant};

use crate::metrics::{blank_record, QueryRecord};
use crate::server::{JoinCell, Request, Response};

use super::shard::TenantId;

#[derive(Debug, Clone)]
pub struct RouterConfig {
    /// Max queued requests per tenant.
    pub queue_cap: usize,
    /// Max queued requests across all tenants.
    pub global_cap: usize,
    /// Per-tenant queue cap while load shedding is engaged for that
    /// tenant ([`Router::set_shed`], DESIGN.md §14).  Must be below
    /// `queue_cap` to have any effect.
    pub shed_queue_cap: usize,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            queue_cap: 32,
            global_cap: 256,
            shed_queue_cap: 4,
        }
    }
}

/// Why admission control turned a request away.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Rejection {
    QueueFull,
    GlobalFull,
    UnknownTenant,
    /// Load shedding under sustained SLO violation: the tenant's queue
    /// is clamped to `shed_queue_cap` so latency for what *is* admitted
    /// stays bounded.
    Shed,
}

impl Rejection {
    /// Stable label value for the `router.rejected{reason=...}` series.
    pub fn label(&self) -> &'static str {
        match self {
            Rejection::QueueFull => "queue_full",
            Rejection::GlobalFull => "global_full",
            Rejection::UnknownTenant => "unknown_tenant",
            Rejection::Shed => "shed",
        }
    }
}

impl std::fmt::Display for Rejection {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Rejection::QueueFull => write!(f, "per-tenant queue full"),
            Rejection::GlobalFull => write!(f, "global queue full"),
            Rejection::UnknownTenant => write!(f, "unknown tenant"),
            Rejection::Shed => write!(f, "load shed under SLO violation"),
        }
    }
}

/// Count + journal one admission rejection (rejections are rare, so the
/// labeled-series lookup off the hot path is fine).
fn note_rejected(tenant: TenantId, why: Rejection) {
    crate::obs::counter_labeled("router.rejected", &[("reason", why.label())]).inc();
    crate::obs::emit(
        crate::obs::Event::new("admission.rejected")
            .tenant(tenant as usize)
            .msg(why.label()),
    );
}

/// Per-tenant queues + fair scheduler.
///
/// A queue can be *blocked* (its tenant's shard is cold and a hydration
/// is in flight): blocked queues keep admitting requests — clients queue
/// behind the hydration instead of being bounced — but the scheduler
/// skips them until [`Router::set_blocked`] lifts the block.
pub struct Router<T> {
    cfg: RouterConfig,
    queues: Vec<VecDeque<T>>,
    /// Blocked queues are skipped by `pop` (cold tenant, hydration
    /// pending); requests still enqueue.
    blocked: Vec<bool>,
    /// Shedding tenants admit only up to `shed_queue_cap` queued
    /// requests; the SLO monitor drives this per window.
    shed: Vec<bool>,
    /// Next tenant the scheduler looks at (rotates on every pop).
    cursor: usize,
    queued: usize,
    pub enqueued: u64,
    pub rejected: u64,
    pub popped: u64,
}

impl<T> Router<T> {
    pub fn new(cfg: RouterConfig) -> Self {
        Router {
            cfg,
            queues: Vec::new(),
            blocked: Vec::new(),
            shed: Vec::new(),
            cursor: 0,
            queued: 0,
            enqueued: 0,
            rejected: 0,
            popped: 0,
        }
    }

    /// Register the next tenant; ids align with the registry's.
    pub fn register_tenant(&mut self) -> TenantId {
        self.queues.push(VecDeque::new());
        self.blocked.push(false);
        self.shed.push(false);
        (self.queues.len() - 1) as TenantId
    }

    pub fn tenants(&self) -> usize {
        self.queues.len()
    }

    pub fn len(&self) -> usize {
        self.queued
    }

    pub fn is_empty(&self) -> bool {
        self.queued == 0
    }

    pub fn queue_len(&self, tenant: TenantId) -> usize {
        self.queues.get(tenant as usize).map_or(0, |q| q.len())
    }

    /// Per-tenant queue depths, in tenant-id order — the governor's
    /// queueing signal (`TenantRegistry::set_queue_depths`).
    pub fn depths(&self) -> Vec<usize> {
        self.queues.iter().map(|q| q.len()).collect()
    }

    /// Block or unblock a tenant's queue.  Blocked queues admit but are
    /// never popped (their tenant's shard is cold; requests wait for the
    /// hydration instead of occupying the inference thread).
    pub fn set_blocked(&mut self, tenant: TenantId, blocked: bool) {
        if let Some(b) = self.blocked.get_mut(tenant as usize) {
            *b = blocked;
        }
    }

    pub fn is_blocked(&self, tenant: TenantId) -> bool {
        self.blocked.get(tenant as usize).copied().unwrap_or(false)
    }

    /// Engage or release load shedding for a tenant (the SLO monitor's
    /// sustained-violation actuator): while engaged, admission clamps
    /// the tenant's queue to `shed_queue_cap`.
    pub fn set_shed(&mut self, tenant: TenantId, shed: bool) {
        if let Some(s) = self.shed.get_mut(tenant as usize) {
            *s = shed;
        }
    }

    pub fn is_shedding(&self, tenant: TenantId) -> bool {
        self.shed.get(tenant as usize).copied().unwrap_or(false)
    }

    /// Lift every block (shutdown drains: the caller serves the rest
    /// with synchronous hydration).
    pub fn unblock_all(&mut self) {
        for b in &mut self.blocked {
            *b = false;
        }
    }

    /// Queued requests that are currently eligible to pop (not blocked).
    pub fn ready_len(&self) -> usize {
        self.queues
            .iter()
            .zip(&self.blocked)
            .filter(|(_, &b)| !b)
            .map(|(q, _)| q.len())
            .sum()
    }

    /// Admission-controlled enqueue; a rejected item is handed back so
    /// the caller can answer the client.
    pub fn try_push(&mut self, tenant: TenantId, item: T) -> Result<(), (Rejection, T)> {
        let Some(q) = self.queues.get_mut(tenant as usize) else {
            self.rejected += 1;
            note_rejected(tenant, Rejection::UnknownTenant);
            return Err((Rejection::UnknownTenant, item));
        };
        if self.queued >= self.cfg.global_cap {
            self.rejected += 1;
            note_rejected(tenant, Rejection::GlobalFull);
            return Err((Rejection::GlobalFull, item));
        }
        if self.shed.get(tenant as usize).copied().unwrap_or(false)
            && q.len() >= self.cfg.shed_queue_cap
        {
            self.rejected += 1;
            note_rejected(tenant, Rejection::Shed);
            return Err((Rejection::Shed, item));
        }
        if q.len() >= self.cfg.queue_cap {
            self.rejected += 1;
            note_rejected(tenant, Rejection::QueueFull);
            return Err((Rejection::QueueFull, item));
        }
        q.push_back(item);
        self.queued += 1;
        self.enqueued += 1;
        crate::obs_counter!("router.admitted").inc();
        Ok(())
    }

    /// Round-robin pop: take the head of the first non-empty *unblocked*
    /// queue at or after the cursor, then advance the cursor past it.
    /// Backlogged tenants therefore get equal service regardless of
    /// arrival rate; within a tenant, order stays FIFO.  Returns None
    /// when everything queued sits behind a blocked queue.
    pub fn pop(&mut self) -> Option<(TenantId, T)> {
        let n = self.queues.len();
        if n == 0 || self.queued == 0 {
            return None;
        }
        for step in 0..n {
            let t = (self.cursor + step) % n;
            if self.blocked.get(t).copied().unwrap_or(false) {
                continue;
            }
            if let Some(item) = self.queues.get_mut(t).and_then(|q| q.pop_front()) {
                self.cursor = (t + 1) % n;
                self.queued -= 1;
                self.popped += 1;
                return Some((t as TenantId, item));
            }
        }
        None
    }
}

// ---------------------------------------------------------------------------
// threaded serving loop
// ---------------------------------------------------------------------------

/// Commands accepted by the multi-tenant serving loop.
pub enum TenantCommand {
    Serve { tenant: TenantId, req: Request },
    /// Run one idle tick for a tenant (population/conversions).
    IdleTick { tenant: TenantId },
    Shutdown,
}

/// Client handle to a multi-tenant serving thread.
#[derive(Clone)]
pub struct TenantServerHandle {
    tx: mpsc::Sender<TenantCommand>,
    join: JoinCell,
}

impl TenantServerHandle {
    /// Assemble a handle around an externally-spawned serving thread
    /// (the tiered serving loop in `crate::tiering::service` builds its
    /// own state but speaks the same command protocol).
    pub fn from_parts(
        tx: mpsc::Sender<TenantCommand>,
        join: thread::JoinHandle<anyhow::Result<()>>,
    ) -> Self {
        TenantServerHandle {
            tx,
            join: JoinCell::new(join),
        }
    }

    /// Blocking query on behalf of `tenant`.
    pub fn query(&self, tenant: TenantId, id: usize, query: &str) -> anyhow::Result<Response> {
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(TenantCommand::Serve {
                tenant,
                req: Request {
                    id,
                    query: query.to_string(),
                    submitted: Instant::now(),
                    respond: rtx,
                },
            })
            .map_err(|_| anyhow::anyhow!("tenant server is down"))?;
        rrx.recv()
            .map_err(|_| anyhow::anyhow!("tenant server dropped request"))
    }

    pub fn idle_tick(&self, tenant: TenantId) -> anyhow::Result<()> {
        self.tx
            .send(TenantCommand::IdleTick { tenant })
            .map_err(|_| anyhow::anyhow!("tenant server is down"))
    }

    pub fn shutdown(&self) {
        let _ = self.tx.send(TenantCommand::Shutdown);
    }

    /// Wait for the serving thread to exit (idempotent).
    pub fn join(&self) -> anyhow::Result<()> {
        self.join.join()
    }
}

/// Run the routed serving loop on the current thread.  Commands are
/// drained into the router between serves; `Shutdown` stops admission
/// and drains everything already queued before returning.
pub fn run_tenant_loop(
    rx: mpsc::Receiver<TenantCommand>,
    cfg: RouterConfig,
    n_tenants: usize,
    serve_fn: impl FnMut(TenantId, &str) -> anyhow::Result<QueryRecord>,
    idle_fn: impl FnMut(TenantId),
) {
    run_tenant_loop_gated(rx, cfg, n_tenants, serve_fn, idle_fn, |_| true, |_| Vec::new())
}

/// The gated variant of [`run_tenant_loop`] — the warm/cold tiering
/// serving shape (DESIGN.md §11).
///
/// * `admit_fn` runs when a request is admitted for a tenant: returning
///   false blocks the tenant's queue (its shard is cold; `admit_fn` is
///   expected to have kicked an asynchronous hydration).  Requests keep
///   queueing behind the block instead of occupying the inference
///   thread.
/// * `poll_fn` runs every scheduling iteration with the current
///   per-tenant queue depths (the governor's queueing signal) and
///   returns tenants whose hydration completed; their queues unblock
///   and drain fairly.
///
/// On shutdown/disconnect with requests still parked behind blocks, the
/// blocks are lifted and the remaining requests drain through `serve_fn`
/// — which must then tolerate a cold tenant (synchronous hydration).
pub fn run_tenant_loop_gated(
    rx: mpsc::Receiver<TenantCommand>,
    cfg: RouterConfig,
    n_tenants: usize,
    mut serve_fn: impl FnMut(TenantId, &str) -> anyhow::Result<QueryRecord>,
    mut idle_fn: impl FnMut(TenantId),
    mut admit_fn: impl FnMut(TenantId) -> bool,
    mut poll_fn: impl FnMut(&[usize]) -> Vec<TenantId>,
) {
    let mut router: Router<Request> = Router::new(cfg);
    for _ in 0..n_tenants {
        router.register_tenant();
    }
    let mut shutting_down = false;
    let mut disconnected = false;

    let handle = |cmd: TenantCommand,
                      router: &mut Router<Request>,
                      shutting_down: &mut bool,
                      idle_fn: &mut dyn FnMut(TenantId),
                      admit_fn: &mut dyn FnMut(TenantId) -> bool| {
        match cmd {
            TenantCommand::Serve { tenant, req } => {
                if *shutting_down {
                    respond_error(req, "server shutting down");
                } else if let Err((why, req)) = router.try_push(tenant, req) {
                    respond_error(req, &format!("admission rejected: {why}"));
                } else if !admit_fn(tenant) {
                    router.set_blocked(tenant, true);
                }
            }
            TenantCommand::IdleTick { tenant } => {
                if !*shutting_down {
                    idle_fn(tenant);
                }
            }
            TenantCommand::Shutdown => *shutting_down = true,
        }
    };

    loop {
        // block for a command only when there is nothing to serve and
        // nothing in flight
        if router.is_empty() && !disconnected {
            if shutting_down {
                break;
            }
            match rx.recv() {
                Ok(cmd) => handle(
                    cmd,
                    &mut router,
                    &mut shutting_down,
                    &mut idle_fn,
                    &mut admit_fn,
                ),
                Err(_) => break,
            }
        }
        // drain whatever else is pending without blocking
        loop {
            match rx.try_recv() {
                Ok(cmd) => handle(
                    cmd,
                    &mut router,
                    &mut shutting_down,
                    &mut idle_fn,
                    &mut admit_fn,
                ),
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => {
                    disconnected = true;
                    break;
                }
            }
        }
        // completed hydrations make their tenants' queues poppable (the
        // callback also sees live queue depths — the queueing signal)
        let depths = router.depths();
        crate::obs_gauge!("router.queue_depth").set(depths.iter().sum::<usize>() as i64);
        for t in poll_fn(&depths) {
            router.set_blocked(t, false);
        }
        // serve one request, picked fairly across tenants
        match router.pop() {
            Some((tenant, req)) => {
                crate::obs_hist!("router.wait_ms")
                    .record(req.submitted.elapsed().as_secs_f64() * 1e3);
                // causal trace: root the request at submission time so
                // the queue wait shows up as its own child span
                let tracer = crate::obs::tracer();
                let pop_ns = tracer.now_ns();
                let start_ns =
                    pop_ns.saturating_sub(req.submitted.elapsed().as_nanos() as u64);
                let ctx = tracer.begin_trace("request", Some(tenant), start_ns);
                if let Some(ctx) = ctx {
                    tracer.add_span(ctx.trace, Some(ctx.span), "queue_wait", start_ns, pop_ns);
                }
                let record = {
                    let _attached = crate::obs::trace::attach(ctx);
                    serve_fn(tenant, &req.query).unwrap_or_else(|e| {
                        let mut r = blank_record(req.id);
                        r.answer = format!("error: {e:#}");
                        r
                    })
                };
                let e2e_ms = req.submitted.elapsed().as_secs_f64() * 1e3;
                crate::obs_hist!("router.e2e_ms").record(e2e_ms);
                if let Some(ctx) = ctx {
                    tracer.end_trace(ctx, tracer.now_ns());
                }
                let _ = req.respond.send(Response {
                    id: req.id,
                    record,
                    e2e_ms,
                });
            }
            None => {
                if router.is_empty() {
                    if shutting_down || disconnected {
                        break;
                    }
                } else if shutting_down || disconnected {
                    // no more commands are coming: lift the blocks so the
                    // parked requests drain (serve_fn hydrates in-line)
                    router.unblock_all();
                } else {
                    // everything queued waits on a hydration in flight
                    thread::sleep(Duration::from_millis(1));
                }
            }
        }
    }
}

fn respond_error(req: Request, msg: &str) {
    let mut r = blank_record(req.id);
    r.answer = format!("error: {msg}");
    let e2e_ms = req.submitted.elapsed().as_secs_f64() * 1e3;
    let _ = req.respond.send(Response {
        id: req.id,
        record: r,
        e2e_ms,
    });
}

/// Spawn a multi-tenant serving thread whose state is built inside the
/// thread (non-Send engine state never crosses threads), mirroring
/// `server::spawn_with`.
pub fn spawn_tenant_server<S: 'static>(
    cfg: RouterConfig,
    n_tenants: usize,
    make_state: impl FnOnce() -> anyhow::Result<S> + Send + 'static,
    serve_fn: impl Fn(&mut S, TenantId, &str) -> anyhow::Result<QueryRecord> + Send + 'static,
    idle_fn: impl Fn(&mut S, TenantId) + Send + 'static,
) -> TenantServerHandle {
    let (tx, rx) = mpsc::channel();
    let join = thread::Builder::new()
        .name("percache-tenant-server".into())
        .spawn(move || -> anyhow::Result<()> {
            let state = std::cell::RefCell::new(make_state()?);
            run_tenant_loop(
                rx,
                cfg,
                n_tenants,
                |t, q| serve_fn(&mut state.borrow_mut(), t, q),
                |t| idle_fn(&mut state.borrow_mut(), t),
            );
            Ok(())
        })
        // percache-allow(panic_path): thread-spawn failure at process start is unrecoverable resource exhaustion; dying loudly beats serving without a loop
        .expect("spawn tenant server thread");
    TenantServerHandle {
        tx,
        join: JoinCell::new(join),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn router(queue_cap: usize, global_cap: usize, tenants: usize) -> Router<usize> {
        let mut r = Router::new(RouterConfig {
            queue_cap,
            global_cap,
            ..RouterConfig::default()
        });
        for _ in 0..tenants {
            r.register_tenant();
        }
        r
    }

    #[test]
    fn round_robin_is_fair_under_backlog() {
        let mut r = router(16, 64, 3);
        // tenant 0 floods, tenants 1/2 trickle
        for i in 0..9 {
            r.try_push(0, i).unwrap();
        }
        for i in 0..3 {
            r.try_push(1, 100 + i).unwrap();
            r.try_push(2, 200 + i).unwrap();
        }
        let mut served = [0usize; 3];
        for _ in 0..9 {
            let (t, _) = r.pop().unwrap();
            served[t as usize] += 1;
        }
        // first 9 pops: each backlogged tenant gets exactly 3
        assert_eq!(served, [3, 3, 3], "unfair service: {served:?}");
    }

    #[test]
    fn fifo_within_tenant() {
        let mut r = router(16, 64, 2);
        for i in 0..5 {
            r.try_push(0, i).unwrap();
        }
        let mut got = Vec::new();
        while let Some((_, v)) = r.pop() {
            got.push(v);
        }
        assert_eq!(got, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn admission_control_rejects() {
        let mut r = router(2, 3, 2);
        r.try_push(0, 1).unwrap();
        r.try_push(0, 2).unwrap();
        assert_eq!(r.try_push(0, 3).unwrap_err().0, Rejection::QueueFull);
        r.try_push(1, 4).unwrap();
        assert_eq!(r.try_push(1, 5).unwrap_err().0, Rejection::GlobalFull);
        assert_eq!(r.try_push(9, 6).unwrap_err().0, Rejection::UnknownTenant);
        assert_eq!(r.rejected, 3);
        assert_eq!(r.len(), 3);
    }

    #[test]
    fn rejection_ordering_global_before_per_tenant() {
        // queue_cap 2, global 3: walk the system into every overload
        // combination and pin the verdict ordering — the global cap is
        // checked first, so a saturated system reports the system-wide
        // condition, and the per-tenant cap binds only when there is
        // still global room
        let mut r = router(2, 3, 2);
        r.try_push(0, 1).unwrap();
        r.try_push(0, 2).unwrap();
        // tenant 0 full, global 2/3: the per-tenant cap is binding
        assert_eq!(r.try_push(0, 3).unwrap_err().0, Rejection::QueueFull);
        r.try_push(1, 4).unwrap();
        // global 3/3, tenant 1 at 1/2: the global cap is binding
        assert_eq!(r.try_push(1, 5).unwrap_err().0, Rejection::GlobalFull);
        // both caps violated at once for tenant 0: global wins
        assert_eq!(r.try_push(0, 6).unwrap_err().0, Rejection::GlobalFull);
        // popping makes global room again: tenant 0 re-binds per-tenant
        let _ = r.pop().unwrap();
        assert_eq!(r.queue_len(0), 1);
        r.try_push(0, 7).unwrap();
        assert_eq!(r.try_push(0, 8).unwrap_err().0, Rejection::QueueFull);
        assert_eq!(r.rejected, 4);
    }

    #[test]
    fn shedding_clamps_one_tenant_and_spares_the_rest() {
        let mut r: Router<usize> = Router::new(RouterConfig {
            queue_cap: 8,
            global_cap: 64,
            shed_queue_cap: 2,
        });
        for _ in 0..2 {
            r.register_tenant();
        }
        r.set_shed(0, true);
        assert!(r.is_shedding(0) && !r.is_shedding(1));
        r.try_push(0, 1).unwrap();
        r.try_push(0, 2).unwrap();
        // shed tenant clamped to shed_queue_cap, not queue_cap
        assert_eq!(r.try_push(0, 3).unwrap_err().0, Rejection::Shed);
        // other tenants keep the full cap
        for i in 0..8 {
            r.try_push(1, 10 + i).unwrap();
        }
        assert_eq!(r.try_push(1, 99).unwrap_err().0, Rejection::QueueFull);
        // releasing the shed restores normal admission
        r.set_shed(0, false);
        r.try_push(0, 3).unwrap();
        assert_eq!(r.queue_len(0), 3);
        // the global cap still outranks the shed verdict
        let mut r: Router<usize> = Router::new(RouterConfig {
            queue_cap: 8,
            global_cap: 1,
            shed_queue_cap: 2,
        });
        r.register_tenant();
        r.set_shed(0, true);
        r.try_push(0, 1).unwrap();
        assert_eq!(r.try_push(0, 2).unwrap_err().0, Rejection::GlobalFull);
    }

    #[test]
    fn blocked_queue_admits_but_is_not_popped() {
        let mut r = router(4, 8, 2);
        r.try_push(0, 1).unwrap();
        r.try_push(1, 2).unwrap();
        r.set_blocked(0, true);
        assert!(r.is_blocked(0));
        assert_eq!(r.ready_len(), 1);
        assert_eq!(r.pop().unwrap(), (1, 2));
        assert!(r.pop().is_none(), "blocked head must not pop");
        assert_eq!(r.len(), 1, "the blocked item stays queued");
        r.try_push(0, 3).unwrap(); // blocked queues still admit
        r.set_blocked(0, false);
        assert_eq!(r.pop().unwrap(), (0, 1));
        assert_eq!(r.pop().unwrap(), (0, 3));
        assert!(r.is_empty());
    }

    #[test]
    fn unblock_all_clears_every_block() {
        let mut r = router(4, 8, 3);
        for t in 0..3 {
            r.try_push(t, t as usize).unwrap();
            r.set_blocked(t, true);
        }
        assert_eq!(r.ready_len(), 0);
        r.unblock_all();
        assert_eq!(r.ready_len(), 3);
        assert!(r.pop().is_some());
    }

    #[test]
    fn empty_router_pops_nothing() {
        let mut r = router(4, 8, 2);
        assert!(r.pop().is_none());
        assert!(r.is_empty());
    }

    #[test]
    fn threaded_loop_serves_and_drains_on_shutdown() {
        let handle = spawn_tenant_server(
            RouterConfig::default(),
            2,
            || Ok(Vec::<(TenantId, String)>::new()),
            |seen, t, q| {
                seen.push((t, q.to_string()));
                let mut r = blank_record(seen.len());
                r.answer = format!("t{t}: {q}");
                Ok(r)
            },
            |_, _| {},
        );
        let a = handle.query(0, 1, "hello").unwrap();
        assert_eq!(a.record.answer, "t0: hello");
        let b = handle.query(1, 2, "world").unwrap();
        assert_eq!(b.record.answer, "t1: world");
        handle.shutdown();
        handle.join().unwrap();
        // join is idempotent
        handle.join().unwrap();
    }

    #[test]
    fn rejected_request_gets_well_formed_error_response() {
        // queue_cap 0: every admission fails deterministically, so the
        // client-visible shape of a rejection is pinned down
        let handle = spawn_tenant_server(
            RouterConfig {
                queue_cap: 0,
                global_cap: 8,
                ..RouterConfig::default()
            },
            1,
            || Ok(()),
            |_, _, _| Ok(blank_record(0)),
            |_, _| {},
        );
        let resp = handle.query(0, 42, "hello").unwrap();
        assert_eq!(resp.id, 42, "the response must echo the request id");
        assert!(
            resp.record.answer.starts_with("error: admission rejected"),
            "{}",
            resp.record.answer
        );
        assert!(
            resp.record.answer.contains("per-tenant queue full"),
            "{}",
            resp.record.answer
        );
        assert!(resp.e2e_ms >= 0.0);
        handle.shutdown();
        handle.join().unwrap();
    }

    #[test]
    fn unknown_tenant_gets_error_response() {
        let handle = spawn_tenant_server(
            RouterConfig::default(),
            1,
            || Ok(()),
            |_, _, _| Ok(blank_record(0)),
            |_, _| {},
        );
        let resp = handle.query(7, 1, "hi").unwrap();
        assert!(resp.record.answer.contains("unknown tenant"), "{}", resp.record.answer);
        handle.shutdown();
        handle.join().unwrap();
    }
}
