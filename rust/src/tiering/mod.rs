//! Warm/cold shard tiering (DESIGN.md §11): demote idle tenant shards to
//! disk, page them back on demand.
//!
//! PR 1's memory governor can only shrink a cold tenant's budget
//! slice-by-slice while the shard's QA bank, QKV tree and predictor stay
//! resident forever.  This subsystem converts the registry into a
//! two-tier residency system — RAGCache's hot/cold knowledge-cache shape
//! applied to whole tenant shards under mobile memory pressure:
//!
//! * [`residency`] — the [`Residency`] state machine
//!   (Hot/Demoting/Cold/Hydrating) and the deterministic per-tenant
//!   [`ActivityTracker`] (EWMA request rate + last-touch tick).
//! * [`controller`] — the [`TieringController`] policy loop: demotes
//!   shards idle past a threshold (and, proactively, under a
//!   memory-pressure watermark), skips tenants with queued work, starts
//!   asynchronous hydrations on a background [`controller::HydrationWorker`]
//!   thread, and warms shards ahead of forecasted active periods via
//!   scheduled prefetches.
//! * [`sim`] — deterministic tiered replay (router admission + blocked
//!   queues + controller ticks) used by `percache exp tiering`, the
//!   integration tests and the CLI demo.
//! * [`service`] — the threaded serving loop: requests for a cold tenant
//!   queue behind the async hydration instead of blocking the inference
//!   thread (`spawn_tiered_server`, on the gated router loop).
//!
//! The cold tier *is* the PR 2 persistence format: demotion snapshots the
//! shard into its `shard_<id>/` directory (`TenantShard::save`, now
//! incremental) and drops the in-RAM shard; the freed bytes flow back
//! into the governor's global pool for the remaining hot shards.
//! Hydration is `TenantShard::open_or_create` on a worker thread followed
//! by a governed shrink to the shard's current share.

pub mod controller;
pub mod residency;
pub mod service;
pub mod sim;

pub use controller::{HydrationWorker, TickReport, TieringController};
pub use residency::{ActivityTracker, Residency};
pub use service::spawn_tiered_server;
