//! The tiered serving loop: `run_tenant_loop_gated` wired to a
//! persistent registry, the [`TieringController`] and a background
//! [`HydrationWorker`] (DESIGN.md §11).
//!
//! A request for a cold tenant does not block the inference thread:
//! admission kicks an asynchronous hydration and blocks only that
//! tenant's queue; other tenants keep serving, and the blocked queue
//! drains fairly once the worker delivers the rebuilt shard.  Idle-tick
//! commands drive the controller (demotion + prefetch), mirroring the
//! engine's idle-path population cadence.
//!
//! Serving is the cache-level sim (`tenancy::sim::serve_one`) — the
//! residency system under test is fully real; only the LLM cost is
//! modeled — so the tiered server runs without PJRT artifacts
//! (`percache serve --tiering`).

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::mpsc;
use std::thread;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::config::TenancyConfig;
use crate::metrics::QueryRecord;
use crate::tenancy::router::run_tenant_loop_gated;
use crate::tenancy::sim::{serve_one, SimConfig};
use crate::tenancy::{
    HydrationSpec, RouterConfig, TenantId, TenantRegistry, TenantServerHandle,
};
use crate::tokenizer::fnv1a64;
use crate::util::json::Json;

use super::controller::{HydrationWorker, TieringController};
use super::residency::Residency;

/// Counters the serving thread writes to `<dir>/tiering_report.json` at
/// shutdown (the thread's state dies with it; the report is how demos
/// and tests observe what the residency system did).
pub const REPORT_FILE: &str = "tiering_report.json";

/// Everything the tiered serving thread needs to build its state.
#[derive(Debug, Clone)]
pub struct TieredServerConfig {
    pub tenancy: TenancyConfig,
    pub sim: SimConfig,
    /// Persistent registry base dir (the cold tier lives here).
    pub dir: PathBuf,
    pub n_tenants: usize,
    /// Echo journal events to stderr (CLI demo / `--verbose`).
    pub log: bool,
    /// Periodic metrics dump target (`--metrics-file`): the obs
    /// snapshot plus the tiering report, rewritten from the idle path.
    pub metrics_file: Option<PathBuf>,
    pub metrics_interval_secs: u64,
}

struct State {
    registry: TenantRegistry,
    controller: TieringController,
    worker: HydrationWorker,
    sim: SimConfig,
    /// Stall clocks for in-flight hydrations (started → installed).
    hydration_started: HashMap<TenantId, Instant>,
    metrics_file: Option<PathBuf>,
    metrics_interval_secs: u64,
    last_dump: Option<Instant>,
}

impl State {
    /// Derive the demo prompt path for a query: a per-tenant context
    /// prefix (reusable across the tenant's queries) + the query segment.
    fn seg_keys(tenant: TenantId, query: &str) -> Vec<u64> {
        vec![
            fnv1a64(b"sys"),
            fnv1a64(format!("t{tenant}/profile").as_bytes()),
            fnv1a64(query.as_bytes()),
        ]
    }

    /// Hand a hydration spec to the worker and start its stall clock.
    /// `why` is the journal event kind ("hydration.started" for demand
    /// misses, "prefetch.started" for forecast-driven warming).
    fn submit_hydration(&mut self, spec: HydrationSpec, why: &'static str) {
        let tenant = spec.tenant;
        self.hydration_started.insert(tenant, Instant::now());
        crate::obs::emit(crate::obs::Event::new(why).tenant(tenant as usize));
        self.worker.submit(spec);
    }

    /// Record one hydration outcome: stall histogram + journal event.
    fn note_hydrated(&mut self, tenant: TenantId, err: Option<String>) {
        let stall_ms = self
            .hydration_started
            .remove(&tenant)
            .map(|t| t.elapsed().as_secs_f64() * 1e3)
            .unwrap_or(0.0);
        match err {
            None => {
                crate::obs_hist!("tiering.hydration_stall_ms").record(stall_ms);
                crate::obs::emit(
                    crate::obs::Event::new("hydration.finished")
                        .tenant(tenant as usize)
                        .field("stall_ms", stall_ms),
                );
            }
            Some(msg) => crate::obs::emit(
                crate::obs::Event::new("hydration.failed")
                    .tenant(tenant as usize)
                    .field("stall_ms", stall_ms)
                    .msg(msg),
            ),
        }
    }

    /// Feed the live queue depths into the registry (the backlog veto +
    /// governor boost) and install every hydration the worker finished;
    /// returns the tenants whose queues may unblock.
    fn poll_hydrations(&mut self, depths: &[usize]) -> Vec<TenantId> {
        self.registry.set_queue_depths(depths);
        let mut ready = Vec::new();
        for (tenant, built) in self.worker.poll() {
            match built {
                Ok(shard) => {
                    if self.registry.finish_hydration(tenant, shard).is_ok() {
                        self.note_hydrated(tenant, None);
                        ready.push(tenant);
                    }
                }
                Err(e) => {
                    self.note_hydrated(tenant, Some(format!("{e:#}")));
                    let _ = self.registry.abort_hydration(tenant);
                    // unblock so the queued requests drain through the
                    // synchronous fallback instead of waiting forever
                    ready.push(tenant);
                }
            }
        }
        ready
    }

    /// Make `tenant` resident before serving (shutdown drains and
    /// hydration-failure fallbacks reach here with a non-Hot shard).
    fn ensure_resident(&mut self, tenant: TenantId) -> Result<()> {
        loop {
            match self.registry.residency(tenant) {
                Some(Residency::Hot) | Some(Residency::Demoting) => return Ok(()),
                Some(Residency::Cold) => return self.registry.hydrate_tenant(tenant),
                Some(Residency::Hydrating) => {
                    // the worker holds the shard; wait for it — if this
                    // request is traced, the stall shows up as its own
                    // span in the request tree
                    let _stall = crate::obs::trace::child("hydration_stall");
                    match self.worker.wait_one() {
                        Some((t, Ok(shard))) => {
                            self.registry.finish_hydration(t, shard)?;
                            self.note_hydrated(t, None);
                        }
                        Some((t, Err(e))) => {
                            self.registry.abort_hydration(t)?;
                            let msg = format!("{e:#}");
                            self.note_hydrated(t, Some(msg.clone()));
                            if t == tenant {
                                anyhow::bail!("hydration failed: {msg}");
                            }
                        }
                        None => anyhow::bail!("hydration worker died"),
                    }
                }
                None => anyhow::bail!("unknown tenant {tenant}"),
            }
        }
    }

    fn serve(&mut self, tenant: TenantId, query: &str) -> Result<QueryRecord> {
        self.ensure_resident(tenant)?;
        self.controller.note_request(tenant);
        let keys = Self::seg_keys(tenant, query);
        let shard = self
            .registry
            .shard_mut(tenant)
            .context("resident shard vanished")?;
        serve_one(&self.sim, shard, query, &keys)
    }

    /// Admission gate: a Hot tenant serves normally; a Cold tenant
    /// starts a background hydration and parks its queue.
    fn admit(&mut self, tenant: TenantId) -> bool {
        self.controller.note_request(tenant);
        match self.registry.residency(tenant) {
            Some(Residency::Hot) | Some(Residency::Demoting) => true,
            Some(Residency::Hydrating) => false,
            Some(Residency::Cold) => match self.registry.begin_hydration(tenant) {
                Ok(spec) => {
                    self.submit_hydration(spec, "hydration.started");
                    false
                }
                Err(_) => true, // raced to Hot; serve normally
            },
            None => true, // unknown tenant: the serve path answers with an error
        }
    }

    /// One idle tick: run the controller (demotion + prefetch), then
    /// refresh the on-disk report + metrics dump so both survive a
    /// non-graceful exit.
    fn idle(&mut self) {
        let _span = crate::obs::span("tiering.tick_ms");
        match self.controller.tick(&mut self.registry) {
            Ok(report) => {
                if !report.demoted.is_empty() {
                    crate::obs::emit(
                        crate::obs::Event::new("controller.demoted")
                            .field("tick", report.tick as f64)
                            .field("n", report.demoted.len() as f64)
                            .field("freed_bytes", report.freed_bytes as f64),
                    );
                }
                for tenant in report.prefetch {
                    if let Ok(spec) = self.registry.begin_hydration(tenant) {
                        self.submit_hydration(spec, "prefetch.started");
                    }
                }
            }
            Err(e) => crate::obs::emit(
                crate::obs::Event::new("controller.error").msg(format!("{e:#}")),
            ),
        }
        let _ = self.write_report();
        self.maybe_dump_metrics();
    }

    /// The residency counters a demo/test reads back.
    fn report_json(&self) -> Json {
        let mut o = Json::obj();
        o.insert("ticks", self.controller.tick_count());
        o.insert("demotions", self.registry.demotions);
        o.insert("hydrations", self.registry.hydrations);
        o.insert("idle_demotions", self.controller.idle_demotions);
        o.insert("pressure_demotions", self.controller.pressure_demotions);
        o.insert("prefetches", self.controller.prefetches);
        o.insert("resident_bytes", self.registry.resident_bytes());
        o.insert("resident_count", self.registry.resident_count());
        Json::Obj(o)
    }

    /// Rewrite `<dir>/tiering_report.json` (idle path + shutdown — not
    /// only at shutdown, so the report survives a crash or SIGKILL).
    fn write_report(&self) -> Result<()> {
        let dir = self
            .registry
            .persist_dir()
            .context("tiered registry is persistent")?;
        std::fs::write(dir.join(REPORT_FILE), self.report_json().to_string_pretty())?;
        Ok(())
    }

    /// Periodic `--metrics-file` dump from the idle path: the obs
    /// snapshot (typed JSON + Prometheus text) with the tiering report
    /// folded in.  The first tick writes immediately; later ticks
    /// rewrite at the configured interval.
    fn maybe_dump_metrics(&mut self) {
        let Some(path) = self.metrics_file.clone() else {
            return;
        };
        let due = match self.last_dump {
            None => true,
            Some(t) => t.elapsed().as_secs() >= self.metrics_interval_secs,
        };
        if !due {
            return;
        }
        self.last_dump = Some(Instant::now());
        let _ = crate::obs::dump_metrics_file(&path, &[("tiering", self.report_json())]);
    }

    /// Shutdown: make everything consistent on disk and leave the
    /// residency counters where a demo/test can read them.
    fn finish(&mut self) -> Result<()> {
        // drain any hydration still in flight so no shard is lost
        while self.worker.in_flight() > 0 {
            match self.worker.wait_one() {
                Some((t, Ok(shard))) => {
                    let _ = self.registry.finish_hydration(t, shard);
                    self.note_hydrated(t, None);
                }
                Some((t, Err(e))) => {
                    let _ = self.registry.abort_hydration(t);
                    self.note_hydrated(t, Some(format!("{e:#}")));
                }
                None => break,
            }
        }
        self.registry.save_all()?;
        self.write_report()?;
        if let Some(path) = &self.metrics_file {
            let _ = crate::obs::dump_metrics_file(path, &[("tiering", self.report_json())]);
        }
        Ok(())
    }
}

/// Spawn the tiered multi-tenant serving thread.  The registry opens
/// (or creates) under `cfg.dir`; missing tenants up to `cfg.n_tenants`
/// are created.  The returned handle is the ordinary
/// [`TenantServerHandle`] — `query` for requests, `idle_tick` to drive
/// the controller, `shutdown`/`join` to stop (writing
/// `tiering_report.json` + saving every resident shard on the way out).
pub fn spawn_tiered_server(cfg: TieredServerConfig) -> TenantServerHandle {
    let (tx, rx) = mpsc::channel();
    let n_tenants = cfg.n_tenants;
    let router_cfg = RouterConfig {
        queue_cap: cfg.tenancy.queue_cap,
        global_cap: cfg.tenancy.global_queue_cap,
        shed_queue_cap: cfg.tenancy.slo.shed_queue_cap(cfg.tenancy.queue_cap),
    };
    let join = thread::Builder::new()
        .name("percache-tiered-server".into())
        .spawn(move || -> Result<()> {
            if cfg.log {
                crate::obs::set_verbose(true);
            }
            let mut registry = TenantRegistry::open_or_create(&cfg.tenancy, cfg.dir.clone())?;
            while registry.len() < cfg.n_tenants {
                registry.create_tenant()?;
            }
            let controller =
                TieringController::new(cfg.tenancy.tiering.clone(), registry.len());
            let state = RefCell::new(State {
                registry,
                controller,
                worker: HydrationWorker::spawn(),
                sim: cfg.sim.clone(),
                hydration_started: HashMap::new(),
                metrics_file: cfg.metrics_file.clone(),
                metrics_interval_secs: cfg.metrics_interval_secs.max(1),
                last_dump: None,
            });
            run_tenant_loop_gated(
                rx,
                router_cfg,
                n_tenants,
                |t, q| state.borrow_mut().serve(t, q),
                |_| state.borrow_mut().idle(),
                |t| state.borrow_mut().admit(t),
                |depths| state.borrow_mut().poll_hydrations(depths),
            );
            state.borrow_mut().finish()
        })
        // percache-allow(panic_path): thread-spawn failure at process start is unrecoverable resource exhaustion; dying loudly beats serving without a loop
        .expect("spawn tiered server thread");
    TenantServerHandle::from_parts(tx, join)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TieringConfig;
    use crate::tenancy::sim::sim_slice_bytes;

    fn tmp(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "percache_tiersvc_{tag}_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn config(dir: &PathBuf, idle_ticks: u64) -> TieredServerConfig {
        let mut tenancy = TenancyConfig::default();
        tenancy.enabled = true;
        tenancy.max_tenants = 4;
        tenancy.global_qkv_bytes = 64 * sim_slice_bytes();
        tenancy.tiering = TieringConfig {
            enabled: true,
            idle_ticks_to_demote: idle_ticks,
            min_resident: 1,
            ..TieringConfig::default()
        };
        TieredServerConfig {
            tenancy,
            sim: SimConfig::default(),
            dir: dir.clone(),
            n_tenants: 2,
            log: false,
            metrics_file: None,
            metrics_interval_secs: 5,
        }
    }

    #[test]
    fn cold_tenant_serves_after_async_hydration() {
        let dir = tmp("async");
        let handle = spawn_tiered_server(config(&dir, 2));
        // prime both tenants
        handle.query(0, 1, "alpha question one").unwrap();
        handle.query(1, 2, "beta question one").unwrap();
        // two idle ticks with only tenant 0 active → tenant 1 demotes
        handle.query(0, 3, "alpha question two").unwrap();
        handle.idle_tick(0).unwrap();
        handle.query(0, 4, "alpha question three").unwrap();
        handle.idle_tick(0).unwrap();
        // tenant 1 returns: the request parks behind the background
        // hydration and still gets a real answer
        let resp = handle.query(1, 5, "beta question one").unwrap();
        assert!(
            !resp.record.answer.starts_with("error"),
            "cold-tenant request must serve after hydration: {}",
            resp.record.answer
        );
        handle.shutdown();
        handle.join().unwrap();

        let report =
            std::fs::read_to_string(dir.join(REPORT_FILE)).expect("report must be written");
        let j = Json::parse(&report).unwrap();
        assert!(
            j.get("demotions").as_usize().unwrap() >= 1,
            "idle tenant must have demoted: {report}"
        );
        assert!(
            j.get("hydrations").as_usize().unwrap() >= 1,
            "comeback must have hydrated: {report}"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn service_warm_restarts_from_the_cold_tier() {
        let dir = tmp("restart");
        // long idle threshold: nothing demotes on its own
        let handle = spawn_tiered_server(config(&dir, 1000));
        handle.query(0, 1, "warm up zero").unwrap();
        handle.query(1, 2, "warm up one").unwrap();
        handle.shutdown();
        handle.join().unwrap();
        // a second server over the same dir warm-restarts both tenants
        let handle = spawn_tiered_server(config(&dir, 1000));
        let resp = handle.query(1, 3, "warm up one").unwrap();
        assert!(!resp.record.answer.starts_with("error"), "{}", resp.record.answer);
        handle.shutdown();
        handle.join().unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }
}
