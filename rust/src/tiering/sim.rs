//! Deterministic tiered replay: the tenancy cache-level replay with the
//! residency system in the loop (DESIGN.md §11).
//!
//! One scheduling round (a router batch) is one controller tick.  The
//! router's admission control and fair scheduling run exactly as in
//! `tenancy::sim::replay`; on top of that, per-tenant queue depths feed
//! the governor's queueing signal, the [`TieringController`] demotes
//! idle/pressured shards between rounds, and a request that lands on a
//! cold shard pays a measured *hydration stall* (the snapshot reload)
//! before it is served — the cost `BENCH_tiering.json` reports as
//! `hydration_stall_p99_ms`.  Demand hydration here is synchronous
//! (deterministic single-thread replay); the asynchronous path — blocked
//! queues draining behind a background worker — is the serving loop's
//! ([`super::service::spawn_tiered_server`]).

use anyhow::Result;

use crate::metrics::Recorder;
use crate::tenancy::sim::{serve_one, Arrival, SimConfig};
use crate::tenancy::{Router, RouterConfig, TenantRegistry};

use super::controller::TieringController;

/// Tiered replay result: the plain replay's outcome plus residency
/// accounting.
#[derive(Debug)]
pub struct TieredOutcome {
    pub per_tenant: Vec<Recorder>,
    pub rejected: u64,
    pub rebalances: u64,
    pub demotions: u64,
    pub hydrations: u64,
    /// Measured ms each demand hydration stalled the request that
    /// triggered it (empty when nothing ever went cold).
    pub hydration_stall_ms: Vec<f64>,
    /// Resident (tree + QA) bytes sampled after every controller tick —
    /// the series whose drop makes demotion observable.
    pub resident_bytes_ticks: Vec<usize>,
}

impl TieredOutcome {
    /// All records flattened and sorted by total latency.
    pub fn all_total_ms(&self) -> Vec<f64> {
        let mut v: Vec<f64> = self
            .per_tenant
            .iter()
            .flat_map(|r| r.records.iter().map(|q| q.total_ms()))
            .collect();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        v
    }

    pub fn mean_resident_bytes(&self) -> f64 {
        if self.resident_bytes_ticks.is_empty() {
            return 0.0;
        }
        self.resident_bytes_ticks.iter().sum::<usize>() as f64
            / self.resident_bytes_ticks.len() as f64
    }

    pub fn min_resident_bytes(&self) -> usize {
        self.resident_bytes_ticks.iter().copied().min().unwrap_or(0)
    }

    pub fn peak_resident_bytes(&self) -> usize {
        self.resident_bytes_ticks.iter().copied().max().unwrap_or(0)
    }
}

/// Replay `arrivals` through router + registry with the tiering
/// controller ticking once per scheduling round.  The registry must be
/// persistent (`open_or_create`) when the controller is enabled —
/// demotion writes the cold tier.  With tiering disabled this measures
/// exactly the pre-tiering behaviour (every shard stays resident),
/// which is the experiment's baseline arm.
pub fn replay_tiered(
    registry: &mut TenantRegistry,
    controller: &mut TieringController,
    router_cfg: RouterConfig,
    cfg: &SimConfig,
    arrivals: &[Arrival],
    batch: usize,
) -> Result<TieredOutcome> {
    let mut router: Router<Arrival> = Router::new(router_cfg);
    for _ in 0..registry.len() {
        router.register_tenant();
    }
    let mut per_tenant: Vec<Recorder> = (0..registry.len()).map(|_| Recorder::new()).collect();
    let mut rebalances = 0u64;
    let mut hydration_stall_ms = Vec::new();
    let mut resident_bytes_ticks = Vec::new();

    for chunk in arrivals.chunks(batch.max(1)) {
        for a in chunk {
            if router.try_push(a.tenant, a.clone()).is_ok() {
                controller.note_request(a.tenant);
            }
        }
        // the queueing signal: backlog boosts governor utility and
        // vetoes demotion
        registry.set_queue_depths(&router.depths());
        while let Some((tenant, a)) = router.pop() {
            if registry.shard(tenant).is_none() {
                // cold shard: this request pays the page-in
                let t0 = std::time::Instant::now();
                registry.hydrate_tenant(tenant)?;
                hydration_stall_ms.push(t0.elapsed().as_secs_f64() * 1e3);
            }
            let shard = registry
                .shard_mut(tenant)
                .ok_or_else(|| anyhow::anyhow!("router/registry tenant mismatch"))?;
            let rec = serve_one(cfg, shard, &a.query, &a.seg_keys)?;
            per_tenant[tenant as usize].push(rec);
            if registry.note_serve() {
                rebalances += 1;
            }
        }
        registry.set_queue_depths(&router.depths());
        let report = controller.tick(registry)?;
        // scheduled prefetches warm shards before their active period;
        // no request waits on them, so no stall is recorded
        for tenant in report.prefetch {
            registry.hydrate_tenant(tenant)?;
        }
        resident_bytes_ticks.push(registry.resident_bytes());
    }
    registry.check_invariants()?;
    Ok(TieredOutcome {
        per_tenant,
        rejected: router.rejected,
        rebalances,
        demotions: registry.demotions,
        hydrations: registry.hydrations,
        hydration_stall_ms,
        resident_bytes_ticks,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{TenancyConfig, TieringConfig};
    use crate::tenancy::sim::sim_slice_bytes;
    use crate::tenancy::TenantId;
    use crate::tokenizer::fnv1a64;

    fn tmp(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "percache_tiersim_{tag}_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn tcfg(n: usize, idle_ticks: u64) -> TenancyConfig {
        let mut tc = TenancyConfig::default();
        tc.enabled = true;
        tc.max_tenants = n;
        tc.global_qkv_bytes = 64 * sim_slice_bytes();
        tc.rebalance_every = 8;
        tc.tiering = TieringConfig {
            enabled: true,
            idle_ticks_to_demote: idle_ticks,
            min_resident: 1,
            ..TieringConfig::default()
        };
        tc
    }

    fn arrival(tenant: TenantId, q: &str, topic: u64) -> Arrival {
        Arrival {
            tenant,
            query: q.to_string(),
            seg_keys: vec![
                fnv1a64(b"sys"),
                fnv1a64(format!("t{tenant}/c{topic}a").as_bytes()),
                fnv1a64(format!("t{tenant}/c{topic}b").as_bytes()),
                fnv1a64(q.as_bytes()),
            ],
            shared: Vec::new(),
        }
    }

    /// Tenant 1 bursts, goes silent (demotes), then returns: the comeback
    /// request pays a hydration stall and then hits its restored cache.
    #[test]
    fn on_off_tenant_demotes_and_comes_back_warm() {
        let dir = tmp("onoff");
        let tc = tcfg(2, 2);
        let mut reg = TenantRegistry::open_or_create(&tc, dir.clone()).unwrap();
        reg.create_tenant().unwrap();
        reg.create_tenant().unwrap();
        let mut ctl = TieringController::new(tc.tiering.clone(), 2);
        let cfg = SimConfig::default();

        let mut arrivals = Vec::new();
        // phase 1 (2 ticks of 4): both tenants active
        for i in 0..8u64 {
            arrivals.push(arrival((i % 2) as TenantId, &format!("query item{:04}", i / 2), 0));
        }
        // phase 2 (4 ticks): only tenant 0 → tenant 1 idles past 2 ticks
        for i in 0..16u64 {
            arrivals.push(arrival(0, &format!("query item{i:04} still"), 0));
        }
        // phase 3: tenant 1 returns with a verbatim phase-1 repeat
        arrivals.push(arrival(1, "query item0000", 0));

        let out = replay_tiered(
            &mut reg,
            &mut ctl,
            RouterConfig::default(),
            &cfg,
            &arrivals,
            4,
        )
        .unwrap();
        assert!(out.demotions >= 1, "idle tenant must demote");
        assert_eq!(out.hydrations, out.hydration_stall_ms.len() as u64);
        assert!(out.hydrations >= 1, "comeback must hydrate");
        // the resident-bytes series must dip while tenant 1 is cold
        assert!(
            out.min_resident_bytes() < out.peak_resident_bytes(),
            "demotion must be observable in resident bytes: {:?}",
            out.resident_bytes_ticks
        );
        // the comeback query is a verbatim repeat primed in phase 1: the
        // rehydrated QA bank must serve it as a hit
        let last = out.per_tenant[1].records.last().unwrap();
        assert_eq!(
            last.path,
            crate::metrics::ServePath::QaHit,
            "rehydrated shard must keep its hit behaviour"
        );
        reg.check_invariants().unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// The disabled-arm replay is exactly the pre-tiering behaviour.
    #[test]
    fn disabled_tiering_keeps_everything_resident() {
        let dir = tmp("disabled");
        let mut tc = tcfg(3, 1);
        tc.tiering.enabled = false;
        let mut reg = TenantRegistry::open_or_create(&tc, dir.clone()).unwrap();
        for _ in 0..3 {
            reg.create_tenant().unwrap();
        }
        let mut ctl = TieringController::new(tc.tiering.clone(), 3);
        let arrivals: Vec<Arrival> = (0..12)
            .map(|i| arrival(0, &format!("q item{i:04}"), 0))
            .collect();
        let out = replay_tiered(
            &mut reg,
            &mut ctl,
            RouterConfig::default(),
            &SimConfig::default(),
            &arrivals,
            4,
        )
        .unwrap();
        assert_eq!(out.demotions, 0);
        assert_eq!(out.hydrations, 0);
        assert_eq!(reg.resident_count(), 3);
        assert!(out.hydration_stall_ms.is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
