//! The shard residency state machine and the per-tenant activity signal
//! that drives it (DESIGN.md §11).
//!
//! ```text
//!            demote_tenant            save ok
//!   Hot ────────────────▶ Demoting ────────────▶ Cold
//!    ▲                        │ save failed        │ begin_hydration
//!    │                        ▼                    ▼
//!    │◀──────────────────── Hot              Hydrating
//!    │            finish_hydration                 │
//!    └─────────────────────────────────────────────┘
//! ```
//!
//! `Hot` and `Demoting` shards are resident in RAM; `Cold` and
//! `Hydrating` shards exist only as their on-disk snapshot (the PR 2
//! persistence format: `shard_<id>/` with slice files, store manifest,
//! `cache_state.json` and `shard_stats.json`).  `Demoting` is transient
//! inside `TenantRegistry::demote_tenant`; `Hydrating` is observable for
//! as long as the background hydration worker is rebuilding the shard.

/// Where a tenant shard currently lives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Residency {
    /// Fully resident in RAM, serving requests.
    Hot,
    /// Snapshot in progress; still resident (transient).
    Demoting,
    /// Evicted to the cold tier; only the on-disk snapshot exists.
    Cold,
    /// A background hydration is rebuilding the shard from disk.
    Hydrating,
}

impl Residency {
    /// Whether a shard in this state occupies RAM (has an in-memory
    /// `TenantShard`).
    pub fn is_resident(self) -> bool {
        matches!(self, Residency::Hot | Residency::Demoting)
    }

    pub fn label(self) -> &'static str {
        match self {
            Residency::Hot => "hot",
            Residency::Demoting => "demoting",
            Residency::Cold => "cold",
            Residency::Hydrating => "hydrating",
        }
    }
}

/// Per-tenant activity signal: EWMA request rate over logical ticks
/// (scheduling rounds) plus the last-touch tick.  Deterministic — no
/// wall clock — so demotion decisions replay identically in tests and
/// experiments.
#[derive(Debug, Clone)]
pub struct ActivityTracker {
    /// Requests observed since the current tick started.
    pending: u64,
    /// EWMA of requests-per-tick.
    rate: f64,
    /// Tick of the most recent request (0 = never touched).
    last_touch: u64,
    alpha: f64,
    pub touches: u64,
}

impl ActivityTracker {
    pub fn new(alpha: f64) -> Self {
        ActivityTracker {
            pending: 0,
            rate: 0.0,
            last_touch: 0,
            alpha: alpha.clamp(1e-6, 1.0),
            touches: 0,
        }
    }

    /// Record one request at tick `now`.
    pub fn touch(&mut self, now: u64) {
        self.pending += 1;
        self.touches += 1;
        self.last_touch = now;
    }

    /// Fold the tick's request count into the EWMA rate (call once per
    /// tick, after all of the tick's requests were recorded).
    pub fn end_tick(&mut self) {
        self.rate += self.alpha * (self.pending as f64 - self.rate);
        self.pending = 0;
    }

    /// Smoothed requests-per-tick.
    pub fn rate(&self) -> f64 {
        self.rate
    }

    pub fn last_touch(&self) -> u64 {
        self.last_touch
    }

    /// Ticks since the last request (`now` itself counts as elapsed; a
    /// never-touched tracker reports `now`).
    pub fn idle_ticks(&self, now: u64) -> u64 {
        now.saturating_sub(self.last_touch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn residency_labels_and_residency() {
        assert!(Residency::Hot.is_resident());
        assert!(Residency::Demoting.is_resident());
        assert!(!Residency::Cold.is_resident());
        assert!(!Residency::Hydrating.is_resident());
        assert_eq!(Residency::Cold.label(), "cold");
    }

    #[test]
    fn activity_tracks_rate_and_idleness() {
        let mut a = ActivityTracker::new(0.5);
        assert_eq!(a.idle_ticks(10), 10, "never touched = idle forever");
        a.touch(3);
        a.touch(3);
        a.end_tick();
        assert!(a.rate() > 0.9, "{}", a.rate());
        assert_eq!(a.idle_ticks(3), 0);
        assert_eq!(a.idle_ticks(8), 5);
        // quiet ticks decay the rate toward zero
        for _ in 0..8 {
            a.end_tick();
        }
        assert!(a.rate() < 0.01, "{}", a.rate());
        assert_eq!(a.touches, 2);
    }
}
