//! The tiering policy loop and the background hydration worker
//! (DESIGN.md §11).
//!
//! [`TieringController`] owns one [`ActivityTracker`] per tenant and, on
//! every logical tick, decides which shards to demote (idle past
//! `idle_ticks_to_demote`, or proactively under the memory-pressure
//! watermark) and which cold shards to warm ahead of a forecasted
//! active period.  The *mechanics* of demotion/hydration live in
//! [`TenantRegistry`]; the controller only drives them, so the policy is
//! a pure function of (activity, queue depths, residency) and replays
//! deterministically in tests and experiments.
//!
//! [`HydrationWorker`] rebuilds cold shards on a background thread so
//! the inference thread never blocks on disk: the serving loop submits a
//! [`HydrationSpec`], keeps the tenant's queue blocked, and installs the
//! finished shard on a later poll.

use std::sync::mpsc;
use std::thread;

use anyhow::Result;

use crate::config::TieringConfig;
use crate::tenancy::registry::HydrationSpec;
use crate::tenancy::{TenantId, TenantRegistry, TenantShard};

use super::residency::{ActivityTracker, Residency};

/// What one controller tick did (reporting + caller follow-up: the
/// caller decides how `prefetch` shards get hydrated — synchronously in
/// the replay, via the [`HydrationWorker`] in the serving loop).
#[derive(Debug, Default)]
pub struct TickReport {
    pub tick: u64,
    /// Tenants demoted this tick (snapshot written, RAM reclaimed).
    pub demoted: Vec<TenantId>,
    /// Resident bytes freed by this tick's demotions.
    pub freed_bytes: usize,
    /// Cold tenants whose forecasted active period is within the
    /// prefetch lead: the caller should start hydrating them now.
    pub prefetch: Vec<TenantId>,
    /// Cold snapshots evicted by the disk budget this tick (oldest
    /// first); these tenants restart empty via `recreate_evicted`.
    pub cold_evicted: Vec<TenantId>,
}

/// Per-tenant activity tracking + the demote/prefetch policy.
pub struct TieringController {
    cfg: TieringConfig,
    trackers: Vec<ActivityTracker>,
    tick: u64,
    /// Forecasted active periods: (tenant, tick it becomes active).
    scheduled: Vec<(TenantId, u64)>,
    pub idle_demotions: u64,
    pub pressure_demotions: u64,
    pub prefetches: u64,
}

impl TieringController {
    pub fn new(cfg: TieringConfig, n_tenants: usize) -> Self {
        let alpha = cfg.activity_alpha;
        TieringController {
            cfg,
            trackers: (0..n_tenants).map(|_| ActivityTracker::new(alpha)).collect(),
            tick: 0,
            scheduled: Vec::new(),
            idle_demotions: 0,
            pressure_demotions: 0,
            prefetches: 0,
        }
    }

    pub fn config(&self) -> &TieringConfig {
        &self.cfg
    }

    pub fn tick_count(&self) -> u64 {
        self.tick
    }

    /// Track a late-created tenant (ids align with the registry's).
    pub fn register_tenant(&mut self) {
        self.trackers.push(ActivityTracker::new(self.cfg.activity_alpha));
    }

    /// Record one admitted request for `tenant` at the current tick.
    pub fn note_request(&mut self, tenant: TenantId) {
        if let Some(t) = self.trackers.get_mut(tenant as usize) {
            t.touch(self.tick);
        }
    }

    /// The tenant's smoothed requests-per-tick (reporting).
    pub fn rate(&self, tenant: TenantId) -> f64 {
        self.trackers.get(tenant as usize).map_or(0.0, |t| t.rate())
    }

    /// Forecast that `tenant` becomes active at `at_tick` (from the
    /// predictor, a calendar, or the workload itself): hydration starts
    /// `prefetch_lead_ticks` early so the shard is warm on arrival.
    pub fn schedule_active(&mut self, tenant: TenantId, at_tick: u64) {
        self.scheduled.push((tenant, at_tick));
    }

    /// Close the current tick and run the policy over `registry`:
    /// fold activity EWMAs, demote idle/pressured shards, and report
    /// which cold shards to prefetch.  A disabled controller still
    /// tracks activity (so enabling later starts from real signals) but
    /// never demotes or prefetches.
    pub fn tick(&mut self, registry: &mut TenantRegistry) -> Result<TickReport> {
        // tenants created since construction get fresh trackers
        while self.trackers.len() < registry.len() {
            self.register_tenant();
        }
        for t in &mut self.trackers {
            t.end_tick();
        }
        self.tick += 1;
        let now = self.tick;
        let mut report = TickReport {
            tick: now,
            ..TickReport::default()
        };
        if !self.cfg.enabled {
            return Ok(report);
        }

        // idle demotions, in id order (deterministic): a tenant with
        // queued work is never a candidate, whatever its hit rate, and
        // one currently blowing its SLO keeps its warm cache (demoting
        // it would convert a latency problem into a worse one)
        for id in 0..registry.len() as TenantId {
            if registry.resident_count() <= self.cfg.min_resident {
                break;
            }
            if registry.residency(id) != Some(Residency::Hot) {
                continue;
            }
            if registry.queue_depth(id) > 0 {
                continue;
            }
            if self.slo_vetoed(registry, id) {
                continue;
            }
            // before judging idleness, let the tenant's own predictor
            // schedule its next forecasted active period — a periodic
            // (diurnal) tenant then demotes *with* a return forecast, so
            // the prefetch below warms it ahead of the next burst
            if self.cfg.predictor_prefetch && !self.has_pending_forecast(id, now) {
                if let Some(at) = registry
                    .shard(id)
                    .and_then(|s| s.predictor.forecast_next_active())
                {
                    if at > now {
                        self.scheduled.push((id, at));
                    }
                }
            }
            if self.imminently_active(id, now) {
                continue;
            }
            let idle = self
                .trackers
                .get(id as usize)
                .map_or(0, |t| t.idle_ticks(now));
            if idle >= self.cfg.idle_ticks_to_demote {
                report.freed_bytes += registry.demote_tenant(id)?;
                report.demoted.push(id);
                self.idle_demotions += 1;
            }
        }

        // memory-pressure watermark: demote the least-recently-active
        // hot shard even before its idle threshold
        let limit = (self.cfg.demote_watermark_frac
            * registry.config().global_qkv_bytes as f64) as usize;
        while registry.total_qkv_used() > limit
            && registry.resident_count() > self.cfg.min_resident
        {
            let Some(victim) = self.pressure_victim(registry, now) else {
                break;
            };
            report.freed_bytes += registry.demote_tenant(victim)?;
            report.demoted.push(victim);
            self.pressure_demotions += 1;
        }

        // cold-tier disk budget: the snapshots themselves are bounded.
        // Evict oldest-first (LRU by demotion stamp) until under the
        // cap; an evicted tenant restarts empty via recreate_evicted.
        if self.cfg.cold_bytes_cap > 0 {
            while registry.cold_bytes() > self.cfg.cold_bytes_cap as u64 {
                let Some(victim) = registry.oldest_cold() else {
                    break;
                };
                registry.evict_cold(victim)?;
                report.cold_evicted.push(victim);
            }
        }

        // prefetch: start hydrating cold shards whose forecasted active
        // period is within the lead window.  A forecast whose shard is
        // still hot is kept until the burst actually starts (it goes on
        // vetoing demotion); a fired or expired forecast is dropped.
        // Under fleet-wide SLO violation every forecast is deferred —
        // hydration work (and the RAM it re-adds) would feed the very
        // overload the governor is shedding.
        if self.global_slo_pressure(registry) {
            return Ok(report);
        }
        let lead = self.cfg.prefetch_lead_ticks;
        let mut keep = Vec::new();
        for &(tenant, at_tick) in &self.scheduled {
            if at_tick > now + lead {
                keep.push((tenant, at_tick));
            } else if registry.cold_evicted(tenant) {
                // nothing on disk to warm; the forecast is moot
            } else if registry.residency(tenant) == Some(Residency::Cold) {
                report.prefetch.push(tenant);
                self.prefetches += 1;
            } else if now < at_tick {
                keep.push((tenant, at_tick));
            }
        }
        self.scheduled = keep;
        Ok(report)
    }

    /// Demotion veto: the tenant's windowed SLO miss rate is at or past
    /// the veto threshold (signals default to zero when no SLO monitor
    /// feeds the registry, so the veto is inert outside SLO arms).
    fn slo_vetoed(&self, registry: &TenantRegistry, id: TenantId) -> bool {
        registry.slo_signal(id).miss_rate >= self.cfg.slo_veto_miss_rate
    }

    /// Served-weighted fleet miss rate at or past the veto threshold:
    /// the deferral signal for prefetch hydrations.
    fn global_slo_pressure(&self, registry: &TenantRegistry) -> bool {
        let mut served = 0u64;
        let mut missed = 0.0f64;
        for id in 0..registry.len() as TenantId {
            let sig = registry.slo_signal(id);
            served += sig.window_served;
            missed += sig.miss_rate * sig.window_served as f64;
        }
        served > 0 && missed / served as f64 >= self.cfg.slo_veto_miss_rate
    }

    /// Whether a forecast for `tenant` is already scheduled in the
    /// future (the predictor re-forecasting every tick would thrash).
    fn has_pending_forecast(&self, tenant: TenantId, now: u64) -> bool {
        self.scheduled.iter().any(|&(t, at)| t == tenant && at > now)
    }

    /// Whether a forecasted active period makes demoting `tenant` now
    /// pointless (it would hydrate right back within the lead window).
    fn imminently_active(&self, tenant: TenantId, now: u64) -> bool {
        self.scheduled
            .iter()
            .any(|&(t, at)| t == tenant && at <= now + self.cfg.prefetch_lead_ticks)
    }

    /// Least-recently-active hot tenant with no queued work (and not
    /// SLO-vetoed: pressure never strips the cache of a tenant already
    /// missing its latency target).
    fn pressure_victim(&self, registry: &TenantRegistry, now: u64) -> Option<TenantId> {
        (0..registry.len() as TenantId)
            .filter(|&id| registry.residency(id) == Some(Residency::Hot))
            .filter(|&id| registry.queue_depth(id) == 0)
            .filter(|&id| !self.imminently_active(id, now))
            .filter(|&id| !self.slo_vetoed(registry, id))
            .max_by_key(|&id| self.trackers.get(id as usize).map_or(0, |t| t.idle_ticks(now)))
    }
}

// ---------------------------------------------------------------------------
// background hydration
// ---------------------------------------------------------------------------

/// Background thread rebuilding cold shards from their snapshots.
///
/// Submit a [`HydrationSpec`] (from `TenantRegistry::begin_hydration`),
/// poll for finished shards, and install them with `finish_hydration`.
/// The worker owns no registry state, so a hydration in flight never
/// blocks the serving thread's registry access.
pub struct HydrationWorker {
    tx: Option<mpsc::Sender<HydrationSpec>>,
    rx: mpsc::Receiver<(TenantId, Result<TenantShard>)>,
    handle: Option<thread::JoinHandle<()>>,
    pub submitted: u64,
    pub completed: u64,
}

impl HydrationWorker {
    pub fn spawn() -> Self {
        let (jtx, jrx) = mpsc::channel::<HydrationSpec>();
        let (rtx, rrx) = mpsc::channel();
        let handle = thread::Builder::new()
            .name("percache-hydration".into())
            .spawn(move || {
                while let Ok(spec) = jrx.recv() {
                    let tenant = spec.tenant;
                    let built = TenantShard::open_or_create_pooled(
                        spec.tenant,
                        spec.qa_bytes,
                        spec.qkv_bytes,
                        spec.utility_alpha,
                        spec.dir,
                        spec.pool,
                    );
                    if rtx.send((tenant, built)).is_err() {
                        break;
                    }
                }
            })
            // percache-allow(panic_path): thread-spawn failure at process start is unrecoverable resource exhaustion; dying loudly beats serving without a worker
            .expect("spawn hydration worker thread");
        HydrationWorker {
            tx: Some(jtx),
            rx: rrx,
            handle: Some(handle),
            submitted: 0,
            completed: 0,
        }
    }

    /// Queue one hydration; the result arrives via [`Self::poll`].
    pub fn submit(&mut self, spec: HydrationSpec) {
        self.submitted += 1;
        if let Some(tx) = &self.tx {
            let _ = tx.send(spec);
        }
    }

    /// Drain every finished hydration without blocking.
    pub fn poll(&mut self) -> Vec<(TenantId, Result<TenantShard>)> {
        let mut out = Vec::new();
        while let Ok(r) = self.rx.try_recv() {
            self.completed += 1;
            out.push(r);
        }
        out
    }

    /// Block until the next hydration finishes (shutdown drains).
    pub fn wait_one(&mut self) -> Option<(TenantId, Result<TenantShard>)> {
        match self.rx.recv() {
            Ok(r) => {
                self.completed += 1;
                Some(r)
            }
            Err(_) => None,
        }
    }

    pub fn in_flight(&self) -> u64 {
        self.submitted - self.completed
    }
}

impl Drop for HydrationWorker {
    fn drop(&mut self) {
        // closing the job channel stops the worker loop
        self.tx.take();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{TenancyConfig, TieringConfig};
    use crate::llm::QkvTensor;

    fn tmp(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "percache_tierctl_{tag}_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn tcfg(global_slices: usize) -> TenancyConfig {
        let mut tc = TenancyConfig::default();
        tc.enabled = true;
        tc.max_tenants = 8;
        tc.global_qkv_bytes = global_slices * (QkvTensor::zeros(1, 4, 64).byte_size() + 16);
        tc.tiering = TieringConfig {
            enabled: true,
            idle_ticks_to_demote: 3,
            min_resident: 1,
            ..TieringConfig::default()
        };
        tc
    }

    fn touch_tenant(reg: &mut TenantRegistry, id: TenantId) {
        let t = QkvTensor::zeros(1, 4, 64);
        reg.shard_mut(id)
            .unwrap()
            .insert_path(&[100 + id as u64, 200], vec![t.clone(), t])
            .unwrap();
    }

    #[test]
    fn idle_tenant_demotes_after_threshold_but_active_stays() {
        let dir = tmp("idle");
        let tc = tcfg(64);
        let mut reg = TenantRegistry::open_or_create(&tc, dir.clone()).unwrap();
        reg.create_tenant().unwrap();
        reg.create_tenant().unwrap();
        touch_tenant(&mut reg, 0);
        touch_tenant(&mut reg, 1);
        let mut ctl = TieringController::new(tc.tiering.clone(), 2);
        for tick in 0..4 {
            ctl.note_request(0); // tenant 0 stays active, tenant 1 idles
            let rep = ctl.tick(&mut reg).unwrap();
            if tick < 2 {
                assert!(rep.demoted.is_empty(), "tick {tick}: too early");
            }
        }
        assert_eq!(reg.residency(0), Some(Residency::Hot));
        assert_eq!(reg.residency(1), Some(Residency::Cold));
        assert_eq!(ctl.idle_demotions, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn queued_work_vetoes_demotion() {
        let dir = tmp("queued");
        let tc = tcfg(64);
        let mut reg = TenantRegistry::open_or_create(&tc, dir.clone()).unwrap();
        reg.create_tenant().unwrap();
        reg.create_tenant().unwrap();
        // tenant 1 never sends a request but has a backlog queued
        reg.set_queue_depths(&[0, 4]);
        let mut ctl = TieringController::new(tc.tiering.clone(), 2);
        for _ in 0..6 {
            ctl.note_request(0);
            ctl.tick(&mut reg).unwrap();
        }
        assert_eq!(
            reg.residency(1),
            Some(Residency::Hot),
            "backlogged tenants must never demote"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn watermark_pressure_demotes_least_recently_active() {
        let dir = tmp("pressure");
        let mut tc = tcfg(8); // tiny global budget
        tc.tiering.idle_ticks_to_demote = 1000; // idle path disabled
        tc.tiering.demote_watermark_frac = 0.25;
        let mut reg = TenantRegistry::open_or_create(&tc, dir.clone()).unwrap();
        for _ in 0..3 {
            reg.create_tenant().unwrap();
        }
        let mut ctl = TieringController::new(tc.tiering.clone(), 3);
        // establish a distinct last-touch order while nothing is cached
        // yet (no bytes → no pressure): 0 is the stalest, 2 the freshest
        for id in 0..3u32 {
            ctl.note_request(id);
        }
        ctl.tick(&mut reg).unwrap();
        ctl.note_request(1);
        ctl.note_request(2);
        ctl.tick(&mut reg).unwrap();
        ctl.note_request(2);
        // now trip the watermark: 6 cached slices against a 2-slice limit
        for id in 0..3 {
            touch_tenant(&mut reg, id);
        }
        let rep = ctl.tick(&mut reg).unwrap();
        assert_eq!(
            rep.demoted,
            vec![0, 1],
            "stalest tenants must go first, down to the watermark"
        );
        assert_eq!(ctl.pressure_demotions, 2);
        assert!(rep.freed_bytes > 0);
        assert_eq!(reg.residency(2), Some(Residency::Hot), "freshest survives");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn disabled_controller_never_demotes() {
        let dir = tmp("disabled");
        let mut tc = tcfg(64);
        tc.tiering.enabled = false;
        let mut reg = TenantRegistry::open_or_create(&tc, dir.clone()).unwrap();
        reg.create_tenant().unwrap();
        reg.create_tenant().unwrap();
        let mut ctl = TieringController::new(tc.tiering.clone(), 2);
        for _ in 0..10 {
            let rep = ctl.tick(&mut reg).unwrap();
            assert!(rep.demoted.is_empty());
        }
        assert_eq!(reg.resident_count(), 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn prefetch_fires_within_lead_and_skips_demotion() {
        let dir = tmp("prefetch");
        let mut tc = tcfg(64);
        tc.tiering.prefetch_lead_ticks = 2;
        let mut reg = TenantRegistry::open_or_create(&tc, dir.clone()).unwrap();
        reg.create_tenant().unwrap();
        reg.create_tenant().unwrap();
        touch_tenant(&mut reg, 1);
        let mut ctl = TieringController::new(tc.tiering.clone(), 2);
        // let tenant 1 go cold
        for _ in 0..4 {
            ctl.note_request(0);
            ctl.tick(&mut reg).unwrap();
        }
        assert_eq!(reg.residency(1), Some(Residency::Cold));
        // forecast: tenant 1 active at tick 8 → prefetch fires at 8-2=6
        ctl.schedule_active(1, 8);
        ctl.note_request(0);
        let rep = ctl.tick(&mut reg).unwrap(); // tick 5
        assert!(rep.prefetch.is_empty(), "tick {} too early", rep.tick);
        ctl.note_request(0);
        let rep = ctl.tick(&mut reg).unwrap(); // tick 6 = 8 - lead
        assert_eq!(rep.prefetch, vec![1]);
        assert_eq!(ctl.prefetches, 1);
        // the caller hydrates; the shard is warm before its burst
        reg.hydrate_tenant(1).unwrap();
        assert_eq!(reg.residency(1), Some(Residency::Hot));
        // an imminent forecast also vetoes demotion of a hot shard
        ctl.schedule_active(1, 9);
        ctl.note_request(0);
        let rep = ctl.tick(&mut reg).unwrap(); // tick 7: 1 idle but imminent
        assert!(
            !rep.demoted.contains(&1),
            "imminently-active shard must not demote"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn cold_budget_evicts_oldest_and_blocks_hydration() {
        let dir = tmp("cold_budget");
        let mut tc = tcfg(64);
        tc.tiering.idle_ticks_to_demote = 1000; // only the budget acts
        let mut reg = TenantRegistry::open_or_create(&tc, dir.clone()).unwrap();
        for _ in 0..3 {
            reg.create_tenant().unwrap();
        }
        for id in 0..3 {
            touch_tenant(&mut reg, id);
        }
        // tenant 1 demoted first: the oldest snapshot, the LRU victim
        reg.demote_tenant(1).unwrap();
        reg.demote_tenant(2).unwrap();
        let total = reg.cold_bytes();
        assert!(total > 0);
        // cap admits one snapshot but not both
        tc.tiering.cold_bytes_cap = (total - 1) as usize;
        let mut ctl = TieringController::new(tc.tiering.clone(), 3);
        ctl.note_request(0);
        let rep = ctl.tick(&mut reg).unwrap();
        assert_eq!(rep.cold_evicted, vec![1], "oldest cold snapshot goes first");
        assert_eq!(reg.oldest_cold(), Some(2), "newer snapshot survives");
        assert!(reg.cold_bytes() <= tc.tiering.cold_bytes_cap as u64);

        // the evicted tenant's hydration fails loudly; the survivor's works
        let err = reg.hydrate_tenant(1).unwrap_err().to_string();
        assert!(err.contains("evicted"), "loud failure, got: {err}");
        reg.hydrate_tenant(2).unwrap();
        assert_eq!(reg.residency(2), Some(Residency::Hot));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn slo_violation_vetoes_demotion_and_defers_prefetch() {
        use crate::tenancy::SloSignal;
        let dir = tmp("slo_veto");
        let mut tc = tcfg(64);
        tc.tiering.prefetch_lead_ticks = 2;
        let mut reg = TenantRegistry::open_or_create(&tc, dir.clone()).unwrap();
        reg.create_tenant().unwrap();
        reg.create_tenant().unwrap();
        touch_tenant(&mut reg, 1);
        let violating = SloSignal {
            miss_rate: 0.9,
            queue_delay_ms: 50.0,
            target_ms: 20.0,
            window_served: 16,
        };
        // tenant 1 idles but is missing its SLO: demotion is vetoed
        reg.set_slo_signals(&[SloSignal::default(), violating]);
        let mut ctl = TieringController::new(tc.tiering.clone(), 2);
        for _ in 0..6 {
            ctl.note_request(0);
            ctl.tick(&mut reg).unwrap();
        }
        assert_eq!(
            reg.residency(1),
            Some(Residency::Hot),
            "SLO-missing tenants keep their warm cache"
        );
        // signal clears: the same idleness now demotes
        reg.set_slo_signals(&[SloSignal::default(), SloSignal::default()]);
        for _ in 0..4 {
            ctl.note_request(0);
            ctl.tick(&mut reg).unwrap();
        }
        assert_eq!(reg.residency(1), Some(Residency::Cold));

        // fleet-wide violation defers prefetch hydration entirely
        reg.set_slo_signals(&[violating, SloSignal::default()]);
        ctl.schedule_active(1, ctl.tick_count() + 1);
        ctl.note_request(0);
        let rep = ctl.tick(&mut reg).unwrap();
        assert!(
            rep.prefetch.is_empty(),
            "prefetch must defer under fleet-wide SLO pressure"
        );
        reg.set_slo_signals(&[SloSignal::default(), SloSignal::default()]);
        ctl.note_request(0);
        let rep = ctl.tick(&mut reg).unwrap();
        assert_eq!(rep.prefetch, vec![1], "deferred forecast fires once clear");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn predictor_periodicity_feeds_prefetch() {
        let dir = tmp("pred_prefetch");
        let mut tc = tcfg(64);
        tc.tiering.prefetch_lead_ticks = 2;
        let mut reg = TenantRegistry::open_or_create(&tc, dir.clone()).unwrap();
        reg.create_tenant().unwrap();
        reg.create_tenant().unwrap();
        touch_tenant(&mut reg, 1);
        // tenant 1's predictor saw three bursts, period 12 → next at 36
        for start in [0u64, 12, 24] {
            for off in 0..3 {
                reg.shard_mut(1).unwrap().predictor.observe_arrival(start + off);
            }
        }
        let mut ctl = TieringController::new(tc.tiering.clone(), 2);
        let mut prefetched_at = None;
        for _ in 0..40 {
            ctl.note_request(0);
            let rep = ctl.tick(&mut reg).unwrap();
            if rep.prefetch.contains(&1) {
                prefetched_at = Some(rep.tick);
                reg.hydrate_tenant(1).unwrap();
                break;
            }
        }
        assert_eq!(
            prefetched_at,
            Some(34),
            "forecast 36 minus lead 2: hydration starts at tick 34"
        );
        assert_eq!(
            reg.residency(1),
            Some(Residency::Hot),
            "shard is warm before its forecasted burst"
        );
        assert!(ctl.prefetches >= 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn hydration_worker_rebuilds_in_background() {
        let dir = tmp("worker");
        let tc = tcfg(64);
        let mut reg = TenantRegistry::open_or_create(&tc, dir.clone()).unwrap();
        reg.create_tenant().unwrap();
        reg.create_tenant().unwrap();
        touch_tenant(&mut reg, 1);
        reg.demote_tenant(1).unwrap();

        let mut worker = HydrationWorker::spawn();
        let spec = reg.begin_hydration(1).unwrap();
        worker.submit(spec);
        assert_eq!(worker.in_flight(), 1);
        let (tenant, shard) = worker.wait_one().expect("worker must deliver");
        assert_eq!(tenant, 1);
        reg.finish_hydration(1, shard.unwrap()).unwrap();
        assert_eq!(reg.residency(1), Some(Residency::Hot));
        assert_eq!(
            reg.shard_mut(1).unwrap().prefix_match(&[101, 200]).len(),
            2,
            "hydrated shard serves its cached path"
        );
        assert_eq!(worker.in_flight(), 0);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
