//! Mini property-testing framework (no `proptest` in the vendored set).
//!
//! Seeded generation + first-failure reporting.  Used by the coordinator
//! invariants suite (rust/tests/properties.rs) and module unit tests.
//!
//! ```ignore
//! forall(200, |rng| rng.range(0, 100), |&n| {
//!     check(n < 100, format!("n={n} out of range"))
//! });
//! ```

use crate::util::rng::Rng;

pub type PropResult = Result<(), String>;

/// Run `cases` property checks over generated inputs.  On failure, panics
/// with the case index, the generating seed and the debug form of the
/// input — enough to replay deterministically.
pub fn forall<T: std::fmt::Debug>(
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    prop: impl Fn(&T) -> PropResult,
) {
    let base_seed = std::env::var("PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE_u64);
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64);
        let mut rng = Rng::new(seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property failed at case {case}/{cases} (seed {seed}):\n  \
                 input: {input:?}\n  reason: {msg}\n  \
                 replay with PROP_SEED={base_seed}"
            );
        }
    }
}

/// Assertion helper returning PropResult.
pub fn check(cond: bool, msg: impl Into<String>) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Approximate float equality helper.
pub fn check_close(a: f64, b: f64, tol: f64, what: &str) -> PropResult {
    check(
        (a - b).abs() <= tol,
        format!("{what}: {a} vs {b} (tol {tol})"),
    )
}

// ---------------------------------------------------------------------------
// Common generators
// ---------------------------------------------------------------------------

const WORDS: &[&str] = &[
    "budget", "meeting", "review", "thursday", "launch", "product", "email",
    "schedule", "report", "quarterly", "deadline", "project", "team", "room",
    "rehearsal", "presentation", "invoice", "travel", "flight", "dinner",
    "doctor", "appointment", "contract", "client", "design", "metrics",
];

/// Random word from a small realistic vocabulary.
pub fn gen_word(rng: &mut Rng) -> String {
    (*rng.pick(WORDS)).to_string()
}

/// Random sentence of `lo..=hi` vocabulary words.
pub fn gen_sentence(rng: &mut Rng, lo: usize, hi: usize) -> String {
    let n = rng.range(lo, hi);
    (0..n).map(|_| gen_word(rng)).collect::<Vec<_>>().join(" ")
}

/// Random unit-ish embedding vector (not normalized).
pub fn gen_vec(rng: &mut Rng, dim: usize) -> Vec<f32> {
    (0..dim).map(|_| rng.normal() as f32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivially() {
        forall(50, |rng| rng.range(1, 10), |&n| check(n >= 1 && n <= 10, "range"));
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn forall_reports_failure() {
        forall(50, |rng| rng.range(0, 100), |&n| check(n < 90, format!("n={n}")));
    }

    #[test]
    fn generators_deterministic_per_seed() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(1);
        assert_eq!(gen_sentence(&mut a, 3, 8), gen_sentence(&mut b, 3, 8));
    }

    #[test]
    fn check_close_tolerance() {
        assert!(check_close(1.0, 1.0005, 1e-3, "x").is_ok());
        assert!(check_close(1.0, 1.1, 1e-3, "x").is_err());
    }
}
