//! The cache scheduler (paper §4.3): elastic use of compute and storage.
//!
//! Three mechanisms, all driven from here and executed by the engine's
//! idle path:
//!
//! 1. **Adaptive population** (§4.3.2) — when τ_query > τ_scheduler,
//!    QA-bank hits are unlikely, so populating answers (decoding) wastes
//!    compute; the scheduler switches population to prefill-only.
//! 2. **QKV→QA conversion** (§4.3.3) — when τ_query drops below the
//!    cutoff, previously-undecoded QA entries become valuable; decode
//!    them during idle time.
//! 3. **QA→QKV conversion** (§4.3.3) — when QKV storage is relaxed,
//!    re-prefill QA-bank queries whose tree slices were evicted, restoring
//!    prefix-match coverage.

/// What population does for a predicted/new query.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PopulationStrategy {
    /// Strategy 1: prefill only — populate the QKV tree and store the
    /// query in the QA bank *without* an answer.
    PrefillOnly,
    /// Strategy 2: prefill + decode — populate both layers fully.
    PrefillAndDecode,
}

/// Idle-time work items the scheduler can emit.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum IdleAction {
    /// Run query prediction and populate with the current strategy.
    PredictAndPopulate,
    /// Decode QA entries that lack answers (QKV→QA conversion).
    DecodePending,
    /// Re-prefill QA queries to restore evicted QKV slices (QA→QKV).
    RestoreQkv,
}

#[derive(Debug, Clone)]
pub struct CacheScheduler {
    pub enabled: bool,
    pub tau_cutoff: f64,
    /// Latched τ_query (updated by the engine when config changes).
    tau_query: f64,
    /// Set when τ_query crossed downward since the last idle tick.
    tau_dropped: bool,
    /// Set when the QKV storage budget grew since the last idle tick.
    storage_grew: bool,
}

impl CacheScheduler {
    pub fn new(enabled: bool, tau_cutoff: f64, tau_query: f64) -> Self {
        CacheScheduler {
            enabled,
            tau_cutoff,
            tau_query,
            tau_dropped: false,
            storage_grew: false,
        }
    }

    /// Current population strategy (paper Fig 10's switch).
    pub fn strategy(&self) -> PopulationStrategy {
        if self.enabled && self.tau_query > self.tau_cutoff {
            PopulationStrategy::PrefillOnly
        } else {
            PopulationStrategy::PrefillAndDecode
        }
    }

    /// Notify a τ_query change; detects downward crossings of the cutoff.
    pub fn on_tau_change(&mut self, new_tau: f64) {
        let was_above = self.tau_query > self.tau_cutoff;
        let now_above = new_tau > self.tau_cutoff;
        if was_above && !now_above {
            self.tau_dropped = true;
        }
        self.tau_query = new_tau;
    }

    /// Notify a QKV storage-budget change.
    pub fn on_storage_change(&mut self, old_bytes: usize, new_bytes: usize) {
        if new_bytes > old_bytes {
            self.storage_grew = true;
        }
    }

    /// Plan the next idle tick's actions (consumes the latched events).
    /// Prediction always runs; conversions run when their trigger fired.
    pub fn plan_idle(&mut self) -> Vec<IdleAction> {
        let mut actions = vec![IdleAction::PredictAndPopulate];
        if !self.enabled {
            return actions;
        }
        if self.tau_dropped
            || self.strategy() == PopulationStrategy::PrefillAndDecode && self.tau_dropped
        {
            actions.push(IdleAction::DecodePending);
        }
        // Even without an explicit drop event, decode pending entries when
        // the current strategy wants answers (keeps the bank converging
        // after a period of prefill-only population).
        if self.strategy() == PopulationStrategy::PrefillAndDecode
            && !actions.contains(&IdleAction::DecodePending)
        {
            actions.push(IdleAction::DecodePending);
        }
        if self.storage_grew {
            actions.push(IdleAction::RestoreQkv);
        }
        self.tau_dropped = false;
        self.storage_grew = false;
        actions
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strategy_switches_at_cutoff() {
        let mut s = CacheScheduler::new(true, 0.87, 0.85);
        assert_eq!(s.strategy(), PopulationStrategy::PrefillAndDecode);
        s.on_tau_change(0.90);
        assert_eq!(s.strategy(), PopulationStrategy::PrefillOnly);
        s.on_tau_change(0.85);
        assert_eq!(s.strategy(), PopulationStrategy::PrefillAndDecode);
    }

    #[test]
    fn disabled_scheduler_always_decodes() {
        let mut s = CacheScheduler::new(false, 0.87, 0.95);
        assert_eq!(s.strategy(), PopulationStrategy::PrefillAndDecode);
        let plan = s.plan_idle();
        assert_eq!(plan, vec![IdleAction::PredictAndPopulate]);
    }

    #[test]
    fn tau_drop_triggers_decode_pending() {
        let mut s = CacheScheduler::new(true, 0.87, 0.90);
        assert_eq!(s.strategy(), PopulationStrategy::PrefillOnly);
        let plan = s.plan_idle();
        assert!(!plan.contains(&IdleAction::DecodePending), "{plan:?}");

        s.on_tau_change(0.85); // crosses downward
        let plan = s.plan_idle();
        assert!(plan.contains(&IdleAction::DecodePending));
        // event is consumed but strategy still wants decoding
        let plan2 = s.plan_idle();
        assert!(plan2.contains(&IdleAction::DecodePending));
    }

    #[test]
    fn storage_growth_triggers_restore_once() {
        let mut s = CacheScheduler::new(true, 0.87, 0.90);
        s.on_storage_change(6 << 20, 8 << 20);
        let plan = s.plan_idle();
        assert!(plan.contains(&IdleAction::RestoreQkv));
        let plan2 = s.plan_idle();
        assert!(!plan2.contains(&IdleAction::RestoreQkv), "latched event consumed");
    }

    #[test]
    fn storage_shrink_does_not_restore() {
        let mut s = CacheScheduler::new(true, 0.87, 0.90);
        s.on_storage_change(8 << 20, 6 << 20);
        assert!(!s.plan_idle().contains(&IdleAction::RestoreQkv));
    }
}
