//! Seeded load traces for the scenario harness (`percache exp
//! scenarios`, DESIGN.md §14): deterministic multi-tenant arrival
//! streams with per-tenant SLO targets, shaped after the load patterns
//! the paper's third claim ("adapt configurations to dynamic system
//! loads") has to survive.
//!
//! Four scenarios:
//!
//! * **diurnal** — each tenant wakes periodically (phase-offset active
//!   windows), the pattern the per-tenant `QueryPredictor` can learn and
//!   the tiering prefetch hook can warm shards ahead of;
//! * **bursty** — a background trickle punctured by flash crowds: one
//!   tenant's arrival rate jumps far past serving capacity for a few
//!   ticks, with cache-busting unique queries;
//! * **churn** — tenants arrive, live for a window, and leave; each
//!   entry opens with an onboarding flood of cold queries (exercises the
//!   cold tier and its disk budget);
//! * **adversarial** — sustained overload of unique queries on unique
//!   segment paths across every tenant: zero cache reuse, every SLO
//!   signal saturates.  Used to pin that admission sheds load before
//!   the governor thrashes allocations.
//!
//! Everything is derived from `TraceSpec.seed` through `util::rng::Rng`
//! — same seed, same trace, byte for byte.  Time is virtual: a trace is
//! `ticks` scheduling rounds, each `tick_ms` modeled milliseconds wide;
//! the replay in `exp::scenarios_exp` serves against the same modeled
//! clock, so latencies and SLO misses are reproducible across machines.

use anyhow::Result;

use crate::tenancy::sim::{Arrival, SimConfig};
use crate::tenancy::TenantId;
use crate::tokenizer::{fnv1a64, SEGMENT_TOKENS};
use crate::util::rng::Rng;

/// Scenario names, in report order.
pub const SCENARIOS: [&str; 4] = ["diurnal", "bursty", "churn", "adversarial"];

/// Requests at full modeled cost one tick can serve: the capacity the
/// rates below are calibrated against (`tick_ms = CAPACITY_PER_TICK ×`
/// the modeled full-serve latency).
pub const CAPACITY_PER_TICK: usize = 8;

/// Queries reused per tenant outside floods (repeats hit the QA bank).
const TOPICS: usize = 2;
const VARIANTS: usize = 3;

/// Trace shape (full vs `--smoke`).
#[derive(Debug, Clone, Copy)]
pub struct TraceSpec {
    pub tenants: usize,
    pub ticks: usize,
    pub seed: u64,
}

impl TraceSpec {
    pub fn full(seed: u64) -> Self {
        TraceSpec {
            tenants: 6,
            ticks: 240,
            seed,
        }
    }

    pub fn smoke(seed: u64) -> Self {
        TraceSpec {
            tenants: 4,
            ticks: 96,
            seed,
        }
    }
}

/// One scenario: per-tick arrival batches plus per-tenant p99 SLO
/// targets in modeled milliseconds.
#[derive(Debug, Clone)]
pub struct ScenarioTrace {
    pub name: String,
    pub tenants: usize,
    /// Modeled wall-width of one scheduling tick, ms.
    pub tick_ms: f64,
    /// `ticks[t]` = the arrivals stamped at tick `t`'s start.
    pub ticks: Vec<Vec<Arrival>>,
    /// Per-tenant p99 end-to-end SLO bound, modeled ms.
    pub slo_p99_ms: Vec<f64>,
    pub seed: u64,
}

impl ScenarioTrace {
    pub fn n_ticks(&self) -> usize {
        self.ticks.len()
    }

    pub fn total_arrivals(&self) -> usize {
        self.ticks.iter().map(|t| t.len()).sum()
    }
}

/// Modeled latency of one full-cost serve (4-segment prefill + decode)
/// under the default sim cost model — the unit every rate, tick width
/// and SLO target in this module is calibrated in.
pub fn modeled_full_serve_ms() -> f64 {
    let cfg = SimConfig::default();
    let s_tokens = 4 * SEGMENT_TOKENS;
    let flops =
        cfg.dims.prefill_full(s_tokens) + cfg.decode_tokens as u64 * cfg.dims.decode_step(s_tokens);
    flops as f64 / (cfg.gflops * 1e6)
}

/// Tick width: the modeled budget for [`CAPACITY_PER_TICK`] full serves.
pub fn tick_width_ms() -> f64 {
    CAPACITY_PER_TICK as f64 * modeled_full_serve_ms()
}

/// Per-tenant SLO targets: one tick of queueing headroom, with tenant 0
/// a premium tenant holding a tighter bound.
fn slo_targets(tenants: usize) -> Vec<f64> {
    let base = tick_width_ms();
    (0..tenants)
        .map(|t| if t == 0 { base * 0.75 } else { base })
        .collect()
}

/// A reusable pool query: verbatim repeats land in the QA bank, same
/// topic shares a cached 2-chunk segment path.
fn pool_arrival(tenant: TenantId, i: usize) -> Arrival {
    let topic = i % TOPICS;
    let variant = (i / TOPICS) % VARIANTS;
    let q = format!("tenant{tenant:02} topic{topic} phrasing{variant} daily digest request");
    let tag = |part: &str| fnv1a64(format!("t{tenant}/topic{topic}/{part}").as_bytes());
    Arrival {
        seg_keys: vec![fnv1a64(b"sys"), tag("a"), tag("b"), fnv1a64(q.as_bytes())],
        tenant,
        query: q,
        shared: Vec::new(),
    }
}

/// A cache-busting query: unique text on a unique segment path, so
/// neither the QA bank nor the QKV tree can help.
fn unique_arrival(tenant: TenantId, uid: u64) -> Arrival {
    let q = format!("tenant{tenant:02} novel{uid:08} audit trail lookup item{uid}");
    let tag = |part: &str| fnv1a64(format!("t{tenant}/u{uid}/{part}").as_bytes());
    Arrival {
        seg_keys: vec![fnv1a64(b"sys"), tag("a"), tag("b"), fnv1a64(q.as_bytes())],
        tenant,
        query: q,
        shared: Vec::new(),
    }
}

/// Phase-offset periodic active windows; period and duty cycle derived
/// from the spec so a smoke trace still covers 4 full cycles.
pub fn diurnal(spec: &TraceSpec) -> ScenarioTrace {
    let period = (spec.ticks / 4).max(8);
    let duty = (period / 4).max(2);
    let mut seq = vec![0usize; spec.tenants];
    let mut ticks = Vec::with_capacity(spec.ticks);
    for tick in 0..spec.ticks {
        let mut batch = Vec::new();
        for t in 0..spec.tenants {
            let phase = (t * period / spec.tenants) % period;
            let pos = (tick + period - phase) % period;
            if pos < duty {
                // active window: a moderate 4/tick, well under capacity
                for _ in 0..4 {
                    batch.push(pool_arrival(t as TenantId, seq[t]));
                    seq[t] += 1;
                }
            }
        }
        ticks.push(batch);
    }
    ScenarioTrace {
        name: "diurnal".into(),
        tenants: spec.tenants,
        tick_ms: tick_width_ms(),
        ticks,
        slo_p99_ms: slo_targets(spec.tenants),
        seed: spec.seed,
    }
}

/// Background trickle + flash crowds: every quarter of the trace one
/// tenant's rate jumps to ~4× capacity for a few ticks, with unique
/// queries so the crowd cannot be served from cache.
pub fn bursty(spec: &TraceSpec) -> ScenarioTrace {
    let mut rng = Rng::new(spec.seed ^ 0xB0657);
    let crowd_len = 6usize.min(spec.ticks / 8).max(3);
    let crowd_gap = (spec.ticks / 4).max(crowd_len * 2);
    let crowd_rate = CAPACITY_PER_TICK * 4;
    // pick each crowd's victim tenant up front (deterministic from seed)
    let crowds: Vec<(usize, TenantId)> = (0..spec.ticks / crowd_gap)
        .map(|k| {
            let start = k * crowd_gap + crowd_gap / 3 + rng.below(3);
            (start, rng.below(spec.tenants) as TenantId)
        })
        .collect();
    let mut seq = vec![0usize; spec.tenants];
    let mut uid = 0u64;
    let mut ticks = Vec::with_capacity(spec.ticks);
    for tick in 0..spec.ticks {
        let mut batch = Vec::new();
        // trickle: each tenant one pool query every other tick
        for t in 0..spec.tenants {
            if (tick + t) % 2 == 0 {
                batch.push(pool_arrival(t as TenantId, seq[t]));
                seq[t] += 1;
            }
        }
        for &(start, victim) in &crowds {
            if tick >= start && tick < start + crowd_len {
                for _ in 0..crowd_rate {
                    batch.push(unique_arrival(victim, uid));
                    uid += 1;
                }
            }
        }
        ticks.push(batch);
    }
    ScenarioTrace {
        name: "bursty".into(),
        tenants: spec.tenants,
        tick_ms: tick_width_ms(),
        ticks,
        slo_p99_ms: slo_targets(spec.tenants),
        seed: spec.seed,
    }
}

/// Sliding tenant population: tenant `t` is live for a two-stride
/// window starting at `t × stride`, opening with an onboarding flood of
/// cold queries, then a steady pool rate.  Departed tenants idle out to
/// the cold tier, growing it monotonically — the disk-budget workload.
pub fn churn(spec: &TraceSpec) -> ScenarioTrace {
    let stride = (spec.ticks / spec.tenants).max(4);
    let life = stride * 2;
    let flood_ticks = 3usize;
    let flood_rate = 12usize;
    let mut seq = vec![0usize; spec.tenants];
    let mut uid = 0u64;
    let mut ticks = Vec::with_capacity(spec.ticks);
    for tick in 0..spec.ticks {
        let mut batch = Vec::new();
        for t in 0..spec.tenants {
            let entry = t * stride;
            if tick < entry || tick >= entry + life {
                continue;
            }
            if tick - entry < flood_ticks {
                // onboarding flood: cold, unique, far above fair share
                for _ in 0..flood_rate {
                    batch.push(unique_arrival(t as TenantId, uid));
                    uid += 1;
                }
            } else {
                for _ in 0..3 {
                    batch.push(pool_arrival(t as TenantId, seq[t]));
                    seq[t] += 1;
                }
            }
        }
        ticks.push(batch);
    }
    ScenarioTrace {
        name: "churn".into(),
        tenants: spec.tenants,
        tick_ms: tick_width_ms(),
        ticks,
        slo_p99_ms: slo_targets(spec.tenants),
        seed: spec.seed,
    }
}

/// Sustained cache-thrashing overload: every tick carries 1.5× capacity
/// of unique queries spread round-robin across all tenants.  Nothing
/// hits, every tenant's SLO signal saturates, and the only defenses are
/// admission shedding and a governor that does not thrash.
pub fn adversarial(spec: &TraceSpec) -> ScenarioTrace {
    let rate = CAPACITY_PER_TICK * 3 / 2;
    let mut uid = 0u64;
    let mut ticks = Vec::with_capacity(spec.ticks);
    for tick in 0..spec.ticks {
        let mut batch = Vec::with_capacity(rate);
        for i in 0..rate {
            let t = ((tick * rate + i) % spec.tenants) as TenantId;
            batch.push(unique_arrival(t, uid));
            uid += 1;
        }
        ticks.push(batch);
    }
    ScenarioTrace {
        name: "adversarial".into(),
        tenants: spec.tenants,
        tick_ms: tick_width_ms(),
        ticks,
        slo_p99_ms: slo_targets(spec.tenants),
        seed: spec.seed,
    }
}

/// Build one scenario by name.
pub fn scenario(name: &str, spec: &TraceSpec) -> Result<ScenarioTrace> {
    match name {
        "diurnal" => Ok(diurnal(spec)),
        "bursty" => Ok(bursty(spec)),
        "churn" => Ok(churn(spec)),
        "adversarial" => Ok(adversarial(spec)),
        other => anyhow::bail!("unknown scenario '{other}' (have {SCENARIOS:?})"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SEED: u64 = 0x5CE7A710;

    #[test]
    fn traces_are_seed_deterministic() {
        for name in SCENARIOS {
            let spec = TraceSpec::smoke(SEED);
            let a = scenario(name, &spec).unwrap();
            let b = scenario(name, &spec).unwrap();
            assert_eq!(a.n_ticks(), b.n_ticks(), "{name}");
            for (x, y) in a.ticks.iter().flatten().zip(b.ticks.iter().flatten()) {
                assert_eq!(x.tenant, y.tenant, "{name}");
                assert_eq!(x.query, y.query, "{name}");
                assert_eq!(x.seg_keys, y.seg_keys, "{name}");
            }
            assert_eq!(a.slo_p99_ms, b.slo_p99_ms, "{name}");
        }
    }

    #[test]
    fn every_scenario_has_arrivals_for_every_tenant() {
        for name in SCENARIOS {
            let spec = TraceSpec::smoke(SEED);
            let tr = scenario(name, &spec).unwrap();
            assert_eq!(tr.n_ticks(), spec.ticks);
            assert_eq!(tr.slo_p99_ms.len(), spec.tenants);
            for t in 0..spec.tenants {
                assert!(
                    tr.ticks.iter().flatten().any(|a| a.tenant == t as TenantId),
                    "{name}: tenant {t} never arrives"
                );
            }
        }
    }

    #[test]
    fn bursty_peaks_exceed_capacity_and_diurnal_does_not() {
        let spec = TraceSpec::smoke(SEED);
        let b = bursty(&spec);
        let peak = b.ticks.iter().map(|t| t.len()).max().unwrap_or(0);
        assert!(
            peak > CAPACITY_PER_TICK * 2,
            "flash crowd must exceed capacity: peak {peak}"
        );
        let d = diurnal(&spec);
        // diurnal windows overlap at most briefly; total stays moderate
        assert!(d.total_arrivals() > 0);
    }

    #[test]
    fn adversarial_queries_never_repeat() {
        let spec = TraceSpec::smoke(SEED);
        let tr = adversarial(&spec);
        let mut seen = std::collections::HashSet::new();
        for a in tr.ticks.iter().flatten() {
            assert!(seen.insert(a.query.clone()), "repeat: {}", a.query);
        }
    }

    #[test]
    fn premium_tenant_has_the_tighter_slo() {
        let spec = TraceSpec::smoke(SEED);
        let tr = churn(&spec);
        assert!(tr.slo_p99_ms[0] < tr.slo_p99_ms[1]);
    }
}
