//! Synthetic dataset generators standing in for the paper's four QA
//! datasets (MISeD, EnronQA, self-collected Email and Dialog; 20 users,
//! ~275 queries total).
//!
//! Substitution contract (DESIGN.md §3): the evaluation consumes the
//! datasets only through three structural properties, all of which the
//! generators control and the fig2/3/5/6 harnesses verify:
//!
//! 1. **similar query pairs exist** (Fig 2) — paraphrase pairs share
//!    content words ⇒ high embedding cosine;
//! 2. **chunk retrieval repeats** (Fig 3) — several queries target each
//!    topic, and the email family is densest, like the paper's Email user
//!    whose every chunk was retrieved more than once;
//! 3. **queries are sparse/varied in sequence** (Fig 6) — consecutive
//!    queries switch topics, so reactive caches populate slowly.
//!
//! Queries use the same template families as predict:: — both model
//! "questions users ask about personal data", which is precisely why the
//! paper's knowledge-based prediction works.

use crate::predict::{DETAIL_TEMPLATES, GENERAL_TEMPLATES};
use crate::util::rng::Rng;

pub mod traces;

pub const DATASETS: [&str; 4] = ["mised", "enronqa", "email", "dialog"];
pub const USERS_PER_DATASET: usize = 5;

#[derive(Debug, Clone)]
pub struct QueryCase {
    pub text: String,
    /// Generator ground-truth answer (English; used for realism and
    /// retrieval checks — quality metrics use self-consistency vs the
    /// naive baseline, see EXPERIMENTS.md).
    pub gold_answer: String,
    /// Topic index, for retrieval-overlap analyses.
    pub topic: usize,
    /// Paraphrase-pair id: queries sharing one are near-duplicates.
    pub paraphrase_of: Option<usize>,
}

#[derive(Debug, Clone)]
pub struct UserData {
    pub dataset: String,
    pub user: usize,
    pub documents: Vec<String>,
    pub queries: Vec<QueryCase>,
}

struct Family {
    subjects: &'static [&'static str],
    objects: &'static [&'static str],
    people: &'static [&'static str],
    places: &'static [&'static str],
    filler: &'static [&'static str],
    /// topic count range (fewer topics ⇒ denser chunk reuse)
    topics: (usize, usize),
    /// queries per user range
    queries: (usize, usize),
}

fn family(dataset: &str) -> Family {
    match dataset {
        "mised" => Family {
            subjects: &["budget", "roadmap", "sprint", "design", "hiring", "metrics"],
            objects: &["review", "planning", "standup", "retrospective", "sync", "workshop"],
            people: &["sarah", "james", "priya", "miguel", "elena"],
            places: &["room alpha", "room beta", "the boardroom", "the annex"],
            filler: &[
                "the team walked through the agenda and raised open issues",
                "action items were assigned and the notes were circulated",
                "several stakeholders joined remotely to discuss progress",
                "the discussion covered risks dependencies and timelines",
            ],
            topics: (4, 6),
            queries: (10, 14),
        },
        "enronqa" => Family {
            subjects: &["contract", "invoice", "settlement", "pipeline", "forecast", "audit"],
            objects: &["approval", "renewal", "dispute", "summary", "deadline", "transfer"],
            people: &["ken", "louise", "rebecca", "jeff", "andrew"],
            places: &["houston office", "legal department", "trading floor", "finance desk"],
            filler: &[
                "please see the attached document for the full details",
                "forwarding the earlier thread for your records and reply",
                "let me know if the terms look acceptable before friday",
                "the counterparty requested a revised schedule this week",
            ],
            topics: (3, 4), // densest: every chunk gets re-retrieved
            queries: (11, 15),
        },
        "email" => Family {
            subjects: &["flight", "hotel", "rent", "insurance", "subscription", "package"],
            objects: &["booking", "payment", "confirmation", "renewal", "delivery", "refund"],
            people: &["mom", "alex", "the landlord", "support", "dr chen"],
            places: &["the airport", "downtown", "the clinic", "the apartment"],
            filler: &[
                "thank you for your purchase your reference number is enclosed",
                "this is an automated message please do not reply directly",
                "your statement is now available in the customer portal",
                "we look forward to seeing you please arrive fifteen minutes early",
            ],
            topics: (3, 5),
            queries: (10, 14),
        },
        "dialog" => Family {
            subjects: &["dinner", "gym", "groceries", "movie", "birthday", "weekend"],
            objects: &["plan", "session", "list", "night", "party", "trip"],
            people: &["sam", "taylor", "jordan", "casey", "robin"],
            places: &["the new place on main street", "the park", "home", "the mall"],
            filler: &[
                "yeah that sounds good let us figure out the timing later",
                "i was thinking we could invite a few more people along",
                "remind me to check the weather before we decide anything",
                "we talked about it over coffee this morning",
            ],
            topics: (4, 6),
            queries: (10, 13),
        },
        other => panic!("unknown dataset family '{other}'"),
    }
}

const DAYS: [&str; 5] = ["monday", "tuesday", "wednesday", "thursday", "friday"];
const TIMES: [&str; 5] = ["9am", "10am", "noon", "3pm", "5pm"];

#[derive(Debug, Clone)]
struct Topic {
    subject: String,
    object: String,
    person: String,
    place: String,
    day: String,
    time: String,
}

impl Topic {
    fn name(&self) -> String {
        format!("{} {}", self.subject, self.object)
    }
}

/// Deterministic generation for (dataset, user).
pub fn generate(dataset: &str, user: usize) -> UserData {
    assert!(user < USERS_PER_DATASET, "user index out of range");
    let fam = family(dataset);
    let seed = crate::tokenizer::fnv1a64(format!("{dataset}/{user}").as_bytes());
    let mut rng = Rng::new(seed);

    // -- topics -------------------------------------------------------------
    let n_topics = rng.range(fam.topics.0, fam.topics.1);
    let mut topics = Vec::with_capacity(n_topics);
    let mut subj_idx = rng.sample_indices(fam.subjects.len(), n_topics.min(fam.subjects.len()));
    while subj_idx.len() < n_topics {
        subj_idx.push(rng.below(fam.subjects.len()));
    }
    for i in 0..n_topics {
        topics.push(Topic {
            subject: fam.subjects[subj_idx[i]].to_string(),
            object: fam.objects[rng.below(fam.objects.len())].to_string(),
            person: fam.people[rng.below(fam.people.len())].to_string(),
            place: fam.places[rng.below(fam.places.len())].to_string(),
            day: DAYS[rng.below(DAYS.len())].to_string(),
            time: TIMES[rng.below(TIMES.len())].to_string(),
        });
    }

    // -- documents ------------------------------------------------------------
    // one document per topic: fact sentences + filler, ~2 chunks each
    let mut documents = Vec::with_capacity(n_topics);
    for t in &topics {
        let mut doc = String::new();
        doc.push_str(&format!(
            "the {} is scheduled for {} at {} in {}. ",
            t.name(),
            t.day,
            t.time,
            t.place
        ));
        doc.push_str(&format!(
            "{} is responsible for the {} and will prepare the summary. ",
            t.person,
            t.name()
        ));
        doc.push_str(&format!("{}. ", rng.pick(fam.filler)));
        doc.push_str(&format!(
            "they decided to move forward with the {} after {} confirmed the details. ",
            t.name(),
            t.person
        ));
        doc.push_str(&format!("{}. ", rng.pick(fam.filler)));
        documents.push(doc);
    }

    // -- queries --------------------------------------------------------------
    let n_queries = rng.range(fam.queries.0, fam.queries.1);
    let mut queries: Vec<QueryCase> = Vec::with_capacity(n_queries);
    // question makers keyed by fact, with paraphrase alternatives sharing
    // content words (⇒ high cosine under the content-word embedder)
    // Paraphrase calibration: alt 1 keeps the *content-word set* identical
    // (reordering / stopword swaps only ⇒ near-1.0 cosine under the
    // content-word embedder — these hit at τ=0.85 like the paper's 0.815+
    // pairs); alt 2 adds one content word (≈0.8 cosine — hits only at
    // lower thresholds, which is what makes the Fig 19 τ sweep move).
    #[allow(clippy::type_complexity)]
    let makers: Vec<(&str, Box<dyn Fn(&Topic, usize) -> (String, String)>)> = vec![
        ("when", Box::new(|t: &Topic, alt: usize| {
            let q = match alt {
                0 => format!("when is the {} scheduled", t.name()),
                1 => format!("the {} is scheduled for when", t.name()),
                _ => format!("what day is the {} scheduled", t.name()),
            };
            (q, format!("the {} is on {} at {}", t.name(), t.day, t.time))
        })),
        ("who", Box::new(|t: &Topic, alt: usize| {
            let q = match alt {
                0 => format!("who is responsible for the {}", t.name()),
                1 => format!("responsible for the {} is who", t.name()),
                _ => format!("which person is responsible for the {}", t.name()),
            };
            (q, format!("{} is responsible for the {}", t.person, t.name()))
        })),
        ("where", Box::new(|t: &Topic, alt: usize| {
            let q = match alt {
                0 => format!("where does the {} take place", t.name()),
                1 => format!("where will the {} take place", t.name()),
                _ => format!("in which room does the {} take place", t.name()),
            };
            (q, format!("the {} takes place in {}", t.name(), t.place))
        })),
        ("what-time", Box::new(|t: &Topic, alt: usize| {
            let q = match alt {
                0 => format!("what time is the {}", t.name()),
                1 => format!("the {} is at what time", t.name()),
                _ => format!("which time is the {} set for", t.name()),
            };
            (q, format!("the {} is at {} on {}", t.name(), t.time, t.day))
        })),
        ("decision", Box::new(|t: &Topic, alt: usize| {
            let q = match alt {
                0 => format!("what did they decide about the {}", t.name()),
                1 => format!("they decide what about the {}", t.name()),
                _ => format!("what did they finally decide about the {}", t.name()),
            };
            (
                q,
                format!("they decided to move forward with the {}", t.name()),
            )
        })),
    ];

    // cover each topic at least once, then add extra + paraphrase pairs
    let mut slots: Vec<(usize, usize, usize)> = Vec::new(); // (topic, maker, alt)
    for ti in 0..n_topics {
        slots.push((ti, rng.below(makers.len()), 0));
    }
    while slots.len() < n_queries {
        let ti = rng.below(n_topics);
        slots.push((ti, rng.below(makers.len()), 0));
    }
    slots.truncate(n_queries);
    rng.shuffle(&mut slots);

    // base queries, de-duplicated as we go (template collisions), while
    // remembering which slot produced each surviving query
    let mut kept_slots: Vec<(usize, usize)> = Vec::new(); // (topic, maker)
    let mut seen = std::collections::HashSet::new();
    for (ti, mi, _) in &slots {
        let (q, a) = makers[*mi].1(&topics[*ti], 0);
        if !seen.insert(q.clone()) {
            continue;
        }
        kept_slots.push((*ti, *mi));
        queries.push(QueryCase {
            text: q,
            gold_answer: a,
            topic: *ti,
            paraphrase_of: None,
        });
    }

    // paraphrase pairs: ~25% of queries get a later paraphrase (Fig 2's
    // high-similarity pairs), appended non-adjacently (Fig 6's sparsity)
    let n_para = (queries.len() / 4).max(1);
    for _ in 0..n_para {
        let src = rng.below(kept_slots.len());
        let (ti, mi) = kept_slots[src];
        // 2/3 exact-content paraphrases (alt 1), 1/3 near-misses (alt 2)
        let alt = if rng.below(3) < 2 { 1 } else { 2 };
        let (q, a) = makers[mi].1(&topics[ti], alt);
        if !seen.insert(q.clone()) {
            continue;
        }
        queries.push(QueryCase {
            text: q,
            gold_answer: a,
            topic: ti,
            paraphrase_of: Some(src),
        });
    }

    UserData {
        dataset: dataset.to_string(),
        user,
        documents,
        queries,
    }
}

// ---------------------------------------------------------------------------
// multi-tenant workloads
// ---------------------------------------------------------------------------

/// One tenant's trace in a multi-tenant workload.
#[derive(Debug, Clone)]
pub struct TenantTrace {
    pub tenant: usize,
    pub dataset: String,
    pub user: usize,
    /// Relative arrival weight (Zipf over tenant rank).
    pub weight: f64,
    pub data: UserData,
}

/// A device-wide workload: per-tenant traces + a deterministic
/// interleaved arrival order `(tenant, per-tenant sequence number)`.
/// Query streams cycle, so long runs repeat queries — the reuse the
/// caches exist to exploit.
#[derive(Debug, Clone)]
pub struct MultiTenantWorkload {
    pub tenants: Vec<TenantTrace>,
    pub arrivals: Vec<(usize, usize)>,
    /// Topics with index below this draw from a corpus common to every
    /// tenant (identical chunk content → identical segment keys), the
    /// overlap cross-tenant dedup exploits.  0 = fully private
    /// workloads, the pre-pool behaviour.
    pub shared_topics: usize,
}

/// Generate a multi-tenant workload: `n_tenants` tenants cycling through
/// the (dataset, user) grid, `total_arrivals` arrivals interleaved with
/// Zipf(`zipf_s`) tenant skew (rank-1 tenants dominate, the long tail
/// trickles — the shape a shared on-device assistant actually sees).
pub fn multi_tenant(
    n_tenants: usize,
    total_arrivals: usize,
    zipf_s: f64,
    seed: u64,
) -> MultiTenantWorkload {
    multi_tenant_shared(n_tenants, total_arrivals, zipf_s, seed, 0.0)
}

/// [`multi_tenant`] with a public-corpus knob: `shared_corpus_frac` of
/// each tenant's topics (lowest indices first) comes from a pool common
/// to all tenants, so their chunk segment keys collide across tenants —
/// the overlap `percache exp dedup` measures.  At 0.0 this is exactly
/// [`multi_tenant`].
pub fn multi_tenant_shared(
    n_tenants: usize,
    total_arrivals: usize,
    zipf_s: f64,
    seed: u64,
    shared_corpus_frac: f64,
) -> MultiTenantWorkload {
    assert!(n_tenants > 0, "need at least one tenant");
    let mut rng = Rng::new(seed ^ 0x7E4A47);
    let mut tenants = Vec::with_capacity(n_tenants);
    for t in 0..n_tenants {
        let dataset = DATASETS[t % DATASETS.len()];
        let user = (t / DATASETS.len()) % USERS_PER_DATASET;
        tenants.push(TenantTrace {
            tenant: t,
            dataset: dataset.to_string(),
            user,
            weight: 1.0 / ((t + 1) as f64).powf(zipf_s),
            data: generate(dataset, user),
        });
    }
    let weights: Vec<f64> = tenants.iter().map(|t| t.weight).collect();
    let mut next_seq = vec![0usize; n_tenants];
    let mut arrivals = Vec::with_capacity(total_arrivals);
    for _ in 0..total_arrivals {
        let t = rng.weighted(&weights);
        arrivals.push((t, next_seq[t]));
        next_seq[t] += 1;
    }
    let min_topics = tenants
        .iter()
        .map(|t| t.data.documents.len())
        .min()
        .unwrap_or(0);
    let shared_topics =
        (shared_corpus_frac.clamp(0.0, 1.0) * min_topics as f64).round() as usize;
    MultiTenantWorkload {
        tenants,
        arrivals,
        shared_topics,
    }
}

/// All users of all datasets (the paper's 20-user evaluation set).
pub fn all_users() -> Vec<UserData> {
    let mut out = Vec::new();
    for ds in DATASETS {
        for u in 0..USERS_PER_DATASET {
            out.push(generate(ds, u));
        }
    }
    out
}

// Re-exported so the predictor's templates and the generator stay
// visibly coupled (both model user questioning behaviour).
pub fn template_families() -> (usize, usize) {
    (GENERAL_TEMPLATES.len(), DETAIL_TEMPLATES.len())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = generate("mised", 0);
        let b = generate("mised", 0);
        assert_eq!(a.documents, b.documents);
        assert_eq!(
            a.queries.iter().map(|q| &q.text).collect::<Vec<_>>(),
            b.queries.iter().map(|q| &q.text).collect::<Vec<_>>()
        );
    }

    #[test]
    fn users_differ() {
        let a = generate("mised", 0);
        let b = generate("mised", 1);
        assert_ne!(a.documents, b.documents);
    }

    #[test]
    fn all_families_generate() {
        for ds in DATASETS {
            let u = generate(ds, 0);
            assert!(!u.documents.is_empty(), "{ds}: no documents");
            assert!(u.queries.len() >= 8, "{ds}: too few queries");
            for q in &u.queries {
                assert!(!q.text.is_empty() && !q.gold_answer.is_empty());
                assert!(q.topic < u.documents.len());
            }
        }
    }

    #[test]
    fn paraphrase_pairs_share_content_words() {
        let u = generate("enronqa", 2);
        let paras: Vec<&QueryCase> =
            u.queries.iter().filter(|q| q.paraphrase_of.is_some()).collect();
        assert!(!paras.is_empty(), "need paraphrase pairs for Fig 2");
        for p in paras {
            let src = &u.queries[p.paraphrase_of.unwrap()];
            let pw: std::collections::HashSet<_> =
                crate::tokenizer::words(&p.text).into_iter().collect();
            let sw: std::collections::HashSet<_> =
                crate::tokenizer::words(&src.text).into_iter().collect();
            let shared = pw.intersection(&sw).count();
            assert!(
                shared >= 3,
                "paraphrase {:?} of {:?} shares {shared} words",
                p.text,
                src.text
            );
        }
    }

    #[test]
    fn topics_get_repeated_queries() {
        // Fig 3 precondition: at least one topic is asked about ≥ 2 times
        let u = generate("enronqa", 0);
        let mut counts = std::collections::HashMap::new();
        for q in &u.queries {
            *counts.entry(q.topic).or_insert(0usize) += 1;
        }
        assert!(counts.values().any(|&c| c >= 2), "{counts:?}");
    }

    #[test]
    fn total_query_volume_near_paper() {
        let total: usize = all_users().iter().map(|u| u.queries.len()).sum();
        // paper: 275 across 20 users; accept the same order
        assert!((180..=360).contains(&total), "total queries {total}");
    }

    #[test]
    #[should_panic(expected = "user index")]
    fn user_bounds_checked() {
        generate("mised", 99);
    }

    #[test]
    fn multi_tenant_deterministic_and_covering() {
        let a = multi_tenant(8, 200, 1.0, 42);
        let b = multi_tenant(8, 200, 1.0, 42);
        assert_eq!(a.arrivals, b.arrivals);
        assert_eq!(a.tenants.len(), 8);
        assert_eq!(a.arrivals.len(), 200);
        for &(t, _) in &a.arrivals {
            assert!(t < 8);
        }
        // per-tenant sequence numbers are contiguous from zero
        let mut counts = vec![0usize; 8];
        for &(t, seq) in &a.arrivals {
            assert_eq!(seq, counts[t], "sequence gap for tenant {t}");
            counts[t] += 1;
        }
    }

    #[test]
    fn multi_tenant_zipf_skews_toward_low_ranks() {
        let w = multi_tenant(8, 800, 1.2, 7);
        let mut counts = vec![0usize; 8];
        for &(t, _) in &w.arrivals {
            counts[t] += 1;
        }
        assert!(
            counts[0] > counts[7],
            "rank-1 tenant must dominate the tail: {counts:?}"
        );
        // distinct tenants map to distinct (dataset, user) traces here
        assert_ne!(w.tenants[0].data.documents, w.tenants[1].data.documents);
    }

    #[test]
    fn shared_corpus_frac_scales_public_topics() {
        let none = multi_tenant_shared(4, 100, 1.0, 42, 0.0);
        assert_eq!(none.shared_topics, 0, "frac 0.0 keeps everything private");
        let half = multi_tenant_shared(4, 100, 1.0, 42, 0.5);
        let all = multi_tenant_shared(4, 100, 1.0, 42, 1.0);
        assert!(half.shared_topics > 0, "frac 0.5 must mark topics public");
        assert!(all.shared_topics > half.shared_topics);
        // the knob changes only the sharedness, not the arrival stream
        assert_eq!(none.arrivals, all.arrivals);
        // out-of-range fracs clamp instead of exploding
        assert_eq!(
            multi_tenant_shared(4, 100, 1.0, 42, 7.5).shared_topics,
            all.shared_topics
        );
    }

    #[test]
    fn multi_tenant_zero_skew_is_roughly_uniform() {
        let w = multi_tenant(4, 400, 0.0, 3);
        let mut counts = vec![0usize; 4];
        for &(t, _) in &w.arrivals {
            counts[t] += 1;
        }
        for &c in &counts {
            assert!((60..=140).contains(&c), "skewed without zipf: {counts:?}");
        }
    }
}
