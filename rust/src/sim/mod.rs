//! Device simulation: profiles, energy/battery model, idle clock.
//!
//! Substitution (DESIGN.md §3): the paper measures on four physical
//! phones + an A6000 server.  We measure real CPU wall-clock through the
//! PJRT hot path, then scale per stage with a device profile; profiles
//! are calibrated to reproduce the paper's two structural observations —
//! (a) on mobile, prefill and decode BOTH contribute materially (limited
//! parallelism ⇒ compute-bound prefill is slow); (b) on a server GPU,
//! prefill is massively parallel and decode dominates (Fig 4).
//! Cross-device ordering (Fig 21) follows SoC compute capability.

use crate::metrics::QueryRecord;

#[derive(Debug, Clone, PartialEq)]
pub struct DeviceProfile {
    pub name: &'static str,
    /// Multipliers over measured CPU-baseline stage latencies.
    pub prefill_scale: f64,
    pub decode_scale: f64,
    /// Non-LLM stages (embed, match, retrieval, load).
    pub other_scale: f64,
    /// Energy cost of compute (J per GFLOP) — drives the battery model.
    pub joules_per_gflop: f64,
    /// Battery capacity in joules (Wh × 3600).
    pub battery_joules: f64,
}

/// The measurement baseline — the workstation CPU itself, unscaled.
pub const BASELINE: DeviceProfile = DeviceProfile {
    name: "cpu-baseline",
    prefill_scale: 1.0,
    decode_scale: 1.0,
    other_scale: 1.0,
    joules_per_gflop: 0.35,
    battery_joules: 18.5 * 3600.0,
};

/// Google Pixel 7 (Tensor G2) — the paper's primary device.
pub const PIXEL7: DeviceProfile = DeviceProfile {
    name: "pixel7",
    prefill_scale: 6.0,
    decode_scale: 4.0,
    other_scale: 2.0,
    joules_per_gflop: 0.55,
    battery_joules: 4355.0 * 3.85, // 4355 mAh × 3.85 V
};

/// Redmi K60 Pro (Snapdragon 8 Gen 2) — fastest of the three phones.
pub const REDMI_K60: DeviceProfile = DeviceProfile {
    name: "redmi-k60-pro",
    prefill_scale: 4.5,
    decode_scale: 3.2,
    other_scale: 1.8,
    joules_per_gflop: 0.50,
    battery_joules: 5000.0 * 3.85,
};

/// Samsung Galaxy S22 Ultra (SD 8 Gen 1, older/thermally limited).
pub const S22_ULTRA: DeviceProfile = DeviceProfile {
    name: "s22-ultra",
    prefill_scale: 7.0,
    decode_scale: 4.8,
    other_scale: 2.2,
    joules_per_gflop: 0.62,
    battery_joules: 5000.0 * 3.85,
};

/// OnePlus Ace 6 — the paper's battery-measurement device.
pub const ONEPLUS_ACE6: DeviceProfile = DeviceProfile {
    name: "oneplus-ace6",
    prefill_scale: 5.0,
    decode_scale: 3.5,
    other_scale: 1.9,
    joules_per_gflop: 0.52,
    battery_joules: 6100.0 * 3.85,
};

/// NVIDIA RTX A6000 server: prefill parallelizes (~30× vs mobile-class),
/// decode is memory-bound (~8×) — reproducing Fig 4's decode-dominant mix.
pub const SERVER_A6000: DeviceProfile = DeviceProfile {
    name: "server-a6000",
    prefill_scale: 0.08,
    decode_scale: 0.60,
    other_scale: 0.5,
    joules_per_gflop: 0.08,
    battery_joules: f64::INFINITY,
};

pub const PHONES: [&DeviceProfile; 3] = [&REDMI_K60, &S22_ULTRA, &ONEPLUS_ACE6];

pub fn by_name(name: &str) -> Option<&'static DeviceProfile> {
    match name {
        "cpu-baseline" => Some(&BASELINE),
        "pixel7" => Some(&PIXEL7),
        "redmi-k60-pro" => Some(&REDMI_K60),
        "s22-ultra" => Some(&S22_ULTRA),
        "oneplus-ace6" => Some(&ONEPLUS_ACE6),
        "server-a6000" => Some(&SERVER_A6000),
        _ => None,
    }
}

impl DeviceProfile {
    /// Scale a measured record's stage latencies onto this device.
    pub fn scale_record(&self, r: &QueryRecord) -> QueryRecord {
        let mut s = r.clone();
        s.prefill_ms *= self.prefill_scale;
        s.decode_ms *= self.decode_scale;
        s.embed_ms *= self.other_scale;
        s.qa_match_ms *= self.other_scale;
        s.retrieval_ms *= self.other_scale;
        s.tree_match_ms *= self.other_scale;
        s.cache_load_ms *= self.other_scale;
        s
    }

    pub fn energy_joules(&self, flops: u64) -> f64 {
        flops as f64 / 1e9 * self.joules_per_gflop
    }
}

/// Battery state for the Fig 20 reproduction.
#[derive(Debug, Clone)]
pub struct Battery {
    profile: DeviceProfile,
    consumed_joules: f64,
}

impl Battery {
    pub fn new(profile: DeviceProfile) -> Self {
        Battery {
            profile,
            consumed_joules: 0.0,
        }
    }

    pub fn consume_flops(&mut self, flops: u64) {
        self.consumed_joules += self.profile.energy_joules(flops);
    }

    /// Remaining battery percentage.
    pub fn level_percent(&self) -> f64 {
        (100.0 * (1.0 - self.consumed_joules / self.profile.battery_joules)).max(0.0)
    }

    pub fn consumed_percent(&self) -> f64 {
        100.0 - self.level_percent()
    }
}

/// Idle-time clock: decides when the engine may run population work.
/// Mobile idle windows (overnight charging etc.) are modelled as a simple
/// duty cycle over a logical tick counter — enough to sequence idle work
/// deterministically in experiments.
#[derive(Debug, Clone)]
pub struct IdleClock {
    tick: u64,
    /// Every `period` ticks, `idle_len` ticks are idle.
    pub period: u64,
    pub idle_len: u64,
}

impl IdleClock {
    pub fn new(period: u64, idle_len: u64) -> Self {
        assert!(idle_len <= period && period > 0);
        IdleClock {
            tick: 0,
            period,
            idle_len,
        }
    }

    /// Always-idle clock (experiments that drive population explicitly).
    pub fn always_idle() -> Self {
        Self::new(1, 1)
    }

    pub fn advance(&mut self) {
        self.tick += 1;
    }

    pub fn is_idle(&self) -> bool {
        self.tick % self.period < self.idle_len
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::blank_record;

    #[test]
    fn mobile_vs_server_latency_mix() {
        // Fig 4's structural claim: prefill/decode comparable on mobile,
        // decode-dominant on server — for a typical prefill-heavy record.
        let mut r = blank_record(0);
        r.prefill_ms = 100.0;
        r.decode_ms = 30.0;

        let mob = PIXEL7.scale_record(&r);
        let srv = SERVER_A6000.scale_record(&r);
        // mobile: prefill clearly dominant or comparable
        assert!(mob.prefill_ms > mob.decode_ms);
        // server: decode dominates
        assert!(srv.decode_ms < mob.decode_ms);
        assert!(srv.prefill_ms < srv.decode_ms);
    }

    #[test]
    fn phone_ordering_matches_soc_tiers() {
        let mut r = blank_record(0);
        r.prefill_ms = 100.0;
        r.decode_ms = 50.0;
        let k60 = REDMI_K60.scale_record(&r).total_ms();
        let ace = ONEPLUS_ACE6.scale_record(&r).total_ms();
        let s22 = S22_ULTRA.scale_record(&r).total_ms();
        assert!(k60 < ace && ace < s22);
    }

    #[test]
    fn battery_drains_linearly_in_flops() {
        let mut b = Battery::new(ONEPLUS_ACE6);
        assert_eq!(b.level_percent(), 100.0);
        b.consume_flops(1_000_000_000_000); // 1 TFLOP
        let after_one = b.consumed_percent();
        b.consume_flops(1_000_000_000_000);
        assert!((b.consumed_percent() - 2.0 * after_one).abs() < 1e-9);
        assert!(after_one > 0.0);
    }

    #[test]
    fn battery_floors_at_zero() {
        let mut b = Battery::new(DeviceProfile {
            battery_joules: 1.0,
            ..PIXEL7
        });
        b.consume_flops(u64::MAX / 2);
        assert_eq!(b.level_percent(), 0.0);
    }

    #[test]
    fn idle_clock_duty_cycle() {
        let mut c = IdleClock::new(4, 1);
        let mut idles = 0;
        for _ in 0..8 {
            if c.is_idle() {
                idles += 1;
            }
            c.advance();
        }
        assert_eq!(idles, 2);
        assert!(IdleClock::always_idle().is_idle());
    }

    #[test]
    fn profile_lookup() {
        assert_eq!(by_name("pixel7").unwrap().name, "pixel7");
        assert!(by_name("nokia3310").is_none());
    }
}
