//! Request-serving loop: a dedicated inference thread owns the engine
//! (PJRT executables are not Sync; mobile inference is single-device
//! anyway) and client threads submit queries over a channel — the
//! coordination shape of a real on-device assistant service.
//!
//! Used by `examples/e2e_serve.rs` and the `percache serve` subcommand.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::Instant;

use crate::metrics::QueryRecord;

/// A request travelling to the inference thread.
pub struct Request {
    pub id: usize,
    pub query: String,
    /// Queue timestamp, for end-to-end (queueing + serving) latency.
    pub submitted: Instant,
    pub respond: mpsc::Sender<Response>,
}

#[derive(Debug)]
pub struct Response {
    pub id: usize,
    pub record: QueryRecord,
    /// Total time including queueing.
    pub e2e_ms: f64,
}

/// Commands accepted by the serving loop.
pub enum Command {
    Serve(Request),
    /// Run one idle tick (population/conversions).
    IdleTick,
    Shutdown,
}

/// Shareable join-handle cell: the first `join()` waits for the thread
/// and propagates its result; later calls — including from clones —
/// return Ok immediately.  Used by [`ServerHandle`] and the tenancy
/// router's `TenantServerHandle`.
#[derive(Clone)]
pub struct JoinCell(Arc<Mutex<Option<thread::JoinHandle<anyhow::Result<()>>>>>);

impl JoinCell {
    pub fn new(handle: thread::JoinHandle<anyhow::Result<()>>) -> Self {
        JoinCell(Arc::new(Mutex::new(Some(handle))))
    }

    pub fn join(&self) -> anyhow::Result<()> {
        let handle = crate::util::sync::lock_or_recover(&self.0).take();
        match handle {
            Some(h) => h
                .join()
                .map_err(|_| anyhow::anyhow!("server thread panicked"))?,
            None => Ok(()),
        }
    }
}

/// Handle held by clients.
#[derive(Clone)]
pub struct ServerHandle {
    tx: mpsc::Sender<Command>,
    join: JoinCell,
}

impl ServerHandle {
    /// Blocking query: submit and wait for the answer.
    pub fn query(&self, id: usize, query: &str) -> anyhow::Result<Response> {
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(Command::Serve(Request {
                id,
                query: query.to_string(),
                submitted: Instant::now(),
                respond: rtx,
            }))
            .map_err(|_| anyhow::anyhow!("server is down"))?;
        rrx.recv().map_err(|_| anyhow::anyhow!("server dropped request"))
    }

    pub fn idle_tick(&self) -> anyhow::Result<()> {
        self.tx
            .send(Command::IdleTick)
            .map_err(|_| anyhow::anyhow!("server is down"))
    }

    /// Request shutdown.  Already-queued requests are drained and
    /// answered before the serving loop exits (see [`run_loop`]).
    pub fn shutdown(&self) {
        let _ = self.tx.send(Command::Shutdown);
    }

    /// Wait for the inference thread to exit.  Idempotent: the first
    /// caller joins; later calls (or clones) return Ok immediately.
    pub fn join(&self) -> anyhow::Result<()> {
        self.join.join()
    }
}

/// Run a serving loop on the CURRENT thread, with `serve_fn` handling
/// each query and `idle_fn` handling idle ticks.  Returns when Shutdown
/// arrives — but only after draining and answering every request already
/// queued at that point (clients blocked in `query()` would otherwise
/// hang on a dropped channel).  (The engine stays on this thread; see
/// `spawn_with`.)
pub fn run_loop(
    rx: mpsc::Receiver<Command>,
    mut serve_fn: impl FnMut(&str) -> anyhow::Result<QueryRecord>,
    mut idle_fn: impl FnMut(),
) {
    let mut serve = |req: Request| {
        let record = serve_fn(&req.query).unwrap_or_else(|e| {
            let mut r = crate::metrics::blank_record(req.id);
            r.answer = format!("error: {e:#}");
            r
        });
        let e2e_ms = req.submitted.elapsed().as_secs_f64() * 1e3;
        let _ = req.respond.send(Response {
            id: req.id,
            record,
            e2e_ms,
        });
    };
    loop {
        match rx.recv() {
            Ok(Command::Serve(req)) => serve(req),
            Ok(Command::IdleTick) => idle_fn(),
            Ok(Command::Shutdown) => {
                // drain: answer everything that was queued before the
                // shutdown command; idle work is skipped
                while let Ok(cmd) = rx.try_recv() {
                    if let Command::Serve(req) = cmd {
                        serve(req);
                    }
                }
                break;
            }
            Err(_) => break, // all senders gone
        }
    }
}

/// Spawn a server thread whose state is built *inside* the thread by
/// `make_state` (so non-Send engine state never crosses threads), then
/// serve with the provided handlers.  Wait for the thread with
/// `handle.join()` after `handle.shutdown()`.
pub fn spawn_with<S: 'static>(
    make_state: impl FnOnce() -> anyhow::Result<S> + Send + 'static,
    serve_fn: impl Fn(&mut S, &str) -> anyhow::Result<QueryRecord> + Send + 'static,
    idle_fn: impl Fn(&mut S) + Send + 'static,
) -> ServerHandle {
    let (tx, rx) = mpsc::channel();
    let handle = thread::Builder::new()
        .name("percache-server".into())
        .spawn(move || -> anyhow::Result<()> {
            let state = std::cell::RefCell::new(make_state()?);
            run_loop(
                rx,
                |q| serve_fn(&mut state.borrow_mut(), q),
                || idle_fn(&mut state.borrow_mut()),
            );
            Ok(())
        })
        // percache-allow(panic_path): thread-spawn failure at process start is unrecoverable resource exhaustion; dying loudly beats serving without a loop
        .expect("spawn server thread");
    ServerHandle {
        tx,
        join: JoinCell::new(handle),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::blank_record;

    #[test]
    fn serve_roundtrip_and_shutdown() {
        let handle = spawn_with(
            || Ok(0usize),
            |count, q| {
                *count += 1;
                let mut r = blank_record(*count);
                r.answer = format!("echo {q}");
                r.prefill_ms = 1.0;
                Ok(r)
            },
            |_| {},
        );
        let resp = handle.query(1, "hello").unwrap();
        assert_eq!(resp.record.answer, "echo hello");
        assert!(resp.e2e_ms >= 0.0);
        handle.shutdown();
        handle.join().unwrap();
        // idempotent, also from a clone
        handle.clone().join().unwrap();
    }

    #[test]
    fn concurrent_clients_serialize_on_engine() {
        let handle = spawn_with(
            || Ok(Vec::<usize>::new()),
            |seen, q| {
                let n: usize = q.parse().unwrap();
                seen.push(n);
                Ok(blank_record(n))
            },
            |_| {},
        );
        let mut clients = Vec::new();
        for i in 0..8 {
            let h = handle.clone();
            clients.push(std::thread::spawn(move || {
                h.query(i, &i.to_string()).unwrap().id
            }));
        }
        let mut got: Vec<usize> = clients.into_iter().map(|c| c.join().unwrap()).collect();
        got.sort();
        assert_eq!(got, (0..8).collect::<Vec<_>>());
        handle.shutdown();
        handle.join().unwrap();
    }

    #[test]
    fn idle_tick_reaches_state() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let ticks = Arc::new(AtomicUsize::new(0));
        let t2 = Arc::clone(&ticks);
        let handle = spawn_with(
            || Ok(()),
            |_, _| Ok(blank_record(0)),
            move |_| {
                t2.fetch_add(1, Ordering::SeqCst);
            },
        );
        handle.idle_tick().unwrap();
        handle.idle_tick().unwrap();
        handle.shutdown();
        handle.join().unwrap();
        assert_eq!(ticks.load(Ordering::SeqCst), 2);
    }

    #[test]
    fn error_in_serve_becomes_error_answer() {
        let handle = spawn_with(
            || Ok(()),
            |_, _| anyhow::bail!("boom"),
            |_| {},
        );
        let resp = handle.query(0, "x").unwrap();
        assert!(resp.record.answer.contains("boom"));
        handle.shutdown();
        handle.join().unwrap();
    }

    #[test]
    fn shutdown_drains_queued_requests() {
        // Drive run_loop directly so the queue state is deterministic:
        // three requests and a shutdown are already in the channel before
        // the loop starts — all three must still be answered.
        let (tx, rx) = mpsc::channel();
        let mut responders = Vec::new();
        for i in 0..3 {
            let (rtx, rrx) = mpsc::channel();
            tx.send(Command::Serve(Request {
                id: i,
                query: format!("q{i}"),
                submitted: Instant::now(),
                respond: rtx,
            }))
            .unwrap();
            responders.push(rrx);
        }
        tx.send(Command::Shutdown).unwrap();
        let mut served = 0usize;
        run_loop(
            rx,
            |q| {
                served += 1;
                let mut r = blank_record(0);
                r.answer = format!("ans {q}");
                Ok(r)
            },
            || {},
        );
        assert_eq!(served, 3, "queued requests were dropped on shutdown");
        for (i, rrx) in responders.into_iter().enumerate() {
            let resp = rrx.recv().expect("response must arrive before exit");
            assert_eq!(resp.record.answer, format!("ans q{i}"));
        }
    }

    #[test]
    fn shutdown_drain_skips_idle_ticks() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let (tx, rx) = mpsc::channel();
        tx.send(Command::Shutdown).unwrap();
        tx.send(Command::IdleTick).unwrap();
        let ticks = AtomicUsize::new(0);
        run_loop(rx, |_| Ok(blank_record(0)), || {
            ticks.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(ticks.load(Ordering::SeqCst), 0, "idle work after shutdown");
    }
}
