//! Hybrid retrieval: BM25 (Robertson–Zaragoza) + dense cosine, following
//! the paper's §4.2.2 hybrid strategy [13].
//!
//! BM25 scores are min-max normalized per query before mixing with the
//! cosine term: `score = α·bm25̂ + (1-α)·cos`.  The index updates
//! incrementally as chunks are added.

use std::collections::HashMap;

use crate::embedding::{cosine, Embedding};
use crate::kb::{ChunkId, KnowledgeBank};
use crate::tokenizer;

const K1: f64 = 1.2;
const B: f64 = 0.75;

/// Incremental BM25 index over chunk word bags.
#[derive(Debug, Default)]
pub struct Bm25Index {
    /// Per-document term frequencies.
    docs: Vec<HashMap<String, usize>>,
    doc_len: Vec<usize>,
    df: HashMap<String, usize>,
    total_len: usize,
}

impl Bm25Index {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add_document(&mut self, text: &str) -> usize {
        let words = tokenizer::words(text);
        let mut tf = HashMap::new();
        for w in &words {
            *tf.entry(w.clone()).or_insert(0) += 1;
        }
        for w in tf.keys() {
            *self.df.entry(w.clone()).or_insert(0) += 1;
        }
        self.total_len += words.len();
        self.doc_len.push(words.len());
        self.docs.push(tf);
        self.docs.len() - 1
    }

    pub fn len(&self) -> usize {
        self.docs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.docs.is_empty()
    }

    fn avgdl(&self) -> f64 {
        if self.docs.is_empty() {
            return 1.0;
        }
        (self.total_len as f64 / self.docs.len() as f64).max(1.0)
    }

    fn idf(&self, term: &str) -> f64 {
        let n = self.docs.len() as f64;
        let df = self.df.get(term).copied().unwrap_or(0) as f64;
        // BM25+ style floor keeps common terms from going negative.
        (((n - df + 0.5) / (df + 0.5)) + 1.0).ln()
    }

    pub fn score(&self, query_words: &[String], doc: usize) -> f64 {
        let tf = &self.docs[doc];
        let dl = self.doc_len[doc] as f64;
        let avgdl = self.avgdl();
        let mut s = 0.0;
        for term in query_words {
            let f = tf.get(term).copied().unwrap_or(0) as f64;
            if f > 0.0 {
                s += self.idf(term) * f * (K1 + 1.0) / (f + K1 * (1.0 - B + B * dl / avgdl));
            }
        }
        s
    }

    pub fn scores(&self, query: &str) -> Vec<f64> {
        let qw = tokenizer::words(query);
        (0..self.docs.len()).map(|d| self.score(&qw, d)).collect()
    }
}

/// Hybrid retriever over a knowledge bank.
pub struct Retriever {
    bm25: Bm25Index,
    /// α weight for the (normalized) BM25 term.
    pub alpha: f64,
}

#[derive(Debug, Clone, PartialEq)]
pub struct Retrieved {
    pub chunk: ChunkId,
    pub score: f64,
}

impl Retriever {
    pub fn new(alpha: f64) -> Self {
        Retriever {
            bm25: Bm25Index::new(),
            alpha,
        }
    }

    /// Must be called once per chunk, in chunk-id order (asserts to catch
    /// drift between the index and the bank).
    pub fn index_chunk(&mut self, id: ChunkId, text: &str) {
        let got = self.bm25.add_document(text);
        assert_eq!(got, id, "retriever out of sync with knowledge bank");
    }

    /// Top-k chunks by hybrid score, ties broken by chunk id for
    /// determinism.  `query_emb` must come from the same embedder as the
    /// chunk embeddings.
    pub fn retrieve(
        &self,
        query: &str,
        query_emb: &Embedding,
        kb: &KnowledgeBank,
        top_k: usize,
    ) -> Vec<Retrieved> {
        if kb.is_empty() {
            return Vec::new();
        }
        let bm = self.bm25.scores(query);
        let bm_max = bm.iter().cloned().fold(0.0f64, f64::max);
        let mut scored: Vec<Retrieved> = kb
            .chunks()
            .iter()
            .map(|c| {
                let bmn = if bm_max > 0.0 { bm[c.id] / bm_max } else { 0.0 };
                let cos = cosine(query_emb, &c.embedding) as f64;
                Retrieved {
                    chunk: c.id,
                    score: self.alpha * bmn + (1.0 - self.alpha) * cos,
                }
            })
            .collect();
        scored.sort_by(|a, b| {
            b.score
                .partial_cmp(&a.score)
                .unwrap()
                .then(a.chunk.cmp(&b.chunk))
        });
        scored.truncate(top_k);
        scored
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idx(texts: &[&str]) -> Bm25Index {
        let mut i = Bm25Index::new();
        for t in texts {
            i.add_document(t);
        }
        i
    }

    #[test]
    fn bm25_prefers_matching_terms() {
        let i = idx(&[
            "budget review meeting thursday",
            "travel booking flight monday",
            "budget budget budget numbers",
        ]);
        let s = i.scores("budget review");
        assert!(s[0] > s[1], "{s:?}");
        assert!(s[2] > s[1], "{s:?}");
        // doc 0 matches both terms; doc 2 matches one term thrice —
        // two distinct matches should win
        assert!(s[0] > s[2], "{s:?}");
    }

    #[test]
    fn bm25_rare_terms_weigh_more() {
        let i = idx(&[
            "meeting meeting alpha",
            "meeting meeting beta",
            "meeting meeting gamma",
        ]);
        let s_rare = i.scores("alpha");
        let s_common = i.scores("meeting");
        assert!(s_rare[0] > s_common[0]);
        assert_eq!(s_rare[1], 0.0);
    }

    #[test]
    fn bm25_length_normalization() {
        let mut i = Bm25Index::new();
        i.add_document("budget");
        i.add_document(&format!("budget {}", "filler ".repeat(50)));
        let s = i.scores("budget");
        assert!(s[0] > s[1], "shorter doc should score higher: {s:?}");
    }

    #[test]
    fn empty_query_scores_zero() {
        let i = idx(&["alpha beta"]);
        assert_eq!(i.scores("")[0], 0.0);
        assert_eq!(i.scores("zzz unknown")[0], 0.0);
    }

    #[test]
    fn retriever_sync_assertion() {
        let mut r = Retriever::new(0.5);
        r.index_chunk(0, "a");
        r.index_chunk(1, "b");
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            r.index_chunk(5, "skip");
        }));
        assert!(result.is_err());
    }
}
