//! # PerCache
//!
//! Reproduction of *"PerCache: Predictive Hierarchical Cache for RAG
//! Applications on Mobile Devices"* as a three-layer rust + JAX + Pallas
//! system: the rust coordinator here (Layer 3) serves every request from
//! AOT-compiled HLO artifacts (Layers 2/1, built once by
//! `python/compile/aot.py`) through the PJRT C API.
//!
//! Module map (see DESIGN.md for the full inventory):
//!
//! * [`runtime`] / [`llm`] / [`embedding`] — PJRT artifact execution.
//! * [`cache`] — the hierarchical cache: QA bank + QKV prefix tree.
//! * [`retrieval`] / [`kb`] — hybrid BM25+dense retrieval over the
//!   knowledge bank.
//! * [`predict`] — predictive cache population (knowledge/history views).
//! * [`scheduler`] — adaptive population strategy + cross-layer conversion.
//! * [`engine`] — the PerCache facade (serve + populate pipelines).
//! * [`baselines`] — Naive / RAGCache / MeanCache / Sleep-time Compute and
//!   combinations, behind one `CachePolicy` trait.
//! * [`tenancy`] — multi-tenant cache sharding: per-tenant shards, the
//!   global memory governor, and the fair-scheduling request router.
//! * [`pool`] — the cross-tenant content-addressed slice pool shared
//!   chunks dedup into (refcounted, copy-on-write — DESIGN.md §15).
//! * [`tiering`] — warm/cold shard residency: idle shards demote to
//!   their on-disk snapshot and page back on demand.
//! * [`obs`] — runtime telemetry: the metrics registry, stage spans,
//!   and the event journal every serving layer records into.
//! * [`analysis`] — the `percache check` static analysis pass over the
//!   crate's own sources (panic paths, lock order, metric schema,
//!   unsafe audit — DESIGN.md §13).
//! * [`datasets`] / [`sim`] — synthetic workloads and device models.
//! * [`exp`] — the paper-figure/table reproduction harness.
//! * [`util`] / [`testkit`] / [`tokenizer`] / [`metrics`] — substrates.

// Style idioms the seed tree uses pervasively (`&Embedding` parameters,
// inherent `Json::to_string`, arg-less `new()` constructors, configs
// built by mutating a `default()`).  Allowed explicitly so the CI
// clippy gate (`-D warnings`) enforces everything else; shrinking this
// list is tracked cleanup, not a blocker.
// Crate policy (enforced twice: here at compile time, and by the
// `unsafe_audit` rule in `percache check`): only `runtime/` — the PJRT
// FFI boundary — may contain `unsafe`, and each block needs a
// `// SAFETY:` contract.
#![deny(unsafe_code)]
#![allow(clippy::ptr_arg)]
#![allow(clippy::inherent_to_string)]
#![allow(clippy::new_without_default)]
#![allow(clippy::field_reassign_with_default)]
#![allow(clippy::len_without_is_empty)]
#![allow(clippy::type_complexity)]

pub mod analysis;
pub mod baselines;
pub mod cache;
pub mod config;
pub mod datasets;
pub mod embedding;
pub mod engine;
pub mod exp;
pub mod kb;
pub mod llm;
pub mod metrics;
pub mod obs;
pub mod pool;
pub mod predict;
pub mod retrieval;
#[allow(unsafe_code)] // PJRT FFI boundary — the one module allowed unsafe
pub mod runtime;
pub mod scheduler;
pub mod server;
pub mod sim;
pub mod tenancy;
pub mod testkit;
pub mod tiering;
pub mod tokenizer;
pub mod util;
