//! Snapshot/restore for the cache hierarchy (DESIGN.md §10): the warm
//! half of warm restart.
//!
//! The slice *data* already lives on disk (the [`SliceStore`] manifest
//! makes it resumable); this module persists everything that gives those
//! bytes meaning — the QKV prefix-tree structure (keys, parent links,
//! slice ids, LFU freqs), the QA bank entries (query, embedding, answer,
//! freq) and the predictor's recent-query history — into one versioned
//! `cache_state.json` next to the slice files, written atomically
//! (tmp + rename).
//!
//! Crash-safety model: the store manifest commits on every put/remove,
//! the state snapshot only on [`save_state`] (engine shutdown / explicit
//! checkpoint).  [`load_state`] therefore reconciles the two sides:
//! store slices no state snapshot references are garbage-collected, and
//! snapshot nodes whose slice vanished keep their structure but drop the
//! slice — both directions degrade to a smaller warm cache, never to
//! corruption.

use std::path::Path;

use anyhow::{Context, Result};

use crate::predict::QueryPredictor;
use crate::util::json::Json;

use super::qa_bank::{QaBank, QaEntry, QaId};
use super::qkv_tree::{NodeSnapshot, QkvTree};
use super::store::{SliceId, SliceStore};

/// State-snapshot schema version; readers reject anything else.
pub const STATE_VERSION: usize = 1;
/// Snapshot file name inside a cache directory.
pub const STATE_FILE: &str = "cache_state.json";
const STATE_MAGIC: &str = "percache-state";

/// What a [`load_state`] restore brought back (reporting).
#[derive(Debug, Clone, Default)]
pub struct RestoreReport {
    pub tree_nodes: usize,
    pub tree_slices: usize,
    pub qa_entries: usize,
    pub history: usize,
    /// Store slices no snapshot node referenced, GC'd at load.
    pub unreferenced_slices: usize,
}

/// Serialize the QKV tree section of a snapshot.
fn tree_section(tree: &QkvTree) -> Json {
    let nodes: Vec<Json> = tree
        .export()
        .iter()
        .map(|n| {
            let mut o = Json::obj();
            // seg keys are full-range u64 hashes: hex strings, not f64
            o.insert("key", format!("{:016x}", n.key));
            o.insert(
                "parent",
                match n.parent {
                    None => Json::Num(-1.0),
                    Some(p) => Json::from(p),
                },
            );
            o.insert(
                "slice",
                match n.slice {
                    None => Json::Null,
                    Some(s) => Json::from(s),
                },
            );
            o.insert("freq", n.freq);
            Json::Obj(o)
        })
        .collect();
    let mut tj = Json::obj();
    tj.insert("nodes", Json::Arr(nodes));
    Json::Obj(tj)
}

/// Serialize the QA-bank section of a snapshot.
fn qa_section(qa: &QaBank) -> Json {
    let entries: Vec<Json> = qa
        .entries()
        .iter()
        .map(|e| {
            let mut o = Json::obj();
            o.insert("id", e.id);
            o.insert("query", e.query.as_str());
            o.insert(
                "embedding",
                Json::Arr(e.embedding.iter().map(|&x| Json::Num(x as f64)).collect()),
            );
            o.insert(
                "answer",
                match &e.answer {
                    None => Json::Null,
                    Some(a) => Json::Arr(a.iter().map(|&t| Json::from(t)).collect()),
                },
            );
            o.insert("predicted", e.predicted);
            o.insert("freq", e.freq);
            Json::Obj(o)
        })
        .collect();
    let mut qj = Json::obj();
    qj.insert("next_id", qa.next_id());
    qj.insert("entries", Json::Arr(entries));
    Json::Obj(qj)
}

/// Serialize the predictor section of a snapshot.
fn predictor_section(predictor: &QueryPredictor) -> Json {
    let mut pj = Json::obj();
    pj.insert(
        "history",
        Json::Arr(
            predictor
                .history_snapshot()
                .into_iter()
                .map(Json::Str)
                .collect(),
        ),
    );
    pj.insert(
        "arrival_ticks",
        Json::Arr(
            predictor
                .arrival_ticks()
                .iter()
                .map(|&t| Json::Num(t as f64))
                .collect(),
        ),
    );
    Json::Obj(pj)
}

/// Assemble and atomically commit a snapshot from its three sections.
fn write_snapshot(dir: &Path, tree_j: Json, qa_j: Json, pred_j: Json) -> Result<()> {
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating cache dir {}", dir.display()))?;
    let mut root = Json::obj();
    root.insert("magic", STATE_MAGIC);
    root.insert("version", STATE_VERSION);
    root.insert("tree", tree_j);
    root.insert("qa", qa_j);
    root.insert("predictor", pred_j);
    let tmp = dir.join(format!("{STATE_FILE}.tmp"));
    let fin = dir.join(STATE_FILE);
    std::fs::write(&tmp, Json::Obj(root).to_string_pretty())
        .with_context(|| format!("writing {}", tmp.display()))?;
    std::fs::rename(&tmp, &fin).with_context(|| format!("committing {}", fin.display()))?;
    Ok(())
}

/// Atomically persist the cache hierarchy's state into `dir` (next to
/// the slice files of the disk store).  Always writes; use a
/// [`Snapshotter`] for dirty-flag-aware incremental saves.
pub fn save_state(
    dir: &Path,
    tree: &QkvTree,
    qa: &QaBank,
    predictor: &QueryPredictor,
) -> Result<()> {
    write_snapshot(
        dir,
        tree_section(tree),
        qa_section(qa),
        predictor_section(predictor),
    )
}

/// Incremental snapshot writer: keeps the assembled snapshot document
/// cached and re-serializes only the sections whose source structure
/// reports dirty since the last save (clean sections stay in the cached
/// document untouched — no clone, no re-serialization).  A save where
/// nothing is dirty (and the snapshot file exists) is a complete no-op,
/// which makes per-serve checkpointing and demote-time saves of idle
/// shards cheap.
#[derive(Debug, Default)]
pub struct Snapshotter {
    /// The cached snapshot document (magic/version + three sections).
    root: Option<Json>,
    /// Snapshots actually written / skipped as clean (reporting).
    pub writes: u64,
    pub skipped: u64,
    /// Sections served from cache across all writes (reporting).
    pub sections_reused: u64,
}

impl Snapshotter {
    pub fn new() -> Self {
        Snapshotter::default()
    }

    /// Save `dir`'s snapshot if anything changed; returns whether a file
    /// write happened.  Clears the dirty flags of everything it captured.
    pub fn save(
        &mut self,
        dir: &Path,
        tree: &mut QkvTree,
        qa: &mut QaBank,
        predictor: &mut QueryPredictor,
    ) -> Result<bool> {
        let have_root = self.root.is_some();
        let tree_fresh = tree.is_dirty() || !have_root;
        let qa_fresh = qa.is_dirty() || !have_root;
        let pred_fresh = predictor.is_dirty() || !have_root;
        if !tree_fresh && !qa_fresh && !pred_fresh && dir.join(STATE_FILE).exists() {
            self.skipped += 1;
            crate::obs_counter!("persist.dirty_skips").inc();
            return Ok(false);
        }
        self.sections_reused +=
            [tree_fresh, qa_fresh, pred_fresh].iter().filter(|f| !**f).count() as u64;
        if !have_root {
            let mut o = Json::obj();
            o.insert("magic", STATE_MAGIC);
            o.insert("version", STATE_VERSION);
            self.root = Some(Json::Obj(o));
        }
        let Some(Json::Obj(root)) = self.root.as_mut() else {
            unreachable!("snapshotter root is always an object");
        };
        if tree_fresh {
            root.insert("tree", tree_section(tree));
        }
        if qa_fresh {
            root.insert("qa", qa_section(qa));
        }
        if pred_fresh {
            root.insert("predictor", predictor_section(predictor));
        }
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating cache dir {}", dir.display()))?;
        let tmp = dir.join(format!("{STATE_FILE}.tmp"));
        let fin = dir.join(STATE_FILE);
        let doc = self.root.as_ref().expect("root just ensured");
        let text = doc.to_string_pretty();
        let snapshot_bytes = text.len();
        std::fs::write(&tmp, text).with_context(|| format!("writing {}", tmp.display()))?;
        std::fs::rename(&tmp, &fin).with_context(|| format!("committing {}", fin.display()))?;
        tree.mark_clean();
        qa.mark_clean();
        predictor.mark_clean();
        self.writes += 1;
        crate::obs_counter!("persist.snapshot_writes").inc();
        crate::obs_counter!("persist.bytes_written").add(snapshot_bytes as u64);
        crate::obs::emit(
            crate::obs::Event::new("checkpoint.written")
                .field("bytes", snapshot_bytes as f64),
        );
        Ok(true)
    }
}

/// Restore the cache hierarchy persisted at `dir`, reconciling against
/// the (already-opened) disk `store`.
///
/// Returns `Ok(None)` when no snapshot exists — in that case any slices
/// the store resumed are purged too (with no tree to reference them they
/// are dead weight, and a later snapshot would GC them anyway).  A
/// present but unreadable/incompatible snapshot is an error, never
/// silently discarded.
pub fn load_state(
    dir: &Path,
    store: &mut SliceStore,
    qkv_limit: usize,
    qa_limit: usize,
    predictor: &mut QueryPredictor,
) -> Result<Option<(QkvTree, QaBank, RestoreReport)>> {
    let path = dir.join(STATE_FILE);
    if !path.exists() {
        store.remove_many(&store.ids());
        return Ok(None);
    }
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("reading {}", path.display()))?;
    let j = Json::parse(&text)
        .with_context(|| format!("invalid cache state {}", path.display()))?;
    anyhow::ensure!(
        j.get("magic").as_str() == Some(STATE_MAGIC),
        "cache state missing magic {STATE_MAGIC:?}"
    );
    let version = j.get("version").as_usize().context("state missing version")?;
    anyhow::ensure!(
        version == STATE_VERSION,
        "unsupported cache-state version {version} (reader supports {STATE_VERSION})"
    );

    // -- tree --------------------------------------------------------------
    let mut nodes = Vec::new();
    for n in j.get("tree").get("nodes").as_arr().context("state missing tree.nodes")? {
        let key_hex = n.get("key").as_str().context("node missing key")?;
        let key = u64::from_str_radix(key_hex, 16)
            .with_context(|| format!("bad node key {key_hex:?}"))?;
        let parent = match n.get("parent").as_i64().context("node missing parent")? {
            -1 => None,
            p if p >= 0 => Some(p as usize),
            p => anyhow::bail!("bad parent index {p}"),
        };
        let slice = match n.get("slice") {
            Json::Null => None,
            v => Some(v.as_usize().context("bad slice id")? as SliceId),
        };
        let freq = n.get("freq").as_usize().unwrap_or(0) as u64;
        nodes.push(NodeSnapshot {
            key,
            parent,
            slice,
            freq,
        });
    }
    let tree = QkvTree::restore(qkv_limit, &nodes, store)?;

    // GC store slices the restored tree doesn't reference (puts committed
    // after the last snapshot, or slices the restore's budget pass shed)
    let referenced: std::collections::HashSet<SliceId> =
        tree.slice_ids().into_iter().collect();
    let orphans: Vec<SliceId> = store
        .ids()
        .into_iter()
        .filter(|id| !referenced.contains(id))
        .collect();
    let unreferenced = orphans.len();
    store.remove_many(&orphans);

    // -- qa bank -----------------------------------------------------------
    let qa_j = j.get("qa");
    let next_id = qa_j.get("next_id").as_usize().context("qa missing next_id")? as QaId;
    let mut entries = Vec::new();
    for e in qa_j.get("entries").as_arr().context("qa missing entries")? {
        let id = e.get("id").as_usize().context("qa entry missing id")? as QaId;
        let query = e
            .get("query")
            .as_str()
            .context("qa entry missing query")?
            .to_string();
        let mut embedding = Vec::new();
        for x in e.get("embedding").as_arr().context("qa entry missing embedding")? {
            embedding.push(x.as_f64().context("bad embedding component")? as f32);
        }
        let answer = match e.get("answer") {
            Json::Null => None,
            v => {
                let mut a = Vec::new();
                for t in v.as_arr().context("bad qa answer")? {
                    a.push(t.as_i64().context("bad answer token")? as i32);
                }
                Some(a)
            }
        };
        entries.push(QaEntry {
            id,
            query,
            embedding,
            answer,
            predicted: e.get("predicted").as_bool().unwrap_or(false),
            freq: e.get("freq").as_usize().unwrap_or(0) as u64,
        });
    }
    let qa = QaBank::from_entries(qa_limit, entries, next_id)?;

    // -- predictor history -------------------------------------------------
    let mut history = 0;
    for h in j.get("predictor").get("history").as_arr().unwrap_or(&[]) {
        if let Some(s) = h.as_str() {
            predictor.observe(s);
            history += 1;
        }
    }
    // arrival ticks (periodicity signal for prefetch forecasts); absent
    // in pre-scenario snapshots, which restore with an empty buffer
    for t in j
        .get("predictor")
        .get("arrival_ticks")
        .as_arr()
        .unwrap_or(&[])
    {
        if let Some(n) = t.as_usize() {
            predictor.observe_arrival(n as u64);
        }
    }
    // the replayed history equals the snapshot: nothing new to persist
    predictor.mark_clean();

    let report = RestoreReport {
        tree_nodes: tree.node_count(),
        tree_slices: tree.slice_count(),
        qa_entries: qa.len(),
        history,
        unreferenced_slices: unreferenced,
    };
    Ok(Some((tree, qa, report)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::llm::QkvTensor;

    fn tensor(tag: f32) -> QkvTensor {
        let mut t = QkvTensor::zeros(1, 4, 64);
        t.data[0] = tag;
        t
    }

    fn tmp_dir(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "percache_persist_{tag}_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn emb(x: f32, y: f32) -> Vec<f32> {
        let n = (x * x + y * y).sqrt().max(1e-9);
        vec![x / n, y / n, 0.0, 0.0]
    }

    #[test]
    fn save_load_roundtrips_the_whole_hierarchy() {
        let dir = tmp_dir("roundtrip");
        let limit = 1 << 20;
        let (snapshot_bytes, snapshot_qa) = {
            let mut store = SliceStore::disk(dir.clone()).unwrap();
            let mut tree = QkvTree::new(limit);
            tree.insert_path(&[10, 20], vec![tensor(1.0), tensor(2.0)], &mut store)
                .unwrap();
            let mut qa = QaBank::new(limit);
            qa.insert("alpha query", emb(1.0, 0.0), Some(vec![4, 5]), false);
            qa.insert("beta query", emb(0.0, 1.0), None, true);
            let mut pred = QueryPredictor::new(1);
            pred.observe("alpha query");
            pred.observe_arrival(3);
            pred.observe_arrival(9);
            save_state(&dir, &tree, &qa, &pred).unwrap();
            (tree.bytes_used(), qa.bytes_used())
        };
        let mut store = SliceStore::disk(dir.clone()).unwrap();
        let mut pred = QueryPredictor::new(1);
        let (mut tree, mut qa, rep) =
            load_state(&dir, &mut store, limit, limit, &mut pred)
                .unwrap()
                .expect("snapshot must be found");
        assert_eq!(rep.tree_slices, 2);
        assert_eq!(rep.qa_entries, 2);
        assert_eq!(rep.history, 1);
        assert_eq!(tree.bytes_used(), snapshot_bytes);
        assert_eq!(qa.bytes_used(), snapshot_qa);
        assert_eq!(tree.match_prefix(&[10, 20]).len(), 2);
        let hit = qa.match_query(&emb(1.0, 0.0), 0.85).expect("restored qa hit");
        assert_eq!(hit.1, vec![4, 5]);
        assert_eq!(pred.history_len(), 1);
        assert_eq!(
            pred.arrival_ticks(),
            &[3, 9],
            "arrival ticks must survive the snapshot"
        );
        assert!(!pred.is_dirty(), "restore leaves the predictor clean");
        tree.check_invariants().unwrap();
        qa.check_invariants().unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn snapshotter_skips_clean_saves_and_reuses_sections() {
        let dir = tmp_dir("incremental");
        let limit = 1 << 20;
        let mut store = SliceStore::disk(dir.clone()).unwrap();
        let mut tree = QkvTree::new(limit);
        let mut qa = QaBank::new(limit);
        let mut pred = QueryPredictor::new(1);
        tree.insert_path(&[10], vec![tensor(1.0)], &mut store).unwrap();
        qa.insert("alpha query", emb(1.0, 0.0), Some(vec![1]), false);
        let mut saver = Snapshotter::new();
        assert!(
            saver.save(&dir, &mut tree, &mut qa, &mut pred).unwrap(),
            "first save must write"
        );
        // nothing changed: the save is a complete no-op
        assert!(!saver.save(&dir, &mut tree, &mut qa, &mut pred).unwrap());
        assert_eq!(saver.skipped, 1);
        // dirty one section: rewrite, reusing the other two from cache
        qa.insert("beta query", emb(0.0, 1.0), None, true);
        assert!(saver.save(&dir, &mut tree, &mut qa, &mut pred).unwrap());
        assert!(
            saver.sections_reused >= 2,
            "clean sections must come from the cache ({})",
            saver.sections_reused
        );
        drop(store);
        // the snapshot on disk is complete and loadable
        let mut store = SliceStore::disk(dir.clone()).unwrap();
        let mut pred = QueryPredictor::new(1);
        let (tree, qa, _) = load_state(&dir, &mut store, limit, limit, &mut pred)
            .unwrap()
            .expect("snapshot must exist");
        assert_eq!(qa.len(), 2);
        assert_eq!(tree.slice_count(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_snapshot_purges_dangling_slices() {
        let dir = tmp_dir("nosnap");
        {
            let mut store = SliceStore::disk(dir.clone()).unwrap();
            store.put(tensor(1.0)).unwrap();
        }
        let mut store = SliceStore::disk(dir.clone()).unwrap();
        assert_eq!(store.count(), 1);
        let mut pred = QueryPredictor::new(1);
        let got = load_state(&dir, &mut store, 1 << 20, 1 << 20, &mut pred).unwrap();
        assert!(got.is_none());
        assert_eq!(store.count(), 0, "slices without a snapshot are purged");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_snapshot_is_rejected_not_discarded() {
        let dir = tmp_dir("badsnap");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join(STATE_FILE), "{broken").unwrap();
        let mut store = SliceStore::disk(dir.clone()).unwrap();
        let mut pred = QueryPredictor::new(1);
        assert!(load_state(&dir, &mut store, 1 << 20, 1 << 20, &mut pred).is_err());
        // wrong version too
        std::fs::write(
            dir.join(STATE_FILE),
            r#"{"magic":"percache-state","version":99,"tree":{"nodes":[]},"qa":{"next_id":1,"entries":[]},"predictor":{"history":[]}}"#,
        )
        .unwrap();
        assert!(load_state(&dir, &mut store, 1 << 20, 1 << 20, &mut pred).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn load_gcs_slices_newer_than_the_snapshot() {
        let dir = tmp_dir("gcnewer");
        {
            let mut store = SliceStore::disk(dir.clone()).unwrap();
            let mut tree = QkvTree::new(1 << 20);
            tree.insert_path(&[1], vec![tensor(1.0)], &mut store).unwrap();
            let qa = QaBank::new(1 << 20);
            let pred = QueryPredictor::new(1);
            save_state(&dir, &tree, &qa, &pred).unwrap();
            // a put committed after the snapshot (crash before re-save)
            store.put(tensor(9.0)).unwrap();
        }
        let mut store = SliceStore::disk(dir.clone()).unwrap();
        assert_eq!(store.count(), 2);
        let mut pred = QueryPredictor::new(1);
        let (tree, _qa, rep) = load_state(&dir, &mut store, 1 << 20, 1 << 20, &mut pred)
            .unwrap()
            .unwrap();
        assert_eq!(rep.unreferenced_slices, 1);
        assert_eq!(store.count(), 1);
        assert_eq!(tree.slice_count(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
