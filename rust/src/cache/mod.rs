//! The hierarchical cache (the paper's central data structure):
//!
//! * [`qa_bank`] — layer 1: semantic query→answer cache (skips all
//!   inference on a hit);
//! * [`qkv_tree`] — layer 2: prefix tree of per-chunk QKV tensor slices
//!   (skips Q/K/V projections of cached prompt prefixes);
//! * [`slicer`] — splits whole-prompt QKV tensors into tree-node slices;
//! * [`store`] — slice persistence (memory or on-disk, load-on-demand,
//!   with a versioned manifest so directories reopen safely);
//! * [`persist`] — snapshot/restore of tree structure, QA entries and
//!   predictor history (warm restart, DESIGN.md §10).

pub mod persist;
pub mod qa_bank;
pub mod qkv_tree;
pub mod slicer;
pub mod store;

pub use persist::{load_state, save_state, RestoreReport, Snapshotter};
pub use qa_bank::{QaBank, QaEntry, QaId, QaMatch};
pub use qkv_tree::{NodeSnapshot, PrefixMatch, QkvTree, SegKey};
pub use slicer::{slice_prompt, SegmentSlice};
pub use store::{Backend, SliceId, SliceStore};
