//! The QKV cache prefix tree (paper §4.1.1 / §4.2.2, after RAGCache [26]).
//!
//! Each node is one knowledge-bank segment (system prompt or chunk),
//! keyed by its content hash; each root-to-node path is a chunk list some
//! prompt used.  A node *may* hold a QKV tensor slice (it can be evicted
//! independently); prefix matching walks from the root and stops at the
//! first key miss or slice-less node, mirroring the paper's sequential
//! match ("continues until a mismatch is encountered").
//!
//! Eviction is LFU over slice-bearing nodes (paper keeps a retrieval
//! counter per cached layer), tie-broken deepest-first so shallow prefixes
//! — which serve the most paths — survive longest.

use std::collections::HashMap;

use anyhow::Result;

use super::store::{SliceId, SliceStore};
use crate::llm::QkvTensor;

/// Content key of a segment (fnv1a64 of the raw text).
pub type SegKey = u64;

#[derive(Debug)]
struct Node {
    key: SegKey,
    depth: usize,
    slice: Option<SliceId>,
    slice_bytes: usize,
    children: HashMap<SegKey, usize>,
    freq: u64,
}

/// Serializable view of one tree node (see [`QkvTree::export`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NodeSnapshot {
    pub key: SegKey,
    /// Index of the parent within the snapshot vec (None = root).
    pub parent: Option<usize>,
    pub slice: Option<SliceId>,
    pub freq: u64,
}

/// Result of a prefix match.
#[derive(Debug, Clone)]
pub struct PrefixMatch {
    /// Matched slice ids, in path order (contiguous from the root).
    pub slices: Vec<SliceId>,
}

impl PrefixMatch {
    pub fn len(&self) -> usize {
        self.slices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slices.is_empty()
    }
}

#[derive(Debug)]
pub struct QkvTree {
    nodes: Vec<Node>,
    roots: HashMap<SegKey, usize>,
    byte_limit: usize,
    bytes_used: usize,
    /// Persisted state (structure, slices, LFU freqs) changed since the
    /// last [`Self::mark_clean`] — incremental snapshots skip clean trees.
    dirty: bool,
    /// Eviction/metric counters.
    pub evictions: u64,
    pub hits: u64,
    pub misses: u64,
}

impl QkvTree {
    pub fn new(byte_limit: usize) -> Self {
        QkvTree {
            nodes: Vec::new(),
            roots: HashMap::new(),
            byte_limit,
            bytes_used: 0,
            dirty: false,
            evictions: 0,
            hits: 0,
            misses: 0,
        }
    }

    /// Whether persisted state changed since the last [`Self::mark_clean`].
    pub fn is_dirty(&self) -> bool {
        self.dirty
    }

    /// Mark the current state as snapshotted (persistence internal).
    pub fn mark_clean(&mut self) {
        self.dirty = false;
    }

    pub fn bytes_used(&self) -> usize {
        self.bytes_used
    }

    pub fn byte_limit(&self) -> usize {
        self.byte_limit
    }

    /// Change the storage budget at runtime (Fig 15c / Fig 18); shrinking
    /// evicts immediately.
    pub fn set_byte_limit(&mut self, limit: usize, store: &mut SliceStore) {
        self.byte_limit = limit;
        self.enforce_budget(store, &[]);
    }

    pub fn slice_count(&self) -> usize {
        self.nodes.iter().filter(|n| n.slice.is_some()).count()
    }

    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }

    /// Longest cached prefix for a path of segment keys.  Stops at the
    /// first missing node *or* missing slice; bumps LFU counters on the
    /// matched nodes.
    pub fn match_prefix(&mut self, keys: &[SegKey]) -> PrefixMatch {
        let mut slices = Vec::new();
        let mut level = &self.roots;
        let mut matched_nodes = Vec::new();
        for key in keys {
            match level.get(key) {
                Some(&idx) if self.nodes[idx].slice.is_some() => {
                    slices.push(self.nodes[idx].slice.unwrap());
                    matched_nodes.push(idx);
                    level = &self.nodes[idx].children;
                }
                _ => break,
            }
        }
        if !matched_nodes.is_empty() {
            // persisted LFU freqs move
            self.dirty = true;
        }
        for idx in matched_nodes {
            self.nodes[idx].freq += 1;
        }
        if slices.is_empty() {
            self.misses += 1;
        } else {
            self.hits += 1;
        }
        PrefixMatch { slices }
    }

    /// Longest structural prefix (nodes exist, slices may be evicted) —
    /// used by the QA→QKV conversion to find restore candidates.
    pub fn structural_match(&self, keys: &[SegKey]) -> usize {
        let mut level = &self.roots;
        let mut n = 0;
        for key in keys {
            match level.get(key) {
                Some(&idx) => {
                    n += 1;
                    level = &self.nodes[idx].children;
                }
                None => break,
            }
        }
        n
    }

    /// How many leading segments of `keys` have *slices* present, without
    /// touching LFU counters (scheduler probes).
    pub fn cached_prefix_len(&self, keys: &[SegKey]) -> usize {
        let mut level = &self.roots;
        let mut n = 0;
        for key in keys {
            match level.get(key) {
                Some(&idx) if self.nodes[idx].slice.is_some() => {
                    n += 1;
                    level = &self.nodes[idx].children;
                }
                _ => break,
            }
        }
        n
    }

    /// Insert (or refresh) a path of segments with their QKV slices.
    /// Existing nodes keep their stored slice (first write wins — tensors
    /// for the same content at the same depth are identical by
    /// construction); missing slices are (re)attached.
    pub fn insert_path(
        &mut self,
        keys: &[SegKey],
        slices: Vec<QkvTensor>,
        store: &mut SliceStore,
    ) -> Result<()> {
        self.insert_path_shared(keys, slices, &[], store)
    }

    /// [`Self::insert_path`] with per-segment share-eligibility: segments
    /// flagged `true` may be interned in the cross-tenant slice pool
    /// (when the store has one attached) instead of stored privately.
    /// `shared` may be shorter than `keys` — missing flags mean private,
    /// so `&[]` is exactly the single-tenant insert path.
    pub fn insert_path_shared(
        &mut self,
        keys: &[SegKey],
        slices: Vec<QkvTensor>,
        shared: &[bool],
        store: &mut SliceStore,
    ) -> Result<()> {
        anyhow::ensure!(
            keys.len() == slices.len(),
            "keys/slices length mismatch: {} vs {}",
            keys.len(),
            slices.len()
        );
        let mut inserted_nodes = Vec::with_capacity(keys.len());
        let mut parent: Option<usize> = None;
        for (depth, (key, tensor)) in keys.iter().zip(slices).enumerate() {
            let level = match parent {
                None => &mut self.roots,
                Some(p) => &mut self.nodes[p].children,
            };
            let idx = match level.get(key) {
                Some(&idx) => idx,
                None => {
                    let idx = self.nodes.len();
                    match parent {
                        None => {
                            self.roots.insert(*key, idx);
                        }
                        Some(p) => {
                            self.nodes[p].children.insert(*key, idx);
                        }
                    }
                    self.nodes.push(Node {
                        key: *key,
                        depth,
                        slice: None,
                        slice_bytes: 0,
                        children: HashMap::new(),
                        freq: 0,
                    });
                    self.dirty = true;
                    idx
                }
            };
            if self.nodes[idx].slice.is_none() {
                let share = shared.get(depth).copied().unwrap_or(false);
                let (sid, bytes) = store.put_keyed(*key, tensor, share)?;
                self.nodes[idx].slice = Some(sid);
                self.nodes[idx].slice_bytes = bytes;
                self.bytes_used += bytes;
                self.dirty = true;
            }
            inserted_nodes.push(idx);
            parent = Some(idx);
        }
        self.enforce_budget(store, &inserted_nodes);
        Ok(())
    }

    /// LFU eviction until under budget.  `protect` shields the nodes of
    /// the path just inserted (otherwise a large insert could evict
    /// itself mid-flight).  If everything is protected, protection is
    /// dropped (budget wins).
    fn enforce_budget(&mut self, store: &mut SliceStore, protect: &[usize]) {
        while self.bytes_used > self.byte_limit {
            let candidate = self.pick_eviction(protect).or_else(|| self.pick_eviction(&[]));
            match candidate {
                Some(idx) => self.evict_slice(idx, store),
                None => break, // nothing evictable
            }
        }
    }

    fn pick_eviction(&self, protect: &[usize]) -> Option<usize> {
        self.nodes
            .iter()
            .enumerate()
            .filter(|(i, n)| n.slice.is_some() && !protect.contains(i))
            .min_by(|(_, a), (_, b)| {
                a.freq
                    .cmp(&b.freq)
                    .then(b.depth.cmp(&a.depth)) // deeper evicts first
                    .then(a.key.cmp(&b.key))
            })
            .map(|(i, _)| i)
    }

    fn evict_slice(&mut self, idx: usize, store: &mut SliceStore) {
        if let Some(sid) = self.nodes[idx].slice.take() {
            store.remove(sid);
            self.bytes_used -= self.nodes[idx].slice_bytes;
            self.nodes[idx].slice_bytes = 0;
            self.evictions += 1;
            self.dirty = true;
        }
    }

    /// Serializable view of the tree structure for persistence
    /// (DESIGN.md §10): nodes in an order where every parent precedes its
    /// children, with parent links as indices into the returned vec.
    /// Slice byte sizes are re-derived from the store on restore, so only
    /// ids are exported.
    pub fn export(&self) -> Vec<NodeSnapshot> {
        let mut out = Vec::with_capacity(self.nodes.len());
        let mut stack: Vec<(usize, Option<usize>)> =
            self.roots.values().map(|&i| (i, None)).collect();
        while let Some((idx, parent)) = stack.pop() {
            let snap_idx = out.len();
            let n = &self.nodes[idx];
            out.push(NodeSnapshot {
                key: n.key,
                parent,
                slice: n.slice,
                freq: n.freq,
            });
            for &c in n.children.values() {
                stack.push((c, Some(snap_idx)));
            }
        }
        out
    }

    /// Rebuild a tree from an [`Self::export`] snapshot.  Nodes whose
    /// slice id is no longer present in `store` (evicted or lost between
    /// snapshot and restore) keep their structure but drop the slice —
    /// exactly the state `match_prefix` already tolerates.  The budget is
    /// enforced through the normal LFU path before returning.
    pub fn restore(
        byte_limit: usize,
        snapshot: &[NodeSnapshot],
        store: &mut SliceStore,
    ) -> Result<Self> {
        let mut tree = QkvTree::new(byte_limit);
        let mut seen_slices = std::collections::HashSet::new();
        for (i, s) in snapshot.iter().enumerate() {
            let depth = match s.parent {
                None => 0,
                Some(p) => {
                    anyhow::ensure!(
                        p < i,
                        "snapshot node {i}: parent {p} does not precede it"
                    );
                    tree.nodes[p].depth + 1
                }
            };
            let idx = tree.nodes.len();
            let fresh = match s.parent {
                None => tree.roots.insert(s.key, idx).is_none(),
                Some(p) => tree.nodes[p].children.insert(s.key, idx).is_none(),
            };
            anyhow::ensure!(fresh, "snapshot node {i}: duplicate key {:#x}", s.key);
            if let Some(sid) = s.slice {
                // two nodes sharing a slice id would double-count bytes
                // and leave a dangling id when one of them is evicted
                anyhow::ensure!(
                    seen_slices.insert(sid),
                    "snapshot node {i}: duplicate slice id {sid}"
                );
            }
            let (slice, slice_bytes) = match s.slice {
                Some(sid) => match store.size_of(sid) {
                    Some(b) => (Some(sid), b),
                    None => (None, 0),
                },
                None => (None, 0),
            };
            tree.bytes_used += slice_bytes;
            tree.nodes.push(Node {
                key: s.key,
                depth,
                slice,
                slice_bytes,
                children: HashMap::new(),
                freq: s.freq,
            });
        }
        tree.enforce_budget(store, &[]);
        tree.check_invariants()?;
        Ok(tree)
    }

    /// Slice ids currently attached to nodes (persistence-time GC of
    /// unreferenced store entries).
    pub fn slice_ids(&self) -> Vec<SliceId> {
        self.nodes.iter().filter_map(|n| n.slice).collect()
    }

    /// Detach a slice the store could not serve (e.g. quarantined after
    /// a checksum mismatch) so future matches stop treating it as
    /// cached.  The node structure survives — exactly the state an LFU
    /// eviction leaves behind.  Returns false if no node held the id.
    pub fn drop_slice(&mut self, sid: SliceId, store: &mut SliceStore) -> bool {
        let idx = match self.nodes.iter().position(|n| n.slice == Some(sid)) {
            None => return false,
            Some(i) => i,
        };
        self.nodes[idx].slice = None;
        self.bytes_used -= self.nodes[idx].slice_bytes;
        self.nodes[idx].slice_bytes = 0;
        self.dirty = true;
        // release whatever accounting the store still holds (a
        // quarantined slice is usually already gone — this is a no-op)
        store.remove(sid);
        true
    }

    /// Copy-on-write: make the slice at the end of `keys` private (deep
    /// copy out of the shared pool; see [`SliceStore::make_private`]),
    /// recharging this tree's budget with the slice's full byte size and
    /// re-enforcing it.  Returns false when the path or slice is absent.
    pub fn privatize(&mut self, keys: &[SegKey], store: &mut SliceStore) -> Result<bool> {
        let mut level = &self.roots;
        let mut idx = None;
        for key in keys {
            match level.get(key) {
                Some(&i) => {
                    idx = Some(i);
                    level = &self.nodes[i].children;
                }
                None => return Ok(false),
            }
        }
        let idx = match idx {
            None => return Ok(false),
            Some(i) => i,
        };
        let sid = match self.nodes[idx].slice {
            None => return Ok(false),
            Some(s) => s,
        };
        let new_bytes = store.make_private(sid)?;
        let old_bytes = self.nodes[idx].slice_bytes;
        self.nodes[idx].slice_bytes = new_bytes;
        self.bytes_used = self.bytes_used - old_bytes + new_bytes;
        self.dirty = true;
        self.enforce_budget(store, &[idx]);
        Ok(true)
    }

    /// Internal-consistency check for property tests: byte accounting must
    /// equal the sum over slice-bearing nodes, and every child edge must
    /// point at a node of depth parent+1 with the matching key.
    pub fn check_invariants(&self) -> Result<()> {
        let sum: usize = self
            .nodes
            .iter()
            .filter(|n| n.slice.is_some())
            .map(|n| n.slice_bytes)
            .sum();
        anyhow::ensure!(
            sum == self.bytes_used,
            "byte accounting drift: sum={sum} used={}",
            self.bytes_used
        );
        anyhow::ensure!(
            self.bytes_used <= self.byte_limit || self.slice_count() == 0,
            "over budget with evictable slices"
        );
        for (key, &idx) in &self.roots {
            anyhow::ensure!(self.nodes[idx].key == *key, "root key mismatch");
            anyhow::ensure!(self.nodes[idx].depth == 0, "root depth != 0");
        }
        for node in &self.nodes {
            for (key, &cidx) in &node.children {
                anyhow::ensure!(self.nodes[cidx].key == *key, "child key mismatch");
                anyhow::ensure!(
                    self.nodes[cidx].depth == node.depth + 1,
                    "child depth mismatch"
                );
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tensor(tag: f32) -> QkvTensor {
        let mut t = QkvTensor::zeros(1, 4, 64);
        t.data[0] = tag;
        t
    }

    fn bytes_one() -> usize {
        tensor(0.0).byte_size() + 16
    }

    #[test]
    fn insert_then_match_full_path() {
        let mut store = SliceStore::memory();
        let mut tree = QkvTree::new(10 * bytes_one());
        tree.insert_path(&[1, 2, 3], vec![tensor(1.0), tensor(2.0), tensor(3.0)], &mut store)
            .unwrap();
        let m = tree.match_prefix(&[1, 2, 3]);
        assert_eq!(m.len(), 3);
        assert_eq!(store.get(m.slices[0]).unwrap().data[0], 1.0);
        tree.check_invariants().unwrap();
    }

    #[test]
    fn partial_match_stops_at_divergence() {
        let mut store = SliceStore::memory();
        let mut tree = QkvTree::new(10 * bytes_one());
        tree.insert_path(&[1, 2, 3], vec![tensor(1.0), tensor(2.0), tensor(3.0)], &mut store)
            .unwrap();
        assert_eq!(tree.match_prefix(&[1, 2, 99]).len(), 2);
        assert_eq!(tree.match_prefix(&[1, 99]).len(), 1);
        assert_eq!(tree.match_prefix(&[99]).len(), 0);
        // order matters: [2,1] is not a prefix
        assert_eq!(tree.match_prefix(&[2, 1]).len(), 0);
    }

    #[test]
    fn shared_prefix_merges() {
        let mut store = SliceStore::memory();
        let mut tree = QkvTree::new(10 * bytes_one());
        tree.insert_path(&[1, 2], vec![tensor(1.0), tensor(2.0)], &mut store).unwrap();
        tree.insert_path(&[1, 5], vec![tensor(1.0), tensor(5.0)], &mut store).unwrap();
        // node 1 is shared: 3 slices total, not 4
        assert_eq!(tree.slice_count(), 3);
        assert_eq!(tree.match_prefix(&[1, 5]).len(), 2);
        tree.check_invariants().unwrap();
    }

    #[test]
    fn eviction_respects_lfu_and_budget() {
        let mut store = SliceStore::memory();
        let mut tree = QkvTree::new(3 * bytes_one());
        tree.insert_path(&[1, 2, 3], vec![tensor(1.0), tensor(2.0), tensor(3.0)], &mut store)
            .unwrap();
        // heat up the prefix
        for _ in 0..5 {
            tree.match_prefix(&[1, 2]);
        }
        // inserting a new root forces one eviction; node 3 (cold, deepest)
        // must be the victim
        tree.insert_path(&[9], vec![tensor(9.0)], &mut store).unwrap();
        assert!(tree.bytes_used() <= tree.byte_limit());
        assert_eq!(tree.match_prefix(&[1, 2, 3]).len(), 2, "3 evicted");
        assert_eq!(tree.match_prefix(&[9]).len(), 1);
        assert_eq!(tree.evictions, 1);
        tree.check_invariants().unwrap();
    }

    #[test]
    fn match_stops_at_evicted_slice_then_restore_reattaches() {
        let mut store = SliceStore::memory();
        let mut tree = QkvTree::new(2 * bytes_one());
        tree.insert_path(&[1, 2], vec![tensor(1.0), tensor(2.0)], &mut store).unwrap();
        for _ in 0..3 {
            tree.match_prefix(&[1]);
        }
        tree.insert_path(&[7], vec![tensor(7.0)], &mut store).unwrap(); // evicts node 2
        assert_eq!(tree.match_prefix(&[1, 2]).len(), 1);
        assert_eq!(tree.structural_match(&[1, 2]), 2, "node survives eviction");
        // restore: re-insert the same path reattaches the missing slice
        tree.set_byte_limit(3 * bytes_one(), &mut store);
        tree.insert_path(&[1, 2], vec![tensor(1.0), tensor(2.0)], &mut store).unwrap();
        assert_eq!(tree.match_prefix(&[1, 2]).len(), 2);
        tree.check_invariants().unwrap();
    }

    #[test]
    fn shrinking_budget_evicts_immediately() {
        let mut store = SliceStore::memory();
        let mut tree = QkvTree::new(4 * bytes_one());
        tree.insert_path(&[1, 2, 3, 4],
                         vec![tensor(1.0), tensor(2.0), tensor(3.0), tensor(4.0)],
                         &mut store).unwrap();
        assert_eq!(tree.slice_count(), 4);
        tree.set_byte_limit(2 * bytes_one(), &mut store);
        assert_eq!(tree.slice_count(), 2);
        assert!(tree.bytes_used() <= 2 * bytes_one());
        tree.check_invariants().unwrap();
    }

    #[test]
    fn export_restore_roundtrips_structure_and_slices() {
        let mut store = SliceStore::memory();
        let mut tree = QkvTree::new(10 * bytes_one());
        tree.insert_path(&[1, 2, 3], vec![tensor(1.0), tensor(2.0), tensor(3.0)], &mut store)
            .unwrap();
        tree.insert_path(&[1, 5], vec![tensor(1.0), tensor(5.0)], &mut store).unwrap();
        for _ in 0..4 {
            tree.match_prefix(&[1, 2]);
        }
        let snap = tree.export();
        assert_eq!(snap.len(), tree.node_count());
        let restored = QkvTree::restore(tree.byte_limit(), &snap, &mut store).unwrap();
        assert_eq!(restored.node_count(), tree.node_count());
        assert_eq!(restored.slice_count(), tree.slice_count());
        assert_eq!(restored.bytes_used(), tree.bytes_used());
        let mut r = restored;
        assert_eq!(r.match_prefix(&[1, 2, 3]).len(), 3);
        assert_eq!(r.match_prefix(&[1, 5]).len(), 2);
        r.check_invariants().unwrap();
    }

    #[test]
    fn restore_drops_slices_missing_from_store() {
        let mut store = SliceStore::memory();
        let mut tree = QkvTree::new(10 * bytes_one());
        tree.insert_path(&[1, 2], vec![tensor(1.0), tensor(2.0)], &mut store).unwrap();
        let snap = tree.export();
        // simulate a slice lost between snapshot and restore
        let victim = snap.iter().find(|n| n.parent.is_some()).unwrap().slice.unwrap();
        store.remove(victim);
        let restored = QkvTree::restore(tree.byte_limit(), &snap, &mut store).unwrap();
        assert_eq!(restored.node_count(), 2, "structure survives");
        assert_eq!(restored.slice_count(), 1, "lost slice dropped");
        restored.check_invariants().unwrap();
    }

    #[test]
    fn restore_rejects_malformed_snapshots() {
        let mut store = SliceStore::memory();
        // parent pointing forward
        let bad = vec![NodeSnapshot { key: 1, parent: Some(1), slice: None, freq: 0 }];
        assert!(QkvTree::restore(1 << 20, &bad, &mut store).is_err());
        // duplicate root key
        let dup = vec![
            NodeSnapshot { key: 7, parent: None, slice: None, freq: 0 },
            NodeSnapshot { key: 7, parent: None, slice: None, freq: 0 },
        ];
        assert!(QkvTree::restore(1 << 20, &dup, &mut store).is_err());
        // duplicate slice id across two nodes
        let (sid, _) = store.put(tensor(1.0)).unwrap();
        let dup_slice = vec![
            NodeSnapshot { key: 1, parent: None, slice: Some(sid), freq: 0 },
            NodeSnapshot { key: 2, parent: None, slice: Some(sid), freq: 0 },
        ];
        assert!(QkvTree::restore(1 << 20, &dup_slice, &mut store).is_err());
    }

    #[test]
    fn dirty_tracks_mutations_and_clears() {
        let mut store = SliceStore::memory();
        let mut tree = QkvTree::new(10 * bytes_one());
        assert!(!tree.is_dirty(), "fresh tree is clean");
        tree.insert_path(&[1], vec![tensor(1.0)], &mut store).unwrap();
        assert!(tree.is_dirty());
        tree.mark_clean();
        // a miss touches nothing persisted
        tree.match_prefix(&[9]);
        assert!(!tree.is_dirty());
        // a hit bumps persisted LFU freqs
        tree.match_prefix(&[1]);
        assert!(tree.is_dirty());
        tree.mark_clean();
        // restoring a snapshot that needed no evictions yields a clean tree
        let snap = tree.export();
        let restored = QkvTree::restore(tree.byte_limit(), &snap, &mut store).unwrap();
        assert!(!restored.is_dirty());
    }

    fn pooled_store(cap_slices: usize, tenant: u32) -> SliceStore {
        let handle = crate::pool::PoolHandle::new(
            crate::pool::SlicePool::memory(cap_slices * bytes_one()).shared(),
            tenant,
        );
        SliceStore::memory_with_pool(handle)
    }

    #[test]
    fn shared_inserts_charge_handles_not_payloads() {
        let mut store = pooled_store(8, 0);
        let mut tree = QkvTree::new(10 * bytes_one());
        tree.insert_path_shared(
            &[1, 2],
            vec![tensor(1.0), tensor(2.0)],
            &[true, false],
            &mut store,
        )
        .unwrap();
        let handle = crate::pool::HANDLE_BYTES;
        assert_eq!(tree.bytes_used(), handle + bytes_one());
        assert_eq!(store.pooled_count(), 1);
        assert_eq!(tree.match_prefix(&[1, 2]).len(), 2);
        // shard invariant: every tree slice has a store entry
        assert_eq!(store.count(), tree.slice_count());
        tree.check_invariants().unwrap();
    }

    #[test]
    fn evicting_pooled_slice_releases_the_reference() {
        let mut store = pooled_store(8, 0);
        let mut tree = QkvTree::new(10 * bytes_one());
        tree.insert_path_shared(&[1], vec![tensor(1.0)], &[true], &mut store)
            .unwrap();
        let sid = tree.slice_ids()[0];
        let key = store.pool_key_of(sid).unwrap();
        tree.set_byte_limit(0, &mut store);
        assert_eq!(tree.slice_count(), 0);
        assert_eq!(store.pooled_count(), 0, "pool ref released on eviction");
        assert!(store.pool_probe(key).is_some(), "entry stays warm, zero-ref");
        tree.check_invariants().unwrap();
    }

    #[test]
    fn privatize_recharges_budget_and_unshares() {
        let mut store = pooled_store(8, 0);
        let mut tree = QkvTree::new(10 * bytes_one());
        tree.insert_path_shared(
            &[1, 2],
            vec![tensor(1.0), tensor(2.0)],
            &[true, true],
            &mut store,
        )
        .unwrap();
        let before = tree.bytes_used();
        assert!(tree.privatize(&[1, 2], &mut store).unwrap());
        assert_eq!(
            tree.bytes_used(),
            before - crate::pool::HANDLE_BYTES + bytes_one(),
            "budget recharged with the full private size"
        );
        assert_eq!(store.pooled_count(), 1, "only the targeted slice copied");
        // the private copy still serves matches, and invariants hold
        assert_eq!(tree.match_prefix(&[1, 2]).len(), 2);
        tree.check_invariants().unwrap();
        // absent paths / sliceless nodes are a clean false
        assert!(!tree.privatize(&[1, 99], &mut store).unwrap());
    }

    #[test]
    fn drop_slice_degrades_to_structural_node() {
        let mut store = SliceStore::memory();
        let mut tree = QkvTree::new(10 * bytes_one());
        tree.insert_path(&[1, 2], vec![tensor(1.0), tensor(2.0)], &mut store)
            .unwrap();
        let sid = tree.match_prefix(&[1, 2]).slices[1];
        assert!(tree.drop_slice(sid, &mut store));
        assert_eq!(tree.match_prefix(&[1, 2]).len(), 1, "slice gone");
        assert_eq!(tree.structural_match(&[1, 2]), 2, "structure survives");
        assert!(!tree.drop_slice(sid, &mut store), "second drop is a no-op");
        tree.check_invariants().unwrap();
    }

    #[test]
    fn empty_tree_matches_nothing() {
        let mut tree = QkvTree::new(1 << 20);
        assert!(tree.match_prefix(&[1, 2]).is_empty());
        assert_eq!(tree.structural_match(&[1]), 0);
        tree.check_invariants().unwrap();
    }
}
