//! QKV slice storage backend: in-memory or on-disk (load-on-demand, like
//! the paper's implementation — Table 1 measures slice loading separately
//! from matching, which this split makes possible).
//!
//! Disk format per slice: 16-byte header (magic, layers, d_model, seq as
//! u32 LE) followed by raw f32 LE data.
//!
//! A disk directory additionally carries a versioned manifest
//! (`store_manifest.json`: next_id + per-slice id/bytes/checksum) so that
//! reopening an existing directory *resumes* — ids continue after the
//! highest committed id instead of restarting at 1 and overwriting live
//! slice files, entries are validated against the files on disk, and
//! slice files with no manifest entry (a crash between the data write and
//! the manifest commit) are garbage-collected.  The manifest is written
//! atomically (tmp + rename) after every mutation; the slice file is
//! written first, so the manifest only ever references complete files.
//! See DESIGN.md §10 for the full on-disk layout.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::llm::QkvTensor;
use crate::pool::{PoolHandle, PoolKey, HANDLE_BYTES};
use crate::tokenizer::fnv1a64;
use crate::util::json::Json;

pub type SliceId = u64;

const MAGIC: u32 = 0x51_4B_56_01; // "QKV\x01"

/// Manifest schema version; readers reject anything else.
pub const MANIFEST_VERSION: usize = 1;
/// Manifest file name inside a slice directory.
pub const MANIFEST_FILE: &str = "store_manifest.json";
/// Manifest magic string (distinguishes it from unrelated JSON).
const MANIFEST_MAGIC: &str = "percache-slices";

#[derive(Debug, Clone)]
pub enum Backend {
    Memory,
    Disk(PathBuf),
}

/// Slice store with exact byte accounting (the tree enforces the budget).
pub struct SliceStore {
    backend: Backend,
    mem: HashMap<SliceId, Arc<QkvTensor>>,
    sizes: HashMap<SliceId, usize>,
    /// fnv1a64 over the slice file bytes (disk backend only).
    checksums: HashMap<SliceId, u64>,
    /// Slices interned in the shared pool: id → content key.  Their
    /// `sizes` entry is [`HANDLE_BYTES`]; the payload lives in the pool.
    pooled: HashMap<SliceId, PoolKey>,
    pool: Option<PoolHandle>,
    next_id: SliceId,
    /// Counters for Table 1-style reporting.
    pub loads: u64,
    pub stores: u64,
    /// Unreferenced/invalid slice files removed while (re)opening a dir.
    pub orphans_removed: u64,
    /// Slices dropped on a checksum mismatch (first failed `get`).
    pub quarantined: u64,
}

impl SliceStore {
    pub fn memory() -> Self {
        Self::new(Backend::Memory)
    }

    /// Open (or create) an on-disk store.  An existing directory is
    /// *resumed* from its manifest: ids continue after the highest
    /// committed id, committed slices stay readable, and stray slice
    /// files without a manifest entry are garbage-collected.  A present
    /// but unreadable/incompatible manifest is an error — never silently
    /// clobbered.
    pub fn disk(dir: PathBuf) -> Result<Self> {
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating slice dir {}", dir.display()))?;
        let mut store = Self::new(Backend::Disk(dir));
        store.open_dir()?;
        Ok(store)
    }

    /// Like [`Self::disk`], but attached to the cross-tenant slice pool:
    /// manifest entries tagged with a pool key re-acquire their pool
    /// references (the per-tenant refcount rebuild of a warm restart);
    /// tagged entries whose key the pool no longer holds are dropped.
    pub fn disk_with_pool(dir: PathBuf, pool: PoolHandle) -> Result<Self> {
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating slice dir {}", dir.display()))?;
        let mut store = Self::new(Backend::Disk(dir));
        store.pool = Some(pool);
        store.open_dir()?;
        Ok(store)
    }

    /// Memory backend attached to the cross-tenant slice pool.
    pub fn memory_with_pool(pool: PoolHandle) -> Self {
        let mut store = Self::new(Backend::Memory);
        store.pool = Some(pool);
        store
    }

    fn new(backend: Backend) -> Self {
        SliceStore {
            backend,
            mem: HashMap::new(),
            sizes: HashMap::new(),
            checksums: HashMap::new(),
            pooled: HashMap::new(),
            pool: None,
            next_id: 1,
            loads: 0,
            stores: 0,
            orphans_removed: 0,
            quarantined: 0,
        }
    }

    /// Whether a shared pool is attached (pooling enabled for this store).
    pub fn has_pool(&self) -> bool {
        self.pool.is_some()
    }

    /// Pool probe for position-aware reuse: the chunk's KV if the shared
    /// pool holds it, composable at any prompt offset.  None when no
    /// pool is attached or the key isn't resident.
    pub fn pool_probe(&self, key: PoolKey) -> Option<Arc<QkvTensor>> {
        self.pool.as_ref()?.probe(key)
    }

    /// Content key of a pooled slice (None for private slices).
    pub fn pool_key_of(&self, id: SliceId) -> Option<PoolKey> {
        self.pooled.get(&id).copied()
    }

    /// Number of this store's slices that live in the shared pool.
    pub fn pooled_count(&self) -> usize {
        self.pooled.len()
    }

    /// Disk directory backing this store (None for the memory backend).
    pub fn dir(&self) -> Option<&Path> {
        match &self.backend {
            Backend::Memory => None,
            Backend::Disk(d) => Some(d),
        }
    }

    fn path(&self, id: SliceId) -> Option<PathBuf> {
        self.dir().map(|dir| dir.join(slice_file_name(id)))
    }

    /// Load state from an existing slice directory (see [`Self::disk`]).
    fn open_dir(&mut self) -> Result<()> {
        let dir = match self.dir() {
            None => return Ok(()),
            Some(d) => d.to_path_buf(),
        };
        let manifest_path = dir.join(MANIFEST_FILE);
        if manifest_path.exists() {
            let text = std::fs::read_to_string(&manifest_path)
                .with_context(|| format!("reading {}", manifest_path.display()))?;
            self.load_manifest(&text)
                .with_context(|| format!("invalid slice-store manifest {}", manifest_path.display()))?;
            self.validate_entries()?;
        } else {
            // Pre-manifest (or brand-new) directory: adopt whatever valid
            // slice files exist instead of clobbering them.
            self.rebuild_from_files(&dir)?;
        }
        self.collect_orphans(&dir)?;
        let adopted: usize = self.sizes.values().sum();
        if adopted != 0 {
            crate::obs_gauge!("store.resident_bytes").add(adopted as i64);
        }
        // Commit the (possibly repaired) view so the directory is
        // consistent even if the process dies before the first put.
        self.write_manifest()
    }

    fn load_manifest(&mut self, text: &str) -> Result<()> {
        let j = Json::parse(text).context("parsing json")?;
        anyhow::ensure!(
            j.get("magic").as_str() == Some(MANIFEST_MAGIC),
            "missing or wrong magic (want {MANIFEST_MAGIC:?})"
        );
        let version = j.get("version").as_usize().context("missing version")?;
        anyhow::ensure!(
            version == MANIFEST_VERSION,
            "unsupported manifest version {version} (reader supports {MANIFEST_VERSION})"
        );
        let next = j.get("next_id").as_usize().context("missing next_id")? as SliceId;
        anyhow::ensure!(next >= 1, "next_id must be >= 1");
        let slices = j.get("slices").as_arr().context("missing slices array")?;
        for e in slices {
            let id = e.get("id").as_usize().context("slice entry missing id")? as SliceId;
            let bytes = e.get("bytes").as_usize().context("slice entry missing bytes")?;
            let sum_hex = e
                .get("checksum")
                .as_str()
                .context("slice entry missing checksum")?;
            let sum = u64::from_str_radix(sum_hex, 16)
                .with_context(|| format!("bad checksum hex {sum_hex:?}"))?;
            anyhow::ensure!(
                id >= 1 && id < next,
                "slice id {id} out of range (next_id {next})"
            );
            anyhow::ensure!(
                !self.sizes.contains_key(&id),
                "duplicate slice id {id}"
            );
            if let Some(key_hex) = e.get("pool").as_str() {
                // pooled handle: payload lives in the shared pool.
                // Re-acquire the reference; a pool that dropped the key
                // (or no attached pool) just shrinks the warm cache.
                let key = PoolKey::from_str_radix(key_hex, 16)
                    .with_context(|| format!("bad pool key hex {key_hex:?}"))?;
                if let Some(p) = &self.pool {
                    if p.acquire(key).is_some() {
                        self.sizes.insert(id, HANDLE_BYTES);
                        self.pooled.insert(id, key);
                    }
                }
                continue;
            }
            self.sizes.insert(id, bytes);
            self.checksums.insert(id, sum);
        }
        self.next_id = next;
        Ok(())
    }

    /// Cross-check manifest entries against the files on disk.  An entry
    /// whose file is missing or has the wrong length (a torn write / lost
    /// file) is dropped from the store — it never shadows a fresh insert.
    fn validate_entries(&mut self) -> Result<()> {
        let ids: Vec<SliceId> = self.sizes.keys().copied().collect();
        for id in ids {
            if self.pooled.contains_key(&id) {
                continue; // no local file: payload is in the pool
            }
            let p = self.path(id).expect("disk backend");
            let ok = match std::fs::metadata(&p) {
                Ok(m) => m.len() as usize == self.sizes[&id],
                Err(_) => false,
            };
            if !ok {
                self.sizes.remove(&id);
                self.checksums.remove(&id);
                let _ = std::fs::remove_file(&p);
                self.orphans_removed += 1;
            }
        }
        Ok(())
    }

    /// Adopt slice files from a directory that predates the manifest:
    /// ids are recovered from the file names, sizes/checksums from the
    /// file contents, and `next_id` resumes past the highest id seen.
    fn rebuild_from_files(&mut self, dir: &Path) -> Result<()> {
        let mut max_id = 0;
        for entry in
            std::fs::read_dir(dir).with_context(|| format!("listing {}", dir.display()))?
        {
            let entry = entry?;
            let name = entry.file_name().to_string_lossy().into_owned();
            let id = match parse_slice_file_name(&name) {
                Some(id) => id,
                None => continue,
            };
            let buf = std::fs::read(entry.path())
                .with_context(|| format!("reading {}", entry.path().display()))?;
            if decode_slice(&buf).is_err() {
                // undecodable slice file: treat as an orphan
                let _ = std::fs::remove_file(entry.path());
                self.orphans_removed += 1;
                continue;
            }
            self.sizes.insert(id, buf.len());
            self.checksums.insert(id, fnv1a64(&buf));
            max_id = max_id.max(id);
        }
        self.next_id = max_id + 1;
        Ok(())
    }

    /// Remove slice files with no manifest entry (a crash between the
    /// slice write and the manifest commit leaves exactly these behind).
    fn collect_orphans(&mut self, dir: &Path) -> Result<()> {
        for entry in
            std::fs::read_dir(dir).with_context(|| format!("listing {}", dir.display()))?
        {
            let entry = entry?;
            let name = entry.file_name().to_string_lossy().into_owned();
            if let Some(id) = parse_slice_file_name(&name) {
                if !self.sizes.contains_key(&id) {
                    let _ = std::fs::remove_file(entry.path());
                    self.orphans_removed += 1;
                }
            }
        }
        Ok(())
    }

    /// Atomically (tmp + rename) persist the manifest.  No-op in memory.
    fn write_manifest(&self) -> Result<()> {
        let dir = match self.dir() {
            None => return Ok(()),
            Some(d) => d,
        };
        let mut root = Json::obj();
        root.insert("magic", MANIFEST_MAGIC);
        root.insert("version", MANIFEST_VERSION);
        root.insert("next_id", self.next_id);
        let mut ids: Vec<SliceId> = self.sizes.keys().copied().collect();
        ids.sort_unstable();
        let slices: Vec<Json> = ids
            .iter()
            .map(|id| {
                let mut o = Json::obj();
                o.insert("id", *id);
                o.insert("bytes", self.sizes[id]);
                o.insert(
                    "checksum",
                    format!("{:016x}", self.checksums.get(id).copied().unwrap_or(0)),
                );
                if let Some(key) = self.pooled.get(id) {
                    o.insert("pool", format!("{key:016x}"));
                }
                Json::Obj(o)
            })
            .collect();
        root.insert("slices", Json::Arr(slices));

        let tmp = dir.join(format!("{MANIFEST_FILE}.tmp"));
        let fin = dir.join(MANIFEST_FILE);
        std::fs::write(&tmp, Json::Obj(root).to_string_pretty())
            .with_context(|| format!("writing {}", tmp.display()))?;
        std::fs::rename(&tmp, &fin)
            .with_context(|| format!("committing {}", fin.display()))?;
        Ok(())
    }

    /// Persist a slice; returns its id and byte size.  On any failure the
    /// store is left exactly as it was (no id consumed, no accounting).
    pub fn put(&mut self, tensor: QkvTensor) -> Result<(SliceId, usize)> {
        let id = self.next_id;
        let bytes = tensor.byte_size() + 16;
        match self.path(id) {
            None => {
                self.mem.insert(id, Arc::new(tensor));
            }
            Some(p) => {
                let buf = encode_slice(&tensor);
                debug_assert_eq!(buf.len(), bytes);
                let sum = fnv1a64(&buf);
                if let Err(e) =
                    std::fs::write(&p, &buf).with_context(|| format!("writing {}", p.display()))
                {
                    // nothing was committed; leave the store untouched
                    let _ = std::fs::remove_file(&p);
                    return Err(e);
                }
                self.checksums.insert(id, sum);
            }
        }
        self.sizes.insert(id, bytes);
        self.next_id += 1;
        self.stores += 1;
        if let Err(e) = self.write_manifest() {
            // roll back: a failed put must leave the store unchanged
            self.sizes.remove(&id);
            self.checksums.remove(&id);
            self.mem.remove(&id);
            self.next_id -= 1;
            self.stores -= 1;
            if let Some(p) = self.path(id) {
                let _ = std::fs::remove_file(p);
            }
            return Err(e);
        }
        crate::obs_counter!("store.puts").inc();
        crate::obs_gauge!("store.resident_bytes").add(bytes as i64);
        Ok((id, bytes))
    }

    /// Persist a slice under its segment content key.  When a pool is
    /// attached and the slice is shared-eligible, the payload is
    /// interned in the cross-tenant pool and this store only accounts a
    /// [`HANDLE_BYTES`] handle; otherwise (no pool, private slice, or
    /// the pool rejected the intern under capacity pressure) this is
    /// exactly [`Self::put`] — the single-tenant path is byte-identical.
    pub fn put_keyed(
        &mut self,
        key: PoolKey,
        tensor: QkvTensor,
        shared: bool,
    ) -> Result<(SliceId, usize)> {
        if shared {
            if let Some(pool) = self.pool.clone() {
                let _t = crate::obs::trace::child("pool_intern");
                if pool.intern(key, &tensor) {
                    let id = self.next_id;
                    self.sizes.insert(id, HANDLE_BYTES);
                    self.pooled.insert(id, key);
                    self.next_id += 1;
                    self.stores += 1;
                    if let Err(e) = self.write_manifest() {
                        // roll back: a failed put must leave the store
                        // (and the pool refcount) unchanged
                        self.sizes.remove(&id);
                        self.pooled.remove(&id);
                        pool.release(key);
                        self.next_id -= 1;
                        self.stores -= 1;
                        return Err(e);
                    }
                    crate::obs_counter!("store.puts").inc();
                    crate::obs_gauge!("store.resident_bytes").add(HANDLE_BYTES as i64);
                    return Ok((id, HANDLE_BYTES));
                }
            }
        }
        self.put(tensor)
    }

    /// Load a slice (on-demand from disk for the Disk backend, with
    /// checksum verification against the manifest; pooled slices come
    /// back as the pool's shared allocation).  The payload is
    /// `Arc`-shared — hot-path gets never copy tensor data.
    ///
    /// A disk slice whose bytes no longer match the manifest checksum is
    /// *quarantined* on the first mismatch — dropped from the manifest,
    /// file GC'd, `slice.quarantined` journaled — so one corrupt file
    /// degrades to a cache miss instead of failing identically forever.
    pub fn get(&mut self, id: SliceId) -> Result<Arc<QkvTensor>> {
        self.loads += 1;
        crate::obs_counter!("store.loads").inc();
        if let Some(&key) = self.pooled.get(&id) {
            let pool = self.pool.as_ref().context("pooled slice without a pool")?;
            return pool
                .get(key)
                .with_context(|| format!("pooled slice {id} (key {key:016x}) left the pool"));
        }
        match self.path(id) {
            None => self
                .mem
                .get(&id)
                .map(Arc::clone)
                .with_context(|| format!("slice {id} missing from memory store")),
            Some(p) => {
                let buf =
                    std::fs::read(&p).with_context(|| format!("reading {}", p.display()))?;
                if let Some(&want) = self.checksums.get(&id) {
                    let got = fnv1a64(&buf);
                    if got != want {
                        crate::obs_counter!("store.checksum_failures").inc();
                        self.quarantine(id, &p);
                        anyhow::bail!(
                            "slice {id} checksum mismatch ({got:016x} != {want:016x}); quarantined"
                        );
                    }
                }
                decode_slice(&buf).map(Arc::new)
            }
        }
    }

    /// Drop a corrupt slice so it can never fail the same way twice:
    /// manifest entry removed, file GC'd, accounting released.
    fn quarantine(&mut self, id: SliceId, path: &Path) {
        let bytes = self.sizes.remove(&id).unwrap_or(0);
        self.checksums.remove(&id);
        let _ = std::fs::remove_file(path);
        // best-effort: a failed manifest write self-heals at the next
        // open (the entry's file is gone → dropped by validation there)
        let _ = self.write_manifest();
        self.quarantined += 1;
        if bytes != 0 {
            crate::obs_gauge!("store.resident_bytes").sub(bytes as i64);
        }
        crate::obs::emit(
            crate::obs::Event::new("slice.quarantined")
                .field("id", id as f64)
                .field("bytes", bytes as f64),
        );
    }

    /// Copy-on-write: turn a pooled slice into a private copy under the
    /// same id (deep copy of the payload; the pool reference is
    /// released).  Returns the slice's new byte size so the owning tree
    /// can recharge its budget.  A no-op (returning the current size)
    /// for slices that are already private.
    pub fn make_private(&mut self, id: SliceId) -> Result<usize> {
        let _t = crate::obs::trace::child("pool_cow");
        let key = match self.pooled.get(&id) {
            None => {
                return self
                    .size_of(id)
                    .with_context(|| format!("slice {id} not in store"));
            }
            Some(&k) => k,
        };
        let pool = self.pool.clone().context("pooled slice without a pool")?;
        let shared = pool
            .get(key)
            .with_context(|| format!("pooled slice {id} (key {key:016x}) left the pool"))?;
        let tensor: QkvTensor = (*shared).clone();
        let bytes = tensor.byte_size() + 16;
        // commit the private payload before flipping any accounting, so
        // a failure leaves the slice pooled and fully readable
        match self.path(id) {
            None => {
                self.mem.insert(id, Arc::new(tensor));
            }
            Some(p) => {
                let buf = encode_slice(&tensor);
                let sum = fnv1a64(&buf);
                if let Err(e) =
                    std::fs::write(&p, &buf).with_context(|| format!("writing {}", p.display()))
                {
                    let _ = std::fs::remove_file(&p);
                    return Err(e);
                }
                self.checksums.insert(id, sum);
            }
        }
        self.pooled.remove(&id);
        self.sizes.insert(id, bytes);
        pool.release(key);
        let _ = self.write_manifest();
        crate::obs_gauge!("store.resident_bytes").add(bytes as i64 - HANDLE_BYTES as i64);
        crate::obs::emit(
            crate::obs::Event::new("pool.cow")
                .field("key", key as f64)
                .field("bytes", bytes as f64),
        );
        Ok(bytes)
    }

    /// Delete a slice; returns the bytes freed.
    pub fn remove(&mut self, id: SliceId) -> usize {
        self.remove_many(&[id])
    }

    /// Delete many slices with a single manifest commit (bulk GC stays
    /// O(n), not O(n²) in manifest writes); returns total bytes freed.
    pub fn remove_many(&mut self, ids: &[SliceId]) -> usize {
        let mut freed = 0;
        let mut removed = 0u64;
        for &id in ids {
            let bytes = self.sizes.remove(&id).unwrap_or(0);
            if bytes != 0 {
                removed += 1;
            }
            self.checksums.remove(&id);
            if let Some(key) = self.pooled.remove(&id) {
                // drop this store's reference; the pool keeps the entry
                // warm until capacity pressure evicts it
                if let Some(pool) = &self.pool {
                    pool.release(key);
                }
            } else {
                match self.path(id) {
                    None => {
                        self.mem.remove(&id);
                    }
                    Some(p) => {
                        let _ = std::fs::remove_file(p);
                    }
                }
            }
            freed += bytes;
        }
        if freed != 0 {
            crate::obs_counter!("store.evictions").add(removed);
            crate::obs_gauge!("store.resident_bytes").sub(freed as i64);
            crate::obs::emit(
                crate::obs::Event::new("slice.evicted")
                    .field("n", removed as f64)
                    .field("freed_bytes", freed as f64),
            );
            // best-effort: a failed manifest write self-heals at the next
            // open (the dangling entries' files are gone → dropped there)
            let _ = self.write_manifest();
        }
        freed
    }

    pub fn size_of(&self, id: SliceId) -> Option<usize> {
        self.sizes.get(&id).copied()
    }

    /// Whether `id` is a live slice in this store.
    pub fn contains(&self, id: SliceId) -> bool {
        self.sizes.contains_key(&id)
    }

    pub fn count(&self) -> usize {
        self.sizes.len()
    }

    /// Next id that `put` would assign (reporting/tests).
    pub fn next_id(&self) -> SliceId {
        self.next_id
    }

    /// Live slice ids, ascending.
    pub fn ids(&self) -> Vec<SliceId> {
        let mut v: Vec<SliceId> = self.sizes.keys().copied().collect();
        v.sort_unstable();
        v
    }
}

impl Drop for SliceStore {
    fn drop(&mut self) {
        // keep the global resident-bytes gauge consistent when a whole
        // store goes away (e.g. a tenant shard demoting to the cold tier)
        let resident: usize = self.sizes.values().sum();
        if resident != 0 {
            crate::obs_gauge!("store.resident_bytes").sub(resident as i64);
        }
        // release every pool reference this store held, so a demoted or
        // dropped shard never strands pool bytes behind dead refcounts
        if let Some(pool) = self.pool.take() {
            for (_, key) in self.pooled.drain() {
                pool.release(key);
            }
        }
    }
}

fn slice_file_name(id: SliceId) -> String {
    format!("slice_{id:016x}.qkv")
}

fn parse_slice_file_name(name: &str) -> Option<SliceId> {
    let hex = name.strip_prefix("slice_")?.strip_suffix(".qkv")?;
    SliceId::from_str_radix(hex, 16).ok()
}

// `pub(crate)` so the pool's payload files share this wire format.
pub(crate) fn encode_slice(tensor: &QkvTensor) -> Vec<u8> {
    let mut buf = Vec::with_capacity(tensor.byte_size() + 16);
    buf.extend_from_slice(&MAGIC.to_le_bytes());
    buf.extend_from_slice(&(tensor.layers as u32).to_le_bytes());
    buf.extend_from_slice(&(tensor.d_model as u32).to_le_bytes());
    buf.extend_from_slice(&(tensor.seq as u32).to_le_bytes());
    for v in &tensor.data {
        buf.extend_from_slice(&v.to_le_bytes());
    }
    buf
}

pub(crate) fn decode_slice(buf: &[u8]) -> Result<QkvTensor> {
    anyhow::ensure!(buf.len() >= 16, "slice file too short");
    let magic = u32::from_le_bytes(buf[0..4].try_into().unwrap());
    anyhow::ensure!(magic == MAGIC, "bad slice magic");
    let layers = u32::from_le_bytes(buf[4..8].try_into().unwrap()) as usize;
    let d_model = u32::from_le_bytes(buf[8..12].try_into().unwrap()) as usize;
    let seq = u32::from_le_bytes(buf[12..16].try_into().unwrap()) as usize;
    let n = layers * 3 * seq * d_model;
    anyhow::ensure!(buf.len() == 16 + n * 4, "slice file size mismatch");
    let mut data = vec![0f32; n];
    for (i, c) in buf[16..].chunks_exact(4).enumerate() {
        data[i] = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
    }
    Ok(QkvTensor::from_flat(layers, d_model, seq, data))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tensor(seed: f32) -> QkvTensor {
        let mut t = QkvTensor::zeros(2, 8, 64);
        for (i, v) in t.data.iter_mut().enumerate() {
            *v = seed + i as f32 * 0.5;
        }
        t
    }

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "percache_store_{tag}_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn memory_roundtrip() {
        let mut s = SliceStore::memory();
        let t = tensor(1.0);
        let (id, bytes) = s.put(t.clone()).unwrap();
        assert_eq!(bytes, t.byte_size() + 16);
        assert_eq!(*s.get(id).unwrap(), t);
        assert_eq!(s.remove(id), bytes);
        assert!(s.get(id).is_err());
        assert_eq!(s.count(), 0);
    }

    #[test]
    fn disk_roundtrip() {
        let dir = tmp_dir("rt");
        let mut s = SliceStore::disk(dir.clone()).unwrap();
        let t = tensor(-3.25);
        let (id, _) = s.put(t.clone()).unwrap();
        let loaded = s.get(id).unwrap();
        assert_eq!(*loaded, t);
        assert_eq!(s.loads, 1);
        s.remove(id);
        assert!(s.get(id).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn disk_detects_corruption() {
        let dir = tmp_dir("corrupt");
        let mut s = SliceStore::disk(dir.clone()).unwrap();
        let (id, _) = s.put(tensor(0.0)).unwrap();
        let p = dir.join(slice_file_name(id));
        std::fs::write(&p, b"garbage data here").unwrap();
        assert!(s.get(id).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn ids_unique() {
        let mut s = SliceStore::memory();
        let (a, _) = s.put(tensor(0.0)).unwrap();
        let (b, _) = s.put(tensor(1.0)).unwrap();
        assert_ne!(a, b);
    }

    #[test]
    fn reopen_resumes_ids_and_preserves_slices() {
        let dir = tmp_dir("reopen");
        let ta = tensor(1.0);
        let tb = tensor(2.0);
        let (a, b) = {
            let mut s = SliceStore::disk(dir.clone()).unwrap();
            (s.put(ta.clone()).unwrap().0, s.put(tb.clone()).unwrap().0)
        };
        let mut s = SliceStore::disk(dir.clone()).unwrap();
        assert_eq!(s.count(), 2, "reopen must keep committed slices");
        assert_eq!(*s.get(a).unwrap(), ta);
        assert_eq!(*s.get(b).unwrap(), tb);
        let (c, _) = s.put(tensor(3.0)).unwrap();
        assert!(c > b, "resumed id {c} must not collide with {a}/{b}");
        // the old slices are untouched by the new put
        assert_eq!(*s.get(a).unwrap(), ta);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn reopen_collects_orphan_files() {
        let dir = tmp_dir("orphan");
        {
            let mut s = SliceStore::disk(dir.clone()).unwrap();
            s.put(tensor(1.0)).unwrap();
        }
        // a crash between slice write and manifest commit leaves a stray
        // file behind; it must be GC'd, not adopted or clobbered over
        let stray = dir.join(slice_file_name(0xff));
        std::fs::write(&stray, encode_slice(&tensor(9.0))).unwrap();
        let s = SliceStore::disk(dir.clone()).unwrap();
        assert_eq!(s.count(), 1);
        assert_eq!(s.orphans_removed, 1);
        assert!(!stray.exists(), "orphan file must be removed");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupted_manifest_is_rejected() {
        let dir = tmp_dir("badmanifest");
        {
            let mut s = SliceStore::disk(dir.clone()).unwrap();
            s.put(tensor(1.0)).unwrap();
        }
        std::fs::write(dir.join(MANIFEST_FILE), "{not json").unwrap();
        assert!(SliceStore::disk(dir.clone()).is_err(), "garbage manifest");
        std::fs::write(
            dir.join(MANIFEST_FILE),
            r#"{"magic":"percache-slices","version":999,"next_id":1,"slices":[]}"#,
        )
        .unwrap();
        assert!(SliceStore::disk(dir.clone()).is_err(), "future version");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn manifestless_dir_is_adopted_not_clobbered() {
        let dir = tmp_dir("legacy");
        std::fs::create_dir_all(&dir).unwrap();
        // legacy layout: slice files, no manifest
        let t = tensor(4.0);
        std::fs::write(dir.join(slice_file_name(7)), encode_slice(&t)).unwrap();
        let mut s = SliceStore::disk(dir.clone()).unwrap();
        assert_eq!(s.count(), 1);
        assert_eq!(*s.get(7).unwrap(), t);
        let (id, _) = s.put(tensor(5.0)).unwrap();
        assert_eq!(id, 8, "ids resume past the adopted max");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn memory_gets_share_one_allocation() {
        let mut s = SliceStore::memory();
        let (id, _) = s.put(tensor(2.5)).unwrap();
        let a = s.get(id).unwrap();
        let b = s.get(id).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "hot-path gets must not deep-copy");
    }

    #[test]
    fn checksum_mismatch_quarantines_on_first_get() {
        let dir = tmp_dir("quarantine");
        let mut s = SliceStore::disk(dir.clone()).unwrap();
        let (good, _) = s.put(tensor(1.0)).unwrap();
        let (bad, _) = s.put(tensor(2.0)).unwrap();
        let p = dir.join(slice_file_name(bad));
        // flip one byte, keeping the length (so only the checksum trips)
        let mut buf = std::fs::read(&p).unwrap();
        let last = buf.len() - 1;
        buf[last] ^= 0xFF;
        std::fs::write(&p, &buf).unwrap();

        let err = s.get(bad).unwrap_err().to_string();
        assert!(err.contains("quarantined"), "got: {err}");
        assert_eq!(s.quarantined, 1);
        assert!(!s.contains(bad), "quarantined slice leaves the store");
        assert!(!p.exists(), "quarantined file is GC'd");
        // the second failure mode of the old behavior: the entry stayed
        // in the manifest and failed identically forever — now it's a
        // clean miss, and a reopen agrees
        assert!(s.get(bad).is_err());
        assert_eq!(s.quarantined, 1, "no double-quarantine");
        drop(s);
        let mut s = SliceStore::disk(dir.clone()).unwrap();
        assert!(!s.contains(bad));
        assert_eq!(*s.get(good).unwrap(), tensor(1.0), "good slice unaffected");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn failed_put_rolls_back_completely() {
        let dir = tmp_dir("rollback");
        let mut s = SliceStore::disk(dir.clone()).unwrap();
        s.put(tensor(1.0)).unwrap();
        let before_count = s.count();
        let before_next = s.next_id();
        let before_stores = s.stores;

        // force the slice-file write to fail: a directory squats on the
        // path the next put would use
        let squat = dir.join(slice_file_name(before_next));
        std::fs::create_dir_all(&squat).unwrap();
        assert!(s.put(tensor(2.0)).is_err());
        std::fs::remove_dir_all(&squat).unwrap();
        assert_eq!(s.count(), before_count, "no accounting leaked");
        assert_eq!(s.next_id(), before_next, "no id consumed");
        assert_eq!(s.stores, before_stores);

        // force the manifest commit to fail instead: a directory squats
        // on the manifest tmp path
        let tmp = dir.join(format!("{MANIFEST_FILE}.tmp"));
        std::fs::create_dir_all(&tmp).unwrap();
        assert!(s.put(tensor(3.0)).is_err());
        std::fs::remove_dir_all(&tmp).unwrap();
        assert_eq!(s.count(), before_count);
        assert_eq!(s.next_id(), before_next);
        assert!(
            !dir.join(slice_file_name(before_next)).exists(),
            "rolled-back slice file removed"
        );
        // the store still works after both failures
        let (id, _) = s.put(tensor(4.0)).unwrap();
        assert_eq!(*s.get(id).unwrap(), tensor(4.0));
        let _ = std::fs::remove_dir_all(&dir);
    }

    fn pool_handle(cap_slices: usize, tenant: u32) -> crate::pool::PoolHandle {
        let bytes = tensor(0.0).byte_size() + 16;
        crate::pool::PoolHandle::new(
            crate::pool::SlicePool::memory(cap_slices * bytes).shared(),
            tenant,
        )
    }

    #[test]
    fn pooled_put_get_remove_roundtrip() {
        let h = pool_handle(8, 0);
        let mut s = SliceStore::memory_with_pool(h.clone());
        let t = tensor(6.0);
        let (id, bytes) = s.put_keyed(0xC0FFEE, t.clone(), true).unwrap();
        assert_eq!(bytes, HANDLE_BYTES, "pooled slice charges only a handle");
        assert_eq!(s.size_of(id), Some(HANDLE_BYTES));
        assert_eq!(s.pooled_count(), 1);
        assert_eq!(*s.get(id).unwrap(), t);
        assert!(Arc::ptr_eq(
            &s.get(id).unwrap(),
            &s.pool_probe(0xC0FFEE).unwrap()
        ));
        assert_eq!(s.remove(id), HANDLE_BYTES);
        assert!(s.get(id).is_err());
        // the pool keeps the entry warm at zero refs
        assert!(s.pool_probe(0xC0FFEE).is_some());
    }

    #[test]
    fn unshared_or_poolless_put_keyed_matches_put() {
        // no pool attached: put_keyed is exactly put
        let mut plain = SliceStore::memory();
        let (id, bytes) = plain.put_keyed(1, tensor(1.0), true).unwrap();
        assert_eq!(bytes, tensor(1.0).byte_size() + 16);
        assert_eq!(*plain.get(id).unwrap(), tensor(1.0));
        // pool attached but slice not shared-eligible: private too
        let mut pooled = SliceStore::memory_with_pool(pool_handle(8, 0));
        let (_, b2) = pooled.put_keyed(1, tensor(1.0), false).unwrap();
        assert_eq!(b2, bytes);
        assert_eq!(pooled.pooled_count(), 0);
    }

    #[test]
    fn reopen_with_pool_rebuilds_refcounts() {
        let dir = tmp_dir("poolreopen");
        let pool = crate::pool::SlicePool::memory(1 << 20).shared();
        let h = crate::pool::PoolHandle::new(Arc::clone(&pool), 7);
        let t = tensor(3.5);
        let (pid, prv) = {
            let mut s = SliceStore::disk_with_pool(dir.clone(), h.clone()).unwrap();
            let (pid, _) = s.put_keyed(0xAA, t.clone(), true).unwrap();
            let (prv, _) = s.put(tensor(9.0)).unwrap();
            (pid, prv)
        };
        // the drop released the shard's reference; the entry stays warm
        assert_eq!(crate::util::sync::lock_or_recover(&pool).refcount(0xAA), 0);
        let mut s = SliceStore::disk_with_pool(dir.clone(), h).unwrap();
        assert_eq!(
            crate::util::sync::lock_or_recover(&pool).refcount(0xAA),
            1,
            "reopen re-acquires the pool reference"
        );
        assert_eq!(s.size_of(pid), Some(HANDLE_BYTES));
        assert_eq!(*s.get(pid).unwrap(), t);
        assert_eq!(*s.get(prv).unwrap(), tensor(9.0));
        // reopening WITHOUT a pool drops the pooled entry, keeps private
        drop(s);
        let s = SliceStore::disk(dir.clone()).unwrap();
        assert!(!s.contains(pid), "pooled entry dropped without a pool");
        assert!(s.contains(prv));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn make_private_copies_and_never_aliases() {
        let h = pool_handle(8, 0);
        let mut s = SliceStore::memory_with_pool(h.clone());
        let t = tensor(1.5);
        let (id, _) = s.put_keyed(0xBEE, t.clone(), true).unwrap();
        let pooled_arc = s.pool_probe(0xBEE).unwrap();
        let bytes = s.make_private(id).unwrap();
        assert_eq!(bytes, t.byte_size() + 16);
        assert_eq!(s.pooled_count(), 0);
        assert_eq!(s.size_of(id), Some(bytes));
        let private_arc = s.get(id).unwrap();
        assert!(
            !Arc::ptr_eq(&pooled_arc, &private_arc),
            "COW must never alias the pool entry"
        );
        assert_eq!(*private_arc, t, "payload copied intact");
        // the pool reference was released; already-private is a no-op
        assert!(s.pool_probe(0xBEE).is_some(), "pool entry survives, warm");
        assert_eq!(s.make_private(id).unwrap(), bytes);
    }

    #[test]
    fn missing_slice_file_is_dropped_on_reopen() {
        let dir = tmp_dir("missing");
        let (a, b) = {
            let mut s = SliceStore::disk(dir.clone()).unwrap();
            (s.put(tensor(1.0)).unwrap().0, s.put(tensor(2.0)).unwrap().0)
        };
        std::fs::remove_file(dir.join(slice_file_name(a))).unwrap();
        let mut s = SliceStore::disk(dir.clone()).unwrap();
        assert!(!s.contains(a), "lost slice must be dropped");
        assert!(s.contains(b));
        assert!(s.get(b).is_ok());
        assert!(s.next_id() > b, "ids never reused even after a loss");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
