//! QKV slice storage backend: in-memory or on-disk (load-on-demand, like
//! the paper's implementation — Table 1 measures slice loading separately
//! from matching, which this split makes possible).
//!
//! Disk format per slice: 16-byte header (magic, layers, d_model, seq as
//! u32 LE) followed by raw f32 LE data.

use std::collections::HashMap;
use std::path::PathBuf;

use anyhow::{Context, Result};

use crate::llm::QkvTensor;

pub type SliceId = u64;

const MAGIC: u32 = 0x51_4B_56_01; // "QKV\x01"

#[derive(Debug, Clone)]
pub enum Backend {
    Memory,
    Disk(PathBuf),
}

/// Slice store with exact byte accounting (the tree enforces the budget).
pub struct SliceStore {
    backend: Backend,
    mem: HashMap<SliceId, QkvTensor>,
    sizes: HashMap<SliceId, usize>,
    next_id: SliceId,
    /// Counters for Table 1-style reporting.
    pub loads: u64,
    pub stores: u64,
}

impl SliceStore {
    pub fn memory() -> Self {
        Self::new(Backend::Memory)
    }

    pub fn disk(dir: PathBuf) -> Result<Self> {
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating slice dir {}", dir.display()))?;
        Ok(Self::new(Backend::Disk(dir)))
    }

    fn new(backend: Backend) -> Self {
        SliceStore {
            backend,
            mem: HashMap::new(),
            sizes: HashMap::new(),
            next_id: 1,
            loads: 0,
            stores: 0,
        }
    }

    fn path(&self, id: SliceId) -> Option<PathBuf> {
        match &self.backend {
            Backend::Memory => None,
            Backend::Disk(dir) => Some(dir.join(format!("slice_{id:016x}.qkv"))),
        }
    }

    /// Persist a slice; returns its id and byte size.
    pub fn put(&mut self, tensor: QkvTensor) -> Result<(SliceId, usize)> {
        let id = self.next_id;
        self.next_id += 1;
        let bytes = tensor.byte_size() + 16;
        self.sizes.insert(id, bytes);
        self.stores += 1;
        match self.path(id) {
            None => {
                self.mem.insert(id, tensor);
            }
            Some(p) => {
                let mut buf = Vec::with_capacity(bytes);
                buf.extend_from_slice(&MAGIC.to_le_bytes());
                buf.extend_from_slice(&(tensor.layers as u32).to_le_bytes());
                buf.extend_from_slice(&(tensor.d_model as u32).to_le_bytes());
                buf.extend_from_slice(&(tensor.seq as u32).to_le_bytes());
                for v in &tensor.data {
                    buf.extend_from_slice(&v.to_le_bytes());
                }
                std::fs::write(&p, &buf)
                    .with_context(|| format!("writing {}", p.display()))?;
            }
        }
        Ok((id, bytes))
    }

    /// Load a slice (on-demand from disk for the Disk backend).
    pub fn get(&mut self, id: SliceId) -> Result<QkvTensor> {
        self.loads += 1;
        match self.path(id) {
            None => self
                .mem
                .get(&id)
                .cloned()
                .with_context(|| format!("slice {id} missing from memory store")),
            Some(p) => {
                let buf =
                    std::fs::read(&p).with_context(|| format!("reading {}", p.display()))?;
                anyhow::ensure!(buf.len() >= 16, "slice file too short");
                let magic = u32::from_le_bytes(buf[0..4].try_into().unwrap());
                anyhow::ensure!(magic == MAGIC, "bad slice magic");
                let layers = u32::from_le_bytes(buf[4..8].try_into().unwrap()) as usize;
                let d_model = u32::from_le_bytes(buf[8..12].try_into().unwrap()) as usize;
                let seq = u32::from_le_bytes(buf[12..16].try_into().unwrap()) as usize;
                let n = layers * 3 * seq * d_model;
                anyhow::ensure!(buf.len() == 16 + n * 4, "slice file size mismatch");
                let mut data = vec![0f32; n];
                for (i, c) in buf[16..].chunks_exact(4).enumerate() {
                    data[i] = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
                }
                Ok(QkvTensor::from_flat(layers, d_model, seq, data))
            }
        }
    }

    /// Delete a slice; returns the bytes freed.
    pub fn remove(&mut self, id: SliceId) -> usize {
        let bytes = self.sizes.remove(&id).unwrap_or(0);
        match self.path(id) {
            None => {
                self.mem.remove(&id);
            }
            Some(p) => {
                let _ = std::fs::remove_file(p);
            }
        }
        bytes
    }

    pub fn size_of(&self, id: SliceId) -> Option<usize> {
        self.sizes.get(&id).copied()
    }

    pub fn count(&self) -> usize {
        self.sizes.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tensor(seed: f32) -> QkvTensor {
        let mut t = QkvTensor::zeros(2, 8, 64);
        for (i, v) in t.data.iter_mut().enumerate() {
            *v = seed + i as f32 * 0.5;
        }
        t
    }

    #[test]
    fn memory_roundtrip() {
        let mut s = SliceStore::memory();
        let t = tensor(1.0);
        let (id, bytes) = s.put(t.clone()).unwrap();
        assert_eq!(bytes, t.byte_size() + 16);
        assert_eq!(s.get(id).unwrap(), t);
        assert_eq!(s.remove(id), bytes);
        assert!(s.get(id).is_err());
        assert_eq!(s.count(), 0);
    }

    #[test]
    fn disk_roundtrip() {
        let dir = std::env::temp_dir().join(format!("percache_store_{}", std::process::id()));
        let mut s = SliceStore::disk(dir.clone()).unwrap();
        let t = tensor(-3.25);
        let (id, _) = s.put(t.clone()).unwrap();
        let loaded = s.get(id).unwrap();
        assert_eq!(loaded, t);
        assert_eq!(s.loads, 1);
        s.remove(id);
        assert!(s.get(id).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn disk_detects_corruption() {
        let dir = std::env::temp_dir().join(format!("percache_corrupt_{}", std::process::id()));
        let mut s = SliceStore::disk(dir.clone()).unwrap();
        let (id, _) = s.put(tensor(0.0)).unwrap();
        let p = dir.join(format!("slice_{id:016x}.qkv"));
        std::fs::write(&p, b"garbage data here").unwrap();
        assert!(s.get(id).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn ids_unique() {
        let mut s = SliceStore::memory();
        let (a, _) = s.put(tensor(0.0)).unwrap();
        let (b, _) = s.put(tensor(1.0)).unwrap();
        assert_ne!(a, b);
    }
}
