//! The QA bank: semantic cache of (query, embedding, answer) entries
//! (paper §4.1.1 / §4.2.1).
//!
//! Matching is cosine similarity against all stored queries; above
//! τ_query the cached answer is returned and the whole LLM inference is
//! skipped.  Entries may exist *without* an answer — that's the
//! scheduler's prefill-only population strategy (§4.3.2); the QKV→QA
//! conversion decodes them later.  LFU eviction under a byte budget.

use crate::embedding::{cosine, Embedding};

pub type QaId = u64;

#[derive(Debug, Clone)]
pub struct QaEntry {
    pub id: QaId,
    pub query: String,
    pub embedding: Embedding,
    /// Generated answer tokens; None = not yet decoded (strategy-1
    /// population or refreshed-stale entry).
    pub answer: Option<Vec<i32>>,
    /// Whether this entry came from query prediction (vs a real query).
    pub predicted: bool,
    pub freq: u64,
}

impl QaEntry {
    /// Approximate storage footprint (paper Table 1: ~4 KB/entry).
    pub fn bytes(&self) -> usize {
        self.query.len()
            + self.embedding.len() * 4
            + self.answer.as_ref().map(|a| a.len() * 4).unwrap_or(0)
            + 64
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QaMatch {
    pub id: QaId,
    pub similarity: f64,
    pub has_answer: bool,
}

#[derive(Debug, Default)]
pub struct QaBank {
    entries: Vec<QaEntry>,
    byte_limit: usize,
    bytes_used: usize,
    next_id: QaId,
    /// Persisted state (entries, answers, LFU freqs) changed since the
    /// last [`Self::mark_clean`] — incremental snapshots skip clean banks.
    dirty: bool,
    pub evictions: u64,
}

impl QaBank {
    pub fn new(byte_limit: usize) -> Self {
        QaBank {
            byte_limit,
            next_id: 1,
            ..Default::default()
        }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn bytes_used(&self) -> usize {
        self.bytes_used
    }

    pub fn byte_limit(&self) -> usize {
        self.byte_limit
    }

    pub fn set_byte_limit(&mut self, limit: usize) {
        self.byte_limit = limit;
        self.enforce_budget(&[]);
    }

    pub fn entries(&self) -> &[QaEntry] {
        &self.entries
    }

    /// Next id `insert` would assign (persistence).
    pub fn next_id(&self) -> QaId {
        self.next_id
    }

    /// Whether persisted state changed since the last [`Self::mark_clean`].
    pub fn is_dirty(&self) -> bool {
        self.dirty
    }

    /// Mark the current state as snapshotted (persistence internal).
    pub fn mark_clean(&mut self) {
        self.dirty = false;
    }

    /// Rebuild a bank from persisted entries (DESIGN.md §10).  Ids must
    /// be unique and below `next_id` so later inserts never collide with
    /// restored entries; the byte budget is enforced on the way in.
    pub fn from_entries(
        byte_limit: usize,
        entries: Vec<QaEntry>,
        next_id: QaId,
    ) -> anyhow::Result<Self> {
        let mut bank = QaBank::new(byte_limit);
        for e in entries {
            anyhow::ensure!(
                e.id >= 1 && e.id < next_id,
                "qa entry id {} out of range (next_id {next_id})",
                e.id
            );
            anyhow::ensure!(
                bank.entries.iter().all(|x| x.id != e.id),
                "duplicate qa entry id {}",
                e.id
            );
            bank.bytes_used += e.bytes();
            bank.entries.push(e);
        }
        bank.next_id = next_id.max(1);
        bank.enforce_budget(&[]);
        Ok(bank)
    }

    pub fn get(&self, id: QaId) -> Option<&QaEntry> {
        self.entries.iter().find(|e| e.id == id)
    }

    /// Best match regardless of threshold (analysis / Fig 6).
    pub fn best_similarity(&self, emb: &Embedding) -> Option<QaMatch> {
        self.entries
            .iter()
            .map(|e| QaMatch {
                id: e.id,
                similarity: cosine(emb, &e.embedding) as f64,
                has_answer: e.answer.is_some(),
            })
            .max_by(|a, b| a.similarity.partial_cmp(&b.similarity).unwrap())
    }

    /// Cache-hit check: best *answered* entry with similarity ≥ τ.
    /// Bumps the LFU counter on hit.
    pub fn match_query(&mut self, emb: &Embedding, tau: f64) -> Option<(QaMatch, Vec<i32>)> {
        let best = self
            .entries
            .iter()
            .enumerate()
            .filter(|(_, e)| e.answer.is_some())
            .map(|(i, e)| (i, cosine(emb, &e.embedding) as f64))
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())?;
        if best.1 < tau {
            return None;
        }
        let (i, sim) = best;
        self.entries[i].freq += 1;
        self.dirty = true; // persisted LFU freq moved
        Some((
            QaMatch {
                id: self.entries[i].id,
                similarity: sim,
                has_answer: true,
            },
            self.entries[i].answer.clone().unwrap(),
        ))
    }

    /// Insert or update.  An (almost) identical query — similarity >
    /// 0.999 — updates the existing entry instead of duplicating it.
    pub fn insert(
        &mut self,
        query: &str,
        emb: Embedding,
        answer: Option<Vec<i32>>,
        predicted: bool,
    ) -> QaId {
        if let Some(pos) = self
            .entries
            .iter()
            .position(|e| e.query == query || cosine(&e.embedding, &emb) > 0.9995)
        {
            let old = self.entries[pos].bytes();
            if answer.is_some() {
                self.entries[pos].answer = answer;
            }
            self.entries[pos].predicted &= predicted;
            let new = self.entries[pos].bytes();
            self.bytes_used = self.bytes_used + new - old;
            let id = self.entries[pos].id;
            self.dirty = true;
            self.enforce_budget(&[id]);
            return id;
        }
        let id = self.next_id;
        self.next_id += 1;
        let e = QaEntry {
            id,
            query: query.to_string(),
            embedding: emb,
            answer,
            predicted,
            freq: 0,
        };
        self.bytes_used += e.bytes();
        self.entries.push(e);
        self.dirty = true;
        self.enforce_budget(&[id]);
        id
    }

    /// Entries lacking answers (conversion QKV→QA decodes these, §4.3.3).
    pub fn undecoded(&self) -> Vec<QaId> {
        self.entries
            .iter()
            .filter(|e| e.answer.is_none())
            .map(|e| e.id)
            .collect()
    }

    pub fn set_answer(&mut self, id: QaId, answer: Vec<i32>) -> bool {
        if let Some(e) = self.entries.iter_mut().find(|e| e.id == id) {
            let old = e.bytes();
            e.answer = Some(answer);
            let new = e.bytes();
            self.bytes_used = self.bytes_used + new - old;
            self.dirty = true;
            true
        } else {
            false
        }
    }

    /// Dynamic cache refresh (§4.1.3): when a new chunk arrives, entries
    /// whose queries rank it in their top-k become stale — their answers
    /// are cleared so idle-time decoding regenerates them against the
    /// updated knowledge.  Returns the ids invalidated.
    pub fn refresh_for_chunk(&mut self, chunk_emb: &Embedding, k_refresh: usize) -> Vec<QaId> {
        let mut sims: Vec<(usize, f64)> = self
            .entries
            .iter()
            .enumerate()
            .map(|(i, e)| (i, cosine(chunk_emb, &e.embedding) as f64))
            .collect();
        sims.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        let mut out = Vec::new();
        for &(i, sim) in sims.iter().take(k_refresh) {
            if sim > 0.3 && self.entries[i].answer.is_some() {
                let old = self.entries[i].bytes();
                self.entries[i].answer = None;
                let new = self.entries[i].bytes();
                self.bytes_used = self.bytes_used + new - old;
                self.dirty = true;
                out.push(self.entries[i].id);
            }
        }
        out
    }

    fn enforce_budget(&mut self, protect: &[QaId]) {
        while self.bytes_used > self.byte_limit && !self.entries.is_empty() {
            let victim = self
                .entries
                .iter()
                .enumerate()
                .filter(|(_, e)| !protect.contains(&e.id))
                .min_by(|(_, a), (_, b)| a.freq.cmp(&b.freq).then(a.id.cmp(&b.id)))
                .map(|(i, _)| i)
                .or_else(|| {
                    self.entries
                        .iter()
                        .enumerate()
                        .min_by(|(_, a), (_, b)| a.freq.cmp(&b.freq).then(a.id.cmp(&b.id)))
                        .map(|(i, _)| i)
                });
            match victim {
                Some(i) => {
                    let e = self.entries.remove(i);
                    self.bytes_used -= e.bytes();
                    self.evictions += 1;
                    self.dirty = true;
                }
                None => break,
            }
        }
    }

    /// Byte-accounting invariant for property tests.
    pub fn check_invariants(&self) -> anyhow::Result<()> {
        let sum: usize = self.entries.iter().map(|e| e.bytes()).sum();
        anyhow::ensure!(
            sum == self.bytes_used,
            "qa bank byte drift: {sum} vs {}",
            self.bytes_used
        );
        anyhow::ensure!(
            self.bytes_used <= self.byte_limit || self.entries.len() <= 1,
            "qa bank over budget"
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn emb(x: f32, y: f32) -> Embedding {
        let n = (x * x + y * y).sqrt().max(1e-9);
        vec![x / n, y / n, 0.0, 0.0]
    }

    #[test]
    fn match_respects_threshold_and_answers() {
        let mut qa = QaBank::new(1 << 20);
        qa.insert("budget meeting", emb(1.0, 0.0), Some(vec![10, 11]), false);
        qa.insert("unanswered", emb(0.0, 1.0), None, true);

        // identical direction → sim 1.0 ≥ 0.85: hit
        let (m, ans) = qa.match_query(&emb(1.0, 0.0), 0.85).unwrap();
        assert_eq!(ans, vec![10, 11]);
        assert!(m.similarity > 0.999);

        // orthogonal query: no hit even though an entry exists there
        // (it has no answer)
        assert!(qa.match_query(&emb(0.0, 1.0), 0.85).is_none());

        // sub-threshold: no hit
        assert!(qa.match_query(&emb(0.6, 0.8), 0.99).is_none());
    }

    #[test]
    fn duplicate_insert_updates_in_place() {
        let mut qa = QaBank::new(1 << 20);
        let a = qa.insert("q1", emb(1.0, 0.0), None, true);
        let b = qa.insert("q1", emb(1.0, 0.0), Some(vec![5]), false);
        assert_eq!(a, b);
        assert_eq!(qa.len(), 1);
        assert_eq!(qa.get(a).unwrap().answer, Some(vec![5]));
        assert!(!qa.get(a).unwrap().predicted, "real query overrides predicted");
        qa.check_invariants().unwrap();
    }

    #[test]
    fn lfu_eviction_under_budget() {
        let mut qa = QaBank::new(500); // fits ~2 entries of ~220 B
        qa.insert("hot query", emb(1.0, 0.0), Some(vec![1; 32]), false);
        qa.insert("cold query", emb(0.0, 1.0), Some(vec![2; 32]), false);
        for _ in 0..5 {
            qa.match_query(&emb(1.0, 0.0), 0.9).unwrap();
        }
        qa.insert("newcomer", emb(0.7, 0.7), Some(vec![3; 32]), false);
        assert!(qa.bytes_used() <= 500);
        assert!(qa.evictions >= 1);
        // hot survives
        assert!(qa.match_query(&emb(1.0, 0.0), 0.9).is_some());
        qa.check_invariants().unwrap();
    }

    #[test]
    fn undecoded_and_set_answer() {
        let mut qa = QaBank::new(1 << 20);
        let a = qa.insert("pending", emb(1.0, 0.0), None, true);
        assert_eq!(qa.undecoded(), vec![a]);
        assert!(qa.set_answer(a, vec![7, 8]));
        assert!(qa.undecoded().is_empty());
        assert!(!qa.set_answer(999, vec![0]));
        qa.check_invariants().unwrap();
    }

    #[test]
    fn refresh_invalidates_topk_similar() {
        let mut qa = QaBank::new(1 << 20);
        let a = qa.insert("about budget", emb(1.0, 0.1), Some(vec![1]), false);
        let _b = qa.insert("about travel", emb(0.0, 1.0), Some(vec![2]), false);
        let stale = qa.refresh_for_chunk(&emb(1.0, 0.0), 1);
        assert_eq!(stale, vec![a]);
        assert_eq!(qa.undecoded(), vec![a]);
        qa.check_invariants().unwrap();
    }

    #[test]
    fn from_entries_roundtrips_and_validates() {
        let mut qa = QaBank::new(1 << 20);
        qa.insert("alpha", emb(1.0, 0.0), Some(vec![1]), false);
        qa.insert("beta", emb(0.0, 1.0), None, true);
        let entries: Vec<QaEntry> = qa.entries().to_vec();
        let restored = QaBank::from_entries(1 << 20, entries.clone(), qa.next_id()).unwrap();
        assert_eq!(restored.len(), 2);
        assert_eq!(restored.bytes_used(), qa.bytes_used());
        assert_eq!(restored.next_id(), qa.next_id());
        restored.check_invariants().unwrap();
        // a fresh insert never collides with a restored id
        let mut restored = restored;
        let new_id = restored.insert("gamma", emb(0.5, 0.5), None, false);
        assert!(entries.iter().all(|e| e.id != new_id));

        // out-of-range / duplicate ids are rejected
        assert!(QaBank::from_entries(1 << 20, entries.clone(), 1).is_err());
        let mut dup = entries.clone();
        dup.push(entries[0].clone());
        assert!(QaBank::from_entries(1 << 20, dup, qa.next_id()).is_err());
    }

    #[test]
    fn dirty_tracks_mutations_and_clears() {
        let mut qa = QaBank::new(1 << 20);
        assert!(!qa.is_dirty(), "fresh bank is clean");
        let id = qa.insert("q1", emb(1.0, 0.0), None, true);
        assert!(qa.is_dirty());
        qa.mark_clean();
        // a miss touches nothing persisted
        assert!(qa.match_query(&emb(0.0, 1.0), 0.99).is_none());
        assert!(!qa.is_dirty());
        qa.set_answer(id, vec![1, 2]);
        assert!(qa.is_dirty());
        qa.mark_clean();
        // a hit bumps the persisted LFU freq
        qa.match_query(&emb(1.0, 0.0), 0.85).unwrap();
        assert!(qa.is_dirty());
        // restore without evictions is clean
        let restored =
            QaBank::from_entries(1 << 20, qa.entries().to_vec(), qa.next_id()).unwrap();
        assert!(!restored.is_dirty());
    }

    #[test]
    fn best_similarity_reports_unanswered_too() {
        let mut qa = QaBank::new(1 << 20);
        qa.insert("no answer yet", emb(1.0, 0.0), None, true);
        let m = qa.best_similarity(&emb(1.0, 0.0)).unwrap();
        assert!(!m.has_answer);
        assert!(m.similarity > 0.999);
    }
}
