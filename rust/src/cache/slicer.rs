//! Cache slicer (paper §4.1.1): splits a whole-prompt QKV tensor into
//! per-segment slices keyed by segment content, ready for tree insertion.
//!
//! The paper's slicer computes chunk start/end positions via the
//! tokenizer; here segments are fixed 64-token units so positions are
//! implicit — the interesting part is *which* segments are cacheable: the
//! system prompt and the knowledge chunks are; the query segment (always
//! last) is not, since query text varies (its tensors would never be
//! prefix-matched again — predicted duplicates hit the QA bank instead).
//!
//! Tokenization-boundary note (paper App. B.2): the paper's BPE tokenizer
//! can merge subwords across chunk boundaries, forcing them to drop
//! trailing tokens of the last matched node.  Our word-hash tokenizer is
//! context-free — a word's id never depends on neighbours — so sliced
//! tensors are exactly the tensors a fresh prefill would produce
//! (guaranteed by the reuse-exactness tests) and no boundary trimming is
//! needed.  Documented as a substitution in DESIGN.md §3.

use crate::llm::QkvTensor;
use crate::tokenizer::SEGMENT_TOKENS;

/// One cacheable slice: the segment's content key plus its tensors.
#[derive(Debug, Clone)]
pub struct SegmentSlice {
    pub key: u64,
    pub tensor: QkvTensor,
}

/// Split a whole-prompt QKV tensor into cacheable segment slices.
///
/// `seg_keys` are the content keys for ALL prompt segments, in order
/// (sysprompt, chunks…, query); the final (query) segment is skipped.
pub fn slice_prompt(qkv: &QkvTensor, seg_keys: &[u64]) -> Vec<SegmentSlice> {
    assert_eq!(
        qkv.seq,
        seg_keys.len() * SEGMENT_TOKENS,
        "QKV length disagrees with segment count"
    );
    let cacheable = seg_keys.len().saturating_sub(1);
    (0..cacheable)
        .map(|s| SegmentSlice {
            key: seg_keys[s],
            tensor: qkv.slice_segments(s, s + 1),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tagged(n_seg: usize) -> QkvTensor {
        let mut t = QkvTensor::zeros(2, 4, n_seg * SEGMENT_TOKENS);
        for s in 0..n_seg {
            // mark the first element of each segment's first row
            let off = s * SEGMENT_TOKENS * 4;
            t.data[off] = (s + 1) as f32;
        }
        t
    }

    #[test]
    fn slices_all_but_query_segment() {
        let qkv = tagged(4);
        let keys = [11, 22, 33, 99]; // 99 = query
        let slices = slice_prompt(&qkv, &keys);
        assert_eq!(slices.len(), 3);
        assert_eq!(slices[0].key, 11);
        assert_eq!(slices[2].key, 33);
        for (i, s) in slices.iter().enumerate() {
            assert_eq!(s.tensor.seq, SEGMENT_TOKENS);
            assert_eq!(s.tensor.data[0], (i + 1) as f32, "segment content");
        }
    }

    #[test]
    fn single_segment_prompt_yields_nothing() {
        let qkv = tagged(1);
        assert!(slice_prompt(&qkv, &[42]).is_empty());
    }

    #[test]
    #[should_panic(expected = "disagrees")]
    fn length_mismatch_panics() {
        let qkv = tagged(3);
        slice_prompt(&qkv, &[1, 2]);
    }
}
