//! Baselines (paper §5.2): every method is the *same* engine under a
//! different configuration — which is exactly what each baseline is:
//!
//! | method              | QA bank | QKV cache | population  | scheduler |
//! |---------------------|---------|-----------|-------------|-----------|
//! | Naive               |   off   |    off    | —           |    off    |
//! | RAGCache            |   off   |  KV-only  | reactive    |    off    |
//! | MeanCache           |   on    |    off    | reactive    |    off    |
//! | Sleep-time Compute  |   on    |    off    | predictive  |    off    |
//! | RAGCache+MeanCache  |   on    |  KV-only  | reactive    |    off    |
//! | RAGCache+SC         |   on    |  KV-only  | predictive  |    off    |
//! | PerCache            |   on    |  Q+K+V    | predictive  |    on     |
//!
//! Sharing one engine keeps the comparison honest: identical retrieval,
//! prompts, decode budget and measurement points.

use anyhow::Result;

use crate::config::{PerCacheConfig, PopulationMode};
use crate::engine::PerCache;
use crate::llm::ReuseVariant;
use crate::runtime::Runtime;

/// All method names, in the paper's presentation order.
pub const METHODS: [&str; 7] = [
    "naive",
    "ragcache",
    "meancache",
    "sleeptime",
    "ragcache+meancache",
    "ragcache+sleeptime",
    "percache",
];

/// Build the configuration for a named method, starting from `base`
/// (so experiments can sweep τ/stride/storage uniformly).
pub fn method_config(method: &str, base: &PerCacheConfig) -> Result<PerCacheConfig> {
    let mut c = base.clone();
    match method {
        "naive" => {
            c.qa_enabled = false;
            c.qkv_enabled = false;
            c.population = PopulationMode::Reactive;
            c.scheduler_enabled = false;
        }
        "ragcache" => {
            c.qa_enabled = false;
            c.qkv_enabled = true;
            c.reuse_variant = ReuseVariant::Kv;
            c.population = PopulationMode::Reactive;
            c.scheduler_enabled = false;
        }
        "meancache" => {
            c.qa_enabled = true;
            c.qkv_enabled = false;
            c.population = PopulationMode::Reactive;
            c.scheduler_enabled = false;
        }
        "sleeptime" => {
            c.qa_enabled = true;
            c.qkv_enabled = false;
            c.population = PopulationMode::Predictive;
            c.scheduler_enabled = false;
        }
        "ragcache+meancache" => {
            c.qa_enabled = true;
            c.qkv_enabled = true;
            c.reuse_variant = ReuseVariant::Kv;
            c.population = PopulationMode::Reactive;
            c.scheduler_enabled = false;
        }
        "ragcache+sleeptime" => {
            c.qa_enabled = true;
            c.qkv_enabled = true;
            c.reuse_variant = ReuseVariant::Kv;
            c.population = PopulationMode::Predictive;
            c.scheduler_enabled = false;
        }
        "percache" => {
            c.qa_enabled = true;
            c.qkv_enabled = true;
            c.reuse_variant = ReuseVariant::Qkv;
            c.population = PopulationMode::Predictive;
            c.scheduler_enabled = true;
        }
        other => anyhow::bail!("unknown method '{other}' (expected one of {METHODS:?})"),
    }
    Ok(c)
}

/// Construct an engine for a named method.
pub fn build_method<'rt>(
    rt: &'rt Runtime,
    method: &str,
    base: &PerCacheConfig,
) -> Result<PerCache<'rt>> {
    PerCache::new(rt, method_config(method, base)?)
}

/// Pretty label used in tables (matches the paper's legend).
pub fn label(method: &str) -> &'static str {
    match method {
        "naive" => "Naive",
        "ragcache" => "RAGCache",
        "meancache" => "MeanCache",
        "sleeptime" => "Sleep-time Compute",
        "ragcache+meancache" => "RAGCache+MeanCache",
        "ragcache+sleeptime" => "RAGCache+SC",
        "percache" => "PerCache",
        _ => "?",
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_methods_configure() {
        let base = PerCacheConfig::default();
        for m in METHODS {
            let c = method_config(m, &base).unwrap();
            c.validate().unwrap();
        }
        assert!(method_config("bogus", &base).is_err());
    }

    #[test]
    fn percache_is_the_full_system() {
        let c = method_config("percache", &PerCacheConfig::default()).unwrap();
        assert!(c.qa_enabled && c.qkv_enabled && c.scheduler_enabled);
        assert_eq!(c.reuse_variant, ReuseVariant::Qkv);
        assert_eq!(c.population, PopulationMode::Predictive);
    }

    #[test]
    fn ragcache_is_kv_only_reactive() {
        let c = method_config("ragcache", &PerCacheConfig::default()).unwrap();
        assert!(!c.qa_enabled && c.qkv_enabled);
        assert_eq!(c.reuse_variant, ReuseVariant::Kv);
        assert_eq!(c.population, PopulationMode::Reactive);
    }

    #[test]
    fn base_sweeps_propagate() {
        let mut base = PerCacheConfig::default();
        base.tau_query = 0.6;
        base.prediction_stride = 2;
        for m in METHODS {
            let c = method_config(m, &base).unwrap();
            assert_eq!(c.tau_query, 0.6);
            assert_eq!(c.prediction_stride, 2);
        }
    }

    #[test]
    fn labels_cover_methods() {
        for m in METHODS {
            assert_ne!(label(m), "?");
        }
    }
}
