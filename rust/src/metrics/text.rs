//! Text-quality metrics: ROUGE-L and BLEU (paper Figs 19 & 23).
//!
//! Implemented over the shared word split (tokenizer::words) so cached
//! answers and fresh generations are compared in the same token space.

use crate::tokenizer;

/// ROUGE-L F1 between candidate and reference texts.
pub fn rouge_l(candidate: &str, reference: &str) -> f64 {
    let c = tokenizer::words(candidate);
    let r = tokenizer::words(reference);
    rouge_l_tokens(&c, &r)
}

pub fn rouge_l_tokens<T: PartialEq>(c: &[T], r: &[T]) -> f64 {
    if c.is_empty() || r.is_empty() {
        return if c.is_empty() && r.is_empty() { 1.0 } else { 0.0 };
    }
    let l = lcs_len(c, r) as f64;
    if l == 0.0 {
        return 0.0;
    }
    let prec = l / c.len() as f64;
    let rec = l / r.len() as f64;
    2.0 * prec * rec / (prec + rec)
}

/// Longest common subsequence length, O(|a|·|b|) with rolling rows.
fn lcs_len<T: PartialEq>(a: &[T], b: &[T]) -> usize {
    if a.is_empty() || b.is_empty() {
        return 0;
    }
    let mut prev = vec![0usize; b.len() + 1];
    let mut cur = vec![0usize; b.len() + 1];
    for ai in a {
        for (j, bj) in b.iter().enumerate() {
            cur[j + 1] = if ai == bj {
                prev[j] + 1
            } else {
                cur[j].max(prev[j + 1])
            };
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

/// BLEU-4 with add-one smoothing and brevity penalty.
pub fn bleu(candidate: &str, reference: &str) -> f64 {
    let c = tokenizer::words(candidate);
    let r = tokenizer::words(reference);
    bleu_tokens(&c, &r)
}

pub fn bleu_tokens(c: &[String], r: &[String]) -> f64 {
    if c.is_empty() || r.is_empty() {
        return if c.is_empty() && r.is_empty() { 1.0 } else { 0.0 };
    }
    let max_n = 4.min(c.len()).min(r.len());
    let mut log_sum = 0.0;
    for n in 1..=max_n {
        let cand = ngram_counts(c, n);
        let refs = ngram_counts(r, n);
        let mut matched = 0usize;
        let mut total = 0usize;
        for (g, &cnt) in &cand {
            total += cnt;
            matched += cnt.min(refs.get(g).copied().unwrap_or(0));
        }
        // add-one smoothing keeps zero-match orders finite
        let p = (matched as f64 + 1.0) / (total as f64 + 1.0);
        log_sum += p.ln();
    }
    let geo = (log_sum / max_n as f64).exp();
    let bp = if c.len() >= r.len() {
        1.0
    } else {
        (1.0 - r.len() as f64 / c.len() as f64).exp()
    };
    bp * geo
}

fn ngram_counts(tokens: &[String], n: usize) -> std::collections::HashMap<&[String], usize> {
    let mut m = std::collections::HashMap::new();
    if tokens.len() >= n {
        for w in tokens.windows(n) {
            *m.entry(w).or_insert(0) += 1;
        }
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rouge_identical_is_one() {
        assert!((rouge_l("the budget meeting", "the budget meeting") - 1.0).abs() < 1e-9);
    }

    #[test]
    fn rouge_disjoint_is_zero() {
        assert_eq!(rouge_l("alpha beta", "gamma delta"), 0.0);
    }

    #[test]
    fn rouge_partial_ordering() {
        let r = "the meeting moved to thursday at 3pm";
        let near = rouge_l("meeting moved to thursday", r);
        let far = rouge_l("thursday", r);
        assert!(near > far && far > 0.0);
    }

    #[test]
    fn rouge_empty_cases() {
        assert_eq!(rouge_l("", ""), 1.0);
        assert_eq!(rouge_l("a", ""), 0.0);
        assert_eq!(rouge_l("", "a"), 0.0);
    }

    #[test]
    fn lcs_known_value() {
        let a = ["a", "b", "c", "d", "e"];
        let b = ["b", "x", "d", "e", "y"];
        assert_eq!(lcs_len(&a, &b), 3); // b d e
    }

    #[test]
    fn bleu_identical_is_one() {
        let s = "the quarterly budget review meeting is moved";
        assert!((bleu(s, s) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn bleu_order_sensitivity() {
        let r = "the budget review meeting on thursday";
        let good = bleu("the budget review meeting on thursday", r);
        let scrambled = bleu("thursday on meeting review budget the", r);
        assert!(good > scrambled);
    }

    #[test]
    fn bleu_brevity_penalty() {
        let r = "one two three four five six seven eight";
        let short = bleu("one two", r);
        let long = bleu("one two three four five six seven eight", r);
        assert!(long > short);
    }

    #[test]
    fn bleu_short_sequences_finite() {
        assert!(bleu("a", "a") > 0.9);
        assert!(bleu("a", "b") >= 0.0);
    }
}
