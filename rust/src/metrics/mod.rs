//! Measurement substrate: latency recording, analytic FLOPs, text quality.

pub mod flops;
pub mod recorder;
pub mod text;

pub use flops::ModelDims;
pub use recorder::{blank_record, record_query_obs, QueryRecord, Recorder, ServePath, Stage};
