//! Per-query latency/stage recording and aggregate statistics.
//!
//! Every policy (PerCache and all baselines) reports through this type so
//! the experiment harness compares identical measurements.  Latencies are
//! wall-clock over the PJRT hot path; FLOPs are analytic (metrics::flops);
//! `scale` lets sim::DeviceProfile map measured CPU time onto a device
//! profile without touching the recording sites.

use std::time::Instant;

/// How a query was ultimately served.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServePath {
    /// QA-bank hit: cached answer returned, no LLM inference.
    QaHit,
    /// QKV-cache hit: reuse prefill with `matched_segments` cached segments.
    QkvHit,
    /// Full inference, nothing reused.
    Full,
}

/// One query's measurement record.
#[derive(Debug, Clone)]
pub struct QueryRecord {
    pub query_id: usize,
    pub path: ServePath,
    /// prompt segments total / cached-prefix segments matched
    pub n_segments: usize,
    pub matched_segments: usize,
    // stage latencies, milliseconds (already device-scaled)
    pub embed_ms: f64,
    pub qa_match_ms: f64,
    pub retrieval_ms: f64,
    pub tree_match_ms: f64,
    pub cache_load_ms: f64,
    pub prefill_ms: f64,
    pub decode_ms: f64,
    pub flops: u64,
    pub answer: String,
}

impl QueryRecord {
    pub fn total_ms(&self) -> f64 {
        self.embed_ms
            + self.qa_match_ms
            + self.retrieval_ms
            + self.tree_match_ms
            + self.cache_load_ms
            + self.prefill_ms
            + self.decode_ms
    }
}

/// Stage timer helper: `let t = Stage::start(); ...; rec.prefill_ms = t.ms()`.
pub struct Stage(Instant);

impl Stage {
    pub fn start() -> Self {
        Stage(Instant::now())
    }

    pub fn ms(&self) -> f64 {
        self.0.elapsed().as_secs_f64() * 1e3
    }
}

/// Aggregates across a query stream.
#[derive(Debug, Clone, Default)]
pub struct Recorder {
    pub records: Vec<QueryRecord>,
}

impl Recorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, r: QueryRecord) {
        self.records.push(r);
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    pub fn mean_total_ms(&self) -> f64 {
        if self.records.is_empty() {
            return 0.0;
        }
        self.records.iter().map(|r| r.total_ms()).sum::<f64>() / self.records.len() as f64
    }

    pub fn qa_hit_rate(&self) -> f64 {
        self.rate(|r| r.path == ServePath::QaHit)
    }

    /// QKV hit rate among queries that reached the knowledge bank
    /// (the paper reports layer hit rates independently).
    pub fn qkv_hit_rate(&self) -> f64 {
        let misses: Vec<_> = self
            .records
            .iter()
            .filter(|r| r.path != ServePath::QaHit)
            .collect();
        if misses.is_empty() {
            return 0.0;
        }
        misses.iter().filter(|r| r.path == ServePath::QkvHit).count() as f64
            / misses.len() as f64
    }

    /// Fraction of prompt segments served from the QKV cache, over all
    /// LLM-inference queries (a finer-grained reuse measure).
    pub fn segment_reuse_ratio(&self) -> f64 {
        let (mut matched, mut total) = (0usize, 0usize);
        for r in &self.records {
            if r.path != ServePath::QaHit {
                matched += r.matched_segments;
                total += r.n_segments;
            }
        }
        if total == 0 {
            0.0
        } else {
            matched as f64 / total as f64
        }
    }

    pub fn total_flops(&self) -> u64 {
        self.records.iter().map(|r| r.flops).sum()
    }

    pub fn mean_stage(&self, f: impl Fn(&QueryRecord) -> f64) -> f64 {
        if self.records.is_empty() {
            return 0.0;
        }
        self.records.iter().map(&f).sum::<f64>() / self.records.len() as f64
    }

    fn rate(&self, pred: impl Fn(&QueryRecord) -> bool) -> f64 {
        if self.records.is_empty() {
            return 0.0;
        }
        self.records.iter().filter(|r| pred(r)).count() as f64 / self.records.len() as f64
    }

    pub fn percentile_total_ms(&self, p: f64) -> f64 {
        if self.records.is_empty() {
            return 0.0;
        }
        let mut v: Vec<f64> = self.records.iter().map(|r| r.total_ms()).collect();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        crate::util::bench::percentile(&v, p)
    }
}

/// 1-in-N sampling for the per-stage breakdown histograms.  The serve
/// fast path (a QA-bank hit) is only a few microseconds of real work,
/// so recording every stage on every query would spend a visible slice
/// of the telemetry budget (DESIGN.md §12); stage *distributions* are
/// diagnostic, not SLO signals, and survive sampling unchanged.
const STAGE_SAMPLE_EVERY: u64 = 8;

/// Record one served query into the global telemetry registry.
///
/// Exact on every query: the serve-path counter and the end-to-end
/// `engine.total_ms` histogram — the operator-facing SLO signals.
/// Sampled 1-in-[`STAGE_SAMPLE_EVERY`]: the matched-segment histogram
/// and the per-stage latency histograms (stages that did not run — 0 ms
/// — are skipped so the distributions describe work actually done).
/// Called by every serve path (engine and the cache-level sim); each
/// series resolves once per call site, so the typical per-query cost is
/// two relaxed atomic bumps plus one sampling tick.
pub fn record_query_obs(rec: &QueryRecord) {
    use std::sync::atomic::{AtomicU64, Ordering};
    static STAGE_TICK: AtomicU64 = AtomicU64::new(0);

    match rec.path {
        ServePath::QaHit => crate::obs_counter!("engine.qa_hit").inc(),
        ServePath::QkvHit => crate::obs_counter!("engine.qkv_hit").inc(),
        ServePath::Full => crate::obs_counter!("engine.full").inc(),
    }
    crate::obs_hist!("engine.total_ms").record(rec.total_ms());
    // project the measured stages into the causal trace (no-op unless
    // the global tracer is on and this thread carries a trace context)
    crate::obs::trace::emit_stages_ending_now(&[
        ("embed", rec.embed_ms),
        ("qa_probe", rec.qa_match_ms),
        ("retrieval", rec.retrieval_ms),
        ("qkv_match", rec.tree_match_ms),
        ("slice_load", rec.cache_load_ms),
        ("prefill", rec.prefill_ms),
        ("decode", rec.decode_ms),
    ]);
    if STAGE_TICK.fetch_add(1, Ordering::Relaxed) % STAGE_SAMPLE_EVERY != 0 {
        return;
    }
    // percache-allow(metrics_schema): a count histogram documented in §12; the `_ms` suffix is reserved for latencies
    crate::obs_hist!("engine.matched_segments").record(rec.matched_segments as f64);
    if rec.embed_ms > 0.0 {
        crate::obs_hist!("engine.embed_ms").record(rec.embed_ms);
    }
    if rec.qa_match_ms > 0.0 {
        crate::obs_hist!("engine.qa_match_ms").record(rec.qa_match_ms);
    }
    if rec.retrieval_ms > 0.0 {
        crate::obs_hist!("engine.retrieval_ms").record(rec.retrieval_ms);
    }
    if rec.tree_match_ms > 0.0 {
        crate::obs_hist!("engine.tree_match_ms").record(rec.tree_match_ms);
    }
    if rec.cache_load_ms > 0.0 {
        crate::obs_hist!("engine.cache_load_ms").record(rec.cache_load_ms);
    }
    if rec.prefill_ms > 0.0 {
        crate::obs_hist!("engine.prefill_ms").record(rec.prefill_ms);
    }
    if rec.decode_ms > 0.0 {
        crate::obs_hist!("engine.decode_ms").record(rec.decode_ms);
    }
}

pub fn blank_record(query_id: usize) -> QueryRecord {
    QueryRecord {
        query_id,
        path: ServePath::Full,
        n_segments: 0,
        matched_segments: 0,
        embed_ms: 0.0,
        qa_match_ms: 0.0,
        retrieval_ms: 0.0,
        tree_match_ms: 0.0,
        cache_load_ms: 0.0,
        prefill_ms: 0.0,
        decode_ms: 0.0,
        flops: 0,
        answer: String::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(id: usize, path: ServePath, prefill: f64, decode: f64) -> QueryRecord {
        let mut r = blank_record(id);
        r.path = path;
        r.prefill_ms = prefill;
        r.decode_ms = decode;
        r.n_segments = 4;
        r.matched_segments = if path == ServePath::QkvHit { 2 } else { 0 };
        r.flops = 100;
        r
    }

    #[test]
    fn aggregates() {
        let mut rc = Recorder::new();
        rc.push(rec(0, ServePath::QaHit, 0.0, 0.0));
        rc.push(rec(1, ServePath::QkvHit, 10.0, 5.0));
        rc.push(rec(2, ServePath::Full, 20.0, 5.0));
        rc.push(rec(3, ServePath::Full, 30.0, 5.0));

        assert!((rc.mean_total_ms() - 18.75).abs() < 1e-9);
        assert!((rc.qa_hit_rate() - 0.25).abs() < 1e-9);
        assert!((rc.qkv_hit_rate() - (1.0 / 3.0)).abs() < 1e-9);
        assert!((rc.segment_reuse_ratio() - (2.0 / 12.0)).abs() < 1e-9);
        assert_eq!(rc.total_flops(), 400);
    }

    #[test]
    fn empty_recorder_is_zero() {
        let rc = Recorder::new();
        assert_eq!(rc.mean_total_ms(), 0.0);
        assert_eq!(rc.qa_hit_rate(), 0.0);
        assert_eq!(rc.qkv_hit_rate(), 0.0);
    }

    #[test]
    fn stage_timer_positive() {
        let t = Stage::start();
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert!(t.ms() >= 1.0);
    }

    #[test]
    fn percentiles_ordered() {
        let mut rc = Recorder::new();
        for i in 0..100 {
            rc.push(rec(i, ServePath::Full, i as f64, 0.0));
        }
        assert!(rc.percentile_total_ms(50.0) <= rc.percentile_total_ms(95.0));
    }
}
