//! Analytic FLOP accounting for the transformer artifacts.
//!
//! Exact for the configured model (2·M·N·K per matmul, attention over the
//! padded bucket length — the same work XLA actually executes).  Drives
//! the paper's TFLOPs plots (Fig 15a) and the battery/energy model
//! (Fig 20) via sim::battery.

/// Model dimensions, read from artifacts/manifest.json by the runtime.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ModelDims {
    pub layers: usize,
    pub d_model: usize,
    pub heads: usize,
    pub ffn: usize,
    pub vocab: usize,
}

impl ModelDims {
    /// Total parameter count (tied LM head).
    pub fn params(&self) -> u64 {
        let d = self.d_model as u64;
        let f = self.ffn as u64;
        let v = self.vocab as u64;
        let per_layer = 4 * d * d + 3 * d * f + 2 * d; // attn + swiglu + norms
        v * d + self.layers as u64 * per_layer + d
    }

    fn matmul(m: u64, n: u64, k: u64) -> u64 {
        2 * m * n * k
    }

    /// FLOPs of attention internals (scores + weighted sum) for `s_q`
    /// query rows against `s_k` key rows, all heads together.
    fn attn_core(&self, s_q: u64, s_k: u64) -> u64 {
        // scores: [H, s_q, hd] x [H, s_k, hd] -> 2*s_q*s_k*d total
        // probs@v: same again
        2 * Self::matmul(s_q, s_k, self.d_model as u64)
    }

    fn mlp(&self, s: u64) -> u64 {
        3 * Self::matmul(s, self.ffn as u64, self.d_model as u64)
    }

    fn lm_head(&self) -> u64 {
        Self::matmul(1, self.vocab as u64, self.d_model as u64)
    }

    /// Q/K/V + output projections for `s_proj` projected rows out of `s`
    /// total rows (reuse skips prefix projections but not wo/attention/mlp).
    fn layer(&self, s: u64, q_rows: u64, kv_rows: u64) -> u64 {
        let d = self.d_model as u64;
        Self::matmul(q_rows, d, d)             // wq
            + 2 * Self::matmul(kv_rows, d, d)  // wk, wv
            + Self::matmul(s, d, d)            // wo (full length)
            + self.attn_core(s, s)
            + self.mlp(s)
    }

    /// Full prefill over `s` tokens.
    pub fn prefill_full(&self, s: usize) -> u64 {
        let s = s as u64;
        self.layers as u64 * self.layer(s, s, s) + self.lm_head()
    }

    /// PerCache reuse: Q, K and V projected only for the suffix.
    pub fn prefill_reuse_qkv(&self, p: usize, s: usize) -> u64 {
        let (p, s) = (p as u64, s as u64);
        let suf = s - p;
        self.layers as u64 * self.layer(s, suf, suf) + self.lm_head()
    }

    /// RAGCache-style reuse: K/V suffix-only, Q recomputed full-length.
    pub fn prefill_reuse_kv(&self, p: usize, s: usize) -> u64 {
        let (p, s) = (p as u64, s as u64);
        let suf = s - p;
        self.layers as u64 * self.layer(s, s, suf) + self.lm_head()
    }

    /// One decode step against a KV cache of `ctx` rows (padded bucket).
    pub fn decode_step(&self, ctx: usize) -> u64 {
        let d = self.d_model as u64;
        let per_layer = 3 * Self::matmul(1, d, d)      // qkv for 1 token
            + Self::matmul(1, d, d)                    // wo
            + self.attn_core(1, ctx as u64)
            + self.mlp(1);
        self.layers as u64 * per_layer + self.lm_head()
    }

    /// Q/K/V projection FLOPs alone — the quantity Fig 13 breaks down.
    pub fn projection_flops(&self, q_rows: usize, kv_rows: usize) -> (u64, u64, u64) {
        let d = self.d_model as u64;
        let q = Self::matmul(q_rows as u64, d, d);
        let k = Self::matmul(kv_rows as u64, d, d);
        (q, k, k)
    }
}

/// Embedding encoder FLOPs (tiny; included for completeness of the
/// battery model).
pub fn embed_flops(seg: usize, d_embed: usize, d_hidden: usize, d_out: usize) -> u64 {
    (2 * seg * d_embed + 2 * d_embed * d_hidden + 2 * d_hidden * d_out) as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn llama() -> ModelDims {
        ModelDims { layers: 4, d_model: 256, heads: 8, ffn: 1024, vocab: 8192 }
    }

    #[test]
    fn params_order_of_magnitude() {
        let p = llama().params();
        // 8192*256 + 4*(4*256² + 3*256*1024 + 512) + 256 ≈ 7.3M
        assert!(p > 6_000_000 && p < 9_000_000, "{p}");
    }

    #[test]
    fn reuse_strictly_cheaper_and_ordered() {
        let m = llama();
        let (p, s) = (128, 256);
        let full = m.prefill_full(s);
        let kv = m.prefill_reuse_kv(p, s);
        let qkv = m.prefill_reuse_qkv(p, s);
        assert!(qkv < kv, "qkv reuse must beat kv reuse: {qkv} vs {kv}");
        assert!(kv < full, "kv reuse must beat full: {kv} vs {full}");
    }

    #[test]
    fn reuse_saving_matches_projection_arithmetic() {
        let m = llama();
        let (p, s) = (192, 256);
        let diff = m.prefill_full(s) - m.prefill_reuse_qkv(p, s);
        // exactly the skipped q/k/v projections of the prefix
        let d = 256u64;
        let expect = m.layers as u64 * 3 * 2 * (p as u64) * d * d;
        assert_eq!(diff, expect);
    }

    #[test]
    fn zero_prefix_equals_full() {
        let m = llama();
        assert_eq!(m.prefill_reuse_qkv(0, 192), m.prefill_full(192));
        assert_eq!(m.prefill_reuse_kv(0, 192), m.prefill_full(192));
    }

    #[test]
    fn decode_scales_with_ctx() {
        let m = llama();
        assert!(m.decode_step(384) > m.decode_step(128));
        // decode ≪ prefill
        assert!(m.decode_step(384) * 20 < m.prefill_full(256));
    }

    #[test]
    fn projection_split() {
        let m = llama();
        let (q, k, v) = m.projection_flops(256, 64);
        assert_eq!(q, 2 * 256 * 256 * 256);
        assert_eq!(k, v);
        assert_eq!(k, 2 * 64 * 256 * 256);
    }
}
