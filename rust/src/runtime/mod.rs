//! PJRT runtime: loads HLO-text artifacts and executes them on the CPU
//! client (the serve-time half of the AOT bridge — python never runs here).
//!
//! Pattern follows /opt/xla-example/load_hlo: `HloModuleProto::from_text_file`
//! → `XlaComputation::from_proto` → `client.compile` → `execute`.
//!
//! Perf-relevant design points:
//! * executables compile lazily on first use and are cached by name;
//! * model weights upload to device **once** (`PjRtBuffer`s) and every call
//!   uses `execute_b`, so the hot path transfers only the small data inputs;
//! * outputs come back as literals; helpers unwrap the `return_tuple=True`
//!   convention used by aot.py.

pub mod manifest;

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;

use anyhow::{Context, Result};

pub use manifest::{ArtifactEntry, EmbedManifest, Manifest, ModelManifest, WeightEntry};

/// Data input for an artifact call.
pub enum Input {
    I32Scalar(i32),
    I32(Vec<i32>, Vec<usize>),
    F32(Vec<f32>, Vec<usize>),
    /// Borrowed f32 tensor (avoids copying big QKV/KV caches).
    F32Ref(*const f32, usize, Vec<usize>),
}

impl Input {
    pub fn f32_slice(data: &[f32], dims: Vec<usize>) -> Input {
        Input::F32Ref(data.as_ptr(), data.len(), dims)
    }
}

struct ModelState {
    weights: Vec<xla::PjRtBuffer>,
    /// Host-side float count, kept for tests/debug introspection.
    host_floats: usize,
}

/// The PJRT runtime: client + manifest + compiled-executable cache +
/// per-model device-resident weights.
pub struct Runtime {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    executables: RefCell<HashMap<String, xla::PjRtLoadedExecutable>>,
    models: RefCell<HashMap<String, ModelState>>,
    embed_state: RefCell<Option<ModelState>>,
    /// Cumulative executions, for metrics/tests.
    pub exec_count: RefCell<u64>,
}

impl Runtime {
    pub fn load(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            manifest,
            client,
            executables: RefCell::new(HashMap::new()),
            models: RefCell::new(HashMap::new()),
            embed_state: RefCell::new(None),
            exec_count: RefCell::new(0),
        })
    }

    pub fn load_default() -> Result<Runtime> {
        Self::load(&Manifest::default_dir())
    }

    // -- weights -----------------------------------------------------------

    fn read_weights_bin(&self, bin: &str, expect_floats: usize) -> Result<Vec<f32>> {
        let path = self.manifest.dir.join(bin);
        let bytes =
            std::fs::read(&path).with_context(|| format!("reading {}", path.display()))?;
        anyhow::ensure!(
            bytes.len() == expect_floats * 4,
            "weights blob {} has {} bytes, manifest expects {}",
            bin,
            bytes.len(),
            expect_floats * 4
        );
        let mut floats = vec![0f32; expect_floats];
        for (i, c) in bytes.chunks_exact(4).enumerate() {
            floats[i] = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
        }
        Ok(floats)
    }

    fn upload_weights(&self, entries: &[WeightEntry], bin: &str) -> Result<ModelState> {
        let total: usize = entries.iter().map(|w| w.len).sum();
        let floats = self.read_weights_bin(bin, total)?;
        let mut bufs = Vec::with_capacity(entries.len());
        for w in entries {
            let slice = &floats[w.offset..w.offset + w.len];
            let buf = self
                .client
                .buffer_from_host_buffer(slice, &w.shape, None)
                .with_context(|| format!("uploading weight {}", w.name))?;
            bufs.push(buf);
        }
        Ok(ModelState {
            weights: bufs,
            host_floats: total,
        })
    }

    fn ensure_model(&self, model: &str) -> Result<()> {
        if !self.models.borrow().contains_key(model) {
            let mm = self.manifest.model(model)?.clone();
            let state = self.upload_weights(&mm.weights, &mm.weights_bin)?;
            self.models.borrow_mut().insert(model.to_string(), state);
        }
        Ok(())
    }

    fn ensure_embed(&self) -> Result<()> {
        if self.embed_state.borrow().is_none() {
            let em = self.manifest.embed.clone();
            let state = self.upload_weights(&em.weights, &em.weights_bin)?;
            *self.embed_state.borrow_mut() = Some(state);
        }
        Ok(())
    }

    /// Host-side float count of a model's uploaded weights (test hook).
    pub fn model_weight_floats(&self, model: &str) -> Result<usize> {
        self.ensure_model(model)?;
        Ok(self.models.borrow()[model].host_floats)
    }

    // -- executables ---------------------------------------------------------

    fn ensure_executable(&self, key: &str, file: &str) -> Result<()> {
        if self.executables.borrow().contains_key(key) {
            return Ok(());
        }
        let path = self.manifest.dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {key}"))?;
        self.executables.borrow_mut().insert(key.to_string(), exe);
        Ok(())
    }

    /// Pre-compile a set of artifacts (used at startup to keep first-query
    /// compile time out of the latency measurements).
    pub fn warm(&self, model: &str, artifact_names: &[&str]) -> Result<()> {
        self.ensure_model(model)?;
        let mm = self.manifest.model(model)?.clone();
        for a in artifact_names {
            let art = mm.artifact(a)?;
            self.ensure_executable(&format!("{model}/{a}"), &art.file)?;
        }
        Ok(())
    }

    pub fn compiled_count(&self) -> usize {
        self.executables.borrow().len()
    }

    // -- execution ---------------------------------------------------------

    fn upload_input(&self, input: &Input) -> Result<xla::PjRtBuffer> {
        match input {
            Input::I32Scalar(v) => self
                .client
                .buffer_from_host_buffer(&[*v], &[], None)
                .context("uploading i32 scalar"),
            Input::I32(data, dims) => self
                .client
                .buffer_from_host_buffer(data, dims, None)
                .context("uploading i32 tensor"),
            Input::F32(data, dims) => self
                .client
                .buffer_from_host_buffer(data, dims, None)
                .context("uploading f32 tensor"),
            Input::F32Ref(ptr, len, dims) => {
                // SAFETY: the `Input::F32Ref` constructor contract
                // requires `ptr` valid for `len` f32 reads for the
                // lifetime of this call; `buffer_from_host_buffer`
                // copies the data to the device before returning, so
                // the borrow does not outlive the upload.
                let slice = unsafe { std::slice::from_raw_parts(*ptr, *len) };
                self.client
                    .buffer_from_host_buffer(slice, dims, None)
                    .context("uploading f32 ref tensor")
            }
        }
    }

    /// Execute a model artifact: uploads `data_inputs`, appends the
    /// device-resident weights, returns the decomposed output tuple.
    pub fn exec_model(
        &self,
        model: &str,
        artifact: &str,
        data_inputs: &[Input],
    ) -> Result<Vec<xla::Literal>> {
        self.ensure_model(model)?;
        let mm = self.manifest.model(model)?.clone();
        let art = mm.artifact(artifact)?;
        anyhow::ensure!(
            data_inputs.len() == art.inputs.len(),
            "artifact {artifact} expects {} data inputs ({:?}), got {}",
            art.inputs.len(),
            art.inputs,
            data_inputs.len()
        );
        let key = format!("{model}/{artifact}");
        self.ensure_executable(&key, &art.file)?;

        let mut args: Vec<xla::PjRtBuffer> = Vec::with_capacity(data_inputs.len());
        for inp in data_inputs {
            args.push(self.upload_input(inp)?);
        }
        let models = self.models.borrow();
        let state = &models[model];
        let execs = self.executables.borrow();
        let exe = &execs[&key];

        let mut all: Vec<&xla::PjRtBuffer> = args.iter().collect();
        all.extend(state.weights.iter());
        let out = exe
            .execute_b(&all)
            .with_context(|| format!("executing {key}"))?;
        *self.exec_count.borrow_mut() += 1;
        let lit = out[0][0].to_literal_sync().context("downloading result")?;
        lit.to_tuple().context("decomposing output tuple")
    }

    /// Execute the embedding artifact on one token segment.
    pub fn exec_embed(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        self.ensure_embed()?;
        let em = self.manifest.embed.clone();
        let key = "embed".to_string();
        self.ensure_executable(&key, &em.artifact)?;

        let tok_buf = self
            .client
            .buffer_from_host_buffer(tokens, &[tokens.len()], None)?;
        let state_ref = self.embed_state.borrow();
        let state = state_ref.as_ref().unwrap();
        let execs = self.executables.borrow();
        let exe = &execs[&key];

        let mut all: Vec<&xla::PjRtBuffer> = vec![&tok_buf];
        all.extend(state.weights.iter());
        let out = exe.execute_b(&all).context("executing embed")?;
        *self.exec_count.borrow_mut() += 1;
        let lit = out[0][0].to_literal_sync()?;
        let e = lit.to_tuple1().context("embed output tuple")?;
        e.to_vec::<f32>().context("embed output to_vec")
    }
}

/// Extract an f32 tensor from a literal.
pub fn literal_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().context("literal to f32 vec")
}
