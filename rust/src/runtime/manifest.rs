//! artifacts/manifest.json parsing.
//!
//! The manifest is the single source of truth for model dimensions, the
//! artifact grid, and weight-blob layout — rust never hard-codes any of
//! them (DESIGN.md §2).  Produced by python/compile/aot.py.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::metrics::ModelDims;
use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct WeightEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize, // in f32 elements
    pub len: usize,
}

#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: String,
    pub kind: String,
    pub n_seg: Option<usize>,
    pub p_seg: Option<usize>,
    /// Tokens per call for decode_block artifacts.
    pub block: Option<usize>,
    pub inputs: Vec<String>,
    pub outputs: Vec<String>,
}

#[derive(Debug, Clone)]
pub struct ModelManifest {
    pub name: String,
    pub stands_for: String,
    pub dims: ModelDims,
    pub head_dim: usize,
    pub weights_bin: String,
    pub weights: Vec<WeightEntry>,
    pub artifacts: HashMap<String, ArtifactEntry>,
}

#[derive(Debug, Clone)]
pub struct EmbedManifest {
    pub stands_for: String,
    pub d_embed: usize,
    pub d_hidden: usize,
    pub d_out: usize,
    pub weights_bin: String,
    pub weights: Vec<WeightEntry>,
    pub artifact: String,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub segment_tokens: usize,
    pub n_segments: Vec<usize>,
    pub decode_ctx: usize,
    pub decode_gen_tokens: usize,
    pub vocab: usize,
    pub pad: i32,
    pub models: HashMap<String, ModelManifest>,
    pub embed: EmbedManifest,
}

fn parse_weights(j: &Json) -> Result<Vec<WeightEntry>> {
    let arr = j.as_arr().context("weights must be an array")?;
    let mut out = Vec::with_capacity(arr.len());
    for w in arr {
        out.push(WeightEntry {
            name: w.get("name").as_str().context("weight name")?.to_string(),
            shape: w
                .get("shape")
                .as_arr()
                .context("weight shape")?
                .iter()
                .map(|d| d.as_usize().unwrap_or(0))
                .collect(),
            offset: w.get("offset").as_usize().context("weight offset")?,
            len: w.get("len").as_usize().context("weight len")?,
        });
    }
    Ok(out)
}

fn parse_str_list(j: &Json) -> Vec<String> {
    j.as_arr()
        .map(|a| {
            a.iter()
                .filter_map(|s| s.as_str().map(|x| x.to_string()))
                .collect()
        })
        .unwrap_or_default()
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;

        let mut models = HashMap::new();
        let mobj = j
            .get("models")
            .as_obj()
            .context("manifest missing models")?;
        for (mname, mj) in mobj.iter() {
            let mut artifacts = HashMap::new();
            let aobj = mj
                .get("artifacts")
                .as_obj()
                .with_context(|| format!("model {mname} missing artifacts"))?;
            for (aname, aj) in aobj.iter() {
                artifacts.insert(
                    aname.to_string(),
                    ArtifactEntry {
                        name: aname.to_string(),
                        file: aj.get("file").as_str().context("artifact file")?.to_string(),
                        kind: aj.get("kind").as_str().context("artifact kind")?.to_string(),
                        n_seg: aj.get("n_seg").as_usize(),
                        p_seg: aj.get("p_seg").as_usize(),
                        block: aj.get("block").as_usize(),
                        inputs: parse_str_list(aj.get("inputs")),
                        outputs: parse_str_list(aj.get("outputs")),
                    },
                );
            }
            let dims = ModelDims {
                layers: mj.get("layers").as_usize().context("layers")?,
                d_model: mj.get("d_model").as_usize().context("d_model")?,
                heads: mj.get("heads").as_usize().context("heads")?,
                ffn: mj.get("ffn").as_usize().context("ffn")?,
                vocab: mj.get("vocab").as_usize().context("vocab")?,
            };
            models.insert(
                mname.to_string(),
                ModelManifest {
                    name: mname.to_string(),
                    stands_for: mj.get("stands_for").as_str().unwrap_or("").to_string(),
                    dims,
                    head_dim: mj.get("head_dim").as_usize().unwrap_or(dims.d_model / dims.heads),
                    weights_bin: mj
                        .get("weights_bin")
                        .as_str()
                        .context("weights_bin")?
                        .to_string(),
                    weights: parse_weights(mj.get("weights"))?,
                    artifacts,
                },
            );
        }
        if models.is_empty() {
            bail!("manifest has no models");
        }

        let ej = j.get("embed");
        let embed = EmbedManifest {
            stands_for: ej.get("stands_for").as_str().unwrap_or("").to_string(),
            d_embed: ej.get("d_embed").as_usize().context("embed d_embed")?,
            d_hidden: ej.get("d_hidden").as_usize().context("embed d_hidden")?,
            d_out: ej.get("d_out").as_usize().context("embed d_out")?,
            weights_bin: ej
                .get("weights_bin")
                .as_str()
                .context("embed weights_bin")?
                .to_string(),
            weights: parse_weights(ej.get("weights"))?,
            artifact: ej.get("artifact").as_str().context("embed artifact")?.to_string(),
        };

        Ok(Manifest {
            dir: dir.to_path_buf(),
            segment_tokens: j.get("segment_tokens").as_usize().context("segment_tokens")?,
            n_segments: j
                .get("n_segments")
                .as_arr()
                .context("n_segments")?
                .iter()
                .filter_map(|x| x.as_usize())
                .collect(),
            decode_ctx: j.get("decode_ctx").as_usize().context("decode_ctx")?,
            decode_gen_tokens: j.get("decode_gen_tokens").as_usize().unwrap_or(64),
            vocab: j.get("vocab").as_usize().context("vocab")?,
            pad: j.get("pad").as_i64().context("pad")? as i32,
            models,
            embed,
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelManifest> {
        self.models
            .get(name)
            .with_context(|| format!("model '{name}' not in manifest"))
    }

    /// Default artifacts directory: $PERCACHE_ARTIFACTS or ./artifacts.
    pub fn default_dir() -> PathBuf {
        std::env::var("PERCACHE_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }
}

impl ModelManifest {
    pub fn artifact(&self, name: &str) -> Result<&ArtifactEntry> {
        self.artifacts
            .get(name)
            .with_context(|| format!("artifact '{name}' not built for model {}", self.name))
    }

    pub fn total_weight_floats(&self) -> usize {
        self.weights.iter().map(|w| w.len).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build a tiny synthetic manifest to validate parsing without
    /// requiring artifacts (the real file is covered by integration tests).
    #[test]
    fn parses_synthetic_manifest() {
        let text = r#"{
          "segment_tokens": 64, "n_segments": [2,3], "decode_ctx": 384,
          "decode_gen_tokens": 64, "vocab": 8192, "pad": 0,
          "models": {
            "m": {
              "stands_for": "X", "layers": 2, "d_model": 64, "heads": 2,
              "head_dim": 32, "ffn": 128, "vocab": 8192,
              "weights_bin": "w.bin",
              "weights": [{"name":"tok_emb","shape":[8192,64],"offset":0,"len":524288}],
              "artifacts": {
                "prefill_full_n2": {"file":"f.hlo.txt","kind":"prefill_full",
                  "n_seg":2,"inputs":["tokens"],"outputs":["logits","qkv"]}
              }
            }
          },
          "embed": {
            "stands_for":"E","d_embed":64,"d_hidden":128,"d_out":64,
            "weights_bin":"we.bin","weights":[],"artifact":"embed.hlo.txt",
            "inputs":["tokens"],"outputs":["embedding"]
          }
        }"#;
        let dir = std::env::temp_dir().join("percache_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), text).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.segment_tokens, 64);
        let mm = m.model("m").unwrap();
        assert_eq!(mm.dims.layers, 2);
        assert_eq!(mm.artifact("prefill_full_n2").unwrap().n_seg, Some(2));
        assert!(mm.artifact("nope").is_err());
        assert!(m.model("nope").is_err());
        assert_eq!(mm.total_weight_floats(), 524288);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_manifest_is_error_with_hint() {
        let err = Manifest::load(Path::new("/nonexistent/dir")).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
