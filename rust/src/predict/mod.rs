//! Predictive query generation (paper §4.1.2): the answer to single-user
//! query sparsity.  Two complementary views, run during device idle time:
//!
//! * **knowledge-based** — questions about key content of the knowledge
//!   bank, derived from the knowledge *abstract* (not raw chunks — the
//!   paper uses abstracts to keep prediction cheap and broad);
//! * **history-based** — questions mimicking the user's own phrasing and
//!   topical drift, from a recent-query buffer.
//!
//! Substitution note (DESIGN.md §3): the paper prompts the on-device LLM
//! (App. B.3); our tiny random-weight LM cannot produce meaningful text,
//! so questions are synthesized from the same inputs the paper's prompts
//! see — abstract terms and the history buffer — via the question-template
//! families the datasets actually use.  What the *system* needs from
//! prediction is preserved: predicted queries retrieve the chunks future
//! real queries retrieve and embed near them.  The LLM *cost* of
//! prediction is still charged by the engine (prefill over the abstract /
//! history prompt).

use std::collections::VecDeque;

use crate::kb::KnowledgeBank;
use crate::tokenizer;
use crate::util::rng::Rng;

/// Question-template families shared (deliberately) with the dataset
/// generators — both model "questions a user asks about personal data".
pub const GENERAL_TEMPLATES: &[&str] = &[
    "what is the main topic of the {a} discussion",
    "summarize the {a} {b} notes",
    "what was said about the {a}",
];

pub const DETAIL_TEMPLATES: &[&str] = &[
    "when is the {a} {b} scheduled",
    "who is responsible for the {a} {b}",
    "what did they decide about the {a} {b}",
    "where does the {a} {b} take place",
    "what time is the {a} {b}",
];

/// History buffer capacity (recent queries considered for style mimicry).
pub const HISTORY_CAP: usize = 16;

/// Arrival-tick buffer capacity (recent activity considered for the
/// next-active-period forecast).
pub const ARRIVAL_TICKS_CAP: usize = 64;

/// Ticks of silence that end one activity burst and start the next.
const BURST_GAP_TICKS: u64 = 3;

#[derive(Debug)]
pub struct QueryPredictor {
    history: VecDeque<String>,
    /// Controller ticks at which this tenant received queries (deduped
    /// consecutively, capped) — the signal behind
    /// [`Self::forecast_next_active`].
    arrival_ticks: Vec<u64>,
    rng: Rng,
    /// Persisted state (the history buffer) changed since the last
    /// [`Self::mark_clean`] — incremental snapshots skip clean predictors.
    dirty: bool,
    /// Round counters for metrics / Fig 20-style accounting.
    pub knowledge_rounds: u64,
    pub history_rounds: u64,
}

impl QueryPredictor {
    pub fn new(seed: u64) -> Self {
        QueryPredictor {
            history: VecDeque::new(),
            arrival_ticks: Vec::new(),
            rng: Rng::new(seed),
            dirty: false,
            knowledge_rounds: 0,
            history_rounds: 0,
        }
    }

    /// Record a real user query into the history buffer.
    pub fn observe(&mut self, query: &str) {
        if self.history.len() == HISTORY_CAP {
            self.history.pop_front();
        }
        self.history.push_back(query.to_string());
        self.dirty = true;
    }

    /// Record that this tenant received at least one query at a tiering
    /// controller tick.  Consecutive duplicates collapse (one entry per
    /// active tick), and the buffer is capped at [`ARRIVAL_TICKS_CAP`].
    pub fn observe_arrival(&mut self, tick: u64) {
        if self.arrival_ticks.last() == Some(&tick) {
            return;
        }
        if self.arrival_ticks.len() == ARRIVAL_TICKS_CAP {
            self.arrival_ticks.remove(0);
        }
        self.arrival_ticks.push(tick);
        self.dirty = true;
    }

    /// Active-tick history, oldest first (persistence + reporting).
    pub fn arrival_ticks(&self) -> &[u64] {
        &self.arrival_ticks
    }

    /// Forecast the tick at which this tenant's next active period
    /// starts, from the periodicity of its arrival history.
    ///
    /// Activity is grouped into bursts (gaps > [`BURST_GAP_TICKS`] split
    /// them); with at least three burst starts whose last two
    /// inter-burst periods agree within 25%, the next start is
    /// extrapolated at the mean period.  Irregular traffic forecasts
    /// nothing — a wrong prefetch costs memory, no forecast costs only
    /// a hydration stall.
    pub fn forecast_next_active(&self) -> Option<u64> {
        let mut starts: Vec<u64> = Vec::new();
        let mut prev: Option<u64> = None;
        for &t in &self.arrival_ticks {
            let new_burst = match prev {
                Some(p) => t.saturating_sub(p) > BURST_GAP_TICKS,
                None => true,
            };
            if new_burst {
                starts.push(t);
            }
            prev = Some(t);
        }
        if starts.len() < 3 {
            return None;
        }
        let n = starts.len();
        let p1 = starts[n - 1] - starts[n - 2];
        let p2 = starts[n - 2] - starts[n - 3];
        // reject periods that disagree by more than 25% of the larger
        if p1.abs_diff(p2) * 4 > p1.max(p2) {
            return None;
        }
        Some(starts[n - 1] + (p1 + p2) / 2)
    }

    /// Whether persisted state changed since the last [`Self::mark_clean`].
    pub fn is_dirty(&self) -> bool {
        self.dirty
    }

    /// Mark the current state as snapshotted (persistence internal).
    pub fn mark_clean(&mut self) {
        self.dirty = false;
    }

    pub fn history_len(&self) -> usize {
        self.history.len()
    }

    /// Recent-query buffer, oldest first (persistence, DESIGN.md §10).
    /// Restoring is just `observe`-ing these back in order.
    pub fn history_snapshot(&self) -> Vec<String> {
        self.history.iter().cloned().collect()
    }

    /// Drop the recent-query buffer (a state restore replaces history
    /// wholesale rather than mixing two sessions').
    pub fn clear_history(&mut self) {
        if !self.history.is_empty() {
            self.dirty = true;
        }
        self.history.clear();
    }

    /// Knowledge-based prediction: `stride` questions over abstract terms.
    /// Mirrors the paper's two question kinds (general + detailed).
    pub fn predict_from_knowledge(&mut self, kb: &KnowledgeBank, stride: usize) -> Vec<String> {
        self.knowledge_rounds += 1;
        let terms = kb.abstract_terms(12);
        if terms.is_empty() {
            return Vec::new();
        }
        let mut out = Vec::with_capacity(stride);
        for i in 0..stride {
            let a = terms[self.rng.below(terms.len())].clone();
            let b = terms[self.rng.below(terms.len())].clone();
            let template = if i % 3 == 0 {
                GENERAL_TEMPLATES[self.rng.below(GENERAL_TEMPLATES.len())]
            } else {
                DETAIL_TEMPLATES[self.rng.below(DETAIL_TEMPLATES.len())]
            };
            out.push(fill_template(template, &a, &b));
        }
        dedup_keep_order(out)
    }

    /// History-based prediction: recombine content words from recent real
    /// queries with fresh question stems ("mirror the language style …
    /// and interests shown in the examples").
    pub fn predict_from_history(&mut self, stride: usize) -> Vec<String> {
        if self.history.is_empty() {
            return Vec::new();
        }
        self.history_rounds += 1;
        // harvest content words (non-stopword-ish: len > 3) from history
        let mut content: Vec<String> = Vec::new();
        for q in &self.history {
            for w in tokenizer::words(q) {
                if w.len() > 3 && !content.contains(&w) {
                    content.push(w);
                }
            }
        }
        if content.is_empty() {
            return Vec::new();
        }
        let stems = [
            "what about the",
            "any update on the",
            "remind me about the",
            "when was the",
            "what happened with the",
        ];
        let mut out = Vec::with_capacity(stride);
        for _ in 0..stride {
            let stem = stems[self.rng.below(stems.len())];
            let a = &content[self.rng.below(content.len())];
            let b = &content[self.rng.below(content.len())];
            let q = if a == b {
                format!("{stem} {a}")
            } else {
                format!("{stem} {a} {b}")
            };
            out.push(q);
        }
        dedup_keep_order(out)
    }

    /// The "prompt" whose LLM cost the engine charges for a prediction
    /// round — abstract terms (knowledge view) or the history buffer
    /// (history view), exactly the context the paper's prompts carry.
    pub fn prediction_context(&self, kb: &KnowledgeBank) -> String {
        let mut ctx = kb.abstract_terms(12).join(" ");
        for q in &self.history {
            ctx.push(' ');
            ctx.push_str(q);
        }
        ctx
    }
}

fn fill_template(template: &str, a: &str, b: &str) -> String {
    template.replace("{a}", a).replace("{b}", b)
}

fn dedup_keep_order(v: Vec<String>) -> Vec<String> {
    let mut seen = std::collections::HashSet::new();
    v.into_iter().filter(|q| seen.insert(q.clone())).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kb::KnowledgeBank;

    fn kb_with(texts: &[&str]) -> KnowledgeBank {
        // build without an embedder via the test-only raw path: reuse
        // add-like logic by constructing through public API is not
        // possible without a runtime, so replicate minimal state.
        let mut kb = KnowledgeBank::new();
        // SAFETY: test-only — uses the internal pathway through
        // add_chunk's logic but bypassing embeddings isn't exposed;
        // instead lean on abstract_terms needing only text+df, which we
        // get via a tiny shim below.
        for t in texts {
            kb_push(&mut kb, t);
        }
        kb
    }

    // Minimal mirror of KnowledgeBank::add_chunk without the embedder.
    fn kb_push(kb: &mut KnowledgeBank, text: &str) {
        kb.test_insert_chunk(crate::kb::Chunk {
            id: kb.len(),
            text: text.to_string(),
            tokens: tokenizer::encode_segment(text),
            embedding: vec![0.0; 4],
            key: tokenizer::fnv1a64(text.as_bytes()),
        });
    }

    #[test]
    fn knowledge_prediction_uses_kb_terms() {
        let kb = kb_with(&[
            "quarterly budget review meeting thursday finance",
            "product launch rehearsal presentation friday",
        ]);
        let mut p = QueryPredictor::new(1);
        let qs = p.predict_from_knowledge(&kb, 5);
        assert!(!qs.is_empty());
        let joined = qs.join(" ");
        let terms = kb.abstract_terms(12);
        assert!(
            terms.iter().any(|t| joined.contains(t.as_str())),
            "predictions {qs:?} must mention kb terms {terms:?}"
        );
    }

    #[test]
    fn history_prediction_mirrors_content_words() {
        let mut p = QueryPredictor::new(2);
        p.observe("when is the budget review meeting");
        p.observe("who attends the product launch");
        let qs = p.predict_from_history(5);
        assert!(!qs.is_empty());
        for q in &qs {
            let has = ["budget", "review", "meeting", "product", "launch", "attends", "when"]
                .iter()
                .any(|w| q.contains(w));
            assert!(has, "{q} should reuse history content");
        }
    }

    #[test]
    fn empty_inputs_give_no_predictions() {
        let kb = KnowledgeBank::new();
        let mut p = QueryPredictor::new(3);
        assert!(p.predict_from_knowledge(&kb, 5).is_empty());
        assert!(p.predict_from_history(5).is_empty());
    }

    #[test]
    fn history_buffer_caps() {
        let mut p = QueryPredictor::new(4);
        for i in 0..40 {
            p.observe(&format!("query number {i}"));
        }
        assert_eq!(p.history_len(), HISTORY_CAP);
    }

    #[test]
    fn arrival_ticks_dedupe_and_cap() {
        let mut p = QueryPredictor::new(5);
        p.observe_arrival(3);
        p.observe_arrival(3); // consecutive duplicate collapses
        p.observe_arrival(4);
        assert_eq!(p.arrival_ticks(), &[3, 4]);
        for t in 0..(ARRIVAL_TICKS_CAP as u64 * 2) {
            p.observe_arrival(100 + t);
        }
        assert_eq!(p.arrival_ticks().len(), ARRIVAL_TICKS_CAP);
        assert!(p.is_dirty());
    }

    #[test]
    fn periodic_arrivals_forecast_the_next_burst() {
        let mut p = QueryPredictor::new(6);
        // three bursts of 3 active ticks, period 12: starts 0, 12, 24
        for start in [0u64, 12, 24] {
            for off in 0..3 {
                p.observe_arrival(start + off);
            }
        }
        assert_eq!(
            p.forecast_next_active(),
            Some(36),
            "period-12 bursts must forecast the fourth start"
        );
    }

    #[test]
    fn irregular_arrivals_forecast_nothing() {
        let mut p = QueryPredictor::new(7);
        assert_eq!(p.forecast_next_active(), None, "empty history");
        // two bursts are not enough evidence
        for t in [0u64, 1, 12, 13] {
            p.observe_arrival(t);
        }
        assert_eq!(p.forecast_next_active(), None, "two bursts");
        // a third burst at a wildly different period is rejected
        p.observe_arrival(50);
        assert_eq!(p.forecast_next_active(), None, "periods disagree");
    }

    #[test]
    fn deterministic_per_seed() {
        let kb = kb_with(&["alpha beta gamma delta epsilon budget"]);
        let mut a = QueryPredictor::new(7);
        let mut b = QueryPredictor::new(7);
        assert_eq!(a.predict_from_knowledge(&kb, 4), b.predict_from_knowledge(&kb, 4));
    }
}
