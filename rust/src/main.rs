//! `percache` — leader binary: serve queries, run experiments, inspect
//! the system.
//!
//! ```text
//! percache serve   [--model llama] [--dataset mised] [--user 0]
//!                  [--persist-dir state/] [--checkpoint-secs 30]
//!                  [--tiering --tenants 4] …
//! percache exp     <fig2|…|table1|persistence|tiering|obs|dedup|all>
//!                  [--out reports] [--smoke]
//! percache tenants [--tenants 8] [--arrivals 0] [--zipf 1.0] [--sweep]
//! percache metrics [path] [--prom]
//! percache trace   [path] [--tenant N] [--p 99] [--max-unattributed 0.05]
//! percache check   [--json reports/ANALYSIS.json]
//! percache info
//! ```

// Same seed-tree style allowance as rust/src/lib.rs (configs are built
// by mutating a `default()`); the CI clippy gate enforces the rest.
#![allow(clippy::field_reassign_with_default)]
#![deny(unsafe_code)]

use anyhow::Result;
use percache::util::cli::Cli;

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn real_main() -> Result<()> {
    let mut args = std::env::args().skip(1);
    let sub = args.next().unwrap_or_else(|| "help".to_string());
    match sub.as_str() {
        "serve" => cmd_serve(),
        "exp" => cmd_exp(),
        "tenants" => cmd_tenants(),
        "metrics" => cmd_metrics(),
        "trace" => cmd_trace(),
        "check" => cmd_check(),
        "info" => cmd_info(),
        _ => {
            println!(
                "percache — predictive hierarchical cache for on-device RAG\n\n\
                 subcommands:\n  \
                 serve    run the interactive serving demo over a dataset user\n  \
                 exp      reproduce a paper figure/table (or `all`)\n  \
                 tenants  multi-tenant sharding demo/sweep (no artifacts needed)\n  \
                 metrics  pretty-print a metrics dump (see serve --metrics-file)\n  \
                 trace    span-tree attribution over a causal trace dump\n  \
                 check    run the static analysis pass over the crate sources\n  \
                 info     print manifest / artifact summary\n\n\
                 run `percache <subcommand> --help` for flags"
            );
            Ok(())
        }
    }
}

/// Multi-tenant cache sharding under one global budget — runs entirely at
/// the cache level (no PJRT artifacts required).
fn cmd_tenants() -> Result<()> {
    use percache::config::TenancyConfig;
    use percache::tenancy::sim::{arrivals_from_workload, replay, sim_slice_bytes, SimConfig};
    use percache::tenancy::{RouterConfig, TenantRegistry};

    let cli = Cli::new("percache tenants — multi-tenant sharding demo / scaling sweep")
        .flag("tenants", "8", "tenant count")
        .flag("arrivals", "0", "total arrivals (0 = 40 per tenant)")
        .flag("zipf", "1.0", "tenant-popularity skew exponent")
        .flag("budget-slices", "96", "global QKV budget in slices")
        .flag("rebalance-every", "16", "governor cadence in serves")
        .switch("sweep", "run the tenant-count sweep + BENCH_tenancy.json")
        .switch("verbose", "per-tenant breakdown");
    let a = cli.parse_env(1);

    if a.get_bool("sweep") {
        return percache::exp::tenancy_exp::run_and_report();
    }

    let n = a.get_usize("tenants").max(1);
    let arrivals_n = match a.get_usize("arrivals") {
        0 => n * 40,
        v => v,
    };
    let tc = TenancyConfig {
        enabled: true,
        max_tenants: n,
        global_qkv_bytes: a.get_usize("budget-slices") * sim_slice_bytes(),
        rebalance_every: a.get_usize("rebalance-every").max(1),
        ..TenancyConfig::default()
    };

    let mut reg = TenantRegistry::new(&tc);
    for _ in 0..n {
        reg.create_tenant()?;
    }
    let w = percache::datasets::multi_tenant(n, arrivals_n, a.get_f64("zipf"), 0xBEEF);
    let arrivals = arrivals_from_workload(&w);
    let out = replay(
        &mut reg,
        RouterConfig {
            queue_cap: tc.queue_cap,
            global_cap: tc.global_queue_cap,
            shed_queue_cap: tc.slo.shed_queue_cap(tc.queue_cap),
        },
        &SimConfig::default(),
        &arrivals,
        8,
    )?;

    println!(
        "[tenants] {} tenants, {} arrivals, global budget {} slices ({} KB)",
        n,
        arrivals.len(),
        a.get_usize("budget-slices"),
        tc.global_qkv_bytes / 1024,
    );
    if a.get_bool("verbose") {
        for (i, shard) in reg.shards().iter().enumerate() {
            let rec = &out.per_tenant[i];
            println!(
                "  t{:02} [{}:{}] serves={:3} hit={:3.0}% budget={:6} B used={:6} B",
                i,
                w.tenants[i].dataset,
                w.tenants[i].user,
                rec.len(),
                shard.stats.hit_rate() * 100.0,
                shard.qkv_budget(),
                shard.tree.bytes_used(),
            );
        }
    }
    let lat = out.all_total_ms();
    println!(
        "[done] p50={:.2}ms p99={:.2}ms rejected={} rebalances={} budgets {} / {} B",
        percache::util::bench::percentile(&lat, 50.0),
        percache::util::bench::percentile(&lat, 99.0),
        out.rejected,
        out.rebalances,
        reg.total_qkv_budget(),
        tc.global_qkv_bytes,
    );
    reg.check_invariants()
}

fn cmd_info() -> Result<()> {
    let rt = percache::runtime::Runtime::load_default()?;
    let m = &rt.manifest;
    println!("artifacts: {}", m.dir.display());
    println!(
        "segment_tokens={} decode_ctx={} vocab={}",
        m.segment_tokens, m.decode_ctx, m.vocab
    );
    for (name, mm) in &m.models {
        println!(
            "model {name}: {} — layers={} d_model={} heads={} ffn={} ({} artifacts, {} params)",
            mm.stands_for,
            mm.dims.layers,
            mm.dims.d_model,
            mm.dims.heads,
            mm.dims.ffn,
            mm.artifacts.len(),
            mm.dims.params(),
        );
    }
    println!("embed: {} d_out={}", m.embed.stands_for, m.embed.d_out);
    Ok(())
}

fn cmd_serve() -> Result<()> {
    let cli = Cli::new("percache serve — demo serving loop on a dataset user")
        .flag("model", "llama", "model config (llama|qwen)")
        .flag("dataset", "mised", "dataset family")
        .flag("user", "0", "user index")
        .flag("method", "percache", "method (percache or a baseline)")
        .flag("tau", "0.85", "QA-bank similarity threshold")
        .flag("idle-every", "1", "idle ticks between queries (0 = none)")
        .flag(
            "persist-dir",
            "",
            "durable cache dir: warm-restores on start, snapshots on exit",
        )
        .flag(
            "checkpoint-secs",
            "0",
            "crash-consistent snapshot cadence from the idle path (0 = only at exit)",
        )
        .flag(
            "metrics-file",
            "",
            "periodic telemetry dump path (obs snapshot as JSON + Prometheus text)",
        )
        .flag("metrics-interval-secs", "5", "telemetry dump cadence")
        .switch(
            "tiering",
            "tiered multi-tenant serving demo (warm/cold residency; no artifacts needed)",
        )
        .flag("tenants", "4", "tenant count for --tiering")
        .flag("demote-idle-ticks", "2", "idle ticks before demotion for --tiering")
        .switch("verbose", "per-query breakdown");
    let a = cli.parse_env(1);
    if a.get_bool("verbose") {
        // one diagnostics path: tail the event journal to stderr
        percache::obs::set_verbose(true);
    }
    if a.get_bool("tiering") {
        return cmd_serve_tiered(&a);
    }

    let rt = percache::runtime::Runtime::load_default()?;
    let mut base = percache::config::PerCacheConfig::default();
    base.model = a.get("model").to_string();
    base.tau_query = a.get_f64("tau");
    base.obs.apply();
    let persist_dir = a.get("persist-dir").to_string();
    if !persist_dir.is_empty() {
        base.persist_dir = Some(persist_dir.clone());
    }
    // persist_dir in the config warm-restores the engine at construction
    let mut eng = percache::baselines::build_method(&rt, a.get("method"), &base)?;
    if !persist_dir.is_empty() {
        println!(
            "[persist] cache dir {persist_dir}: restored {} tree slices, {} QA entries",
            eng.tree.slice_count(),
            eng.qa.len(),
        );
    }

    let data = percache::datasets::generate(a.get("dataset"), a.get_usize("user"));
    for doc in &data.documents {
        eng.add_document(doc)?;
    }
    println!(
        "[serve] {} user {}: {} chunks, {} queries, method={}",
        data.dataset,
        data.user,
        eng.kb.len(),
        data.queries.len(),
        percache::baselines::label(a.get("method"))
    );

    let idle_every = a.get_usize("idle-every");
    if idle_every > 0 {
        let rep = eng.idle_tick()?;
        println!(
            "[idle] predicted={} populated={} flops={:.2} GF",
            rep.predicted,
            rep.populated,
            rep.flops as f64 / 1e9
        );
    }

    let checkpoint_secs = a.get_usize("checkpoint-secs");
    let mut last_checkpoint = std::time::Instant::now();
    let mut checkpoints = 0u64;
    let metrics_file = a.get("metrics-file").to_string();
    let metrics_interval = a.get_usize("metrics-interval-secs").max(1) as u64;
    let mut last_metrics = std::time::Instant::now();
    let mut rec = percache::metrics::Recorder::new();
    for (i, q) in data.queries.iter().enumerate() {
        let r = eng.serve(&q.text)?;
        if a.get_bool("verbose") {
            println!(
                "  q{i:02} [{:?}] total={:.1}ms prefill={:.1} decode={:.1} reused={}/{}  {}",
                r.path,
                r.total_ms(),
                r.prefill_ms,
                r.decode_ms,
                r.matched_segments,
                r.n_segments,
                q.text
            );
        }
        rec.push(r);
        if idle_every > 0 && (i + 1) % idle_every == 0 {
            eng.idle_tick()?;
        }
        // periodic crash-consistent checkpoint on the idle path: the
        // snapshotter makes a clean save a no-op, so this is cheap
        if !persist_dir.is_empty()
            && checkpoint_secs > 0
            && last_checkpoint.elapsed().as_secs() >= checkpoint_secs as u64
        {
            if eng.save_state()? {
                checkpoints += 1;
            }
            last_checkpoint = std::time::Instant::now();
        }
        // periodic telemetry dump from the same idle path
        if !metrics_file.is_empty() && last_metrics.elapsed().as_secs() >= metrics_interval {
            let _ = percache::obs::dump_metrics_file(std::path::Path::new(&metrics_file), &[]);
            last_metrics = std::time::Instant::now();
        }
    }
    println!(
        "[done] mean={:.1}ms p95={:.1}ms qa_hit={:.0}% qkv_hit={:.0}% seg_reuse={:.0}%",
        rec.mean_total_ms(),
        rec.percentile_total_ms(95.0),
        rec.qa_hit_rate() * 100.0,
        rec.qkv_hit_rate() * 100.0,
        rec.segment_reuse_ratio() * 100.0,
    );
    if !persist_dir.is_empty() {
        eng.save_state()?;
        println!(
            "[persist] cache state saved to {persist_dir} ({checkpoints} periodic checkpoints)"
        );
    }
    if !metrics_file.is_empty() {
        percache::obs::dump_metrics_file(std::path::Path::new(&metrics_file), &[])?;
        println!("[obs] metrics snapshot written to {metrics_file}");
    }
    Ok(())
}

/// `percache serve --tiering`: the tiered multi-tenant serving demo.
/// Drives the threaded gated loop (cold tenants hydrate on a background
/// worker) tenant-major, so early tenants go idle and demote while later
/// ones serve, then revisits tenant 0 to show the warm comeback.  Runs
/// at the cache level — no PJRT artifacts needed.
fn cmd_serve_tiered(a: &percache::util::cli::Args) -> Result<()> {
    use percache::config::{TenancyConfig, TieringConfig};
    use percache::tenancy::sim::{sim_slice_bytes, SimConfig};
    use percache::tiering::service::{spawn_tiered_server, TieredServerConfig, REPORT_FILE};

    let n = a.get_usize("tenants").clamp(2, 64);
    let persist_dir = match a.get("persist-dir") {
        "" => "state/tiering".to_string(),
        d => d.to_string(),
    };
    let mut tenancy = TenancyConfig::default();
    tenancy.enabled = true;
    tenancy.max_tenants = n;
    tenancy.global_qkv_bytes = 32 * n * sim_slice_bytes();
    tenancy.tiering = TieringConfig {
        enabled: true,
        idle_ticks_to_demote: a.get_usize("demote-idle-ticks").max(1) as u64,
        min_resident: 1,
        ..TieringConfig::default()
    };
    let metrics_file = match a.get("metrics-file") {
        "" => None,
        p => Some(std::path::PathBuf::from(p)),
    };
    let handle = spawn_tiered_server(TieredServerConfig {
        tenancy,
        sim: SimConfig::default(),
        dir: std::path::PathBuf::from(&persist_dir),
        n_tenants: n,
        log: true,
        metrics_file,
        metrics_interval_secs: a.get_usize("metrics-interval-secs").max(1) as u64,
    });
    println!("[tiering] {n} tenants over {persist_dir} (cold tier = shard_<id>/ snapshots)");

    let queries_per_tenant = 6;
    let mut id = 0usize;
    let mut hits = 0usize;
    let mut served = 0usize;
    let mut ask = |tenant: u32, text: String| -> Result<()> {
        let resp = handle.query(tenant, id, &text)?;
        id += 1;
        served += 1;
        if resp.record.path != percache::metrics::ServePath::Full {
            hits += 1;
        }
        if a.get_bool("verbose") {
            println!(
                "  t{tenant} [{:?}] e2e={:.2}ms  {text}",
                resp.record.path, resp.e2e_ms
            );
        }
        Ok(())
    };
    // tenant-major: by the time the last tenant serves, the first ones
    // have idled past the demotion threshold
    for t in 0..n as u32 {
        for j in 0..queries_per_tenant {
            ask(t, format!("tenant{t} demo question {} about calendar", j % 3))?;
        }
        handle.idle_tick(t)?;
        handle.idle_tick(t)?;
    }
    // comeback: tenant 0 is cold by now; its queue parks behind the
    // background hydration and the verbatim repeats hit the QA bank
    for j in 0..queries_per_tenant {
        ask(0, format!("tenant0 demo question {} about calendar", j % 3))?;
    }
    drop(ask);
    handle.shutdown();
    handle.join()?;

    let report_path = std::path::Path::new(&persist_dir).join(REPORT_FILE);
    let report = std::fs::read_to_string(&report_path)?;
    let j = percache::util::json::Json::parse(&report)?;
    println!(
        "[done] served={served} hits={hits} demotions={} hydrations={} resident {}/{} shards ({} KB)",
        j.get("demotions").as_usize().unwrap_or(0),
        j.get("hydrations").as_usize().unwrap_or(0),
        j.get("resident_count").as_usize().unwrap_or(0),
        n,
        j.get("resident_bytes").as_usize().unwrap_or(0) / 1024,
    );
    println!("[tiering] full counters: {}", report_path.display());
    Ok(())
}

/// `percache metrics <file|dir>`: pretty-print a metrics dump written
/// by `percache serve --metrics-file` (tables by default, Prometheus
/// text with `--prom`).
fn cmd_metrics() -> Result<()> {
    use anyhow::Context as _;
    use percache::obs::MetricsSnapshot;
    use percache::util::table::{fmt_ms, Table};

    let cli = Cli::new("percache metrics — pretty-print a metrics snapshot dump")
        .switch("prom", "print the Prometheus text exposition instead of tables");
    let a = cli.parse_env(1);
    let arg = a
        .positional
        .first()
        .cloned()
        .unwrap_or_else(|| "reports/metrics.json".to_string());
    let mut path = std::path::PathBuf::from(&arg);
    if path.is_dir() {
        path = path.join("metrics.json");
    }
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("reading {}", path.display()))?;
    let j = percache::util::json::Json::parse(&text).context("parsing metrics dump")?;
    let snap = MetricsSnapshot::from_json(j.get("metrics"))
        .context("dump missing a `metrics` snapshot section")?;
    if a.get_bool("prom") {
        print!("{}", percache::obs::prometheus::encode(&snap));
        return Ok(());
    }

    let fmt_labels = |labels: &[(String, String)]| -> String {
        labels
            .iter()
            .map(|(k, v)| format!("{k}={v}"))
            .collect::<Vec<_>>()
            .join(",")
    };
    println!(
        "[metrics] {} — snapshot at uptime {:.1}s",
        path.display(),
        snap.t_ms / 1e3
    );
    let mut counters = Table::new("Counters", &["name", "labels", "value"]);
    for c in &snap.counters {
        counters.row(vec![c.name.clone(), fmt_labels(&c.labels), c.value.to_string()]);
    }
    print!("{}", counters.render());
    let mut gauges = Table::new("Gauges", &["name", "labels", "value"]);
    for g in &snap.gauges {
        gauges.row(vec![g.name.clone(), fmt_labels(&g.labels), g.value.to_string()]);
    }
    print!("{}", gauges.render());
    let mut hists = Table::new(
        "Histograms",
        &["name", "labels", "count", "p50 ms", "p99 ms", "mean ms"],
    );
    for h in &snap.hists {
        let mean = if h.count > 0 {
            h.sum_ms / h.count as f64
        } else {
            0.0
        };
        hists.row(vec![
            h.name.clone(),
            fmt_labels(&h.labels),
            h.count.to_string(),
            fmt_ms(h.p50),
            fmt_ms(h.p99),
            fmt_ms(mean),
        ]);
    }
    print!("{}", hists.render());
    Ok(())
}

/// Collect trace dumps out of any of the shapes `percache` writes: a
/// bare `percache.trace/v1` document, a `--metrics-file` dump carrying
/// a `trace` section, or the scenario suite's `TRACE_scenarios.json`
/// (one dump per scenario under `scenarios[].trace`).
fn collect_trace_dumps(
    j: &percache::util::json::Json,
    out: &mut Vec<percache::obs::trace::DumpEntry>,
) -> Result<(), String> {
    if j.get("traces").as_arr().is_some() {
        out.extend(percache::obs::trace::parse_dump(j)?);
        return Ok(());
    }
    if j.get("trace").as_obj().is_some() {
        return collect_trace_dumps(j.get("trace"), out);
    }
    if let Some(scs) = j.get("scenarios").as_arr() {
        for sc in scs {
            collect_trace_dumps(sc, out)?;
        }
        return Ok(());
    }
    Err(
        "no trace dump found (expected a 'traces' array, a 'trace' section, \
         or a 'scenarios' list)"
            .to_string(),
    )
}

/// `percache trace <file>`: the causal-trace forensics analyzer
/// (DESIGN.md §16).  Reconstructs each sampled request's span tree,
/// prints the per-stage attribution table (p50 / p-hi self time, share
/// of total end-to-end) and the slowest tail exemplars' critical
/// paths, then exits non-zero when the file holds no traces or any
/// tail exemplar leaves more than `--max-unattributed` of its
/// end-to-end time unattributed.
fn cmd_trace() -> Result<()> {
    use anyhow::Context as _;
    use percache::obs::trace::{attribute, critical_path_line, stage_rows, Attribution};
    use percache::util::table::Table;

    let cli = Cli::new("percache trace — span-tree attribution over a causal trace dump")
        .flag("tenant", "", "only analyse this tenant's traces")
        .flag("p", "99", "tail percentile column of the stage table")
        .flag("top", "5", "critical-path lines to print (slowest tail exemplars)")
        .flag(
            "max-unattributed",
            "0.05",
            "fail when a tail exemplar's unattributed fraction exceeds this",
        );
    let a = cli.parse_env(1);
    let path = a
        .positional
        .first()
        .cloned()
        .unwrap_or_else(|| "reports/TRACE_scenarios.json".to_string());
    let text =
        std::fs::read_to_string(&path).with_context(|| format!("reading {path}"))?;
    let j = percache::util::json::Json::parse(&text).context("parsing trace dump json")?;
    let mut entries = Vec::new();
    collect_trace_dumps(&j, &mut entries).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;

    let tenant_filter = match a.get("tenant") {
        "" => None,
        t => Some(
            t.parse::<u32>()
                .map_err(|_| anyhow::anyhow!("--tenant must be an integer, got '{t}'"))?,
        ),
    };
    if let Some(t) = tenant_filter {
        entries.retain(|e| e.trace.tenant == Some(t));
    }
    anyhow::ensure!(
        !entries.is_empty(),
        "{path}: no traces to analyse{}",
        tenant_filter
            .map(|t| format!(" for tenant {t}"))
            .unwrap_or_default()
    );

    let p_hi = a.get_f64("p").clamp(50.0, 100.0);
    let mut tails: Vec<Attribution> = Vec::new();
    let mut atts: Vec<Attribution> = Vec::new();
    for e in &entries {
        if let Some(att) = attribute(&e.trace) {
            if e.kind == "tail" {
                tails.push(att.clone());
            }
            atts.push(att);
        }
    }
    anyhow::ensure!(!atts.is_empty(), "{path}: every trace was empty");

    let e2e_total: f64 = atts.iter().map(|x| x.e2e_ms).sum();
    let unattr_total: f64 = atts.iter().map(|x| x.unattributed_ms).sum();
    println!(
        "[trace] {}: {} traces ({} tail exemplars), total e2e {:.2}ms, \
         unattributed {:.1}%",
        path,
        atts.len(),
        tails.len(),
        e2e_total,
        if e2e_total > 0.0 {
            unattr_total / e2e_total * 100.0
        } else {
            0.0
        }
    );
    let mut table = Table::new(
        "per-stage attribution (self time across all sampled traces)",
        &["stage", "count", "total ms", "p50 ms", &format!("p{p_hi:.0} ms"), "share"],
    );
    for r in stage_rows(&atts, p_hi) {
        table.row(vec![
            r.stage,
            r.count.to_string(),
            format!("{:.3}", r.total_ms),
            format!("{:.3}", r.p50_ms),
            format!("{:.3}", r.p_hi_ms),
            format!("{:.1}%", r.frac * 100.0),
        ]);
    }
    print!("{}", table.render());

    tails.sort_by(|x, y| y.e2e_ms.total_cmp(&x.e2e_ms));
    let top = a.get_usize("top").max(1);
    if !tails.is_empty() {
        println!("critical paths (slowest tail exemplars):");
        for t in tails.iter().take(top) {
            println!("  {}", critical_path_line(t));
        }
    }

    let max_unattr = a.get_f64("max-unattributed");
    let violations: Vec<String> = tails
        .iter()
        .filter(|t| t.unattributed_frac() > max_unattr)
        .map(critical_path_line)
        .collect();
    anyhow::ensure!(
        violations.is_empty(),
        "{} tail exemplar(s) exceed the {:.0}% unattributed budget:\n  {}",
        violations.len(),
        max_unattr * 100.0,
        violations.join("\n  ")
    );
    Ok(())
}

/// `percache check`: the project-specific static analysis pass
/// (DESIGN.md §13).  Non-zero exit on any finding, so CI can gate on
/// it; `--json` additionally writes the machine-readable report.
fn cmd_check() -> Result<()> {
    let cli = Cli::new("percache check — static analysis over the crate's own sources")
        .flag("json", "", "also write the findings report to this path")
        .flag(
            "src",
            concat!(env!("CARGO_MANIFEST_DIR"), "/src"),
            "source root to analyse",
        )
        .flag(
            "design",
            concat!(env!("CARGO_MANIFEST_DIR"), "/../DESIGN.md"),
            "design doc for the metrics-schema rule",
        );
    let a = cli.parse_env(1);
    let src_root = std::path::PathBuf::from(a.get("src"));
    let design = std::path::PathBuf::from(a.get("design"));
    let report = percache::analysis::analyze(&src_root, &design)?;

    let json_path = a.get("json").to_string();
    if !json_path.is_empty() {
        let p = std::path::Path::new(&json_path);
        if let Some(dir) = p.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(p, report.to_json().to_string_pretty())?;
        println!("[check] findings report written to {json_path}");
    }

    for f in &report.findings {
        eprintln!("{}", f.render());
    }
    println!(
        "[check] {} files analysed, {} findings, {} suppressed by percache-allow",
        report.files,
        report.findings.len(),
        report.suppressed
    );
    anyhow::ensure!(
        report.is_clean(),
        "percache check failed with {} finding(s)",
        report.findings.len()
    );
    Ok(())
}

fn cmd_exp() -> Result<()> {
    let cli = Cli::new("percache exp — reproduce paper figures/tables")
        .flag("out", "reports", "CSV output directory")
        .flag(
            "baseline",
            "",
            "bench-regression gate: compare BENCH json against this committed \
             baseline (scenarios; bootstraps the file when missing)",
        )
        .switch("smoke", "small deterministic workloads (CI-sized)");
    let a = cli.parse_env(1);
    let which = a
        .positional
        .first()
        .cloned()
        .unwrap_or_else(|| "all".to_string());
    std::env::set_var("PERCACHE_REPORTS", a.get("out"));
    if a.get_bool("smoke") {
        std::env::set_var("PERCACHE_SMOKE", "1");
    }
    if !a.get("baseline").is_empty() {
        std::env::set_var("PERCACHE_BASELINE", a.get("baseline"));
    }
    // cache-level experiments run anywhere: no artifacts, no warm-up
    if percache::exp::is_runtime_free(&which) {
        return percache::exp::run_offline(&which);
    }

    let rt = percache::runtime::Runtime::load_default()?;
    // Pre-compile every artifact the experiments touch so first-call PJRT
    // compilation never pollutes a latency measurement.
    warm_all(&rt)?;
    if which == "all" {
        percache::exp::run_all(&rt)
    } else {
        percache::exp::run_experiment(&rt, &which)
    }
}

fn warm_all(rt: &percache::runtime::Runtime) -> Result<()> {
    let t0 = std::time::Instant::now();
    for model in ["llama", "qwen"] {
        let names: Vec<String> = rt
            .manifest
            .model(model)?
            .artifacts
            .keys()
            .cloned()
            .collect();
        let refs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
        rt.warm(model, &refs)?;
    }
    let _ = rt.exec_embed(&vec![0i32; 64])?;
    eprintln!(
        "[warm] {} executables compiled in {:.1}s",
        rt.compiled_count(),
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}
