//! Source-file model for the analysis pass: a lexed file plus the
//! derived structure rules need — a matching-bracket index, the
//! `#[cfg(test)]` token ranges (so rules can skip test code), extracted
//! function spans (for per-function lock scoping), and the
//! `percache-allow` suppression map parsed from comments.

use super::lexer::{self, Comment, Tok, Token};

/// An inline suppression: `// percache-allow(<rule>): <justification>`.
/// It suppresses findings of `rule` on its own line and the next line
/// (so it can sit above the offending statement, the usual style).
#[derive(Debug, Clone)]
pub struct Allow {
    pub rule: String,
    pub justification: String,
    pub line: usize,
}

/// One extracted `fn` item: its name and the token range of its body
/// (indices into `SourceFile::tokens`, `body_start` = index of `{`,
/// `body_end` = index of the matching `}`).
#[derive(Debug, Clone)]
pub struct FnSpan {
    pub name: String,
    pub line: usize,
    pub body_start: usize,
    pub body_end: usize,
}

/// A lexed source file with derived structure.
pub struct SourceFile {
    /// Absolute (or as-given) path, for diagnostics.
    pub path: String,
    /// Path relative to the analysis root, unix-style (`tenancy/router.rs`).
    pub rel: String,
    pub tokens: Vec<Token>,
    pub comments: Vec<Comment>,
    /// For each token index holding an open bracket `( [ {`, the index
    /// of its matching close bracket (and vice versa). usize::MAX when
    /// unmatched.
    pub match_idx: Vec<usize>,
    /// Token ranges `[start, end]` (inclusive) covered by `#[cfg(test)]` items.
    pub test_ranges: Vec<(usize, usize)>,
    pub fns: Vec<FnSpan>,
    pub allows: Vec<Allow>,
}

impl SourceFile {
    pub fn parse(path: &str, rel: &str, text: &str) -> SourceFile {
        let (tokens, comments) = lexer::lex(text);
        let match_idx = bracket_match(&tokens);
        let test_ranges = find_test_ranges(&tokens, &match_idx);
        let fns = find_fns(&tokens, &match_idx);
        let allows = parse_allows(&comments);
        SourceFile {
            path: path.to_string(),
            rel: rel.replace('\\', "/"),
            tokens,
            comments,
            match_idx,
            test_ranges,
            fns,
            allows,
        }
    }

    /// True if token index `i` falls inside a `#[cfg(test)]` item.
    pub fn in_test(&self, i: usize) -> bool {
        self.test_ranges.iter().any(|&(s, e)| i >= s && i <= e)
    }

    /// The matching bracket index for token `i`, if any.
    pub fn matching(&self, i: usize) -> Option<usize> {
        match self.match_idx.get(i) {
            Some(&m) if m != usize::MAX => Some(m),
            _ => None,
        }
    }

    /// True if a comment containing `needle` appears on `line` or
    /// within `above` lines before it.  Used for `// SAFETY:` contracts.
    pub fn comment_near(&self, line: usize, above: usize, needle: &str) -> bool {
        self.comments.iter().any(|c| {
            c.line <= line && c.line + above >= line && c.text.contains(needle)
        })
    }
}

/// Compute the matching-bracket table over `( ) [ ] { }`.
fn bracket_match(tokens: &[Token]) -> Vec<usize> {
    let mut out = vec![usize::MAX; tokens.len()];
    let mut stack: Vec<(char, usize)> = Vec::new();
    for (i, t) in tokens.iter().enumerate() {
        match t.kind {
            Tok::Punct(c @ ('(' | '[' | '{')) => stack.push((c, i)),
            Tok::Punct(c @ (')' | ']' | '}')) => {
                let want = match c {
                    ')' => '(',
                    ']' => '[',
                    _ => '{',
                };
                // pop until we find the matching opener (tolerates the
                // stray brackets a token-level view can produce)
                while let Some((open, oi)) = stack.pop() {
                    if open == want {
                        out[oi] = i;
                        out[i] = oi;
                        break;
                    }
                }
            }
            _ => {}
        }
    }
    out
}

/// Find token ranges covered by `#[cfg(test)]` attributes: the
/// attribute itself through the end of the item it decorates (the
/// matching `}` of the next `{` at this level).
fn find_test_ranges(tokens: &[Token], match_idx: &[usize]) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    let mut i = 0;
    while i + 5 < tokens.len() {
        let is_cfg_test = tokens[i].kind.is_punct('#')
            && tokens[i + 1].kind.is_punct('[')
            && tokens[i + 2].kind.is_ident("cfg")
            && tokens[i + 3].kind.is_punct('(')
            && tokens[i + 4].kind.is_ident("test")
            && tokens[i + 5].kind.is_punct(')');
        if is_cfg_test {
            // skip to end of the attribute `]`
            let attr_end = match_idx.get(i + 1).copied().unwrap_or(usize::MAX);
            let mut j = if attr_end != usize::MAX { attr_end + 1 } else { i + 6 };
            // find the `{` opening the decorated item's body
            while j < tokens.len() && !tokens[j].kind.is_punct('{') {
                // a `;` first means a braceless item (e.g. `mod tests;`)
                if tokens[j].kind.is_punct(';') {
                    break;
                }
                j += 1;
            }
            if j < tokens.len() && tokens[j].kind.is_punct('{') {
                let close = match_idx.get(j).copied().unwrap_or(usize::MAX);
                if close != usize::MAX {
                    out.push((i, close));
                    i = close + 1;
                    continue;
                }
            }
            out.push((i, j.min(tokens.len().saturating_sub(1))));
            i = j + 1;
        } else {
            i += 1;
        }
    }
    out
}

/// Extract `fn` items: `fn <name> ... {` with the `{` found at zero
/// extra paren/bracket depth (so where-clauses and argument lists with
/// closures don't confuse the body detection).
fn find_fns(tokens: &[Token], match_idx: &[usize]) -> Vec<FnSpan> {
    let mut out = Vec::new();
    let mut i = 0;
    while i < tokens.len() {
        if tokens[i].kind.is_ident("fn") {
            let name = match tokens.get(i + 1).and_then(|t| t.kind.ident()) {
                Some(n) => n.to_string(),
                None => {
                    i += 1;
                    continue;
                }
            };
            let line = tokens[i].line;
            // scan forward for the body `{`, skipping bracketed groups
            let mut j = i + 2;
            let mut found = None;
            while j < tokens.len() {
                match tokens[j].kind {
                    Tok::Punct('{') => {
                        found = Some(j);
                        break;
                    }
                    Tok::Punct('(') | Tok::Punct('[') => {
                        let m = match_idx.get(j).copied().unwrap_or(usize::MAX);
                        if m == usize::MAX {
                            break;
                        }
                        j = m + 1;
                    }
                    Tok::Punct(';') => break, // trait method declaration
                    _ => j += 1,
                }
            }
            if let Some(open) = found {
                if let Some(&close) = match_idx.get(open) {
                    if close != usize::MAX {
                        out.push(FnSpan {
                            name,
                            line,
                            body_start: open,
                            body_end: close,
                        });
                        i += 2;
                        continue;
                    }
                }
            }
        }
        i += 1;
    }
    out
}

/// Parse `percache-allow(<rule>): <justification>` from comments.
/// An allow with an empty justification is still recorded (the engine
/// reports it as a finding of its own — justifications are mandatory).
fn parse_allows(comments: &[Comment]) -> Vec<Allow> {
    let mut out = Vec::new();
    for c in comments {
        let mut rest = c.text.as_str();
        while let Some(at) = rest.find("percache-allow(") {
            let after = &rest[at + "percache-allow(".len()..];
            let Some(close) = after.find(')') else { break };
            let rule = after[..close].trim().to_string();
            let tail = &after[close + 1..];
            let justification = tail
                .strip_prefix(':')
                .map(|t| t.trim_end_matches(['*', '/']).trim().to_string())
                .unwrap_or_default();
            out.push(Allow {
                rule,
                justification,
                line: c.line,
            });
            rest = tail;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bracket_matching() {
        let f = SourceFile::parse("t.rs", "t.rs", "fn f(a: u8) { (a, [a]) }");
        let open = f
            .tokens
            .iter()
            .position(|t| t.kind.is_punct('{'))
            .expect("open brace");
        let close = f.matching(open).expect("matched");
        assert!(f.tokens[close].kind.is_punct('}'));
        assert_eq!(f.matching(close), Some(open));
    }

    #[test]
    fn test_ranges_detected() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n fn t() { x.unwrap(); }\n}\n";
        let f = SourceFile::parse("t.rs", "t.rs", src);
        let unwrap_idx = f
            .tokens
            .iter()
            .position(|t| t.kind.is_ident("unwrap"))
            .expect("unwrap");
        assert!(f.in_test(unwrap_idx));
        let live_idx = f
            .tokens
            .iter()
            .position(|t| t.kind.is_ident("live"))
            .expect("live");
        assert!(!f.in_test(live_idx));
    }

    #[test]
    fn fn_extraction_skips_where_and_args() {
        let src = "fn g<T>(f: impl Fn(u8) -> u8) -> u8 where T: Clone { f(1) }";
        let f = SourceFile::parse("t.rs", "t.rs", src);
        assert_eq!(f.fns.len(), 1);
        assert_eq!(f.fns[0].name, "g");
        assert!(f.tokens[f.fns[0].body_start].kind.is_punct('{'));
    }

    #[test]
    fn trait_decl_has_no_body() {
        let f = SourceFile::parse("t.rs", "t.rs", "trait T { fn a(&self); fn b(&self) {} }");
        assert_eq!(f.fns.len(), 1);
        assert_eq!(f.fns[0].name, "b");
    }

    #[test]
    fn allow_parsing() {
        let src = "// percache-allow(panic_path): startup is allowed to die\nx.unwrap();\n\
                   // percache-allow(lock_order):\ny();\n";
        let f = SourceFile::parse("t.rs", "t.rs", src);
        assert_eq!(f.allows.len(), 2);
        assert_eq!(f.allows[0].rule, "panic_path");
        assert_eq!(f.allows[0].justification, "startup is allowed to die");
        assert_eq!(f.allows[0].line, 1);
        assert_eq!(f.allows[1].rule, "lock_order");
        assert!(f.allows[1].justification.is_empty());
    }

    #[test]
    fn comment_near_safety() {
        let src = "// SAFETY: ptr is valid for len reads\nlet s = unsafe { f() };\n";
        let f = SourceFile::parse("t.rs", "t.rs", src);
        assert!(f.comment_near(2, 5, "SAFETY:"));
        assert!(!f.comment_near(2, 5, "NOPE:"));
    }
}
