//! The rules of the `percache check` analysis pass.
//!
//! Per-file rules (`panic_path`, `unsafe_audit`) expose
//! `check(&SourceFile) -> Vec<Finding>`; whole-tree rules
//! (`lock_order`, `metrics_schema`) expose `check_files(...)` because
//! their findings depend on cross-file state (the global lock graph,
//! the code↔doc metric diff).  See DESIGN.md §13 for how to add one.

pub mod lock_order;
pub mod metrics_schema;
pub mod panic_path;
pub mod unsafe_audit;
