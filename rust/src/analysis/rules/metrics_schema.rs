//! Rule `metrics_schema`: every metric name in code must follow the
//! DESIGN.md §12 naming scheme and appear in the documented metric
//! table — and every documented family must still exist in code.
//! Drift between code and doc is an error in *both* directions.
//!
//! Extraction matches the obs emission surface exactly: the
//! `obs_counter!/obs_gauge!/obs_hist!` macros and the
//! `counter/gauge/histogram/span/counter_labeled/gauge_labeled/
//! histogram_labeled` free functions, each taking the series name as
//! the first string literal.  The obs module's own definitions pass
//! names through as parameters (never literals), so they don't match.
//!
//! Doc parsing: backticked entries in the §12 markdown table rows
//! (lines starting with `|`).  Entries may contain `*` globs
//! (`tiering.resident_*`) and `<ident>` placeholders
//! (`engine.<stage>_ms`); single-word entries without a dot are label
//! names, not metric families, and are ignored.
//!
//! A second conformance surface rides along when the design doc has a
//! §16 section: the trace-dump JSON schema.  Every string key the
//! trace exporter (`obs/trace.rs`, `obs/exemplar.rs`) `insert`s must
//! appear in a §16 table whose header row contains the word `field`,
//! and every documented field must still be written by the exporter —
//! drift is an error in both directions, exactly like §12.  Designs
//! without a §16 section (the unit-test mini-designs) skip this
//! surface silently.

use crate::analysis::lexer::Tok;
use crate::analysis::source::SourceFile;
use crate::analysis::{Finding, RULE_METRICS_SCHEMA};

/// Macro names whose first string argument is a metric name.
const METRIC_MACROS: &[(&str, Kind)] = &[
    ("obs_counter", Kind::Counter),
    ("obs_gauge", Kind::Gauge),
    ("obs_hist", Kind::Histogram),
];

/// Free functions whose first string argument is a metric name.
const METRIC_FNS: &[(&str, Kind)] = &[
    ("counter", Kind::Counter),
    ("counter_labeled", Kind::Counter),
    ("gauge", Kind::Gauge),
    ("gauge_labeled", Kind::Gauge),
    ("histogram", Kind::Histogram),
    ("histogram_labeled", Kind::Histogram),
    ("span", Kind::Histogram),
    // synthesized snapshot-time series (obs/snapshot.rs `synth`)
    ("synth", Kind::Counter),
];

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    Counter,
    Gauge,
    Histogram,
}

/// One metric-name usage extracted from code.
pub struct MetricUse {
    pub name: String,
    pub kind: Kind,
    pub file: String,
    pub line: usize,
}

/// Extract metric-name usages from one file (skipping test code and
/// the macro/function *definitions* in `obs/`, which take the name as
/// a parameter rather than a literal, so they never match anyway).
pub fn extract_uses(file: &SourceFile) -> Vec<MetricUse> {
    let toks = &file.tokens;
    let mut out = Vec::new();
    for i in 0..toks.len() {
        if file.in_test(i) {
            continue;
        }
        let Some(name) = toks[i].kind.ident() else { continue };
        // macro: ident ! ( "name"
        if let Some(&(_, kind)) = METRIC_MACROS.iter().find(|(m, _)| *m == name) {
            if toks.get(i + 1).map(|t| t.kind.is_punct('!')).unwrap_or(false)
                && toks.get(i + 2).map(|t| t.kind.is_punct('(')).unwrap_or(false)
            {
                if let Some(Tok::Str(s)) = toks.get(i + 3).map(|t| &t.kind) {
                    out.push(MetricUse {
                        name: s.clone(),
                        kind,
                        file: file.rel.clone(),
                        line: toks[i].line,
                    });
                }
            }
            continue;
        }
        // function: ident ( "name"   — but not a macro definition's
        // `macro_rules!` body (no string literal directly follows there)
        if let Some(&(_, kind)) = METRIC_FNS.iter().find(|(m, _)| *m == name) {
            if toks.get(i + 1).map(|t| t.kind.is_punct('(')).unwrap_or(false) {
                if let Some(Tok::Str(s)) = toks.get(i + 2).map(|t| &t.kind) {
                    // require the metric shape here: fn names like
                    // `write` won't collide, but e.g. `span("x")` in a
                    // doc example would — the dot requirement filters
                    // incidental single-word strings.
                    if s.contains('.') {
                        out.push(MetricUse {
                            name: s.clone(),
                            kind,
                            file: file.rel.clone(),
                            line: toks[i].line,
                        });
                    }
                }
            }
        }
    }
    out
}

/// A documented metric family pattern from the §12 table.
pub struct DocPattern {
    pub pattern: String,
    pub line: usize,
}

/// Parse the documented metric families out of DESIGN.md §12: all
/// backticked, dot-containing entries on table rows (`|`-prefixed
/// lines) between the §12 heading and the next `## ` heading.
pub fn parse_doc_patterns(design: &str) -> Vec<DocPattern> {
    let mut out = Vec::new();
    let mut in_section = false;
    for (ln, line) in design.lines().enumerate() {
        let trimmed = line.trim_start();
        if trimmed.starts_with("## ") {
            in_section = trimmed.contains("§12");
            continue;
        }
        if !in_section || !trimmed.starts_with('|') {
            continue;
        }
        for span in backticked(trimmed) {
            // strip label annotations like `router.rejected{reason}`
            let pat = span.split('{').next().unwrap_or("").trim();
            if pat.contains('.') && is_metric_shape(pat) {
                out.push(DocPattern {
                    pattern: pat.to_string(),
                    line: ln + 1,
                });
            }
        }
    }
    out
}

fn backticked(line: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut rest = line;
    while let Some(a) = rest.find('`') {
        let tail = &rest[a + 1..];
        let Some(b) = tail.find('`') else { break };
        out.push(&tail[..b]);
        rest = &tail[b + 1..];
    }
    out
}

/// Name scheme: `layer.metric[...]` — lowercase alphanumeric/underscore
/// segments joined by dots, at least two segments, starting with a
/// letter.  `*` and `<ident>` are allowed only in doc patterns.
fn is_metric_shape(s: &str) -> bool {
    if !s.starts_with(|c: char| c.is_ascii_lowercase()) {
        return false;
    }
    let mut segs = 0;
    for seg in s.split('.') {
        if seg.is_empty() {
            return false;
        }
        segs += 1;
        let mut chars = seg.chars().peekable();
        while let Some(c) = chars.next() {
            match c {
                'a'..='z' | '0'..='9' | '_' | '*' => {}
                '<' => {
                    // placeholder `<ident>`
                    let mut ok = false;
                    for p in chars.by_ref() {
                        if p == '>' {
                            ok = true;
                            break;
                        }
                        if !(p.is_ascii_lowercase() || p == '_') {
                            return false;
                        }
                    }
                    if !ok {
                        return false;
                    }
                }
                _ => return false,
            }
        }
    }
    segs >= 2
}

/// Does `name` (a concrete code-side metric) conform to the strict
/// naming scheme (no globs/placeholders)?
pub fn valid_name(name: &str) -> bool {
    is_metric_shape(name) && !name.contains('*') && !name.contains('<')
}

/// Match a concrete name against a doc pattern with `*` (matches
/// `[a-z0-9_]*`) and `<ident>` (matches `[a-z0-9_]+`) wildcards.
pub fn pattern_matches(pattern: &str, name: &str) -> bool {
    // translate the pattern to segments of literal/wildcard pieces and
    // run a simple backtracking match.
    fn name_char(c: char) -> bool {
        c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_'
    }
    fn match_from(pat: &[char], name: &[char]) -> bool {
        if pat.is_empty() {
            return name.is_empty();
        }
        match pat[0] {
            '*' => {
                // greedy-with-backtracking over [a-z0-9_]*
                let mut k = 0;
                loop {
                    if match_from(&pat[1..], &name[k..]) {
                        return true;
                    }
                    if k < name.len() && name_char(name[k]) {
                        k += 1;
                    } else {
                        return false;
                    }
                }
            }
            '<' => {
                // skip to '>' in pattern; consume one-or-more name chars
                let close = pat.iter().position(|&c| c == '>').unwrap_or(pat.len() - 1);
                let rest = &pat[close + 1..];
                let mut k = 1; // at least one char
                if name.is_empty() || !name_char(name[0]) {
                    return false;
                }
                loop {
                    if match_from(rest, &name[k..]) {
                        return true;
                    }
                    if k < name.len() && name_char(name[k]) {
                        k += 1;
                    } else {
                        return false;
                    }
                }
            }
            c => {
                if name.first() == Some(&c) {
                    match_from(&pat[1..], &name[1..])
                } else {
                    false
                }
            }
        }
    }
    let p: Vec<char> = pattern.chars().collect();
    let n: Vec<char> = name.chars().collect();
    match_from(&p, &n)
}

/// Files whose JSON `insert` string literals constitute the §16 trace
/// dump schema (relative-path suffixes).
const TRACE_DUMP_FILES: &[&str] = &["obs/trace.rs", "obs/exemplar.rs"];

/// A documented trace-dump field from a §16 `field` table.
pub struct DocField {
    pub name: String,
    pub line: usize,
}

/// A dump-field literal written by the trace exporter.
pub struct FieldUse {
    pub name: String,
    pub file: String,
    pub line: usize,
}

/// Snake-case JSON field shape: `[a-z][a-z0-9_]*`, no dots.
fn is_field_shape(s: &str) -> bool {
    let mut chars = s.chars();
    matches!(chars.next(), Some(c) if c.is_ascii_lowercase())
        && chars.all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_')
}

/// Parse the documented dump fields out of DESIGN.md §16: backticked
/// snake-case entries on the body rows of tables whose header row
/// contains the word `field`.  Returns `None` when the design has no
/// §16 section at all (this surface is then skipped entirely).
pub fn parse_doc_fields(design: &str) -> Option<Vec<DocField>> {
    let mut out = Vec::new();
    let mut in_section = false;
    let mut seen_section = false;
    let mut prev_was_row = false;
    let mut in_field_table = false;
    for (ln, line) in design.lines().enumerate() {
        let trimmed = line.trim_start();
        if trimmed.starts_with("## ") {
            in_section = trimmed.contains("§16");
            seen_section |= in_section;
            prev_was_row = false;
            in_field_table = false;
            continue;
        }
        if !in_section || !trimmed.starts_with('|') {
            prev_was_row = false;
            in_field_table = false;
            continue;
        }
        if !prev_was_row {
            // first `|` line of a table: the header row decides whether
            // this table documents dump fields
            in_field_table = trimmed.to_lowercase().contains("field");
            prev_was_row = true;
            continue;
        }
        if in_field_table {
            for span in backticked(trimmed) {
                let name = span.trim();
                if is_field_shape(name) {
                    out.push(DocField {
                        name: name.to_string(),
                        line: ln + 1,
                    });
                }
            }
        }
    }
    seen_section.then_some(out)
}

/// Extract the dump-field literals one trace-exporter file writes:
/// every `insert("snake_case", …)` outside test code.
pub fn extract_dump_fields(file: &SourceFile) -> Vec<FieldUse> {
    if !TRACE_DUMP_FILES.iter().any(|t| file.rel.ends_with(t)) {
        return Vec::new();
    }
    let toks = &file.tokens;
    let mut out = Vec::new();
    for i in 0..toks.len() {
        if file.in_test(i) {
            continue;
        }
        if toks[i].kind.ident() != Some("insert") {
            continue;
        }
        if !toks.get(i + 1).map(|t| t.kind.is_punct('(')).unwrap_or(false) {
            continue;
        }
        let Some(Tok::Str(s)) = toks.get(i + 2).map(|t| &t.kind) else {
            continue;
        };
        if is_field_shape(s) {
            out.push(FieldUse {
                name: s.clone(),
                file: file.rel.clone(),
                line: toks[i].line,
            });
        }
    }
    out
}

/// Run the full conformance check: code↔doc in both directions plus
/// the naming-scheme and histogram-suffix rules.
pub fn check_files(files: &[SourceFile], design: &str, design_rel: &str) -> Vec<Finding> {
    let mut findings = Vec::new();
    let patterns = parse_doc_patterns(design);
    if patterns.is_empty() {
        findings.push(Finding::new(
            RULE_METRICS_SCHEMA,
            design_rel,
            1,
            "no metric table found in DESIGN.md §12 — cannot check conformance".to_string(),
        ));
        return findings;
    }
    let mut uses: Vec<MetricUse> = Vec::new();
    for f in files {
        uses.extend(extract_uses(f));
    }
    for u in &uses {
        if !valid_name(&u.name) {
            findings.push(Finding::new(
                RULE_METRICS_SCHEMA,
                &u.file,
                u.line,
                format!(
                    "metric `{}` violates the §12 naming scheme (lowercase dotted `layer.metric`)",
                    u.name
                ),
            ));
            continue;
        }
        if u.kind == Kind::Histogram && !u.name.ends_with("_ms") {
            findings.push(Finding::new(
                RULE_METRICS_SCHEMA,
                &u.file,
                u.line,
                format!(
                    "histogram `{}` should end in `_ms` per §12 (latencies in milliseconds)",
                    u.name
                ),
            ));
        }
        if !patterns.iter().any(|p| pattern_matches(&p.pattern, &u.name)) {
            findings.push(Finding::new(
                RULE_METRICS_SCHEMA,
                &u.file,
                u.line,
                format!("metric `{}` is not documented in the DESIGN.md §12 table", u.name),
            ));
        }
    }
    // reverse direction: documented but unused
    for p in &patterns {
        if !uses.iter().any(|u| pattern_matches(&p.pattern, &u.name)) {
            findings.push(Finding::new(
                RULE_METRICS_SCHEMA,
                design_rel,
                p.line,
                format!(
                    "documented metric family `{}` has no emitting call site in code",
                    p.pattern
                ),
            ));
        }
    }
    // trace-dump field surface (§16), both directions — skipped when
    // the design has no §16 section
    if let Some(fields) = parse_doc_fields(design) {
        let mut writes: Vec<FieldUse> = Vec::new();
        for f in files {
            writes.extend(extract_dump_fields(f));
        }
        for w in &writes {
            if !fields.iter().any(|d| d.name == w.name) {
                findings.push(Finding::new(
                    RULE_METRICS_SCHEMA,
                    &w.file,
                    w.line,
                    format!(
                        "trace dump field `{}` is not documented in the DESIGN.md §16 field table",
                        w.name
                    ),
                ));
            }
        }
        for d in &fields {
            if !writes.iter().any(|w| w.name == d.name) {
                findings.push(Finding::new(
                    RULE_METRICS_SCHEMA,
                    design_rel,
                    d.line,
                    format!(
                        "documented trace dump field `{}` is never written by the trace exporter",
                        d.name
                    ),
                ));
            }
        }
    }
    findings
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOC: &str = "\
# Design
## §12 Telemetry
| family | kind |
|---|---|
| `router.admitted` / `router.rejected`{`reason`} | counter |
| `engine.<stage>_ms`, `engine.matched_segments` | histogram |
| `tiering.resident_*` | gauge |
## §13 Next
| `not.in_section` | x |
";

    #[test]
    fn doc_patterns_parsed() {
        let pats: Vec<String> = parse_doc_patterns(DOC).into_iter().map(|p| p.pattern).collect();
        assert!(pats.contains(&"router.admitted".to_string()));
        assert!(pats.contains(&"engine.<stage>_ms".to_string()));
        assert!(pats.contains(&"tiering.resident_*".to_string()));
        // label words and out-of-section entries excluded
        assert!(!pats.iter().any(|p| p == "reason"));
        assert!(!pats.iter().any(|p| p == "not.in_section"));
    }

    #[test]
    fn name_scheme() {
        assert!(valid_name("router.e2e_ms")); // digits allowed
        assert!(valid_name("a.b_c"));
        assert!(!valid_name("NoCaps.x"));
        assert!(!valid_name("single"));
        assert!(!valid_name("trailing."));
        assert!(!valid_name("tiering.resident_*")); // globs are doc-only
    }

    #[test]
    fn wildcard_matching() {
        assert!(pattern_matches("tiering.resident_*", "tiering.resident_bytes"));
        assert!(pattern_matches("tiering.resident_*", "tiering.resident_"));
        assert!(!pattern_matches("tiering.resident_*", "tiering.demotions"));
        assert!(pattern_matches("engine.<stage>_ms", "engine.prefill_ms"));
        assert!(!pattern_matches("engine.<stage>_ms", "engine._ms"));
        assert!(pattern_matches("router.admitted", "router.admitted"));
        assert!(!pattern_matches("router.admitted", "router.admitted_x"));
    }

    fn uses_of(src: &str) -> Vec<(String, Kind)> {
        let f = SourceFile::parse("m.rs", "m.rs", src);
        extract_uses(&f).into_iter().map(|u| (u.name, u.kind)).collect()
    }

    #[test]
    fn extraction_macros_and_fns() {
        let src = r#"
            fn f() {
                crate::obs_counter!("engine.qa_hit").inc();
                crate::obs_hist!("engine.total_ms").record(1.0);
                crate::obs::counter_labeled("router.rejected", &[("reason", l)]);
                let _g = crate::obs::span("tiering.tick_ms");
            }
        "#;
        let us = uses_of(src);
        assert_eq!(us.len(), 4);
        assert!(us.contains(&("engine.qa_hit".to_string(), Kind::Counter)));
        assert!(us.contains(&("tiering.tick_ms".to_string(), Kind::Histogram)));
    }

    #[test]
    fn extraction_skips_tests_and_param_defs() {
        // definitions pass the name through as a parameter — no literal
        let src = "pub fn counter(name: &str) {}\n#[cfg(test)]\n\
                   mod t { fn x() { crate::obs_counter!(\"x.y\").inc(); } }";
        assert!(uses_of(src).is_empty());
    }

    #[test]
    fn io_write_string_not_a_metric() {
        // single-word strings through non-obs fns are filtered by the
        // dot requirement; `write` isn't a metric fn at all.
        let src = "fn f(w: &mut W) { w.write(\"x\"); gauge(\"plain\"); }";
        assert!(uses_of(src).is_empty());
    }

    #[test]
    fn conformance_both_directions() {
        let code = r#"
            fn f() {
                crate::obs_counter!("router.admitted").inc();
                crate::obs_hist!("engine.prefill_ms").record(1.0);
                crate::obs_counter!("router.BAD").inc();
                crate::obs_hist!("engine.matched_segments").record(1.0);
                crate::obs_counter!("undocumented.thing").inc();
            }
        "#;
        let files = vec![SourceFile::parse("m.rs", "m.rs", code)];
        let fs = check_files(&files, DOC, "DESIGN.md");
        // router.BAD: bad scheme; matched_segments: hist w/o _ms;
        // undocumented.thing: not in doc; router.rejected +
        // tiering.resident_*: documented but unused.
        assert!(fs.iter().any(|f| f.message.contains("router.BAD")));
        assert!(fs.iter().any(|f| f.message.contains("engine.matched_segments")));
        assert!(fs.iter().any(|f| f.message.contains("undocumented.thing")));
        assert!(fs.iter().any(|f| f.message.contains("router.rejected")));
        assert!(fs.iter().any(|f| f.message.contains("tiering.resident_*")));
        assert_eq!(fs.len(), 5, "{fs:?}");
    }

    const DOC16: &str = "\
# Design
## §12 Telemetry
| family | kind |
|---|---|
| `router.admitted` | counter |
## §16 Causal tracing
Stage vocabulary (not a field table — header has no trigger word):
| stage | meaning |
|---|---|
| `prefill` | engine prefill |
Dump fields:
| field | where |
|---|---|
| `trace` | dump + entry |
| `spans` | dump |
| `ghost_field` | nowhere |
";

    #[test]
    fn doc_fields_parsed_only_from_field_tables() {
        let fields: Vec<String> =
            parse_doc_fields(DOC16).unwrap().into_iter().map(|d| d.name).collect();
        assert_eq!(fields, vec!["trace", "spans", "ghost_field"]);
        // no §16 heading at all → surface absent, not empty
        assert!(parse_doc_fields(DOC).is_none());
    }

    #[test]
    fn dump_field_extraction_is_scoped_to_exporter_files() {
        let src = r#"
            fn export() {
                o.insert("trace", 1u64);
                o.insert("spans", Json::Arr(v));
                o.insert("NotAField", 2u64);
            }
            #[cfg(test)]
            mod t { fn x() { o.insert("test_only", 0u64); } }
        "#;
        let tracer = SourceFile::parse("obs/trace.rs", "obs/trace.rs", src);
        let names: Vec<String> =
            extract_dump_fields(&tracer).into_iter().map(|u| u.name).collect();
        assert_eq!(names, vec!["trace", "spans"]);
        // identical source outside the exporter file set contributes nothing
        let other = SourceFile::parse("util/json.rs", "util/json.rs", src);
        assert!(extract_dump_fields(&other).is_empty());
    }

    #[test]
    fn field_conformance_both_directions() {
        let code = r#"
            fn f() { crate::obs_counter!("router.admitted").inc(); }
            fn export() {
                o.insert("trace", 1u64);
                o.insert("spans", Json::Arr(v));
                o.insert("undocumented_field", 0u64);
            }
        "#;
        let files = vec![SourceFile::parse("obs/trace.rs", "obs/trace.rs", code)];
        let fs = check_files(&files, DOC16, "DESIGN.md");
        // undocumented_field: written but undocumented; ghost_field:
        // documented but never written.  `trace`/`spans` conform, and
        // the stage-vocabulary table contributes nothing.
        assert!(fs.iter().any(|f| f.message.contains("undocumented_field")));
        assert!(fs.iter().any(|f| f.message.contains("ghost_field")));
        assert!(!fs.iter().any(|f| f.message.contains("prefill")));
        assert_eq!(fs.len(), 2, "{fs:?}");
    }
}
