//! Rule `unsafe_audit`: the crate's policy is that only `runtime/`
//! (the PJRT FFI boundary) may contain `unsafe`, and every `unsafe`
//! there must carry a `// SAFETY:` contract comment within a few
//! lines above it.  Everywhere else `#![deny(unsafe_code)]` holds and
//! this rule backs it up at analysis time (so fixtures and generated
//! code get the same treatment as compiled code).

use crate::analysis::source::SourceFile;
use crate::analysis::{Finding, RULE_UNSAFE_AUDIT};

/// How many lines above an `unsafe` token a `// SAFETY:` comment may
/// sit and still count as documenting it.
const SAFETY_WINDOW: usize = 5;

pub fn check(file: &SourceFile) -> Vec<Finding> {
    let mut out = Vec::new();
    let in_runtime = file.rel.starts_with("runtime/") || file.rel == "runtime.rs";
    for (i, t) in file.tokens.iter().enumerate() {
        if !t.kind.is_ident("unsafe") {
            continue;
        }
        if file.in_test(i) {
            continue;
        }
        if !in_runtime {
            out.push(Finding::new(
                RULE_UNSAFE_AUDIT,
                &file.rel,
                t.line,
                "unsafe outside runtime/ — the crate policy is \
                 #![deny(unsafe_code)] everywhere else"
                    .to_string(),
            ));
        } else if !file.comment_near(t.line, SAFETY_WINDOW, "SAFETY:") {
            out.push(Finding::new(
                RULE_UNSAFE_AUDIT,
                &file.rel,
                t.line,
                "unsafe block without a // SAFETY: contract comment".to_string(),
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn findings(rel: &str, src: &str) -> Vec<Finding> {
        let f = SourceFile::parse(rel, rel, src);
        check(&f)
    }

    #[test]
    fn unsafe_outside_runtime_flagged() {
        let fs = findings("cache/store.rs", "fn f() { unsafe { g() } }");
        assert_eq!(fs.len(), 1);
        assert!(fs[0].message.contains("outside runtime/"));
    }

    #[test]
    fn runtime_unsafe_needs_safety_comment() {
        let fs = findings("runtime/mod.rs", "fn f() { unsafe { g() } }");
        assert_eq!(fs.len(), 1);
        assert!(fs[0].message.contains("SAFETY:"));
    }

    #[test]
    fn runtime_unsafe_with_safety_passes() {
        let src = "fn f() {\n    // SAFETY: caller guarantees ptr valid for len reads\n    \
                   unsafe { g() }\n}";
        assert!(findings("runtime/mod.rs", src).is_empty());
    }

    #[test]
    fn safety_window_bounded() {
        // a SAFETY: comment 10 lines up does not cover the block
        let mut src = String::from("// SAFETY: too far away\n");
        src.push_str(&"\n".repeat(9));
        src.push_str("fn f() { unsafe { g() } }\n");
        assert_eq!(findings("runtime/mod.rs", &src).len(), 1);
    }

    #[test]
    fn the_word_unsafe_in_string_is_fine() {
        let fs = findings("cache/store.rs", "fn f() { log(\"unsafe stuff\"); }");
        assert!(fs.is_empty());
    }
}
