//! Rule `lock_order`: build the cross-module lock acquisition graph
//! and report cycles as potential deadlocks.
//!
//! For each function we extract lock acquisitions — `.lock()`,
//! zero-argument `.read()` / `.write()`, and the `util::sync`
//! recovery helpers (`lock_or_recover` / `read_or_recover` /
//! `write_or_recover`) — and the token span over which each guard is
//! held.  When lock B is acquired strictly inside lock A's guard
//! scope, we add a directed edge A→B.  A cycle in the resulting
//! digraph means two call paths can interleave acquisitions in
//! opposite orders — the classic deadlock shape.
//!
//! Lock identity is approximated from the receiver expression:
//! `module_stem::receiver_tail` (e.g. `journal::stripes`), except
//! receivers rooted at an UPPERCASE identifier (statics like
//! `REGISTRY`), which keep the bare name so the same global lock
//! unifies across files — that is what makes the graph cross-module.
//!
//! Guard scope: `let g = x.lock();` holds to the end of the enclosing
//! block; a guard used as a temporary (`x.lock().push(..)`) holds to
//! the end of the statement — the next `;` at the same depth — or
//! through the `{...}` block when the statement is an `if let`/`for`/
//! `while let` head (scrutinee temporaries live for the whole block).

use crate::analysis::lexer::{Tok, Token};
use crate::analysis::source::SourceFile;
use crate::analysis::{Finding, RULE_LOCK_ORDER};
use std::collections::{BTreeMap, BTreeSet};

/// One acquisition site inside a function.
struct Acq {
    /// Canonical lock id (`file_stem::receiver` or bare static name).
    id: String,
    /// Token index of the acquiring method/function ident.
    tok: usize,
    /// Token index one past the end of the guard's scope.
    scope_end: usize,
    line: usize,
}

/// An edge in the global lock graph, with one witness site.
pub struct Edge {
    pub from: String,
    pub to: String,
    pub file: String,
    pub line: usize,
}

const ACQ_METHODS: &[&str] = &["lock", "read", "write"];
const ACQ_HELPERS: &[&str] = &["lock_or_recover", "read_or_recover", "write_or_recover"];

pub fn check_files(files: &[SourceFile]) -> Vec<Finding> {
    let edges = collect_edges(files);
    report_cycles(&edges)
}

fn collect_edges(files: &[SourceFile]) -> Vec<Edge> {
    let mut edges = Vec::new();
    for file in files {
        let stem = file
            .rel
            .rsplit('/')
            .next()
            .unwrap_or(&file.rel)
            .trim_end_matches(".rs")
            .to_string();
        for f in &file.fns {
            if file.in_test(f.body_start) {
                continue;
            }
            let acqs = find_acquisitions(file, &stem, f.body_start, f.body_end);
            for (i, a) in acqs.iter().enumerate() {
                for b in acqs.iter().skip(i + 1) {
                    if b.tok > a.tok && b.tok < a.scope_end && a.id != b.id {
                        edges.push(Edge {
                            from: a.id.clone(),
                            to: b.id.clone(),
                            file: file.rel.clone(),
                            line: b.line,
                        });
                    }
                }
            }
        }
    }
    edges
}

/// Scan a function body for lock acquisitions and compute guard scopes.
fn find_acquisitions(file: &SourceFile, stem: &str, start: usize, end: usize) -> Vec<Acq> {
    let toks = &file.tokens;
    let mut out = Vec::new();
    let mut i = start;
    while i < end {
        let Some(name) = toks[i].kind.ident() else {
            i += 1;
            continue;
        };
        let open = i + 1;
        let is_call = toks.get(open).map(|t| t.kind.is_punct('(')).unwrap_or(false);
        if !is_call {
            i += 1;
            continue;
        }
        let method = ACQ_METHODS.contains(&name) && i > 0 && toks[i - 1].kind.is_punct('.');
        let helper = ACQ_HELPERS.contains(&name);
        if !method && !helper {
            i += 1;
            continue;
        }
        // zero-argument check for .read()/.write() (to skip io::Read /
        // fmt writes like file.write(buf)); .lock() on std Mutex is
        // also zero-arg.  Helpers take exactly the lock reference.
        let close = match file.matching(open) {
            Some(c) => c,
            None => {
                i += 1;
                continue;
            }
        };
        if method && close != open + 1 {
            i += 1;
            continue; // has arguments — not a std lock acquisition
        }
        let id = if method {
            receiver_id(toks, i - 1, stem)
        } else {
            // helper: lock_or_recover(&self.q) / read_or_recover(&SHARED)
            argument_id(toks, open, close, stem)
        };
        let Some(id) = id else {
            i += 1;
            continue;
        };
        let scope_end = guard_scope_end(file, i, close, end);
        out.push(Acq {
            id,
            tok: i,
            scope_end,
            line: toks[i].line,
        });
        i = close + 1;
    }
    out
}

/// Walk backwards from the `.` before the acquiring method to build
/// the receiver id.  Collects `ident`/`Num` segments joined by dots,
/// jumping over `[...]` index groups and `(...)` call argument lists.
fn receiver_id(toks: &[Token], mut i: usize, stem: &str) -> Option<String> {
    // i points at the '.'; walk left
    let mut segs: Vec<String> = Vec::new();
    loop {
        if i == 0 {
            break;
        }
        i -= 1;
        match &toks[i].kind {
            Tok::Ident(s) => {
                segs.push(s.clone());
                // continue only through `.` or `::`
                if i >= 1 && toks[i - 1].kind.is_punct('.') {
                    i -= 1; // consume the dot, loop continues
                } else if i >= 2 && toks[i - 1].kind.is_punct(':') && toks[i - 2].kind.is_punct(':')
                {
                    i -= 2;
                } else {
                    break;
                }
            }
            Tok::Num(_) => {
                segs.push("field".to_string());
                if i >= 1 && toks[i - 1].kind.is_punct('.') {
                    i -= 1;
                } else {
                    break;
                }
            }
            Tok::Punct(']') | Tok::Punct(')') => {
                // jump to the matching opener; the group contributes
                // nothing to the id, but the expression continues left
                let mut depth = 1usize;
                let close_ch = if toks[i].kind.is_punct(']') { ']' } else { ')' };
                let open_ch = if close_ch == ']' { '[' } else { '(' };
                while i > 0 && depth > 0 {
                    i -= 1;
                    if toks[i].kind.is_punct(close_ch) {
                        depth += 1;
                    } else if toks[i].kind.is_punct(open_ch) {
                        depth -= 1;
                    }
                }
                // after the opener, expect an ident (vec name / fn name)
                // on its left — loop naturally continues from here
            }
            _ => break,
        }
    }
    finish_id(segs, stem)
}

/// Extract a lock id from a helper call's argument tokens:
/// `lock_or_recover(&self.stripes[k])` → receiver walk from the close.
fn argument_id(toks: &[Token], open: usize, close: usize, stem: &str) -> Option<String> {
    if close <= open + 1 {
        return None;
    }
    // Walk backwards from the token before `)` the same way as a
    // method receiver — the argument's trailing path is the lock.
    receiver_id_from_end(toks, close, stem)
}

fn receiver_id_from_end(toks: &[Token], close: usize, stem: &str) -> Option<String> {
    // Reuse receiver_id by treating `close` (the `)`) position like the
    // dot: walk left from close-1... but receiver_id expects i at a
    // separator.  Simplest: synthesize by starting at `close` which the
    // backward walker treats as a group only if it *is* ')' — instead
    // start the generic walk at the last token of the argument.
    receiver_id(toks, close, stem)
}

fn finish_id(mut segs: Vec<String>, stem: &str) -> Option<String> {
    if segs.is_empty() {
        return None;
    }
    segs.reverse();
    // drop leading `self` / `crate` / `super` noise
    while segs
        .first()
        .map(|s| s == "self" || s == "crate" || s == "super")
        .unwrap_or(false)
    {
        segs.remove(0);
    }
    if segs.is_empty() {
        return None;
    }
    let root_is_static = segs[0].chars().all(|c| c.is_ascii_uppercase() || c == '_');
    let tail = segs.join(".");
    if root_is_static {
        Some(tail) // global: unify across files
    } else {
        Some(format!("{stem}::{tail}"))
    }
}

/// Compute where the guard acquired at `acq_tok` stops being held.
fn guard_scope_end(file: &SourceFile, acq_tok: usize, call_close: usize, fn_end: usize) -> usize {
    let toks = &file.tokens;
    // find the start of the enclosing statement: scan left for `;` or
    // `{` at the same depth; check whether the statement begins `let`.
    let mut j = acq_tok;
    let mut depth = 0i32;
    let mut stmt_start = 0usize;
    while j > 0 {
        j -= 1;
        match toks[j].kind {
            Tok::Punct(')') | Tok::Punct(']') | Tok::Punct('}') => depth += 1,
            Tok::Punct('(') | Tok::Punct('[') => {
                if depth == 0 {
                    // we're inside a call's argument list — statement
                    // boundary search continues outside it; treat the
                    // opener's left as the boundary region
                    stmt_start = j + 1;
                    break;
                }
                depth -= 1;
            }
            Tok::Punct('{') => {
                if depth == 0 {
                    stmt_start = j + 1;
                    break;
                }
                depth -= 1;
            }
            Tok::Punct(';') => {
                if depth == 0 {
                    stmt_start = j + 1;
                    break;
                }
            }
            _ => {}
        }
    }
    let is_let = toks
        .get(stmt_start)
        .map(|t| t.kind.is_ident("let"))
        .unwrap_or(false);
    if is_let {
        // guard bound to a name: held to the end of the enclosing block
        return enclosing_block_end(file, acq_tok).unwrap_or(fn_end);
    }
    // temporary: held to the next `;` at depth 0, or through a `{...}`
    // block if one opens first (if-let / while-let / for / match heads)
    let mut k = call_close + 1;
    let mut d = 0i32;
    while k < fn_end {
        match toks[k].kind {
            Tok::Punct('(') | Tok::Punct('[') => d += 1,
            Tok::Punct(')') | Tok::Punct(']') => d -= 1,
            Tok::Punct(';') if d == 0 => return k,
            Tok::Punct('{') if d == 0 => {
                // scrutinee temporary lives through the block
                return file.matching(k).unwrap_or(fn_end);
            }
            Tok::Punct('}') if d == 0 => return k, // end of expr block
            _ => {}
        }
        k += 1;
    }
    fn_end
}

/// The `}` closing the innermost block containing `tok`.
fn enclosing_block_end(file: &SourceFile, tok: usize) -> Option<usize> {
    let toks = &file.tokens;
    let mut depth = 0i32;
    let mut k = tok;
    while k > 0 {
        k -= 1;
        match toks[k].kind {
            Tok::Punct('}') => depth += 1,
            Tok::Punct('{') => {
                if depth == 0 {
                    return file.matching(k);
                }
                depth -= 1;
            }
            _ => {}
        }
    }
    None
}

/// DFS cycle detection over the edge list; reports each cycle once,
/// anchored at its lexically-first witness edge.
fn report_cycles(edges: &[Edge]) -> Vec<Finding> {
    let mut adj: BTreeMap<&str, Vec<&Edge>> = BTreeMap::new();
    for e in edges {
        adj.entry(&e.from).or_default().push(e);
    }
    let mut findings = Vec::new();
    let mut reported: BTreeSet<String> = BTreeSet::new();
    let nodes: Vec<&str> = adj.keys().copied().collect();
    for &start in &nodes {
        let mut stack: Vec<(&str, Vec<&Edge>)> = vec![(start, Vec::new())];
        let mut visited: BTreeSet<&str> = BTreeSet::new();
        while let Some((node, path)) = stack.pop() {
            if let Some(outs) = adj.get(node) {
                for &e in outs {
                    if e.to == start {
                        // cycle closed
                        let mut cyc: Vec<&str> = path.iter().map(|p| p.from.as_str()).collect();
                        cyc.push(node);
                        cyc.push(&e.to);
                        // canonical key: sorted node set
                        let mut key_nodes: Vec<&str> = cyc.clone();
                        key_nodes.sort_unstable();
                        key_nodes.dedup();
                        let key = key_nodes.join(" ");
                        if reported.insert(key) {
                            let witness = path.first().copied().unwrap_or(e);
                            findings.push(Finding::new(
                                RULE_LOCK_ORDER,
                                &witness.file,
                                witness.line,
                                format!(
                                    "potential deadlock: lock-order cycle {}",
                                    cyc.join(" -> ")
                                ),
                            ));
                        }
                    } else if !path.iter().any(|p| p.from == e.to) && visited.insert(e.to.as_str())
                    {
                        let mut next = path.clone();
                        next.push(e);
                        stack.push((e.to.as_str(), next));
                    }
                }
            }
        }
    }
    findings
}


#[cfg(test)]
mod tests {
    use super::*;

    fn edges_of(files: &[(&str, &str)]) -> Vec<(String, String)> {
        let parsed: Vec<SourceFile> = files
            .iter()
            .map(|(rel, src)| SourceFile::parse(rel, rel, src))
            .collect();
        collect_edges(&parsed)
            .into_iter()
            .map(|e| (e.from, e.to))
            .collect()
    }

    #[test]
    fn nested_let_guards_make_edge() {
        let src = "fn f(&self) { let a = self.alpha.lock(); let b = self.beta.lock(); \
                   use_both(a, b); }";
        let es = edges_of(&[("m.rs", src)]);
        assert_eq!(es, vec![("m::alpha".to_string(), "m::beta".to_string())]);
    }

    #[test]
    fn sequential_temporaries_no_edge() {
        // guard dropped at each `;` — no nesting
        let src = "fn f(&self) { self.alpha.lock().push(1); self.beta.lock().push(2); }";
        assert!(edges_of(&[("m.rs", src)]).is_empty());
    }

    #[test]
    fn read_then_write_same_lock_no_edge() {
        // same id ⇒ no edge (reader/writer upgrade is a different bug
        // class, and our registry does read-drop-then-write correctly)
        let src = "fn f(&self) { if let Some(x) = self.map.read().get(k) { return x; } \
                   self.map.write().insert(k, v); }";
        assert!(edges_of(&[("m.rs", src)]).is_empty());
    }

    #[test]
    fn statics_unify_across_files() {
        let a = "fn f() { let g = LOCK_A.lock(); LOCK_B.lock().touch(); drop(g); }";
        let b = "fn g() { let h = LOCK_B.lock(); LOCK_A.lock().touch(); drop(h); }";
        let es = edges_of(&[("a.rs", a), ("b.rs", b)]);
        assert!(es.contains(&("LOCK_A".to_string(), "LOCK_B".to_string())));
        assert!(es.contains(&("LOCK_B".to_string(), "LOCK_A".to_string())));
        let parsed = vec![
            SourceFile::parse("a.rs", "a.rs", a),
            SourceFile::parse("b.rs", "b.rs", b),
        ];
        let findings = check_files(&parsed);
        assert_eq!(findings.len(), 1, "{findings:?}");
        assert!(findings[0].message.contains("cycle"));
    }

    #[test]
    fn io_write_with_args_not_an_acquisition() {
        let src = "fn f(w: &mut W, buf: &[u8]) { w.write(buf).ok(); \
                   w.inner.read_to_end(buf).ok(); }";
        assert!(edges_of(&[("m.rs", src)]).is_empty());
    }

    #[test]
    fn helper_calls_are_acquisitions() {
        let src = "fn f(&self) { let a = lock_or_recover(&self.alpha); \
                   read_or_recover(&self.beta).len(); drop(a); }";
        let es = edges_of(&[("m.rs", src)]);
        assert_eq!(es, vec![("m::alpha".to_string(), "m::beta".to_string())]);
    }

    #[test]
    fn indexed_receiver_contributes_container_name() {
        let src = "fn f(&self, k: usize) { self.stripes[k].lock().push(1); }";
        let parsed = SourceFile::parse("j.rs", "j.rs", src);
        let acqs = find_acquisitions(&parsed, "j", 0, parsed.tokens.len());
        assert_eq!(acqs.len(), 1);
        assert_eq!(acqs[0].id, "j::stripes");
    }

    #[test]
    fn test_code_skipped() {
        let src = "#[cfg(test)]\nmod tests { fn t(&self) { let a = self.x.lock(); \
                   self.y.lock(); } }";
        assert!(edges_of(&[("m.rs", src)]).is_empty());
    }

    #[test]
    fn three_lock_cycle_detected() {
        let a = "fn f() { let g = LOCK_A.lock(); LOCK_B.lock().t(); drop(g); }\n\
                 fn g() { let g = LOCK_B.lock(); LOCK_C.lock().t(); drop(g); }";
        let b = "fn h() { let g = LOCK_C.lock(); LOCK_A.lock().t(); drop(g); }";
        let parsed = vec![
            SourceFile::parse("a.rs", "a.rs", a),
            SourceFile::parse("b.rs", "b.rs", b),
        ];
        let findings = check_files(&parsed);
        assert_eq!(findings.len(), 1, "{findings:?}");
        assert!(findings[0].message.contains("LOCK_A"));
        assert!(findings[0].message.contains("LOCK_C"));
    }
}
