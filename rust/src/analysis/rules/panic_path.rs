//! Rule `panic_path`: no `unwrap()/expect()/panic!`-family macros or
//! unchecked indexing in serve-path modules.
//!
//! A panic on a serve path unwinds a tenant loop or poisons a shared
//! lock; everything the router/registry/tiering/obs layers do per
//! request must degrade, not die.  The rule covers exactly the modules
//! a request flows through; batch/experiment code (`exp/`, `sim/`,
//! `datasets/`...) may still unwrap.  Test code is always skipped.

use crate::analysis::lexer::Tok;
use crate::analysis::source::SourceFile;
use crate::analysis::{Finding, RULE_PANIC_PATH};

/// Module prefixes (relative to the src root) that constitute the
/// serve path.  A trailing `/` means a whole directory.
const SERVE_PATHS: &[&str] = &[
    "server/",
    "tenancy/router.rs",
    "tenancy/registry.rs",
    "tiering/service.rs",
    "tiering/controller.rs",
    "obs/",
];

/// Identifiers whose presence before `[` means the bracket is *not*
/// an index expression (slice patterns, `for x in xs[..]`, etc.).
const NON_RECEIVER_KEYWORDS: &[&str] = &[
    "return", "break", "in", "match", "if", "else", "loop", "while", "for", "move", "ref", "mut",
    "let", "as", "box", "vec",
];

pub fn applies(rel: &str) -> bool {
    SERVE_PATHS.iter().any(|p| {
        if let Some(dir) = p.strip_suffix('/') {
            rel.starts_with(dir) && rel.len() > dir.len()
        } else {
            rel == *p
        }
    })
}

pub fn check(file: &SourceFile) -> Vec<Finding> {
    if !applies(&file.rel) {
        return Vec::new();
    }
    let mut out = Vec::new();
    let toks = &file.tokens;
    for i in 0..toks.len() {
        if file.in_test(i) {
            continue;
        }
        let t = &toks[i];
        // `.unwrap(` / `.expect(` — exact idents, so unwrap_or /
        // unwrap_or_else / expect_err-free variants don't match.
        if let Some(name) = t.kind.ident() {
            if (name == "unwrap" || name == "expect")
                && i > 0
                && toks[i - 1].kind.is_punct('.')
                && toks.get(i + 1).map(|n| n.kind.is_punct('(')).unwrap_or(false)
            {
                out.push(Finding::new(
                    RULE_PANIC_PATH,
                    &file.rel,
                    t.line,
                    format!(
                        ".{name}() on a serve path can panic; \
                         handle the error or use util::sync helpers"
                    ),
                ));
                continue;
            }
            // panic-family macros
            if matches!(name, "panic" | "unreachable" | "todo" | "unimplemented")
                && toks.get(i + 1).map(|n| n.kind.is_punct('!')).unwrap_or(false)
            {
                out.push(Finding::new(
                    RULE_PANIC_PATH,
                    &file.rel,
                    t.line,
                    format!(
                        "{name}! on a serve path aborts the request loop; \
                         return an error instead"
                    ),
                ));
                continue;
            }
        }
        // unchecked indexing: `expr[index]` where expr ends in an
        // identifier / `)` / `]` and the index is not a bare integer
        // literal or a pure range.
        if t.kind.is_punct('[') {
            let is_index_expr = match i.checked_sub(1).map(|p| &toks[p].kind) {
                Some(Tok::Ident(name)) => !NON_RECEIVER_KEYWORDS.contains(&name.as_str()),
                Some(Tok::Punct(')')) | Some(Tok::Punct(']')) => true,
                _ => false,
            };
            if !is_index_expr {
                continue;
            }
            let Some(close) = file.matching(i) else { continue };
            let inner = &toks[i + 1..close];
            if inner.is_empty() {
                continue; // `[]` — type position
            }
            // bare integer literal index (tuple-struct-like fixed access)
            // is fine: `bounds[0]` can only be wrong if the array is
            // empty, which the type system rules out for our arrays.
            if inner.len() == 1 {
                if let Tok::Num(_) = inner[0].kind {
                    continue;
                }
            }
            // range slicing (`[..]`, `[a..b]`, `[..=n]`) is recognised
            // by two *adjacent* dot tokens; bounds are usually checked
            // `len()` values, so we only flag direct element indexing.
            let is_range = inner
                .windows(2)
                .any(|w| w[0].kind.is_punct('.') && w[1].kind.is_punct('.'));
            if is_range {
                continue;
            }
            out.push(Finding::new(
                RULE_PANIC_PATH,
                &file.rel,
                t.line,
                "unchecked indexing on a serve path can panic; use .get()/.get_mut()".to_string(),
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn findings(rel: &str, src: &str) -> Vec<Finding> {
        let f = SourceFile::parse(rel, rel, src);
        check(&f)
    }

    #[test]
    fn scope_limited_to_serve_paths() {
        assert!(applies("server/mod.rs"));
        assert!(applies("obs/journal.rs"));
        assert!(applies("tenancy/router.rs"));
        assert!(!applies("tenancy/governor.rs"));
        assert!(!applies("exp/mod.rs"));
        assert!(!applies("server")); // the bare dir name is not a file
    }

    #[test]
    fn flags_unwrap_and_expect() {
        let fs = findings("server/mod.rs", "fn f() { x.unwrap(); y.expect(\"m\"); }");
        assert_eq!(fs.len(), 2);
        assert_eq!(fs[0].line, 1);
    }

    #[test]
    fn unwrap_or_is_fine() {
        let fs = findings(
            "server/mod.rs",
            "fn f() { x.unwrap_or(0); y.unwrap_or_else(|| 1); z.unwrap_or_default(); }",
        );
        assert!(fs.is_empty(), "{fs:?}");
    }

    #[test]
    fn flags_panic_macros() {
        let fs = findings(
            "obs/mod.rs",
            "fn f() { panic!(\"no\"); unreachable!(); todo!(); }",
        );
        assert_eq!(fs.len(), 3);
    }

    #[test]
    fn flags_indexing_but_not_literals_or_ranges() {
        let fs = findings("server/mod.rs", "fn f(v: &[u8], i: usize) { let _ = v[i]; }");
        assert_eq!(fs.len(), 1);
        let fs = findings("server/mod.rs", "fn f(v: &[u8]) { let _ = v[0]; }");
        assert!(fs.is_empty());
        let fs = findings("server/mod.rs", "fn f(v: &[u8], n: usize) { let _ = &v[..n]; }");
        assert!(fs.is_empty());
        // dots from method calls inside the index do not read as a range
        let fs = findings("server/mod.rs", "fn f(v: &[u8], i: usize) { v[i.min(v.len() - 1)]; }");
        assert_eq!(fs.len(), 1);
    }

    #[test]
    fn skips_test_modules_and_attr_slices() {
        let src = "#[cfg(test)]\nmod tests { fn t() { x.unwrap(); v[i]; } }";
        assert!(findings("server/mod.rs", src).is_empty());
        // `#[derive(Debug)]` style attribute brackets are not indexing
        let fs = findings("server/mod.rs", "#[derive(Debug)]\nstruct S;");
        assert!(fs.is_empty());
    }

    #[test]
    fn chained_call_receiver_indexing_flagged() {
        let fs = findings("server/mod.rs", "fn f() { g()[h]; }");
        assert_eq!(fs.len(), 1);
    }
}
